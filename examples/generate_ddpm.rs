//! Generation example (paper Table 5 / Fig. 3): train a tiny DDPM with
//! ssProp convolutions on synth-MNIST, sample with the rust ancestral
//! sampler, score with the FID-proxy, and write a sample grid.
//!
//! Requires `--features pjrt` + artifacts (`make artifacts`):
//!
//! ```bash
//! cargo run --release --features pjrt --example generate_ddpm -- --iters 200
//! ```

use anyhow::Result;

#[cfg(feature = "pjrt")]
mod pjrt_example {
    use anyhow::Result;
    use ssprop::ddpm::{write_pgm_grid, DdpmTrainer};
    use ssprop::metrics::fid_proxy;
    use ssprop::runtime::Engine;
    use ssprop::schedule::{DropScheduler, Schedule};
    use ssprop::util::cli::Args;

    pub fn run() -> Result<()> {
        let args = Args::from_env();
        let iters = args.get_usize("iters", 200);
        let dataset = args.get_or("dataset", "mnist").to_string();
        let engine = Engine::auto()?;

        println!("== DDPM on synth-{dataset}: dense vs ssProp ({iters} iters each) ==\n");
        std::fs::create_dir_all("results")?;

        for (label, schedule, target) in [
            ("dense", Schedule::Constant, 0.0),
            ("ssprop", Schedule::EpochBar { period_epochs: 2 }, 0.8),
        ] {
            let mut tr = DdpmTrainer::new(&engine, &dataset, 2e-3, 0)?;
            let sched = DropScheduler::new(schedule, target, 2, iters.div_ceil(2).max(1));
            let loss = tr.train(iters, &sched)?;
            let samples = tr.sample(7)?;
            let real = tr.real_batch(128);
            let fid = fid_proxy(&real, &samples, 1234);
            let man = tr.denoise_graph.manifest.clone();
            let path = format!("results/ddpm_{dataset}_{label}.pgm");
            write_pgm_grid(&path, &samples, man.img, man.channels)?;
            let m = &tr.metrics;
            println!(
                "{label:<7} loss {loss:.4}  FID-proxy {fid:.4}  bwd FLOPs {:.3e} \
                 ({:.1}% saved)  wall {:.1}s  -> {path}",
                m.flops_actual,
                m.flops_saving() * 100.0,
                m.total_wall_secs()
            );
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn run() -> Result<()> {
    pjrt_example::run()
}

#[cfg(not(feature = "pjrt"))]
fn run() -> Result<()> {
    println!("generate_ddpm drives PJRT artifacts; rebuild with --features pjrt");
    Ok(())
}

fn main() -> Result<()> {
    run()
}
