//! Training metrics: loss/acc curves, FLOPs ledger (dense-equivalent vs
//! actual under the schedule), wall-clock, and energy estimates. Keyed on
//! the conv/BN/dropout inventory ([`LayerSet`]) rather than any runtime's
//! manifest, so native and PJRT trainers share one ledger — the native
//! trainer derives the inventory from the *live* model graph
//! (`Graph::layer_set`), which keeps the savings correct for every zoo
//! preset, BatchNorm terms and residual projections included.

use std::time::Duration;

use crate::energy::{estimate, DeviceProfile, EnergyReport};
use crate::flops::LayerSet;

/// Rolling record of one training run (see module docs).
#[derive(Debug, Default, Clone)]
pub struct TrainMetrics {
    /// Per-iteration training loss.
    pub losses: Vec<f64>,
    /// Per-iteration training accuracy.
    pub accs: Vec<f64>,
    /// Per-iteration scheduled drop rate.
    pub drop_rates: Vec<f64>,
    /// (epoch, test loss, test acc)
    pub evals: Vec<(usize, f64, f64)>,
    /// Wall-clock seconds per epoch.
    pub epoch_secs: Vec<f64>,
    /// Backward FLOPs if every iteration had been dense (Eq. 6).
    pub flops_dense: f64,
    /// Backward FLOPs actually incurred under the schedule (Eq. 9).
    pub flops_actual: f64,
}

impl TrainMetrics {
    /// Record one training iteration: curves + the FLOPs ledger update.
    pub fn record_iter(
        &mut self,
        loss: f64,
        acc: f64,
        drop_rate: f64,
        layers: &LayerSet,
        bt: usize,
    ) {
        self.losses.push(loss);
        self.accs.push(acc);
        self.drop_rates.push(drop_rate);
        self.flops_dense += layers.bwd_flops_per_iter(bt, 0.0);
        self.flops_actual += layers.bwd_flops_per_iter(bt, drop_rate);
    }

    /// Record one epoch's wall-clock time.
    pub fn record_epoch(&mut self, wall: Duration) {
        self.epoch_secs.push(wall.as_secs_f64());
    }

    /// Record a test-split evaluation at `epoch`.
    pub fn record_eval(&mut self, epoch: usize, loss: f64, acc: f64) {
        self.evals.push((epoch, loss, acc));
    }

    /// Mean training loss over the last `ipe` iterations.
    pub fn last_epoch_loss(&self, ipe: usize) -> f64 {
        mean_tail(&self.losses, ipe)
    }

    /// Mean training accuracy over the last `ipe` iterations.
    pub fn last_epoch_acc(&self, ipe: usize) -> f64 {
        mean_tail(&self.accs, ipe)
    }

    /// Most recent recorded test accuracy (NaN when never evaluated).
    pub fn final_test_acc(&self) -> f64 {
        self.evals.last().map(|e| e.2).unwrap_or(f64::NAN)
    }

    /// Most recent recorded test loss (NaN when never evaluated).
    pub fn final_test_loss(&self) -> f64 {
        self.evals.last().map(|e| e.1).unwrap_or(f64::NAN)
    }

    /// Fraction of backward FLOPs saved vs dense training.
    pub fn flops_saving(&self) -> f64 {
        if self.flops_dense <= 0.0 {
            0.0
        } else {
            1.0 - self.flops_actual / self.flops_dense
        }
    }

    /// Total recorded wall-clock time, seconds.
    pub fn total_wall_secs(&self) -> f64 {
        self.epoch_secs.iter().sum()
    }

    /// Energy the *saved* FLOPs would have cost on `dev`.
    pub fn energy_saved(&self, dev: &DeviceProfile) -> EnergyReport {
        estimate(self.flops_dense - self.flops_actual, dev)
    }

    /// Mean drop rate realized over training (≈ target/2 under bar-2-epoch).
    pub fn mean_drop_rate(&self) -> f64 {
        if self.drop_rates.is_empty() {
            0.0
        } else {
            self.drop_rates.iter().sum::<f64>() / self.drop_rates.len() as f64
        }
    }
}

fn mean_tail(v: &[f64], n: usize) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let tail = &v[v.len().saturating_sub(n)..];
    tail.iter().sum::<f64>() / tail.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{build_model, parse_model_spec};

    /// The ledger inventory of a *live* zoo graph — the same derivation
    /// the trainer uses (`Graph::layer_set`), not a hand-maintained conv
    /// list that could drift from the model actually trained.
    fn live_layers() -> LayerSet {
        let spec = parse_model_spec("simple-cnn-d2-w16").unwrap();
        build_model(&spec, 3, 8, 4, 1).unwrap().layer_set()
    }

    #[test]
    fn flops_ledger_tracks_schedule() {
        let layers = live_layers();
        assert_eq!(layers.convs.len(), 2, "the live graph feeds the ledger");
        let mut m = TrainMetrics::default();
        m.record_iter(1.0, 0.1, 0.0, &layers, 8);
        m.record_iter(0.9, 0.2, 0.8, &layers, 8);
        assert!(m.flops_actual < m.flops_dense);
        let saving = m.flops_saving();
        assert!(saving > 0.3 && saving < 0.5, "saving {saving}");
        assert!((m.mean_drop_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn dense_only_run_saves_nothing() {
        let layers = live_layers();
        let mut m = TrainMetrics::default();
        for _ in 0..4 {
            m.record_iter(1.0, 0.5, 0.0, &layers, 8);
        }
        assert_eq!(m.flops_saving(), 0.0);
    }

    #[test]
    fn tail_means() {
        let mut m = TrainMetrics::default();
        let layers = live_layers();
        for (i, l) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            m.record_iter(*l, i as f64, 0.0, &layers, 8);
        }
        assert_eq!(m.last_epoch_loss(2), 1.5);
        assert_eq!(m.last_epoch_acc(2), 2.5);
    }

    #[test]
    fn eval_bookkeeping() {
        let mut m = TrainMetrics::default();
        m.record_eval(0, 2.0, 0.3);
        m.record_eval(1, 1.0, 0.6);
        assert_eq!(m.final_test_acc(), 0.6);
        assert_eq!(m.final_test_loss(), 1.0);
    }
}
