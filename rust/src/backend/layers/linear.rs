//! Dense layers: `Flatten` (NCHW is already contiguous per example, so it
//! is a pure relabeling) and `Linear`, the classifier head. `Linear`'s
//! forward GEMM + bias and its backward loops are the historical
//! SimpleCNN head computation, loop-for-loop, so a `Sequential`-built
//! SimpleCNN replays the legacy model bitwise.

use anyhow::{bail, Result};

use super::{BwdOut, FwdCtx, Layer, LayerWs, ParamView, Selection, Shape};
use crate::backend::Backend;
use crate::util::rng::Pcg;

/// Reshape a (C, H, W) feature map to a flat C·H·W vector. NCHW batches
/// are row-major per example, so the data is copied unchanged.
#[derive(Debug, Clone, Copy)]
pub struct Flatten {
    c: usize,
    h: usize,
    w: usize,
}

impl Flatten {
    /// A flatten over `(c, h, w)` feature maps.
    pub fn new(c: usize, h: usize, w: usize) -> Flatten {
        Flatten { c, h, w }
    }
}

impl Layer for Flatten {
    fn describe(&self) -> String {
        "flatten".to_string()
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        match *input {
            Shape::Spatial { c, h, w } if (c, h, w) == (self.c, self.h, self.w) => {
                Ok(Shape::Flat { features: self.c * self.h * self.w })
            }
            other => {
                let want = (self.c, self.h, self.w);
                bail!("flatten built for {want:?} input, got {other:?}")
            }
        }
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        x.to_vec()
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        _x: &[f32],
        g: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        BwdOut { dx: g.to_vec(), ..BwdOut::default() }
    }
}

/// Fully-connected layer `y = x · W + b` with `W` stored `(in, out)`
/// row-major — the layout the historical `fc_w` used.
#[derive(Debug, Clone)]
pub struct Linear {
    in_f: usize,
    out_f: usize,
    w: Vec<f32>,
    b: Vec<f32>,
}

impl Linear {
    /// He-initialize an `in_f -> out_f` linear layer from the shared model
    /// RNG (same scale and draw order as the historical classifier head).
    pub fn init(rng: &mut Pcg, in_f: usize, out_f: usize) -> Linear {
        assert!(in_f >= 1 && out_f >= 1, "degenerate linear geometry");
        let scale = (2.0 / in_f as f32).sqrt();
        Linear {
            in_f,
            out_f,
            w: (0..in_f * out_f).map(|_| rng.normal() * scale).collect(),
            b: vec![0f32; out_f],
        }
    }
}

impl Layer for Linear {
    fn describe(&self) -> String {
        format!("fc {}->{}", self.in_f, self.out_f)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        match *input {
            Shape::Flat { features } if features == self.in_f => {
                Ok(Shape::Flat { features: self.out_f })
            }
            other => bail!("fc built for {} flat features, got {other:?}", self.in_f),
        }
    }

    fn forward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        assert_eq!(x.len(), bt * self.in_f, "linear input length");
        let mut y = be.gemm(bt, self.in_f, self.out_f, x, &self.w);
        for bi in 0..bt {
            for (c, &bias) in self.b.iter().enumerate() {
                y[bi * self.out_f + c] += bias;
            }
        }
        y
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        let (inf, outf) = (self.in_f, self.out_f);
        // dx = g · Wᵀ, the historical head_backward's first loop
        let dx = if need_dx {
            let mut dx = vec![0f32; bt * inf];
            for b in 0..bt {
                let drow = &g[b * outf..][..outf];
                for f in 0..inf {
                    let wrow = &self.w[f * outf..][..outf];
                    let mut acc = 0f32;
                    for (dv, wv) in drow.iter().zip(wrow) {
                        acc += dv * wv;
                    }
                    dx[b * inf + f] = acc;
                }
            }
            dx
        } else {
            Vec::new()
        };
        // dW = xᵀ · g, db = column sums — the historical second loop
        let mut dw = vec![0f32; inf * outf];
        let mut db = vec![0f32; outf];
        for b in 0..bt {
            let drow = &g[b * outf..][..outf];
            let prow = &x[b * inf..][..inf];
            for (f, &pv) in prow.iter().enumerate() {
                let dst = &mut dw[f * outf..][..outf];
                for (dwv, &dv) in dst.iter_mut().zip(drow) {
                    *dwv += pv * dv;
                }
            }
            for (dbv, &dv) in db.iter_mut().zip(drow) {
                *dbv += dv;
            }
        }
        BwdOut { dx, grads: vec![dw, db], kept: 0 }
    }

    fn params(&self) -> Vec<ParamView<'_>> {
        vec![
            ParamView { field: "w", data: &self.w, shape: vec![self.in_f, self.out_f] },
            ParamView { field: "b", data: &self.b, shape: vec![self.out_f] },
        ]
    }

    fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![&mut self.w, &mut self.b]
    }

    fn load_param(&mut self, field: &str, vals: Vec<f32>) -> Result<()> {
        let dst = match field {
            "w" => &mut self.w,
            "b" => &mut self.b,
            other => bail!("unknown fc field {other:?}"),
        };
        if dst.len() != vals.len() {
            bail!("shape mismatch: {} vs {}", vals.len(), dst.len());
        }
        *dst = vals;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn ctx() -> FwdCtx {
        FwdCtx { train: true, step: 0, example_offset: 0 }
    }

    #[test]
    fn flatten_is_identity_data() {
        let be = NativeBackend::new();
        let f = Flatten::new(2, 2, 2);
        let out = f.out_shape(&Shape::Spatial { c: 2, h: 2, w: 2 }).unwrap();
        assert_eq!(out, Shape::Flat { features: 8 });
        assert!(f.out_shape(&Shape::Flat { features: 8 }).is_err());
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut ws = LayerWs::default();
        assert_eq!(f.forward(&be, &x, 2, &mut ws, &ctx()), x);
        let back = f.backward(&be, &x, &x, 2, &mut ws, Selection::Local(0.0), true);
        assert_eq!(back.dx, x);
    }

    #[test]
    fn linear_forward_hand_checked() {
        let be = NativeBackend::new();
        let mut rng = Pcg::new(1, 1);
        let mut l = Linear::init(&mut rng, 3, 2);
        l.load_param("w", vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]).unwrap();
        l.load_param("b", vec![0.5, -0.5]).unwrap();
        let mut ws = LayerWs::default();
        let y = l.forward(&be, &[1.0, 2.0, 3.0], 1, &mut ws, &ctx());
        assert_eq!(y, vec![14.5, 31.5]);
    }

    #[test]
    fn linear_backward_gradients() {
        let be = NativeBackend::new();
        let mut rng = Pcg::new(2, 1);
        let l = Linear::init(&mut rng, 2, 2);
        let x = vec![1.0, 2.0, 3.0, 4.0]; // bt 2
        let g = vec![0.5, -0.5, 1.0, 1.0];
        let mut ws = LayerWs::default();
        let out = l.backward(&be, &x, &g, 2, &mut ws, Selection::Local(0.0), true);
        // db = column sums of g
        assert_eq!(out.grads[1], vec![1.5, 0.5]);
        // dw[f][c] = sum_b x[b][f] * g[b][c]
        assert_eq!(out.grads[0], vec![0.5 + 3.0, -0.5 + 3.0, 1.0 + 4.0, -1.0 + 4.0]);
        // dx[b][f] = sum_c g[b][c] * w[f][c]
        let ps = l.params();
        let w = &ps[0];
        let want00 = 0.5 * w.data[0] - 0.5 * w.data[1];
        assert!((out.dx[0] - want00).abs() < 1e-6);
        let skipped = l.backward(&be, &x, &g, 2, &mut ws, Selection::Local(0.0), false);
        assert!(skipped.dx.is_empty());
        assert_eq!(skipped.grads, out.grads);
    }

    #[test]
    fn linear_param_errors() {
        let mut rng = Pcg::new(3, 1);
        let mut l = Linear::init(&mut rng, 4, 2);
        assert!(l.load_param("w", vec![0.0; 3]).is_err());
        assert!(l.load_param("nope", vec![0.0]).is_err());
        assert_eq!(l.params()[0].shape, vec![4, 2]);
        assert_eq!(l.describe(), "fc 4->2");
    }
}
