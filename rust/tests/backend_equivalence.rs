//! Backend equivalence suite: the fused plan/workspace path vs the
//! op-level (unfused) route. The plan path reuses the forward's im2col
//! columns and a borrowed scratch, so these tests pin it to the
//! fresh-allocation reference (`sparse_bwd_compact`) over randomized
//! geometries, prove `need_dx = false` is a pure subset of the full
//! backward, and regression-test that consecutive `train_step`s reuse
//! every plan buffer without changing the loss trajectory. Also pins the
//! blocked GEMM microkernel to the naive reference over randomized
//! shapes (dense within 1e-6·k, the sparsity-aware kept-channel views
//! exact), pins every SIMD kernel at every B-panel width *bitwise* to the
//! portable scalar kernel (awkward column counts, keep counts straddling
//! both widths, and random shapes), and proves the always-on stale-cols
//! guard trips on a backward against a different input's cached columns.

use ssprop::backend::gemm::{
    gemm, gemm_into, gemm_into_tiled, gemm_ref, GemmPack, Kernel, Operand, NR, NR2,
};
use ssprop::backend::sparse::{select_channels, sparse_bwd_compact};
use ssprop::backend::{simple_cnn, Backend, Conv2d, Conv2dPlan, NativeBackend, SimpleCnnCfg};
use ssprop::util::prop::check_no_shrink;
use ssprop::util::rng::Pcg;

/// One randomized property case: geometry (stride ∈ {1,2}, padding ∈
/// {0,1}, k ∈ {1,3,5}, H ≠ W), drop rate, and a data seed.
#[derive(Debug, Clone)]
struct Case {
    cfg: Conv2d,
    drop_rate: f64,
    seed: u64,
}

fn gen_case(r: &mut Pcg) -> Case {
    let k = [1usize, 3, 5][r.below(3) as usize];
    let h = k + r.below(5) as usize;
    let mut w = k + r.below(5) as usize;
    if w == h {
        w += 1; // the suite must cover rectangular inputs (H ≠ W)
    }
    let cfg = Conv2d {
        bt: 1 + r.below(2) as usize,
        cin: 1 + r.below(3) as usize,
        h,
        w,
        cout: 1 + r.below(6) as usize,
        k,
        stride: 1 + r.below(2) as usize,
        padding: r.below(2) as usize,
    };
    let drop_rate = [0.0, 0.25, 0.5, 0.8][r.below(4) as usize];
    Case { cfg, drop_rate, seed: r.next_u64() }
}

fn case_data(case: &Case) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let c = &case.cfg;
    let mut rng = Pcg::new(case.seed, 17);
    let x: Vec<f32> = (0..c.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..c.w_len()).map(|_| rng.normal() * 0.2).collect();
    let b: Vec<f32> = (0..c.cout).map(|_| rng.normal() * 0.1).collect();
    let g: Vec<f32> = (0..c.out_len()).map(|_| rng.normal()).collect();
    (x, w, b, g)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// One randomized GEMM shape (deliberately small and odd, so edges of the
/// MR×NR register tile are hit constantly) plus a data seed.
#[derive(Debug, Clone)]
struct GemmCase {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

fn gen_gemm(r: &mut Pcg) -> GemmCase {
    GemmCase {
        m: 1 + r.below(40) as usize,
        k: 1 + r.below(40) as usize,
        n: 1 + r.below(40) as usize,
        seed: r.next_u64(),
    }
}

#[test]
fn blocked_gemm_matches_reference_over_random_shapes() {
    check_no_shrink("gemm-eq-ref", 96, gen_gemm, |c| {
        let mut rng = Pcg::new(c.seed, 3);
        let a: Vec<f32> = (0..c.m * c.k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..c.k * c.n).map(|_| rng.normal()).collect();
        let got = gemm(c.m, c.k, c.n, &a, &b);
        let want = gemm_ref(c.m, c.k, c.n, &a, &b);
        max_abs_diff(&got, &want) <= 1e-6 * c.k as f32
    });
}

#[test]
fn blocked_gemm_matches_reference_at_tile_and_block_edges() {
    // Fixed shapes straddling the microkernel's blocking constants:
    // multiples and non-multiples of MR=4/NR=8, and sizes crossing the
    // KC=256 depth block and MC=64 row block.
    let mut rng = Pcg::new(0xB10C, 7);
    for (m, k, n) in [(4, 8, 8), (5, 9, 9), (64, 256, 8), (65, 257, 17), (130, 300, 33)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let got = gemm(m, k, n, &a, &b);
        let want = gemm_ref(m, k, n, &a, &b);
        let diff = max_abs_diff(&got, &want);
        assert!(diff <= 1e-6 * k as f32, "({m},{k},{n}): diff {diff}");
    }
}

/// The kernels runnable on this host, in dispatch-preference order —
/// always at least [`Kernel::Scalar`].
fn runnable_kernels() -> Vec<Kernel> {
    Kernel::ALL.into_iter().filter(|k| k.available()).collect()
}

#[test]
fn simd_kernels_and_tile_widths_agree_bitwise_on_awkward_column_counts() {
    // Output-column counts with n mod 16 ∈ {1, 7, 9, 15}: both below and
    // above one wide panel, so every kernel hits partial NR8 *and* NR16
    // edge tiles. k = 37 fits one depth block, so every kernel × width
    // must be bitwise equal to the naive reference outright.
    let kernels = runnable_kernels();
    let mut rng = Pcg::new(0x51D0, 13);
    let mut pack = GemmPack::new();
    for n in [1usize, 7, 9, 15, 17, 23, 41, 63] {
        let (m, k) = (13usize, 37usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = gemm_ref(m, k, n, &a, &b);
        for &kernel in &kernels {
            for nr in [NR, NR2] {
                let mut got = Vec::new();
                gemm_into_tiled(
                    m,
                    k,
                    n,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut got,
                    &mut pack,
                    kernel,
                    nr,
                );
                assert_eq!(got, want, "({m},{k},{n}) {kernel:?} nr={nr} vs reference");
            }
        }
    }
}

#[test]
fn kept_channel_keep_counts_agree_bitwise_across_kernels_and_widths() {
    // The dW GEMM's output-column count IS the keep count; pin every
    // kernel × width on keep sets straddling both panel widths:
    // {0, 1, NR−1, NR, NR+1, all}. The anchor is the naive reference on
    // explicitly gathered matrices (K = bt·hw fits one depth block).
    let kernels = runnable_kernels();
    let (bt, hw, cout, np) = (2usize, 9, NR + 4, 11usize);
    let m = bt * hw;
    let mut rng = Pcg::new(0xD3, 19);
    let cols: Vec<f32> = (0..m * np).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..bt * cout * hw).map(|_| rng.normal()).collect();
    let mut pack = GemmPack::new();
    for kp in [0usize, 1, NR - 1, NR, NR + 1, cout] {
        let keep: Vec<usize> = (0..kp).collect();
        // colsᵀ (np × m), explicitly materialized for the reference
        let mut at = vec![0f32; np * m];
        for r in 0..m {
            for c in 0..np {
                at[c * m + r] = cols[r * np + c];
            }
        }
        // explicit (m × kp) gather of the kept gradient channels
        let mut gck = vec![0f32; m * kp];
        for b in 0..bt {
            for (pos, &o) in keep.iter().enumerate() {
                for pix in 0..hw {
                    gck[(b * hw + pix) * kp + pos] = g[(b * cout + o) * hw + pix];
                }
            }
        }
        let want = gemm_ref(np, m, kp, &at, &gck);
        for &kernel in &kernels {
            for nr in [NR, NR2] {
                let mut got = Vec::new();
                gemm_into_tiled(
                    np,
                    m,
                    kp,
                    Operand::Transposed(&cols),
                    Operand::KeptChannels { g: &g, keep: &keep, cout, hw },
                    &mut got,
                    &mut pack,
                    kernel,
                    nr,
                );
                assert_eq!(got, want, "kp={kp} {kernel:?} nr={nr} vs gathered reference");
            }
        }
    }
}

#[test]
fn all_kernels_and_widths_are_bitwise_equal_over_random_shapes() {
    // Kernel and panel width are pure dispatch choices: over random
    // shapes every combination must produce the same bits (the scalar
    // NR=8 result is the anchor; k may exceed one depth block here, so
    // the naive reference is deliberately NOT consulted).
    let kernels = runnable_kernels();
    check_no_shrink("gemm-kernel-eq", 64, gen_gemm, |c| {
        let mut rng = Pcg::new(c.seed, 3);
        let a: Vec<f32> = (0..c.m * c.k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..c.k * c.n).map(|_| rng.normal()).collect();
        let mut pack = GemmPack::new();
        let mut anchor: Option<Vec<f32>> = None;
        for &kernel in &kernels {
            for nr in [NR, NR2] {
                let mut got = Vec::new();
                gemm_into_tiled(
                    c.m,
                    c.k,
                    c.n,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut got,
                    &mut pack,
                    kernel,
                    nr,
                );
                match &anchor {
                    None => anchor = Some(got),
                    Some(w) => {
                        if &got != w {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

#[test]
fn kept_channel_gemm_is_exact_vs_explicit_gather() {
    // The sparsity-aware views (KeptChannels lhs, KeptRows rhs — the dX
    // GEMM's shape) must be *bitwise* equal to running the dense kernel
    // on explicitly gathered matrices: same kernel, same accumulation
    // order, the gather is only fused into packing. Covers empty keep,
    // all-kept, and the paper's D=0.5 selection.
    check_no_shrink("sparse-gemm-exact", 64, gen_case, |case| {
        let c = case.cfg;
        let (_, w, _, g) = case_data(case);
        let hw = c.hout() * c.wout();
        let (m, n) = (c.bt * hw, c.n());
        let mut pk = GemmPack::new();
        let all: Vec<usize> = (0..c.cout).collect();
        for keep in [Vec::new(), all, select_channels(&c, &g, 0.5)] {
            let kp = keep.len();
            // explicit (M, k') gather of the kept gradient channels
            let mut gck = vec![0f32; m * kp];
            for b in 0..c.bt {
                for (pos, &o) in keep.iter().enumerate() {
                    for pix in 0..hw {
                        gck[(b * hw + pix) * kp + pos] = g[(b * c.cout + o) * hw + pix];
                    }
                }
            }
            // explicit (k', N) gather of the kept OIHW weight rows
            let mut wk = vec![0f32; kp * n];
            for (pos, &o) in keep.iter().enumerate() {
                wk[pos * n..][..n].copy_from_slice(&w[o * n..][..n]);
            }
            let gview = Operand::KeptChannels { g: &g, keep: &keep, cout: c.cout, hw };
            let wview = Operand::KeptRows { data: &w, keep: &keep };
            let mut got = Vec::new();
            gemm_into(m, kp, n, gview, wview, &mut got, &mut pk);
            if got != gemm(m, kp, n, &gck, &wk) {
                return false;
            }
        }
        true
    });
}

#[test]
#[should_panic(expected = "plan cols were cached from a different input")]
fn backward_on_different_input_trips_the_stale_cols_guard() {
    // Always-on guard (not a debug_assert): forward on one input, then a
    // backward against another input's x must fail loudly instead of
    // silently computing dW from the wrong cached columns.
    let be = NativeBackend::new();
    let cfg = Conv2d { bt: 1, cin: 1, h: 4, w: 4, cout: 2, k: 3, stride: 1, padding: 1 };
    let mut rng = Pcg::new(11, 4);
    let x1: Vec<f32> = (0..cfg.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cfg.w_len()).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..cfg.out_len()).map(|_| rng.normal()).collect();
    let mut plan = Conv2dPlan::new(cfg);
    be.conv2d_fwd_planned(&mut plan, &x1, &w, None);
    let mut x2 = x1.clone();
    *x2.last_mut().unwrap() += 1.0;
    be.conv2d_bwd_planned_with(&mut plan, &x2, &w, &g, &[0, 1], true);
}

#[test]
fn fused_plan_path_matches_unfused_over_random_geometries() {
    let be = NativeBackend::new();
    check_no_shrink("fused-eq-unfused", 96, gen_case, |case| {
        let c = case.cfg;
        let (x, w, b, g) = case_data(case);
        let mut plan = Conv2dPlan::new(c);
        let (y, grads) = be.conv2d_fwd_bwd(&mut plan, &x, &w, Some(&b), &g, case.drop_rate, true);
        if plan.cols_builds() != 1 {
            return false; // the fused pair must share one im2col build
        }
        // forward: identical to the op-level route
        if y != be.conv2d_fwd(&c, &x, &w, Some(&b)) {
            return false;
        }
        if case.drop_rate == 0.0 {
            // dense: match the unfused dense gradients within 1e-6
            let all: Vec<usize> = (0..c.cout).collect();
            let dense = sparse_bwd_compact(&c, &x, &w, &g, &all, true);
            grads.keep_idx == all
                && max_abs_diff(&grads.dx, &dense.dx) < 1e-6
                && max_abs_diff(&grads.dw, &dense.dw) < 1e-6
                && max_abs_diff(&grads.db, &dense.db) < 1e-6
        } else {
            // sparse: match the old sparse_bwd_compact exactly
            let keep = select_channels(&c, &g, case.drop_rate);
            let want = sparse_bwd_compact(&c, &x, &w, &g, &keep, true);
            grads.keep_idx == keep
                && grads.dx == want.dx
                && grads.dw == want.dw
                && grads.db == want.db
        }
    });
}

#[test]
fn repeated_fused_calls_on_one_plan_are_deterministic() {
    // Buffer reuse across fused calls must not leak state between calls.
    let be = NativeBackend::new();
    let mut rng = Pcg::new(0xBEEF, 5);
    let mut plan: Option<Conv2dPlan> = None;
    let case = Case {
        cfg: Conv2d { bt: 2, cin: 2, h: 6, w: 5, cout: 4, k: 3, stride: 1, padding: 1 },
        drop_rate: 0.5,
        seed: rng.next_u64(),
    };
    let (x, w, b, g) = case_data(&case);
    let mut outs = Vec::new();
    for _ in 0..3 {
        let p = plan.get_or_insert_with(|| Conv2dPlan::new(case.cfg));
        outs.push(be.conv2d_fwd_bwd(p, &x, &w, Some(&b), &g, case.drop_rate, true));
    }
    let (y0, g0) = &outs[0];
    for (y, gr) in &outs[1..] {
        assert_eq!(y, y0, "forward must be identical across reused calls");
        assert_eq!(gr.dx, g0.dx, "dx must be identical across reused calls");
        assert_eq!(gr.dw, g0.dw, "dw must be identical across reused calls");
        assert_eq!(gr.db, g0.db, "db must be identical across reused calls");
    }
    assert_eq!(plan.unwrap().cols_builds(), 3);
}

#[test]
fn skipping_dx_is_bit_identical_on_fused_and_unfused_routes() {
    let be = NativeBackend::new();
    check_no_shrink("need-dx-subset", 48, gen_case, |case| {
        let c = case.cfg;
        let (x, w, b, g) = case_data(case);

        // unfused route
        let full = be.conv2d_bwd_ssprop(&c, &x, &w, &g, case.drop_rate, true);
        let nodx = be.conv2d_bwd_ssprop(&c, &x, &w, &g, case.drop_rate, false);
        if !(nodx.dx.is_empty() && nodx.dw == full.dw && nodx.db == full.db) {
            return false;
        }

        // fused route (fresh plans so both calls see the same cache state)
        let mut pa = Conv2dPlan::new(c);
        let mut pb = Conv2dPlan::new(c);
        let (_, ffull) = be.conv2d_fwd_bwd(&mut pa, &x, &w, Some(&b), &g, case.drop_rate, true);
        let (_, fnodx) = be.conv2d_fwd_bwd(&mut pb, &x, &w, Some(&b), &g, case.drop_rate, false);
        fnodx.dx.is_empty()
            && fnodx.dw == ffull.dw
            && fnodx.db == ffull.db
            && ffull.dw == full.dw
            && ffull.db == full.db
    });
}

#[test]
fn consecutive_train_steps_reuse_workspaces_and_match_fresh_model() {
    let be = NativeBackend::new();
    let mk = || {
        simple_cnn(SimpleCnnCfg { in_ch: 2, img: 8, classes: 3, depth: 2, width: 4, seed: 21 })
    };
    let mut rng = Pcg::new(77, 2);
    let n = 2 * 8 * 8;
    let bt = 6;
    let x: Vec<f32> = (0..bt * n).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..bt).map(|i| (i % 3) as i32).collect();

    let mut m = mk();
    let s1 = m.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
    let caps = m.plan_caps();
    assert_eq!(m.plan_cols_builds(), 2, "step 1: one im2col per layer");

    let s2 = m.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
    let caps2 = m.plan_caps();
    assert_eq!(caps, caps2, "step 2 must allocate no new plan buffers");
    assert_eq!(m.plan_cols_builds(), 4, "step 2: one im2col per layer");

    // same loss trajectory as a freshly-built identical model
    let mut fresh = mk();
    let f1 = fresh.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
    let f2 = fresh.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
    assert_eq!(s1.loss, f1.loss, "step 1 loss must not depend on workspace reuse");
    assert_eq!(s2.loss, f2.loss, "step 2 loss must not depend on workspace reuse");
    assert_eq!(s1.kept_channels, f1.kept_channels);
    assert_eq!(s2.kept_channels, f2.kept_channels);
}

#[test]
fn plans_rekey_across_batch_sizes_without_losing_capacity() {
    // A model stepped at a large batch then a small one must keep the
    // large-batch capacity (no shrink) and still be numerically exact.
    let be = NativeBackend::new();
    let mut m =
        simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 2, depth: 2, width: 3, seed: 9 });
    let mut rng = Pcg::new(5, 8);
    let n = 8 * 8;
    let mk_batch = |bt: usize, rng: &mut Pcg| {
        let x: Vec<f32> = (0..bt * n).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..bt).map(|i| (i % 2) as i32).collect();
        (x, y)
    };
    let (x8, y8) = mk_batch(8, &mut rng);
    let (x2, y2) = mk_batch(2, &mut rng);
    m.train_step(&be, &x8, &y8, 0.0, 0.05).unwrap();
    let caps_big = m.plan_caps();
    m.train_step(&be, &x2, &y2, 0.0, 0.05).unwrap();
    let caps_small = m.plan_caps();
    assert_eq!(caps_big, caps_small, "shrinking the batch must not reallocate");
    m.train_step(&be, &x8, &y8, 0.0, 0.05).unwrap();
    let caps_again = m.plan_caps();
    assert_eq!(caps_big, caps_again, "growing back to the old batch must reuse capacity");
}
