//! Small dense symmetric linear algebra: cyclic Jacobi eigendecomposition
//! and PSD matrix square roots — enough to compute the Fréchet distance
//! exactly (no external BLAS in the offline vendor set).

/// Row-major square matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Side length.
    pub n: usize,
    /// Row-major entries, length n².
    pub a: Vec<f64>,
}

impl Mat {
    /// The n×n zero matrix.
    pub fn zeros(n: usize) -> Mat {
        Mat { n, a: vec![0.0; n * n] }
    }

    /// The n×n identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Dense product `self · other` (same dimensions).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * other.a[k * n + j];
                }
            }
        }
        out
    }

    /// The transposed matrix.
    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Sum of the diagonal.
    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self[(i, i)]).sum()
    }

    /// Average A with Aᵀ in place (clean up numerical asymmetry).
    pub fn symmetrize(&mut self) {
        let n = self.n;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.a[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.a[i * self.n + j]
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V) with A = V diag(w) Vᵀ.
pub fn eigh(m: &Mat, sweeps: usize) -> (Vec<f64>, Mat) {
    let n = m.n;
    let mut a = m.clone();
    let mut v = Mat::eye(n);
    for _ in 0..sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + a.trace().abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of A
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let w = (0..n).map(|i| a[(i, i)]).collect();
    (w, v)
}

/// Symmetric PSD matrix square root via eigendecomposition (negative
/// eigenvalues from numerical noise are clamped to zero).
pub fn sqrtm_psd(m: &Mat) -> Mat {
    let (w, v) = eigh(m, 30);
    let n = m.n;
    let mut out = Mat::zeros(n);
    // V diag(sqrt(w)) V^T
    for k in 0..n {
        let s = w[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v[(i, k)] * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.a[i * n + j] += vik * v[(j, k)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_psd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed, 0);
        let mut b = Mat::zeros(n);
        for i in 0..n * n {
            b.a[i] = rng.normal() as f64;
        }
        let mut m = b.matmul(&b.transpose());
        m.symmetrize();
        m
    }

    #[test]
    fn eigh_reconstructs() {
        let m = random_psd(8, 1);
        let (w, v) = eigh(&m, 30);
        // A = V diag(w) V^T
        let mut rec = Mat::zeros(8);
        for k in 0..8 {
            for i in 0..8 {
                for j in 0..8 {
                    rec.a[i * 8 + j] += v[(i, k)] * w[k] * v[(j, k)];
                }
            }
        }
        for i in 0..64 {
            assert!((rec.a[i] - m.a[i]).abs() < 1e-8, "i={i}");
        }
        assert!(w.iter().all(|&x| x > -1e-9), "PSD eigvals {w:?}");
    }

    #[test]
    fn sqrtm_squares_back() {
        let m = random_psd(6, 2);
        let s = sqrtm_psd(&m);
        let s2 = s.matmul(&s);
        for i in 0..36 {
            assert!((s2.a[i] - m.a[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn sqrtm_of_diagonal() {
        let mut m = Mat::zeros(3);
        m[(0, 0)] = 4.0;
        m[(1, 1)] = 9.0;
        m[(2, 2)] = 16.0;
        let s = sqrtm_psd(&m);
        assert!((s[(0, 0)] - 2.0).abs() < 1e-12);
        assert!((s[(1, 1)] - 3.0).abs() < 1e-12);
        assert!((s[(2, 2)] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let m = random_psd(5, 3);
        let (_, v) = eigh(&m, 30);
        let vtv = v.transpose().matmul(&v);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-9);
            }
        }
    }
}
