"""AOT compiler — lowers every L2 graph to HLO text + manifest + init params.

Run once via ``make artifacts`` (no-op when inputs are unchanged); Python
never appears on the request path afterwards.

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Per artifact ``NAME`` we write:
    artifacts/NAME.hlo.txt         the lowered computation (return_tuple=True)
    artifacts/NAME.manifest.json   flattened I/O specs + model/dataset/FLOPs metadata
    artifacts/NAME.init.tstore     Kaiming-initialized params (+opt,+bn) for trains
plus a global ``artifacts/index.json``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import steps as steps_mod
from . import tensorstore
from .models.ddpm_unet import UNet
from .models.resnet import ResNet
from .models.simple_cnn import SimpleCNN
from .ssprop import make_ssprop_conv_pallas

# ---------------------------------------------------------------------------
# dataset registry (geometry of paper Table 1, scaled; see DESIGN.md §3)
# ---------------------------------------------------------------------------

DATASETS = {
    # name: (channels, img, classes, loss, batch)
    "mnist":      (1, 28, 10, "ce", 32),
    "fashion":    (1, 28, 10, "ce", 32),
    "cifar10":    (3, 32, 10, "ce", 32),
    "cifar100":   (3, 32, 100, "ce", 32),
    "celeba":     (3, 64, 40, "bce", 16),
    # ImageNet-1k substitute: 64px, 100 classes (documented in DESIGN.md).
    "imagenet64": (3, 64, 100, "ce", 16),
}

DDPM_DATASETS = {
    # name: (channels, img, timesteps, batch)
    "mnist":   (1, 28, 200, 16),
    "fashion": (1, 28, 200, 16),
    "celeba":  (3, 64, 100, 8),
}

WIDTH_MULT = 0.25  # CPU-testbed width scale; analytic FLOPs stay full-width


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# artifact emission
# ---------------------------------------------------------------------------

def _emit(out_dir: str, name: str, fn: Callable, args, roles, out_roles,
          meta: Dict[str, Any], init_roles=("param", "opt", "bn")) -> Dict[str, Any]:
    # keep_unused=True: the manifest-driven rust runtime supplies EVERY input
    # (e.g. `dropout_rate` on models without Dropout, `key` under top-k
    # selection), so unused-arg pruning must be disabled.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    hlo = to_hlo_text(lowered)
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, tuple):
        outs = (outs,)
    inputs, outputs = steps_mod.manifest_io(args, roles, outs, out_roles)
    manifest = dict(name=name, inputs=inputs, outputs=outputs, **meta)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # initial values for state-role inputs
    tensors = []
    for role, tree in zip(roles, args):
        if role not in init_roles:
            continue
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            tensors.append((role + jax.tree_util.keystr(path), np.asarray(leaf)))
    if tensors:
        tensorstore.write(os.path.join(out_dir, f"{name}.init.tstore"), tensors)
    return {"name": name, "kind": meta.get("kind"), "hlo_bytes": len(hlo),
            "n_inputs": len(inputs), "n_outputs": len(outputs)}


def _classifier_artifacts(model, mname: str, ds: str, *, optimizer="adam",
                          suffix="") -> List[Dict[str, Any]]:
    cin, img, classes, loss, batch = DATASETS[ds]
    pair = steps_mod.make_classify_steps(model, batch=batch, loss=loss,
                                         optimizer=optimizer)
    inv = model.inventory().as_json()
    meta = dict(model=mname, dataset=ds, batch=batch, loss=loss, img=img,
                channels=cin, classes=classes, width_mult=getattr(model, "width_mult", 1.0),
                layers=inv)
    specs = []
    for kind in ("train", "eval"):
        fn, args, roles, out_roles = pair[kind]
        specs.append((f"{mname}_{ds}{suffix}_{kind}", fn, args, roles, out_roles,
                      dict(kind=kind, **meta)))
    return specs


def build_registry() -> List[tuple]:
    """All artifact specs: (name, fn, args, roles, out_roles, meta)."""
    specs: List[tuple] = []

    # -- Table 4: ResNet-18/50 on six datasets (+ Table 7's ResNet-26) -------
    for arch in ("resnet18", "resnet50"):
        for ds in ("mnist", "fashion", "cifar10", "cifar100", "celeba", "imagenet64"):
            cin, img, classes, _, _ = DATASETS[ds]
            model = ResNet(arch=arch, in_ch=cin, img=img, classes=classes,
                           width_mult=WIDTH_MULT, with_dropout=(arch == "resnet50"))
            specs.extend(_classifier_artifacts(model, arch, ds))
    for ds in ("cifar10", "cifar100"):
        cin, img, classes, _, _ = DATASETS[ds]
        model = ResNet(arch="resnet26", in_ch=cin, img=img, classes=classes,
                       width_mult=WIDTH_MULT)
        specs.extend(_classifier_artifacts(model, "resnet26", ds))

    # -- Fig. 2a/2b: selection-mode variants on ResNet-18 / CIFAR-10 ---------
    for mode, select, tag in (("hw", "topk", "hw"), ("all", "topk", "all"),
                              ("channel", "random", "random")):
        cin, img, classes, _, _ = DATASETS["cifar10"]
        model = ResNet(arch="resnet18", in_ch=cin, img=img, classes=classes,
                       width_mult=WIDTH_MULT, mode=mode, select=select)
        specs.extend(_classifier_artifacts(model, "resnet18", "cifar10",
                                           suffix=f"_{tag}"))

    # -- Fig. 4: SimpleCNN depth sweep on CIFAR-100 --------------------------
    for depth in (2, 3, 4, 5, 6, 7):
        cin, img, classes, _, _ = DATASETS["cifar100"]
        model = SimpleCNN(depth=depth, in_ch=cin, img=img, classes=classes)
        specs.extend(_classifier_artifacts(model, f"cnn{depth}", "cifar100"))

    # -- Table 5 / Fig. 3: DDPM -----------------------------------------------
    for ds, (cin, img, T, batch) in DDPM_DATASETS.items():
        unet = UNet(in_ch=cin, img=img)
        pair = steps_mod.make_ddpm_steps(unet, batch=batch, timesteps=T)
        meta = dict(model="ddpm_unet", dataset=ds, batch=batch, img=img,
                    channels=cin, timesteps=T, layers=unet.inventory().as_json(),
                    beta_schedule=pair["schedule"])
        for kind in ("train", "denoise"):
            fn, args, roles, out_roles = pair[kind]
            specs.append((f"ddpm_{ds}_{kind}", fn, args, roles, out_roles,
                          dict(kind=kind, **meta)))

    # -- compacted Pallas hot-path microbench (true-sparse FLOPs saving) -----
    for tag, drop in (("dense", 0.0), ("d50", 0.5), ("d80", 0.8)):
        conv = make_ssprop_conv_pallas(stride=1, padding=1, drop_rate=drop)

        def grad_fn(x, w, b, conv=conv):
            def lf(x, w, b):
                y = conv(x, w, b)
                return jnp.sum(y * y)
            l, (dx, dw, db) = jax.value_and_grad(lf, (0, 1, 2))(x, w, b)
            return dx, dw, db, l

        bt, cc, hh, kk = 8, 32, 12, 3
        args = (jnp.zeros((bt, cc, hh, hh), jnp.float32),
                jnp.zeros((cc, cc, kk, kk), jnp.float32),
                jnp.zeros((cc,), jnp.float32))
        meta = dict(kind="kernel", model="conv_pallas", drop_rate=drop,
                    layers={"convs": [dict(cin=cc, cout=cc, k=kk, stride=1, padding=1,
                                           hin=hh, win=hh, hout=hh, wout=hh)],
                            "bns": [], "dropouts": []},
                    batch=bt)
        specs.append((f"conv_pallas_{tag}", grad_fn, args,
                      ["data_x", "param", "param"], ["gx", "gw", "gb", "loss"], meta))

    return specs


def _input_digest(root: str) -> str:
    h = hashlib.sha256()
    for base, _, files in sorted(os.walk(os.path.join(root, "compile"))):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(base, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="substring filter on artifact names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = build_registry()
    if args.only:
        specs = [s for s in specs if args.only in s[0]]
    if args.list:
        for s in specs:
            print(s[0])
        return

    # merge with any existing index so `--only` rebuilds don't clobber it
    index_path = os.path.join(args.out_dir, "index.json")
    existing = {}
    if args.only and os.path.exists(index_path):
        with open(index_path) as f:
            existing = {a["name"]: a for a in json.load(f).get("artifacts", [])}
    for (name, fn, fargs, roles, out_roles, meta) in specs:
        info = _emit(args.out_dir, name, fn, fargs, roles, out_roles, meta)
        existing[name] = info
        print(f"  lowered {name}  ({info['hlo_bytes']//1024} KiB, "
              f"{info['n_inputs']} in / {info['n_outputs']} out)", flush=True)
    index = {"artifacts": sorted(existing.values(), key=lambda a: a["name"]),
             "digest": _input_digest(os.path.dirname(os.path.dirname(__file__)))}
    with open(index_path, "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote {len(specs)} artifacts to {args.out_dir} (index: {len(existing)})")


if __name__ == "__main__":
    main()
