"""Pure-jnp reference oracle for every Pallas kernel (L1 correctness anchor).

Everything in this file is written with plain ``jax.numpy``/``jax.lax`` ops so
it is trivially auditable against the paper's equations:

* Eq. 1      -> :func:`conv_fwd_ref`
* Eq. 3/4/5  -> :func:`conv_bwd_ref` (via ``jax.vjp`` of the forward)
* img2col    -> :func:`im2col_ref`
* col2img    -> :func:`col2img_ref`
* channel importance (Fig. 1a "abs + spatial mean") -> :func:`importance_ref`
* exact-k top-k mask   -> :func:`topk_mask_ref`
* compacted backward (the shrunk matmuls of Sec. "Scheduled Sparse BP")
                       -> :func:`sparse_bwd_compact_ref`

The pytest suite asserts ``assert_allclose(pallas_kernel(...), *_ref(...))``
over hypothesis-generated shapes/dtypes, which is the core L1 signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# convolution forward / backward (dense reference)
# ---------------------------------------------------------------------------

DIMS = ("NCHW", "OIHW", "NCHW")  # paper's layout throughout


def conv_fwd_ref(x, w, b=None, *, stride=1, padding=0):
    """Eq. 1 — dense conv forward in NCHW/OIHW, square kernel/stride/pad."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=DIMS,
    )
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def conv_bwd_ref(x, w, g, *, stride=1, padding=0):
    """Eq. 3/4/5 — exact dense gradients (dx, dw, db) via jax.vjp."""
    _, vjp = jax.vjp(
        lambda xx, ww: conv_fwd_ref(xx, ww, None, stride=stride, padding=padding), x, w
    )
    dx, dw = vjp(g)
    db = jnp.sum(g, axis=(0, 2, 3))
    return dx, dw, db


# ---------------------------------------------------------------------------
# img2col / col2img (paper Fig. 1b)
# ---------------------------------------------------------------------------

def out_size(h: int, k: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - k) // stride + 1


def im2col_ref(x, *, k: int, stride: int = 1, padding: int = 0):
    """(Bt,Cin,H,W) -> col_X of shape (Bt*Hout*Wout, Cin*K*K).

    Row (b, i, j) is the flattened Cin x K x K patch under output pixel
    (i, j) — exactly the stretching of Fig. 1(b).
    """
    bt, cin, h, w = x.shape
    ho, wo = out_size(h, k, stride, padding), out_size(w, k, stride, padding)
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    ri = jnp.arange(ho)[:, None] * stride + jnp.arange(k)[None, :]  # (ho,k)
    ci = jnp.arange(wo)[:, None] * stride + jnp.arange(k)[None, :]  # (wo,k)
    # patches: (bt, cin, ho, k, wo, k)
    p = xp[:, :, ri[:, :, None, None], ci[None, None, :, :]]
    # -> (bt, ho, wo, cin, k, k) -> (bt*ho*wo, cin*k*k)
    p = jnp.transpose(p, (0, 2, 4, 1, 3, 5))
    return p.reshape(bt * ho * wo, cin * k * k)


def col2img_ref(cols, *, x_shape, k: int, stride: int = 1, padding: int = 0):
    """Inverse of im2col: scatter-add (Bt*Hout*Wout, Cin*K*K) back to x_shape."""
    bt, cin, h, w = x_shape
    ho, wo = out_size(h, k, stride, padding), out_size(w, k, stride, padding)
    hp, wp = h + 2 * padding, w + 2 * padding
    p = cols.reshape(bt, ho, wo, cin, k, k)
    ri = (jnp.arange(ho)[:, None] * stride + jnp.arange(k)[None, :]).reshape(-1)  # (ho*k,)
    ci = (jnp.arange(wo)[:, None] * stride + jnp.arange(k)[None, :]).reshape(-1)  # (wo*k,)
    p = jnp.transpose(p, (0, 3, 1, 4, 2, 5)).reshape(bt, cin, ho * k, wo * k)
    xp = jnp.zeros((bt, cin, hp, wp), cols.dtype)
    xp = xp.at[:, :, ri[:, None], ci[None, :]].add(p)
    if padding:
        xp = xp[:, :, padding:-padding, padding:-padding]
    return xp


def col_w_ref(w):
    """(Cout,Cin,K,K) -> col_W (Cin*K*K, Cout), matching im2col row layout."""
    cout = w.shape[0]
    return w.reshape(cout, -1).T


def conv_fwd_im2col_ref(x, w, b=None, *, stride=1, padding=0):
    """Forward through the explicit img2col matmul — must equal conv_fwd_ref."""
    bt, cin, h, wd = x.shape
    cout, _, k, _ = w.shape
    ho, wo = out_size(h, k, stride, padding), out_size(wd, k, stride, padding)
    cols = im2col_ref(x, k=k, stride=stride, padding=padding)
    y = cols @ col_w_ref(w)  # (Bt*Ho*Wo, Cout)
    if b is not None:
        y = y + b[None, :]
    return jnp.transpose(y.reshape(bt, ho, wo, cout), (0, 3, 1, 2))


# ---------------------------------------------------------------------------
# ssProp selection primitives
# ---------------------------------------------------------------------------

def importance_ref(g, mode: str = "channel"):
    """Fig. 1(a): abs then mean over the non-selected dims.

    mode='channel' -> (Cout,)     mean over (Bt, H, W)  [paper's deployed mode]
    mode='hw'      -> (H*W,)      mean over (Bt, Cout)
    mode='all'     -> (Cout*H*W,) mean over Bt
    """
    a = jnp.abs(g)
    if mode == "channel":
        return jnp.mean(a, axis=(0, 2, 3))
    if mode == "hw":
        return jnp.mean(a, axis=(0, 1)).reshape(-1)
    if mode == "all":
        return jnp.mean(a, axis=0).reshape(-1)
    raise ValueError(f"unknown mode {mode!r}")


def topk_mask_ref(imp, keep_k):
    """Exact-k {0,1} mask keeping the k largest entries.

    Deterministic under ties via stable argsort rank. ``keep_k`` may be a
    traced scalar (the masked train step computes it from the runtime
    drop-rate input), so no output shape depends on it.
    """
    n = imp.shape[0]
    order = jnp.argsort(-imp)  # stable; descending
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return (ranks < keep_k).astype(imp.dtype)


def random_mask_ref(key, n, keep_k, dtype=jnp.float32):
    """Random-selection baseline of Fig. 2(b): keep k uniformly random entries."""
    ranks = jax.random.permutation(key, jnp.arange(n, dtype=jnp.int32))
    return (ranks < keep_k).astype(dtype)


def keep_k_from_drop_rate(drop_rate, n: int):
    """k = clamp(round((1-D)*n), 1, n) — shared rust/python semantics."""
    kf = jnp.round((1.0 - drop_rate) * n)
    return jnp.clip(kf, 1, n).astype(jnp.int32)


def mask_grad_ref(g, mask, mode: str = "channel"):
    """Broadcast a selection mask back onto the gradient map."""
    bt, c, h, w = g.shape
    if mode == "channel":
        return g * mask[None, :, None, None]
    if mode == "hw":
        return g * mask.reshape(1, 1, h, w)
    if mode == "all":
        return g * mask.reshape(1, c, h, w)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# compacted (true-sparse) backward — the shrunk matmuls
# ---------------------------------------------------------------------------

def sparse_bwd_compact_ref(x, w, g, keep_idx, *, stride=1, padding=0):
    """Paper's compacted img2col backward with static keep indices.

    col[dY]' has shape (Bt*Ho*Wo, k') after channel compaction; then
      dW'      = col_X^T  @ col[dY]'          (N x k')
      col[dX]  = col[dY]' @ col_W'^T          (M x N)
      db'      = sum over M of col[dY]'
    Dropped channels receive exactly-zero dW/db rows; dX gets only kept
    channels' contributions — identical numerics to the masked path.
    """
    bt, cin, h, wd = x.shape
    cout, _, k, _ = w.shape
    ho, wo = out_size(h, k, stride, padding), out_size(wd, k, stride, padding)
    cols = im2col_ref(x, k=k, stride=stride, padding=padding)            # (M, N)
    gc = jnp.transpose(g, (0, 2, 3, 1)).reshape(bt * ho * wo, cout)      # col[dY]
    gck = jnp.take(gc, keep_idx, axis=1)                                 # (M, k')
    cw = col_w_ref(w)                                                    # (N, Cout)
    cwk = jnp.take(cw, keep_idx, axis=1)                                 # (N, k')
    dwk = cols.T @ gck                                                   # (N, k')
    dw = jnp.zeros((cin * k * k, cout), cols.dtype).at[:, keep_idx].set(dwk)
    dw = jnp.transpose(dw, (1, 0)).reshape(cout, cin, k, k)
    dcols = gck @ cwk.T                                                  # (M, N)
    dx = col2img_ref(dcols, x_shape=x.shape, k=k, stride=stride, padding=padding)
    db = jnp.zeros((cout,), g.dtype).at[keep_idx].set(jnp.sum(gck, axis=0))
    return dx, dw, db


# ---------------------------------------------------------------------------
# FLOPs model (paper Eq. 6/7/8/10) — mirrored in rust/src/flops; tested equal
# ---------------------------------------------------------------------------

def conv_bwd_flops(bt, cin, cout, k, ho, wo, drop_rate=0.0, with_selection=False):
    """Eq. 6, and Eq. 9's RHS when drop_rate > 0 / selection enabled."""
    m = bt * ho * wo
    n = cin * k * k
    if drop_rate == 0.0 and not with_selection:
        return float(m * (4 * n + 1) * cout)
    keep = max(1, round((1.0 - drop_rate) * cout))
    fl = float(4 * m * n + m) * keep  # (4MN+M)*C'out — Eq. 9 RHS first term
    if with_selection:
        fl += float(m - 1) * cout  # summation overhead of the importance reduce
    return fl


def bn_bwd_flops(bt, c, h, w):
    """Eq. 7."""
    return float(12 * (bt * h * w * c) + 10 * c)


def dropout_bwd_flops(bt, c, h, w):
    """Eq. 8."""
    return float(2 * (bt * h * w * c))


def drop_rate_lower_bound(cin, k):
    """Eq. 10."""
    return 1.0 / (4 * cin * k * k + 1)
