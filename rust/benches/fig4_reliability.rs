//! Bench for paper Fig. 4: hyperparameter-search cost. Step latency across
//! SimpleCNN depths in dense and sparse modes — the quantity the paper's
//! R&D-phase energy claim scales with.
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench fig4_reliability --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::runtime::Engine;
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping fig4_reliability: {err}");
                return;
            }
        };
        println!("== Fig 4 bench: SimpleCNN depth sweep, dense vs sparse step ==\n");

        for depth in [2usize, 4, 6] {
            let artifact = format!("cnn{depth}_cifar100");
            let mut t = Trainer::new(&engine, TrainConfig::quick(&artifact, 1, 1)).unwrap();
            let order = t.loader.epoch_order(0);
            let batch = t.loader.batch(&order, 0);
            for (mode, d) in [("dense", 0.0f64), ("sparse_d80", 0.8)] {
                let r = bench(
                    &format!("cnn{depth}/{mode}/step"),
                    2,
                    15,
                    Duration::from_secs(6),
                    || {
                        t.step(&batch, d).unwrap();
                    },
                );
                report(&r);
            }
            let man = &t.train_graph.manifest;
            println!(
                "  analytic bwd FLOPs/iter: dense {:.4} B, D=0.8 {:.4} B\n",
                man.bwd_flops(0.0) / 1e9,
                man.bwd_flops(0.8) / 1e9
            );
        }
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("skipping fig4_reliability: PJRT runtime not compiled (build with --features pjrt)");
}

fn main() {
    run();
}
