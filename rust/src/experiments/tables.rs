//! Table drivers: paper Tables 1–7. Tables 4–7 train through PJRT
//! artifacts and are gated behind the `pjrt` feature; the analytic tables
//! (1–3, FLOPs parity, energy) run on any build.

#[cfg(feature = "pjrt")]
use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::report::{f3, f4, pct};
use super::report::{f2, Table};
#[cfg(feature = "pjrt")]
use super::{run_classifier, run_dense, run_ssprop};
use super::Scale;
use crate::data;
#[cfg(feature = "pjrt")]
use crate::ddpm::DdpmTrainer;
use crate::energy::{estimate, fmt_flops, RTX_A5000};
use crate::flops::{paper_resnet, TABLE4_DENSE_BILLIONS};
#[cfg(feature = "pjrt")]
use crate::metrics::fid_proxy;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
#[cfg(feature = "pjrt")]
use crate::schedule::{DropScheduler, Schedule};

/// Table 1: dataset geometry (paper) vs the synthetic substitutes.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — datasets (paper geometry / synthetic substitute sizes)",
        &["Dataset", "Paper Train/Val/Test", "Image Size", "Classes", "Synth Train/Val/Test"],
    );
    for d in data::registry() {
        let (a, b, c) = d.paper_split;
        t.row(vec![
            d.name.to_string(),
            format!("{a}/{b}/{c}"),
            format!("({}, {}, {})", d.channels, d.img, d.img),
            d.classes.to_string(),
            format!("{}/{}/{}", d.train_n, d.val_n, d.test_n),
        ]);
    }
    t
}

/// Tables 2/3: training hyperparameter presets (paper values + testbed values).
pub fn table23(scale: Scale) -> Table {
    let mut t = Table::new(
        "Tables 2/3 — hyperparameters (paper -> this testbed)",
        &["Task", "Dataset", "Model", "LR", "Epochs", "Batch", "Testbed epochs x iters"],
    );
    let rows: &[(&str, &str, &str, &str, &str, &str, &str)] = &[
        ("cls", "mnist", "ResNet-18/50", "2e-4", "50/50", "128/128", ""),
        ("cls", "fashion", "ResNet-18/50", "2e-4", "50/50", "128/128", ""),
        ("cls", "cifar10", "ResNet-18/50", "2e-4", "50/250", "128/128", ""),
        ("cls", "cifar100", "ResNet-18/50", "2e-4", "50/250", "128/128", ""),
        ("cls", "celeba", "ResNet-18/50", "2e-4", "50/50", "128/32", ""),
        ("cls", "imagenet", "ResNet-18/50", "2e-4", "50/50", "32/16", ""),
        ("gen", "mnist", "DDPM T=200", "1e-3", "300", "128", ""),
        ("gen", "fashion", "DDPM T=200", "1e-3", "500", "128", ""),
        ("gen", "celeba", "DDPM T=1000", "2e-4", "200", "128", ""),
    ];
    for (task, ds, model, lr, ep, bs, _) in rows {
        t.row(vec![
            task.to_string(),
            ds.to_string(),
            model.to_string(),
            lr.to_string(),
            ep.to_string(),
            bs.to_string(),
            format!("{} x {}", scale.epochs, scale.iters_per_epoch),
        ]);
    }
    t
}

/// Table 4: classification — dense vs ssProp. `datasets`/`archs` select rows.
#[cfg(feature = "pjrt")]
pub fn table4(engine: &Engine, scale: Scale, datasets: &[&str], archs: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — classification: ResNet vs ssProp (paper FLOPs full width; synthetic acc/time)",
        &[
            "Dataset", "Model", "Paper B/Iter", "Ours B/Iter (full width)", "Scaled B/Iter",
            "Total Est. FLOPs", "Train Time (s)", "Test Acc", "Saving",
        ],
    );
    for &ds in datasets {
        for &arch in archs {
            let artifact = format!("{arch}_{ds}");
            let (dense_tr, dense_acc) = run_dense(engine, &artifact, scale)?;
            let (ss_tr, ss_acc) = run_ssprop(engine, &artifact, scale)?;

            // full-width analytic parity with the paper's column
            let ds_geom = data::spec(ds).unwrap();
            let full = paper_resnet(arch, ds_geom.img, ds_geom.channels, 1.0);
            let paper_bt = paper_batch(arch, ds);
            let full_dense_b = full.bwd_flops_per_iter(paper_bt, 0.0) / 1e9;
            let full_ss_b = full.bwd_flops_scheduled(paper_bt, &[0.0, 0.8]) / 1e9;
            let paper_col = TABLE4_DENSE_BILLIONS
                .iter()
                .find(|r| r.0 == arch && (r.1 == ds || (r.1 == "imagenet" && ds == "imagenet64")))
                .map(|r| f2(r.5))
                .unwrap_or_else(|| "-".into());

            for (label, tr, acc, fullb) in [
                (arch.to_string(), &dense_tr, dense_acc, full_dense_b),
                (format!("ssProp-{}", &arch[6..]), &ss_tr, ss_acc, full_ss_b),
            ] {
                let m = &tr.metrics;
                t.row(vec![
                    ds.to_string(),
                    label,
                    if fullb == full_dense_b { paper_col.clone() } else { "-".into() },
                    f2(fullb),
                    f2(m.flops_actual / m.losses.len() as f64 / 1e9),
                    fmt_flops(m.flops_actual),
                    f2(m.total_wall_secs()),
                    f3(acc),
                    pct(m.flops_saving()),
                ]);
            }
        }
    }
    t.save_json("table4");
    Ok(t)
}

#[cfg(feature = "pjrt")]
fn paper_batch(arch: &str, ds: &str) -> usize {
    match (arch, ds) {
        (_, "mnist" | "fashion" | "cifar10" | "cifar100") => 128,
        ("resnet18", "celeba") => 128,
        ("resnet50", "celeba") => 32,
        ("resnet18", "imagenet64") => 32,
        ("resnet50", "imagenet64") => 16,
        _ => 128,
    }
}

/// Table 5: DDPM generation — dense vs ssProp (FLOPs, time, FID-proxy).
#[cfg(feature = "pjrt")]
pub fn table5(engine: &Engine, scale: Scale, datasets: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 — generation: DDPM vs ssProp-DDPM (FID-proxy on synthetic data)",
        &[
            "Dataset", "Model", "B/Iter (scaled)", "Total FLOPs", "Train Time (s)", "FID-proxy",
            "Saving",
        ],
    );
    let iters = scale.epochs * scale.iters_per_epoch;
    for &ds in datasets {
        for (label, target) in [("DDPM", 0.0), ("ssProp-DDPM", 0.8)] {
            let mut tr = DdpmTrainer::new(engine, ds, scale.lr, scale.seed)?;
            let sched = DropScheduler::new(
                if target == 0.0 {
                    Schedule::Constant
                } else {
                    Schedule::EpochBar { period_epochs: 2 }
                },
                target,
                scale.epochs,
                scale.iters_per_epoch,
            );
            tr.train(iters, &sched)?;
            let gen = tr.sample(scale.seed + 99)?;
            let real = tr.real_batch(64.max(gen.len()));
            let fid = fid_proxy(&real, &gen, 1234);
            let m = &tr.metrics;
            t.row(vec![
                ds.to_string(),
                label.to_string(),
                f2(m.flops_actual / iters as f64 / 1e9),
                fmt_flops(m.flops_actual),
                f2(m.total_wall_secs()),
                f4(fid),
                pct(m.flops_saving()),
            ]);
        }
    }
    t.save_json("table5");
    Ok(t)
}

/// Table 6: Dropout vs ssProp vs both, on ResNet-50.
#[cfg(feature = "pjrt")]
pub fn table6(engine: &Engine, scale: Scale, datasets: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 6 — ResNet-50: Dropout vs ssProp vs combined",
        &[
            "Dataset", "Method (Drop Rate)", "B/Iter (scaled)", "Total FLOPs", "Train Time (s)",
            "Test Acc",
        ],
    );
    // (label, ssprop target, dropout rate, longer factor for dropout runs)
    let modes: &[(&str, f64, f64, usize)] = &[
        ("ResNet-50 (0)", 0.0, 0.0, 1),
        ("w/ Dropout (0.4)", 0.0, 0.4, 2),
        ("w/ ssProp (0.4)", 0.4, 0.0, 1),
        ("w/ Both (0.2 + 0.2)", 0.2, 0.2, 2),
        ("w/ Both (0.4 + 0.4)", 0.4, 0.4, 2),
    ];
    for &ds in datasets {
        for &(label, ss, dr, longer) in modes {
            let mut sc = scale;
            sc.epochs *= longer; // paper: Dropout runs train longer (slower convergence)
            let schedule = if ss == 0.0 {
                Schedule::Constant
            } else {
                Schedule::EpochBar { period_epochs: 2 }
            };
            let (tr, acc) =
                run_classifier(engine, &format!("resnet50_{ds}"), sc, schedule, ss, dr)?;
            let m = &tr.metrics;
            let iters = (sc.epochs * tr.iters_per_epoch()) as f64;
            t.row(vec![
                ds.to_string(),
                label.to_string(),
                f2(m.flops_actual / iters / 1e9),
                fmt_flops(m.flops_actual),
                f2(m.total_wall_secs()),
                f3(acc),
            ]);
        }
    }
    t.save_json("table6");
    Ok(t)
}

/// Table 7: sparse ResNet-50 vs iso-FLOPs ResNet-26.
#[cfg(feature = "pjrt")]
pub fn table7(engine: &Engine, scale: Scale, datasets: &[&str]) -> Result<Table> {
    let mut t = Table::new(
        "Table 7 — ssProp-50 vs normally-trained ResNet-26 (iso-FLOPs)",
        &[
            "Dataset", "Model", "Paper B/Iter", "Full-width B/Iter", "Total FLOPs",
            "Train Time (s)", "Test Acc",
        ],
    );
    for &ds in datasets {
        let ds_geom = data::spec(ds).unwrap();
        for (arch, mode) in [("resnet50", "dense"), ("resnet50", "ssprop"),
                             ("resnet26", "dense"), ("resnet26", "ssprop")] {
            let artifact = format!("{arch}_{ds}");
            let (tr, acc) = if mode == "dense" {
                run_dense(engine, &artifact, scale)?
            } else {
                run_ssprop(engine, &artifact, scale)?
            };
            let full = paper_resnet(arch, ds_geom.img, ds_geom.channels, 1.0);
            let fullb = if mode == "dense" {
                full.bwd_flops_per_iter(128, 0.0)
            } else {
                full.bwd_flops_scheduled(128, &[0.0, 0.8])
            } / 1e9;
            let paper = match (arch, mode) {
                ("resnet50", "dense") => "669.75",
                ("resnet50", "ssprop") => "404.18",
                ("resnet26", "dense") => "440.19",
                ("resnet26", "ssprop") => "264.64",
                _ => "-",
            };
            let label = if mode == "dense" {
                format!("ResNet-{}", &arch[6..])
            } else {
                format!("ssProp-{}", &arch[6..])
            };
            let m = &tr.metrics;
            t.row(vec![
                ds.to_string(),
                label,
                paper.to_string(),
                f2(fullb),
                fmt_flops(m.flops_actual),
                f2(m.total_wall_secs()),
                f3(acc),
            ]);
        }
    }
    t.save_json("table7");
    Ok(t)
}

/// FLOPs parity + lower-bound report (Eq. 9–11 and the Table 4 columns).
pub fn flops_report() -> (Table, Table) {
    let mut t = Table::new(
        "FLOPs parity — paper Table 4 'Est. FLOPs (B/Iter.)' vs our Eq. 6/7 accounting",
        &["Arch", "Dataset", "Batch", "Paper B/Iter", "Ours B/Iter", "Rel. err"],
    );
    for &(arch, ds, img, in_ch, bt, paper_b) in TABLE4_DENSE_BILLIONS {
        let ours = paper_resnet(arch, img, in_ch, 1.0).bwd_flops_per_iter(bt, 0.0) / 1e9;
        t.row(vec![
            arch.to_string(),
            ds.to_string(),
            bt.to_string(),
            f2(paper_b),
            f2(ours),
            format!("{:+.3}%", (ours - paper_b) / paper_b * 100.0),
        ]);
    }
    t.save_json("flops_parity");

    let mut lb = Table::new(
        "Drop-rate lower bound (Eq. 10/11): D > 1/(4·Cin·K²+1)",
        &["Cin", "K", "Lower bound", "Paper bound (K>=3, Cin>=1)"],
    );
    for (cin, k) in [(1usize, 3usize), (3, 3), (64, 3), (1, 5), (512, 1)] {
        lb.row(vec![
            cin.to_string(),
            k.to_string(),
            format!("{:.5}", crate::flops::drop_rate_lower_bound(cin, k)),
            "0.02703".to_string(),
        ]);
    }
    lb.save_json("lower_bound");
    (t, lb)
}

/// Energy/carbon projection of the paper-scale runs (sustainability claim).
pub fn energy_report() -> Table {
    let mut t = Table::new(
        "Energy projection — backward-FLOPs savings at paper scale (RTX A5000 profile)",
        &["Run", "Dense total", "ssProp total", "Saved", "kWh saved", "gCO2e saved"],
    );
    // (name, dense quad, ssprop quad) from paper Table 4 Total Est. FLOPs
    for (name, dense_q, ss_q) in [
        ("CIFAR-10 ResNet-50 x250ep", 65.41, 39.47),
        ("ImageNet ResNet-18 x50ep", 7269.71, 4372.45),
        ("ImageNet ResNet-50 x50ep", 17064.82, 10298.23),
        ("CelebA DDPM x200ep", 3337.92, 2003.00),
    ] {
        let saved = (dense_q - ss_q) * 1e15;
        let r = estimate(saved, &RTX_A5000);
        t.row(vec![
            name.to_string(),
            format!("{dense_q} Quad."),
            format!("{ss_q} Quad."),
            fmt_flops(saved),
            f2(r.kwh),
            f2(r.gco2e),
        ]);
    }
    t.save_json("energy");
    t
}
