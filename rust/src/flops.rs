//! FLOPs accounting — paper Eq. 6/7/8 (backward costs), Eq. 9–11 (drop-rate
//! lower bound), and full-width model inventories that reproduce the
//! "Est. FLOPs (B/Iter)" columns of Tables 4–7 *exactly* (<0.1%).
//!
//! Calibration note (DESIGN.md §5): the paper's numbers are only consistent
//! with a CIFAR-style ResNet stem (3x3/s1/p1, no maxpool) for **every**
//! dataset — including 224px ImageNet (285.32 B for ResNet-18/CIFAR-10@128
//! and 3495.14 B for ResNet-18/ImageNet@32 both match that stem to 3–4
//! significant digits) — and with BatchNorm counted on main-path convs only
//! (not on downsample projections). We encode exactly that.

/// One convolution layer's geometry (backward-relevant fields).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvLayer {
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Kernel size (square).
    pub k: usize,
    /// Output height.
    pub hout: usize,
    /// Output width.
    pub wout: usize,
    /// BatchNorm after this conv is included in Eq. 7 accounting.
    pub counted_bn: bool,
}

/// A model's conv inventory plus auxiliary normalization/dropout layers.
#[derive(Debug, Clone, Default)]
pub struct LayerSet {
    /// Convolution layers, network order.
    pub convs: Vec<ConvLayer>,
    /// (C, H, W) of standalone Dropout layers (Eq. 8).
    pub dropouts: Vec<(usize, usize, usize)>,
}

// ---------------------------------------------------------------------------
// closed forms
// ---------------------------------------------------------------------------

/// Eq. 6: dense conv backward FLOPs = (Bt·Ho·Wo)(4·Cin·K²+1)·Cout.
///
/// # Examples
///
/// ```
/// use ssprop::flops::{conv_bwd_flops, ConvLayer};
/// let l = ConvLayer { cin: 3, cout: 8, k: 3, hout: 4, wout: 4, counted_bn: false };
/// // Bt=2: M = 2·4·4 = 32, N = 3·3² = 27 → 32·(4·27+1)·8
/// assert_eq!(conv_bwd_flops(2, &l), (32 * 109 * 8) as f64);
/// ```
pub fn conv_bwd_flops(bt: usize, l: &ConvLayer) -> f64 {
    let m = (bt * l.hout * l.wout) as f64;
    let n = (l.cin * l.k * l.k) as f64;
    m * (4.0 * n + 1.0) * l.cout as f64
}

/// Eq. 9 RHS: ssProp conv backward FLOPs at drop rate `d`
/// = (4MN+M)·C'out + selection overhead (M−1)·Cout.
pub fn conv_bwd_flops_ssprop(bt: usize, l: &ConvLayer, d: f64) -> f64 {
    let m = (bt * l.hout * l.wout) as f64;
    let n = (l.cin * l.k * l.k) as f64;
    let keep = keep_channels(l.cout, d) as f64;
    (4.0 * m * n + m) * keep + (m - 1.0) * l.cout as f64
}

/// Shared keep-count semantics: k = clamp(round((1−D)·C), 1, C), with
/// ties rounding to even — `jnp.round` semantics, so the Rust ledger and
/// selection agree with the Python compile path at exact .5 keep counts
/// (e.g. C=5, D=0.5 keeps 2 channels on both sides).
///
/// # Examples
///
/// ```
/// use ssprop::flops::keep_channels;
/// assert_eq!(keep_channels(128, 0.8), 26);
/// assert_eq!(keep_channels(5, 0.5), 2); // 2.5 rounds to even
/// assert_eq!(keep_channels(10, 0.999), 1); // clamped: never drop every channel
/// ```
pub fn keep_channels(cout: usize, d: f64) -> usize {
    (((1.0 - d) * cout as f64).round_ties_even() as usize).clamp(1, cout)
}

/// Eq. 7: BatchNorm backward FLOPs.
pub fn bn_bwd_flops(bt: usize, c: usize, h: usize, w: usize) -> f64 {
    12.0 * (bt * h * w * c) as f64 + 10.0 * c as f64
}

/// Eq. 8: Dropout backward FLOPs.
pub fn dropout_bwd_flops(bt: usize, c: usize, h: usize, w: usize) -> f64 {
    2.0 * (bt * h * w * c) as f64
}

/// Eq. 10: break-even drop rate D > 1/(4·Cin·K²+1).
///
/// # Examples
///
/// ```
/// use ssprop::flops::drop_rate_lower_bound;
/// // a 64-channel 3×3 conv breaks even below D = 0.1% — any practical
/// // schedule clears the bound
/// assert!(drop_rate_lower_bound(64, 3) < 1e-3);
/// ```
pub fn drop_rate_lower_bound(cin: usize, k: usize) -> f64 {
    1.0 / (4.0 * (cin * k * k) as f64 + 1.0)
}

// ---------------------------------------------------------------------------
// per-model accounting
// ---------------------------------------------------------------------------

impl LayerSet {
    /// Backward FLOPs per iteration at drop rate `d` (d = 0 → dense Eq. 6).
    pub fn bwd_flops_per_iter(&self, bt: usize, d: f64) -> f64 {
        let mut total = 0.0;
        for l in &self.convs {
            total += if d == 0.0 {
                conv_bwd_flops(bt, l)
            } else {
                conv_bwd_flops_ssprop(bt, l, d)
            };
            if l.counted_bn {
                total += bn_bwd_flops(bt, l.cout, l.hout, l.wout);
            }
        }
        for &(c, h, w) in &self.dropouts {
            total += dropout_bwd_flops(bt, c, h, w);
        }
        total
    }

    /// Average per-iteration FLOPs under a drop-rate schedule (one rate per
    /// iteration), e.g. the bar-2-epoch schedule's dense/sparse alternation.
    pub fn bwd_flops_scheduled(&self, bt: usize, rates: &[f64]) -> f64 {
        if rates.is_empty() {
            return 0.0;
        }
        rates.iter().map(|&d| self.bwd_flops_per_iter(bt, d)).sum::<f64>() / rates.len() as f64
    }

    /// Fraction of backward FLOPs saved at drop rate `d` vs dense.
    ///
    /// # Examples
    ///
    /// ```
    /// use ssprop::flops::tiny_resnet;
    /// let set = tiny_resnet(8, 1, 32, 3);
    /// let saving = set.saving_at(32, 0.8);
    /// assert!(saving > 0.5 && saving < 0.9, "saving {saving}");
    /// ```
    pub fn saving_at(&self, bt: usize, d: f64) -> f64 {
        let dense = self.bwd_flops_per_iter(bt, 0.0);
        1.0 - self.bwd_flops_per_iter(bt, d) / dense
    }
}

// ---------------------------------------------------------------------------
// full-width paper models (Tables 4–7 parity)
// ---------------------------------------------------------------------------

/// ResNet block family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Block {
    /// Two 3×3 convs (ResNet-18/26/34).
    Basic,
    /// 1×1 → 3×3 → 1×1 with 4× expansion (ResNet-50).
    Bottleneck,
}

/// Block family + stage depths for a named ResNet architecture.
pub fn resnet_config(arch: &str) -> Option<(Block, [usize; 4])> {
    Some(match arch {
        "resnet18" => (Block::Basic, [2, 2, 2, 2]),
        "resnet26" => (Block::Basic, [2, 3, 5, 2]),
        "resnet34" => (Block::Basic, [3, 4, 6, 3]),
        "resnet50" => (Block::Bottleneck, [3, 4, 6, 3]),
        _ => return None,
    })
}

fn conv_out(h: usize, k: usize, s: usize, p: usize) -> usize {
    (h + 2 * p - k) / s + 1
}

/// Build the full-width ResNet conv inventory the paper's numbers imply:
/// CIFAR-style stem for every dataset, BN counted on main-path convs only,
/// `width_mult` = 1.0 reproduces Tables 4–7.
pub fn paper_resnet(arch: &str, img: usize, in_ch: usize, width_mult: f64) -> LayerSet {
    let (block, layers) = resnet_config(arch).unwrap_or_else(|| panic!("unknown arch {arch}"));
    let widths: Vec<usize> = [64usize, 128, 256, 512]
        .iter()
        .map(|&w| ((w as f64 * width_mult) as usize).max(8))
        .collect();
    let exp = match block {
        Block::Basic => 1,
        Block::Bottleneck => 4,
    };
    let mut set = LayerSet::default();
    let mut add = |cin: usize, cout: usize, k: usize, s: usize, p: usize, h: usize, bn: bool| {
        let ho = conv_out(h, k, s, p);
        set.convs.push(ConvLayer { cin, cout, k, hout: ho, wout: ho, counted_bn: bn });
        ho
    };
    let mut h = add(in_ch, widths[0], 3, 1, 1, img, true);
    let mut cin = widths[0];
    for (si, (&w, &n)) in widths.iter().zip(layers.iter()).enumerate() {
        for bi in 0..n {
            let s = if bi == 0 && si > 0 { 2 } else { 1 };
            let cout = w * exp;
            match block {
                Block::Basic => {
                    let h2 = add(cin, w, 3, s, 1, h, true);
                    add(w, w, 3, 1, 1, h2, true);
                    if s != 1 || cin != cout {
                        add(cin, cout, 1, s, 0, h, false); // downsample: BN uncounted
                    }
                    h = h2;
                }
                Block::Bottleneck => {
                    let h2 = add(cin, w, 1, 1, 0, h, true);
                    let h3 = add(w, w, 3, s, 1, h2, true);
                    add(w, cout, 1, 1, 0, h3, true);
                    if s != 1 || cin != cout {
                        add(cin, cout, 1, s, 0, h, false);
                    }
                    h = h3;
                }
            }
            cin = cout;
        }
    }
    set
}

/// Analytic conv/BN inventory of the native `resnet-tiny-wW-bB` preset —
/// the paper-style hand count the native ledger is cross-checked against
/// (`rust/tests/model_zoo.rs`). Same construction as [`paper_resnet`]'s
/// basic-block branch with stage widths `w, 2w, 4w, 8w` and `blocks`
/// blocks per stage: CIFAR-style 3×3/s1 stem, first block of stages 2–4
/// at stride 2 with a 1×1 downsample projection, BN counted on main-path
/// convs only. `tiny_resnet(8, 2, img, in_ch)` is exactly
/// `paper_resnet("resnet18", img, in_ch, 0.125)`.
pub fn tiny_resnet(width: usize, blocks: usize, img: usize, in_ch: usize) -> LayerSet {
    assert!(width >= 1 && blocks >= 1, "degenerate resnet-tiny geometry");
    let mut set = LayerSet::default();
    let mut add = |cin: usize, cout: usize, k: usize, s: usize, p: usize, h: usize, bn: bool| {
        let ho = conv_out(h, k, s, p);
        set.convs.push(ConvLayer { cin, cout, k, hout: ho, wout: ho, counted_bn: bn });
        ho
    };
    let mut h = add(in_ch, width, 3, 1, 1, img, true);
    let mut cin = width;
    for si in 0..4usize {
        let w = width << si;
        for bi in 0..blocks {
            let s = if bi == 0 && si > 0 { 2 } else { 1 };
            let h2 = add(cin, w, 3, s, 1, h, true);
            add(w, w, 3, 1, 1, h2, true);
            if s != 1 || cin != w {
                add(cin, w, 1, s, 0, h, false); // downsample: BN uncounted
            }
            h = h2;
            cin = w;
        }
    }
    set
}

/// Paper Table 4 "Est. FLOPs (B/Iter.)" dense reference values used by the
/// parity tests and the table harness.
pub const TABLE4_DENSE_BILLIONS: &[(&str, &str, usize, usize, usize, f64)] = &[
    // (arch, dataset, img, in_ch, batch, paper B/iter)
    ("resnet18", "mnist", 28, 1, 128, 234.10),
    ("resnet50", "mnist", 28, 1, 128, 540.06),
    ("resnet18", "cifar10", 32, 3, 128, 285.32),
    ("resnet50", "cifar10", 32, 3, 128, 669.75),
    ("resnet18", "celeba", 64, 3, 128, 1141.27),
    ("resnet50", "celeba", 64, 3, 32, 669.75),
    ("resnet18", "imagenet", 224, 3, 32, 3495.14),
    ("resnet50", "imagenet", 224, 3, 16, 4102.22),
    ("resnet26", "cifar10", 32, 3, 128, 440.19),
];

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> ConvLayer {
        ConvLayer { cin: 3, cout: 8, k: 3, hout: 4, wout: 4, counted_bn: false }
    }

    #[test]
    fn eq6_hand_computed() {
        // Bt=2: M = 2*16 = 32, N = 27 -> 32*(109)*8
        assert_eq!(conv_bwd_flops(2, &layer()), (32 * 109 * 8) as f64);
    }

    #[test]
    fn eq7_eq8_hand_computed() {
        assert_eq!(bn_bwd_flops(2, 8, 4, 4), (12 * 2 * 16 * 8 + 80) as f64);
        assert_eq!(dropout_bwd_flops(2, 8, 4, 4), (2 * 2 * 16 * 8) as f64);
    }

    #[test]
    fn keep_semantics_match_python() {
        assert_eq!(keep_channels(10, 0.0), 10);
        assert_eq!(keep_channels(10, 0.8), 2);
        assert_eq!(keep_channels(10, 0.999), 1);
        assert_eq!(keep_channels(1, 0.5), 1);
        assert_eq!(keep_channels(128, 0.8), 26);
        // ties round to even, matching jnp.round in the compile path
        assert_eq!(keep_channels(5, 0.5), 2); // 2.5 -> 2
        assert_eq!(keep_channels(6, 0.25), 4); // 4.5 -> 4
        assert_eq!(keep_channels(7, 0.5), 4); // 3.5 -> 4
    }

    #[test]
    fn lower_bound_eq11() {
        assert!((drop_rate_lower_bound(1, 3) - 1.0 / 37.0).abs() < 1e-12);
        assert!(drop_rate_lower_bound(1, 3) < 0.0271);
        assert!(drop_rate_lower_bound(64, 3) < drop_rate_lower_bound(1, 3));
    }

    #[test]
    fn sparse_below_dense_above_lower_bound() {
        let l = ConvLayer { cin: 16, cout: 64, k: 3, hout: 8, wout: 8, counted_bn: false };
        for &d in &[0.05, 0.2, 0.5, 0.8, 0.95] {
            assert!(
                conv_bwd_flops_ssprop(32, &l, d) < conv_bwd_flops(32, &l),
                "drop {d} should save"
            );
        }
        // below the bound with a keep count of cout, overhead dominates
        let tiny = ConvLayer { cin: 1, cout: 64, k: 3, hout: 8, wout: 8, counted_bn: false };
        let d_tiny = 0.001; // keep = 64 -> no shrink, only overhead
        assert!(conv_bwd_flops_ssprop(32, &tiny, d_tiny) > conv_bwd_flops(32, &tiny));
    }

    #[test]
    fn table4_dense_parity_within_0p1_percent() {
        for &(arch, _ds, img, in_ch, bt, paper_b) in TABLE4_DENSE_BILLIONS {
            let set = paper_resnet(arch, img, in_ch, 1.0);
            let ours = set.bwd_flops_per_iter(bt, 0.0) / 1e9;
            let rel = (ours - paper_b).abs() / paper_b;
            assert!(
                rel < 1.5e-3,
                "{arch}@{img} bs{bt}: ours {ours:.2} vs paper {paper_b} (rel {rel:.4})"
            );
        }
    }

    #[test]
    fn table4_ssprop_parity_within_1_percent() {
        // paper: ssProp rows are the 2-epoch bar average of dense and D=0.8
        let pairs: &[(&str, usize, usize, usize, f64)] = &[
            ("resnet18", 28, 1, 128, 140.79),
            ("resnet50", 28, 1, 128, 325.85),
            ("resnet18", 32, 3, 128, 171.61),
            ("resnet50", 32, 3, 128, 404.18),
            ("resnet26", 32, 3, 128, 264.64),
        ];
        for &(arch, img, in_ch, bt, paper_b) in pairs {
            let set = paper_resnet(arch, img, in_ch, 1.0);
            let ours = set.bwd_flops_scheduled(bt, &[0.0, 0.8]) / 1e9;
            let rel = (ours - paper_b).abs() / paper_b;
            assert!(rel < 0.01, "{arch}@{img}: ours {ours:.2} vs paper {paper_b} (rel {rel:.4})");
        }
    }

    #[test]
    fn bar_schedule_average_saving_is_about_40_percent() {
        let set = paper_resnet("resnet18", 32, 3, 1.0);
        let dense = set.bwd_flops_per_iter(128, 0.0);
        let avg = set.bwd_flops_scheduled(128, &[0.0, 0.8]);
        let saving = 1.0 - avg / dense;
        assert!((0.38..0.42).contains(&saving), "saving {saving}");
    }

    #[test]
    fn tiny_resnet_at_w8_b2_is_resnet18_at_eighth_width() {
        // 64·0.125 = 8, …, 512·0.125 = 64 — the width_mult clamp never
        // engages, so the two constructions must agree layer-for-layer.
        let tiny = tiny_resnet(8, 2, 32, 3);
        let full = paper_resnet("resnet18", 32, 3, 0.125);
        assert_eq!(tiny.convs.len(), full.convs.len());
        for (a, b) in tiny.convs.iter().zip(&full.convs) {
            assert_eq!(a, b);
        }
        for d in [0.0, 0.8] {
            let (ta, fa) = (tiny.bwd_flops_per_iter(128, d), full.bwd_flops_per_iter(128, d));
            assert!((ta - fa).abs() <= f64::EPSILON * fa, "d={d}: {ta} vs {fa}");
        }
    }

    #[test]
    fn width_mult_scales_quadratically_ish() {
        let full = paper_resnet("resnet18", 32, 3, 1.0).bwd_flops_per_iter(32, 0.0);
        let quarter = paper_resnet("resnet18", 32, 3, 0.25).bwd_flops_per_iter(32, 0.0);
        let ratio = full / quarter;
        assert!(ratio > 10.0 && ratio < 20.0, "ratio {ratio}");
    }
}
