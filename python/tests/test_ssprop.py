"""ssProp convolution semantics: masked path, compacted Pallas path, modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.ssprop import ConvSpec, make_ssprop_conv_pallas, ssprop_conv

KEY0 = jnp.zeros((2,), jnp.uint32)
SETTINGS = dict(max_examples=15, deadline=None)


def _mk(seed, bt=2, cin=3, cout=8, h=8, k=3):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(bt, cin, h, h)).astype(np.float32))
    w = jnp.array(rng.normal(size=(cout, cin, k, k)).astype(np.float32)) * 0.2
    b = jnp.array(rng.normal(size=(cout,)).astype(np.float32)) * 0.1
    return x, w, b


def _loss(spec, d, key=KEY0):
    def f(x, w, b):
        y = ssprop_conv(x, w, b, jnp.float32(d), key, spec)
        return jnp.sum(jnp.sin(y) * y)
    return f


def test_forward_equals_dense_conv():
    x, w, b = _mk(0)
    spec = ConvSpec(stride=1, padding=1)
    y = ssprop_conv(x, w, b, jnp.float32(0.8), KEY0, spec)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.conv_fwd_ref(x, w, b, stride=1, padding=1)),
                               rtol=1e-5, atol=1e-5)


def test_drop_zero_equals_dense_grads():
    """D=0 must reproduce dense back-prop bit-for-bit (bar scheduler's dense epochs)."""
    x, w, b = _mk(1)
    spec = ConvSpec(stride=1, padding=1)
    gx, gw, gb = jax.grad(_loss(spec, 0.0), (0, 1, 2))(x, w, b)

    def dense(x, w, b):
        y = ref.conv_fwd_ref(x, w, b, stride=1, padding=1)
        return jnp.sum(jnp.sin(y) * y)

    dx, dw, db = jax.grad(dense, (0, 1, 2))(x, w, b)
    np.testing.assert_array_equal(np.asarray(gx), np.asarray(dx))
    np.testing.assert_array_equal(np.asarray(gw), np.asarray(dw))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(db))


@settings(**SETTINGS)
@given(d=st.floats(0.05, 0.95), stride=st.sampled_from([1, 2]),
       padding=st.sampled_from([0, 1]), seed=st.integers(0, 2 ** 31))
def test_masked_grads_match_manual_masking(d, stride, padding, seed):
    x, w, b = _mk(seed)
    spec = ConvSpec(stride=stride, padding=padding)
    gx, gw, gb = jax.grad(_loss(spec, d), (0, 1, 2))(x, w, b)

    # manual: dense output grad, mask top-k channels, dense backward
    def fwd(x, w, b):
        return ref.conv_fwd_ref(x, w, b, stride=stride, padding=padding)

    y, vjp = jax.vjp(fwd, x, w, b)
    g = jnp.cos(y) * y + jnp.sin(y)
    mask = ref.topk_mask_ref(ref.importance_ref(g),
                             ref.keep_k_from_drop_rate(jnp.float32(d), g.shape[1]))
    gm = ref.mask_grad_ref(g, mask)
    mx, mw, mb = vjp(gm)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(mx), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(mw), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(mb), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["hw", "all"])
def test_alternate_modes_zero_the_right_entries(mode):
    x, w, b = _mk(2)
    spec = ConvSpec(stride=1, padding=1, mode=mode)
    d = 0.6

    def fwd(x, w, b):
        return ref.conv_fwd_ref(x, w, b, stride=1, padding=1)

    y, vjp = jax.vjp(fwd, x, w, b)
    g = jnp.cos(y) * y + jnp.sin(y)
    n = {"hw": g.shape[2] * g.shape[3], "all": g.shape[1] * g.shape[2] * g.shape[3]}[mode]
    mask = ref.topk_mask_ref(ref.importance_ref(g, mode),
                             ref.keep_k_from_drop_rate(jnp.float32(d), n))
    gm = ref.mask_grad_ref(g, mask, mode)
    mx, mw, mb = vjp(gm)
    gx, gw, gb = jax.grad(_loss(spec, d), (0, 1, 2))(x, w, b)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(mx), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(mw), rtol=1e-4, atol=1e-4)


def test_random_select_differs_from_topk_but_same_sparsity():
    x, w, b = _mk(3, cout=16)
    key = jnp.asarray([7, 9], jnp.uint32)
    d = 0.5
    gt = jax.grad(_loss(ConvSpec(1, 1, "channel", "topk"), d, key), 1)(x, w, b)
    gr = jax.grad(_loss(ConvSpec(1, 1, "channel", "random"), d, key), 1)(x, w, b)
    # per-output-channel dW rows: exactly k' nonzero in both
    nz_t = np.unique(np.nonzero(np.asarray(gt))[0]).size
    nz_r = np.unique(np.nonzero(np.asarray(gr))[0]).size
    assert nz_t == nz_r == 8
    assert not np.allclose(np.asarray(gt), np.asarray(gr))


def test_dropped_channels_get_zero_weight_grads():
    x, w, b = _mk(4, cout=10)
    spec = ConvSpec(stride=1, padding=1)
    gw = jax.grad(_loss(spec, 0.8), 1)(x, w, b)
    gb = jax.grad(_loss(spec, 0.8), 2)(x, w, b)
    rows = np.asarray(gw).reshape(10, -1)
    nonzero_rows = (np.abs(rows).sum(axis=1) > 0).sum()
    assert nonzero_rows == 2  # keep_k(0.8, 10) = 2
    assert (np.abs(np.asarray(gb)) > 0).sum() == 2


# ---------------------------------------------------------------------------
# compacted Pallas path
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(d=st.sampled_from([0.0, 0.25, 0.5, 0.8]), stride=st.sampled_from([1, 2]),
       seed=st.integers(0, 2 ** 31))
def test_pallas_compact_matches_masked(d, stride, seed):
    x, w, b = _mk(seed, h=9)
    conv_p = make_ssprop_conv_pallas(stride=stride, padding=1, drop_rate=d)
    spec = ConvSpec(stride=stride, padding=1)

    def loss_p(x, w, b):
        y = conv_p(x, w, b)
        return jnp.sum(jnp.sin(y) * y)

    np.testing.assert_allclose(
        np.asarray(conv_p(x, w, b)),
        np.asarray(ref.conv_fwd_ref(x, w, b, stride=stride, padding=1)),
        rtol=1e-4, atol=1e-4)
    px, pw, pb = jax.grad(loss_p, (0, 1, 2))(x, w, b)
    mx, mw, mb = jax.grad(_loss(spec, d), (0, 1, 2))(x, w, b)
    np.testing.assert_allclose(np.asarray(px), np.asarray(mx), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pw), np.asarray(mw), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(pb), np.asarray(mb), rtol=1e-3, atol=1e-4)


def test_compact_ref_matches_masked_ref():
    """sparse_bwd_compact_ref (shrunk matmuls) == masked dense backward."""
    x, w, b = _mk(6, cout=12)
    y = ref.conv_fwd_ref(x, w, b, stride=1, padding=1)
    g = jnp.tanh(y)
    imp = ref.importance_ref(g)
    k = int(ref.keep_k_from_drop_rate(jnp.float32(0.5), 12))
    idx = jnp.sort(jnp.argsort(-imp)[:k])
    cx, cw, cb_ = ref.sparse_bwd_compact_ref(x, w, g, idx, stride=1, padding=1)
    gm = ref.mask_grad_ref(g, ref.topk_mask_ref(imp, jnp.int32(k)))
    mx, mw, mb = ref.conv_bwd_ref(x, w, gm, stride=1, padding=1)
    np.testing.assert_allclose(np.asarray(cx), np.asarray(mx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cw), np.asarray(mw), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cb_), np.asarray(mb), rtol=1e-4, atol=1e-4)
