"""Static performance analysis of the AOT artifacts (EXPERIMENTS.md §Perf).

L2: HLO op histogram per artifact — fusion counts, dot/convolution counts,
    sort counts (the ssProp selection overhead), total instruction count.
L1: BlockSpec-derived VMEM footprint and MXU-utilization estimate for the
    Pallas img2col GEMMs at the paper's layer shapes. interpret=True gives
    CPU-numpy timings only, so TPU efficiency is *estimated structurally*:
      mxu_util = real MACs / padded-tile MACs  (tile quantization loss)
      vmem     = per-step working set (A tile + B tile + acc + out)

Usage:  python -m compile.analyze [--artifacts ../artifacts] [--only NAME]
"""

from __future__ import annotations

import argparse
import os
import re
from collections import Counter

from .kernels.matmul import BK, BM, BN, vmem_bytes

# result type may be a tuple "(f32[16]{0}, s32[16]{0})", hence the parens
OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},/() ]+?\s([a-z][\w\-]*)\(")


def hlo_op_histogram(text: str) -> Counter:
    """Count HLO instruction kinds in an HLO text module."""
    ops = Counter()
    for line in text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def summarize_artifact(path: str) -> dict:
    with open(path) as f:
        ops = hlo_op_histogram(f.read())
    total = sum(ops.values())
    return {
        "total_ops": total,
        "fusion": ops.get("fusion", 0),
        "dot": ops.get("dot", 0),
        "convolution": ops.get("convolution", 0),
        "sort": ops.get("sort", 0),
        "while": ops.get("while", 0),
        "top5": ops.most_common(5),
    }


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def gemm_tile_analysis(m: int, n: int, k: int, bm: int = BM, bn: int = BN, bk: int = BK) -> dict:
    """Tile-quantization MXU utilization + VMEM footprint for one GEMM."""
    bm, bn, bk = min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 8)), min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    real = m * n * k
    padded = mp * np_ * kp
    return {
        "gemm": (m, n, k),
        "block": (bm, bn, bk),
        "grid": (mp // bm, np_ // bn, kp // bk),
        "vmem_bytes": vmem_bytes(bm, bn, bk),
        "mxu_util": real / padded,
    }


def ssprop_backward_gemms(bt: int, cin: int, cout: int, k: int, ho: int, wo: int,
                          drop: float) -> list:
    """The two shrunk GEMMs of the compacted backward at drop rate `drop`."""
    mm = bt * ho * wo
    nn = cin * k * k
    keep = max(1, round((1.0 - drop) * cout))
    return [
        gemm_tile_analysis(nn, keep, mm),   # dW' = col_X^T @ col[dY]'
        gemm_tile_analysis(mm, nn, keep),   # dX  = col[dY]' @ col_W'^T
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("== L2: HLO op histograms ==")
    names = sorted(f for f in os.listdir(args.artifacts) if f.endswith(".hlo.txt"))
    if args.only:
        names = [n for n in names if args.only in n]
    for name in names:
        s = summarize_artifact(os.path.join(args.artifacts, name))
        print(f"{name:44s} ops={s['total_ops']:6d} fusion={s['fusion']:4d} "
              f"dot={s['dot']:3d} conv={s['convolution']:3d} sort={s['sort']:3d} "
              f"while={s['while']:3d}")

    print("\n== L1: Pallas GEMM tile analysis (ResNet-18 stage shapes, bs 128, full width) ==")
    for (cin, cout, k, ho) in [(64, 64, 3, 32), (128, 128, 3, 16), (256, 256, 3, 8),
                               (512, 512, 3, 4)]:
        for drop in (0.0, 0.8):
            for g in ssprop_backward_gemms(128, cin, cout, k, ho, ho, drop):
                print(f"conv {cin:3d}->{cout:3d} k{k} h{ho:2d} D={drop:.1f}  "
                      f"gemm={str(g['gemm']):22s} block={g['block']}  "
                      f"vmem={g['vmem_bytes']/1024:.0f} KiB  mxu_util={g['mxu_util']:.3f}")


if __name__ == "__main__":
    main()
