//! Native-backend hot path: img2col conv forward, dense vs compacted
//! sparse backward, the raw GEMM (blocked microkernel vs the naive
//! reference, emitted as `native/gemm_speedup_*`, plus the runtime-
//! dispatched SIMD kernel vs the portable scalar one on the same blocked
//! loop nest, `native/gemm_simd_speedup_*`), and — the headline —
//! the fused plan/workspace fwd+bwd vs the unfused op calls (the fused
//! path builds each (M, N) im2col matrix once per step instead of twice
//! and reuses every scratch buffer). Each executor section also times the
//! sparsity-aware backward GEMMs on the preset's actual conv shapes,
//! dense (all channels kept) vs the paper's D=0.5, and emits the summed
//! ratio as `native/sparse_gemm_speedup_{spec}_d50` — the FLOPs saving of
//! the compacted backward realized as wall-clock. Runs on the default
//! build (no PJRT, no artifacts), so any machine can baseline it:
//!
//! Run: `cargo bench --bench native_hotpath`
//!
//! `--smoke` shrinks warmup/iterations/budget to a CI-sized run that still
//! exercises every path (used by the CI release job). `--model SPEC`
//! restricts the run to the data-parallel executor section for that model
//! zoo preset (`simple-cnn-d4-w16`, `vgg-tiny`, `dropout-cnn-w8-p25`,
//! `resnet-tiny-w8-b1`, ...) and tags the `native/{serial,parallel}_step_*`
//! / `native/parallel_speedup_*` lines with the spec, so CI can compare the
//! sharding win across architectures; each per-model run closes with a
//! `native/bwd_speedup_{spec}_d80` line (serial dense step / serial sparse
//! step at the paper's D* = 0.8 — the model-level sparse-backward saving,
//! including through residual graphs and BatchNorm).
//!
//! Each executor section also compares the persistent `WorkerPool`
//! against the per-step scoped crew at D* = 0.8
//! (`native/pool_speedup_{spec}_t{2,4}`) and the batch-prefetch training
//! pipeline against the fully synchronous loop over short whole runs
//! (`native/pipeline_speedup_{spec}`) — both executors/loops produce
//! bit-identical results, so these ratios are pure wall-clock wins.
//!
//! `--json PATH` additionally serializes the run as a versioned
//! `bench_report::BenchReport` (`BENCH_native.json` schema — see
//! `docs/BENCHMARKS.md`): the fused/bwd/gemm conv ratios plus, when no
//! `--model` narrows the run, an executor section for **every**
//! `BASELINE_PRESETS` zoo preset with step times, speedup ratios, and the
//! deterministic Eq. 6/9 FLOPs + joules ledger. `ssprop bench-check` gates
//! that file against the committed baseline at the repo root.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use ssprop::backend::gemm::{gemm_into_tiled, gemm_ref, nr_for, GemmPack, Kernel, Operand, NR, NR2};
use ssprop::backend::im2col::im2col;
use ssprop::backend::sparse::{select_channels, sparse_bwd_with_cols, SparseBwdWorkspace};
use ssprop::backend::{
    build_model, parse_model_spec, Backend, Conv2d, Conv2dPlan, ExecConfig, NativeBackend,
    ParallelExecutor, Sequential, WorkerPool,
};
use ssprop::bench_report::{
    preset_ledger, BenchReport, PresetReport, BASELINE_PRESETS, BENCH_BATCH, BENCH_CLASSES,
    BENCH_IMG, BENCH_IN_CH,
};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::util::bench::{bench, fmt_ns, report};
use ssprop::util::rng::Pcg;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let model_arg = argv
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| argv.get(i + 1))
        .map(String::as_str);
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1)).cloned();
    let (warm, iters, secs) = if smoke { (1, 3, 1) } else { (2, 20, 6) };
    let budget = Duration::from_secs(secs);
    let mode = if smoke { "smoke" } else { "full" };

    // With an explicit --model, run only the data-parallel executor
    // section for that preset (CI invokes this once per zoo model).
    if let Some(spec) = model_arg {
        println!("== native backend hot path{} ==", if smoke { " (smoke)" } else { "" });
        let preset = parallel_section(spec, warm, iters, budget);
        if let Some(path) = json_path {
            let mut rep = BenchReport::new("native_hotpath", mode);
            rep.presets.push(preset);
            write_report(&rep, &path);
        }
        return;
    }

    let be = NativeBackend::new();
    println!("== native backend hot path{} ==", if smoke { " (smoke)" } else { "" });
    println!("-- conv fwd/bwd (bt 16, 32ch, 16x16, k3) --");

    let cfg = Conv2d { bt: 16, cin: 32, h: 16, w: 16, cout: 32, k: 3, stride: 1, padding: 1 };
    let mut rng = Pcg::new(3, 3);
    let x: Vec<f32> = (0..cfg.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cfg.w_len()).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..cfg.cout).map(|_| rng.normal() * 0.1).collect();
    let g: Vec<f32> = (0..cfg.out_len()).map(|_| rng.normal()).collect();

    let r = bench("native/conv_fwd", warm, iters, budget, || {
        std::hint::black_box(be.conv2d_fwd(&cfg, &x, &w, Some(&b)));
    });
    report(&r);

    for (label, d, need_dx) in [
        ("dense", 0.0f64, true),
        ("d50", 0.5, true),
        ("d80", 0.8, true),
        ("d80_nodx", 0.8, false),
    ] {
        let r = bench(&format!("native/conv_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, need_dx));
        });
        report(&r);
    }

    let mut conv_ratios = fused_section(&be, &cfg, &x, &w, &b, &g, warm, iters, budget);

    println!("\n-- raw GEMM: blocked microkernel vs naive reference --");
    for (m, k, n) in [(256usize, 288, 128), (1024, 576, 64)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let bb: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let naive = bench(&format!("native/gemm_naive_{m}x{k}x{n}"), warm, iters, budget, || {
            std::hint::black_box(gemm_ref(m, k, n, &a, &bb));
        });
        report(&naive);
        let blocked = bench(&format!("native/gemm_{m}x{k}x{n}"), warm, iters, budget, || {
            std::hint::black_box(be.gemm(m, k, n, &a, &bb));
        });
        report(&blocked);
        let speedup = naive.median_ns / blocked.median_ns;
        println!(
            "{:<48} {:>11.2}x (naive / blocked median)",
            format!("native/gemm_speedup_{m}x{k}x{n}"),
            speedup
        );
        conv_ratios.insert(format!("gemm_speedup_{m}x{k}x{n}"), speedup);

        // Same blocked loop nest, portable scalar kernel vs the
        // runtime-dispatched SIMD one — isolates the vector win from the
        // cache blocking (both shapes take the wide NR2 panel here).
        let kernel = Kernel::active();
        let mut pack = GemmPack::new();
        let mut c = vec![0f32; m * n];
        let scalar = bench(&format!("native/gemm_scalar_{m}x{k}x{n}"), warm, iters, budget, || {
            gemm_into_tiled(
                m,
                k,
                n,
                Operand::Dense(&a),
                Operand::Dense(&bb),
                &mut c,
                &mut pack,
                Kernel::Scalar,
                nr_for(n),
            );
            std::hint::black_box(&mut c);
        });
        report(&scalar);
        let simd = bench(&format!("native/gemm_simd_{m}x{k}x{n}"), warm, iters, budget, || {
            gemm_into_tiled(
                m,
                k,
                n,
                Operand::Dense(&a),
                Operand::Dense(&bb),
                &mut c,
                &mut pack,
                kernel,
                nr_for(n),
            );
            std::hint::black_box(&mut c);
        });
        report(&simd);
        let simd_speedup = scalar.median_ns / simd.median_ns;
        println!(
            "{:<48} {:>11.2}x (scalar / {} median)",
            format!("native/gemm_simd_speedup_{m}x{k}x{n}"),
            simd_speedup,
            kernel.name()
        );
        conv_ratios.insert(format!("gemm_simd_speedup_{m}x{k}x{n}"), simd_speedup);
    }

    println!("\n-- end-to-end SimpleCNN training step (planned path) --");
    for (label, d) in [("dense", 0.0f64), ("d80", 0.8)] {
        let mut t = NativeTrainer::new(NativeTrainConfig::quick("cifar10", 1, 1)).unwrap();
        let order = t.loader.epoch_order(0);
        let batch = t.loader.batch(&order, 0);
        let r = bench(&format!("native/train_step_{label}"), warm, iters, budget, || {
            t.step(&batch, d).unwrap();
        });
        report(&r);
    }

    // A plain run benches the default preset's executor; a `--json` run
    // covers every baseline preset so the artifact is gate-complete.
    let specs: &[&str] =
        if json_path.is_some() { BASELINE_PRESETS } else { &["simple-cnn-d4-w16"] };
    let mut presets = Vec::new();
    for spec in specs {
        presets.push(parallel_section(spec, warm, iters, budget));
    }

    if let Some(path) = json_path {
        let mut rep = BenchReport::new("native_hotpath", mode);
        rep.conv_ratios = conv_ratios;
        rep.presets = presets;
        write_report(&rep, &path);
    }
}

/// The tentpole comparison, two cuts:
///  * full layer step — unfused op calls (two im2col builds, fresh
///    buffers every call) vs the fused plan path (one build, workspace
///    reused across iterations);
///  * backward only — rebuild-the-cols (`conv2d_bwd_ssprop`) vs the
///    cached-cols workspace backward the fused path runs. At the
///    paper's drop rates the compacted GEMMs shrink, so the removed
///    patch gather dominates and this ratio is the headline saving.
///
/// Returns the `fused_speedup_*` / `bwd_speedup_*` ratios keyed as the
/// report schema's `conv_ratios`.
#[allow(clippy::too_many_arguments)]
fn fused_section(
    be: &NativeBackend,
    cfg: &Conv2d,
    x: &[f32],
    w: &[f32],
    b: &[f32],
    g: &[f32],
    warm: usize,
    iters: usize,
    budget: Duration,
) -> BTreeMap<String, f64> {
    println!("\n-- fused plan path vs unfused op calls --");
    let mut ratios = BTreeMap::new();
    let pairs = [("dense", 0.0f64, true), ("d80", 0.8, true), ("d80_nodx", 0.8, false)];
    for (label, d, need_dx) in pairs {
        let un = bench(&format!("native/unfused_fwd_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_fwd(cfg, x, w, Some(b)));
            std::hint::black_box(be.conv2d_bwd_ssprop(cfg, x, w, g, d, need_dx));
        });
        report(&un);
        let mut plan = Conv2dPlan::new(*cfg);
        let fu = bench(&format!("native/fused_fwd_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_fwd_bwd(&mut plan, x, w, Some(b), g, d, need_dx));
        });
        report(&fu);
        let bwd = bench(&format!("native/bwd_rebuild_cols_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_bwd_ssprop(cfg, x, w, g, d, need_dx));
        });
        report(&bwd);
        let cols = im2col(cfg, x);
        let mut ws = SparseBwdWorkspace::default();
        let cached = bench(&format!("native/bwd_cached_cols_{label}"), warm, iters, budget, || {
            let keep = select_channels(cfg, g, d);
            let out = sparse_bwd_with_cols(cfg, &cols, w, g, &keep, need_dx, &mut ws);
            std::hint::black_box(out);
        });
        report(&cached);
        let fused_speedup = un.median_ns / fu.median_ns;
        let bwd_speedup = bwd.median_ns / cached.median_ns;
        println!(
            "{:<48} {:>11.2}x (unfused / fused median)",
            format!("native/fused_speedup_{label}"),
            fused_speedup
        );
        println!(
            "{:<48} {:>11.2}x (rebuild / cached median)",
            format!("native/bwd_speedup_{label}"),
            bwd_speedup
        );
        ratios.insert(format!("fused_speedup_{label}"), fused_speedup);
        ratios.insert(format!("bwd_speedup_{label}"), bwd_speedup);
    }
    ratios
}

/// Data-parallel executor vs the serial step for one zoo preset on a
/// cifar10-sized input (3x32x32, bt 32). Each parallel step shards the
/// batch over the worker count, runs the layer graph per shard with
/// globally-reduced channel selection (and, for presets with BatchNorm,
/// globally-reduced batch statistics), and tree-reduces gradients;
/// `native/parallel_speedup_{spec}_*` is the serial/parallel median ratio
/// (> 1 = the sharded step is faster on this machine). The closing
/// `native/bwd_speedup_{spec}_d80` line is the whole-model sparse-backward
/// saving at the paper's D* = 0.8: serial dense step / serial d80 step —
/// tracked per preset so the residual-graph saving is visible next to the
/// plain conv stacks.
///
/// Closes with a sparse-GEMM subsection: the compacted backward
/// (`sparse_bwd_with_cols`, dx included) on each of the preset's *unique*
/// conv geometries, dense (all channels kept) vs the paper's D=0.5
/// importance selection — summed medians and their ratio, emitted as
/// `native/sparse_gemm_speedup_{spec}_d50`. Columns are prebuilt outside
/// the timer, so the ratio isolates what the sparsity-aware GEMM packing
/// skips. A second subsection times the same dW-shaped GEMMs dense at
/// both B-panel widths and emits `native/sparse_gemm_nr16_speedup_{spec}`
/// (nr8 / nr16 summed medians) — the wide-tile win the keep-count
/// heuristic forgoes when it narrows the panel.
///
/// Returns the section as a `PresetReport` (timings, ratios, and the
/// deterministic FLOPs/joules ledger) for `--json` serialization.
fn parallel_section(spec: &str, warm: usize, iters: usize, budget: Duration) -> PresetReport {
    let be = NativeBackend::new();
    let parsed = parse_model_spec(spec).expect("--model spec");
    let slug = parsed.canonical();
    let build = || -> Sequential {
        build_model(&parsed, BENCH_IN_CH, BENCH_IMG, BENCH_CLASSES, 11).expect("zoo build")
    };
    println!("\n-- data-parallel executor ({slug}, 3x32x32, bt 32) --");
    let n_in = BENCH_IN_CH * BENCH_IMG * BENCH_IMG;
    let bt = BENCH_BATCH;
    let mut prng = Pcg::new(17, 9);
    let px: Vec<f32> = (0..bt * n_in).map(|_| prng.normal()).collect();
    let py: Vec<i32> = (0..bt).map(|i| (i % BENCH_CLASSES) as i32).collect();
    let mut timings_ns = BTreeMap::new();
    let mut ratios = BTreeMap::new();
    let mut serial_medians = [0f64; 2];
    for (idx, (label, d)) in [("dense", 0.0f64), ("d80", 0.8)].into_iter().enumerate() {
        let mut serial = build();
        let name = format!("native/serial_step_{slug}_{label}");
        let base = bench(&name, warm, iters, budget, || {
            serial.train_step(&be, &px, &py, d, 0.01).unwrap();
        });
        report(&base);
        serial_medians[idx] = base.median_ns;
        timings_ns.insert(format!("serial_step_{label}_ns"), base.median_ns);
        for threads in [2usize, 4] {
            let mut model = build();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let name = format!("native/parallel_step_{slug}_{label}_t{threads}");
            let r = bench(&name, warm, iters, budget, || {
                exec.train_step(&mut model, &be, &px, &py, d, 0.01).unwrap();
            });
            report(&r);
            let speedup = base.median_ns / r.median_ns;
            println!(
                "{:<48} {:>11.2}x (serial / t{threads} median)",
                format!("native/parallel_speedup_{slug}_{label}_t{threads}"),
                speedup
            );
            timings_ns.insert(format!("parallel_step_{label}_t{threads}_ns"), r.median_ns);
            ratios.insert(format!("parallel_speedup_{label}_t{threads}"), speedup);
        }
    }
    // Persistent pool vs the per-step scoped crew at the paper's D* = 0.8.
    // Both executors run the *same* shared shard bodies (bit-identical
    // steps), so the ratio isolates what the pool amortizes: per-step
    // thread spawn/join. Biggest on tiny models where spawn cost rivals
    // the step itself.
    println!("-- persistent pool vs per-step spawn ({slug}, d80) --");
    for threads in [2usize, 4] {
        let mut model = build();
        let mut pool = WorkerPool::new(ExecConfig::with_threads(threads));
        let name = format!("native/pool_step_{slug}_d80_t{threads}");
        let r = bench(&name, warm, iters, budget, || {
            pool.train_step(&mut model, &be, &px, &py, 0.8, 0.01).unwrap();
        });
        report(&r);
        let scoped = timings_ns[&format!("parallel_step_d80_t{threads}_ns")];
        let speedup = scoped / r.median_ns;
        println!(
            "{:<48} {:>11.2}x (per-step spawn / pool median)",
            format!("native/pool_speedup_{slug}_t{threads}"),
            speedup
        );
        timings_ns.insert(format!("pool_step_d80_t{threads}_ns"), r.median_ns);
        ratios.insert(format!("pool_speedup_t{threads}"), speedup);
    }

    // Batch-prefetch pipeline vs the fully synchronous loop over short
    // whole training runs (same trainer, same bits — `pipeline` is purely
    // a wall-clock knob, so the ratio is the prefetch overlap realized).
    println!("-- batch-prefetch pipeline vs sync loop ({slug}, short runs) --");
    let train_cfg = |pipeline: bool| {
        let mut cfg = NativeTrainConfig::quick("cifar10", 2, 4);
        cfg.model = slug.clone();
        cfg.batch = 16;
        cfg.threads = 2;
        cfg.pipeline = pipeline;
        cfg
    };
    let sync = bench(&format!("native/sync_run_{slug}"), warm, iters, budget, || {
        let mut t = NativeTrainer::new(train_cfg(false)).unwrap();
        std::hint::black_box(t.run().unwrap());
    });
    report(&sync);
    let piped = bench(&format!("native/pipeline_run_{slug}"), warm, iters, budget, || {
        let mut t = NativeTrainer::new(train_cfg(true)).unwrap();
        std::hint::black_box(t.run().unwrap());
    });
    report(&piped);
    let pipeline_speedup = sync.median_ns / piped.median_ns;
    println!(
        "{:<48} {:>11.2}x (sync / pipelined median)",
        format!("native/pipeline_speedup_{slug}"),
        pipeline_speedup
    );
    timings_ns.insert("sync_run_ns".to_string(), sync.median_ns);
    timings_ns.insert("pipeline_run_ns".to_string(), piped.median_ns);
    ratios.insert("pipeline_speedup".to_string(), pipeline_speedup);

    let model_bwd_speedup = serial_medians[0] / serial_medians[1];
    println!(
        "{:<48} {:>11.2}x (serial dense / serial d80 median)",
        format!("native/bwd_speedup_{slug}_d80"),
        model_bwd_speedup
    );
    ratios.insert("bwd_speedup_d80".to_string(), model_bwd_speedup);

    println!("-- sparse backward GEMMs ({slug} conv shapes, dense vs D=0.5) --");
    let mut geoms: Vec<Conv2d> = Vec::new();
    for gcfg in build().conv_geoms() {
        let gcfg = gcfg.with_batch(bt);
        if !geoms.contains(&gcfg) {
            geoms.push(gcfg);
        }
    }
    let (mut dense_total, mut d50_total) = (0f64, 0f64);
    for (gi, gcfg) in geoms.iter().enumerate() {
        let mut grng = Pcg::new(29, gi as u64);
        let gx: Vec<f32> = (0..gcfg.in_len()).map(|_| grng.normal()).collect();
        let gw: Vec<f32> = (0..gcfg.w_len()).map(|_| grng.normal() * 0.1).collect();
        let gg: Vec<f32> = (0..gcfg.out_len()).map(|_| grng.normal()).collect();
        let cols = im2col(gcfg, &gx);
        let mut ws = SparseBwdWorkspace::default();
        let all: Vec<usize> = (0..gcfg.cout).collect();
        let keep = select_channels(gcfg, &gg, 0.5);
        let dn = bench(&format!("native/sparse_gemm_dense_{slug}_l{gi}"), warm, iters, budget, || {
            let out = sparse_bwd_with_cols(gcfg, &cols, &gw, &gg, &all, true, &mut ws);
            std::hint::black_box(out);
        });
        report(&dn);
        let sp = bench(&format!("native/sparse_gemm_d50_{slug}_l{gi}"), warm, iters, budget, || {
            let out = sparse_bwd_with_cols(gcfg, &cols, &gw, &gg, &keep, true, &mut ws);
            std::hint::black_box(out);
        });
        report(&sp);
        dense_total += dn.median_ns;
        d50_total += sp.median_ns;
    }
    let sparse_speedup = dense_total / d50_total;
    println!("{:<48} {:>11}", format!("native/sparse_gemm_dense_{slug}"), fmt_ns(dense_total));
    println!("{:<48} {:>11}", format!("native/sparse_gemm_d50_{slug}"), fmt_ns(d50_total));
    println!(
        "{:<48} {:>11.2}x (dense / d50 summed medians)",
        format!("native/sparse_gemm_speedup_{slug}_d50"),
        sparse_speedup
    );
    timings_ns.insert("sparse_gemm_dense_ns".to_string(), dense_total);
    timings_ns.insert("sparse_gemm_d50_ns".to_string(), d50_total);
    ratios.insert("sparse_gemm_speedup_d50".to_string(), sparse_speedup);

    // Wide (NR2 = 16) vs narrow (NR = 8) B-panels on dense dW-shaped GEMMs
    // ((Cin·K·K, M) · (M, Cout)) over the same unique conv geometries,
    // active kernel on both sides. The outputs are bit-identical — the
    // summed-median ratio is exactly what the keep-count heuristic trades
    // away when a small keep set narrows the panel.
    println!("-- dW GEMM tile width ({slug} conv shapes, NR 8 vs 16) --");
    let kernel = Kernel::active();
    let (mut nr8_total, mut nr16_total) = (0f64, 0f64);
    for (gi, gcfg) in geoms.iter().enumerate() {
        let (gm, gk, gn) = (gcfg.n(), gcfg.m(), gcfg.cout);
        let mut wrng = Pcg::new(31, gi as u64);
        let wa: Vec<f32> = (0..gm * gk).map(|_| wrng.normal()).collect();
        let wb: Vec<f32> = (0..gk * gn).map(|_| wrng.normal()).collect();
        let mut pack = GemmPack::new();
        let mut c = vec![0f32; gm * gn];
        for (nr, total) in [(NR, &mut nr8_total), (NR2, &mut nr16_total)] {
            let name = format!("native/sparse_gemm_nr{nr}_{slug}_l{gi}");
            let r = bench(&name, warm, iters, budget, || {
                gemm_into_tiled(
                    gm,
                    gk,
                    gn,
                    Operand::Dense(&wa),
                    Operand::Dense(&wb),
                    &mut c,
                    &mut pack,
                    kernel,
                    nr,
                );
                std::hint::black_box(&mut c);
            });
            report(&r);
            *total += r.median_ns;
        }
    }
    let nr16_speedup = nr8_total / nr16_total;
    println!("{:<48} {:>11}", format!("native/sparse_gemm_nr8_{slug}"), fmt_ns(nr8_total));
    println!("{:<48} {:>11}", format!("native/sparse_gemm_nr16_{slug}"), fmt_ns(nr16_total));
    println!(
        "{:<48} {:>11.2}x (nr8 / nr16 summed medians)",
        format!("native/sparse_gemm_nr16_speedup_{slug}"),
        nr16_speedup
    );
    timings_ns.insert("sparse_gemm_nr8_ns".to_string(), nr8_total);
    timings_ns.insert("sparse_gemm_nr16_ns".to_string(), nr16_total);
    ratios.insert("sparse_gemm_nr16_speedup".to_string(), nr16_speedup);

    let (flops, energy) = preset_ledger(&slug, bt).expect("preset ledger");
    PresetReport { spec: slug, timings_ns, ratios, flops, energy }
}

fn write_report(rep: &BenchReport, path: &str) {
    rep.save(Path::new(path)).expect("write bench report");
    println!("\nwrote {path}");
}
