//! Minimal, offline-vendored drop-in for the subset of `anyhow` this
//! workspace uses: [`Error`], [`Result`], [`Context`], and the [`anyhow!`] /
//! [`bail!`] macros.
//!
//! The real `anyhow` crate is not in the offline vendor set (DESIGN.md S9:
//! no crates.io access at build time), so this shim keeps the crate
//! dependency-free while preserving the familiar API. Differences from the
//! real crate are deliberate simplifications:
//!
//! * no backtrace capture,
//! * [`Error::downcast_ref`] walks the whole `source()` chain (the real
//!   crate only inspects context/root values it created),
//! * `Display` shows the outermost message; `Debug` shows the chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error: a boxed [`std::error::Error`] chain.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E>(err: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(err) }
    }

    /// Build an error from a displayable message (no source).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Attach a context message, keeping `self` as the source.
    pub fn context<C>(self, context: C) -> Error
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        Error { inner: Box::new(ContextError { context: context.to_string(), source: self.inner }) }
    }

    /// First error in the chain (outermost to root) that downcasts to `T`.
    pub fn downcast_ref<T>(&self) -> Option<&T>
    where
        T: StdError + 'static,
    {
        let mut cur: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        while let Some(err) = cur {
            if let Some(hit) = err.downcast_ref::<T>() {
                return Some(hit);
            }
            cur = err.source();
        }
        None
    }

    /// Iterate the `source()` chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> + '_ {
        let mut cur: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        std::iter::from_fn(move || {
            let err = cur?;
            cur = err.source();
            Some(err)
        })
    }

    /// Root (innermost) error of the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut cur = self.inner.source();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(err) = cur {
            write!(f, "\n    {err}")?;
            cur = err.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// Message-only error (what `anyhow!` / `Error::msg` produce).
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer over an underlying error.
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ContextError({:?})", self.context)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl StdError for Typed {}

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let err = fails().unwrap_err();
        assert_eq!(err.to_string(), "boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_result_option_and_error() {
        let e: Result<()> = Err(Typed(7)).context("outer");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "outer");

        let o: Result<u32> = None.with_context(|| format!("missing {}", "x"));
        assert_eq!(o.unwrap_err().to_string(), "missing x");

        let chained: Result<()> = Err(Error::new(Typed(9))).context("layer");
        assert_eq!(chained.unwrap_err().to_string(), "layer");
    }

    #[test]
    fn downcast_walks_the_chain() {
        let err = Error::new(Typed(3)).context("ctx1").context("ctx2");
        assert_eq!(err.downcast_ref::<Typed>(), Some(&Typed(3)));
        assert!(err.downcast_ref::<std::io::Error>().is_none());
        assert_eq!(err.root_cause().to_string(), "typed error 3");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let err = Error::new(Typed(5)).context("while testing");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("while testing"));
        assert!(dbg.contains("typed error 5"));
    }

    #[test]
    fn error_msg_from_string() {
        let err: Error = Error::msg(String::from("plain"));
        assert_eq!(err.to_string(), "plain");
        let err = anyhow!("value {v}", v = 1);
        assert_eq!(err.to_string(), "value 1");
    }
}
