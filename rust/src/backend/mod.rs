//! Backend subsystem: pluggable executors for the conv ops ssProp needs.
//!
//! The [`Backend`] trait's primitive contract is the **plan path**: a
//! [`Conv2dPlan`] holds one layer's reusable buffers and the planned
//! forward caches its im2col column matrix there for the planned ssProp
//! backward (channel-importance top-k selection + compacted GEMMs, paper
//! Sec. "Scheduled Sparse BP") to consume — one patch gather per layer per
//! step instead of two. The historical op-level methods
//! ([`Backend::conv2d_fwd`], [`Backend::conv2d_bwd_ssprop`]) are
//! default-implemented wrappers that run the same code through a throwaway
//! plan, so existing callers and the PJRT feature keep compiling.
//! [`NativeBackend`] implements the plan path in pure Rust (img2col GEMMs
//! mirroring `python/compile/kernels/ref.py`, executed by the
//! cache-blocked microkernel in [`gemm`] with a sparsity-aware packing
//! path for the compacted backward), so the default build trains
//! end-to-end on any machine with zero FFI dependencies. The PJRT
//! whole-graph path (`runtime/`, behind the `pjrt` feature) remains the
//! fast AOT route when compiled artifacts exist.
//!
//! Above the trait sit the model and execution layers: [`layers`] is the
//! composable layer-graph API (a [`Layer`] trait plus conv / activation /
//! norm / pool / linear building blocks under a residual-capable
//! [`Graph`] container — [`Sequential`] is its chain-shaped constructor;
//! [`zoo`] parses `--model` specs into presets, including the
//! `resnet-tiny` residual/BatchNorm preset, and [`simple_cnn`] is the
//! paper's Fig. 4 model as a thin constructor over it), and [`parallel`] /
//! [`pool`] are the execution layer: each training batch shards over a
//! fixed worker count, the fused plan path runs per shard on per-worker
//! node workspaces (no locking on the hot path), channel selection and
//! BatchNorm batch statistics reduce globally at barrier rendezvous, and
//! gradients tree-reduce in a fixed order so runs are bit-reproducible.
//! [`ParallelExecutor`] spawns a scoped crew per step; [`WorkerPool`] is
//! the persistent production variant with identical bits. See
//! `docs/ARCHITECTURE.md` for the layer map, the sharding/reduction
//! design, and the executor lifecycle. For inference, [`fold`] converts trained
//! checkpoints into BN-free folded models that the no-workspace eval walk
//! and the `serve` subcommand run.
//!
//! Layout conventions follow the paper throughout: activations NCHW,
//! weights OIHW, row-major flattened `Vec<f32>`.

pub mod fold;
pub mod gemm;
pub mod im2col;
pub mod layers;
pub mod native;
pub mod parallel;
pub mod plan;
pub mod pool;
pub mod simple_cnn;
pub mod sparse;
pub mod zoo;

pub use layers::{Graph, GraphBuilder, Layer, LayerWs, Sequential, Shape, StepStats};
pub use native::NativeBackend;
pub use parallel::{ExecConfig, ParallelExecutor};
pub use pool::WorkerPool;
pub use plan::Conv2dPlan;
pub use simple_cnn::{simple_cnn, SimpleCnnCfg};
pub use zoo::{build_model, parse_model_spec, ModelSpec, ModelSpecError};

/// Geometry of one conv2d call (square kernel/stride/padding, as in the
/// paper's Eq. 1 and the AOT manifests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2d {
    /// Batch size.
    pub bt: usize,
    /// Input channels.
    pub cin: usize,
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Output channels.
    pub cout: usize,
    /// Kernel size (square, K×K).
    pub k: usize,
    /// Stride (same both axes).
    pub stride: usize,
    /// Zero padding (same both axes).
    pub padding: usize,
}

impl Conv2d {
    /// Output height: (H + 2P − K) / S + 1.
    pub fn hout(&self) -> usize {
        im2col::out_size(self.h, self.k, self.stride, self.padding)
    }

    /// Output width: (W + 2P − K) / S + 1.
    pub fn wout(&self) -> usize {
        im2col::out_size(self.w, self.k, self.stride, self.padding)
    }

    /// GEMM row count M = Bt·Hout·Wout.
    pub fn m(&self) -> usize {
        self.bt * self.hout() * self.wout()
    }

    /// GEMM depth N = Cin·K².
    pub fn n(&self) -> usize {
        self.cin * self.k * self.k
    }

    /// Flattened input activation length: Bt·Cin·H·W.
    pub fn in_len(&self) -> usize {
        self.bt * self.cin * self.h * self.w
    }

    /// Flattened output activation length: Bt·Cout·Hout·Wout.
    pub fn out_len(&self) -> usize {
        self.bt * self.cout * self.hout() * self.wout()
    }

    /// Flattened weight length: Cout·Cin·K².
    pub fn w_len(&self) -> usize {
        self.cout * self.cin * self.k * self.k
    }

    /// The same geometry at batch size `bt` (the sub-batch key the
    /// data-parallel executor shards a full-batch geometry down to).
    pub fn with_batch(&self, bt: usize) -> Conv2d {
        Conv2d { bt, ..*self }
    }
}

/// Gradients of one conv layer under ssProp selection. Dropped output
/// channels hold exactly-zero `dw`/`db` rows; `dx` receives only the kept
/// channels' contributions — identical numerics to the masked path.
#[derive(Debug, Clone)]
pub struct ConvGrads {
    /// d loss / d x, shape (Bt, Cin, H, W) — empty when the caller asked
    /// to skip it (`need_dx = false`, e.g. the first layer of a network).
    pub dx: Vec<f32>,
    /// d loss / d w, shape (Cout, Cin, K, K).
    pub dw: Vec<f32>,
    /// d loss / d b, shape (Cout,).
    pub db: Vec<f32>,
    /// Channels selected by importance top-k (ascending; all when dense).
    pub keep_idx: Vec<usize>,
}

/// Conv executor. The plan-path methods are the primitives every
/// implementation provides; the op-level methods are provided wrappers
/// over them. Implementations must match the reference oracle
/// `python/compile/kernels/ref.py` within f32 tolerance (enforced by
/// `rust/tests/native_backend.rs` fixtures on both routes).
///
/// `Send + Sync` is a supertrait so one backend can be shared by the
/// data-parallel executor's worker threads; backends hold no per-call
/// state (all mutable scratch lives in the caller's [`Conv2dPlan`]).
pub trait Backend: Send + Sync {
    /// Short stable identifier ("native", "pjrt", ...) for logs/reports.
    fn name(&self) -> &'static str;

    /// Planned dense conv forward `y = x * w (+ b)` in NCHW/OIHW (paper
    /// Eq. 1). Geometry comes from the plan ([`Conv2dPlan::cfg`]); the
    /// im2col columns of `x` are built into the plan's buffers and stay
    /// cached there for the next planned backward on the same plan.
    fn conv2d_fwd_planned(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        b: Option<&[f32]>,
    ) -> Vec<f32>;

    /// Planned ssProp backward with the kept channels *already chosen*
    /// (paper Eq. 3/4/5 with the channel compaction): run the shrunk
    /// img2col GEMMs for exactly `keep_idx` (ascending, non-empty) out of
    /// the plan's workspace. Consumes the plan's cached columns when live
    /// (skipping the patch gather entirely — they must correspond to this
    /// `x`); otherwise gathers them from `x` first. Either way the cache
    /// is spent afterwards. `need_dx = false` skips the col[dX] GEMM +
    /// scatter entirely (the first layer of a network never consumes dx —
    /// a large share of its backward cost).
    ///
    /// This is the selection-free primitive the data-parallel executor
    /// calls: selection there is *global* (importance reduced across
    /// shards), so it cannot live inside the per-shard backward.
    fn conv2d_bwd_planned_with(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        g: &[f32],
        keep_idx: &[usize],
        need_dx: bool,
    ) -> ConvGrads;

    /// Planned ssProp backward at `drop_rate`: importance = mean |g| over
    /// (Bt, H, W) per output channel; keep k = clamp(round((1−D)·Cout),
    /// 1, Cout) channels (ties to even, matching the compile path); then
    /// run [`Backend::conv2d_bwd_planned_with`] on the selection.
    /// `drop_rate = 0` reproduces exact dense gradients.
    fn conv2d_bwd_planned(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        g: &[f32],
        drop_rate: f64,
        need_dx: bool,
    ) -> ConvGrads {
        let keep_idx = sparse::select_channels(plan.cfg(), g, drop_rate);
        self.conv2d_bwd_planned_with(plan, x, w, g, &keep_idx, need_dx)
    }

    /// Fused forward+backward: one im2col build shared by both passes —
    /// the layer-step primitive `Sequential::train_step` is built on.
    fn conv2d_fwd_bwd(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        b: Option<&[f32]>,
        g: &[f32],
        drop_rate: f64,
        need_dx: bool,
    ) -> (Vec<f32>, ConvGrads) {
        let y = self.conv2d_fwd_planned(plan, x, w, b);
        let grads = self.conv2d_bwd_planned(plan, x, w, g, drop_rate, need_dx);
        (y, grads)
    }

    /// Op-level dense conv forward (throwaway plan per call). Prefer the
    /// plan path on hot loops.
    fn conv2d_fwd(&self, cfg: &Conv2d, x: &[f32], w: &[f32], b: Option<&[f32]>) -> Vec<f32> {
        self.conv2d_fwd_planned(&mut Conv2dPlan::new(*cfg), x, w, b)
    }

    /// Op-level ssProp backward (throwaway plan per call; rebuilds the
    /// columns it could have reused). Prefer the plan path on hot loops.
    fn conv2d_bwd_ssprop(
        &self,
        cfg: &Conv2d,
        x: &[f32],
        w: &[f32],
        g: &[f32],
        drop_rate: f64,
        need_dx: bool,
    ) -> ConvGrads {
        self.conv2d_bwd_planned(&mut Conv2dPlan::new(*cfg), x, w, g, drop_rate, need_dx)
    }

    /// Row-major GEMM helper: C(m×n) = A(m×k) · B(k×n).
    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32>;

    /// Add a per-channel bias onto an NCHW activation in place.
    fn bias_add(&self, cfg: &Conv2d, y: &mut [f32], b: &[f32]);
}

/// The backend every coordinator path uses unless an accelerator route is
/// explicitly selected (the PJRT path routes whole graphs, not single ops).
pub fn default_backend() -> Box<dyn Backend> {
    Box::new(NativeBackend::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_geometry() {
        let c = Conv2d { bt: 2, cin: 3, h: 6, w: 6, cout: 8, k: 3, stride: 1, padding: 1 };
        assert_eq!((c.hout(), c.wout()), (6, 6));
        assert_eq!(c.m(), 2 * 36);
        assert_eq!(c.n(), 27);
        assert_eq!(c.in_len(), 2 * 3 * 36);
        assert_eq!(c.out_len(), 2 * 8 * 36);
        assert_eq!(c.w_len(), 8 * 27);

        let s2 = Conv2d { bt: 1, cin: 2, h: 5, w: 5, cout: 4, k: 3, stride: 2, padding: 0 };
        assert_eq!((s2.hout(), s2.wout()), (2, 2));

        let sub = c.with_batch(1);
        assert_eq!(sub.bt, 1);
        assert_eq!(Conv2d { bt: 2, ..sub }, c, "with_batch changes only the batch");
    }

    #[test]
    fn default_backend_is_native() {
        assert_eq!(default_backend().name(), "native");
    }
}
