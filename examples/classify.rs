//! End-to-end driver (DESIGN.md §7): dense vs ssProp on the synthetic
//! CIFAR-10 substitute, logging both loss curves to
//! results/classify_loss.csv and reporting the Table 4-style comparison.
//!
//! On the default build this drives the **native** backend over any zoo
//! `--model` spec (default: the residual/BatchNorm `resnet-tiny` preset,
//! the native counterpart of the paper's ResNet rows), then closes the
//! loop as a client of the inference serving path: the ssProp-trained
//! model is checkpointed, BN-folded where the spec has BatchNorms
//! (`ssprop::backend::fold`), and a batch of classify requests is
//! answered through `ssprop::coordinator::Server` — the same path the
//! `ssprop serve` subcommand runs:
//!
//! ```bash
//! cargo run --release --example classify -- --model resnet-tiny-w8-b2 \
//!     --epochs 4 --iters 16
//! ```
//!
//! With `--features pjrt` + artifacts (`make artifacts`) it drives the
//! AOT-compiled ResNet-18 instead:
//!
//! ```bash
//! cargo run --release --features pjrt --example classify -- --epochs 6 --iters 50
//! ```

use anyhow::Result;

#[cfg(feature = "pjrt")]
mod pjrt_example {
    use std::io::Write as _;

    use anyhow::Result;
    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::flops::paper_resnet;
    use ssprop::runtime::Engine;
    use ssprop::schedule::{DropScheduler, Schedule};
    use ssprop::util::cli::Args;

    fn train(
        engine: &Engine,
        label: &str,
        schedule: Schedule,
        target: f64,
        epochs: usize,
        ipe: usize,
    ) -> Result<Trainer> {
        let cfg = TrainConfig {
            artifact: "resnet18_cifar10".into(),
            epochs,
            iters_per_epoch: ipe,
            lr: 1e-3,
            scheduler: DropScheduler::new(schedule, target, epochs, ipe),
            dropout_rate: 0.0,
            seed: 0,
            eval_every: 0,
            verbose: false,
        };
        let mut t = Trainer::new(engine, cfg)?;
        let (loss, acc) = t.run()?;
        let m = &t.metrics;
        println!(
            "{label:<10} test loss {loss:.4}  test acc {acc:.3}  bwd FLOPs {:.3e} \
             ({:.1}% saved)  wall {:.1}s",
            m.flops_actual,
            m.flops_saving() * 100.0,
            m.total_wall_secs()
        );
        Ok(t)
    }

    pub fn run() -> Result<()> {
        let args = Args::from_env();
        let epochs = args.get_usize("epochs", 6);
        let ipe = args.get_usize("iters", 50);
        let engine = Engine::auto()?;

        println!("== e2e: ResNet-18 (w=0.25), synth-CIFAR-10, {epochs} epochs x {ipe} iters ==\n");
        let dense = train(&engine, "dense", Schedule::Constant, 0.0, epochs, ipe)?;
        let ssprop =
            train(&engine, "ssProp", Schedule::EpochBar { period_epochs: 2 }, 0.8, epochs, ipe)?;

        // full-width analytic comparison (the paper's Table 4 row)
        let full = paper_resnet("resnet18", 32, 3, 1.0);
        println!("\nfull-width analytic (paper Table 4, bs 128):");
        println!("  dense  {:.2} B/iter (paper 285.32)", full.bwd_flops_per_iter(128, 0.0) / 1e9);
        println!(
            "  ssProp {:.2} B/iter (paper 171.61)",
            full.bwd_flops_scheduled(128, &[0.0, 0.8]) / 1e9
        );

        std::fs::create_dir_all("results")?;
        let mut f = std::fs::File::create("results/classify_loss.csv")?;
        writeln!(f, "iter,dense_loss,ssprop_loss,ssprop_drop_rate")?;
        for i in 0..dense.metrics.losses.len().min(ssprop.metrics.losses.len()) {
            writeln!(
                f,
                "{i},{:.6},{:.6},{:.2}",
                dense.metrics.losses[i], ssprop.metrics.losses[i], ssprop.metrics.drop_rates[i]
            )?;
        }
        println!("\nloss curves -> results/classify_loss.csv");
        Ok(())
    }
}

#[cfg(not(feature = "pjrt"))]
mod native_example {
    use std::io::Write as _;
    use std::path::Path;

    use anyhow::Result;
    use ssprop::backend::fold;
    use ssprop::coordinator::{
        ClassifyRequest, NativeTrainConfig, NativeTrainer, ServeConfig, Server,
    };
    use ssprop::schedule::{DropScheduler, Schedule};
    use ssprop::util::bench::fmt_ns;
    use ssprop::util::cli::Args;
    use ssprop::util::rng::Pcg;

    fn train(
        model: &str,
        label: &str,
        schedule: Schedule,
        target: f64,
        epochs: usize,
        ipe: usize,
    ) -> Result<NativeTrainer> {
        let mut cfg = NativeTrainConfig::quick("cifar10", epochs, ipe);
        cfg.model = model.to_string();
        cfg.lr = 0.05;
        cfg.scheduler = DropScheduler::new(schedule, target, epochs, ipe);
        let mut t = NativeTrainer::new(cfg)?;
        let (loss, acc) = t.run()?;
        let m = &t.metrics;
        println!(
            "{label:<10} test loss {loss:.4}  test acc {acc:.3}  bwd FLOPs {:.3e} \
             ({:.1}% saved)  wall {:.1}s",
            m.flops_actual,
            m.flops_saving() * 100.0,
            m.total_wall_secs()
        );
        Ok(t)
    }

    pub fn run() -> Result<()> {
        let args = Args::from_env();
        let model = args.get_or("model", "resnet-tiny").to_string();
        let epochs = args.get_usize("epochs", 4);
        let ipe = args.get_usize("iters", 12);

        println!(
            "== e2e (native): --model {model} on synth-CIFAR-10, {epochs} epochs x {ipe} iters ==\n"
        );
        let probe = train(&model, "dense", Schedule::Constant, 0.0, epochs, ipe)?;
        let ssprop =
            train(&model, "ssProp", Schedule::EpochBar { period_epochs: 2 }, 0.8, epochs, ipe)?;
        println!("\nmodel {} ({})", probe.model_spec, probe.model.describe());

        std::fs::create_dir_all("results")?;
        let mut f = std::fs::File::create("results/classify_loss.csv")?;
        writeln!(f, "iter,dense_loss,ssprop_loss,ssprop_drop_rate")?;
        for i in 0..probe.metrics.losses.len().min(ssprop.metrics.losses.len()) {
            writeln!(
                f,
                "{i},{:.6},{:.6},{:.2}",
                probe.metrics.losses[i], ssprop.metrics.losses[i], ssprop.metrics.drop_rates[i]
            )?;
        }
        println!("\nloss curves -> results/classify_loss.csv");

        // Close the loop as a serving client: checkpoint the ssProp run,
        // fold its BatchNorms where the spec has any (BN-less specs serve
        // the raw checkpoint), and drain a queue of classify requests
        // through the same batched path as `ssprop serve`.
        let ck = Path::new("results/classify_ck.tstore");
        ssprop.save_checkpoint(ck, epochs)?;
        let folded = Path::new("results/classify_ck_folded.tstore");
        let serve_ck = match fold::fold_checkpoint(ck, folded) {
            Ok(s) => {
                println!("folded {} BatchNorm(s) -> {}", s.folded, folded.display());
                folded
            }
            Err(err) if err.downcast_ref::<fold::FoldError>().is_some() => {
                println!("({err}; serving the raw checkpoint)");
                ck
            }
            Err(err) => return Err(err),
        };
        let cfg = ServeConfig { batch: 8, threads: 2 };
        let mut srv = Server::from_checkpoint(serve_ck, None, cfg)?;
        let n_in = srv.input_len();
        let mut rng = Pcg::new(7, 13);
        let reqs: Vec<ClassifyRequest> = (0..32u64)
            .map(|id| ClassifyRequest { id, pixels: (0..n_in).map(|_| rng.normal()).collect() })
            .collect();
        let (answers, stats) = srv.serve(reqs);
        println!(
            "serve: {} answers in {} batches  p50 {}  p99 {}  {:.1} req/s",
            stats.answered,
            stats.batches,
            fmt_ns(stats.p50_ns as f64),
            fmt_ns(stats.p99_ns as f64),
            stats.throughput_rps
        );
        println!("first answer: request {} -> class {}", answers[0].id, answers[0].class);
        println!("(with --features pjrt + artifacts, this example drives the AOT ResNet-18)");
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn run() -> Result<()> {
    pjrt_example::run()
}

#[cfg(not(feature = "pjrt"))]
fn run() -> Result<()> {
    native_example::run()
}

fn main() -> Result<()> {
    run()
}
