//! Procedural image generator.
//!
//! Class signal = a mixture of (a) an oriented sinusoid texture whose
//! frequency/phase/orientation are class-conditional, (b) a class-
//! conditional channel bias, and (c) a class-positioned Gaussian blob.
//! Per-sample nuisance = random translation + pixel noise. The signal/noise
//! ratio is tuned so small CNNs reach high-but-not-perfect accuracy —
//! preserving the generalize/overfit axis the paper's tables measure.
//!
//! Multi-label mode (celeba): each of the 40 attributes toggles its own
//! spatially-localized overlay; labels are the attribute bits.

use super::{DatasetSpec, Label, Loss, Split};
use crate::util::rng::Pcg;

/// Per-class latent template parameters.
#[derive(Debug, Clone)]
struct ClassTemplate {
    freq: f32,
    angle: f32,
    phase: f32,
    chan_bias: Vec<f32>,
    blob_x: f32,
    blob_y: f32,
}

/// Per-attribute overlay (multi-label datasets).
#[derive(Debug, Clone)]
struct AttrOverlay {
    cx: f32,
    cy: f32,
    sigma: f32,
    chan: usize,
    amp: f32,
}

/// A procedural dataset: examples generated deterministically from
/// (seed, split, index) — nothing to download, epochs replay bit-identically.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    /// Geometry/statistics of the dataset being stood in for.
    pub spec: DatasetSpec,
    seed: u64,
    templates: Vec<ClassTemplate>,
    overlays: Vec<AttrOverlay>,
}

// Tuned so small CNNs land mid-range on held-out data (no ceiling): the
// class signal survives averaging but single pixels are noise-dominated.
const NOISE_STD: f32 = 1.9;
const TEX_AMP: f32 = 0.55;
const BLOB_AMP: f32 = 0.9;

impl SynthDataset {
    /// A dataset whose class templates derive from (name, seed) only.
    pub fn new(spec: DatasetSpec, seed: u64) -> SynthDataset {
        // Templates depend only on (dataset name, seed): the same classes
        // look the same across runs and across train/val/test splits.
        let mut rng = Pcg::new(seed ^ hash_name(spec.name), 0xDA7A);
        let templates = (0..spec.classes.max(1))
            .map(|_| ClassTemplate {
                freq: rng.range_f32(0.2, 1.4),
                angle: rng.range_f32(0.0, std::f32::consts::PI),
                phase: rng.range_f32(0.0, std::f32::consts::PI * 2.0),
                chan_bias: (0..spec.channels).map(|_| rng.range_f32(-0.8, 0.8)).collect(),
                blob_x: rng.range_f32(0.2, 0.8),
                blob_y: rng.range_f32(0.2, 0.8),
            })
            .collect();
        let overlays = (0..spec.classes)
            .map(|a| AttrOverlay {
                cx: rng.range_f32(0.1, 0.9),
                cy: rng.range_f32(0.1, 0.9),
                sigma: rng.range_f32(0.05, 0.18),
                chan: a % spec.channels,
                amp: rng.range_f32(0.7, 1.4),
            })
            .collect();
        SynthDataset { spec, seed, templates, overlays }
    }

    /// Number of examples in `split` (testbed-scaled sizes).
    pub fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.spec.train_n,
            Split::Val => self.spec.val_n,
            Split::Test => self.spec.test_n,
        }
    }

    /// Whether `split` holds no examples.
    pub fn is_empty(&self, split: Split) -> bool {
        self.len(split) == 0
    }

    fn sample_rng(&self, split: Split, index: usize) -> Pcg {
        let sid = match split {
            Split::Train => 1u64,
            Split::Val => 2,
            Split::Test => 3,
        };
        Pcg::new(self.seed ^ (index as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15), sid)
    }

    /// Generate example `index` of `split`: CHW image + label.
    pub fn example(&self, split: Split, index: usize) -> (Vec<f32>, Label) {
        match self.spec.loss {
            Loss::Ce => self.example_ce(split, index),
            Loss::Bce => self.example_bce(split, index),
        }
    }

    fn example_ce(&self, split: Split, index: usize) -> (Vec<f32>, Label) {
        let mut rng = self.sample_rng(split, index);
        let cls = (index % self.spec.classes) as u32; // balanced classes
        let t = &self.templates[cls as usize];
        let n = self.spec.img;
        let (dx, dy) = (rng.range_f32(-3.0, 3.0), rng.range_f32(-3.0, 3.0));
        let mut img = vec![0f32; self.spec.channels * n * n];
        let (sa, ca) = t.angle.sin_cos();
        for c in 0..self.spec.channels {
            let bias = t.chan_bias[c];
            for y in 0..n {
                for x in 0..n {
                    let xf = x as f32 + dx;
                    let yf = y as f32 + dy;
                    let u = ca * xf + sa * yf;
                    let tex = (t.freq * u + t.phase).sin();
                    let bx = t.blob_x * n as f32;
                    let by = t.blob_y * n as f32;
                    let d2 = ((xf - bx) * (xf - bx) + (yf - by) * (yf - by))
                        / (0.02 * (n * n) as f32);
                    let blob = (-d2).exp() * BLOB_AMP;
                    img[(c * n + y) * n + x] =
                        TEX_AMP * tex + 0.6 * bias + blob + NOISE_STD * rng.normal();
                }
            }
        }
        (img, Label::Class(cls))
    }

    fn example_bce(&self, split: Split, index: usize) -> (Vec<f32>, Label) {
        let mut rng = self.sample_rng(split, index);
        let n = self.spec.img;
        let mut img = vec![0f32; self.spec.channels * n * n];
        // base "face": centered ellipse
        for c in 0..self.spec.channels {
            for y in 0..n {
                for x in 0..n {
                    let ex = (x as f32 / n as f32 - 0.5) / 0.35;
                    let ey = (y as f32 / n as f32 - 0.5) / 0.45;
                    let inside = if ex * ex + ey * ey < 1.0 { 0.8 } else { -0.3 };
                    img[(c * n + y) * n + x] = inside + NOISE_STD * rng.normal();
                }
            }
        }
        let mut bits = vec![0f32; self.spec.classes];
        for (a, ov) in self.overlays.iter().enumerate() {
            let on = rng.uniform() < 0.5;
            bits[a] = if on { 1.0 } else { 0.0 };
            if !on {
                continue;
            }
            let cx = ov.cx * n as f32;
            let cy = ov.cy * n as f32;
            let s2 = (ov.sigma * n as f32).powi(2);
            for y in 0..n {
                for x in 0..n {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    img[(ov.chan * n + y) * n + x] += ov.amp * (-d2 / s2).exp();
                }
            }
        }
        (img, Label::Multi(bits))
    }

    /// DDPM target distribution: class-structured images without labels,
    /// scaled to roughly [-1, 1] (diffusion convention).
    pub fn ddpm_example(&self, index: usize) -> Vec<f32> {
        let (mut img, _) = self.example(Split::Train, index);
        for v in &mut img {
            *v = (*v * 0.4).clamp(-1.0, 1.0);
        }
        img
    }
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;

    fn ds(name: &str) -> SynthDataset {
        SynthDataset::new(spec(name).unwrap(), 42)
    }

    #[test]
    fn deterministic_per_index() {
        let d = ds("cifar10");
        let (a, la) = d.example(Split::Train, 7);
        let (b, lb) = d.example(Split::Train, 7);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn distinct_across_indices_and_splits() {
        let d = ds("cifar10");
        let (a, _) = d.example(Split::Train, 0);
        let (b, _) = d.example(Split::Train, 10); // same class (10 classes), diff sample
        assert_ne!(a, b);
        let (c, _) = d.example(Split::Test, 0);
        assert_ne!(a, c);
    }

    #[test]
    fn labels_balanced_and_in_range() {
        let d = ds("cifar100");
        let mut counts = vec![0usize; 100];
        for i in 0..400 {
            match d.example(Split::Train, i).1 {
                Label::Class(c) => counts[c as usize] += 1,
                _ => panic!("expected class label"),
            }
        }
        assert!(counts.iter().all(|&c| c == 4), "balanced classes");
    }

    #[test]
    fn image_shape_and_finite() {
        for name in ["mnist", "cifar10", "celeba", "imagenet64"] {
            let d = ds(name);
            let (img, _) = d.example(Split::Val, 3);
            assert_eq!(img.len(), d.spec.channels * d.spec.img * d.spec.img);
            assert!(img.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn class_signal_dominates_between_class_distance() {
        // same-class examples are closer than different-class ones on average
        let d = ds("cifar10");
        let (a0, _) = d.example(Split::Train, 0);  // class 0
        let (a1, _) = d.example(Split::Train, 10); // class 0
        let (b0, _) = d.example(Split::Train, 1);  // class 1
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt()
        };
        assert!(dist(&a0, &a1) < dist(&a0, &b0));
    }

    #[test]
    fn bce_labels_are_bits_with_both_values() {
        let d = ds("celeba");
        let mut ones = 0usize;
        let mut total = 0usize;
        for i in 0..20 {
            if let (_, Label::Multi(bits)) = d.example(Split::Train, i) {
                assert_eq!(bits.len(), 40);
                ones += bits.iter().filter(|&&b| b == 1.0).count();
                total += bits.len();
                assert!(bits.iter().all(|&b| b == 0.0 || b == 1.0));
            }
        }
        let frac = ones as f64 / total as f64;
        assert!((0.3..0.7).contains(&frac), "attr balance {frac}");
    }

    #[test]
    fn ddpm_examples_bounded() {
        let d = ds("mnist");
        let img = d.ddpm_example(5);
        assert!(img.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }
}
