//! Exhaustive `--model` spec failure-path suite: every [`ModelSpecError`]
//! variant is produced by the parser with the offending spec/token in the
//! user-facing message, the error stays *typed* through the trainer
//! constructor, and a checkpoint restored into a different architecture
//! is rejected with an error naming **both** specs.

use ssprop::backend::{parse_model_spec, ModelSpecError};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};

fn err(spec: &str) -> ModelSpecError {
    parse_model_spec(spec).expect_err(&format!("{spec:?} must not parse"))
}

#[test]
fn unknown_presets_are_typed_and_list_the_known_ones() {
    for spec in ["resnet18", "resnet-tinyx", "simple-cnnx", "", "w8", "-w8", "simple_cnn"] {
        let e = err(spec);
        assert!(matches!(e, ModelSpecError::UnknownPreset { .. }), "{spec:?} -> {e:?}");
        let shown = e.to_string();
        assert!(shown.contains(&format!("{spec:?}")), "{spec:?} missing from {shown:?}");
        for preset in ["simple-cnn", "vgg-tiny", "dropout-cnn", "resnet-tiny"] {
            assert!(shown.contains(preset), "{shown:?} must list {preset}");
        }
    }
}

#[test]
fn bad_param_tokens_are_typed_and_name_the_token() {
    let cases = [
        ("simple-cnn-q4", "q4"),       // unknown key
        ("vgg-tiny-w", "w"),           // missing digits
        ("vgg-tiny-d4", "d4"),         // key not valid for the preset
        ("vgg-tiny-b2", "b2"),         // blocks belong to resnet-tiny only
        ("resnet-tiny-p25", "p25"),    // dropout rate belongs to dropout-cnn
        ("resnet-tiny-d3", "d3"),      // depth belongs to simple-cnn
        ("simple-cnn-p25", "p25"),
        ("simple-cnn-w4-w8", "w8"),    // repeated key
        ("resnet-tiny-b1-b2", "b2"),
        ("resnet-tiny-w8-", ""),       // empty trailing token
        ("dropout-cnn-pxx", "pxx"),    // non-numeric digits
    ];
    for (spec, token) in cases {
        let e = err(spec);
        let ModelSpecError::BadParam { spec: s, token: t } = &e else {
            panic!("{spec:?} -> {e:?}, want BadParam");
        };
        assert_eq!(s, spec);
        assert_eq!(t, token, "{spec:?}");
        let shown = e.to_string();
        assert!(shown.contains(&format!("{token:?}")), "{shown:?}");
        assert!(shown.contains(&format!("{spec:?}")), "{shown:?}");
    }
}

#[test]
fn out_of_range_values_are_typed_and_name_the_token() {
    let cases = [
        ("simple-cnn-d0", "d0"),
        ("simple-cnn-w0", "w0"),
        ("vgg-tiny-w0", "w0"),
        ("dropout-cnn-p0", "p0"),
        ("dropout-cnn-p100", "p100"),
        ("dropout-cnn-p250", "p250"),
        ("resnet-tiny-w0", "w0"),
        ("resnet-tiny-b0", "b0"),
    ];
    for (spec, token) in cases {
        let e = err(spec);
        let ModelSpecError::OutOfRange { spec: s, token: t } = &e else {
            panic!("{spec:?} -> {e:?}, want OutOfRange");
        };
        assert_eq!(s, spec);
        assert_eq!(t, token, "{spec:?}");
        let shown = e.to_string();
        assert!(shown.contains("out of range"), "{shown:?}");
        assert!(shown.contains(&format!("{token:?}")), "{shown:?}");
    }
}

#[test]
fn trainer_surfaces_the_typed_error() {
    let mut cfg = NativeTrainConfig::quick("mnist", 1, 1);
    cfg.model = "resnet-tiny-b0".to_string();
    let e = NativeTrainer::new(cfg).expect_err("must reject");
    let typed = e.downcast_ref::<ModelSpecError>().expect("typed through the trainer");
    assert!(matches!(typed, ModelSpecError::OutOfRange { .. }), "{typed:?}");
}

#[test]
fn checkpoint_spec_mismatch_names_both_specs() {
    let dir = std::env::temp_dir().join("ssprop_spec_mismatch");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet_tiny.tstore");

    let mut cfg = NativeTrainConfig::quick("mnist", 1, 2);
    cfg.batch = 8;
    cfg.model = "resnet-tiny-w4".to_string();
    let mut a = NativeTrainer::new(cfg).unwrap();
    a.run().unwrap();
    a.save_checkpoint(&path, 1).unwrap();

    // same architecture restores fine (BN running stats included)
    let mut same_cfg = NativeTrainConfig::quick("mnist", 1, 2);
    same_cfg.batch = 8;
    same_cfg.model = "resnet-tiny-w4".to_string();
    let mut same = NativeTrainer::new(same_cfg).unwrap();
    assert_eq!(same.load_checkpoint(&path).unwrap(), 1);
    assert_eq!(a.model.flat_params(), same.model.flat_params());

    // a different spec is rejected, naming the saved AND the running spec
    let mut other_cfg = NativeTrainConfig::quick("mnist", 1, 2);
    other_cfg.batch = 8;
    other_cfg.model = "vgg-tiny-w4".to_string();
    let mut other = NativeTrainer::new(other_cfg).unwrap();
    let msg = other.load_checkpoint(&path).expect_err("must reject").to_string();
    assert!(msg.contains("resnet-tiny-w4-b1"), "saved spec missing: {msg}");
    assert!(msg.contains("vgg-tiny-w4"), "running spec missing: {msg}");
}
