"""Static-analysis helpers (compile.analyze)."""

from compile.analyze import gemm_tile_analysis, hlo_op_histogram, ssprop_backward_gemms


SAMPLE_HLO = """\
HloModule test

ENTRY %main (p0: f32[2,2]) -> f32[2,2] {
  %p0 = f32[2,2]{1,0} parameter(0)
  %dot.1 = f32[2,2]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %sort.2 = f32[2,2]{1,0} sort(%dot.1), dimensions={1}, to_apply=%cmp
  ROOT %add.3 = f32[2,2]{1,0} add(%dot.1, %sort.2)
}
"""


def test_histogram_counts_ops():
    h = hlo_op_histogram(SAMPLE_HLO)
    assert h["dot"] == 1
    assert h["sort"] == 1
    assert h["add"] == 1
    assert h["parameter"] == 1


def test_tile_analysis_bounds():
    g = gemm_tile_analysis(100, 100, 100)
    assert 0 < g["mxu_util"] <= 1.0
    assert g["vmem_bytes"] > 0
    # exact-multiple shapes waste nothing
    g2 = gemm_tile_analysis(256, 256, 256)
    assert g2["mxu_util"] == 1.0


def test_compaction_shrinks_gemm_but_costs_utilization():
    dense = ssprop_backward_gemms(128, 64, 64, 3, 32, 32, 0.0)
    sparse = ssprop_backward_gemms(128, 64, 64, 3, 32, 32, 0.8)
    # dW' output width shrinks 64 -> 13
    assert dense[0]["gemm"][1] == 64
    assert sparse[0]["gemm"][1] == 13
    # real work drops even though tile padding reduces utilization
    def work(g):
        m, n, k = g["gemm"]
        return m * n * k
    assert work(sparse[0]) < 0.25 * work(dense[0])


def test_vmem_within_budget_for_default_blocks():
    g = gemm_tile_analysis(4096, 4096, 4096)
    assert g["vmem_bytes"] <= 4 * 1024 * 1024  # fits VMEM with margin
