"""Adam / AdamW with state threaded through the AOT step (paper Tables 2/3).

Optimizer state is a pytree ``{"m": like(params), "v": like(params),
"t": i32 scalar}`` that the rust coordinator feeds back each iteration.
``lr`` is a runtime scalar input so one executable serves every learning
rate (Fig. 4's sweep) and any LR schedule the coordinator wants.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

B1, B2, EPS = 0.9, 0.999, 1e-8  # paper: Adam betas (0.9, 0.999)
ADAMW_WD = 0.01                  # paper: AdamW "default parameters" (torch)


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, *, weight_decay: float = 0.0):
    """One Adam(W) step. weight_decay > 0 gives decoupled AdamW."""
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - B1 ** tf
    bc2 = 1.0 - B2 ** tf

    def upd(p, g, m, v):
        m2 = B1 * m + (1.0 - B1) * g
        v2 = B2 * v + (1.0 - B2) * (g * g)
        step = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + EPS)
        p2 = p - step
        if weight_decay:
            p2 = p2 - lr * weight_decay * p
        return p2, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "t": t}
