//! Data-parallel execution layer: shard each batch over a fixed worker
//! count, run any [`Graph`] — residual connections and BatchNorm included
//! — per shard, reduce gradients deterministically.
//!
//! Design (see `docs/ARCHITECTURE.md` for the full write-up):
//!
//! * **Sharding.** The batch splits into contiguous sub-batches via
//!   [`shard_ranges`] (non-divisible sizes allowed — leading shards take
//!   the remainder). Each worker owns one [`LayerWs`] per graph node,
//!   keyed to its shard size, so the hot path takes **no locks**: conv
//!   im2col columns are cached per worker and consumed by that worker's
//!   backward, exactly like the serial path; dropout masks are keyed on
//!   the *global* example index, so shard boundaries never change them.
//! * **Global selection.** ssProp's channel top-k is defined over the
//!   *whole* batch, so per conv node the workers publish unnormalized
//!   importance partials ([`channel_abs_sums`]), synchronize on a barrier,
//!   worker 0 reduces them in fixed shard order and broadcasts the keep
//!   set, and every shard runs the identical compacted backward
//!   ([`Selection::Keep`]). Dense layers (keep == Cout) and non-conv
//!   nodes skip the rendezvous entirely. This keeps parallel selection
//!   *semantically identical* to serial selection.
//! * **Global batch statistics.** BatchNorm normalizes over the whole
//!   batch, so batch-normalizing nodes rendezvous twice more: once in the
//!   forward (per-channel `[Σx ‖ Σx²]` partials reduced in fixed shard
//!   order, every shard normalizing with the identical global moments)
//!   and once in the backward (`[Σg ‖ Σ(g·x̂)]` partials — the exact
//!   through-the-statistics gradient needs the global sums). One shard
//!   reproduces the serial arithmetic bitwise; the reduced statistics are
//!   folded into the layer's running state once per step, after the
//!   join, from worker 0's workspace.
//! * **Deterministic reduction.** Every parameter gradient reduces through
//!   a fixed-shape pairwise tree (`tree_reduce`) in shard-index order —
//!   never in thread-completion order — so repeated runs at the same
//!   thread count are bit-identical, and a single-worker run reproduces
//!   [`Graph::train_step`] exactly. Against other thread counts only
//!   float re-association differs (≪ 1e-5 on the loss trajectory; pinned
//!   by `rust/tests/determinism.rs`).
//! * **Sharded evaluation.** [`ParallelExecutor::eval_batch`] forwards the
//!   shards in eval mode (BatchNorm normalizes per example with running
//!   statistics — no rendezvous) and hands back *per-example* losses; the
//!   reducer sums them in global example order, which makes sharded
//!   evaluation **bit-identical** to serial evaluation at every thread
//!   count.
//!
//! Two executors drive the identical shard protocol (the per-shard worker
//! body, the reductions, and the epilogue live in shared `pub(crate)`
//! functions below, so the two cannot diverge numerically):
//!
//! * [`ParallelExecutor`] spawns a scoped thread crew per step
//!   (`std::thread::scope`) — zero `unsafe`, but each step pays thread
//!   spawn/join. It remains the reference executor the benchmark's
//!   `pool_speedup` lines compare against.
//! * [`crate::backend::pool::WorkerPool`] keeps the crew alive for the
//!   executor's lifetime and feeds it jobs over channels — the production
//!   path for [`crate::coordinator::NativeTrainer`] and
//!   [`crate::coordinator::serve::Server`]. A panicking worker aborts the
//!   step *loudly* either way: every worker owes a fixed number of
//!   rendezvous per step, and the `BarrierAttendance` guard pays any
//!   outstanding ones during unwinding, so the surviving workers are never
//!   left blocked on a barrier that cannot complete and the panic
//!   propagates to the caller instead of deadlocking training.

use std::sync::{Barrier, Mutex};

use anyhow::{bail, Result};

use super::layers::graph::{accumulate, add_forward, NodeOp};
use super::layers::{softmax_ce_core, softmax_ce_examples, FwdCtx, LayerWs, Selection, INPUT_SLOT};
use super::sparse::{channel_abs_sums, topk_channels};
use super::{Backend, Graph, StepStats};
use crate::flops::keep_channels;
use crate::util::shard::shard_ranges;

/// Upper clamp on auto-detected worker counts ([`ExecConfig::auto`]):
/// beyond this, per-conv barrier rendezvous overhead dominates step time
/// at zoo-preset scale. An *explicit* `threads: N` is never clamped.
pub const MAX_AUTO_THREADS: usize = 16;

/// Execution-layer knobs for [`ParallelExecutor`] and
/// [`crate::backend::pool::WorkerPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecConfig {
    /// Worker threads a batch is sharded over. `0` means **auto**: resolve
    /// [`std::thread::available_parallelism`] at executor construction,
    /// clamped to `[1, MAX_AUTO_THREADS]` (see [`ExecConfig::resolved_threads`]).
    pub threads: usize,
    /// Pin pool worker `w` to CPU core `w` (Linux `sched_setaffinity`;
    /// off by default). Purely a placement hint — results are
    /// bit-identical with or without pinning, since shard arithmetic
    /// never depends on where it runs. A no-op (with a warning at pool
    /// construction) on platforms without the raw syscall path.
    pub affinity: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { threads: 1, affinity: false }
    }
}

impl ExecConfig {
    /// Config with `threads` workers (`0` = auto-detect, see
    /// [`ExecConfig::auto`]).
    pub fn with_threads(threads: usize) -> ExecConfig {
        ExecConfig { threads, affinity: false }
    }

    /// Auto-detecting config: worker count resolves to the machine's
    /// [`std::thread::available_parallelism`] at executor construction.
    pub fn auto() -> ExecConfig {
        ExecConfig { threads: 0, affinity: false }
    }

    /// Builder toggle for [`ExecConfig::affinity`].
    pub fn with_affinity(mut self, affinity: bool) -> ExecConfig {
        self.affinity = affinity;
        self
    }

    /// The concrete worker count this config resolves to: `threads` as
    /// given when positive, otherwise [`std::thread::available_parallelism`]
    /// clamped to `[1, MAX_AUTO_THREADS]` (the documented auto clamp —
    /// detection failure falls back to 1, oversubscribed machines cap at
    /// [`MAX_AUTO_THREADS`] where rendezvous overhead outgrows the shards).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map_or(1, |n| n.get())
                .clamp(1, MAX_AUTO_THREADS)
        }
    }
}

/// Whether [`pin_current_thread`] can actually pin on this target: the
/// raw-`syscall` `sched_setaffinity` path below is Linux/x86-64 only (no
/// libc in the offline dependency set to go through).
pub(crate) fn affinity_supported() -> bool {
    cfg!(all(target_os = "linux", target_arch = "x86_64"))
}

/// Pin the calling thread to CPU core `core`. Purely a cache/NUMA
/// placement hint behind [`ExecConfig::affinity`]: output bits never
/// depend on where a shard runs. Returns whether the kernel accepted
/// the mask (a core index beyond the machine is simply refused).
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub(crate) fn pin_current_thread(core: usize) -> bool {
    // sched_setaffinity(0 /* this thread */, len, mask) via the raw
    // syscall; the 1024-bit mask mirrors glibc's cpu_set_t.
    let mut mask = [0u64; 16];
    mask[(core / 64) % mask.len()] |= 1u64 << (core % 64);
    let ret: i64;
    // SAFETY: syscall 203 only reads `len` bytes of `mask`, which
    // outlives the call; rcx/r11 are the instruction's only clobbers.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret,
            in("rdi") 0i64,
            in("rsi") std::mem::size_of_val(&mask) as i64,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }
    ret == 0
}

/// Unsupported-platform fallback: never pins (the pool already warned).
#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub(crate) fn pin_current_thread(_core: usize) -> bool {
    false
}

/// Everything one shard worker hands back to the reducer after a train
/// step.
#[derive(Debug, Default)]
pub(crate) struct ShardOut {
    /// Σ per-example losses over the shard (full-batch mean = Σ/Bt).
    pub(crate) loss_sum: f64,
    /// Correct predictions in the shard.
    pub(crate) correct: usize,
    /// Per node: the parameter gradients ([`super::layers::BwdOut`]
    /// order), already in full-batch (1/Bt) units.
    pub(crate) grads: Vec<Vec<Vec<f32>>>,
    /// Kept channels summed over conv nodes (filled by worker 0 only).
    pub(crate) kept: usize,
}

/// Unwind insurance for the barrier protocol. Every worker owes the same
/// fixed number of rendezvous per step (two per sparse conv node, four
/// per batch-normalizing node); a worker that panics mid-step would
/// otherwise leave its peers blocked forever on a `std::sync::Barrier`
/// that cannot complete (std barriers have no poisoning). The guard
/// tracks the waits still owed and pays them during unwinding, so peers
/// proceed — at worst briefly computing on a stale or empty broadcast,
/// whose validity asserts make *them* panic and drain the same way — and
/// the original panic then propagates to the caller (out of
/// `std::thread::scope`, or through the pool's reply channel), aborting
/// the step instead of deadlocking it.
struct BarrierAttendance<'a> {
    barrier: &'a Barrier,
    remaining: std::cell::Cell<usize>,
}

impl<'a> BarrierAttendance<'a> {
    fn new(barrier: &'a Barrier, total: usize) -> BarrierAttendance<'a> {
        BarrierAttendance { barrier, remaining: std::cell::Cell::new(total) }
    }

    /// Attend one rendezvous and mark it paid.
    fn wait(&self) {
        self.barrier.wait();
        self.remaining.set(self.remaining.get() - 1);
    }
}

impl Drop for BarrierAttendance<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining.get() {
            self.barrier.wait();
        }
    }
}

/// Deterministic pairwise tree reduction: parts are summed elementwise in
/// a fixed index-ordered binary tree — (0+1)+(2+3)… — so the result
/// depends only on the part order, never on thread timing. A single part
/// passes through bitwise untouched.
fn tree_reduce(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (av, bv) in a.iter_mut().zip(&b) {
                    *av += bv;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap_or_default()
}

/// Reduce per-worker importance partials in fixed shard order, normalize
/// by the *global* batch volume, and select the top-k channels — the
/// cross-shard equivalent of [`super::sparse::select_channels`] (bitwise
/// so for a single shard).
fn reduce_select(
    imp_slots: &[Mutex<Vec<f32>>],
    bt: usize,
    hw: usize,
    cout: usize,
    keep: usize,
) -> Vec<usize> {
    let mut imp = vec![0f32; cout];
    for slot in imp_slots {
        let part = slot.lock().expect("importance slot poisoned");
        for (tot, &v) in imp.iter_mut().zip(part.iter()) {
            *tot += v;
        }
    }
    let denom = (bt * hw) as f32;
    for v in &mut imp {
        *v /= denom;
    }
    topk_channels(&imp, keep)
}

/// Sum per-worker statistics partials in fixed shard order (BatchNorm
/// moments and gradient sums). The first part seeds the accumulator
/// bitwise, so a single shard's reduction is the identity — which keeps
/// one executor worker bit-equal to the serial path.
fn reduce_stat_partials(slots: &[Mutex<Vec<f32>>]) -> Vec<f32> {
    let mut tot: Vec<f32> = Vec::new();
    for slot in slots {
        let part = slot.lock().expect("stat slot poisoned");
        if tot.is_empty() {
            tot = part.clone();
        } else {
            for (t, &v) in tot.iter_mut().zip(part.iter()) {
                *t += v;
            }
        }
    }
    tot
}

/// Everything a train-step shard worker reads besides its own shard range
/// and workspaces: the (shared, read-only) model and batch, the step
/// scalars, and the per-step rendezvous state. Both executors build one
/// per step and hand every worker a reference — the worker body
/// ([`run_train_shard`]) is identical either way, which is what makes the
/// pool bit-identical to the scoped crew by construction.
pub(crate) struct TrainShardCtx<'a> {
    /// The model being trained (read-only during the shard phase).
    pub(crate) model: &'a Graph,
    /// Conv/GEMM executor.
    pub(crate) backend: &'a dyn Backend,
    /// Full-batch inputs (`bt × n_in`).
    pub(crate) x: &'a [f32],
    /// Full-batch labels.
    pub(crate) y: &'a [i32],
    /// Input volume per example.
    pub(crate) n_in: usize,
    /// Global batch size (the gradient denominator on every shard).
    pub(crate) bt: usize,
    /// Classifier output count.
    pub(crate) classes: usize,
    /// This step's scheduled ssProp drop rate.
    pub(crate) drop_rate: f64,
    /// Monotone step counter (dropout mask stream key).
    pub(crate) step: u64,
    /// The step's rendezvous barrier (one attendee per shard).
    pub(crate) barrier: &'a Barrier,
    /// Per-worker partial-publication slots (importance / BN statistics).
    pub(crate) imp_slots: &'a [Mutex<Vec<f32>>],
    /// Worker 0's keep-set broadcast slot.
    pub(crate) keep_slot: &'a Mutex<Vec<usize>>,
    /// Worker 0's reduced-statistics broadcast slot.
    pub(crate) stat_slot: &'a Mutex<Vec<f32>>,
}

/// The shard worker body of one training step: forward with global BN
/// statistics, loss in full-batch units, backward with globally-reduced
/// channel selection, gradients left in `out` for the fixed-order
/// reduction. Runs on a scoped thread ([`ParallelExecutor`]) or a pool
/// worker ([`crate::backend::pool::WorkerPool`]) — same bits either way.
pub(crate) fn run_train_shard(
    ctx: &TrainShardCtx<'_>,
    w: usize,
    range: std::ops::Range<usize>,
    wws: &mut [LayerWs],
    out: &mut ShardOut,
) {
    let m = ctx.model;
    let nn = m.num_layers();
    let sbt = range.end - range.start;
    let xs = &ctx.x[range.start * ctx.n_in..range.end * ctx.n_in];
    let ys = &ctx.y[range.start..range.end];

    // Fixed rendezvous budget — two per sparse conv node (selection),
    // four per batch-normalizing node (two in the forward, two in the
    // backward); the guard pays any outstanding waits if we unwind, so a
    // panic here can never strand the other workers.
    let sparse_convs = (0..nn)
        .filter(|&i| {
            m.node_layer(i)
                .and_then(|l| l.conv_geom())
                .is_some_and(|g| keep_channels(g.cout, ctx.drop_rate) < g.cout)
        })
        .count();
    let bn_nodes =
        (0..nn).filter(|&i| m.node_layer(i).is_some_and(|l| l.needs_batch_stats())).count();
    let attendance = BarrierAttendance::new(ctx.barrier, 2 * sparse_convs + 4 * bn_nodes);

    // Publish this worker's partials, rendezvous, let worker 0 reduce
    // them in fixed shard order, rendezvous again, and read the
    // broadcast back.
    let reduce_stats = |part: Vec<f32>| -> Vec<f32> {
        *ctx.imp_slots[w].lock().expect("stat slot poisoned") = part;
        attendance.wait();
        if w == 0 {
            *ctx.stat_slot.lock().expect("stat broadcast poisoned") =
                reduce_stat_partials(ctx.imp_slots);
        }
        attendance.wait();
        ctx.stat_slot.lock().expect("stat broadcast poisoned").clone()
    };

    // Shard-local forward over the graph slots, in full-batch gradient
    // units (grad_denom = bt). Dropout masks key on the global example
    // offset, so they match serial exactly; batch-normalizing nodes
    // reduce their moments globally before normalizing.
    let fwd_ctx = FwdCtx { train: true, step: ctx.step, example_offset: range.start };
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nn + 1);
    acts.push(xs.to_vec());
    for i in 0..nn {
        let next = match &m.node(i).op {
            NodeOp::Add { a, b } => add_forward(&acts[*a], &acts[*b]),
            NodeOp::Layer { layer, input } => {
                if layer.needs_batch_stats() {
                    let global = reduce_stats(layer.fwd_stat_partials(&acts[*input], sbt));
                    layer.forward_with_stats(
                        ctx.backend,
                        &acts[*input],
                        sbt,
                        &mut wws[i],
                        &fwd_ctx,
                        &global,
                        ctx.bt,
                    )
                } else {
                    layer.forward(ctx.backend, &acts[*input], sbt, &mut wws[i], &fwd_ctx)
                }
            }
        };
        acts.push(next);
    }
    let (loss_sum, correct, dlogits) = softmax_ce_core(&acts[nn], ys, ctx.classes, ctx.bt);
    out.loss_sum = loss_sum;
    out.correct = correct;
    out.grads = (0..nn).map(|_| Vec::new()).collect();

    // Backward in reverse topological order over per-slot gradient
    // accumulators (an Add merge fans the gradient to both operands).
    // Conv selection is global: publish importance partials, rendezvous,
    // worker 0 reduces + broadcasts; dense conv nodes skip the sync and
    // keep everything. Batch-normalizing nodes reduce their gradient
    // sums the same way; every other node runs locally.
    let mut slot_grads: Vec<Option<Vec<f32>>> = (0..nn + 1).map(|_| None).collect();
    slot_grads[nn] = Some(dlogits);
    for i in (0..nn).rev() {
        let g = slot_grads[i + 1].take().expect("every node output feeds a later node");
        let (layer, input) = match &m.node(i).op {
            NodeOp::Add { a, b } => {
                accumulate(&mut slot_grads[*a], g.clone());
                accumulate(&mut slot_grads[*b], g);
                continue;
            }
            NodeOp::Layer { layer, input } => (layer, *input),
        };
        let need_dx = input != INPUT_SLOT;
        let bwd = if layer.needs_batch_stats() {
            let local = layer.bwd_stat_partials(&g, sbt, &wws[i]);
            let global = reduce_stats(local.clone());
            layer.backward_with_stats(
                ctx.backend,
                &acts[input],
                &g,
                sbt,
                &mut wws[i],
                &global,
                &local,
                need_dx,
            )
        } else {
            let keep: Option<Vec<usize>> = layer.conv_geom().map(|geom| {
                let keep_count = keep_channels(geom.cout, ctx.drop_rate);
                if keep_count == geom.cout {
                    return (0..geom.cout).collect();
                }
                let cfg = geom.with_batch(sbt);
                *ctx.imp_slots[w].lock().expect("importance slot poisoned") =
                    channel_abs_sums(&cfg, &g);
                attendance.wait();
                if w == 0 {
                    let hw = geom.hout() * geom.wout();
                    let sel = reduce_select(ctx.imp_slots, ctx.bt, hw, geom.cout, keep_count);
                    *ctx.keep_slot.lock().expect("keep slot poisoned") = sel;
                }
                attendance.wait();
                ctx.keep_slot.lock().expect("keep slot poisoned").clone()
            });
            let sel = match &keep {
                Some(k) => Selection::Keep(k),
                None => Selection::Local(ctx.drop_rate),
            };
            let ws_i = &mut wws[i];
            layer.backward(ctx.backend, &acts[input], &g, sbt, ws_i, sel, need_dx)
        };
        if w == 0 {
            out.kept += bwd.kept;
        }
        out.grads[i] = bwd.grads;
        if need_dx {
            accumulate(&mut slot_grads[input], bwd.dx);
        }
    }
}

/// The shard worker body of one sharded evaluation: forward the shard in
/// eval mode and hand back its per-example losses plus correct count.
pub(crate) fn run_eval_shard(
    model: &Graph,
    backend: &dyn Backend,
    x: &[f32],
    y: &[i32],
    range: std::ops::Range<usize>,
    wws: &mut [LayerWs],
) -> (Vec<f64>, usize) {
    let n_in = model.in_shape().volume();
    let sbt = range.end - range.start;
    let xs = &x[range.start * n_in..range.end * n_in];
    let ys = &y[range.start..range.end];
    let ctx = FwdCtx { train: false, step: 0, example_offset: range.start };
    let acts = model.forward_collect(backend, xs, sbt, wws, &ctx);
    softmax_ce_examples(&acts[model.num_layers()], ys, model.out_features())
}

/// The shard worker body of one sharded inference call: forward the shard
/// in eval mode and hand back its logit rows.
pub(crate) fn run_logits_shard(
    model: &Graph,
    backend: &dyn Backend,
    x: &[f32],
    range: std::ops::Range<usize>,
    wws: &mut [LayerWs],
) -> Vec<f32> {
    let n_in = model.in_shape().volume();
    let sbt = range.end - range.start;
    let xs = &x[range.start * n_in..range.end * n_in];
    let ctx = FwdCtx { train: false, step: 0, example_offset: range.start };
    let mut acts = model.forward_collect(backend, xs, sbt, wws, &ctx);
    acts.swap_remove(model.num_layers())
}

/// Key the per-worker workspaces to the given shard sizes. Conv plans
/// re-key in place, and the worker axis never shrinks — a small step
/// (e.g. the epoch-tail batch over fewer shards) parks the extra workers'
/// workspaces instead of dropping their grown buffers, so steady-state
/// steps allocate nothing here even when the shard count varies.
pub(crate) fn ensure_worker_ws(
    worker_ws: &mut Vec<Vec<LayerWs>>,
    model: &Graph,
    shards: &[std::ops::Range<usize>],
) {
    let nn = model.num_layers();
    if worker_ws.len() < shards.len() {
        worker_ws.resize_with(shards.len(), Vec::new);
    }
    for (wws, r) in worker_ws.iter_mut().zip(shards) {
        let sbt = r.end - r.start;
        wws.resize_with(nn, LayerWs::default);
        for (i, ws) in wws.iter_mut().enumerate() {
            model.node_ensure_ws(i, ws, sbt);
        }
    }
}

/// The train-step epilogue both executors share: reduce the shard scalars
/// in fixed shard order, bail on a non-finite loss, tree-reduce every
/// parameter gradient in shard-index order and apply SGD, then fold the
/// globally-reduced batch statistics into persistent layer state from
/// worker 0's workspace (every worker holds the identical reduced
/// statistics, so worker 0's copy is canonical).
pub(crate) fn apply_shard_outs(
    model: &mut Graph,
    worker_ws: &[Vec<LayerWs>],
    outs: Vec<ShardOut>,
    bt: usize,
    drop_rate: f64,
    lr: f32,
) -> Result<StepStats> {
    let nn = model.num_layers();
    let nw = outs.len();

    // Scalar reductions in fixed shard order.
    let (mut loss_sum, mut correct) = (0f64, 0usize);
    for o in &outs {
        loss_sum += o.loss_sum;
        correct += o.correct;
    }
    let loss = loss_sum / bt as f64;
    if !loss.is_finite() {
        bail!("non-finite loss at drop rate {drop_rate}");
    }
    let kept = outs[0].kept;

    // Gradient tree-reduction (fixed shard order) + SGD updates: for
    // each node, each parameter's shard parts reduce through the same
    // pairwise tree the legacy executor used, then apply.
    let mut parts: Vec<Vec<Vec<Vec<f32>>>> = (0..nn).map(|_| Vec::new()).collect();
    for o in outs {
        for (l, grads) in o.grads.into_iter().enumerate() {
            for (p, gvec) in grads.into_iter().enumerate() {
                if parts[l].len() <= p {
                    parts[l].push(Vec::with_capacity(nw));
                }
                parts[l][p].push(gvec);
            }
        }
    }
    for (l, pgrads) in parts.into_iter().enumerate() {
        if pgrads.is_empty() {
            continue;
        }
        let reduced: Vec<Vec<f32>> = pgrads.into_iter().map(tree_reduce).collect();
        for (param, grad) in model.node_params_mut(l).into_iter().zip(&reduced) {
            for (pv, &gv) in param.iter_mut().zip(grad) {
                *pv -= lr * gv;
            }
        }
    }

    // Fold the global batch statistics into persistent layer state (BN
    // running stats) exactly once per step.
    for i in 0..nn {
        if let Some(ws0) = worker_ws.first().and_then(|wws| wws.get(i)) {
            model.node_commit_stats(i, ws0);
        }
    }

    Ok(StepStats {
        loss,
        acc: correct as f64 / bt as f64,
        kept_channels: kept,
        total_channels: model.total_channels(),
    })
}

/// Data-parallel trainer over any [`Graph`]: owns the per-worker node
/// workspaces and runs [`ParallelExecutor::train_step`] /
/// [`ParallelExecutor::eval_batch`] as described in the module docs,
/// spawning a scoped thread crew per step. Construct once and reuse —
/// worker workspaces keep their buffer capacity across steps (and re-key
/// in place when the batch size or shard sizes change, mirroring
/// [`Graph::ensure_ws`]). For long-lived training/serving loops prefer
/// [`crate::backend::pool::WorkerPool`], which amortizes the per-step
/// thread spawn over a persistent crew with the same bits.
#[derive(Debug)]
pub struct ParallelExecutor {
    cfg: ExecConfig,
    /// `worker_ws[w][i]`: worker w's workspace for graph node i.
    worker_ws: Vec<Vec<LayerWs>>,
}

impl ParallelExecutor {
    /// An executor with no allocated workspaces yet (they grow on first
    /// step and are reused afterwards). An auto config (`threads: 0`)
    /// resolves to the machine's parallelism here, once.
    pub fn new(cfg: ExecConfig) -> ParallelExecutor {
        // Settle the process-wide GEMM kernel before any worker thread
        // exists, so every shard dispatches the same microkernel.
        let _ = super::gemm::Kernel::active();
        let cfg = ExecConfig { threads: cfg.resolved_threads(), affinity: cfg.affinity };
        ParallelExecutor { cfg, worker_ws: Vec::new() }
    }

    /// Resolved worker count (shards per step; capped by the batch size
    /// at step time).
    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    /// Total im2col materializations across all worker workspaces —
    /// advances by `conv_count × workers` per train step when the fused
    /// path is healthy (each worker builds each conv node's columns once,
    /// in its forward).
    pub fn plan_cols_builds(&self) -> u64 {
        self.worker_ws.iter().flatten().map(|w| w.plan_cols_builds()).sum()
    }

    /// One data-parallel SGD training step at `drop_rate`; the parallel
    /// counterpart of [`Graph::train_step`] with identical semantics:
    /// same loss/accuracy, same global channel selection, same dropout
    /// masks, same global BatchNorm statistics, gradients equal up to
    /// float re-association (bit-identical with one worker, and
    /// bit-identical run-to-run at any fixed worker count).
    pub fn train_step(
        &mut self,
        model: &mut Graph,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        let n_in = model.in_shape().volume();
        if bt == 0 || x.len() != bt * n_in {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        let classes = model.out_features();
        let shards = shard_ranges(bt, self.cfg.threads);
        let nw = shards.len();
        // Only the per-worker workspaces are touched here — the model's
        // own (serial-path) workspaces stay untouched and unallocated.
        ensure_worker_ws(&mut self.worker_ws, model, &shards);
        let step = model.begin_step();

        let mut outs: Vec<ShardOut> = (0..nw).map(|_| ShardOut::default()).collect();
        let barrier = Barrier::new(nw);
        let imp_slots: Vec<Mutex<Vec<f32>>> = (0..nw).map(|_| Mutex::new(Vec::new())).collect();
        let keep_slot: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let stat_slot: Mutex<Vec<f32>> = Mutex::new(Vec::new());
        let ctx = TrainShardCtx {
            model,
            backend,
            x,
            y,
            n_in,
            bt,
            classes,
            drop_rate,
            step,
            barrier: &barrier,
            imp_slots: &imp_slots,
            keep_slot: &keep_slot,
            stat_slot: &stat_slot,
        };

        std::thread::scope(|s| {
            let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
            for (w, ((range, wws), out)) in worker_iter.enumerate() {
                let ctx = &ctx;
                let range = range.clone();
                s.spawn(move || run_train_shard(ctx, w, range, wws, out));
            }
        });

        apply_shard_outs(model, &self.worker_ws, outs, bt, drop_rate, lr)
    }

    /// Sharded forward-only evaluation: mean (loss, accuracy) over the
    /// batch, **bit-identical** to [`Graph::eval_batch`] at every
    /// thread count — workers hand back per-example losses and the reducer
    /// sums them in global example order (eval-mode BatchNorm normalizes
    /// per example with running statistics, so no rendezvous is needed).
    /// Panics on malformed batch geometry (the loaders only produce
    /// well-formed batches).
    pub fn eval_batch(
        &mut self,
        model: &Graph,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
    ) -> (f64, f64) {
        let bt = y.len();
        let n_in = model.in_shape().volume();
        assert!(bt > 0 && x.len() == bt * n_in, "bad eval batch geometry");
        let shards = shard_ranges(bt, self.cfg.threads);
        ensure_worker_ws(&mut self.worker_ws, model, &shards);

        let mut outs: Vec<(Vec<f64>, usize)> = shards.iter().map(|_| (Vec::new(), 0)).collect();
        std::thread::scope(|s| {
            let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
            for ((range, wws), out) in worker_iter {
                let range = range.clone();
                s.spawn(move || {
                    *out = run_eval_shard(model, backend, x, y, range, wws);
                });
            }
        });

        let (mut loss_sum, mut correct) = (0f64, 0usize);
        for (losses, c) in &outs {
            for &l in losses {
                loss_sum += l;
            }
            correct += c;
        }
        (loss_sum / bt as f64, correct as f64 / bt as f64)
    }

    /// Sharded inference: the logits of `bt` examples in global example
    /// order, **bit-identical** to [`Graph::infer_logits`] at every thread
    /// count — eval-mode layers are per-example, shards are contiguous
    /// ranges, and the shard outputs concatenate in shard-index order.
    /// This is the serving path's core primitive
    /// ([`crate::coordinator::serve`]): per-worker forward workspaces (conv
    /// plans included) persist across calls, and no gradient accumulators
    /// or backward scratch are ever allocated. Panics on malformed batch
    /// geometry (the request queue only coalesces well-formed requests).
    pub fn eval_logits(
        &mut self,
        model: &Graph,
        backend: &dyn Backend,
        x: &[f32],
        bt: usize,
    ) -> Vec<f32> {
        let n_in = model.in_shape().volume();
        assert!(bt > 0 && x.len() == bt * n_in, "bad inference batch geometry");
        let shards = shard_ranges(bt, self.cfg.threads);
        ensure_worker_ws(&mut self.worker_ws, model, &shards);

        let mut outs: Vec<Vec<f32>> = shards.iter().map(|_| Vec::new()).collect();
        std::thread::scope(|s| {
            let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
            for ((range, wws), out) in worker_iter {
                let range = range.clone();
                s.spawn(move || {
                    *out = run_logits_shard(model, backend, x, range, wws);
                });
            }
        });
        outs.concat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{simple_cnn, NativeBackend, Sequential, SimpleCnnCfg};
    use crate::util::rng::Pcg;

    fn tiny() -> Sequential {
        simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 })
    }

    fn batch(bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg::new(seed, 1);
        let x = (0..bt * 64).map(|_| rng.normal()).collect();
        let y = (0..bt).map(|i| (i % 3) as i32).collect();
        (x, y)
    }

    #[test]
    fn tree_reduce_sums_in_any_part_count() {
        for nparts in 1..6 {
            let parts: Vec<Vec<f32>> = (0..nparts).map(|p| vec![p as f32, 1.0]).collect();
            let want: f32 = (0..nparts).map(|p| p as f32).sum();
            let got = tree_reduce(parts);
            assert_eq!(got[0], want, "{nparts} parts");
            assert_eq!(got[1], nparts as f32);
        }
        assert!(tree_reduce(Vec::new()).is_empty());
    }

    #[test]
    fn stat_reduce_is_identity_for_one_part_and_sums_in_order() {
        let one = vec![Mutex::new(vec![1.5f32, -2.0])];
        assert_eq!(reduce_stat_partials(&one), vec![1.5, -2.0]);
        let two = vec![Mutex::new(vec![1.0f32, 2.0]), Mutex::new(vec![0.5f32, -1.0])];
        assert_eq!(reduce_stat_partials(&two), vec![1.5, 1.0]);
        assert!(reduce_stat_partials(&[]).is_empty());
    }

    #[test]
    fn exec_config_zero_means_auto_detect() {
        // explicit counts pass through unresolved and unclamped
        assert_eq!(ExecConfig::with_threads(3).resolved_threads(), 3);
        assert_eq!(ExecConfig::with_threads(64).resolved_threads(), 64);
        assert_eq!(ExecConfig::default().resolved_threads(), 1);
        // auto resolves to available_parallelism within the documented clamp
        let auto = ExecConfig::auto();
        assert_eq!(auto, ExecConfig::with_threads(0));
        let resolved = auto.resolved_threads();
        assert!((1..=MAX_AUTO_THREADS).contains(&resolved), "auto resolved to {resolved}");
        // executors resolve at construction, so threads() is always concrete
        assert_eq!(ParallelExecutor::new(ExecConfig::auto()).threads(), resolved);
        assert_eq!(ParallelExecutor::new(ExecConfig::with_threads(2)).threads(), 2);
    }

    #[test]
    fn rejects_bad_geometry() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(2));
        assert!(exec.train_step(&mut m, &be, &[0.0; 3], &[0, 1], 0.0, 0.05).is_err());
        assert!(exec.train_step(&mut m, &be, &[], &[], 0.0, 0.05).is_err());
    }

    #[test]
    fn worker_plans_build_cols_once_per_conv_per_step() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(6, 13);
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(3));
        exec.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        let per_step = (m.conv_count() * 3) as u64;
        assert_eq!(exec.plan_cols_builds(), per_step, "one build per conv per worker");
        exec.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        assert_eq!(exec.plan_cols_builds(), 2 * per_step);
    }

    #[test]
    fn more_threads_than_examples_still_trains() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(2, 5);
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(8));
        let stats = exec.train_step(&mut m, &be, &x, &y, 0.8, 0.05).unwrap();
        assert!(stats.loss.is_finite());
        assert_eq!(stats.kept_channels, 2, "D=0.8 at width 4 keeps 1 channel per layer");
        assert_eq!(exec.worker_ws.len(), 2, "shards are capped at the batch size");
    }

    #[test]
    fn workspaces_rekey_across_batch_sizes() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(2));
        let (x8, y8) = batch(8, 3);
        let (x4, y4) = batch(4, 4);
        exec.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let caps: Vec<Vec<[usize; 7]>> = exec
            .worker_ws
            .iter()
            .map(|wws| wws.iter().filter_map(|w| w.plan_caps()).collect())
            .collect();
        exec.train_step(&mut m, &be, &x4, &y4, 0.0, 0.05).unwrap();
        exec.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let caps2: Vec<Vec<[usize; 7]>> = exec
            .worker_ws
            .iter()
            .map(|wws| wws.iter().filter_map(|w| w.plan_caps()).collect())
            .collect();
        assert_eq!(caps, caps2, "shrinking then regrowing the batch must reuse capacity");
    }

    #[test]
    fn sharded_logits_match_serial_bitwise() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(10, 33);
        m.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
        let want = m.infer_logits(&be, &x, 10);
        for threads in [1usize, 2, 3, 8] {
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let got = exec.eval_logits(&m, &be, &x, 10);
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t{threads} logit {i}");
            }
        }
    }

    #[test]
    fn sharded_eval_matches_serial_bitwise() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(10, 21);
        m.train_step(&be, &x, &y, 0.5, 0.05).unwrap();
        let want = m.eval_batch(&be, &x, &y);
        for threads in [1usize, 2, 3, 8] {
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let got = exec.eval_batch(&m, &be, &x, &y);
            assert_eq!(got, want, "t{threads} eval must be bit-identical to serial");
        }
    }
}
