//! `ssprop` — CLI entrypoint for the L3 coordinator.
//!
//! Subcommands map 1:1 onto the paper's experiments; see `ssprop help`.
//! Native commands (quickstart, train-native, datasets, presets, flops,
//! energy, bench-check) run on the pure-Rust backend with zero setup;
//! artifact commands (train, ddpm, tables, figures) execute AOT-compiled
//! graphs and require a build with `--features pjrt` plus `make artifacts`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};
use ssprop::backend::fold;
use ssprop::bench_report::{
    gate, preset_ledger, trajectory, BenchReport, PresetReport, Tolerance, BENCH_BATCH,
};
use ssprop::coordinator::{ClassifyRequest, NativeTrainConfig, NativeTrainer, ServeConfig, Server};
use ssprop::energy::{RTX_A5000, TPU_CORE};
use ssprop::experiments::report::Table;
use ssprop::experiments::{tables, Scale};
use ssprop::schedule::{DropScheduler, Schedule};
use ssprop::util::bench::fmt_ns;
use ssprop::util::cli::Args;
use ssprop::util::rng::Pcg;

const USAGE: &str = "\
ssprop — scheduled sparse back-propagation coordinator (paper reproduction)

USAGE: ssprop <command> [--flags]

native commands (no artifacts needed; pure-Rust backend):
  quickstart   train a zoo model with the paper's scheduler and print the
               FLOPs/energy ledger   [--dataset cifar10] [--model simple-cnn]
               [--epochs 4] [--iters 24] [--target-drop 0.8] [--seed 0]
               [--threads 1 (0 = auto)]
  train-native full native training  --dataset cifar10 [--model simple-cnn]
               [--depth 2] [--width 8] [--batch 16] [--epochs 3] [--iters 16]
               [--lr 0.3]
               [--schedule epoch-bar|constant|linear|cosine|bar|iter-bar|warmup-bar]
               [--target-drop 0.8] [--period 2] [--seed 0] [--threads 1]
               [--include-tail] [--no-pipeline] [--affinity] [--save ck.tstore]
               [--verbose]
               (--model picks a zoo preset: simple-cnn[-dD-wW], vgg-tiny[-wW],
               dropout-cnn[-wW-pP], resnet-tiny[-wW-bB] (residual blocks +
               BatchNorm, W channels x B blocks per stage); bare simple-cnn
               takes --depth/--width. --threads N shards each batch across N
               persistent pool workers with deterministic gradient reduction,
               0 auto-detects the count; --include-tail also trains each
               epoch's leftover partial batch; --no-pipeline disables the
               batch-prefetch pipeline — a wall-clock knob, bits identical;
               --affinity pins pool worker w to core w on Linux/x86-64 — a
               placement hint, bits identical, no-op elsewhere)
  fold         bake a checkpoint's BatchNorm statistics into its conv
               weights for serving: fold --checkpoint ck.tstore --out
               folded.tstore (specs without BatchNorm are a typed no-op)
  serve        answer batched classify requests from a checkpoint (folded
               in memory when needed) and report p50/p99 latency +
               throughput:  serve --checkpoint ck.tstore [--model SPEC]
               [--requests 96] [--batch 32] [--threads 1 (0 = auto)]
               [--seed 0] [--repeat 1] [--json results/BENCH_serve.json]
               (--repeat N drains the same queue N times on one persistent
               server and fails loudly if any drain's answers differ bitwise)
  datasets     print Table 1 (dataset geometry)
  presets      print Tables 2/3 (hyperparameters)
  flops        print FLOPs parity + Eq.10/11 lower-bound tables
  energy       print the paper-scale energy/carbon projection
  bench-check  gate a fresh bench report against the committed baseline:
               bench-check BASELINE.json FRESH.json [--ratio-band 8.0]
               (exits nonzero on regression; see docs/BENCHMARKS.md), or
               print the perf/energy trajectory over a series of reports:
               bench-check --trajectory A.json [B.json ...]
  help         this message

artifact commands (build with --features pjrt, then `make artifacts`):
  train        train one artifact         --artifact resnet18_cifar10 --epochs 4
               [--iters 24] [--lr 1e-3] [--schedule ...] [--target-drop 0.8]
               [--period 2] [--dropout 0.0] [--seed 0] [--save ck.tstore] [--verbose]
  ddpm         train + sample a DDPM      --dataset mnist [--iters 100] [--lr 1e-3]
  sample       sample from a DDPM checkpoint --dataset mnist [--out results/samples.pgm]
  table4|table5|table6|table7
               regenerate a paper table   [--epochs N --iters N --datasets a,b --archs x,y]
  suite        the whole recorded suite in ONE process (shared executable cache)
  fig2         regenerate Fig 2           --part a|b|c|d [--rates 0.25,0.55,0.8]
  fig3         DDPM sample grids          [--datasets mnist,fashion]
  fig4         hyperparameter grid        [--depths 2,4,6 --lrs 4e-4,1.6e-3,6.4e-3]
  artifacts    list compiled artifacts

global flags: --artifacts-dir DIR (default: artifacts)";

fn scale_from(args: &Args) -> Scale {
    let d = Scale::default();
    Scale {
        epochs: args.get_usize("epochs", d.epochs),
        iters_per_epoch: args.get_usize("iters", d.iters_per_epoch),
        seed: args.get_u64("seed", d.seed),
        lr: args.get_f64("lr", d.lr),
    }
}

fn parse_schedule(args: &Args) -> Result<Schedule> {
    Schedule::parse(args.get_or("schedule", "epoch-bar"), args.get_usize("period", 2))
        .ok_or_else(|| anyhow::anyhow!("unknown schedule"))
}

/// Validate the flags that would otherwise trip constructor asserts, so the
/// CLI fails with a clean error instead of a panic (and errors on
/// unparsable values instead of silently training with defaults).
fn parse_horizon_and_target(
    args: &Args,
    def_epochs: usize,
    def_iters: usize,
) -> Result<(usize, usize, f64)> {
    let epochs = parsed_flag(args, "epochs", def_epochs)?;
    let iters = parsed_flag(args, "iters", def_iters)?;
    if epochs == 0 || iters == 0 {
        bail!("--epochs and --iters must be positive");
    }
    let target = parsed_flag(args, "target-drop", 0.8)?;
    if !(0.0..1.0).contains(&target) {
        bail!("--target-drop must be in [0, 1) (got {target})");
    }
    Ok((epochs, iters, target))
}

/// Parse `--threads` (default 1 = single-threaded; 0 = auto-detect via
/// `ExecConfig::auto`'s documented clamp), erroring on negative or
/// non-numeric values here so the CLI fails with a clean message instead
/// of a constructor error or a silent fallback.
fn parse_threads(args: &Args) -> Result<usize> {
    parsed_flag(args, "threads", 1usize)
}

/// Parse an optional flag strictly: absent uses the default, garbage is an
/// error — never a silent fallback.
fn parsed_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    Ok(args.try_parse(key).map_err(anyhow::Error::msg)?.unwrap_or(default))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let artifacts_dir = args.get_or("artifacts-dir", "artifacts").to_string();

    match cmd {
        "help" | "--help" => println!("{USAGE}"),
        "datasets" => tables::table1().print(),
        "presets" => tables::table23(scale_from(&args)).print(),
        "flops" => {
            let (parity, lb) = tables::flops_report();
            parity.print();
            lb.print();
        }
        "energy" => tables::energy_report().print(),
        "bench-check" => cmd_bench_check(&args)?,
        "fold" => cmd_fold(&args)?,
        "serve" => cmd_serve(&args)?,
        "quickstart" => cmd_quickstart(&args)?,
        "train-native" => cmd_train_native(&args)?,
        other => {
            if !artifact_cmd(other, &args, &artifacts_dir)? {
                bail!("unknown command {other:?}; try `ssprop help`");
            }
        }
    }
    Ok(())
}

/// The CI regression gate over committed bench artifacts: diff a fresh
/// `BENCH_*.json` against the baseline per the tolerance policy (ratios
/// inside a wide multiplicative band, FLOPs/joules ledger exact — see
/// `docs/BENCHMARKS.md`) and exit nonzero on regression. With
/// `--trajectory`, render the perf/energy trajectory table over a series
/// of reports instead.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let files: Vec<&String> = args.positional.iter().skip(1).collect();
    if args.has_flag("trajectory") || args.get("trajectory").is_some() {
        // `--trajectory A.json` parses A.json as the flag's value; fold it
        // back into the file list so both spellings work.
        let mut paths: Vec<String> = Vec::new();
        if let Some(v) = args.get("trajectory") {
            paths.push(v.to_string());
        }
        paths.extend(files.iter().map(|f| f.to_string()));
        if paths.is_empty() {
            bail!("bench-check --trajectory needs at least one BENCH_*.json");
        }
        let mut entries = Vec::new();
        for f in &paths {
            entries.push((f.clone(), BenchReport::load(Path::new(f.as_str()))?));
        }
        trajectory(&entries).print();
        return Ok(());
    }
    let &[baseline_path, fresh_path] = files.as_slice() else {
        bail!("usage: ssprop bench-check BASELINE.json FRESH.json [--ratio-band 8.0]");
    };
    let band = parsed_flag(args, "ratio-band", Tolerance::default().ratio_band)?;
    if band <= 1.0 {
        bail!("--ratio-band must be > 1 (a multiplicative band around the baseline)");
    }
    let tol = Tolerance { ratio_band: band, ..Tolerance::default() };
    let baseline = BenchReport::load(Path::new(baseline_path.as_str()))?;
    let fresh = BenchReport::load(Path::new(fresh_path.as_str()))?;
    let res = gate(&baseline, &fresh, &tol);

    let fmt_metric = |v: f64| {
        if v == v.trunc() && v.abs() < 9e15 {
            format!("{}", v as i64)
        } else {
            format!("{v:.6}")
        }
    };
    let mut t = Table::new(
        &format!("bench-check: {fresh_path} vs baseline {baseline_path}"),
        &["metric", "class", "baseline", "fresh", "status"],
    );
    for d in &res.diffs {
        t.row(vec![
            d.metric.clone(),
            d.class.to_string(),
            fmt_metric(d.baseline),
            fmt_metric(d.fresh),
            if d.ok { "ok".to_string() } else { "FAIL".to_string() },
        ]);
    }
    t.print();
    for p in &res.problems {
        println!("problem: {p}");
    }
    if !res.passed() {
        bail!("bench-check FAILED: {} metric(s) out of tolerance", res.failures().len());
    }
    println!("\nbench-check OK: {} metrics compared within tolerance", res.diffs.len());
    Ok(())
}

/// Checkpoint → folded-checkpoint conversion: bake BatchNorm statistics
/// into the preceding conv weights (`backend::fold`) and write the BN-free
/// state under the `#folded`-tagged artifact.
fn cmd_fold(args: &Args) -> Result<()> {
    let (Some(src), Some(dst)) = (args.get("checkpoint"), args.get("out")) else {
        bail!("usage: ssprop fold --checkpoint ck.tstore --out folded.tstore");
    };
    let summary = fold::fold_checkpoint(Path::new(src), Path::new(dst))?;
    println!("folded {} BatchNorm node(s) of {}", summary.folded, summary.spec);
    println!("artifact         {}", summary.artifact);
    println!("state leaves     {}", summary.leaves);
    println!("checkpoint       {dst}");
    Ok(())
}

/// Batched inference serving over a (BN-folded) checkpoint: drain a
/// synthetic classify-request queue through the coalescing batcher and the
/// sharded forward-only walk, report p50/p99 latency + throughput, and —
/// with `--json` — record the run as a `BENCH_serve.json` bench report for
/// the CI gate (docs/BENCHMARKS.md).
fn cmd_serve(args: &Args) -> Result<()> {
    let Some(ck) = args.get("checkpoint") else {
        bail!(
            "usage: ssprop serve --checkpoint ck.tstore [--model SPEC] [--requests 96] \
             [--batch 32] [--threads 1] [--seed 0] [--repeat 1] [--json PATH]"
        );
    };
    let batch = parsed_flag(args, "batch", 32usize)?;
    let n_requests = parsed_flag(args, "requests", 96usize)?;
    if batch == 0 || n_requests == 0 {
        bail!("--batch and --requests must be positive");
    }
    let threads = parse_threads(args)?;
    let repeat = parsed_flag(args, "repeat", 1usize)?;
    if repeat == 0 {
        bail!("--repeat must be positive (1 = a single measured drain)");
    }
    let seed = parsed_flag(args, "seed", 0u64)?;
    let mut srv =
        Server::from_checkpoint(Path::new(ck), args.get("model"), ServeConfig { batch, threads })?;
    // 0 means auto-detect; every report below names the resolved count.
    let threads = srv.threads();
    let n_in = srv.input_len();
    let make_requests = |seed: u64, n: usize| -> Vec<ClassifyRequest> {
        let mut rng = Pcg::new(seed, 77);
        (0..n)
            .map(|i| ClassifyRequest {
                id: i as u64,
                pixels: (0..n_in).map(|_| rng.normal()).collect(),
            })
            .collect()
    };

    println!("== ssprop serve: {} ({} BN node(s) folded) ==\n", srv.spec(), srv.folded());
    // Warm the worker plans, then take the measured drain, then the two
    // reference drains the speedup ratios compare against: the same queue
    // at one thread, and one request at a time.
    srv.serve(make_requests(seed + 1, batch.min(n_requests)));
    let (answers, stats) = srv.serve(make_requests(seed, n_requests));
    // --repeat N: re-drain the identical queue on the same (persistent)
    // server and require every answer to match the first drain bitwise —
    // the pool-reuse determinism check CI runs ahead of the bench gates.
    for pass in 1..repeat {
        let (again, _) = srv.serve(make_requests(seed, n_requests));
        let same = again.len() == answers.len()
            && again.iter().zip(answers.iter()).all(|(a, b)| {
                a.id == b.id
                    && a.class == b.class
                    && a.logits.len() == b.logits.len()
                    && a.logits
                        .iter()
                        .zip(b.logits.iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            });
        if !same {
            bail!(
                "serve --repeat: drain {} diverged bitwise from drain 1 on the same queue",
                pass + 1
            );
        }
    }
    if repeat > 1 {
        println!("repeat drains    {repeat} drains of the same queue, answers bitwise-identical");
    }
    srv.set_threads(1);
    srv.serve(make_requests(seed + 1, batch.min(n_requests)));
    let (_, t1) = srv.serve(make_requests(seed, n_requests));
    srv.set_threads(threads);
    srv.set_batch(1);
    let (_, single) = srv.serve(make_requests(seed, n_requests));
    srv.set_batch(batch);

    let serve_speedup = t1.total_ns.max(1) as f64 / stats.total_ns.max(1) as f64;
    let batch_speedup = single.total_ns.max(1) as f64 / stats.total_ns.max(1) as f64;

    println!("checkpoint       {ck} (epoch {})", srv.epoch());
    println!(
        "requests         {} over {} batch(es) (batch {batch}, threads {threads})",
        stats.answered, stats.batches
    );
    println!("p50 latency      {}", fmt_ns(stats.p50_ns as f64));
    println!("p99 latency      {}", fmt_ns(stats.p99_ns as f64));
    println!("throughput       {:.1} req/s", stats.throughput_rps);
    println!("serve speedup    {serve_speedup:.2}x (t{threads} vs t1)");
    println!("batch speedup    {batch_speedup:.2}x (batch {batch} vs one-at-a-time)");

    if let Some(json_path) = args.get("json") {
        let mut rep = BenchReport::new("serve", "smoke");
        rep.batch = batch;
        // The ledger halves are computed at the bench harness batch size so
        // they stay bit-identical to the BENCH_native.json entries.
        let (flops, energy) = preset_ledger(srv.spec(), BENCH_BATCH)?;
        let mut timings_ns = BTreeMap::new();
        timings_ns.insert("serve_p50_ns".to_string(), stats.p50_ns as f64);
        timings_ns.insert("serve_p99_ns".to_string(), stats.p99_ns as f64);
        timings_ns.insert("serve_total_ns".to_string(), stats.total_ns as f64);
        timings_ns.insert("serve_t1_total_ns".to_string(), t1.total_ns as f64);
        timings_ns.insert("serve_single_total_ns".to_string(), single.total_ns as f64);
        let mut ratios = BTreeMap::new();
        ratios.insert(format!("serve_speedup_t{threads}"), serve_speedup);
        ratios.insert(format!("batch_speedup_b{batch}"), batch_speedup);
        rep.presets.push(PresetReport {
            spec: srv.spec().to_string(),
            timings_ns,
            ratios,
            flops,
            energy,
        });
        rep.save(Path::new(json_path))?;
        println!("bench report     {json_path}");
    }
    Ok(())
}

/// Zero-setup demo: SimpleCNN on the synthetic data plane, paper-default
/// bar scheduler, full FLOPs/energy ledger.
fn cmd_quickstart(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "cifar10").to_string();
    let (epochs, iters, target) = parse_horizon_and_target(args, 4, 24)?;
    let mut cfg = NativeTrainConfig::quick(&dataset, epochs, iters);
    cfg.model = args.get_or("model", "simple-cnn").to_string();
    cfg.seed = parsed_flag(args, "seed", 0u64)?;
    cfg.threads = parse_threads(args)?;
    cfg.scheduler =
        DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, target, epochs, iters);
    cfg.verbose = true;

    println!("== ssProp quickstart: {} on synth-{dataset} (native backend) ==\n", cfg.model);
    let mut t = NativeTrainer::new(cfg)?;
    let (loss, acc) = t.run()?;
    print_native_summary(&t, loss, acc);
    Ok(())
}

/// Full native training with every knob exposed.
fn cmd_train_native(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "cifar10").to_string();
    let (epochs, iters, target) = parse_horizon_and_target(args, 3, 16)?;
    let schedule = parse_schedule(args)?;
    let mut cfg = NativeTrainConfig::quick(&dataset, epochs, iters);
    cfg.model = args.get_or("model", "simple-cnn").to_string();
    cfg.depth = parsed_flag(args, "depth", cfg.depth)?;
    cfg.width = parsed_flag(args, "width", cfg.width)?;
    cfg.batch = parsed_flag(args, "batch", cfg.batch)?;
    if cfg.depth == 0 || cfg.width == 0 {
        bail!("--depth and --width must be positive");
    }
    cfg.lr = parsed_flag(args, "lr", cfg.lr)?;
    cfg.seed = parsed_flag(args, "seed", 0u64)?;
    cfg.threads = parse_threads(args)?;
    cfg.include_tail = args.has_flag("include-tail") || args.get("include-tail").is_some();
    cfg.pipeline = !(args.has_flag("no-pipeline") || args.get("no-pipeline").is_some());
    cfg.affinity = args.has_flag("affinity") || args.get("affinity").is_some();
    cfg.scheduler = DropScheduler::new(schedule, target, epochs, iters);
    cfg.verbose = args.has_flag("verbose") || args.get("verbose").is_some();

    let mut t = NativeTrainer::new(cfg)?;
    let (loss, acc) = t.run()?;
    print_native_summary(&t, loss, acc);
    if let Some(path) = args.get("save") {
        t.save_checkpoint(path, epochs)?;
        println!("checkpoint       {path}");
    }
    Ok(())
}

fn print_native_summary(t: &NativeTrainer, loss: f64, acc: f64) {
    let m = &t.metrics;
    println!("\nbackend          {}", t.backend_name());
    println!("threads          {}", t.threads());
    println!("dataset          {}", t.cfg.dataset);
    println!("model            {} ({})", t.model_spec, t.model.describe());
    println!("final test loss  {loss:.4}");
    println!("final test acc   {acc:.4}");
    println!("mean drop rate   {:.3}", m.mean_drop_rate());
    println!(
        "bwd FLOPs        dense-equivalent {:.3e}, actual {:.3e} (saved {:.1}%)",
        m.flops_dense,
        m.flops_actual,
        m.flops_saving() * 100.0
    );
    let saved = m.energy_saved(&RTX_A5000);
    let saved_tpu = m.energy_saved(&TPU_CORE);
    println!(
        "energy saved     {:.6} kWh ({:.3} gCO2e) @A5000; {:.6} kWh @TPU",
        saved.kwh, saved.gco2e, saved_tpu.kwh
    );
    println!("wall time        {:.2}s", m.total_wall_secs());
}

// ---------------------------------------------------------------------------
// artifact (PJRT) commands
// ---------------------------------------------------------------------------

/// Every command handled by `pjrt_cmds::dispatch` — kept in one place so
/// the no-pjrt build's "rebuild with --features pjrt" hint and the real
/// dispatcher cannot drift apart.
const ARTIFACT_CMDS: &[&str] = &[
    "train", "ddpm", "sample", "artifacts", "suite", "table4", "table5", "table6", "table7",
    "fig2", "fig3", "fig4",
];

/// Dispatch `cmd` if it is an artifact command; Ok(false) when unknown.
#[cfg(not(feature = "pjrt"))]
fn artifact_cmd(cmd: &str, _args: &Args, _artifacts_dir: &str) -> Result<bool> {
    if ARTIFACT_CMDS.contains(&cmd) {
        bail!(
            "`{cmd}` executes AOT artifacts through PJRT; rebuild with `cargo build \
             --features pjrt` (native commands work on any build: quickstart, \
             train-native, datasets, presets, flops, energy)"
        );
    }
    Ok(false)
}

#[cfg(feature = "pjrt")]
fn artifact_cmd(cmd: &str, args: &Args, artifacts_dir: &str) -> Result<bool> {
    let handled = pjrt_cmds::dispatch(cmd, args, artifacts_dir)?;
    debug_assert_eq!(
        handled,
        ARTIFACT_CMDS.contains(&cmd),
        "ARTIFACT_CMDS out of sync for {cmd:?}"
    );
    Ok(handled)
}

#[cfg(feature = "pjrt")]
mod pjrt_cmds {
    use anyhow::{bail, Result};
    use ssprop::coordinator::{checkpoint, TrainConfig, Trainer};
    use ssprop::ddpm::DdpmTrainer;
    use ssprop::energy::RTX_A5000;
    use ssprop::experiments::{figures, tables};
    use ssprop::metrics::fid_proxy;
    use ssprop::runtime::Engine;
    use ssprop::schedule::DropScheduler;
    use ssprop::util::cli::Args;

    use super::{parse_horizon_and_target, parse_schedule, scale_from};

    fn list_arg(args: &Args, key: &str, default: &str) -> Vec<String> {
        args.get_or(key, default).split(',').map(|s| s.trim().to_string()).collect()
    }

    pub fn dispatch(cmd: &str, args: &Args, artifacts_dir: &str) -> Result<bool> {
        match cmd {
            "train" => cmd_train(args, artifacts_dir)?,
            "ddpm" => cmd_ddpm(args, artifacts_dir)?,
            "sample" => cmd_sample(args, artifacts_dir)?,
            "artifacts" => {
                let engine = Engine::new(artifacts_dir)?;
                for name in engine.list_artifacts()? {
                    println!("{name}");
                }
            }
            "table4" => {
                let engine = Engine::new(artifacts_dir)?;
                let datasets = list_arg(args, "datasets", "mnist,cifar10");
                let archs = list_arg(args, "archs", "resnet18,resnet50");
                let t = tables::table4(
                    &engine,
                    scale_from(args),
                    &datasets.iter().map(String::as_str).collect::<Vec<_>>(),
                    &archs.iter().map(String::as_str).collect::<Vec<_>>(),
                )?;
                t.print();
            }
            "table5" => {
                let engine = Engine::new(artifacts_dir)?;
                let datasets = list_arg(args, "datasets", "mnist");
                let t = tables::table5(
                    &engine,
                    scale_from(args),
                    &datasets.iter().map(String::as_str).collect::<Vec<_>>(),
                )?;
                t.print();
            }
            "table6" => {
                let engine = Engine::new(artifacts_dir)?;
                let datasets = list_arg(args, "datasets", "cifar10");
                let t = tables::table6(
                    &engine,
                    scale_from(args),
                    &datasets.iter().map(String::as_str).collect::<Vec<_>>(),
                )?;
                t.print();
            }
            "table7" => {
                let engine = Engine::new(artifacts_dir)?;
                let datasets = list_arg(args, "datasets", "cifar10");
                let t = tables::table7(
                    &engine,
                    scale_from(args),
                    &datasets.iter().map(String::as_str).collect::<Vec<_>>(),
                )?;
                t.print();
            }
            // one process for the whole recorded suite: the engine caches
            // compiled executables, so each model compiles exactly once
            // (ResNet-50 alone costs minutes of XLA CPU compile time).
            "suite" => cmd_suite(args, artifacts_dir)?,
            "fig2" => cmd_fig2(args, artifacts_dir)?,
            "fig3" => {
                let engine = Engine::new(artifacts_dir)?;
                let datasets = list_arg(args, "datasets", "mnist");
                let written = figures::fig3(
                    &engine,
                    scale_from(args),
                    &datasets.iter().map(String::as_str).collect::<Vec<_>>(),
                )?;
                for p in written {
                    println!("wrote {p}");
                }
            }
            "fig4" => {
                let engine = Engine::new(artifacts_dir)?;
                let depths: Vec<usize> = list_arg(args, "depths", "2,4,6")
                    .iter()
                    .filter_map(|s| s.parse().ok())
                    .collect();
                let lrs: Vec<f64> = list_arg(args, "lrs", "4e-4,1.6e-3,6.4e-3")
                    .iter()
                    .filter_map(|s| s.parse().ok())
                    .collect();
                let (normal, sparse) = figures::fig4(&engine, scale_from(args), &depths, &lrs)?;
                normal.print();
                sparse.print();
                let (ia, ib, corr) = figures::fig4_agreement(&normal, &sparse);
                println!("\nbest cell: normal #{ia}, sparse #{ib}; surface correlation {corr:.3}");
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    fn cmd_train(args: &Args, artifacts_dir: &str) -> Result<()> {
        let engine = Engine::new(artifacts_dir)?;
        let artifact = args.get_or("artifact", "resnet18_cifar10").to_string();
        let (epochs, iters, target) = parse_horizon_and_target(args, 4, 24)?;
        let schedule = parse_schedule(args)?;
        let cfg = TrainConfig {
            artifact: artifact.clone(),
            epochs,
            iters_per_epoch: iters,
            lr: args.get_f64("lr", 1e-3),
            scheduler: DropScheduler::new(schedule, target, epochs, iters),
            dropout_rate: args.get_f64("dropout", 0.0),
            seed: args.get_u64("seed", 0),
            eval_every: args.get_usize("eval-every", 0),
            verbose: args.has_flag("verbose") || args.get("verbose").is_some(),
        };
        let mut t = Trainer::new(&engine, cfg)?;
        let (loss, acc) = t.run()?;
        let m = &t.metrics;
        println!("\nartifact         {artifact}");
        println!("final test loss  {loss:.4}");
        println!("final test acc   {acc:.4}");
        println!("mean drop rate   {:.3}", m.mean_drop_rate());
        println!(
            "bwd FLOPs        dense-equivalent {:.3e}, actual {:.3e} (saved {:.1}%)",
            m.flops_dense,
            m.flops_actual,
            m.flops_saving() * 100.0
        );
        let saved = m.energy_saved(&RTX_A5000);
        let saved_tpu = m.energy_saved(&ssprop::energy::TPU_CORE);
        println!(
            "energy saved     {:.6} kWh ({:.3} gCO2e) @A5000; {:.6} kWh @TPU",
            saved.kwh, saved.gco2e, saved_tpu.kwh
        );
        println!("wall time        {:.2}s", m.total_wall_secs());
        if let Some(path) = args.get("save") {
            checkpoint::save(path, &t.state, &artifact, epochs)?;
            println!("checkpoint       {path}");
        }
        Ok(())
    }

    fn cmd_ddpm(args: &Args, artifacts_dir: &str) -> Result<()> {
        let engine = Engine::new(artifacts_dir)?;
        let dataset = args.get_or("dataset", "mnist").to_string();
        let iters = args.get_usize("iters", 100);
        let mut tr =
            DdpmTrainer::new(&engine, &dataset, args.get_f64("lr", 1e-3), args.get_u64("seed", 0))?;
        let sched = DropScheduler::paper_default(2, iters.div_ceil(2).max(1));
        let loss = tr.train(iters, &sched)?;
        println!("ddpm {dataset}: {iters} iters, final loss {loss:.4}");
        let samples = tr.sample(1)?;
        let real = tr.real_batch(64);
        let fid = fid_proxy(&real, &samples, 1234);
        println!("FID-proxy {fid:.4} (vs real synthetic data)");
        let m = &tr.metrics;
        println!(
            "bwd FLOPs saved {:.1}%, wall {:.2}s",
            m.flops_saving() * 100.0,
            m.total_wall_secs()
        );
        let out = args.get_or("out", "results/ddpm_samples.pgm");
        std::fs::create_dir_all("results").ok();
        let man = tr.denoise_graph.manifest.clone();
        ssprop::ddpm::write_pgm_grid(out, &samples, man.img, man.channels)?;
        println!("samples -> {out}");
        Ok(())
    }

    fn cmd_sample(args: &Args, artifacts_dir: &str) -> Result<()> {
        let engine = Engine::new(artifacts_dir)?;
        let dataset = args.get_or("dataset", "mnist").to_string();
        let mut tr = DdpmTrainer::new(&engine, &dataset, 1e-3, 0)?;
        if let Some(ck) = args.get("checkpoint") {
            let (state, _, _) = checkpoint::load(ck)?;
            tr.state = state;
        }
        let samples = tr.sample(args.get_u64("seed", 0))?;
        let out = args.get_or("out", "results/samples.pgm");
        std::fs::create_dir_all("results").ok();
        let man = tr.denoise_graph.manifest.clone();
        ssprop::ddpm::write_pgm_grid(out, &samples, man.img, man.channels)?;
        println!("wrote {out}");
        Ok(())
    }

    fn cmd_fig2(args: &Args, artifacts_dir: &str) -> Result<()> {
        let engine = Engine::new(artifacts_dir)?;
        let scale = scale_from(args);
        let part = args.get_or("part", "c");
        let rates: Vec<f64> = args
            .get_or("rates", "0.25,0.55,0.8")
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
        match part {
            "a" => figures::fig2a(&engine, scale, &rates)?.print(),
            "b" => figures::fig2b(&engine, scale, &rates)?.print(),
            "c" => figures::fig2c(&engine, scale, &rates)?.print(),
            "d" => {
                let periods: Vec<usize> = args
                    .get_or("periods", "30,120,300")
                    .split(',')
                    .filter_map(|s| s.trim().parse().ok())
                    .collect();
                figures::fig2d(&engine, scale, &periods)?.print()
            }
            other => bail!("unknown fig2 part {other:?} (a|b|c|d)"),
        }
        Ok(())
    }

    /// The full recorded experiment suite in a single process (shared
    /// executable cache). Scale via --epochs/--iters; logs land in results/.
    fn cmd_suite(args: &Args, artifacts_dir: &str) -> Result<()> {
        let engine = Engine::new(artifacts_dir)?;
        let scale = scale_from(args);
        let t0 = std::time::Instant::now();

        tables::table1().print();
        tables::table23(scale).print();
        let (parity, lb) = tables::flops_report();
        parity.print();
        lb.print();
        tables::energy_report().print();

        println!(
            "\n[{:.0}s] Table 4 (resnet18: mnist,cifar10; resnet50: cifar10)",
            t0.elapsed().as_secs_f64()
        );
        tables::table4(&engine, scale, &["mnist", "cifar10"], &["resnet18"])?.print();
        tables::table4(&engine, scale, &["cifar10"], &["resnet50"])?.print();

        println!("\n[{:.0}s] Table 7", t0.elapsed().as_secs_f64());
        tables::table7(&engine, scale, &["cifar10"])?.print();

        println!("\n[{:.0}s] Table 6", t0.elapsed().as_secs_f64());
        let mut sc6 = scale;
        sc6.epochs = (scale.epochs / 2).max(1);
        tables::table6(&engine, sc6, &["cifar10"])?.print();

        println!("\n[{:.0}s] Table 5 + Fig 3", t0.elapsed().as_secs_f64());
        let mut sc5 = scale;
        sc5.lr = 2e-3;
        tables::table5(&engine, sc5, &["mnist"])?.print();
        for p in figures::fig3(&engine, sc5, &["mnist"])? {
            println!("wrote {p}");
        }

        println!("\n[{:.0}s] Fig 2", t0.elapsed().as_secs_f64());
        let mut sc2 = scale;
        sc2.iters_per_epoch = (scale.iters_per_epoch * 2 / 3).max(4);
        figures::fig2a(&engine, sc2, &[0.25, 0.8])?.print();
        figures::fig2b(&engine, sc2, &[0.25, 0.8])?.print();
        figures::fig2c(&engine, sc2, &[0.55, 0.8])?.print();
        figures::fig2d(&engine, sc2, &[8, 24])?.print();

        println!("\n[{:.0}s] Fig 4", t0.elapsed().as_secs_f64());
        let mut sc4 = scale;
        sc4.epochs = 3;
        let (normal, sparse) = figures::fig4(&engine, sc4, &[2, 4, 6], &[4e-4, 1.6e-3, 6.4e-3])?;
        normal.print();
        sparse.print();
        let (ia, ib, corr) = figures::fig4_agreement(&normal, &sparse);
        println!("\nfig4 best cell: normal #{ia}, sparse #{ib}; surface correlation {corr:.3}");

        println!("\nsuite done in {:.0}s", t0.elapsed().as_secs_f64());
        Ok(())
    }
}
