"""Tiled Pallas matmul — the GEMM at the heart of the img2col formulation.

This is the canonical MXU-shaped kernel: a 3-D grid over (M-tiles, N-tiles,
K-steps) with an f32 VMEM accumulator scratch. On a real TPU each (bm, bk) x
(bk, bn) block pair streams HBM->VMEM under the BlockSpec schedule and the
``jnp.dot`` maps onto the 128x128 systolic array; here we run it with
``interpret=True`` so the same HLO executes on the CPU PJRT client.

Both ssProp backward matmuls reuse this kernel:
    dW' = col_X^T @ col[dY]'     (N x M) @ (M x k')
    dXc = col[dY]' @ col_W'^T    (M x k') @ (k' x N)
The *compaction* (k' < C_out) is what shrinks the contraction/output dim and
realizes the paper's FLOPs saving; see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes: MXU-friendly 128x128 output tiles with a 128-deep
# contraction step. The wrapper shrinks tiles for small operands so the
# interpret-mode tests stay fast.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a, b, *, bm: int = BM, bn: int = BN, bk: int = BK, interpret: bool = True):
    """C = A @ B with zero-padding to tile multiples (padding contributes 0)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 8)), min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Static VMEM footprint of one grid step: A-tile + B-tile + acc + out."""
    return itemsize * (bm * bk + bk * bn + 2 * bm * bn)
