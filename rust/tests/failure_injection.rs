//! Failure injection: every layer of the runtime must fail loudly and
//! specifically, never silently mis-train.

use std::io::Write as _;

use ssprop::runtime::{f32_literal, Engine, Manifest};
use ssprop::tensorstore::{self, Tensor};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ssprop_fail_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let d = tmp_dir("missing");
    std::fs::write(d.join("index.json"), r#"{"artifacts": []}"#).unwrap();
    let engine = Engine::new(&d).unwrap();
    let err = engine.load("nope_train").err().expect("must fail").to_string();
    assert!(err.contains("nope_train"), "{err}");
}

#[test]
fn garbage_hlo_text_fails_at_parse_not_execute() {
    let d = tmp_dir("garbage");
    std::fs::write(d.join("bad.hlo.txt"), "this is not hlo").unwrap();
    std::fs::write(
        d.join("bad.manifest.json"),
        r#"{"name": "bad", "inputs": [], "outputs": []}"#,
    )
    .unwrap();
    let engine = Engine::new(&d).unwrap();
    let err = format!("{:?}", engine.load("bad").err().expect("must fail"));
    assert!(err.contains("parse"), "{err}");
}

#[test]
fn wrong_input_count_rejected_before_pjrt() {
    // use the real artifacts if present; otherwise skip
    let Ok(engine) = Engine::auto() else { return };
    let Ok(g) = engine.load("conv_pallas_dense") else { return };
    let one = f32_literal(&[1], &[0.0]).unwrap();
    let err = g.run(&[&one]).err().expect("must fail").to_string();
    assert!(err.contains("expects"), "{err}");
}

#[test]
fn manifest_parser_rejects_malformed_documents() {
    for bad in [
        "",                                        // empty
        "{",                                       // truncated
        r#"{"name": "x"}"#,                        // missing inputs/outputs
        r#"{"name": "x", "inputs": 3, "outputs": []}"#, // wrong type
    ] {
        assert!(Manifest::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn tensorstore_header_lying_about_offsets_rejected() {
    let d = tmp_dir("tstore");
    let p = d.join("x.tstore");
    tensorstore::write(&p, &[("a".into(), Tensor::from_f32(vec![2], &[1.0, 2.0]))]).unwrap();
    // corrupt: rewrite header with an offset past the payload
    let raw = std::fs::read(&p).unwrap();
    let hlen = u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize;
    let header = String::from_utf8(raw[12..12 + hlen].to_vec()).unwrap();
    let evil = header.replace("\"offset\":0", "\"offset\":9999");
    assert_ne!(header, evil);
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(b"TSTORE01").unwrap();
    f.write_all(&(evil.len() as u32).to_le_bytes()).unwrap();
    f.write_all(evil.as_bytes()).unwrap();
    f.write_all(&raw[12 + hlen..]).unwrap();
    drop(f);
    assert!(tensorstore::read(&p).is_err());
}

#[test]
fn scheduler_rejects_invalid_targets() {
    use ssprop::schedule::{DropScheduler, Schedule};
    for bad in [1.0, 1.5, -0.1] {
        let r = std::panic::catch_unwind(|| {
            DropScheduler::new(Schedule::Constant, bad, 1, 1)
        });
        assert!(r.is_err(), "target {bad} must be rejected");
    }
}

#[test]
fn engine_auto_fails_without_artifacts() {
    let cwd = std::env::current_dir().unwrap();
    let d = tmp_dir("empty_cwd");
    // guard against parallel-test cwd races by using an explicit bad dir
    let engine = Engine::new(d.join("does_not_exist"));
    // Engine::new itself succeeds (lazy); loading must fail
    if let Ok(e) = engine {
        assert!(e.load("anything").is_err());
        assert!(e.list_artifacts().is_err());
    }
    std::env::set_current_dir(cwd).unwrap();
}
