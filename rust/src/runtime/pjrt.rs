//! PJRT engine (feature `pjrt`): loads `artifacts/*.hlo.txt` produced by the
//! Python compile path, compiles them on the CPU PJRT client, and executes
//! them from the coordinator's hot loop.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → HloModuleProto →
//! XlaComputation → PjRtLoadedExecutable. Steps are lowered with
//! return_tuple=True, so each execution yields one tuple literal that we
//! decompose and re-bind to next-iteration inputs via the manifest's
//! `feeds_input` indices.
//!
//! The default `xla` dependency is a compile-time stub; real execution
//! needs the actual PJRT crate patched in (see README "PJRT backend").

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use crate::tensorstore::{Dtype, Tensor};

/// A compiled graph plus its manifest.
pub struct LoadedGraph {
    /// Artifact name the graph was loaded from.
    pub name: String,
    /// The compiled PJRT executable.
    pub exe: xla::PjRtLoadedExecutable,
    /// The artifact's manifest (I/O specs, geometry, FLOPs inventory).
    pub manifest: Manifest,
}

/// Engine: one PJRT client + an executable cache keyed by artifact name.
pub struct Engine {
    /// The CPU PJRT client graphs compile against.
    pub client: xla::PjRtClient,
    /// Directory holding `*.hlo.txt` + `*.manifest.json` artifacts.
    pub artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<LoadedGraph>>>,
}

// SAFETY: XLA's PjRtClient and PjRtLoadedExecutable are documented
// thread-safe (execution is internally synchronized); the xla crate's
// wrappers miss auto Send/Sync only because they hold FFI pointers.
unsafe impl Send for LoadedGraph {}
unsafe impl Sync for LoadedGraph {}
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Engine over the discovered artifacts directory. Fails with a typed
    /// [`super::EngineError::ArtifactsMissing`] (downcastable through the
    /// anyhow chain) when no `index.json` exists, so callers can downgrade
    /// to a skip instead of a hard failure.
    pub fn auto() -> Result<Engine> {
        let dir = super::find_artifacts_dir()?;
        Engine::new(dir)
    }

    /// Engine over an explicit artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load (or fetch from cache) the artifact `name`.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedGraph>> {
        if let Some(g) = self.cache.lock().unwrap().get(name) {
            return Ok(g.clone());
        }
        let hlo_path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man_path = self.artifacts_dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man_path)
            .with_context(|| format!("manifest for artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name:?}: {e:?}"))?;
        let g = Arc::new(LoadedGraph { name: name.to_string(), exe, manifest });
        self.cache.lock().unwrap().insert(name.to_string(), g.clone());
        Ok(g)
    }

    /// Initial state tensors (params/opt/bn) for a trainable artifact.
    pub fn load_init(&self, name: &str) -> Result<Vec<(String, Tensor)>> {
        crate::tensorstore::read(self.artifacts_dir.join(format!("{name}.init.tstore")))
    }

    /// Names from artifacts/index.json.
    pub fn list_artifacts(&self) -> Result<Vec<String>> {
        let idx = std::fs::read_to_string(self.artifacts_dir.join("index.json"))?;
        let j = crate::util::json::Json::parse(&idx).map_err(anyhow::Error::msg)?;
        Ok(j.arr_field("artifacts")
            .map_err(anyhow::Error::msg)?
            .iter()
            .filter_map(|a| a.str_field("name").ok().map(str::to_string))
            .collect())
    }
}

impl LoadedGraph {
    /// Execute with inputs in manifest order; returns the decomposed output
    /// tuple as host literals (manifest-output order). Accepts owned
    /// literals or references (state leaves are passed by reference from
    /// the coordinator's hot loop — no per-step deep copies).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest expects {}",
                self.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let bufs = self
            .exe
            .execute(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {}: {e:?}", self.name))?;
        let outs = lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.name))?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest expects {}",
                self.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// host tensor <-> literal bridge
// ---------------------------------------------------------------------------

/// Convert a host [`Tensor`] into an `xla::Literal`.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::I32 => xla::ElementType::S32,
        Dtype::U32 => xla::ElementType::U32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &t.data)
        .map_err(|e| anyhow::anyhow!("literal from tensor: {e:?}"))
}

/// Convert an `xla::Literal` back into a host [`Tensor`].
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let (dtype, data) = match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            (Dtype::F32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            (Dtype::I32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::U32 => {
            let v: Vec<u32> = l.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            (Dtype::U32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        other => bail!("unsupported element type {other:?}"),
    };
    Ok(Tensor { dtype, shape: dims, data })
}

/// f32 literal helpers for hot-path input construction.
pub fn f32_literal(shape: &[usize], vals: &[f32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("f32 literal: {e:?}"))
}

/// i32 literal helper for hot-path input construction.
pub fn i32_literal(shape: &[usize], vals: &[i32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("i32 literal: {e:?}"))
}

/// u32 literal helper for hot-path input construction.
pub fn u32_literal(shape: &[usize], vals: &[u32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("u32 literal: {e:?}"))
}

/// Scalar f32 literal (shape `[]`).
pub fn scalar_f32(v: f32) -> Result<xla::Literal> {
    f32_literal(&[], &[v])
}

/// Read a scalar f32 back out of a literal.
pub fn literal_scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
}
