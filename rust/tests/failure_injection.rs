//! Failure injection: every layer of the runtime must fail loudly and
//! specifically, never silently mis-train. The manifest/tensorstore/
//! scheduler/discovery checks run on every build; engine-level checks need
//! the `pjrt` feature.

use std::io::Write as _;

use ssprop::runtime::{EngineError, Manifest};
use ssprop::tensorstore::{self, Tensor};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ssprop_fail_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn artifacts_discovery_error_is_typed() {
    // On a bare runner there is no artifacts/index.json: the error must be
    // the typed ArtifactsMissing (downcastable through anyhow) so tests and
    // benches can downgrade it to a skip. When artifacts do exist, the
    // discovered directory must actually contain the index.
    match ssprop::runtime::find_artifacts_dir() {
        Ok(dir) => {
            // env override is trusted verbatim; fallback needs the index
            assert!(std::env::var("SSPROP_ARTIFACTS").is_ok() || dir.join("index.json").exists());
        }
        Err(err) => {
            let EngineError::ArtifactsMissing { searched } = &err;
            assert!(!searched.is_empty());
            let any: anyhow::Error = err.clone().into();
            assert!(any.downcast_ref::<EngineError>().is_some());
        }
    }
}

#[test]
fn manifest_parser_rejects_malformed_documents() {
    for bad in [
        "",                                             // empty
        "{",                                            // truncated
        r#"{"name": "x"}"#,                             // missing inputs/outputs
        r#"{"name": "x", "inputs": 3, "outputs": []}"#, // wrong type
    ] {
        assert!(Manifest::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn tensorstore_header_lying_about_offsets_rejected() {
    let d = tmp_dir("tstore");
    let p = d.join("x.tstore");
    tensorstore::write(&p, &[("a".into(), Tensor::from_f32(vec![2], &[1.0, 2.0]))]).unwrap();
    // corrupt: rewrite header with an offset past the payload
    let raw = std::fs::read(&p).unwrap();
    let hlen = u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize;
    let header = String::from_utf8(raw[12..12 + hlen].to_vec()).unwrap();
    let evil = header.replace("\"offset\":0", "\"offset\":9999");
    assert_ne!(header, evil);
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(b"TSTORE01").unwrap();
    f.write_all(&(evil.len() as u32).to_le_bytes()).unwrap();
    f.write_all(evil.as_bytes()).unwrap();
    f.write_all(&raw[12 + hlen..]).unwrap();
    drop(f);
    assert!(tensorstore::read(&p).is_err());
}

#[test]
fn scheduler_rejects_invalid_targets() {
    use ssprop::schedule::{DropScheduler, Schedule};
    for bad in [1.0, 1.5, -0.1] {
        let r = std::panic::catch_unwind(|| DropScheduler::new(Schedule::Constant, bad, 1, 1));
        assert!(r.is_err(), "target {bad} must be rejected");
    }
}

#[test]
fn native_trainer_rejects_bad_configs() {
    use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
    let mut cfg = NativeTrainConfig::quick("cifar10", 1, 1);
    cfg.batch = 0;
    assert!(NativeTrainer::new(cfg).is_err(), "zero batch must be rejected");
    let err = NativeTrainer::new(NativeTrainConfig::quick("celeba", 1, 1))
        .err()
        .expect("BCE dataset must be rejected")
        .to_string();
    assert!(err.contains("CE"), "{err}");
}

// ---------------------------------------------------------------------------
// engine-level injections (PJRT builds only)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_failures {
    use super::tmp_dir;
    use ssprop::runtime::{f32_literal, Engine};

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let d = tmp_dir("missing");
        std::fs::write(d.join("index.json"), r#"{"artifacts": []}"#).unwrap();
        let engine = Engine::new(&d).unwrap();
        let err = engine.load("nope_train").err().expect("must fail").to_string();
        assert!(err.contains("nope_train"), "{err}");
    }

    #[test]
    fn garbage_hlo_text_fails_at_parse_not_execute() {
        let d = tmp_dir("garbage");
        std::fs::write(d.join("bad.hlo.txt"), "this is not hlo").unwrap();
        std::fs::write(
            d.join("bad.manifest.json"),
            r#"{"name": "bad", "inputs": [], "outputs": []}"#,
        )
        .unwrap();
        let engine = Engine::new(&d).unwrap();
        let err = format!("{:?}", engine.load("bad").err().expect("must fail"));
        assert!(err.contains("parse"), "{err}");
    }

    #[test]
    fn wrong_input_count_rejected_before_pjrt() {
        // use the real artifacts if present; otherwise skip
        let Ok(engine) = Engine::auto() else { return };
        let Ok(g) = engine.load("conv_pallas_dense") else { return };
        let one = f32_literal(&[1], &[0.0]).unwrap();
        let err = g.run(&[&one]).err().expect("must fail").to_string();
        assert!(err.contains("expects"), "{err}");
    }

    #[test]
    fn engine_with_bad_dir_fails_lazily_on_use() {
        let d = tmp_dir("empty_dir");
        // Engine::new itself succeeds (lazy); loading must fail
        if let Ok(e) = Engine::new(d.join("does_not_exist")) {
            assert!(e.load("anything").is_err());
            assert!(e.list_artifacts().is_err());
        }
    }
}
