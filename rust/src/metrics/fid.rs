//! FID-proxy (S20): exact Fréchet distance over a *fixed random-projection*
//! feature extractor.
//!
//! The paper reports FID with InceptionV3 features; Inception weights are
//! unavailable offline, so we keep the Fréchet statistic
//!     ||μ₁−μ₂||² + tr(Σ₁+Σ₂−2(Σ₁Σ₂)^½)
//! exact but swap the feature map for a seeded random projection with a
//! tanh nonlinearity (a random 1-layer network). For same-dataset
//! comparisons (dense DDPM vs ssProp DDPM, Table 5) the *ordering* is what
//! matters, and random features preserve distributional distances (they
//! are JL-style embeddings); DESIGN.md §3 documents the substitution.

use crate::metrics::linalg::{sqrtm_psd, Mat};
use crate::util::rng::Pcg;

/// Dimensionality of the random-projection feature space.
pub const FEATURE_DIM: usize = 24;

/// Fixed random-projection feature extractor (deterministic per seed+shape).
pub struct FeatureExtractor {
    input_dim: usize,
    w: Vec<f32>, // (FEATURE_DIM, input_dim)
    b: Vec<f32>,
}

impl FeatureExtractor {
    /// An extractor for flattened images of `input_dim` pixels.
    pub fn new(input_dim: usize, seed: u64) -> FeatureExtractor {
        let mut rng = Pcg::new(seed ^ 0xF1D, 23);
        let scale = (2.0 / input_dim as f32).sqrt();
        let w = (0..FEATURE_DIM * input_dim).map(|_| rng.normal() * scale).collect();
        let b = (0..FEATURE_DIM).map(|_| rng.normal() * 0.1).collect();
        FeatureExtractor { input_dim, w, b }
    }

    /// Project one flattened image into the [`FEATURE_DIM`] feature space.
    pub fn features(&self, img: &[f32]) -> Vec<f64> {
        assert_eq!(img.len(), self.input_dim);
        (0..FEATURE_DIM)
            .map(|k| {
                let mut acc = self.b[k];
                let row = &self.w[k * self.input_dim..(k + 1) * self.input_dim];
                for (w, x) in row.iter().zip(img) {
                    acc += w * x;
                }
                acc.tanh() as f64
            })
            .collect()
    }
}

fn stats(feats: &[Vec<f64>]) -> (Vec<f64>, Mat) {
    let n = feats.len() as f64;
    let d = FEATURE_DIM;
    let mut mu = vec![0.0; d];
    for f in feats {
        for i in 0..d {
            mu[i] += f[i] / n;
        }
    }
    let mut cov = Mat::zeros(d);
    for f in feats {
        for i in 0..d {
            let di = f[i] - mu[i];
            for j in 0..d {
                cov.a[i * d + j] += di * (f[j] - mu[j]) / (n - 1.0).max(1.0);
            }
        }
    }
    cov.symmetrize();
    (mu, cov)
}

/// Fréchet distance between the feature distributions of two image sets.
pub fn fid_proxy(real: &[Vec<f32>], generated: &[Vec<f32>], seed: u64) -> f64 {
    assert!(!real.is_empty() && !generated.is_empty());
    let fx = FeatureExtractor::new(real[0].len(), seed);
    let fr: Vec<Vec<f64>> = real.iter().map(|i| fx.features(i)).collect();
    let fg: Vec<Vec<f64>> = generated.iter().map(|i| fx.features(i)).collect();
    let (mu1, c1) = stats(&fr);
    let (mu2, c2) = stats(&fg);
    let d = FEATURE_DIM;
    let mean_term: f64 = (0..d).map(|i| (mu1[i] - mu2[i]).powi(2)).sum();
    // tr(C1 + C2 - 2 (C1^{1/2} C2 C1^{1/2})^{1/2})
    let s1 = sqrtm_psd(&c1);
    let mut inner = s1.matmul(&c2).matmul(&s1);
    inner.symmetrize();
    let cross = sqrtm_psd(&inner);
    let cov_term = c1.trace() + c2.trace() - 2.0 * cross.trace();
    (mean_term + cov_term).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_images(n: usize, dim: usize, mean: f32, std: f32, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg::new(seed, 3);
        (0..n).map(|_| (0..dim).map(|_| mean + std * rng.normal()).collect()).collect()
    }

    #[test]
    fn identical_distributions_near_zero() {
        let a = gaussian_images(500, 64, 0.0, 1.0, 1);
        let b = gaussian_images(500, 64, 0.0, 1.0, 2);
        let f = fid_proxy(&a, &b, 7);
        // finite-sample covariance noise keeps this > 0; the meaningful
        // invariant is that it stays far below any real distribution shift
        let far = gaussian_images(500, 64, 1.5, 1.0, 3);
        let f_far = fid_proxy(&a, &far, 7);
        assert!(f < 0.2 * f_far, "identical {f} vs shifted {f_far}");
    }

    #[test]
    fn self_distance_is_zero() {
        let a = gaussian_images(100, 64, 0.3, 0.8, 5);
        let f = fid_proxy(&a, &a, 7);
        assert!(f < 1e-9, "fid {f}");
    }

    #[test]
    fn shifted_distribution_scores_worse() {
        let real = gaussian_images(200, 64, 0.0, 1.0, 1);
        let near = gaussian_images(200, 64, 0.1, 1.0, 2);
        let far = gaussian_images(200, 64, 1.5, 1.0, 3);
        let f_near = fid_proxy(&real, &near, 7);
        let f_far = fid_proxy(&real, &far, 7);
        assert!(f_near < f_far, "near {f_near} far {f_far}");
    }

    #[test]
    fn variance_mismatch_detected() {
        let real = gaussian_images(200, 64, 0.0, 1.0, 1);
        let narrow = gaussian_images(200, 64, 0.0, 0.1, 2);
        assert!(fid_proxy(&real, &narrow, 7) > fid_proxy(&real, &real, 7) + 0.1);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gaussian_images(50, 32, 0.0, 1.0, 1);
        let b = gaussian_images(50, 32, 0.5, 1.0, 2);
        assert_eq!(fid_proxy(&a, &b, 9), fid_proxy(&a, &b, 9));
        assert_ne!(fid_proxy(&a, &b, 9), fid_proxy(&a, &b, 10));
    }
}
