//! Tiny CLI argument parser (clap is not in the offline vendor set; S11).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

/// Parsed command line: positionals, `--key value` options, bare `--flag`s.
#[derive(Debug, Default)]
pub struct Args {
    /// Arguments without a leading `--`, in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s (no value followed).
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an argv slice (excluding the program name).
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Args {
        Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    /// Raw value of option `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Value of option `key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// `key` parsed as f64; `default` when absent or unparsable.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `key` parsed as usize; `default` when absent or unparsable.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `key` parsed as u64; `default` when absent or unparsable.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `key` parsed as `T`, *erroring* on an unparsable value instead of
    /// silently falling back (the `get_*` behaviour): `Ok(None)` when
    /// absent, `Err` with a usable message when malformed.
    pub fn try_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                v.parse().map(Some).map_err(|_| format!("--{key} got unparsable value {v:?}"))
            }
        }
    }

    /// Was the bare flag `--name` passed (with no value attached)?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&sv(&["train", "extra", "--lr", "0.1", "--drop=0.8", "--verbose"]));
        assert_eq!(a.positional, sv(&["train", "extra"]));
        assert_eq!(a.get("lr"), Some("0.1"));
        assert_eq!(a.get_f64("drop", 0.0), 0.8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["x"]));
        assert_eq!(a.get_usize("epochs", 5), 5);
        assert_eq!(a.get_or("model", "resnet18"), "resnet18");
    }

    #[test]
    fn try_parse_surfaces_parse_errors() {
        let a = Args::parse(&sv(&["--threads", "4", "--lr", "0.O3", "--target-drop", "0.8"]));
        assert_eq!(a.try_parse::<usize>("threads"), Ok(Some(4)));
        assert_eq!(a.try_parse::<f64>("target-drop"), Ok(Some(0.8)));
        assert_eq!(a.try_parse::<u64>("missing"), Ok(None));
        let err = a.try_parse::<f64>("lr").unwrap_err();
        assert!(err.contains("0.O3") && err.contains("lr"), "{err}");
        let err = a.try_parse::<usize>("lr").unwrap_err();
        assert!(err.contains("lr"), "{err}");
    }

    #[test]
    fn trailing_flag_not_swallowing_positional() {
        let a = Args::parse(&sv(&["--fast", "cmd"]));
        // "--fast cmd": 'cmd' doesn't start with --, so it's taken as value;
        // documented behaviour — flags that precede positionals need `=`.
        assert_eq!(a.get("fast"), Some("cmd"));
    }
}
