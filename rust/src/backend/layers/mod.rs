//! Composable layer-graph model API: the [`Layer`] trait, its concrete
//! building blocks, and the [`Sequential`] container that trains any stack
//! of them through the [`Backend`] trait with ssProp sparsification.
//!
//! The paper's central claim is that scheduled sparse BP is a *module* that
//! drops into any architecture; this subsystem is that claim made concrete
//! on the native path. A [`Layer`] owns its parameters and computes
//! forward/backward over a borrowed per-layer workspace ([`LayerWs`] — the
//! conv plan, pool argmax, dropout mask); [`Sequential`] owns the layer
//! list plus one workspace per layer, drives the drop-rate schedule across
//! every conv layer, applies SGD updates, and reports [`StepStats`] exactly
//! as the historical hand-rolled `SimpleCnn` did. The data-parallel
//! executor ([`crate::backend::parallel`]) runs the same layers over
//! per-worker workspaces with *global* cross-shard channel selection.
//!
//! Numerics contract: a `Sequential` built by
//! [`crate::backend::simple_cnn`] replays the legacy model **bitwise** —
//! each layer's loops are the exact FP operations of the old fused path in
//! the same order (pinned by `rust/tests/layer_graph_equivalence.rs`).

mod act;
mod conv;
mod linear;
mod pool;

pub use act::{Dropout, ReLU};
pub use conv::Conv2dLayer;
pub use linear::{Flatten, Linear};
pub use pool::{AvgPool2d, GlobalAvgPool, MaxPool2d};

use anyhow::{bail, Context, Result};

use super::plan::Conv2dPlan;
use super::{Backend, Conv2d};
use crate::flops::LayerSet;
use crate::tensorstore::Tensor;

/// Per-example activation geometry flowing between layers: NCHW feature
/// maps ([`Shape::Spatial`]) or flattened feature vectors ([`Shape::Flat`]).
/// The batch dimension is carried separately, so one `Shape` describes a
/// layer at any batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// A (C, H, W) feature map (NCHW with the batch dimension stripped).
    Spatial {
        /// Channels.
        c: usize,
        /// Height.
        h: usize,
        /// Width.
        w: usize,
    },
    /// A flat feature vector (classifier head territory).
    Flat {
        /// Feature count.
        features: usize,
    },
}

impl Shape {
    /// Scalar count per example.
    pub fn volume(&self) -> usize {
        match *self {
            Shape::Spatial { c, h, w } => c * h * w,
            Shape::Flat { features } => features,
        }
    }
}

/// Forward-pass context: train/eval mode plus the deterministic stream
/// coordinates stochastic layers (Dropout) key their masks on. Keying on
/// the *global* example index makes a sharded forward reproduce the serial
/// masks exactly, whatever the thread count.
#[derive(Debug, Clone, Copy)]
pub struct FwdCtx {
    /// Training mode (Dropout masks; eval is deterministic identity).
    pub train: bool,
    /// Monotone step counter (one dropout mask stream per step).
    pub step: u64,
    /// Global index of this (sub-)batch's first example.
    pub example_offset: usize,
}

/// How a conv layer's backward chooses its ssProp channels.
#[derive(Debug, Clone, Copy)]
pub enum Selection<'a> {
    /// Select locally from this (sub-)batch's gradient at the given drop
    /// rate — the serial path.
    Local(f64),
    /// Back-propagate exactly these output channels (ascending) — the
    /// data-parallel path, where selection is reduced globally across
    /// shards before any shard runs its backward.
    Keep(&'a [usize]),
}

/// One layer's reusable per-(worker, batch) scratch. A plain struct rather
/// than a per-layer associated type so the executor can own a uniform
/// `Vec<LayerWs>` per worker; unused fields stay empty and cost nothing.
#[derive(Debug, Default)]
pub struct LayerWs {
    /// Conv layers: the plan (im2col cache + backward scratch).
    pub(crate) plan: Option<Conv2dPlan>,
    /// MaxPool: flat input index of each output's argmax, recorded by the
    /// forward and consumed by the backward scatter.
    pub(crate) argmax: Vec<usize>,
    /// Dropout: the scaled keep mask of the current training forward
    /// (empty in eval mode or at rate 0).
    pub(crate) mask: Vec<f32>,
}

impl LayerWs {
    /// Capacity fingerprint of the conv plan, if this workspace holds one
    /// (workspace-reuse tests pin these flat across steps).
    pub fn plan_caps(&self) -> Option<[usize; 7]> {
        self.plan.as_ref().map(|p| p.buffer_caps())
    }

    /// im2col builds of the conv plan, if any.
    pub fn plan_cols_builds(&self) -> u64 {
        self.plan.as_ref().map_or(0, |p| p.cols_builds())
    }
}

/// A named view of one parameter tensor (checkpoint export).
#[derive(Debug)]
pub struct ParamView<'a> {
    /// Field name within the layer ("w", "b").
    pub field: &'static str,
    /// Flattened values.
    pub data: &'a [f32],
    /// Tensor shape.
    pub shape: Vec<usize>,
}

/// What one layer's backward hands back to its container.
#[derive(Debug, Default)]
pub struct BwdOut {
    /// d loss / d input — empty when the caller passed `need_dx = false`.
    pub dx: Vec<f32>,
    /// Parameter gradients, aligned with [`Layer::params_mut`] order
    /// (empty for stateless layers).
    pub grads: Vec<Vec<f32>>,
    /// Output channels actually back-propagated (conv layers; 0 elsewhere).
    pub kept: usize,
}

/// One node of a layer graph: owns its parameters, computes forward and
/// backward over a borrowed [`LayerWs`], and describes its geometry and
/// FLOPs contribution. Implementations must be `Send + Sync` so the
/// data-parallel executor can share the (read-only) layer list across
/// worker threads — all mutable per-step state lives in the workspace.
pub trait Layer: std::fmt::Debug + Send + Sync {
    /// Short human-readable description ("conv3x3/s2 1->8").
    fn describe(&self) -> String;

    /// Output shape for `input`, or an error when the geometry mismatches
    /// what the layer was built for.
    fn out_shape(&self, input: &Shape) -> Result<Shape>;

    /// Key the workspace to batch size `bt` (conv plans re-key in place,
    /// preserving capacity). Default: stateless layers need nothing.
    fn ensure_ws(&self, _ws: &mut LayerWs, _bt: usize) {}

    /// Forward over a batch of `bt` examples; may cache into `ws` whatever
    /// the matching backward needs (im2col columns, argmax, masks).
    fn forward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        ctx: &FwdCtx,
    ) -> Vec<f32>;

    /// Backward: `x` is the same input the last forward saw, `g` is
    /// d loss / d output. `need_dx = false` skips the input-gradient
    /// computation (the first layer of a network never consumes it).
    fn backward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut;

    /// Parameter tensors for checkpointing, in update order.
    fn params(&self) -> Vec<ParamView<'_>> {
        Vec::new()
    }

    /// Mutable parameter arrays, aligned with [`BwdOut::grads`].
    fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        Vec::new()
    }

    /// Restore one parameter field saved via [`Layer::params`].
    fn load_param(&mut self, field: &str, _vals: Vec<f32>) -> Result<()> {
        bail!("layer {:?} has no parameter field {field:?}", self.describe())
    }

    /// Conv layers: the batch-1 geometry (the ssProp selection unit).
    /// `None` for every layer that does not participate in channel
    /// selection.
    fn conv_geom(&self) -> Option<Conv2d> {
        None
    }

    /// Contribute this layer to the Eq. 6–9 FLOPs inventory.
    fn account_flops(&self, _set: &mut LayerSet) {}
}

/// Per-step statistics returned by [`Sequential::train_step`].
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Mean softmax cross-entropy over the batch.
    pub loss: f64,
    /// Fraction of the batch classified correctly.
    pub acc: f64,
    /// Output channels actually back-propagated, summed over conv layers.
    pub kept_channels: usize,
    /// Total output channels over conv layers (kept == total when dense).
    pub total_channels: usize,
}

/// A feed-forward layer graph trained end-to-end through the [`Backend`]
/// trait: owns the layers, one [`LayerWs`] per layer, and the step counter
/// that seeds stochastic layers. The final layer must produce a
/// [`Shape::Flat`] logits vector; the softmax cross-entropy loss lives in
/// the container, not in a layer, exactly as in the historical model.
#[derive(Debug)]
pub struct Sequential {
    /// Resolved model-spec string ("simple-cnn-d2-w8") — display and
    /// checkpoint identity.
    spec: String,
    /// Checkpoint name per layer ("conv0", "fc"; empty = stateless).
    names: Vec<String>,
    layers: Vec<Box<dyn Layer>>,
    /// `shapes[l]` is layer l's input shape; `shapes[len]` the output.
    shapes: Vec<Shape>,
    /// Logit count of the final [`Shape::Flat`] output.
    classes: usize,
    /// Per-layer workspaces for the serial path (the executor owns
    /// per-worker sets instead).
    ws: Vec<LayerWs>,
    /// Monotone train-step counter (dropout mask streams).
    step: u64,
}

impl Sequential {
    /// Build a graph from `(checkpoint name, layer)` pairs, propagating and
    /// validating shapes front to back. The final shape must be flat (the
    /// logits); stateless layers pass an empty name.
    pub fn new(
        spec: impl Into<String>,
        in_shape: Shape,
        parts: Vec<(String, Box<dyn Layer>)>,
    ) -> Result<Sequential> {
        if parts.is_empty() {
            bail!("a model needs at least one layer");
        }
        let mut names = Vec::with_capacity(parts.len());
        let mut layers: Vec<Box<dyn Layer>> = Vec::with_capacity(parts.len());
        let mut shapes = vec![in_shape];
        for (name, layer) in parts {
            let cur = *shapes.last().expect("shapes is never empty");
            let next = layer
                .out_shape(&cur)
                .with_context(|| format!("layer {:?} rejects its input", layer.describe()))?;
            shapes.push(next);
            names.push(name);
            layers.push(layer);
        }
        let classes = match *shapes.last().expect("shapes is never empty") {
            Shape::Flat { features } => features,
            Shape::Spatial { .. } => bail!("the final layer must produce flat logits"),
        };
        let ws = (0..layers.len()).map(|_| LayerWs::default()).collect();
        Ok(Sequential { spec: spec.into(), names, layers, shapes, classes, ws, step: 0 })
    }

    /// The resolved model-spec string this graph was built from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// One-line architecture summary (layer descriptions joined).
    pub fn describe(&self) -> String {
        self.layers.iter().map(|l| l.describe()).collect::<Vec<_>>().join(" > ")
    }

    /// Per-example input shape.
    pub fn in_shape(&self) -> Shape {
        self.shapes[0]
    }

    /// Logit count of the classifier head.
    pub fn out_features(&self) -> usize {
        self.classes
    }

    /// Number of layers in the graph.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Read access to layer `l` (the executor walks the graph this way).
    pub fn layer(&self, l: usize) -> &dyn Layer {
        self.layers[l].as_ref()
    }

    /// Mutable access to layer `l` (the executor applies reduced updates).
    pub fn layer_mut(&mut self, l: usize) -> &mut dyn Layer {
        self.layers[l].as_mut()
    }

    /// Number of conv layers (ssProp-selectable units).
    pub fn conv_count(&self) -> usize {
        self.layers.iter().filter(|l| l.conv_geom().is_some()).count()
    }

    /// Total conv output channels — [`StepStats::total_channels`].
    pub fn total_channels(&self) -> usize {
        self.layers.iter().filter_map(|l| l.conv_geom()).map(|g| g.cout).sum()
    }

    /// Key every layer workspace to batch size `bt` (conv plans re-key in
    /// place, preserving capacity). Called by `train_step`; also useful to
    /// prewarm before a timed loop — and, with the epoch-tail batch size,
    /// to prewarm the tail re-key.
    pub fn ensure_ws(&mut self, bt: usize) {
        for (layer, ws) in self.layers.iter().zip(self.ws.iter_mut()) {
            layer.ensure_ws(ws, bt);
        }
    }

    /// A fresh throwaway workspace set keyed to `bt` (eval has no backward
    /// to reuse caches for, and `&self` keeps eval shareable).
    fn fresh_ws(&self, bt: usize) -> Vec<LayerWs> {
        let mut ws: Vec<LayerWs> = (0..self.layers.len()).map(|_| LayerWs::default()).collect();
        for (layer, w) in self.layers.iter().zip(ws.iter_mut()) {
            layer.ensure_ws(w, bt);
        }
        ws
    }

    /// Advance and return the step counter seeding this step's stochastic
    /// layers. The serial and data-parallel paths both draw from here, so
    /// a sharded step reproduces the serial dropout masks.
    pub(crate) fn begin_step(&mut self) -> u64 {
        let step = self.step;
        self.step += 1;
        step
    }

    /// Forward pass keeping every layer input: `acts[l]` is layer l's
    /// input (`acts[0] = x`), `acts[len]` the logits. Runs through the
    /// workspaces in `ws` — the executor passes per-worker sets so the
    /// identical forward runs per shard without locks.
    pub(crate) fn forward_collect(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut [LayerWs],
        ctx: &FwdCtx,
    ) -> Vec<Vec<f32>> {
        assert_eq!(ws.len(), self.layers.len(), "workspace count");
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.to_vec());
        for (layer, w) in self.layers.iter().zip(ws.iter_mut()) {
            let cur = acts.last().expect("acts is never empty");
            let next = layer.forward(be, cur, bt, w, ctx);
            acts.push(next);
        }
        acts
    }

    /// One SGD training step at `drop_rate`; returns loss/acc/kept-channel
    /// stats. `x` is `(bt, in_shape)` flattened, `y` integer labels. Every
    /// conv layer selects its ssProp channels locally from the batch
    /// gradient (the data-parallel executor substitutes global selection).
    pub fn train_step(
        &mut self,
        be: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        if bt == 0 || x.len() != bt * self.in_shape().volume() {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        self.ensure_ws(bt);
        let step = self.begin_step();
        let ctx = FwdCtx { train: true, step, example_offset: 0 };
        // Take the workspaces out so the forward can borrow them alongside
        // `self` (same dance the legacy model did with its plans).
        let mut ws = std::mem::take(&mut self.ws);
        let acts = self.forward_collect(be, x, bt, &mut ws, &ctx);
        let logits = acts.last().expect("acts is never empty");
        let (loss_sum, correct, dlogits) = softmax_ce_core(logits, y, self.classes, bt);
        let loss = loss_sum / bt as f64;
        let acc = correct as f64 / bt as f64;
        if !loss.is_finite() {
            self.ws = ws;
            bail!("non-finite loss at drop rate {drop_rate}");
        }

        // Backward top-down: each layer computes its gradients on
        // pre-update parameters, then takes its SGD update immediately —
        // updates never feed another layer's backward, so the order only
        // has to be fixed, not clever.
        let mut kept = 0usize;
        let mut g = dlogits;
        for l in (0..self.layers.len()).rev() {
            let out = self.layers[l].backward(
                be,
                &acts[l],
                &g,
                bt,
                &mut ws[l],
                Selection::Local(drop_rate),
                l > 0,
            );
            kept += out.kept;
            for (param, grad) in self.layers[l].params_mut().into_iter().zip(&out.grads) {
                for (pv, &gv) in param.iter_mut().zip(grad) {
                    *pv -= lr * gv;
                }
            }
            if l > 0 {
                g = out.dx;
            }
        }
        self.ws = ws;

        Ok(StepStats { loss, acc, kept_channels: kept, total_channels: self.total_channels() })
    }

    /// Forward-only mean (loss, accuracy) on a batch. Stochastic layers run
    /// in eval mode (Dropout is the identity); workspaces are throwaway.
    pub fn eval_batch(&self, be: &dyn Backend, x: &[f32], y: &[i32]) -> (f64, f64) {
        let bt = y.len();
        let mut ws = self.fresh_ws(bt);
        let ctx = FwdCtx { train: false, step: self.step, example_offset: 0 };
        let acts = self.forward_collect(be, x, bt, &mut ws, &ctx);
        let (losses, correct) = softmax_ce_examples(acts.last().unwrap(), y, self.classes);
        let mut loss_sum = 0f64;
        for &l in &losses {
            loss_sum += l;
        }
        (loss_sum / bt as f64, correct as f64 / bt as f64)
    }

    /// Parameters as named tensors — `param['{name}.{field}']`, the
    /// checkpoint format shared with the AOT path (and bit-compatible with
    /// the legacy SimpleCNN's `conv{l}`/`fc` naming).
    pub fn state_tensors(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (name, layer) in self.names.iter().zip(&self.layers) {
            if name.is_empty() {
                continue;
            }
            for p in layer.params() {
                let key = format!("param['{name}.{}']", p.field);
                out.push((key, Tensor::from_f32(p.shape.clone(), p.data)));
            }
        }
        out
    }

    /// Restore parameters saved by [`Sequential::state_tensors`].
    pub fn load_state_tensors(&mut self, tensors: &[(String, Tensor)]) -> Result<()> {
        for (name, t) in tensors {
            let inner = name
                .strip_prefix("param['")
                .and_then(|r| r.strip_suffix("']"))
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            let (lname, field) = inner
                .split_once('.')
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            let l = self
                .names
                .iter()
                .position(|n| n == lname)
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            self.layers[l]
                .load_param(field, t.to_f32())
                .with_context(|| format!("loading {name:?}"))?;
        }
        Ok(())
    }

    /// Every parameter flattened in checkpoint order (bitwise-comparison
    /// target for the determinism suites).
    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for layer in &self.layers {
            for p in layer.params() {
                out.extend_from_slice(p.data);
            }
        }
        out
    }

    /// Conv + dropout inventory for Eq. 6/9 FLOPs accounting.
    pub fn layer_set(&self) -> LayerSet {
        let mut set = LayerSet::default();
        for layer in &self.layers {
            layer.account_flops(&mut set);
        }
        set
    }

    /// Total im2col materializations across this graph's own workspaces —
    /// advances by exactly [`Sequential::conv_count`] per serial
    /// `train_step` when the fused path is healthy.
    pub fn plan_cols_builds(&self) -> u64 {
        self.ws.iter().map(|w| w.plan_cols_builds()).sum()
    }

    /// Capacity fingerprints of every conv plan, conv order (regression
    /// tests pin these flat across steps).
    pub fn plan_caps(&self) -> Vec<[usize; 7]> {
        self.ws.iter().filter_map(|w| w.plan_caps()).collect()
    }
}

/// Softmax cross-entropy core over integer labels for a (sub-)batch:
/// returns (sum of per-example losses, correct count, d loss / d logits)
/// with `1 / grad_denom` folded into the gradient. The serial step passes
/// `grad_denom = bt`; the data-parallel executor passes the *full* batch
/// size from every shard, so per-shard gradients are already in full-batch
/// units and reduce by plain summation.
pub(crate) fn softmax_ce_core(
    logits: &[f32],
    y: &[i32],
    classes: usize,
    grad_denom: usize,
) -> (f64, usize, Vec<f32>) {
    let bt = y.len();
    // The loss/argmax forward is the per-example routine; summing its
    // losses in example order reproduces the historical accumulation
    // bit-for-bit, and the softmax terms below recompute deterministically.
    let (losses, correct) = softmax_ce_examples(logits, y, classes);
    let mut loss = 0f64;
    for &l in &losses {
        loss += l;
    }
    let mut dlogits = vec![0f32; bt * classes];
    for b in 0..bt {
        let row = &logits[b * classes..][..classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let label = y[b] as usize;
        let drow = &mut dlogits[b * classes..][..classes];
        for (c, &v) in row.iter().enumerate() {
            let p = (v - max).exp() / denom;
            drow[c] = (p - if c == label { 1.0 } else { 0.0 }) / grad_denom as f32;
        }
    }
    (loss, correct, dlogits)
}

/// Per-example softmax cross-entropy (no gradient): returns each example's
/// loss plus the correct count. Shard workers hand these back so the
/// reducer can sum losses in *global example order* — which makes sharded
/// evaluation bit-identical to serial evaluation at any thread count.
pub(crate) fn softmax_ce_examples(logits: &[f32], y: &[i32], classes: usize) -> (Vec<f64>, usize) {
    let bt = y.len();
    let mut losses = Vec::with_capacity(bt);
    let mut correct = 0usize;
    for b in 0..bt {
        let row = &logits[b * classes..][..classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let label = y[b] as usize;
        losses.push((denom.ln() - (row[label] - max)) as f64);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if argmax == label {
            correct += 1;
        }
    }
    (losses, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::util::rng::Pcg;

    fn tiny() -> Sequential {
        let mut rng = Pcg::new(3, 1);
        let parts: Vec<(String, Box<dyn Layer>)> = vec![
            ("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 1, 6, 6, 4, 3, 1, 1))),
            (String::new(), Box::new(ReLU)),
            (String::new(), Box::new(GlobalAvgPool::new(4, 6, 6))),
            ("fc".into(), Box::new(Linear::init(&mut rng, 4, 3))),
        ];
        Sequential::new("tiny", Shape::Spatial { c: 1, h: 6, w: 6 }, parts).unwrap()
    }

    #[test]
    fn shape_propagation_and_metadata() {
        let m = tiny();
        assert_eq!(m.in_shape(), Shape::Spatial { c: 1, h: 6, w: 6 });
        assert_eq!(m.out_features(), 3);
        assert_eq!(m.num_layers(), 4);
        assert_eq!(m.conv_count(), 1);
        assert_eq!(m.total_channels(), 4);
        assert!(m.describe().contains("conv3x3"));
        assert_eq!(m.spec(), "tiny");
    }

    #[test]
    fn rejects_spatial_output_and_geometry_mismatch() {
        let mut rng = Pcg::new(3, 1);
        let spatial_end: Vec<(String, Box<dyn Layer>)> =
            vec![("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 1, 6, 6, 4, 3, 1, 1)))];
        assert!(Sequential::new("bad", Shape::Spatial { c: 1, h: 6, w: 6 }, spatial_end).is_err());

        let mut rng = Pcg::new(3, 1);
        let wrong_in: Vec<(String, Box<dyn Layer>)> =
            vec![("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 2, 6, 6, 4, 3, 1, 1)))];
        assert!(Sequential::new("bad", Shape::Spatial { c: 1, h: 6, w: 6 }, wrong_in).is_err());

        assert!(Sequential::new("empty", Shape::Flat { features: 3 }, Vec::new()).is_err());
    }

    #[test]
    fn train_step_reduces_loss_and_counts_channels() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut rng = Pcg::new(9, 2);
        let x: Vec<f32> = (0..6 * 36).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let first = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert_eq!(first.kept_channels, first.total_channels);
        for _ in 0..20 {
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let last = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
        // sparse step keeps round((1-0.8)*4) = 1 of 4 channels
        let sparse = m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert_eq!(sparse.kept_channels, 1);
        assert_eq!(sparse.total_channels, 4);
    }

    #[test]
    fn train_step_rejects_bad_geometry() {
        let be = NativeBackend::new();
        let mut m = tiny();
        assert!(m.train_step(&be, &[0.0; 5], &[0, 1], 0.0, 0.05).is_err());
        assert!(m.train_step(&be, &[], &[], 0.0, 0.05).is_err());
    }

    #[test]
    fn state_tensor_roundtrip_and_errors() {
        let be = NativeBackend::new();
        let mut a = tiny();
        let mut rng = Pcg::new(11, 4);
        let x: Vec<f32> = (0..4 * 36).map(|_| rng.normal()).collect();
        let y: Vec<i32> = vec![0, 1, 2, 0];
        a.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let saved = a.state_tensors();
        assert_eq!(saved.len(), 4, "conv w/b + fc w/b");
        assert!(saved.iter().any(|(n, _)| n == "param['conv0.w']"));
        assert!(saved.iter().any(|(n, _)| n == "param['fc.b']"));

        let mut b = tiny();
        assert_ne!(a.flat_params(), b.flat_params());
        b.load_state_tensors(&saved).unwrap();
        assert_eq!(a.flat_params(), b.flat_params());
        let (la, _) = a.eval_batch(&be, &x, &y);
        let (lb, _) = b.eval_batch(&be, &x, &y);
        assert_eq!(la, lb);

        let bad = vec![("param['fc.b']".to_string(), Tensor::from_f32(vec![2], &[0.0, 1.0]))];
        assert!(b.load_state_tensors(&bad).is_err(), "shape mismatch must fail");
        let unknown = vec![("param['nope.w']".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(b.load_state_tensors(&unknown).is_err(), "unknown layer must fail");
        let mangled = vec![("weights".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(b.load_state_tensors(&mangled).is_err(), "malformed key must fail");
    }

    #[test]
    fn flops_inventory_lists_convs() {
        let m = tiny();
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 1);
        assert_eq!((set.convs[0].cin, set.convs[0].cout, set.convs[0].k), (1, 4, 3));
        assert!(set.dropouts.is_empty());
    }

    #[test]
    fn softmax_ce_examples_matches_core() {
        let logits = vec![0.3, -0.2, 0.9, 0.1, 0.0, -0.5];
        let y = vec![2, 0];
        let (sum, correct, _) = softmax_ce_core(&logits, &y, 3, 2);
        let (each, correct2) = softmax_ce_examples(&logits, &y, 3);
        assert_eq!(correct, correct2);
        let mut acc = 0f64;
        for &l in &each {
            acc += l;
        }
        assert_eq!(acc, sum, "per-example losses must sum to the core's loss");
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let (losses, _) = softmax_ce_examples(&[0.0, 0.0, 0.0, 0.0], &[1, 0], 2);
        for l in losses {
            assert!((l - (2f64).ln()).abs() < 1e-6);
        }
        let (_, _, d) = softmax_ce_core(&[0.0, 0.0, 0.0, 0.0], &[1, 0], 2, 2);
        assert!((d[0] + d[1]).abs() < 1e-6, "gradient rows sum to zero");
        assert!((d[2] + d[3]).abs() < 1e-6);
    }
}
