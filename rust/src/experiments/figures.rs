//! Figure drivers: paper Fig. 2 (sensitivity), Fig. 3 (samples), Fig. 4
//! (hyperparameter-search reliability). All drivers train through PJRT
//! artifacts (`pjrt` feature); the grid-agreement statistic
//! [`fig4_agreement`] is pure table math and always available.

#[cfg(feature = "pjrt")]
use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::report::f3;
use super::report::Table;
#[cfg(feature = "pjrt")]
use super::{run_classifier, Scale};
#[cfg(feature = "pjrt")]
use crate::ddpm::{write_pgm_grid, DdpmTrainer};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
#[cfg(feature = "pjrt")]
use crate::schedule::{DropScheduler, Schedule};

/// Fig. 2a: sparsified dimension (channel vs hw vs all) over drop rates.
#[cfg(feature = "pjrt")]
pub fn fig2a(engine: &Engine, scale: Scale, rates: &[f64]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 2a — sparsified dimensions vs drop rate (CIFAR-10, ResNet-18, constant schedule)",
        &["Drop rate", "sparse-channel", "sparse-hw", "sparse-all"],
    );
    for &d in rates {
        let mut row = vec![format!("{:.0}%", d * 100.0)];
        for suffix in ["", "_hw", "_all"] {
            let artifact = format!("resnet18_cifar10{suffix}");
            let (_, acc) =
                run_classifier(engine, &artifact, scale, Schedule::Constant, d, 0.0)?;
            row.push(f3(acc));
        }
        t.row(row);
    }
    t.save_json("fig2a");
    Ok(t)
}

/// Fig. 2b: top-k vs random gradient selection.
#[cfg(feature = "pjrt")]
pub fn fig2b(engine: &Engine, scale: Scale, rates: &[f64]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 2b — top-k vs random selection (CIFAR-10, ResNet-18)",
        &["Drop rate", "top-k", "random"],
    );
    for &d in rates {
        let (_, acc_t) =
            run_classifier(engine, "resnet18_cifar10", scale, Schedule::Constant, d, 0.0)?;
        let (_, acc_r) =
            run_classifier(engine, "resnet18_cifar10_random", scale, Schedule::Constant, d, 0.0)?;
        t.row(vec![format!("{:.0}%", d * 100.0), f3(acc_t), f3(acc_r)]);
    }
    t.save_json("fig2b");
    Ok(t)
}

/// Fig. 2c: scheduler shapes (constant / linear / cosine / bar) per target rate.
#[cfg(feature = "pjrt")]
pub fn fig2c(engine: &Engine, scale: Scale, rates: &[f64]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 2c — drop schedulers vs target rate (CIFAR-10, ResNet-18)",
        &["Target rate", "constant", "linear", "cosine", "bar"],
    );
    for &d in rates {
        let mut row = vec![format!("{:.0}%", d * 100.0)];
        for s in [Schedule::Constant, Schedule::Linear, Schedule::Cosine, Schedule::Bar] {
            let (_, acc) = run_classifier(engine, "resnet18_cifar10", scale, s, d, 0.0)?;
            row.push(f3(acc));
        }
        t.row(row);
    }
    t.save_json("fig2c");
    Ok(t)
}

/// Fig. 2d: scheduler period sweep (iteration-periodic bar vs 2-epoch bar).
#[cfg(feature = "pjrt")]
pub fn fig2d(engine: &Engine, scale: Scale, periods: &[usize]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 2d — bar-scheduler period sweep at D*=0.8 (CIFAR-10, ResNet-18)",
        &["Period (iters)", "Test acc"],
    );
    for &p in periods {
        let (_, acc) = run_classifier(
            engine,
            "resnet18_cifar10",
            scale,
            Schedule::IterPeriodic { period: p },
            0.8,
            0.0,
        )?;
        t.row(vec![p.to_string(), f3(acc)]);
    }
    // the paper's deployed 2-epoch period
    let (_, acc) = run_classifier(
        engine,
        "resnet18_cifar10",
        scale,
        Schedule::EpochBar { period_epochs: 2 },
        0.8,
        0.0,
    )?;
    t.row(vec!["2 epochs".into(), f3(acc)]);
    t.save_json("fig2d");
    Ok(t)
}

/// Fig. 3: DDPM sample grids -> results/fig3_<dataset>.pgm.
#[cfg(feature = "pjrt")]
pub fn fig3(engine: &Engine, scale: Scale, datasets: &[&str]) -> Result<Vec<String>> {
    let mut written = Vec::new();
    std::fs::create_dir_all("results")?;
    for &ds in datasets {
        let mut tr = DdpmTrainer::new(engine, ds, scale.lr, scale.seed)?;
        let sched = DropScheduler::paper_default(scale.epochs, scale.iters_per_epoch);
        tr.train(scale.epochs * scale.iters_per_epoch, &sched)?;
        let samples = tr.sample(scale.seed + 7)?;
        let man = &tr.denoise_graph.manifest.clone();
        let path = format!("results/fig3_{ds}.pgm");
        write_pgm_grid(&path, &samples, man.img, man.channels)?;
        written.push(path);
    }
    Ok(written)
}

/// Fig. 4: depth x learning-rate reliability grid, dense vs sparse.
#[cfg(feature = "pjrt")]
pub fn fig4(
    engine: &Engine,
    scale: Scale,
    depths: &[usize],
    lrs: &[f64],
) -> Result<(Table, Table)> {
    let run = |sparse: bool| -> Result<Table> {
        let title = if sparse {
            "Fig 4 (sparse mode) — test acc, SimpleCNN depth x LR on CIFAR-100"
        } else {
            "Fig 4 (normal mode) — test acc, SimpleCNN depth x LR on CIFAR-100"
        };
        let mut headers = vec!["depth \\ lr".to_string()];
        headers.extend(lrs.iter().map(|l| format!("{l:.0e}")));
        let mut t = Table::new(title, &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for &d in depths {
            let mut row = vec![d.to_string()];
            for &lr in lrs {
                let mut sc = scale;
                sc.lr = lr;
                let (schedule, target) = if sparse {
                    (Schedule::EpochBar { period_epochs: 2 }, 0.8)
                } else {
                    (Schedule::Constant, 0.0)
                };
                let (_, acc) =
                    run_classifier(engine, &format!("cnn{d}_cifar100"), sc, schedule, target, 0.0)?;
                row.push(f3(acc));
            }
            t.row(row);
        }
        t.save_json(if sparse { "fig4_sparse" } else { "fig4_normal" });
        Ok(t)
    };
    Ok((run(false)?, run(true)?))
}

/// Correlation between the two Fig. 4 grids (the paper's reliability claim:
/// the best hyperparameters agree between modes).
pub fn fig4_agreement(normal: &Table, sparse: &Table) -> (usize, usize, f64) {
    let parse = |t: &Table| -> Vec<f64> {
        t.rows.iter().flat_map(|r| r[1..].iter().filter_map(|c| c.parse().ok())).collect()
    };
    let a = parse(normal);
    let b = parse(sparse);
    let argmax = |v: &[f64]| {
        v.iter().enumerate().max_by(|x, y| x.1.total_cmp(y.1)).map(|(i, _)| i).unwrap_or(0)
    };
    let (ia, ib) = (argmax(&a), argmax(&b));
    // Pearson correlation of the two accuracy surfaces
    let n = a.len().min(b.len()) as f64;
    let (ma, mb) = (a.iter().sum::<f64>() / n, b.iter().sum::<f64>() / n);
    let cov: f64 = a.iter().zip(&b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
    let corr = cov / (va.sqrt() * vb.sqrt()).max(1e-12);
    (ia, ib, corr)
}
