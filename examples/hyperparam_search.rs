//! Hyperparameter-search reliability (paper Fig. 4 + the R&D energy claim):
//! sweep SimpleCNN depth x learning rate in normal and sparse modes, check
//! that the best cell agrees, and project the energy the sparse search saved.
//!
//! Requires `--features pjrt` + artifacts (`make artifacts`):
//!
//! ```bash
//! cargo run --release --features pjrt --example hyperparam_search -- --epochs 4 --iters 16
//! ```

use anyhow::Result;

#[cfg(feature = "pjrt")]
mod pjrt_example {
    use anyhow::Result;
    use ssprop::energy::{estimate, RTX_A5000};
    use ssprop::experiments::{figures, Scale};
    use ssprop::runtime::Engine;
    use ssprop::util::cli::Args;

    pub fn run() -> Result<()> {
        let args = Args::from_env();
        let engine = Engine::auto()?;
        let scale = Scale {
            epochs: args.get_usize("epochs", 4),
            iters_per_epoch: args.get_usize("iters", 12),
            seed: args.get_u64("seed", 0),
            lr: 1e-3,
        };
        let depths = [2usize, 4, 6];
        let lrs = [4e-4, 1.6e-3, 6.4e-3];

        println!("== Fig 4: hyperparameter search reliability (SimpleCNN on synth-CIFAR-100) ==");
        let (normal, sparse) = figures::fig4(&engine, scale, &depths, &lrs)?;
        normal.print();
        sparse.print();

        let (ia, ib, corr) = figures::fig4_agreement(&normal, &sparse);
        let cell = |i: usize| (depths[i / lrs.len()], lrs[i % lrs.len()]);
        let (dn, ln) = cell(ia);
        let (ds, ls) = cell(ib);
        println!("\nbest normal cell: depth {dn}, lr {ln:.1e}");
        println!("best sparse cell: depth {ds}, lr {ls:.1e}");
        println!("accuracy-surface correlation: {corr:.3}");
        println!(
            "reliability: {}",
            if ia == ib { "EXACT agreement (paper's claim)" } else { "adjacent cells" }
        );

        // R&D-phase saving: the sparse search spends ~40% fewer backward FLOPs
        // per run; at the paper's CIFAR-100 ResNet-50 scale that is
        let runs = depths.len() * lrs.len();
        let paper_run_flops = 65.41e15; // Table 4 total, CIFAR-10 ResNet-50
        let saved = estimate(runs as f64 * paper_run_flops * 0.4, &RTX_A5000);
        println!(
            "\nprojected R&D saving for this {runs}-run search at paper scale: \
             {:.1} kWh / {:.0} gCO2e",
            saved.kwh, saved.gco2e
        );
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn run() -> Result<()> {
    pjrt_example::run()
}

#[cfg(not(feature = "pjrt"))]
fn run() -> Result<()> {
    println!("hyperparam_search drives PJRT artifacts; rebuild with --features pjrt");
    println!("(for a native sweep, try: cargo run -- train-native --dataset cifar100 --depth 4)");
    Ok(())
}

fn main() -> Result<()> {
    run()
}
