//! BatchNorm2d: per-channel batch normalization over NCHW feature maps —
//! the layer the paper's ResNet tables train through (its Eq. 7 backward
//! cost is what the ledger's `counted_bn` flag accounts).
//!
//! Training mode normalizes with *batch* statistics and folds them into
//! running statistics (checkpointed under the stable field names `rm` /
//! `rv`); eval mode normalizes with the running statistics, making
//! evaluation per-example and therefore shardable bit-identically. The
//! backward is the exact gradient *through* the batch statistics — which
//! needs per-channel sums over the whole batch, so the layer exposes the
//! [`Layer::fwd_stat_partials`] / [`Layer::bwd_stat_partials`] protocol:
//! the data-parallel executor reduces the partials across shards (fixed
//! shard order, at the same barrier rendezvous channel selection uses)
//! and every shard normalizes/back-propagates with the identical global
//! sums — one shard reproduces the serial arithmetic bitwise.

use anyhow::{bail, Result};

use super::{BwdOut, FwdCtx, Layer, LayerWs, ParamView, Selection, Shape};
use crate::backend::Backend;

/// Per-channel batch normalization over `(c, h, w)` feature maps:
/// `y = γ·x̂ + β` with `x̂ = (x − μ)/√(σ² + ε)`. Learned scale/shift start
/// at γ = 1, β = 0; running statistics at μ = 0, σ² = 1.
#[derive(Debug, Clone)]
pub struct BatchNorm2d {
    c: usize,
    h: usize,
    w: usize,
    /// Variance regularizer ε (1e-5, the standard default).
    eps: f32,
    /// Running-statistics update weight (0.1): `r ← (1−m)·r + m·batch`.
    momentum: f32,
    /// Learned per-channel scale γ.
    gamma: Vec<f32>,
    /// Learned per-channel shift β.
    beta: Vec<f32>,
    /// Running mean (eval-mode μ), updated once per training step.
    running_mean: Vec<f32>,
    /// Running variance (eval-mode σ², unbiased), updated once per step.
    running_var: Vec<f32>,
}

impl BatchNorm2d {
    /// A batch-norm layer over `(c, h, w)` feature maps with the standard
    /// ε = 1e-5 and running-stat momentum 0.1.
    pub fn new(c: usize, h: usize, w: usize) -> BatchNorm2d {
        assert!(c >= 1 && h >= 1 && w >= 1, "degenerate batchnorm geometry");
        BatchNorm2d {
            c,
            h,
            w,
            eps: 1e-5,
            momentum: 0.1,
            gamma: vec![1f32; c],
            beta: vec![0f32; c],
            running_mean: vec![0f32; c],
            running_var: vec![1f32; c],
        }
    }

    /// Channel count.
    pub fn channels(&self) -> usize {
        self.c
    }

    /// Per-channel affine factors `(scale, shift)` that reproduce this
    /// layer's *eval* forward as `y = scale·x + shift`:
    /// `scale = γ/√(rv+ε)`, `shift = β − rm·scale` — computed with the
    /// layer's own ε and the same FP operations the eval path uses, so
    /// BN folding ([`crate::backend::fold`]) inherits its numerics.
    pub fn fold_factors(&self) -> (Vec<f32>, Vec<f32>) {
        let mut scale = vec![0f32; self.c];
        let mut shift = vec![0f32; self.c];
        for ch in 0..self.c {
            let inv = 1.0 / (self.running_var[ch] + self.eps).sqrt();
            scale[ch] = self.gamma[ch] * inv;
            shift[ch] = self.beta[ch] - self.running_mean[ch] * scale[ch];
        }
        (scale, shift)
    }

    fn hw(&self) -> usize {
        self.h * self.w
    }
}

impl Layer for BatchNorm2d {
    fn describe(&self) -> String {
        format!("bn{}", self.c)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        match *input {
            Shape::Spatial { c, h, w } if (c, h, w) == (self.c, self.h, self.w) => Ok(*input),
            other => {
                let want = (self.c, self.h, self.w);
                bail!("bn built for {want:?} input, got {other:?}")
            }
        }
    }

    fn forward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        ctx: &FwdCtx,
    ) -> Vec<f32> {
        if ctx.train {
            // Serial path: this batch *is* the global batch. Routing
            // through the partials keeps one executor shard bitwise equal
            // to the serial computation.
            let partials = self.fwd_stat_partials(x, bt);
            return self.forward_with_stats(be, x, bt, ws, ctx, &partials, bt);
        }
        // Eval: running-statistics normalization, per-example (shardable
        // bit-identically). Clear the training caches so a stray commit
        // after an eval forward is a no-op.
        ws.stats.clear();
        ws.xhat.clear();
        let (c, hw) = (self.c, self.hw());
        assert_eq!(x.len(), bt * c * hw, "bn input length");
        let mut y = vec![0f32; x.len()];
        for b in 0..bt {
            for ch in 0..c {
                let base = (b * c + ch) * hw;
                let inv = 1.0 / (self.running_var[ch] + self.eps).sqrt();
                let (mu, ga, be_) = (self.running_mean[ch], self.gamma[ch], self.beta[ch]);
                for i in 0..hw {
                    y[base + i] = ga * (x[base + i] - mu) * inv + be_;
                }
            }
        }
        y
    }

    fn backward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        // Serial path: local gradient sums are the global ones.
        let partials = self.bwd_stat_partials(g, bt, ws);
        self.backward_with_stats(be, x, g, bt, ws, &partials, &partials, need_dx)
    }

    fn params(&self) -> Vec<ParamView<'_>> {
        vec![
            ParamView { field: "w", data: &self.gamma, shape: vec![self.c] },
            ParamView { field: "b", data: &self.beta, shape: vec![self.c] },
            ParamView { field: "rm", data: &self.running_mean, shape: vec![self.c] },
            ParamView { field: "rv", data: &self.running_var, shape: vec![self.c] },
        ]
    }

    fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        // Learned parameters only — running statistics are not updated by
        // SGD (they fold in through commit_stats).
        vec![&mut self.gamma, &mut self.beta]
    }

    fn load_param(&mut self, field: &str, vals: Vec<f32>) -> Result<()> {
        let dst = match field {
            "w" => &mut self.gamma,
            "b" => &mut self.beta,
            "rm" => &mut self.running_mean,
            "rv" => &mut self.running_var,
            other => bail!("unknown bn field {other:?}"),
        };
        if dst.len() != vals.len() {
            bail!("shape mismatch: {} vs {}", vals.len(), dst.len());
        }
        *dst = vals;
        Ok(())
    }

    // No `account_flops` override: the Eq. 7 BN cost is keyed on the conv
    // this layer normalizes, which only the graph knows — `Graph::layer_set`
    // resolves the conv producing this node's input slot and marks its
    // `counted_bn` (projection shortcuts stay uncounted, mirroring
    // `flops::paper_resnet`).

    fn needs_batch_stats(&self) -> bool {
        true
    }

    fn bn_fold_factors(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        Some(self.fold_factors())
    }

    fn fwd_stat_partials(&self, x: &[f32], bt: usize) -> Vec<f32> {
        let (c, hw) = (self.c, self.hw());
        assert_eq!(x.len(), bt * c * hw, "bn input length");
        let mut p = vec![0f32; 2 * c];
        for b in 0..bt {
            for ch in 0..c {
                let plane = &x[(b * c + ch) * hw..][..hw];
                let (mut s, mut s2) = (0f32, 0f32);
                for &v in plane {
                    s += v;
                    s2 += v * v;
                }
                p[ch] += s;
                p[c + ch] += s2;
            }
        }
        p
    }

    fn forward_with_stats(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        _ctx: &FwdCtx,
        partials: &[f32],
        examples: usize,
    ) -> Vec<f32> {
        let (c, hw) = (self.c, self.hw());
        assert_eq!(x.len(), bt * c * hw, "bn input length");
        assert_eq!(partials.len(), 2 * c, "bn partials length");
        let n = examples * hw;
        let nf = n as f32;
        ws.stats.clear();
        ws.stats.resize(2 * c, 0.0);
        ws.stat_count = n;
        let mut invstd = vec![0f32; c];
        for ch in 0..c {
            let mean = partials[ch] / nf;
            // E[x²] − E[x]² (clamped: cancellation can dip just below 0)
            let var = (partials[c + ch] / nf - mean * mean).max(0.0);
            ws.stats[ch] = mean;
            ws.stats[c + ch] = var;
            invstd[ch] = 1.0 / (var + self.eps).sqrt();
        }
        ws.xhat.clear();
        ws.xhat.resize(x.len(), 0.0);
        let mut y = vec![0f32; x.len()];
        for b in 0..bt {
            for ch in 0..c {
                let base = (b * c + ch) * hw;
                let (mu, inv) = (ws.stats[ch], invstd[ch]);
                let (ga, be_) = (self.gamma[ch], self.beta[ch]);
                for i in 0..hw {
                    let xh = (x[base + i] - mu) * inv;
                    ws.xhat[base + i] = xh;
                    y[base + i] = ga * xh + be_;
                }
            }
        }
        y
    }

    fn bwd_stat_partials(&self, g: &[f32], bt: usize, ws: &LayerWs) -> Vec<f32> {
        let (c, hw) = (self.c, self.hw());
        assert_eq!(ws.xhat.len(), g.len(), "bn backward without a training forward");
        assert_eq!(g.len(), bt * c * hw, "bn gradient length");
        let mut p = vec![0f32; 2 * c];
        for b in 0..bt {
            for ch in 0..c {
                let base = (b * c + ch) * hw;
                let (mut sg, mut sgx) = (0f32, 0f32);
                for i in 0..hw {
                    sg += g[base + i];
                    sgx += g[base + i] * ws.xhat[base + i];
                }
                p[ch] += sg;
                p[c + ch] += sgx;
            }
        }
        p
    }

    fn backward_with_stats(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        partials: &[f32],
        local_partials: &[f32],
        need_dx: bool,
    ) -> BwdOut {
        let (c, hw) = (self.c, self.hw());
        assert_eq!(x.len(), bt * c * hw, "bn input length");
        assert_eq!(partials.len(), 2 * c, "bn gradient partials length");
        assert_eq!(local_partials.len(), 2 * c, "bn local partials length");
        assert!(ws.stat_count > 0, "bn backward without a training forward");
        // This shard's own sums are the gradient *partials* of γ and β —
        // dβ = Σg, dγ = Σ(g·x̂) — which the executor's fixed-order tree
        // reduction sums to the global gradient (serial: local = global).
        // The caller already computed them to publish for reduction, so
        // they arrive as an argument instead of being recomputed here.
        let dbeta = local_partials[..c].to_vec();
        let dgamma = local_partials[c..].to_vec();
        let dx = if need_dx {
            // Exact gradient through the batch statistics:
            //   dx = γ·σ̂⁻¹·(g − Σg/N − x̂·Σ(g·x̂)/N)
            // with the Σ over the *global* batch (the reduced partials).
            let nf = ws.stat_count as f32;
            let mut dx = vec![0f32; g.len()];
            for b in 0..bt {
                for ch in 0..c {
                    let base = (b * c + ch) * hw;
                    let inv = 1.0 / (ws.stats[c + ch] + self.eps).sqrt();
                    let scale = self.gamma[ch] * inv;
                    let k1 = partials[ch] / nf;
                    let k2 = partials[c + ch] / nf;
                    for i in 0..hw {
                        dx[base + i] = scale * (g[base + i] - k1 - ws.xhat[base + i] * k2);
                    }
                }
            }
            dx
        } else {
            Vec::new()
        };
        BwdOut { dx, grads: vec![dgamma, dbeta], kept: 0 }
    }

    fn commit_stats(&mut self, ws: &LayerWs) {
        if ws.stats.is_empty() {
            return;
        }
        let c = self.c;
        debug_assert_eq!(ws.stats.len(), 2 * c, "bn stats length");
        let m = self.momentum;
        let n = ws.stat_count as f32;
        for ch in 0..c {
            let mean = ws.stats[ch];
            // Running variance uses the unbiased estimator (PyTorch
            // semantics); the normalization itself stays biased.
            let var = ws.stats[c + ch];
            let var_u = if ws.stat_count > 1 { var * n / (n - 1.0) } else { var };
            self.running_mean[ch] = (1.0 - m) * self.running_mean[ch] + m * mean;
            self.running_var[ch] = (1.0 - m) * self.running_var[ch] + m * var_u;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::util::rng::Pcg;

    fn ctx(train: bool) -> FwdCtx {
        FwdCtx { train, step: 0, example_offset: 0 }
    }

    fn data(bt: usize, c: usize, hw: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed, 1);
        (0..bt * c * hw).map(|_| rng.normal() * 1.5 + 0.3).collect()
    }

    #[test]
    fn training_forward_normalizes_per_channel() {
        let be = NativeBackend::new();
        let bn = BatchNorm2d::new(2, 3, 3);
        let x = data(4, 2, 9, 7);
        let mut ws = LayerWs::default();
        let y = bn.forward(&be, &x, 4, &mut ws, &ctx(true));
        // with γ=1, β=0 the output is x̂: per-channel mean ≈ 0, var ≈ 1
        for ch in 0..2 {
            let vals: Vec<f32> = (0..4).flat_map(|b| y[(b * 2 + ch) * 9..][..9].to_vec()).collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / vals.len() as f32;
            assert!(mean.abs() < 1e-5, "ch {ch} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "ch {ch} var {var}");
        }
        assert_eq!(ws.stat_count, 4 * 9);
        assert_eq!(ws.xhat, y, "γ=1, β=0 ⇒ y = x̂");
    }

    #[test]
    fn eval_forward_uses_running_stats_and_is_identityish_at_init() {
        let be = NativeBackend::new();
        let mut bn = BatchNorm2d::new(1, 2, 2);
        let x = vec![1.0, -2.0, 0.5, 3.0];
        let mut ws = LayerWs::default();
        // init running stats: μ=0, σ²=1 → y ≈ x (ε-scaled)
        let y = bn.forward(&be, &x, 1, &mut ws, &ctx(false));
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!(ws.stats.is_empty(), "eval must not record batch stats");
        // loaded running stats change eval: μ=1, σ²=4 → y = (x−1)/2ish
        bn.load_param("rm", vec![1.0]).unwrap();
        bn.load_param("rv", vec![4.0]).unwrap();
        let y = bn.forward(&be, &x, 1, &mut ws, &ctx(false));
        assert!((y[0] - 0.0).abs() < 1e-4, "{}", y[0]);
        assert!((y[3] - 1.0).abs() < 1e-4, "{}", y[3]);
    }

    #[test]
    fn commit_folds_batch_stats_into_running_stats() {
        let be = NativeBackend::new();
        let mut bn = BatchNorm2d::new(1, 1, 2);
        let x = vec![1.0, 3.0, 5.0, 7.0]; // bt 2: mean 4, biased var 5
        let mut ws = LayerWs::default();
        bn.forward(&be, &x, 2, &mut ws, &ctx(true));
        assert!((ws.stats[0] - 4.0).abs() < 1e-6);
        assert!((ws.stats[1] - 5.0).abs() < 1e-5);
        bn.commit_stats(&ws);
        // rm = 0.9·0 + 0.1·4; rv = 0.9·1 + 0.1·(5·4/3)
        assert!((bn.running_mean[0] - 0.4).abs() < 1e-6, "{}", bn.running_mean[0]);
        assert!((bn.running_var[0] - (0.9 + 0.1 * 5.0 * 4.0 / 3.0)).abs() < 1e-5);
        // eval-cleared stats make a second commit a no-op
        bn.forward(&be, &x, 2, &mut ws, &ctx(false));
        let rm = bn.running_mean[0];
        bn.commit_stats(&ws);
        assert_eq!(bn.running_mean[0], rm);
    }

    #[test]
    fn backward_matches_numeric_gradient_through_batch_stats() {
        let be = NativeBackend::new();
        let mut bn = BatchNorm2d::new(2, 2, 2);
        bn.load_param("w", vec![1.3, 0.7]).unwrap();
        bn.load_param("b", vec![0.2, -0.1]).unwrap();
        let bt = 3;
        let x = data(bt, 2, 4, 11);
        let gw: Vec<f32> = data(bt, 2, 4, 13); // fixed upstream gradient
        let loss = |bn: &BatchNorm2d, x: &[f32]| -> f64 {
            let mut ws = LayerWs::default();
            let y = bn.forward(&be, x, bt, &mut ws, &ctx(true));
            y.iter().zip(&gw).map(|(&yv, &gv)| (yv as f64) * (gv as f64)).sum()
        };
        let mut ws = LayerWs::default();
        bn.forward(&be, &x, bt, &mut ws, &ctx(true));
        let out = bn.backward(&be, &x, &gw, bt, &mut ws, Selection::Local(0.0), true);
        // numeric check on a spread of input coordinates
        let eps = 1e-2f32;
        for &i in &[0usize, 5, 11, 17, 23] {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[i] += eps;
            xm[i] -= eps;
            let num = (loss(&bn, &xp) - loss(&bn, &xm)) / (2.0 * eps as f64);
            let ana = out.dx[i] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "dx[{i}]: numeric {num} vs analytic {ana}"
            );
        }
        // parameter gradients: dβ = Σg, dγ = Σ(g·x̂)
        let sums = bn.bwd_stat_partials(&gw, bt, &ws);
        assert_eq!(out.grads[0], sums[2..].to_vec(), "dγ");
        assert_eq!(out.grads[1], sums[..2].to_vec(), "dβ");
        // need_dx = false skips dx but keeps the parameter gradients
        let skipped = bn.backward(&be, &x, &gw, bt, &mut ws, Selection::Local(0.0), false);
        assert!(skipped.dx.is_empty());
        assert_eq!(skipped.grads, out.grads);
    }

    #[test]
    fn param_roundtrip_and_metadata() {
        let mut bn = BatchNorm2d::new(3, 2, 2);
        assert_eq!(bn.describe(), "bn3");
        assert_eq!(bn.channels(), 3);
        let ps = bn.params();
        assert_eq!(ps.len(), 4);
        let fields: Vec<&str> = ps.iter().map(|p| p.field).collect();
        assert_eq!(fields, vec!["w", "b", "rm", "rv"]);
        assert!(bn.params_mut().len() == 2, "SGD updates γ/β only");
        assert!(bn.load_param("w", vec![1.0]).is_err(), "wrong length must fail");
        assert!(bn.load_param("nope", vec![1.0; 3]).is_err());
        bn.load_param("rv", vec![2.0; 3]).unwrap();
        assert_eq!(bn.params()[3].data, &[2.0, 2.0, 2.0][..]);
        let out = bn.out_shape(&Shape::Spatial { c: 3, h: 2, w: 2 }).unwrap();
        assert_eq!(out, Shape::Spatial { c: 3, h: 2, w: 2 });
        assert!(bn.out_shape(&Shape::Spatial { c: 2, h: 2, w: 2 }).is_err());
        assert!(bn.out_shape(&Shape::Flat { features: 12 }).is_err());
        assert!(bn.needs_batch_stats());
    }

    #[test]
    fn fold_factors_reproduce_eval_forward() {
        let be = NativeBackend::new();
        let mut bn = BatchNorm2d::new(2, 2, 2);
        bn.load_param("w", vec![1.3, 0.7]).unwrap();
        bn.load_param("b", vec![0.2, -0.1]).unwrap();
        bn.load_param("rm", vec![0.5, -1.2]).unwrap();
        bn.load_param("rv", vec![2.0, 0.3]).unwrap();
        let x = data(3, 2, 4, 23);
        let mut ws = LayerWs::default();
        let y = bn.forward(&be, &x, 3, &mut ws, &ctx(false));
        let (scale, shift) = bn.fold_factors();
        let (c, hw) = (2usize, 4usize);
        for b in 0..3 {
            for ch in 0..c {
                let base = (b * c + ch) * hw;
                for i in 0..hw {
                    let want = scale[ch] * x[base + i] + shift[ch];
                    let got = y[base + i];
                    assert!(
                        (want - got).abs() < 1e-6 * (1.0 + got.abs()),
                        "fold factors must match eval: {want} vs {got}"
                    );
                }
            }
        }
        assert!(bn.bn_fold_factors().is_some(), "BN advertises foldability");
    }

    #[test]
    fn shard_partials_sum_to_full_batch_partials() {
        let bn = BatchNorm2d::new(2, 2, 2);
        let x = data(4, 2, 4, 19);
        let full = bn.fwd_stat_partials(&x, 4);
        let a = bn.fwd_stat_partials(&x[..2 * 8], 2);
        let b = bn.fwd_stat_partials(&x[2 * 8..], 2);
        for i in 0..full.len() {
            assert!((full[i] - (a[i] + b[i])).abs() < 1e-4, "partial {i}");
        }
    }
}
