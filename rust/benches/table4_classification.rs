//! Bench for paper Table 4: per-iteration training cost, dense vs ssProp,
//! across the classification artifacts, plus the analytic FLOPs columns
//! (which match the paper exactly at full width — see `ssprop flops`).
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench table4_classification --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::flops::paper_resnet;
    use ssprop::runtime::Engine;
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping table4_classification: {err}");
                return;
            }
        };
        println!("== Table 4 bench: step latency + analytic FLOPs, dense vs ssProp ==\n");

        for (artifact, arch, img, in_ch, paper_bt, paper_dense, paper_ss) in [
            ("resnet18_mnist", "resnet18", 28, 1, 128, 234.10, 140.79),
            ("resnet18_cifar10", "resnet18", 32, 3, 128, 285.32, 171.61),
            ("resnet50_cifar10", "resnet50", 32, 3, 128, 669.75, 404.18),
        ] {
            let mut t = Trainer::new(&engine, TrainConfig::quick(artifact, 1, 1)).unwrap();
            let order = t.loader.epoch_order(0);
            let batch = t.loader.batch(&order, 0);

            for (mode, d) in [("dense", 0.0f64), ("ssprop_d80", 0.8)] {
                let r = bench(
                    &format!("{artifact}/{mode}/step"),
                    2,
                    20,
                    Duration::from_secs(8),
                    || {
                        t.step(&batch, d).unwrap();
                    },
                );
                report(&r);
            }
            let full = paper_resnet(arch, img, in_ch, 1.0);
            println!(
                "  analytic B/iter @bs{paper_bt}: dense {:.2} (paper {paper_dense}), \
                 ssProp {:.2} (paper {paper_ss})\n",
                full.bwd_flops_per_iter(paper_bt, 0.0) / 1e9,
                full.bwd_flops_scheduled(paper_bt, &[0.0, 0.8]) / 1e9,
            );
        }
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!(
        "skipping table4_classification: PJRT runtime not compiled (build with --features pjrt)"
    );
}

fn main() {
    run();
}
