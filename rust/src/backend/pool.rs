//! Persistent worker pool: the production execution layer.
//!
//! [`super::parallel::ParallelExecutor`] spawns and joins a fresh
//! `std::thread::scope` crew on every step, so small presets pay thread
//! spawn + cold-start costs per step that can dwarf the sparse-backward
//! win itself. [`WorkerPool`] keeps the crew alive for the lifetime of a
//! trainer or server and feeds it jobs over channels, amortizing that
//! overhead to zero while running the *identical* shard protocol — the
//! worker body, reductions, and epilogue are the shared `pub(crate)`
//! functions in [`super::parallel`], so pooled steps are bit-identical
//! to scoped-crew steps by construction (and t=1 stays bitwise-equal to
//! the serial [`Graph::train_step`] path). The gated
//! `native/pool_speedup_*` bench lines track the amortization.
//!
//! ## Job/reply shape
//!
//! Each worker owns one `mpsc` job channel (jobs are pinned to worker
//! slots, because worker *w* owns the per-node workspace set
//! `worker_ws[w]` and must be the thread that mutates it) and runs a
//! trivial loop: receive a job, run it, repeat. A step dispatches one
//! job per shard and blocks on a per-step reply channel until every
//! worker has answered; replies carry the worker index plus the job's
//! panic payload, if any. This request/reply message shape is the
//! in-process rehearsal for the ROADMAP's coordinator/worker cluster
//! mode, where the same jobs go cross-process.
//!
//! Jobs borrow the step's stack frame (the batch, the rendezvous slots,
//! the output slots), which an `mpsc` channel cannot express — senders
//! require `'static` payloads. [`dispatch`] therefore erases the job's
//! lifetime with the classic scoped-pool `transmute`, and contains the
//! unsafety by construction: it does not return until every dispatched
//! job has replied, and a reply is sent strictly *after* the borrowed
//! body has finished running (panicked or not), so no borrow ever
//! outlives its referent. If a channel endpoint dies while borrowed jobs
//! may still be live — a worker thread gone missing mid-step — the
//! process aborts rather than risk unwinding past live borrows.
//!
//! ## Panic discipline
//!
//! A worker body that panics (a backend invariant violation) unwinds
//! through the same `BarrierAttendance` guard the scoped crew uses, so
//! its peers are never stranded on a barrier; the job wrapper catches
//! the unwind, ships the payload back on the reply channel, and the
//! worker thread survives to serve the next step. [`dispatch`] re-raises
//! the lowest-indexed worker's payload on the calling thread
//! (deterministic when several shards fail at once), so a mid-step fault
//! propagates to the caller exactly like the scoped crew's
//! `thread::scope` join — loudly, with the pool still usable afterwards.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use super::layers::LayerWs;
use super::parallel::{
    apply_shard_outs, ensure_worker_ws, run_eval_shard, run_logits_shard, run_train_shard,
    ExecConfig, ShardOut, TrainShardCtx,
};
use super::{Backend, Graph, StepStats};
use crate::util::shard::shard_ranges;

/// A lifetime-erased unit of work bound for one worker thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// What a job reports back: its worker index and its panic payload, if
/// the body unwound.
type Reply = (usize, Option<Box<dyn std::any::Any + Send>>);

/// Decrements the pool's live-worker count when a worker thread exits,
/// however it exits — the observable the drop-joins tests assert on.
struct WorkerAlive(Arc<AtomicUsize>);

impl Drop for WorkerAlive {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Send one borrowed job to each of the first `bodies.len()` workers and
/// block until every one has replied, then re-raise the lowest-indexed
/// panic payload, if any. See the module docs for why the lifetime
/// erasure here is sound and why channel failure aborts.
fn dispatch(txs: &[Sender<Job>], bodies: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let nw = bodies.len();
    let (reply_tx, reply_rx) = channel::<Reply>();
    for (w, body) in bodies.into_iter().enumerate() {
        let reply = reply_tx.clone();
        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let outcome = catch_unwind(AssertUnwindSafe(body));
            // A dead reply receiver means dispatch already aborted the
            // process; nothing useful to do with the error.
            let _ = reply.send((w, outcome.err()));
        });
        // SAFETY: dispatch blocks below until all `nw` replies arrive,
        // and each reply is sent strictly after its job body returned or
        // unwound — so every borrow inside `job` is dead before this
        // function (and thus the borrowed frame) can return. On any
        // channel failure we abort instead of unwinding past the borrow.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Box<dyn FnOnce() + Send>>(job)
        };
        if txs[w].send(job).is_err() {
            // The worker thread is gone and took our borrowed job with
            // it; unwinding here could let the borrow dangle.
            std::process::abort();
        }
    }
    drop(reply_tx);

    let mut first_panic: Option<Reply> = None;
    for _ in 0..nw {
        match reply_rx.recv() {
            Ok((w, Some(payload))) => {
                if first_panic.as_ref().is_none_or(|(pw, _)| w < *pw) {
                    first_panic = Some((w, Some(payload)));
                }
            }
            Ok((_, None)) => {}
            // A worker died without replying — its borrowed job may have
            // been dropped unrun or leaked; the frame must not unwind.
            Err(_) => std::process::abort(),
        }
    }
    if let Some((_, Some(payload))) = first_panic {
        resume_unwind(payload);
    }
}

/// Persistent data-parallel executor: the long-lived counterpart of
/// [`super::parallel::ParallelExecutor`], with identical step semantics
/// and bit-identical results at every thread count (see the module
/// docs). Construct once per trainer/server, reuse across `train_step` /
/// `eval_batch` / `eval_logits` calls in any order; dropping the pool
/// closes the job channels and joins every worker.
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
    /// One job channel per worker — jobs are pinned to the worker slot
    /// whose workspace set they mutate.
    txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// `worker_ws[w][i]`: worker w's workspace for graph node i. Owned
    /// by the pool (not the worker threads) because the epilogue reads
    /// worker 0's workspaces to commit batch statistics, and workspace
    /// telemetry sums across all workers.
    worker_ws: Vec<Vec<LayerWs>>,
    live: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn the worker crew (an auto config resolves to the machine's
    /// parallelism here, once — see [`ExecConfig::resolved_threads`]).
    /// Workspaces grow on first use and are reused afterwards.
    pub fn new(cfg: ExecConfig) -> WorkerPool {
        // Settle the process-wide GEMM kernel before any worker exists —
        // workers then read the already-resolved value and can never
        // disagree about which microkernel a shard dispatches.
        let _ = super::gemm::Kernel::active();
        let threads = cfg.resolved_threads();
        if cfg.affinity && !super::parallel::affinity_supported() {
            eprintln!(
                "warning: affinity requested but core pinning is unsupported on this \
                 platform; workers run unpinned (results are identical either way)"
            );
        }
        let live = Arc::new(AtomicUsize::new(0));
        let mut txs = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let (tx, rx) = channel::<Job>();
            live.fetch_add(1, Ordering::SeqCst);
            let alive = WorkerAlive(Arc::clone(&live));
            let pin = cfg.affinity;
            let handle = std::thread::Builder::new()
                .name(format!("ssprop-pool-{w}"))
                .spawn(move || {
                    let _alive = alive;
                    if pin && !super::parallel::pin_current_thread(w) {
                        // A refused mask (core index beyond the machine,
                        // cgroup restriction) is only a lost hint — the
                        // shard math is placement-independent.
                        eprintln!("warning: could not pin pool worker {w} to core {w}");
                    }
                    // Jobs never unwind (they wrap their body in
                    // catch_unwind), so the loop runs until the pool
                    // drops its sender.
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn pool worker");
            txs.push(tx);
            handles.push(handle);
        }
        WorkerPool { threads, txs, handles, worker_ws: Vec::new(), live }
    }

    /// Resolved worker count (shards per step; capped by the batch size
    /// at step time).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total im2col materializations across all worker workspaces —
    /// advances by `conv_count × shards` per train step when the fused
    /// path is healthy, exactly like the scoped executor's counter.
    pub fn plan_cols_builds(&self) -> u64 {
        self.worker_ws.iter().flatten().map(|w| w.plan_cols_builds()).sum()
    }

    /// One data-parallel SGD training step at `drop_rate` — the pooled
    /// counterpart of [`super::parallel::ParallelExecutor::train_step`],
    /// bit-identical to it at every thread count (same shard bodies, same
    /// fixed-order reductions, same epilogue).
    pub fn train_step(
        &mut self,
        model: &mut Graph,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        let n_in = model.in_shape().volume();
        if bt == 0 || x.len() != bt * n_in {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        let classes = model.out_features();
        let shards = shard_ranges(bt, self.threads);
        let nw = shards.len();
        ensure_worker_ws(&mut self.worker_ws, model, &shards);
        let step = model.begin_step();

        let mut outs: Vec<ShardOut> = (0..nw).map(|_| ShardOut::default()).collect();
        let barrier = Barrier::new(nw);
        let imp_slots: Vec<Mutex<Vec<f32>>> = (0..nw).map(|_| Mutex::new(Vec::new())).collect();
        let keep_slot: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let stat_slot: Mutex<Vec<f32>> = Mutex::new(Vec::new());
        let ctx = TrainShardCtx {
            model,
            backend,
            x,
            y,
            n_in,
            bt,
            classes,
            drop_rate,
            step,
            barrier: &barrier,
            imp_slots: &imp_slots,
            keep_slot: &keep_slot,
            stat_slot: &stat_slot,
        };

        let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
        let bodies: Vec<Box<dyn FnOnce() + Send + '_>> = worker_iter
            .enumerate()
            .map(|(w, ((range, wws), out))| {
                let ctx = &ctx;
                let range = range.clone();
                Box::new(move || run_train_shard(ctx, w, range, wws, out))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        dispatch(&self.txs, bodies);

        apply_shard_outs(model, &self.worker_ws, outs, bt, drop_rate, lr)
    }

    /// Sharded forward-only evaluation — the pooled counterpart of
    /// [`super::parallel::ParallelExecutor::eval_batch`], bit-identical
    /// to [`Graph::eval_batch`] at every thread count. Panics on
    /// malformed batch geometry.
    pub fn eval_batch(
        &mut self,
        model: &Graph,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
    ) -> (f64, f64) {
        let bt = y.len();
        let n_in = model.in_shape().volume();
        assert!(bt > 0 && x.len() == bt * n_in, "bad eval batch geometry");
        let shards = shard_ranges(bt, self.threads);
        ensure_worker_ws(&mut self.worker_ws, model, &shards);

        let mut outs: Vec<(Vec<f64>, usize)> = shards.iter().map(|_| (Vec::new(), 0)).collect();
        let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
        let bodies: Vec<Box<dyn FnOnce() + Send + '_>> = worker_iter
            .map(|((range, wws), out)| {
                let range = range.clone();
                Box::new(move || {
                    *out = run_eval_shard(model, backend, x, y, range, wws);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        dispatch(&self.txs, bodies);

        let (mut loss_sum, mut correct) = (0f64, 0usize);
        for (losses, c) in &outs {
            for &l in losses {
                loss_sum += l;
            }
            correct += c;
        }
        (loss_sum / bt as f64, correct as f64 / bt as f64)
    }

    /// Sharded inference — the pooled counterpart of
    /// [`super::parallel::ParallelExecutor::eval_logits`], bit-identical
    /// to [`Graph::infer_logits`] at every thread count. The serving
    /// path's core primitive: per-worker forward workspaces (conv plans
    /// included) persist across calls and across the pool's whole
    /// lifetime. Panics on malformed batch geometry.
    pub fn eval_logits(
        &mut self,
        model: &Graph,
        backend: &dyn Backend,
        x: &[f32],
        bt: usize,
    ) -> Vec<f32> {
        let n_in = model.in_shape().volume();
        assert!(bt > 0 && x.len() == bt * n_in, "bad inference batch geometry");
        let shards = shard_ranges(bt, self.threads);
        ensure_worker_ws(&mut self.worker_ws, model, &shards);

        let mut outs: Vec<Vec<f32>> = shards.iter().map(|_| Vec::new()).collect();
        let worker_iter = shards.iter().zip(self.worker_ws.iter_mut()).zip(outs.iter_mut());
        let bodies: Vec<Box<dyn FnOnce() + Send + '_>> = worker_iter
            .map(|((range, wws), out)| {
                let range = range.clone();
                Box::new(move || {
                    *out = run_logits_shard(model, backend, x, range, wws);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        dispatch(&self.txs, bodies);
        outs.concat()
    }

    /// Live worker-thread count observable (for lifecycle tests).
    #[cfg(test)]
    fn live_workers(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.live)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channels ends every worker loop; joining makes
        // the teardown synchronous so no pool thread outlives the pool.
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{
        simple_cnn, Conv2d, Conv2dPlan, ConvGrads, NativeBackend, ParallelExecutor, Sequential,
        SimpleCnnCfg,
    };
    use crate::util::rng::Pcg;

    fn tiny() -> Sequential {
        simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 })
    }

    fn batch(bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg::new(seed, 1);
        let x = (0..bt * 64).map(|_| rng.normal()).collect();
        let y = (0..bt).map(|i| (i % 3) as i32).collect();
        (x, y)
    }

    #[test]
    fn pooled_steps_match_scoped_executor_bitwise() {
        let be = NativeBackend::new();
        for threads in [1usize, 2, 3] {
            let mut m_pool = tiny();
            let mut m_exec = tiny();
            let mut pool = WorkerPool::new(ExecConfig::with_threads(threads));
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            for step in 0..4 {
                let (x, y) = batch(6, 40 + step);
                let d = if step % 2 == 0 { 0.8 } else { 0.0 };
                let a = pool.train_step(&mut m_pool, &be, &x, &y, d, 0.05).unwrap();
                let b = exec.train_step(&mut m_exec, &be, &x, &y, d, 0.05).unwrap();
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "t{threads} step {step}");
                assert_eq!(a.kept_channels, b.kept_channels);
            }
            let (x, _) = batch(5, 99);
            let lp = pool.eval_logits(&m_pool, &be, &x, 5);
            let le = exec.eval_logits(&m_exec, &be, &x, 5);
            assert_eq!(lp.len(), le.len());
            for (i, (a, b)) in lp.iter().zip(&le).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t{threads} logit {i}");
            }
        }
    }

    #[test]
    fn affinity_hint_leaves_bits_unchanged() {
        // --affinity is a placement hint only: a pinned pool must
        // reproduce an unpinned pool bit-for-bit (whether or not the
        // kernel accepted the masks on this machine)
        let be = NativeBackend::new();
        let mut m_pin = tiny();
        let mut m_free = tiny();
        let mut pinned = WorkerPool::new(ExecConfig::with_threads(2).with_affinity(true));
        let mut free = WorkerPool::new(ExecConfig::with_threads(2));
        for step in 0..3 {
            let (x, y) = batch(6, 70 + step);
            let a = pinned.train_step(&mut m_pin, &be, &x, &y, 0.8, 0.05).unwrap();
            let b = free.train_step(&mut m_free, &be, &x, &y, 0.8, 0.05).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
            assert_eq!(a.kept_channels, b.kept_channels);
        }
    }

    #[test]
    fn pool_reuses_workspaces_and_counts_col_builds() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(6, 13);
        let mut pool = WorkerPool::new(ExecConfig::with_threads(3));
        pool.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        let per_step = (m.conv_count() * 3) as u64;
        assert_eq!(pool.plan_cols_builds(), per_step, "one build per conv per worker");
        pool.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        assert_eq!(pool.plan_cols_builds(), 2 * per_step);
    }

    #[test]
    fn pool_rekeys_workspaces_across_batch_sizes() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut pool = WorkerPool::new(ExecConfig::with_threads(2));
        let (x8, y8) = batch(8, 3);
        let (x4, y4) = batch(4, 4);
        let s8 = pool.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let s4 = pool.train_step(&mut m, &be, &x4, &y4, 0.0, 0.05).unwrap();
        let s8b = pool.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        assert!(s8.loss.is_finite() && s4.loss.is_finite() && s8b.loss.is_finite());
        let caps: Vec<Vec<[usize; 7]>> = pool
            .worker_ws
            .iter()
            .map(|wws| wws.iter().filter_map(|w| w.plan_caps()).collect())
            .collect();
        pool.train_step(&mut m, &be, &x4, &y4, 0.0, 0.05).unwrap();
        pool.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let caps2: Vec<Vec<[usize; 7]>> = pool
            .worker_ws
            .iter()
            .map(|wws| wws.iter().filter_map(|w| w.plan_caps()).collect())
            .collect();
        assert_eq!(caps, caps2, "shrinking then regrowing the batch must reuse capacity");
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = WorkerPool::new(ExecConfig::with_threads(4));
        let live = pool.live_workers();
        assert_eq!(live.load(Ordering::SeqCst), 4);
        drop(pool);
        assert_eq!(live.load(Ordering::SeqCst), 0, "drop must join all worker threads");
    }

    #[test]
    fn auto_config_resolves_at_construction() {
        let pool = WorkerPool::new(ExecConfig::auto());
        let t = pool.threads();
        assert!((1..=crate::backend::parallel::MAX_AUTO_THREADS).contains(&t));
        assert_eq!(pool.live_workers().load(Ordering::SeqCst), t);
    }

    /// Delegates to the native backend but panics in the planned forward
    /// when run on worker 0's thread — a stand-in for a backend invariant
    /// violation inside one shard while its peers keep going.
    #[derive(Debug)]
    struct FaultyForward(NativeBackend);

    impl Backend for FaultyForward {
        fn name(&self) -> &'static str {
            "faulty-forward"
        }

        fn conv2d_fwd_planned(
            &self,
            plan: &mut Conv2dPlan,
            x: &[f32],
            w: &[f32],
            b: Option<&[f32]>,
        ) -> Vec<f32> {
            if std::thread::current().name() == Some("ssprop-pool-0") {
                panic!("injected conv fault");
            }
            self.0.conv2d_fwd_planned(plan, x, w, b)
        }

        fn conv2d_bwd_planned_with(
            &self,
            plan: &mut Conv2dPlan,
            x: &[f32],
            w: &[f32],
            g: &[f32],
            keep_idx: &[usize],
            need_dx: bool,
        ) -> ConvGrads {
            self.0.conv2d_bwd_planned_with(plan, x, w, g, keep_idx, need_dx)
        }

        fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
            self.0.gemm(m, k, n, a, b)
        }

        fn bias_add(&self, cfg: &Conv2d, y: &mut [f32], b: &[f32]) {
            self.0.bias_add(cfg, y, b)
        }
    }

    #[test]
    fn worker_panic_propagates_loudly_and_pool_survives() {
        let good = NativeBackend::new();
        let bad = FaultyForward(NativeBackend::new());
        let mut m = tiny();
        let (x, y) = batch(8, 17);
        let mut pool = WorkerPool::new(ExecConfig::with_threads(4));

        // A healthy step first, so the fault hits warm workspaces.
        pool.train_step(&mut m, &good, &x, &y, 0.0, 0.05).unwrap();

        // Fault at D=0.8: worker 0 dies in its forward, before any of the
        // step's selection rendezvous — its BarrierAttendance pays the
        // outstanding waits during unwinding, so workers 1..3 drain
        // instead of deadlocking, and dispatch re-raises the
        // lowest-indexed payload on this thread.
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.train_step(&mut m, &bad, &x, &y, 0.8, 0.05);
        }));
        let payload = unwound.expect_err("worker panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "injected conv fault", "the worker's own payload must surface");

        // No deadlock, no dead workers: the pool keeps training.
        assert_eq!(pool.live_workers().load(Ordering::SeqCst), 4);
        let stats = pool.train_step(&mut m, &good, &x, &y, 0.8, 0.05).unwrap();
        assert!(stats.loss.is_finite());
        let live = pool.live_workers();
        drop(pool);
        assert_eq!(live.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn rejects_bad_geometry() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut pool = WorkerPool::new(ExecConfig::with_threads(2));
        assert!(pool.train_step(&mut m, &be, &[0.0; 3], &[0, 1], 0.0, 0.05).is_err());
        assert!(pool.train_step(&mut m, &be, &[], &[], 0.0, 0.05).is_err());
    }
}
