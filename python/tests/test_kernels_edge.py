"""Edge-case and dtype sweeps for the L1 kernels beyond the main suite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.im2col import col2img, im2col
from compile.kernels.matmul import matmul
from compile.ssprop import ConvSpec, make_ssprop_conv_pallas, ssprop_conv

KEY0 = jnp.zeros((2,), jnp.uint32)


# -- degenerate geometries ----------------------------------------------------

def test_one_by_one_kernel_conv():
    """K=1 convs (half of ResNet-50's bottlenecks) through both paths."""
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(2, 4, 6, 6)).astype(np.float32))
    w = jnp.array(rng.normal(size=(8, 4, 1, 1)).astype(np.float32))
    b = jnp.zeros((8,), jnp.float32)
    conv_p = make_ssprop_conv_pallas(stride=1, padding=0, drop_rate=0.5)
    np.testing.assert_allclose(
        np.asarray(conv_p(x, w, b)),
        np.asarray(ref.conv_fwd_ref(x, w, b, stride=1, padding=0)),
        rtol=1e-4, atol=1e-4)


def test_single_pixel_output():
    """Kernel size == input size -> 1x1 output map."""
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(1, 2, 4, 4)).astype(np.float32))
    cols = im2col(x, k=4, stride=1, padding=0)
    assert cols.shape == (1, 2 * 16)
    np.testing.assert_allclose(np.asarray(cols),
                               np.asarray(ref.im2col_ref(x, k=4, stride=1, padding=0)),
                               rtol=1e-6)


def test_single_channel_single_batch():
    rng = np.random.default_rng(2)
    x = jnp.array(rng.normal(size=(1, 1, 5, 5)).astype(np.float32))
    w = jnp.array(rng.normal(size=(1, 1, 3, 3)).astype(np.float32))
    b = jnp.zeros((1,), jnp.float32)
    spec = ConvSpec(stride=1, padding=1)

    def loss(x, w, b):
        return jnp.sum(ssprop_conv(x, w, b, jnp.float32(0.9), KEY0, spec) ** 2)

    gx, gw = jax.grad(loss, (0, 1))(x, w, b)
    # with a single channel, keep_k clamps to 1 -> gradients stay dense
    assert np.abs(np.asarray(gw)).sum() > 0
    assert np.isfinite(np.asarray(gx)).all()


def test_drop_rate_one_clamps_to_one_channel():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(2, 3, 6, 6)).astype(np.float32))
    w = jnp.array(rng.normal(size=(8, 3, 3, 3)).astype(np.float32))
    b = jnp.zeros((8,), jnp.float32)
    spec = ConvSpec(stride=1, padding=1)

    def loss(x, w, b):
        return jnp.sum(ssprop_conv(x, w, b, jnp.float32(0.9999), KEY0, spec) ** 2)

    gw = jax.grad(loss, 1)(x, w, b)
    rows = np.abs(np.asarray(gw).reshape(8, -1)).sum(axis=1)
    assert (rows > 0).sum() == 1  # exactly one kept channel


# -- dtype robustness ---------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 40), k=st.integers(4, 40), n=st.integers(4, 40))
def test_matmul_bf16_tolerance(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(matmul(jnp.array(a, jnp.bfloat16), jnp.array(b, jnp.bfloat16)),
                     dtype=np.float32)
    # bf16 inputs, f32 accumulation: error bounded by input rounding
    np.testing.assert_allclose(got, a @ b, rtol=0.05, atol=0.3 * np.sqrt(k))


def test_im2col_preserves_dtype():
    x = jnp.ones((1, 2, 5, 5), jnp.bfloat16)
    assert im2col(x, k=3, stride=1, padding=1).dtype == jnp.bfloat16
    cols = jnp.ones((25, 18), jnp.bfloat16)
    assert col2img(cols, x_shape=(1, 2, 5, 5), k=3, stride=1, padding=1).dtype == jnp.bfloat16


# -- gradient-selection invariants under transformations -----------------------

def test_mask_invariant_to_gradient_scaling():
    """Top-k selection is scale-invariant: 2*g selects the same channels."""
    rng = np.random.default_rng(5)
    g = jnp.array(rng.normal(size=(2, 12, 4, 4)).astype(np.float32))
    k = jnp.int32(3)
    m1 = ref.topk_mask_ref(ref.importance_ref(g), k)
    m2 = ref.topk_mask_ref(ref.importance_ref(2.0 * g), k)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_mask_permutation_equivariance():
    """Permuting channels permutes the mask identically."""
    rng = np.random.default_rng(6)
    g = jnp.array(rng.normal(size=(2, 10, 4, 4)).astype(np.float32))
    perm = jnp.array(rng.permutation(10))
    k = jnp.int32(4)
    m = ref.topk_mask_ref(ref.importance_ref(g), k)
    mp = ref.topk_mask_ref(ref.importance_ref(g[:, perm]), k)
    np.testing.assert_array_equal(np.asarray(m)[np.asarray(perm)], np.asarray(mp))


def test_compact_ref_with_unsorted_vs_sorted_indices():
    """Scatter of dW' is order-independent."""
    rng = np.random.default_rng(7)
    x = jnp.array(rng.normal(size=(1, 2, 6, 6)).astype(np.float32))
    w = jnp.array(rng.normal(size=(6, 2, 3, 3)).astype(np.float32))
    g = jnp.array(rng.normal(size=(1, 6, 6, 6)).astype(np.float32))
    idx_sorted = jnp.array([1, 3, 5])
    idx_unsorted = jnp.array([5, 1, 3])
    a = ref.sparse_bwd_compact_ref(x, w, g, idx_sorted, stride=1, padding=1)
    b = ref.sparse_bwd_compact_ref(x, w, g, idx_unsorted, stride=1, padding=1)
    for ta, tb in zip(a, b):
        np.testing.assert_allclose(np.asarray(ta), np.asarray(tb), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stride,padding", [(1, 0), (2, 1), (3, 2)])
def test_pallas_fwd_strides_and_pads(stride, padding):
    rng = np.random.default_rng(8)
    x = jnp.array(rng.normal(size=(2, 3, 11, 11)).astype(np.float32))
    w = jnp.array(rng.normal(size=(4, 3, 3, 3)).astype(np.float32))
    b = jnp.array(rng.normal(size=(4,)).astype(np.float32))
    conv_p = make_ssprop_conv_pallas(stride=stride, padding=padding, drop_rate=0.0)
    np.testing.assert_allclose(
        np.asarray(conv_p(x, w, b)),
        np.asarray(ref.conv_fwd_ref(x, w, b, stride=stride, padding=padding)),
        rtol=1e-4, atol=1e-4)
