"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/strides/padding/dtypes; assert_allclose against the
reference is the core L1 signal demanded by DESIGN.md §7.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.im2col import col2img, im2col
from compile.kernels.importance import channel_importance
from compile.kernels.matmul import matmul, vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       seed=st.integers(0, 2 ** 31))
def test_matmul_matches_numpy(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(matmul(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_block_shapes(bm, bn, bk):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(33, 45)).astype(np.float32)
    b = rng.normal(size=(45, 21)).astype(np.float32)
    got = np.asarray(matmul(jnp.array(a), jnp.array(b), bm=bm, bn=bn, bk=bk))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_bf16():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(32, 32)).astype(np.float32)
    b = rng.normal(size=(32, 32)).astype(np.float32)
    got = matmul(jnp.array(a, jnp.bfloat16), jnp.array(b, jnp.bfloat16))
    np.testing.assert_allclose(np.asarray(got, np.float32), a @ b, rtol=0.1, atol=0.5)


def test_vmem_footprint_within_tpu_budget():
    # default 128x128x128 f32 tiles must fit VMEM (~16 MiB/core) with margin
    assert vmem_bytes(128, 128, 128) <= 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# im2col / col2img
# ---------------------------------------------------------------------------

conv_geom = st.tuples(
    st.integers(1, 3),               # bt
    st.integers(1, 4),               # cin
    st.integers(4, 10),              # h
    st.integers(4, 10),              # w
    st.sampled_from([1, 2, 3]),      # k
    st.sampled_from([1, 2]),         # stride
    st.sampled_from([0, 1]),         # padding
).filter(lambda t: t[2] + 2 * t[6] >= t[4] and t[3] + 2 * t[6] >= t[4])


@settings(**SETTINGS)
@given(geom=conv_geom, seed=st.integers(0, 2 ** 31))
def test_im2col_matches_ref(geom, seed):
    bt, cin, h, w, k, s, p = geom
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(bt, cin, h, w)).astype(np.float32))
    got = im2col(x, k=k, stride=s, padding=p)
    want = ref.im2col_ref(x, k=k, stride=s, padding=p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(**SETTINGS)
@given(geom=conv_geom, seed=st.integers(0, 2 ** 31))
def test_col2img_matches_ref(geom, seed):
    bt, cin, h, w, k, s, p = geom
    rng = np.random.default_rng(seed)
    ho, wo = ref.out_size(h, k, s, p), ref.out_size(w, k, s, p)
    cols = jnp.array(rng.normal(size=(bt * ho * wo, cin * k * k)).astype(np.float32))
    got = col2img(cols, x_shape=(bt, cin, h, w), k=k, stride=s, padding=p)
    want = ref.col2img_ref(cols, x_shape=(bt, cin, h, w), k=k, stride=s, padding=p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_col2img_is_im2col_adjoint():
    """<im2col(x), c> == <x, col2img(c)> — the defining adjoint property."""
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(2, 3, 8, 8)).astype(np.float32))
    k, s, p = 3, 2, 1
    cols = ref.im2col_ref(x, k=k, stride=s, padding=p)
    c = jnp.array(rng.normal(size=cols.shape).astype(np.float32))
    lhs = jnp.sum(cols * c)
    rhs = jnp.sum(x * ref.col2img_ref(c, x_shape=x.shape, k=k, stride=s, padding=p))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-4)


def test_im2col_forward_equals_lax_conv():
    rng = np.random.default_rng(4)
    x = jnp.array(rng.normal(size=(2, 3, 9, 9)).astype(np.float32))
    w = jnp.array(rng.normal(size=(5, 3, 3, 3)).astype(np.float32))
    b = jnp.array(rng.normal(size=(5,)).astype(np.float32))
    y1 = ref.conv_fwd_ref(x, w, b, stride=2, padding=1)
    y2 = ref.conv_fwd_im2col_ref(x, w, b, stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# channel importance
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(bt=st.integers(1, 4), c=st.integers(1, 20), h=st.integers(1, 9),
       w=st.integers(1, 9), cb=st.sampled_from([1, 4, 8]), seed=st.integers(0, 2 ** 31))
def test_importance_matches_ref(bt, c, h, w, cb, seed):
    rng = np.random.default_rng(seed)
    g = jnp.array(rng.normal(size=(bt, c, h, w)).astype(np.float32))
    got = channel_importance(g, cb=cb)
    want = ref.importance_ref(g, "channel")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_importance_nonnegative_and_scale_equivariant():
    rng = np.random.default_rng(5)
    g = jnp.array(rng.normal(size=(2, 6, 4, 4)).astype(np.float32))
    imp = np.asarray(channel_importance(g))
    assert (imp >= 0).all()
    imp2 = np.asarray(channel_importance(2.0 * g))
    np.testing.assert_allclose(imp2, 2.0 * imp, rtol=1e-5)


# ---------------------------------------------------------------------------
# selection semantics
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 64), frac=st.floats(0.0, 0.99), seed=st.integers(0, 2 ** 31))
def test_topk_mask_exact_k(n, frac, seed):
    rng = np.random.default_rng(seed)
    imp = jnp.array(rng.normal(size=(n,)).astype(np.float32))
    k = ref.keep_k_from_drop_rate(jnp.float32(frac), n)
    mask = np.asarray(ref.topk_mask_ref(imp, k))
    assert mask.sum() == int(k)
    # kept entries dominate dropped entries
    if 0 < int(k) < n:
        assert np.min(np.asarray(imp)[mask > 0]) >= np.max(np.asarray(imp)[mask == 0]) - 1e-6


def test_topk_mask_tie_determinism():
    imp = jnp.ones((8,), jnp.float32)
    m1 = np.asarray(ref.topk_mask_ref(imp, jnp.int32(3)))
    m2 = np.asarray(ref.topk_mask_ref(imp, jnp.int32(3)))
    assert (m1 == m2).all() and m1.sum() == 3


@settings(**SETTINGS)
@given(n=st.integers(1, 64), k=st.integers(1, 64), seed=st.integers(0, 2 ** 31))
def test_random_mask_exact_k(n, k, seed):
    k = min(k, n)
    mask = np.asarray(ref.random_mask_ref(jax.random.PRNGKey(seed), n, jnp.int32(k)))
    assert mask.sum() == k


def test_keep_k_bounds():
    assert int(ref.keep_k_from_drop_rate(jnp.float32(0.0), 10)) == 10
    assert int(ref.keep_k_from_drop_rate(jnp.float32(0.999), 10)) == 1
    assert int(ref.keep_k_from_drop_rate(jnp.float32(0.8), 10)) == 2
    assert int(ref.keep_k_from_drop_rate(jnp.float32(0.5), 1)) == 1
