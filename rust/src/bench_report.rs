//! Committed bench artifacts + the regression gate (`BENCH_*.json`).
//!
//! The paper's whole pitch is making the energy cost of back-propagation
//! *visible*; this module makes the repo's own perf/energy numbers visible
//! the same way. `native_hotpath --json PATH` serializes one benchmark run
//! as a versioned, machine-readable [`BenchReport`] — per-preset step
//! times, speedup ratios, the Eq. 6/9 FLOPs ledger, and
//! [`crate::energy`] joules — and `ssprop bench-check` diffs a fresh run
//! against the committed baseline (`BENCH_native.json` at the repo root)
//! with per-metric tolerances, exiting nonzero on regression. The full
//! story (schema, tolerance policy, CI wiring) lives in
//! `docs/BENCHMARKS.md`.
//!
//! Metric classes, per the tolerance policy:
//!
//! * **timings** (`*_ns`) — machine-dependent; recorded for the
//!   trajectory, never gated.
//! * **ratios** (`*_speedup_*`) — noisy but machine-comparable; gated
//!   inside a wide multiplicative band ([`Tolerance::ratio_band`]).
//! * **ledger values** (FLOPs, joules, batch) — analytic and
//!   deterministic; gated exactly ([`Tolerance::exact_rel`]).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::Result;

use crate::backend::gemm::Kernel;
use crate::backend::{build_model, parse_model_spec};
use crate::energy::{estimate, CPU_TESTBED, RTX_A5000, TPU_CORE};
use crate::experiments::report::Table;
use crate::util::bench::fmt_ns;
use crate::util::json::{num, obj, s, Json};

/// Version stamped into every report; readers reject other versions with
/// the typed [`ReportError::SchemaVersion`]. Version 2 added the
/// `gemm_speedup_*` conv ratios (blocked microkernel vs naive reference)
/// and the per-preset `sparse_gemm_*` metrics (sparsity-aware backward
/// GEMMs on the preset's conv shapes, dense vs D=0.5). Version 3 added
/// the persistent-executor metrics: per-preset `pool_speedup_t{2,4}`
/// (per-step-spawn scoped crew vs persistent [`crate::backend::WorkerPool`])
/// and `pipeline_speedup` (batch-prefetch pipelined training run vs the
/// fully synchronous loop), with their `pool_step_d80_t{2,4}_ns` /
/// `pipeline_run_ns` / `sync_run_ns` timings. Version 4 added the
/// SIMD-dispatch metrics: the report-level `kernel` field (the
/// [`crate::backend::gemm::Kernel`] the run dispatched, gated as an
/// exact-match string like `device`), the `gemm_simd_speedup_{m}x{k}x{n}`
/// conv ratios (portable scalar tile vs the dispatched SIMD tile on the
/// same blocked kernel), and the per-preset `sparse_gemm_nr16_speedup`
/// ratio with its `sparse_gemm_nr{8,16}_ns` timings (narrow vs wide
/// B-panel tile on the preset's dense-keep dW shapes).
pub const SCHEMA_VERSION: u64 = 4;

/// The ssProp drop rate the ledger columns are evaluated at (the paper's
/// D* = 0.8, Eq. 9).
pub const BENCH_DROP: f64 = 0.8;

/// Input channels of the bench harness's synthetic batch (CIFAR-sized).
pub const BENCH_IN_CH: usize = 3;
/// Image side length of the bench harness's synthetic batch.
pub const BENCH_IMG: usize = 32;
/// Classifier outputs of the bench harness's models.
pub const BENCH_CLASSES: usize = 10;
/// Batch size of the bench harness's executor sections.
pub const BENCH_BATCH: usize = 32;

/// Zoo presets the committed `BENCH_native.json` baseline tracks (and the
/// `--json` bench run measures), canonical spec form.
pub const BASELINE_PRESETS: &[&str] = &["simple-cnn-d4-w16", "vgg-tiny-w8", "resnet-tiny-w8-b1"];

/// Device-profile names a report may legally carry in `energy.device`
/// (the [`crate::energy`] profiles). Anything else is refused on load
/// with [`ReportError::UnknownValue`] naming the offending key.
pub const KNOWN_DEVICES: &[&str] = &[RTX_A5000.name, TPU_CORE.name, CPU_TESTBED.name];

/// Typed error for reading/validating a bench report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReportError {
    /// Reading the file failed.
    Io {
        /// Path that failed to read or write.
        path: String,
        /// The underlying I/O error, rendered.
        error: String,
    },
    /// The file is not a valid JSON document.
    Parse(String),
    /// The document's `schema_version` is not the one this build reads.
    SchemaVersion {
        /// Version found in the document.
        found: u64,
        /// Version this build expects ([`SCHEMA_VERSION`]).
        expected: u64,
    },
    /// The document parses as JSON but violates the report schema.
    Malformed(String),
    /// A machine-identity field (`kernel`, `energy.device`) holds a
    /// string this build does not know. Refusing up front beats gating
    /// timings against a mismatched machine silently.
    UnknownValue {
        /// Offending field, e.g. `kernel` or `resnet-tiny-w8-b1.energy.device`.
        key: String,
        /// The unrecognized string found there.
        value: String,
    },
}

impl fmt::Display for ReportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReportError::Io { path, error } => write!(f, "bench report {path}: {error}"),
            ReportError::Parse(e) => write!(f, "bench report is not valid JSON: {e}"),
            ReportError::SchemaVersion { found, expected } => {
                write!(f, "bench report schema_version {found} (this build reads {expected})")
            }
            ReportError::Malformed(e) => write!(f, "malformed bench report: {e}"),
            ReportError::UnknownValue { key, value } => {
                write!(f, "bench report field {key} holds unknown value {value:?}")
            }
        }
    }
}

impl std::error::Error for ReportError {}

/// Eq. 6/9 backward-FLOPs ledger for one preset at the bench batch size —
/// analytic (from [`crate::flops::LayerSet`]), so byte-deterministic
/// across machines and gated exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct FlopsLedger {
    /// Dense backward FLOPs per iteration (Eq. 6, drop rate 0).
    pub bwd_dense: f64,
    /// ssProp backward FLOPs per iteration at [`BENCH_DROP`] (Eq. 9).
    pub bwd_d80: f64,
    /// Fraction saved at [`BENCH_DROP`]: `1 - bwd_d80 / bwd_dense`.
    pub saving_frac: f64,
}

/// Per-iteration energy ledger for one preset on the paper's testbed GPU
/// ([`RTX_A5000`]) — joules via [`crate::energy::EnergyReport::joules`],
/// deterministic and gated exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyLedger {
    /// Device profile name the joules are computed against.
    pub device: String,
    /// Joules per dense backward iteration.
    pub dense_j: f64,
    /// Joules per ssProp backward iteration at [`BENCH_DROP`].
    pub d80_j: f64,
    /// Joules saved per iteration (`estimate(dense − d80)`).
    pub saved_j: f64,
}

/// One zoo preset's measurements + ledger inside a [`BenchReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct PresetReport {
    /// Canonical model spec (`backend::zoo`), e.g. `resnet-tiny-w8-b1`.
    pub spec: String,
    /// Median step times in nanoseconds (`serial_step_{dense,d80}_ns`,
    /// `parallel_step_{dense,d80}_t{2,4}_ns`, `pool_step_d80_t{2,4}_ns`,
    /// `{pipeline,sync}_run_ns`, `sparse_gemm_{dense,d50}_ns`).
    /// Machine-dependent — never gated, recorded for the trajectory table.
    pub timings_ns: BTreeMap<String, f64>,
    /// Speedup ratios (`parallel_speedup_{dense,d80}_t{2,4}`,
    /// `pool_speedup_t{2,4}`, `pipeline_speedup`, `bwd_speedup_d80`,
    /// `sparse_gemm_speedup_d50`). Gated within
    /// [`Tolerance::ratio_band`].
    pub ratios: BTreeMap<String, f64>,
    /// Eq. 6/9 FLOPs ledger (exact).
    pub flops: FlopsLedger,
    /// Joules ledger (exact).
    pub energy: EnergyLedger,
}

/// One `native_hotpath` run, serializable to/from `BENCH_*.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// Schema version ([`SCHEMA_VERSION`]).
    pub schema_version: u64,
    /// Producing benchmark (`native_hotpath`).
    pub bench: String,
    /// `smoke` (CI-sized) or `full`.
    pub mode: String,
    /// GEMM microkernel the run dispatched
    /// ([`crate::backend::gemm::Kernel::name`]: `scalar`/`sse2`/`avx2`).
    /// A machine-identity field like `device` — gated as an exact string
    /// match, and validated against the known kernel names on load.
    pub kernel: String,
    /// Executor-section batch size ([`BENCH_BATCH`]); gated exactly.
    pub batch: usize,
    /// Conv-microbench ratios from the fixed-geometry sections
    /// (`fused_speedup_*`, `bwd_speedup_*`, `gemm_speedup_{m}x{k}x{n}`);
    /// gated within the ratio band.
    pub conv_ratios: BTreeMap<String, f64>,
    /// Per-preset sections, run order.
    pub presets: Vec<PresetReport>,
}

/// Compute the deterministic ledger halves of a [`PresetReport`] for
/// `spec` at batch size `bt`, on the bench harness geometry
/// ([`BENCH_IN_CH`]×[`BENCH_IMG`]², [`BENCH_CLASSES`] classes): Eq. 6/9
/// FLOPs from the live graph's [`crate::flops::LayerSet`] and joules on
/// [`RTX_A5000`]. `bench-check` relies on these being bit-reproducible.
pub fn preset_ledger(spec: &str, bt: usize) -> Result<(FlopsLedger, EnergyLedger)> {
    let parsed = parse_model_spec(spec)?;
    let set = build_model(&parsed, BENCH_IN_CH, BENCH_IMG, BENCH_CLASSES, 0)?.layer_set();
    let dense = set.bwd_flops_per_iter(bt, 0.0);
    let d80 = set.bwd_flops_per_iter(bt, BENCH_DROP);
    let flops = FlopsLedger { bwd_dense: dense, bwd_d80: d80, saving_frac: 1.0 - d80 / dense };
    let energy = EnergyLedger {
        device: RTX_A5000.name.to_string(),
        dense_j: estimate(dense, &RTX_A5000).joules(),
        d80_j: estimate(d80, &RTX_A5000).joules(),
        saved_j: estimate(dense - d80, &RTX_A5000).joules(),
    };
    Ok((flops, energy))
}

/// Two-space-indented writer (scalars reuse the compact `Json` writer, so
/// numbers format identically to the wire form).
fn pretty(j: &Json, pad: usize, out: &mut String) {
    match j {
        Json::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(pad + 2));
                pretty(v, pad + 2, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(pad));
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(pad + 2));
                out.push_str(&Json::Str(k.clone()).to_string());
                out.push_str(": ");
                pretty(v, pad + 2, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(pad));
            out.push('}');
        }
        scalar => out.push_str(&scalar.to_string()),
    }
}

fn map_json(m: &BTreeMap<String, f64>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
}

fn map_from_json(j: &Json, key: &str) -> Result<BTreeMap<String, f64>, ReportError> {
    let o = j
        .get(key)
        .and_then(Json::as_obj)
        .ok_or_else(|| ReportError::Malformed(format!("missing object field {key:?}")))?;
    let mut out = BTreeMap::new();
    for (k, v) in o {
        let n = v
            .as_f64()
            .ok_or_else(|| ReportError::Malformed(format!("non-numeric metric {key}.{k}")))?;
        out.insert(k.clone(), n);
    }
    Ok(out)
}

fn f64_of(j: &Json, key: &str) -> Result<f64, ReportError> {
    j.f64_field(key).map_err(ReportError::Malformed)
}

fn str_of(j: &Json, key: &str) -> Result<String, ReportError> {
    j.str_field(key).map(str::to_string).map_err(ReportError::Malformed)
}

impl FlopsLedger {
    fn to_json(&self) -> Json {
        obj(vec![
            ("bwd_d80", num(self.bwd_d80)),
            ("bwd_dense", num(self.bwd_dense)),
            ("saving_frac", num(self.saving_frac)),
        ])
    }

    fn from_json(j: &Json) -> Result<FlopsLedger, ReportError> {
        Ok(FlopsLedger {
            bwd_dense: f64_of(j, "bwd_dense")?,
            bwd_d80: f64_of(j, "bwd_d80")?,
            saving_frac: f64_of(j, "saving_frac")?,
        })
    }
}

impl EnergyLedger {
    fn to_json(&self) -> Json {
        obj(vec![
            ("d80_j", num(self.d80_j)),
            ("dense_j", num(self.dense_j)),
            ("device", s(&self.device)),
            ("saved_j", num(self.saved_j)),
        ])
    }

    fn from_json(j: &Json) -> Result<EnergyLedger, ReportError> {
        Ok(EnergyLedger {
            device: str_of(j, "device")?,
            dense_j: f64_of(j, "dense_j")?,
            d80_j: f64_of(j, "d80_j")?,
            saved_j: f64_of(j, "saved_j")?,
        })
    }
}

impl PresetReport {
    fn to_json(&self) -> Json {
        obj(vec![
            ("energy", self.energy.to_json()),
            ("flops", self.flops.to_json()),
            ("ratios", map_json(&self.ratios)),
            ("spec", s(&self.spec)),
            ("timings_ns", map_json(&self.timings_ns)),
        ])
    }

    fn from_json(j: &Json) -> Result<PresetReport, ReportError> {
        let flops = j
            .get("flops")
            .ok_or_else(|| ReportError::Malformed("preset missing \"flops\"".into()))?;
        let energy = j
            .get("energy")
            .ok_or_else(|| ReportError::Malformed("preset missing \"energy\"".into()))?;
        Ok(PresetReport {
            spec: str_of(j, "spec")?,
            timings_ns: map_from_json(j, "timings_ns")?,
            ratios: map_from_json(j, "ratios")?,
            flops: FlopsLedger::from_json(flops)?,
            energy: EnergyLedger::from_json(energy)?,
        })
    }
}

impl BenchReport {
    /// An empty report shell for `bench` in `mode` at the harness batch
    /// size; the producer fills `conv_ratios`/`presets` as sections run.
    pub fn new(bench: &str, mode: &str) -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            bench: bench.to_string(),
            mode: mode.to_string(),
            kernel: Kernel::active().name().to_string(),
            batch: BENCH_BATCH,
            conv_ratios: BTreeMap::new(),
            presets: Vec::new(),
        }
    }

    /// Serialize to the committed JSON shape (key-sorted objects).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("batch", num(self.batch as f64)),
            ("bench", s(&self.bench)),
            ("conv_ratios", map_json(&self.conv_ratios)),
            ("kernel", s(&self.kernel)),
            ("mode", s(&self.mode)),
            ("presets", Json::Arr(self.presets.iter().map(PresetReport::to_json).collect())),
            ("schema_version", num(self.schema_version as f64)),
        ])
    }

    /// Parse a report document, rejecting other schema versions with the
    /// typed [`ReportError::SchemaVersion`].
    pub fn parse(text: &str) -> Result<BenchReport, ReportError> {
        let j = Json::parse(text).map_err(ReportError::Parse)?;
        BenchReport::from_json(&j)
    }

    /// Build a report from parsed JSON (see [`BenchReport::parse`]).
    pub fn from_json(j: &Json) -> Result<BenchReport, ReportError> {
        let found = j
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| ReportError::Malformed("missing \"schema_version\"".into()))?
            as u64;
        if found != SCHEMA_VERSION {
            return Err(ReportError::SchemaVersion { found, expected: SCHEMA_VERSION });
        }
        let presets_json = j.arr_field("presets").map_err(ReportError::Malformed)?;
        let presets =
            presets_json.iter().map(PresetReport::from_json).collect::<Result<Vec<_>, _>>()?;
        let kernel = str_of(j, "kernel")?;
        // Machine-identity strings are validated up front with the typed
        // error naming the offending key: a baseline produced by an
        // unknown kernel or device must refuse to gate, not silently
        // compare timings across machines.
        if Kernel::parse(&kernel).is_none() {
            return Err(ReportError::UnknownValue { key: "kernel".into(), value: kernel });
        }
        for p in &presets {
            if !KNOWN_DEVICES.contains(&p.energy.device.as_str()) {
                return Err(ReportError::UnknownValue {
                    key: format!("{}.energy.device", p.spec),
                    value: p.energy.device.clone(),
                });
            }
        }
        Ok(BenchReport {
            schema_version: found,
            bench: str_of(j, "bench")?,
            mode: str_of(j, "mode")?,
            kernel,
            batch: j.usize_field("batch").map_err(ReportError::Malformed)?,
            conv_ratios: map_from_json(j, "conv_ratios")?,
            presets,
        })
    }

    /// Load a `BENCH_*.json` file.
    pub fn load(path: &Path) -> Result<BenchReport, ReportError> {
        let text = std::fs::read_to_string(path).map_err(|e| ReportError::Io {
            path: path.display().to_string(),
            error: e.to_string(),
        })?;
        BenchReport::parse(&text)
    }

    /// The report as indented, key-sorted JSON (the committed-baseline
    /// format — reviewable diffs, stable across regeneration).
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        pretty(&self.to_json(), 0, &mut out);
        out.push('\n');
        out
    }

    /// Write the report to `path` (parent directories created) in the
    /// [`BenchReport::to_pretty_string`] format.
    pub fn save(&self, path: &Path) -> Result<(), ReportError> {
        let io = |e: std::io::Error| ReportError::Io {
            path: path.display().to_string(),
            error: e.to_string(),
        };
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(io)?;
            }
        }
        std::fs::write(path, self.to_pretty_string()).map_err(io)
    }

    /// The preset section for `spec`, if recorded.
    pub fn preset(&self, spec: &str) -> Option<&PresetReport> {
        self.presets.iter().find(|p| p.spec == spec)
    }
}

// ---------------------------------------------------------------------------
// the regression gate
// ---------------------------------------------------------------------------

/// Per-class tolerances the gate applies (`docs/BENCHMARKS.md` policy).
#[derive(Debug, Clone, Copy)]
pub struct Tolerance {
    /// Multiplicative band for ratio metrics: a fresh ratio must land in
    /// `[baseline / band, baseline × band]`. Wide by design — smoke runs
    /// on shared CI runners are noisy; the gate catches collapses, not
    /// jitter.
    pub ratio_band: f64,
    /// Relative tolerance for deterministic ledger values (effectively
    /// exact; the slack only absorbs decimal round-tripping).
    pub exact_rel: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance { ratio_band: 8.0, exact_rel: 1e-12 }
    }
}

/// How a metric is compared (and displayed) by the gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricClass {
    /// Machine-dependent timing — informational, never fails the gate.
    Timing,
    /// Speedup ratio — wide multiplicative band.
    Ratio,
    /// Deterministic ledger value — exact.
    Exact,
}

impl fmt::Display for MetricClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            MetricClass::Timing => "timing",
            MetricClass::Ratio => "ratio",
            MetricClass::Exact => "exact",
        })
    }
}

/// One compared metric: baseline vs fresh value and the verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct Diff {
    /// Dotted metric path, e.g. `resnet-tiny-w8-b1.ratios.bwd_speedup_d80`.
    pub metric: String,
    /// Comparison class applied.
    pub class: MetricClass,
    /// Value in the baseline report.
    pub baseline: f64,
    /// Value in the fresh report.
    pub fresh: f64,
    /// Whether the metric is within tolerance.
    pub ok: bool,
}

/// Outcome of gating a fresh report against a baseline.
#[derive(Debug, Clone, Default)]
pub struct GateResult {
    /// Every compared metric (timings included, informational).
    pub diffs: Vec<Diff>,
    /// Structural failures: presets or metrics the fresh report lacks,
    /// device mismatches.
    pub problems: Vec<String>,
}

impl GateResult {
    /// True when no structural problems and every gated metric passed.
    pub fn passed(&self) -> bool {
        self.problems.is_empty() && self.diffs.iter().all(|d| d.ok)
    }

    /// Human-readable failure lines (empty when [`GateResult::passed`]).
    pub fn failures(&self) -> Vec<String> {
        let mut out = self.problems.clone();
        for d in self.diffs.iter().filter(|d| !d.ok) {
            out.push(format!(
                "{} ({}): baseline {} vs fresh {}",
                d.metric, d.class, d.baseline, d.fresh
            ));
        }
        out
    }
}

fn ratio_ok(baseline: f64, fresh: f64, band: f64) -> bool {
    baseline.is_finite()
        && fresh.is_finite()
        && baseline > 0.0
        && fresh > 0.0
        && fresh <= baseline * band
        && fresh >= baseline / band
}

fn exact_ok(baseline: f64, fresh: f64, rel: f64) -> bool {
    let scale = baseline.abs().max(fresh.abs()).max(1.0);
    (fresh - baseline).abs() <= rel * scale
}

fn diff_maps(
    out: &mut GateResult,
    prefix: &str,
    class: MetricClass,
    baseline: &BTreeMap<String, f64>,
    fresh: &BTreeMap<String, f64>,
    tol: &Tolerance,
) {
    for (k, &b) in baseline {
        let metric = format!("{prefix}.{k}");
        match fresh.get(k) {
            None if class == MetricClass::Timing => {} // informational anyway
            None => out.problems.push(format!("{metric}: missing from fresh report")),
            Some(&f) => {
                let ok = match class {
                    MetricClass::Timing => true,
                    MetricClass::Ratio => ratio_ok(b, f, tol.ratio_band),
                    MetricClass::Exact => exact_ok(b, f, tol.exact_rel),
                };
                out.diffs.push(Diff { metric, class, baseline: b, fresh: f, ok });
            }
        }
    }
}

/// Gate `fresh` against `baseline`: every baseline metric is looked up in
/// the fresh report and compared per its class. Extra metrics/presets in
/// the fresh report are ignored (forward-compatible); metrics *missing*
/// from it are structural failures.
pub fn gate(baseline: &BenchReport, fresh: &BenchReport, tol: &Tolerance) -> GateResult {
    let mut out = GateResult::default();
    out.diffs.push(Diff {
        metric: "batch".into(),
        class: MetricClass::Exact,
        baseline: baseline.batch as f64,
        fresh: fresh.batch as f64,
        ok: baseline.batch == fresh.batch,
    });
    if baseline.kernel != fresh.kernel {
        out.problems.push(format!(
            "kernel: baseline {:?} vs fresh {:?} (different dispatch — regenerate the \
             baseline or set SSPROP_GEMM_KERNEL to match)",
            baseline.kernel, fresh.kernel
        ));
    }
    diff_maps(
        &mut out,
        "conv_ratios",
        MetricClass::Ratio,
        &baseline.conv_ratios,
        &fresh.conv_ratios,
        tol,
    );
    for bp in &baseline.presets {
        let Some(fp) = fresh.preset(&bp.spec) else {
            out.problems.push(format!("preset {:?}: missing from fresh report", bp.spec));
            continue;
        };
        let p = &bp.spec;
        let timings = format!("{p}.timings_ns");
        diff_maps(&mut out, &timings, MetricClass::Timing, &bp.timings_ns, &fp.timings_ns, tol);
        let ratios = format!("{p}.ratios");
        diff_maps(&mut out, &ratios, MetricClass::Ratio, &bp.ratios, &fp.ratios, tol);
        if bp.energy.device != fp.energy.device {
            out.problems.push(format!(
                "{p}.energy.device: baseline {:?} vs fresh {:?}",
                bp.energy.device, fp.energy.device
            ));
        }
        let exact = [
            ("flops.bwd_dense", bp.flops.bwd_dense, fp.flops.bwd_dense),
            ("flops.bwd_d80", bp.flops.bwd_d80, fp.flops.bwd_d80),
            ("flops.saving_frac", bp.flops.saving_frac, fp.flops.saving_frac),
            ("energy.dense_j", bp.energy.dense_j, fp.energy.dense_j),
            ("energy.d80_j", bp.energy.d80_j, fp.energy.d80_j),
            ("energy.saved_j", bp.energy.saved_j, fp.energy.saved_j),
        ];
        for (name, b, f) in exact {
            out.diffs.push(Diff {
                metric: format!("{p}.{name}"),
                class: MetricClass::Exact,
                baseline: b,
                fresh: f,
                ok: exact_ok(b, f, tol.exact_rel),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// trajectory
// ---------------------------------------------------------------------------

/// Render labelled reports (oldest first) as a perf/energy trajectory
/// table: one row per (report, preset) with the step times, the best
/// parallel speedup, and the ledger columns.
pub fn trajectory(entries: &[(String, BenchReport)]) -> Table {
    let headers =
        ["report", "preset", "serial dense", "serial d80", "par d80 t4", "GFLOPs", "saved J"];
    let mut t = Table::new("Perf/energy trajectory", &headers);
    for (label, rep) in entries {
        for p in &rep.presets {
            let timing =
                |k: &str| p.timings_ns.get(k).map(|&n| fmt_ns(n)).unwrap_or_else(|| "-".into());
            let ratio = |k: &str| {
                p.ratios.get(k).map(|r| format!("{r:.2}x")).unwrap_or_else(|| "-".into())
            };
            t.row(vec![
                label.clone(),
                p.spec.clone(),
                timing("serial_step_dense_ns"),
                timing("serial_step_d80_ns"),
                ratio("parallel_speedup_d80_t4"),
                format!("{:.3}", p.flops.bwd_dense / 1e9),
                format!("{:.6}", p.energy.saved_j),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_preset(spec: &str) -> PresetReport {
        let (flops, energy) = preset_ledger(spec, BENCH_BATCH).unwrap();
        let mut timings_ns = BTreeMap::new();
        timings_ns.insert("serial_step_dense_ns".into(), 5e6);
        timings_ns.insert("serial_step_d80_ns".into(), 3e6);
        let mut ratios = BTreeMap::new();
        ratios.insert("bwd_speedup_d80".into(), 5e6 / 3e6);
        ratios.insert("parallel_speedup_dense_t2".into(), 1.5);
        PresetReport { spec: spec.into(), timings_ns, ratios, flops, energy }
    }

    fn sample_report() -> BenchReport {
        let mut r = BenchReport::new("native_hotpath", "smoke");
        r.conv_ratios.insert("fused_speedup_dense".into(), 1.5);
        r.presets.push(sample_preset("simple-cnn-d4-w16"));
        r
    }

    #[test]
    fn ledger_is_deterministic_and_consistent() {
        let (f1, e1) = preset_ledger("vgg-tiny-w8", 32).unwrap();
        let (f2, e2) = preset_ledger("vgg-tiny", 32).unwrap(); // canonicalizes
        assert_eq!(f1, f2);
        assert_eq!(e1, e2);
        assert!(f1.bwd_d80 < f1.bwd_dense);
        assert!((f1.saving_frac - (1.0 - f1.bwd_d80 / f1.bwd_dense)).abs() == 0.0);
        // joules ledger is the estimate() of the same FLOPs
        assert_eq!(e1.dense_j, estimate(f1.bwd_dense, &RTX_A5000).joules());
        assert_eq!(e1.saved_j, estimate(f1.bwd_dense - f1.bwd_d80, &RTX_A5000).joules());
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let r = sample_report();
        let text = r.to_json().to_string();
        assert_eq!(BenchReport::parse(&text).unwrap(), r);
        // the committed-baseline pretty form parses to the same report
        let pretty = r.to_pretty_string();
        assert!(pretty.ends_with("}\n"));
        assert_eq!(BenchReport::parse(&pretty).unwrap(), r);
    }

    #[test]
    fn gate_passes_identical_and_fails_perturbed() {
        let base = sample_report();
        assert!(gate(&base, &base, &Tolerance::default()).passed());

        // timings may drift arbitrarily
        let mut timing_drift = base.clone();
        *timing_drift.presets[0].timings_ns.get_mut("serial_step_dense_ns").unwrap() *= 40.0;
        assert!(gate(&base, &timing_drift, &Tolerance::default()).passed());

        // a collapsed ratio fails
        let mut slow = base.clone();
        *slow.presets[0].ratios.get_mut("parallel_speedup_dense_t2").unwrap() = 0.01;
        let res = gate(&base, &slow, &Tolerance::default());
        assert!(!res.passed());
        let fails = res.failures();
        assert!(fails.iter().any(|f| f.contains("parallel_speedup_dense_t2")), "{fails:?}");

        // a changed deterministic ledger value fails
        let mut drift = base.clone();
        drift.presets[0].flops.bwd_dense += 1.0;
        assert!(!gate(&base, &drift, &Tolerance::default()).passed());
    }

    #[test]
    fn gate_flags_missing_presets_and_metrics() {
        let base = sample_report();
        let mut empty = base.clone();
        empty.presets.clear();
        let res = gate(&base, &empty, &Tolerance::default());
        assert!(!res.passed());
        assert!(res.problems[0].contains("simple-cnn-d4-w16"));

        let mut no_ratio = base.clone();
        no_ratio.conv_ratios.clear();
        assert!(!gate(&base, &no_ratio, &Tolerance::default()).passed());
    }

    #[test]
    fn schema_version_mismatch_is_typed() {
        let mut j = sample_report().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".into(), Json::Num(99.0));
        }
        let err = BenchReport::parse(&j.to_string()).unwrap_err();
        assert_eq!(err, ReportError::SchemaVersion { found: 99, expected: SCHEMA_VERSION });
    }

    #[test]
    fn report_records_the_active_kernel() {
        let r = sample_report();
        assert_eq!(r.kernel, Kernel::active().name());
        assert!(Kernel::parse(&r.kernel).is_some());
    }

    #[test]
    fn unknown_kernel_or_device_is_refused_with_the_offending_key() {
        // an unknown kernel string must not gate silently
        let mut j = sample_report().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("kernel".into(), Json::Str("quantum".into()));
        }
        let err = BenchReport::parse(&j.to_string()).unwrap_err();
        assert_eq!(
            err,
            ReportError::UnknownValue { key: "kernel".into(), value: "quantum".into() }
        );
        assert!(err.to_string().contains("kernel"), "{err}");

        // ... and neither must an unknown device profile
        let mut bad_dev = sample_report();
        bad_dev.presets[0].energy.device = "Abacus 9000".into();
        let err = BenchReport::parse(&bad_dev.to_json().to_string()).unwrap_err();
        assert_eq!(
            err,
            ReportError::UnknownValue {
                key: "simple-cnn-d4-w16.energy.device".into(),
                value: "Abacus 9000".into(),
            }
        );
        assert!(err.to_string().contains("energy.device"), "{err}");
    }

    #[test]
    fn gate_fails_kernel_mismatch_as_structural_problem() {
        let base = sample_report();
        let mut other = base.clone();
        other.kernel = if base.kernel == "scalar" { "avx2".into() } else { "scalar".into() };
        let res = gate(&base, &other, &Tolerance::default());
        assert!(!res.passed());
        assert!(res.problems.iter().any(|p| p.contains("kernel")), "{:?}", res.problems);
    }

    #[test]
    fn trajectory_renders_a_row_per_preset() {
        let r = sample_report();
        let t = trajectory(&[("PR6".into(), r.clone()), ("PR7".into(), r)]);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("simple-cnn-d4-w16"));
    }
}
