//! Model-zoo regression suite: non-SimpleCNN presets must train end-to-end
//! through the coordinator with the sparse backward engaged, the paper's
//! ssProp+Dropout compatibility claim must hold (finite losses, kept
//! channels exactly matching the schedule), the data-parallel executor
//! must drive any layer graph (MaxPool scatter, Dropout masks, residual
//! Add merges, BatchNorm statistics) with the same determinism contract
//! the SimpleCNN path has, and the native `resnet-tiny` ledger must match
//! the paper-style analytic hand count.

use ssprop::backend::{
    build_model, parse_model_spec, ExecConfig, NativeBackend, ParallelExecutor, Sequential,
};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::flops::{keep_channels, paper_resnet, tiny_resnet};
use ssprop::schedule::{DropScheduler, Schedule};
use ssprop::util::rng::Pcg;

fn build(spec: &str) -> Sequential {
    let parsed = parse_model_spec(spec).unwrap();
    build_model(&parsed, 1, 12, 4, 33).unwrap()
}

fn batch(bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Pcg::new(seed, 2);
    let x = (0..bt * 144).map(|_| rng.normal()).collect();
    let y = (0..bt).map(|i| (i % 4) as i32).collect();
    (x, y)
}

/// Expected kept-channel count at drop rate `d` for a model's conv stack.
fn expected_kept(m: &Sequential, d: f64) -> usize {
    let set = m.layer_set();
    set.convs.iter().map(|c| keep_channels(c.cout, d)).sum()
}

#[test]
fn zoo_presets_train_end_to_end_with_sparse_backward() {
    // MaxPool, Dropout, and the residual/BatchNorm family — the
    // acceptance trio
    for model in ["vgg-tiny-w4", "dropout-cnn-w6-p25", "resnet-tiny-w4-b1"] {
        let mut cfg = NativeTrainConfig::quick("mnist", 2, 6);
        cfg.batch = 8;
        cfg.model = model.to_string();
        cfg.scheduler = DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, 2, 6);
        let mut t = NativeTrainer::new(cfg).unwrap();
        let (loss, acc) = t.run().unwrap();
        assert!(loss.is_finite(), "{model}: final loss {loss}");
        assert!((0.0..=1.0).contains(&acc), "{model}: acc {acc}");
        assert!(t.metrics.losses.iter().all(|l| l.is_finite()), "{model}: training losses");
        assert!(
            t.metrics.flops_actual < t.metrics.flops_dense,
            "{model}: the sparse epochs must show up in the ledger"
        );
        assert_eq!(t.model_spec, model, "{model}: resolved spec is recorded");
    }
}

#[test]
fn dropout_composes_with_ssprop_and_kept_channels_match_schedule() {
    let be = NativeBackend::new();
    let mut m = build("dropout-cnn-w6-p40");
    let (x, y) = batch(8, 11);
    for (step, d) in [0.0f64, 0.5, 0.8, 0.8, 0.0].iter().enumerate() {
        let stats = m.train_step(&be, &x, &y, *d, 0.05).unwrap();
        assert!(stats.loss.is_finite(), "step {step} at d={d}");
        assert_eq!(
            stats.kept_channels,
            expected_kept(&m, *d),
            "step {step}: selection must follow the schedule exactly at d={d}"
        );
        assert_eq!(stats.total_channels, 12, "two conv layers of width 6");
    }
    // eval runs dropout as the identity, so it is deterministic
    let e1 = m.eval_batch(&be, &x, &y);
    let e2 = m.eval_batch(&be, &x, &y);
    assert_eq!(e1, e2, "eval must not draw dropout masks");
}

#[test]
fn dropout_masks_make_sharded_training_match_serial() {
    // Dropout masks key on the global example index, so a 1-worker
    // executor run is bit-identical to serial even though masks are drawn
    // per step; multi-worker runs agree within float re-association.
    let be = NativeBackend::new();
    let data: Vec<_> = (0..6).map(|i| batch(8, 100 + i)).collect();

    let mut serial = build("dropout-cnn-w6-p25");
    let mut one = build("dropout-cnn-w6-p25");
    let mut exec1 = ParallelExecutor::new(ExecConfig::with_threads(1));
    for (step, (x, y)) in data.iter().enumerate() {
        let d = if step % 2 == 0 { 0.8 } else { 0.0 };
        let a = serial.train_step(&be, x, y, d, 0.05).unwrap();
        let b = exec1.train_step(&mut one, &be, x, y, d, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}: t1 loss bits");
        assert_eq!(serial.flat_params(), one.flat_params(), "step {step}: t1 params");
    }

    for threads in [2usize, 4] {
        let mut m = build("dropout-cnn-w6-p25");
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        let mut reference = build("dropout-cnn-w6-p25");
        for (step, (x, y)) in data.iter().enumerate() {
            let d = if step % 2 == 0 { 0.8 } else { 0.0 };
            let a = reference.train_step(&be, x, y, d, 0.05).unwrap();
            let b = exec.train_step(&mut m, &be, x, y, d, 0.05).unwrap();
            assert!(
                (a.loss - b.loss).abs() < 1e-5,
                "t{threads} step {step}: {} vs {}",
                a.loss,
                b.loss
            );
            assert_eq!(a.kept_channels, b.kept_channels, "t{threads} step {step}: selection");
        }
    }
}

#[test]
fn maxpool_graph_is_deterministic_across_thread_counts() {
    let be = NativeBackend::new();
    let data: Vec<_> = (0..6).map(|i| batch(12, 200 + i)).collect();

    let mut serial = build("vgg-tiny-w4");
    let mut stats_serial = Vec::new();
    for (step, (x, y)) in data.iter().enumerate() {
        let d = if step % 2 == 0 { 0.0 } else { 0.8 };
        stats_serial.push(serial.train_step(&be, x, y, d, 0.05).unwrap());
    }

    for threads in [1usize, 2, 4] {
        let mut m = build("vgg-tiny-w4");
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        for (step, (x, y)) in data.iter().enumerate() {
            let d = if step % 2 == 0 { 0.0 } else { 0.8 };
            let got = exec.train_step(&mut m, &be, x, y, d, 0.05).unwrap();
            let want = &stats_serial[step];
            assert!(
                (got.loss - want.loss).abs() < 1e-5,
                "t{threads} step {step}: {} vs {}",
                got.loss,
                want.loss
            );
            assert_eq!(got.kept_channels, want.kept_channels, "t{threads} step {step}");
        }
        // sharded eval through the pooled graph is bitwise too
        let (x, y) = &data[0];
        let want = serial.eval_batch(&be, x, y);
        let got = exec.eval_batch(&m, &be, x, y);
        // models differ (training re-association), so compare m's own eval
        let own = m.eval_batch(&be, x, y);
        assert_eq!(got.0.to_bits(), own.0.to_bits(), "t{threads}: eval bits");
        assert!((got.0 - want.0).abs() < 1e-3, "t{threads}: eval near serial");
    }
}

#[test]
fn resnet_tiny_ledger_matches_paper_style_hand_count() {
    // The native graph's self-reported inventory (`Graph::layer_set`,
    // which is exactly what the trainer's TrainMetrics ledger consumes)
    // vs the analytic construction — and, at w8-b2, vs paper_resnet's
    // ResNet-18 at 1/8 width. Satellite acceptance: within 0.1%.
    for (spec, w, b) in [("resnet-tiny-w8-b2", 8usize, 2usize), ("resnet-tiny-w4-b1", 4, 1)] {
        let parsed = parse_model_spec(spec).unwrap();
        let native = build_model(&parsed, 3, 32, 10, 7).unwrap().layer_set();
        let hand = tiny_resnet(w, b, 32, 3);
        assert_eq!(native.convs.len(), hand.convs.len(), "{spec}: conv inventory size");
        let counted = |s: &ssprop::flops::LayerSet| s.convs.iter().filter(|c| c.counted_bn).count();
        assert_eq!(counted(&native), counted(&hand), "{spec}: BN accounting");
        for (bt, d) in [(128usize, 0.0f64), (128, 0.8), (16, 0.5)] {
            let (a, h) = (native.bwd_flops_per_iter(bt, d), hand.bwd_flops_per_iter(bt, d));
            let rel = (a - h).abs() / h;
            assert!(rel < 1e-3, "{spec} bt{bt} d{d}: native {a} vs hand {h} (rel {rel})");
        }
    }
    // chain the check through to the paper tables: w8-b2 == resnet18/8
    let native = build_model(&parse_model_spec("resnet-tiny-w8-b2").unwrap(), 3, 32, 10, 7)
        .unwrap()
        .layer_set();
    let paper = paper_resnet("resnet18", 32, 3, 0.125);
    let rel = (native.bwd_flops_per_iter(128, 0.0) - paper.bwd_flops_per_iter(128, 0.0)).abs()
        / paper.bwd_flops_per_iter(128, 0.0);
    assert!(rel < 1e-3, "native vs paper_resnet: rel {rel}");
}

#[test]
fn resnet_tiny_trains_serially_and_sharded_with_matching_selection() {
    let be = NativeBackend::new();
    let data: Vec<_> = (0..4).map(|i| batch(8, 300 + i)).collect();

    let mut serial = build("resnet-tiny-w4-b1");
    let mut stats_serial = Vec::new();
    for (step, (x, y)) in data.iter().enumerate() {
        let d = if step % 2 == 0 { 0.0 } else { 0.8 };
        stats_serial.push(serial.train_step(&be, x, y, d, 0.05).unwrap());
    }
    assert!(stats_serial.iter().all(|s| s.loss.is_finite()));
    let expected_sparse = expected_kept(&serial, 0.8);
    assert_eq!(stats_serial[1].kept_channels, expected_sparse, "proj convs select too");

    for threads in [2usize, 4] {
        let mut m = build("resnet-tiny-w4-b1");
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        for (step, (x, y)) in data.iter().enumerate() {
            let d = if step % 2 == 0 { 0.0 } else { 0.8 };
            let got = exec.train_step(&mut m, &be, x, y, d, 0.05).unwrap();
            let want = &stats_serial[step];
            assert!(
                (got.loss - want.loss).abs() < 1e-4,
                "t{threads} step {step}: {} vs {}",
                got.loss,
                want.loss
            );
            assert_eq!(got.kept_channels, want.kept_channels, "t{threads} step {step}");
        }
        // sharded eval through the residual graph stays bitwise vs its
        // own serial eval (running-stat BN is per-example)
        let (x, y) = &data[0];
        let own = m.eval_batch(&be, x, y);
        let got = exec.eval_batch(&m, &be, x, y);
        assert_eq!(got.0.to_bits(), own.0.to_bits(), "t{threads}: eval bits");
    }
}

#[test]
fn resnet_tiny_checkpoint_roundtrips_bn_running_stats() {
    let dir = std::env::temp_dir().join("ssprop_zoo_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet-tiny-w4-b1.tstore");
    let mut cfg = NativeTrainConfig::quick("mnist", 1, 2);
    cfg.batch = 8;
    cfg.model = "resnet-tiny-w4-b1".to_string();
    let mut a = NativeTrainer::new(cfg.clone()).unwrap();
    a.run().unwrap();
    a.save_checkpoint(&path, 1).unwrap();

    // the checkpoint carries the BN running statistics under stable names
    let names: Vec<String> = a.model.state_tensors().into_iter().map(|(n, _)| n).collect();
    for leaf in ["param['stem.bn.rm']", "param['stem.bn.rv']", "param['s1b0.bn2.w']"] {
        assert!(names.iter().any(|n| n == leaf), "{leaf} missing from {names:?}");
    }

    let mut b = NativeTrainer::new(cfg).unwrap();
    assert_ne!(a.model.flat_params(), b.model.flat_params(), "training moved the state");
    assert_eq!(b.load_checkpoint(&path).unwrap(), 1);
    assert_eq!(a.model.flat_params(), b.model.flat_params(), "params + running stats restored");
    assert_eq!(a.evaluate(), b.evaluate(), "eval (running-stat BN) restored");
}

#[test]
fn checkpoints_roundtrip_for_zoo_models() {
    let dir = std::env::temp_dir().join("ssprop_zoo_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    for model in ["vgg-tiny-w4", "dropout-cnn-w6-p25"] {
        let path = dir.join(format!("{model}.tstore"));
        let mut cfg = NativeTrainConfig::quick("mnist", 1, 2);
        cfg.batch = 8;
        cfg.model = model.to_string();
        let mut a = NativeTrainer::new(cfg.clone()).unwrap();
        a.run().unwrap();
        a.save_checkpoint(&path, 1).unwrap();

        let mut b = NativeTrainer::new(cfg).unwrap();
        assert_eq!(b.load_checkpoint(&path).unwrap(), 1);
        assert_eq!(a.model.flat_params(), b.model.flat_params(), "{model}: params restored");
        assert_eq!(a.evaluate(), b.evaluate(), "{model}: eval restored");
    }
}
