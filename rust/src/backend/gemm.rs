//! Cache-blocked, register-tiled f32 GEMM — the kernel behind the native
//! backend's im2col convolutions (the ROADMAP's "single biggest lever
//! `native_hotpath` can measure").
//!
//! The decomposition is the classic panel-packing one: the depth
//! dimension is split into [`KC`]-sized blocks; each block's B rows are
//! packed into `nr`-wide column panels ([`NR`] or [`NR2`], see below) and
//! its A rows into [`MR`]-wide row panels; a fixed MR×nr register tile
//! then walks the packed panels. Packing makes both microkernel operands
//! contiguous streaming reads, with the panel sizes chosen so one A panel
//! plus one B panel sit in L1 while a whole packed A block
//! ([`MC`]×[`KC`]) stays L2-resident. Edge tiles are zero-padded during
//! packing (and only edge tiles — full tiles are plain copies), so the
//! microkernel itself never branches on shape.
//!
//! # Kernel dispatch
//!
//! The register tile is implemented by a family of microkernels behind
//! the [`Kernel`] descriptor: a portable scalar kernel (the reference),
//! plus explicit x86-64 SIMD kernels (SSE2 and AVX2) that vectorize
//! **across the NR (output-column) dimension** with separate mul+add —
//! never FMA contraction — so every output element keeps the exact
//! scalar per-element operation sequence. The kernel is selected once
//! per process via [`Kernel::active`] (`is_x86_feature_detected!` at
//! first use, overridable with `SSPROP_GEMM_KERNEL={scalar,sse2,avx2}`
//! for CI A/B runs) and never re-read, so every worker thread agrees.
//!
//! # Tile width
//!
//! B panels pack at two widths: narrow [`NR`] = 8 and wide [`NR2`] = 16.
//! The width is chosen by [`nr_for`] as a pure function of the GEMM's
//! output-column count — for the sparse dW GEMM the output columns *are*
//! the ssProp kept channels, so small keep sets (high-sparsity epochs)
//! stay on the narrow tile while dense/low-sparsity steps take the wide
//! one. Width never depends on timing, and (because column lanes are
//! independent) never changes a single output bit.
//!
//! Two properties the rest of the crate leans on:
//!
//! * **Deterministic accumulation.** Every output element accumulates its
//!   depth products in strictly increasing depth order — KC blocks in
//!   order, in-order within each block — so results do not depend on the
//!   kernel, the tile width, or how the blocking parameters land on a
//!   given shape, are identical from run to run, and (the kernel is
//!   single-threaded; the parallel executor shards *batches*, never a
//!   GEMM) stay bit-identical per worker-thread count. For depths ≤
//!   [`KC`] the summation order is exactly the naive triple loop's
//!   ([`gemm_ref`]). SIMD lanes map to output columns, and each lane does
//!   one mul then one add per depth step — the same two roundings, in the
//!   same order, as the scalar chain — so scalar/SSE2/AVX2 and NR8/NR16
//!   outputs are bitwise equal always.
//! * **Dense semantics.** There is no value-based zero skipping (the old
//!   naive kernel skipped `a == 0.0` terms, silently swallowing NaN/Inf
//!   from the B operand). Sparsity enters only *structurally*: the
//!   [`Operand::KeptChannels`] / [`Operand::KeptRows`] views fuse the
//!   ssProp `keep_idx` gather into the packing stage, so the compacted
//!   backward GEMMs never read, pack, or multiply a dropped channel's
//!   rows at all — zero by construction, not by test.

use std::sync::OnceLock;

/// Rows of the register tile (width of a packed A panel).
pub const MR: usize = 4;
/// Narrow columns of the register tile (width of a narrow packed B
/// panel). Kept small on purpose: the dW GEMM's output columns are the
/// *kept channels*, so a wide tile would pad small keep sets back up to
/// dense-width work.
pub const NR: usize = 8;
/// Wide columns of the register tile: two AVX2 vectors per tile row.
/// [`nr_for`] picks this width when the output-column count (the keep
/// count, for the sparse dW GEMM) fills at least one wide panel.
pub const NR2: usize = 16;
/// Depth block: one A panel (MR×KC) plus one wide B panel (KC×NR2) is
/// 20 KiB — comfortably L1-resident.
const KC: usize = 256;
/// Row block: the packed A block (MC×KC, 64 KiB) stays L2-resident.
const MC: usize = 64;
/// Column block: bounds the packed B block (KC×NC) at 1 MiB.
const NC: usize = 1024;

/// The microkernel accumulator: one wide tile, of which only the first
/// `nr` lanes of each row are packed/meaningful. Narrow-width kernels
/// simply leave the upper lanes at zero; write-back never reads past the
/// live column count anyway.
type Acc = [[f32; NR2]; MR];

/// One microkernel implementation, selected once per process. All
/// variants produce bitwise-identical output (see the module docs); they
/// differ only in how many output-column lanes each instruction covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar reference tile (any target).
    Scalar,
    /// x86-64 SSE2 tile: 4-lane `__m128` vectors across the columns.
    /// SSE2 is part of the x86-64 baseline, so this is the portable
    /// x86-64 fallback.
    Sse2,
    /// x86-64 AVX2 tile: 8-lane `__m256` vectors across the columns
    /// (one per tile row at NR=8, two at NR=16).
    Avx2,
}

/// The once-resolved process-wide kernel choice (see [`Kernel::active`]).
static ACTIVE_KERNEL: OnceLock<Kernel> = OnceLock::new();

impl Kernel {
    /// Every kernel in preference order (fastest first). Test suites walk
    /// this, filtered by [`Kernel::available`].
    pub const ALL: [Kernel; 3] = [Kernel::Avx2, Kernel::Sse2, Kernel::Scalar];

    /// The kernel's `SSPROP_GEMM_KERNEL` / report name.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Sse2 => "sse2",
            Kernel::Avx2 => "avx2",
        }
    }

    /// Parse a `SSPROP_GEMM_KERNEL` / report name.
    pub fn parse(name: &str) -> Option<Kernel> {
        match name {
            "scalar" => Some(Kernel::Scalar),
            "sse2" => Some(Kernel::Sse2),
            "avx2" => Some(Kernel::Avx2),
            _ => None,
        }
    }

    /// Whether this kernel can run on the current host.
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Sse2 => cfg!(target_arch = "x86_64"),
            Kernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
        }
    }

    /// The best kernel the current host supports (AVX2 → SSE2 → scalar).
    pub fn detect() -> Kernel {
        Kernel::ALL.into_iter().find(|k| k.available()).unwrap_or(Kernel::Scalar)
    }

    /// The process-wide kernel, resolved exactly once: the
    /// `SSPROP_GEMM_KERNEL` override if set (panicking loudly on an
    /// unknown name or a kernel this host cannot run — a silent fallback
    /// would fake CI A/B results), else [`Kernel::detect`]. Pool and
    /// executor constructors force this before spawning workers, so
    /// every worker reads the same settled value.
    pub fn active() -> Kernel {
        *ACTIVE_KERNEL.get_or_init(|| match std::env::var("SSPROP_GEMM_KERNEL") {
            Ok(name) => {
                let k = Kernel::parse(&name).unwrap_or_else(|| {
                    panic!(
                        "SSPROP_GEMM_KERNEL={name:?}: unknown kernel \
                         (expected scalar, sse2, or avx2)"
                    )
                });
                assert!(
                    k.available(),
                    "SSPROP_GEMM_KERNEL={name:?}: kernel is not supported on this host"
                );
                k
            }
            Err(_) => Kernel::detect(),
        })
    }
}

/// Tile width for a GEMM with `out_cols` output columns — the keep-count
/// heuristic. For the sparse dW GEMM the output columns are the kept
/// channels, so small keep sets stay on the narrow tile (no padding a
/// 3-channel keep set up to 16 lanes of work) while dense and
/// low-sparsity steps take the wide one. A pure function of shape —
/// never timing — so runs stay reproducible; and since column lanes are
/// independent, the choice never changes output bits.
pub fn nr_for(out_cols: usize) -> usize {
    if out_cols >= NR2 {
        NR2
    } else {
        NR
    }
}

/// Reusable packing buffers for [`gemm_into`]. Each plan/workspace owns
/// its own pack, so the parallel executor's per-worker plans stay
/// lock-free and the steady-state hot loop allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct GemmPack {
    /// Packed A block: up to MC/MR panels of KC×MR.
    pa: Vec<f32>,
    /// Packed B block: up to NC/nr panels of KC×nr, sized for whichever
    /// tile width ([`NR`] or [`NR2`]) the current call packs at.
    pb: Vec<f32>,
}

impl GemmPack {
    /// A fresh, empty pack (panel buffers grow lazily on first use).
    pub fn new() -> GemmPack {
        GemmPack::default()
    }

    /// Capacity of the two panel buffers (packed A, packed B); the
    /// workspace-reuse tests pin these flat across steady-state steps.
    pub fn caps(&self) -> [usize; 2] {
        [self.pa.capacity(), self.pb.capacity()]
    }
}

/// A read-only GEMM operand: how the packing stage reads logical element
/// (row, col) of a (rows × cols) matrix. The dense layouts index straight
/// into the slice; the `Kept*` views are what makes the backward GEMMs
/// sparsity-aware — they gather only the ssProp `keep_idx` channels while
/// packing, so dropped channels contribute no reads and no FLOPs.
#[derive(Debug, Clone, Copy)]
pub enum Operand<'a> {
    /// Row-major (rows × cols) matrix.
    Dense(&'a [f32]),
    /// Transposed view: the slice holds the (cols × rows) row-major
    /// underlying matrix; element (r, c) reads `data[c * rows + r]`.
    Transposed(&'a [f32]),
    /// Kept output channels of an NCHW gradient as the compacted
    /// (Bt·Ho·Wo × k') col-form matrix `col[dY]'`: element (r, c) reads
    /// plane `keep[c]` of image `r / hw` at pixel `r % hw`.
    KeptChannels {
        /// NCHW gradient, length (rows / `hw`) · `cout` · `hw`.
        g: &'a [f32],
        /// Kept channel indices (each < `cout`); the logical column axis.
        keep: &'a [usize],
        /// Total output channels in `g`.
        cout: usize,
        /// Spatial plane size Ho·Wo.
        hw: usize,
    },
    /// Kept rows of a row-major matrix: logical row r is underlying row
    /// `keep[r]` (the compacted OIHW weight view `col_W'ᵀ`).
    KeptRows {
        /// Underlying row-major matrix, rows of length cols.
        data: &'a [f32],
        /// Kept row indices; the logical row axis.
        keep: &'a [usize],
    },
}

impl Operand<'_> {
    /// Validate the operand against its logical (rows × cols) shape.
    fn check(&self, rows: usize, cols: usize, side: &str) {
        match *self {
            Operand::Dense(d) | Operand::Transposed(d) => {
                assert_eq!(d.len(), rows * cols, "{side}: operand length");
            }
            Operand::KeptChannels { g, keep, cout, hw } => {
                assert_eq!(keep.len(), cols, "{side}: kept-channel count");
                assert!(hw > 0 && rows % hw == 0, "{side}: rows must be whole planes");
                assert_eq!(g.len(), (rows / hw) * cout * hw, "{side}: NCHW gradient length");
                assert!(keep.iter().all(|&o| o < cout), "{side}: keep index out of range");
            }
            Operand::KeptRows { data, keep } => {
                assert_eq!(keep.len(), rows, "{side}: kept-row count");
                let fits = keep.iter().all(|&r| (r + 1) * cols <= data.len());
                assert!(fits, "{side}: kept row out of range");
            }
        }
    }
}

/// Set `buf` to exactly `len` slots *without* zero-filling slots the
/// packing loops are about to overwrite anyway. (A plain
/// `clear`+`resize` zero-writes the whole block every call; the packing
/// loops then write every live slot a second time. Only edge-tile pad
/// lanes actually need zeros, and the pack loops write those
/// explicitly.) Growth beyond the previous length still zero-fills the
/// new tail, which is harmless and happens once per high-water mark.
fn prep_pack_buf(buf: &mut Vec<f32>, len: usize) {
    if buf.len() > len {
        buf.truncate(len);
    } else if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Pack rows `i0..i0+mc` × depth `p0..p0+kc` of the (m × k) operand `a`
/// into MR-wide row panels (`buf[panel][depth][row]`), dispatching the
/// per-variant index math once so the inner loops stay monomorphic.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &Operand<'_>,
    m: usize,
    k: usize,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    buf: &mut Vec<f32>,
) {
    match *a {
        Operand::Dense(d) => pack_a_with(|r, p| d[r * k + p], i0, mc, p0, kc, buf),
        Operand::Transposed(d) => pack_a_with(|r, p| d[p * m + r], i0, mc, p0, kc, buf),
        Operand::KeptChannels { g, keep, cout, hw } => {
            pack_a_with(|r, p| g[((r / hw) * cout + keep[p]) * hw + r % hw], i0, mc, p0, kc, buf)
        }
        Operand::KeptRows { data, keep } => {
            pack_a_with(|r, p| data[keep[r] * k + p], i0, mc, p0, kc, buf)
        }
    }
}

/// Shared A-packing loop: `get(row, depth)` reads the operand. Full
/// panels are plain copies (every slot written); only the final partial
/// panel, if any, zero-pads its missing row lanes — so the buffer is
/// written exactly once per slot with no blanket re-zeroing.
fn pack_a_with(
    get: impl Fn(usize, usize) -> f32,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    buf: &mut Vec<f32>,
) {
    let panels = mc.div_ceil(MR);
    prep_pack_buf(buf, panels * kc * MR);
    for ip in 0..panels {
        let iw = MR.min(mc - ip * MR);
        let panel = &mut buf[ip * kc * MR..][..kc * MR];
        for (p, prow) in panel.chunks_exact_mut(MR).enumerate() {
            for (i, slot) in prow.iter_mut().enumerate() {
                *slot = if i < iw { get(i0 + ip * MR + i, p0 + p) } else { 0.0 };
            }
        }
    }
}

/// Pack depth `p0..p0+kc` × columns `j0..j0+nc` of the (k × n) operand
/// `b` into `nr`-wide column panels (`buf[panel][depth][col]`).
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &Operand<'_>,
    k: usize,
    n: usize,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    nr: usize,
    buf: &mut Vec<f32>,
) {
    match *b {
        Operand::Dense(d) => pack_b_with(|p, c| d[p * n + c], p0, kc, j0, nc, nr, buf),
        Operand::Transposed(d) => pack_b_with(|p, c| d[c * k + p], p0, kc, j0, nc, nr, buf),
        Operand::KeptChannels { g, keep, cout, hw } => pack_b_with(
            |p, c| g[((p / hw) * cout + keep[c]) * hw + p % hw],
            p0,
            kc,
            j0,
            nc,
            nr,
            buf,
        ),
        Operand::KeptRows { data, keep } => {
            pack_b_with(|p, c| data[keep[p] * n + c], p0, kc, j0, nc, nr, buf)
        }
    }
}

/// Shared B-packing loop: `get(depth, col)` reads the operand. As with
/// [`pack_a_with`], full panels are plain copies and only the final
/// partial panel zero-pads its missing column lanes.
#[allow(clippy::too_many_arguments)]
fn pack_b_with(
    get: impl Fn(usize, usize) -> f32,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    nr: usize,
    buf: &mut Vec<f32>,
) {
    let panels = nc.div_ceil(nr);
    prep_pack_buf(buf, panels * kc * nr);
    for jp in 0..panels {
        let jw = nr.min(nc - jp * nr);
        let panel = &mut buf[jp * kc * nr..][..kc * nr];
        for (p, prow) in panel.chunks_exact_mut(nr).enumerate() {
            for (j, slot) in prow.iter_mut().enumerate() {
                *slot = if j < jw { get(p0 + p, j0 + jp * nr + j) } else { 0.0 };
            }
        }
    }
}

/// The portable register tile: `acc[MR][..nr] += a_panel ⊗ b_panel` over
/// one depth block, depth-major so each element's sum order is the plain
/// in-order one. Also the semantic reference the SIMD tiles must match
/// bit-for-bit.
#[inline]
fn microkernel_scalar(pa: &[f32], pb: &[f32], nr: usize, acc: &mut Acc) {
    for (arow, brow) in pa.chunks_exact(MR).zip(pb.chunks_exact(nr)) {
        for (accrow, &av) in acc.iter_mut().zip(arow) {
            for (cv, &bv) in accrow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// x86-64 SIMD register tiles. Both vectorize across the NR
/// (output-column) dimension and use *separate* mul then add — never an
/// FMA, whose single rounding would diverge from the scalar chain — so
/// each column lane performs exactly the scalar kernel's operation
/// sequence and the results are bitwise identical to [`microkernel_scalar`].
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{Acc, MR};
    use core::arch::x86_64::*;

    /// SSE2 tile: `nr/4` four-lane column vectors per tile row,
    /// accumulated in registers across the depth block.
    ///
    /// # Safety
    /// Caller guarantees `pa.len() = kc·MR`, `pb.len() = kc·nr`, and
    /// `nr ∈ {8, 16}`. SSE2 itself is part of the x86-64 baseline.
    pub unsafe fn sse2(pa: &[f32], pb: &[f32], nr: usize, acc: &mut Acc) {
        debug_assert!(nr == 8 || nr == 16);
        let nv = nr / 4;
        let mut vacc = [[_mm_setzero_ps(); 4]; MR];
        for (arow, brow) in pa.chunks_exact(MR).zip(pb.chunks_exact(nr)) {
            let mut bv = [_mm_setzero_ps(); 4];
            for (v, slot) in bv.iter_mut().enumerate().take(nv) {
                *slot = _mm_loadu_ps(brow.as_ptr().add(v * 4));
            }
            for (vrow, &av) in vacc.iter_mut().zip(arow) {
                let a = _mm_set1_ps(av);
                for (cacc, &b) in vrow.iter_mut().zip(&bv).take(nv) {
                    *cacc = _mm_add_ps(*cacc, _mm_mul_ps(a, b));
                }
            }
        }
        for (row, vrow) in acc.iter_mut().zip(&vacc) {
            for (v, &vec) in vrow.iter().enumerate().take(nv) {
                _mm_storeu_ps(row.as_mut_ptr().add(v * 4), vec);
            }
        }
    }

    /// AVX2 tile: `nr/8` eight-lane column vectors per tile row,
    /// accumulated in registers across the depth block.
    ///
    /// # Safety
    /// Caller guarantees `pa.len() = kc·MR`, `pb.len() = kc·nr`,
    /// `nr ∈ {8, 16}`, and that the host supports AVX2
    /// ([`super::Kernel::available`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn avx2(pa: &[f32], pb: &[f32], nr: usize, acc: &mut Acc) {
        debug_assert!(nr == 8 || nr == 16);
        let nv = nr / 8;
        let mut vacc = [[_mm256_setzero_ps(); 2]; MR];
        for (arow, brow) in pa.chunks_exact(MR).zip(pb.chunks_exact(nr)) {
            let mut bv = [_mm256_setzero_ps(); 2];
            for (v, slot) in bv.iter_mut().enumerate().take(nv) {
                *slot = _mm256_loadu_ps(brow.as_ptr().add(v * 8));
            }
            for (vrow, &av) in vacc.iter_mut().zip(arow) {
                let a = _mm256_set1_ps(av);
                for (cacc, &b) in vrow.iter_mut().zip(&bv).take(nv) {
                    *cacc = _mm256_add_ps(*cacc, _mm256_mul_ps(a, b));
                }
            }
        }
        for (row, vrow) in acc.iter_mut().zip(&vacc) {
            for (v, &vec) in vrow.iter().enumerate().take(nv) {
                _mm256_storeu_ps(row.as_mut_ptr().add(v * 8), vec);
            }
        }
    }
}

/// Run the selected microkernel over one panel pair into a zeroed tile.
#[inline]
fn run_tile(kernel: Kernel, pa: &[f32], pb: &[f32], nr: usize, acc: &mut Acc) {
    match kernel {
        Kernel::Scalar => microkernel_scalar(pa, pb, nr, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: panel lengths are kc·MR / kc·nr by construction,
        // nr ∈ {NR, NR2}, and gemm_into_tiled asserted availability.
        Kernel::Sse2 => unsafe { x86::sse2(pa, pb, nr, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above; Avx2.available() implies the CPU has AVX2.
        Kernel::Avx2 => unsafe { x86::avx2(pa, pb, nr, acc) },
        #[cfg(not(target_arch = "x86_64"))]
        Kernel::Sse2 | Kernel::Avx2 => unreachable!("x86-64 kernel on a non-x86-64 host"),
    }
}

/// Walk one packed (mc × kc × nc) block with the register tile, adding
/// each tile's partial sums into `c` (row stride `n`). Zero-padded edge
/// lanes are computed but never written back, so padding cannot leak —
/// not even NaN × 0 artifacts.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    n: usize,
    i0: usize,
    mc: usize,
    j0: usize,
    nc: usize,
    kc: usize,
    nr: usize,
    kernel: Kernel,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
) {
    for jp in 0..nc.div_ceil(nr) {
        let jw = nr.min(nc - jp * nr);
        let bpanel = &pb[jp * kc * nr..][..kc * nr];
        for ip in 0..mc.div_ceil(MR) {
            let iw = MR.min(mc - ip * MR);
            let apanel = &pa[ip * kc * MR..][..kc * MR];
            let mut acc = [[0f32; NR2]; MR];
            run_tile(kernel, apanel, bpanel, nr, &mut acc);
            for (i, accrow) in acc.iter().enumerate().take(iw) {
                let crow = &mut c[(i0 + ip * MR + i) * n + j0 + jp * nr..][..jw];
                for (cv, &av) in crow.iter_mut().zip(accrow) {
                    *cv += av;
                }
            }
        }
    }
}

/// C(m×n) = A(m×k) · B(k×n) into `c` (cleared and resized in place),
/// reusing `pack`'s panel buffers across calls, with the process-wide
/// [`Kernel::active`] microkernel and the [`nr_for`] tile width.
///
/// Accumulation per output element is strictly increasing-depth (see the
/// module docs), so results are deterministic for every shape, kernel,
/// and width, and bit-identical to [`gemm_ref`] whenever `k` fits one
/// depth block.
pub fn gemm_into(
    m: usize,
    k: usize,
    n: usize,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut Vec<f32>,
    pack: &mut GemmPack,
) {
    gemm_into_tiled(m, k, n, a, b, c, pack, Kernel::active(), nr_for(n));
}

/// [`gemm_into`] with an explicit microkernel and B-panel tile width
/// (`nr` ∈ {[`NR`], [`NR2`]}). Call sites that know their sparsity
/// structure pass `nr_for(keep_count)` here; the equivalence suite and
/// the bench use it to pin every kernel × width combination against the
/// reference. Output bits do not depend on either argument.
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut Vec<f32>,
    pack: &mut GemmPack,
    kernel: Kernel,
    nr: usize,
) {
    assert!(nr == NR || nr == NR2, "tile width must be NR ({NR}) or NR2 ({NR2}), got {nr}");
    assert!(kernel.available(), "GEMM kernel {:?} is not supported on this host", kernel.name());
    a.check(m, k, "gemm lhs");
    b.check(k, n, "gemm rhs");
    c.clear();
    c.resize(m * n, 0.0);
    for j0 in (0..n).step_by(NC) {
        let nc = NC.min(n - j0);
        for p0 in (0..k).step_by(KC) {
            let kc = KC.min(k - p0);
            pack_b(&b, k, n, p0, kc, j0, nc, nr, &mut pack.pb);
            for i0 in (0..m).step_by(MC) {
                let mc = MC.min(m - i0);
                pack_a(&a, m, k, i0, mc, p0, kc, &mut pack.pa);
                macro_kernel(n, i0, mc, j0, nc, kc, nr, kernel, &pack.pa, &pack.pb, c);
            }
        }
    }
}

/// Allocating dense GEMM: `C = A · B` through the blocked kernel with a
/// throwaway pack. Op-level convenience — the plan path passes its own
/// [`GemmPack`] to [`gemm_into`] so nothing allocates per step.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_into(m, k, n, Operand::Dense(a), Operand::Dense(b), &mut c, &mut GemmPack::new());
    c
}

/// Naive in-order triple-loop reference (no blocking, no skipping): the
/// correctness oracle for the property tests and the "before" side of the
/// bench's `native/gemm_speedup_*` lines.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "gemm lhs length");
    assert_eq!(b.len(), k * n, "gemm rhs length");
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..][..n];
        for (p, &av) in a[i * k..][..k].iter().enumerate() {
            let brow = &b[p * n..][..n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..len).map(f).collect()
    }

    fn mat(len: usize, mul: usize, md: usize, scale: f32, off: f32) -> Vec<f32> {
        fill(len, |i| ((i * mul) % md) as f32 * scale - off)
    }

    /// Every kernel this host can actually run.
    fn kernels() -> Vec<Kernel> {
        Kernel::ALL.into_iter().filter(|k| k.available()).collect()
    }

    #[test]
    fn matches_reference_across_tile_edges() {
        // shapes straddling the MR/NR/MC/KC boundaries, incl. 1-wide edges
        let shapes =
            [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 9), (64, 16, 8), (65, 257, 17), (70, 300, 33)];
        for (m, k, n) in shapes {
            let a = mat(m * k, 7, 13, 0.25, 1.5);
            let b = mat(k * n, 5, 11, 0.5, 2.0);
            let got = gemm(m, k, n, &a, &b);
            let want = gemm_ref(m, k, n, &a, &b);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "shape ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn bitwise_reference_match_within_one_depth_block() {
        // k ≤ KC ⇒ a single depth block ⇒ the blocked summation order is
        // exactly the naive in-order chain — for every kernel and width
        let (m, k, n) = (13, KC, 21);
        let a = mat(m * k, 3, 17, 0.125, 1.0);
        let b = mat(k * n, 11, 19, 0.25, 2.25);
        let want = gemm_ref(m, k, n, &a, &b);
        assert_eq!(gemm(m, k, n, &a, &b), want);
        let mut c = Vec::new();
        let mut pk = GemmPack::new();
        for kernel in kernels() {
            for nr in [NR, NR2] {
                gemm_into_tiled(
                    m,
                    k,
                    n,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut c,
                    &mut pk,
                    kernel,
                    nr,
                );
                assert_eq!(c, want, "kernel {:?} nr {nr}", kernel.name());
            }
        }
    }

    #[test]
    fn kernels_and_widths_agree_bitwise_beyond_one_depth_block() {
        // k > KC exercises the cross-block accumulation; every kernel ×
        // width combination must still agree to the bit
        let (m, k, n) = (9, 2 * KC + 37, 23);
        let a = mat(m * k, 7, 29, 0.0625, 0.9);
        let b = mat(k * n, 5, 23, 0.125, 1.1);
        let mut want = Vec::new();
        let mut pk = GemmPack::new();
        gemm_into_tiled(
            m,
            k,
            n,
            Operand::Dense(&a),
            Operand::Dense(&b),
            &mut want,
            &mut pk,
            Kernel::Scalar,
            NR,
        );
        let mut c = Vec::new();
        for kernel in kernels() {
            for nr in [NR, NR2] {
                gemm_into_tiled(
                    m,
                    k,
                    n,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut c,
                    &mut pk,
                    kernel,
                    nr,
                );
                assert_eq!(c, want, "kernel {:?} nr {nr}", kernel.name());
            }
        }
    }

    #[test]
    fn kernel_names_round_trip_and_unknowns_are_rejected() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert_eq!(Kernel::parse("avx512"), None);
        assert_eq!(Kernel::parse("AVX2"), None, "names are case-sensitive");
        assert!(Kernel::Scalar.available(), "scalar must run everywhere");
        assert!(Kernel::detect().available());
        // active() settles once and keeps answering the same kernel
        assert_eq!(Kernel::active(), Kernel::active());
        assert!(Kernel::active().available());
    }

    #[test]
    fn pack_bits_identical_to_zero_filled_reference() {
        // the edge-only padding fast path must produce byte-identical
        // panels to a full zero-fill-then-write reference, even when the
        // buffer is dirty from a previous, larger pack
        fn ref_pack_a(
            get: impl Fn(usize, usize) -> f32,
            i0: usize,
            mc: usize,
            p0: usize,
            kc: usize,
        ) -> Vec<f32> {
            let panels = mc.div_ceil(MR);
            let mut buf = vec![0f32; panels * kc * MR];
            for ip in 0..panels {
                let iw = MR.min(mc - ip * MR);
                let panel = &mut buf[ip * kc * MR..][..kc * MR];
                for (p, prow) in panel.chunks_exact_mut(MR).enumerate() {
                    for (i, slot) in prow.iter_mut().enumerate().take(iw) {
                        *slot = get(i0 + ip * MR + i, p0 + p);
                    }
                }
            }
            buf
        }
        fn ref_pack_b(
            get: impl Fn(usize, usize) -> f32,
            p0: usize,
            kc: usize,
            j0: usize,
            nc: usize,
            nr: usize,
        ) -> Vec<f32> {
            let panels = nc.div_ceil(nr);
            let mut buf = vec![0f32; panels * kc * nr];
            for jp in 0..panels {
                let jw = nr.min(nc - jp * nr);
                let panel = &mut buf[jp * kc * nr..][..kc * nr];
                for (p, prow) in panel.chunks_exact_mut(nr).enumerate() {
                    for (j, slot) in prow.iter_mut().enumerate().take(jw) {
                        *slot = get(p0 + p, j0 + jp * nr + j);
                    }
                }
            }
            buf
        }

        let (m, k, n) = (11, 19, 27);
        let a = mat(m * k, 7, 31, 0.5, 3.0);
        let b = mat(k * n, 3, 29, 0.25, 2.0);
        let mut buf = Vec::new();
        // dirty the buffer with a larger pack first so stale panels and a
        // shrinking length are both exercised
        pack_a(&Operand::Dense(&a), m, k, 0, m, 0, k, &mut buf);
        for (i0, mc, p0, kc) in [(0, m, 0, k), (4, 7, 8, 11), (8, 3, 16, 3)] {
            pack_a(&Operand::Dense(&a), m, k, i0, mc, p0, kc, &mut buf);
            let want = ref_pack_a(|r, p| a[r * k + p], i0, mc, p0, kc);
            assert_eq!(buf, want, "pack_a ({i0},{mc},{p0},{kc})");
        }
        let mut buf = Vec::new();
        pack_b(&Operand::Dense(&b), k, n, 0, k, 0, n, NR2, &mut buf);
        for nr in [NR, NR2] {
            for (p0, kc, j0, nc) in [(0, k, 0, n), (8, 11, 4, 21), (16, 3, 24, 3)] {
                pack_b(&Operand::Dense(&b), k, n, p0, kc, j0, nc, nr, &mut buf);
                let want = ref_pack_b(|p, c| b[p * n + c], p0, kc, j0, nc, nr);
                assert_eq!(buf, want, "pack_b ({p0},{kc},{j0},{nc}) nr {nr}");
            }
        }
    }

    #[test]
    fn nr_heuristic_is_pure_and_narrow_below_one_wide_panel() {
        assert_eq!(nr_for(0), NR);
        assert_eq!(nr_for(1), NR);
        assert_eq!(nr_for(NR2 - 1), NR);
        assert_eq!(nr_for(NR2), NR2);
        assert_eq!(nr_for(1000), NR2);
        for n in 0..64 {
            assert_eq!(nr_for(n), nr_for(n), "pure function of shape");
        }
    }

    #[test]
    fn transposed_view_matches_materialized_transpose() {
        let (m, k, n) = (6, 10, 9);
        let at = mat(k * m, 7, 23, 0.2, 2.0); // underlying (k × m)
        let b = mat(k * n, 3, 13, 0.4, 1.2);
        let mut a = vec![0f32; m * k];
        for r in 0..m {
            for p in 0..k {
                a[r * k + p] = at[p * m + r];
            }
        }
        let mut c = Vec::new();
        let mut pk = GemmPack::new();
        gemm_into(m, k, n, Operand::Transposed(&at), Operand::Dense(&b), &mut c, &mut pk);
        assert_eq!(c, gemm(m, k, n, &a, &b), "A-side transposed view");
        let bt = mat(n * k, 9, 29, 0.3, 1.9); // underlying (n × k)
        let mut bm = vec![0f32; k * n];
        for p in 0..k {
            for j in 0..n {
                bm[p * n + j] = bt[j * k + p];
            }
        }
        gemm_into(m, k, n, Operand::Dense(&a), Operand::Transposed(&bt), &mut c, &mut pk);
        assert_eq!(c, gemm(m, k, n, &a, &bm), "B-side transposed view");
    }

    #[test]
    fn kept_views_equal_explicit_gathers_bitwise() {
        // KeptChannels: (bt·hw × k') gather of an NCHW gradient
        let (bt, cout, hw) = (2, 5, 6);
        let g = mat(bt * cout * hw, 7, 31, 0.2, 3.0);
        let keep = [0usize, 2, 4];
        let rows = bt * hw;
        let mut gck = vec![0f32; rows * keep.len()];
        for r in 0..rows {
            for (c, &o) in keep.iter().enumerate() {
                gck[r * keep.len() + c] = g[((r / hw) * cout + o) * hw + r % hw];
            }
        }
        let b = mat(keep.len() * 4, 3, 11, 0.5, 1.0);
        let view = Operand::KeptChannels { g: &g, keep: &keep, cout, hw };
        let (mut c1, mut c2) = (Vec::new(), Vec::new());
        let pk = &mut GemmPack::new();
        gemm_into(rows, keep.len(), 4, view, Operand::Dense(&b), &mut c1, pk);
        gemm_into(rows, keep.len(), 4, Operand::Dense(&gck), Operand::Dense(&b), &mut c2, pk);
        assert_eq!(c1, c2, "KeptChannels must equal the explicit gather");

        // KeptRows: kept rows of a (cout × n) weight matrix as the rhs
        let n = 7;
        let w = mat(cout * n, 5, 17, 0.25, 2.0);
        let mut wk = vec![0f32; keep.len() * n];
        for (r, &o) in keep.iter().enumerate() {
            wk[r * n..][..n].copy_from_slice(&w[o * n..][..n]);
        }
        let a = mat(3 * keep.len(), 9, 13, 0.4, 1.1);
        let rows_view = Operand::KeptRows { data: &w, keep: &keep };
        gemm_into(3, keep.len(), n, Operand::Dense(&a), rows_view, &mut c1, pk);
        gemm_into(3, keep.len(), n, Operand::Dense(&a), Operand::Dense(&wk), &mut c2, pk);
        assert_eq!(c1, c2, "KeptRows must equal the explicit gather");
    }

    #[test]
    fn empty_dims_and_empty_keep_are_fine() {
        assert!(gemm(0, 3, 4, &[], &[0.0; 12]).is_empty());
        assert_eq!(gemm(2, 0, 3, &[], &[]), vec![0.0; 6]);
        assert!(gemm(2, 3, 0, &[0.0; 6], &[]).is_empty());
        // an empty keep set is a legal (if useless) 0-column operand
        let g = vec![1.0f32; 8];
        let view = Operand::KeptChannels { g: &g, keep: &[], cout: 2, hw: 4 };
        let mut c = vec![99.0];
        gemm_into(4, 0, 3, view, Operand::Dense(&[]), &mut c, &mut GemmPack::new());
        assert_eq!(c, vec![0.0; 12]);
    }

    #[test]
    fn nan_and_inf_propagate_like_dense_math() {
        // 0·NaN and 0·Inf are NaN under dense semantics; the kernel must
        // not "optimize" them away (the old zero-skip bug) — in any
        // kernel or width
        for kernel in kernels() {
            for nr in [NR, NR2] {
                let mut c = Vec::new();
                let mut pk = GemmPack::new();
                let a = [0.0, 1.0];
                let b = [f32::NAN, 1.0, 2.0, 3.0];
                gemm_into_tiled(
                    1,
                    2,
                    2,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut c,
                    &mut pk,
                    kernel,
                    nr,
                );
                assert!(c[0].is_nan(), "0·NaN must surface as NaN ({:?})", kernel.name());
                assert_eq!(c[1], 3.0); // 0·1 + 1·3
                let a = [0.0];
                let b = [f32::INFINITY];
                gemm_into_tiled(
                    1,
                    1,
                    1,
                    Operand::Dense(&a),
                    Operand::Dense(&b),
                    &mut c,
                    &mut pk,
                    kernel,
                    nr,
                );
                assert!(c[0].is_nan(), "0·Inf must surface as NaN ({:?})", kernel.name());
            }
        }
    }

    #[test]
    fn pack_caps_stay_flat_on_reuse() {
        let (m, k, n) = (37, 29, 23);
        let a = mat(m * k, 3, 7, 0.5, 1.0);
        let b = mat(k * n, 5, 9, 0.25, 0.5);
        let mut pack = GemmPack::new();
        let mut c = Vec::new();
        for nr in [NR, NR2, NR, NR2] {
            // alternating widths must also settle: pb's high-water mark
            // is the wide packing, after which neither buffer regrows
            gemm_into_tiled(
                m,
                k,
                n,
                Operand::Dense(&a),
                Operand::Dense(&b),
                &mut c,
                &mut pack,
                Kernel::active(),
                nr,
            );
        }
        let caps = pack.caps();
        for nr in [NR, NR2] {
            gemm_into_tiled(
                m,
                k,
                n,
                Operand::Dense(&a),
                Operand::Dense(&b),
                &mut c,
                &mut pack,
                Kernel::active(),
                nr,
            );
        }
        assert_eq!(pack.caps(), caps, "packing must reuse, not regrow");
    }
}
