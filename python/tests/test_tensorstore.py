"""Tensorstore format: python round-trip (rust side tested in cargo)."""

import numpy as np
import pytest

from compile import tensorstore as ts


def test_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = [
        ("param.w", rng.normal(size=(3, 4, 5)).astype(np.float32)),
        ("param.b", np.arange(7, dtype=np.int32)),
        ("key", np.array([1, 2], dtype=np.uint32)),
        ("scalar", np.float32(3.5).reshape(())),
    ]
    p = tmp_path / "t.tstore"
    ts.write(str(p), tensors)
    back = ts.read(str(p))
    assert set(back) == {n for n, _ in tensors}
    for name, arr in tensors:
        np.testing.assert_array_equal(back[name], arr)
        assert back[name].dtype == arr.dtype


def test_empty_shape_and_zero_size(tmp_path):
    p = tmp_path / "t.tstore"
    ts.write(str(p), [("empty", np.zeros((0, 3), np.float32))])
    back = ts.read(str(p))
    assert back["empty"].shape == (0, 3)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.tstore"
    p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(ValueError):
        ts.read(str(p))


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        ts.write(str(tmp_path / "x.tstore"), [("f64", np.zeros(3, np.float64))])
