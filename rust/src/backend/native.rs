//! Pure-Rust [`Backend`]: img2col GEMM forward + the compacted sparse
//! backward from [`super::sparse`]. Zero FFI, runs anywhere — this is the
//! crate's default executor and the correctness anchor the fixture tests
//! pin against `python/compile/kernels/ref.py`.

use super::im2col::{col_w, im2col};
use super::sparse::{select_channels, sparse_bwd_compact};
use super::{Backend, Conv2d, ConvGrads};

#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn conv2d_fwd(&self, cfg: &Conv2d, x: &[f32], w: &[f32], b: Option<&[f32]>) -> Vec<f32> {
        let (m, n) = (cfg.m(), cfg.n());
        let (ho, wo) = (cfg.hout(), cfg.wout());
        let cols = im2col(cfg, x);
        let cw = col_w(cfg, w);
        let ycol = self.gemm(m, n, cfg.cout, &cols, &cw); // (M, Cout)

        // (M, Cout) -> NCHW, folding the bias in during the transpose
        let mut y = vec![0f32; cfg.out_len()];
        for bi in 0..cfg.bt {
            for o in 0..cfg.cout {
                let bias = b.map_or(0.0, |bb| bb[o]);
                let plane = &mut y[(bi * cfg.cout + o) * ho * wo..][..ho * wo];
                for (pix, v) in plane.iter_mut().enumerate() {
                    *v = ycol[(bi * ho * wo + pix) * cfg.cout + o] + bias;
                }
            }
        }
        y
    }

    fn conv2d_bwd_ssprop(
        &self,
        cfg: &Conv2d,
        x: &[f32],
        w: &[f32],
        g: &[f32],
        drop_rate: f64,
        need_dx: bool,
    ) -> ConvGrads {
        let keep_idx = select_channels(cfg, g, drop_rate);
        sparse_bwd_compact(cfg, x, w, g, &keep_idx, need_dx)
    }

    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), m * k, "gemm lhs length");
        assert_eq!(b.len(), k * n, "gemm rhs length");
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            let crow = &mut c[i * n..][..n];
            for (p, &av) in a[i * k..][..k].iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..][..n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
        c
    }

    fn bias_add(&self, cfg: &Conv2d, y: &mut [f32], b: &[f32]) {
        let hw = cfg.hout() * cfg.wout();
        assert_eq!(y.len(), cfg.out_len(), "bias_add activation length");
        assert_eq!(b.len(), cfg.cout, "bias_add bias length");
        for bi in 0..cfg.bt {
            for (o, &bias) in b.iter().enumerate() {
                let plane = &mut y[(bi * cfg.cout + o) * hw..][..hw];
                for v in plane.iter_mut() {
                    *v += bias;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_identity_and_known_product() {
        let be = NativeBackend::new();
        // 2x2 identity
        let c = be.gemm(2, 2, 2, &[1.0, 0.0, 0.0, 1.0], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 4.0]);
        // (1x3) . (3x2)
        let c = be.gemm(1, 3, 2, &[1.0, 2.0, 3.0], &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(c, vec![14.0, 32.0]);
    }

    #[test]
    fn conv_fwd_1x1_kernel_is_channel_mix() {
        // 1x1 conv == per-pixel matmul over channels: easy to hand-check.
        let cfg = Conv2d { bt: 1, cin: 2, h: 2, w: 2, cout: 1, k: 1, stride: 1, padding: 0 };
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]; // (1,2,2,2)
        let w = vec![2.0, 0.5]; // (1,2,1,1)
        let y = NativeBackend::new().conv2d_fwd(&cfg, &x, &w, Some(&[1.0]));
        assert_eq!(y, vec![2.0 + 5.0 + 1.0, 4.0 + 10.0 + 1.0, 6.0 + 15.0 + 1.0, 8.0 + 20.0 + 1.0]);
    }

    #[test]
    fn dense_bwd_keeps_every_channel() {
        let cfg = Conv2d { bt: 1, cin: 1, h: 4, w: 4, cout: 3, k: 3, stride: 1, padding: 1 };
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| i as f32 * 0.1).collect();
        let w: Vec<f32> = (0..cfg.w_len()).map(|i| (i % 3) as f32 - 1.0).collect();
        let g: Vec<f32> = (0..cfg.out_len()).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
        let out = NativeBackend::new().conv2d_bwd_ssprop(&cfg, &x, &w, &g, 0.0, true);
        assert_eq!(out.keep_idx, vec![0, 1, 2]);
        assert_eq!(out.dx.len(), cfg.in_len());
        // skipping dx leaves dw/db identical and dx empty
        let nodx = NativeBackend::new().conv2d_bwd_ssprop(&cfg, &x, &w, &g, 0.0, false);
        assert!(nodx.dx.is_empty());
        assert_eq!(nodx.dw, out.dw);
        assert_eq!(nodx.db, out.db);
        assert_eq!(out.dw.len(), cfg.w_len());
        // dense db = plain sum of g per channel
        let hw = cfg.hout() * cfg.wout();
        for o in 0..3 {
            let want: f32 = g[o * hw..(o + 1) * hw].iter().sum();
            assert!((out.db[o] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn bias_add_broadcasts_per_channel() {
        let cfg = Conv2d { bt: 2, cin: 1, h: 2, w: 2, cout: 2, k: 1, stride: 1, padding: 0 };
        let mut y = vec![0f32; cfg.out_len()];
        NativeBackend::new().bias_add(&cfg, &mut y, &[1.0, -2.0]);
        let mut want = vec![1.0f32; 4];
        want.extend([-2.0; 4]);
        let want = [want.clone(), want].concat();
        assert_eq!(y, want);
    }
}
