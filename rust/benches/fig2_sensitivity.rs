//! Bench for paper Fig. 2: sensitivity-analysis machinery.
//! (a/b) step latency across selection modes (channel/hw/all, topk/random),
//! (c)   scheduler evaluation throughput across shapes,
//! (d)   period-sweep rate computation cost.
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench fig2_sensitivity --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::runtime::Engine;
    use ssprop::schedule::{DropScheduler, Schedule};
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping fig2_sensitivity: {err}");
                return;
            }
        };
        println!("== Fig 2 bench: selection-mode step latency + scheduler throughput ==\n");

        // (a/b) selection-mode variants at D = 0.8
        for (suffix, label) in [
            ("", "channel_topk"),
            ("_hw", "hw_topk"),
            ("_all", "all_topk"),
            ("_random", "channel_random"),
        ] {
            let artifact = format!("resnet18_cifar10{suffix}");
            let mut t = Trainer::new(&engine, TrainConfig::quick(&artifact, 1, 1)).unwrap();
            let order = t.loader.epoch_order(0);
            let batch = t.loader.batch(&order, 0);
            let r = bench(&format!("fig2ab/{label}/step_d80"), 2, 12, Duration::from_secs(8), || {
                t.step(&batch, 0.8).unwrap();
            });
            report(&r);
        }

        // (c) scheduler shapes: rate_at over a full training horizon
        println!();
        for (name, s) in [
            ("constant", Schedule::Constant),
            ("linear", Schedule::Linear),
            ("cosine", Schedule::Cosine),
            ("bar", Schedule::Bar),
            ("epoch_bar", Schedule::EpochBar { period_epochs: 2 }),
        ] {
            let d = DropScheduler::new(s, 0.8, 50, 300);
            let r =
                bench(&format!("fig2c/{name}/rates_15k_iters"), 2, 50, Duration::from_secs(3), || {
                    let rates = d.rates();
                    std::hint::black_box(rates.len());
                });
            report(&r);
        }

        // (d) period sweep cost
        println!();
        for p in [30usize, 120, 300] {
            let d = DropScheduler::new(Schedule::IterPeriodic { period: p }, 0.8, 50, 300);
            let r =
                bench(&format!("fig2d/period_{p}/mean_rate"), 2, 50, Duration::from_secs(3), || {
                    std::hint::black_box(d.mean_rate());
                });
            report(&r);
        }
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("skipping fig2_sensitivity: PJRT runtime not compiled (build with --features pjrt)");
}

fn main() {
    run();
}
