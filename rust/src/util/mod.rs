//! Offline-substrate utilities: JSON, RNG, CLI, bench harness, property
//! testing. These stand in for serde/rand/clap/criterion/proptest, none of
//! which are available in the vendored dependency set (DESIGN.md S9–S13).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod shard;
