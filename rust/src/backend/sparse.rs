//! ssProp selection primitives + the compacted (true-sparse) backward:
//! channel importance (paper Fig. 1a "abs + spatial mean"), exact-k top-k
//! with deterministic tie-breaking, and the shrunk img2col GEMMs of
//! Sec. "Scheduled Sparse BP". Mirrors `ref.py::importance_ref`,
//! `topk_mask_ref`, `keep_k_from_drop_rate`, `sparse_bwd_compact_ref`.

use super::im2col::{col2img, col_w, im2col};
use super::{Conv2d, ConvGrads};
use crate::flops::keep_channels;

/// Fig. 1(a) channel mode: importance[o] = mean |g| over (Bt, H, W).
pub fn channel_importance(cfg: &Conv2d, g: &[f32]) -> Vec<f32> {
    let hw = cfg.hout() * cfg.wout();
    assert_eq!(g.len(), cfg.bt * cfg.cout * hw, "gradient length");
    let mut imp = vec![0f32; cfg.cout];
    for b in 0..cfg.bt {
        for o in 0..cfg.cout {
            let plane = &g[(b * cfg.cout + o) * hw..][..hw];
            imp[o] += plane.iter().map(|v| v.abs()).sum::<f32>();
        }
    }
    let denom = (cfg.bt * hw) as f32;
    for v in &mut imp {
        *v /= denom;
    }
    imp
}

/// Indices of the `keep` largest importances, ascending. Ties break toward
/// the lower channel index (matching the stable argsort in the reference).
pub fn topk_channels(imp: &[f32], keep: usize) -> Vec<usize> {
    let keep = keep.min(imp.len());
    let mut order: Vec<usize> = (0..imp.len()).collect();
    order.sort_by(|&a, &b| {
        imp[b].partial_cmp(&imp[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut kept = order[..keep].to_vec();
    kept.sort_unstable();
    kept
}

/// Selection for a drop rate: k = clamp(round((1−D)·Cout), 1, Cout)
/// channels by importance (shared rust/python semantics via
/// [`keep_channels`]).
pub fn select_channels(cfg: &Conv2d, g: &[f32], drop_rate: f64) -> Vec<usize> {
    let keep = keep_channels(cfg.cout, drop_rate);
    if keep == cfg.cout {
        return (0..cfg.cout).collect();
    }
    topk_channels(&channel_importance(cfg, g), keep)
}

/// Compacted img2col backward with static keep indices:
///   col[dY]' = channel-compacted col[dY]          (M × k')
///   dW'      = col_Xᵀ · col[dY]'                  (N × k')
///   col[dX]  = col[dY]' · col_W'ᵀ                 (M × N)
///   db'      = column sums of col[dY]'
/// Dropped channels receive exactly-zero dW/db rows. With
/// `keep_idx = 0..Cout` this is the exact dense backward (Eq. 3/4/5).
/// `need_dx = false` skips the col[dX] GEMM + col2img (dx comes back
/// empty).
pub fn sparse_bwd_compact(
    cfg: &Conv2d,
    x: &[f32],
    w: &[f32],
    g: &[f32],
    keep_idx: &[usize],
    need_dx: bool,
) -> ConvGrads {
    let (m, n, kp) = (cfg.m(), cfg.n(), keep_idx.len());
    let (ho, wo) = (cfg.hout(), cfg.wout());
    assert!((1..=cfg.cout).contains(&kp), "keep count out of range");
    assert_eq!(g.len(), cfg.out_len(), "gradient length");

    let cols = im2col(cfg, x); // (M, N)

    // col[dY]' — gather kept channels while transposing NCHW -> (M, k')
    let mut gck = vec![0f32; m * kp];
    for b in 0..cfg.bt {
        for (pos, &o) in keep_idx.iter().enumerate() {
            let plane = &g[(b * cfg.cout + o) * ho * wo..][..ho * wo];
            for (pix, &gv) in plane.iter().enumerate() {
                gck[(b * ho * wo + pix) * kp + pos] = gv;
            }
        }
    }

    // dW' = col_Xᵀ · col[dY]'  (N × k'), accumulated row-by-row over M
    let mut dwk = vec![0f32; n * kp];
    for mi in 0..m {
        let crow = &cols[mi * n..][..n];
        let grow = &gck[mi * kp..][..kp];
        for (ni, &cv) in crow.iter().enumerate() {
            if cv == 0.0 {
                continue;
            }
            let dst = &mut dwk[ni * kp..][..kp];
            for (d, &gv) in dst.iter_mut().zip(grow) {
                *d += cv * gv;
            }
        }
    }
    // scatter into full (Cout, Cin, K, K)
    let mut dw = vec![0f32; cfg.w_len()];
    for (pos, &o) in keep_idx.iter().enumerate() {
        let dst = &mut dw[o * n..][..n];
        for (ni, d) in dst.iter_mut().enumerate() {
            *d = dwk[ni * kp + pos];
        }
    }

    // col_W' (k' columns of col_W), then col[dX] = col[dY]' · col_W'ᵀ
    let dx = if need_dx {
        let cw = col_w(cfg, w); // (N, Cout)
        let mut cwk = vec![0f32; n * kp];
        for ni in 0..n {
            for (pos, &o) in keep_idx.iter().enumerate() {
                cwk[ni * kp + pos] = cw[ni * cfg.cout + o];
            }
        }
        let mut dcols = vec![0f32; m * n];
        for mi in 0..m {
            let grow = &gck[mi * kp..][..kp];
            let drow = &mut dcols[mi * n..][..n];
            for (ni, d) in drow.iter_mut().enumerate() {
                let wrow = &cwk[ni * kp..][..kp];
                let mut acc = 0f32;
                for (gv, wv) in grow.iter().zip(wrow) {
                    acc += gv * wv;
                }
                *d = acc;
            }
        }
        col2img(cfg, &dcols)
    } else {
        Vec::new()
    };

    // db' — column sums of col[dY]', scattered to kept channels
    let mut db = vec![0f32; cfg.cout];
    for mi in 0..m {
        let grow = &gck[mi * kp..][..kp];
        for (pos, &o) in keep_idx.iter().enumerate() {
            db[o] += grow[pos];
        }
    }

    ConvGrads { dx, dw, db, keep_idx: keep_idx.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Conv2d {
        Conv2d { bt: 2, cin: 1, h: 4, w: 4, cout: 3, k: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn importance_is_abs_mean_per_channel() {
        let c = cfg();
        let hw = c.hout() * c.wout();
        let mut g = vec![0f32; c.out_len()];
        // channel 1 gets |v| = 2 everywhere in batch 0 only -> mean 1.0
        for v in &mut g[hw..2 * hw] {
            *v = -2.0;
        }
        let imp = channel_importance(&c, &g);
        assert_eq!(imp, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn topk_stable_under_ties() {
        assert_eq!(topk_channels(&[0.5, 0.5, 0.5, 0.5], 2), vec![0, 1]);
        assert_eq!(topk_channels(&[0.1, 0.9, 0.3, 0.9], 2), vec![1, 3]);
        assert_eq!(topk_channels(&[0.1, 0.9, 0.3], 5), vec![0, 1, 2]);
    }

    #[test]
    fn select_channels_keeps_clamped_count() {
        let c = cfg();
        let g = vec![1.0f32; c.out_len()];
        assert_eq!(select_channels(&c, &g, 0.0).len(), 3);
        assert_eq!(select_channels(&c, &g, 0.5).len(), 2); // round(1.5) = 2
        assert_eq!(select_channels(&c, &g, 0.99).len(), 1); // clamp to 1
    }

    #[test]
    fn dropped_channels_get_zero_dw_db() {
        let c = cfg();
        let x: Vec<f32> = (0..c.in_len()).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..c.w_len()).map(|i| (i % 5) as f32 * 0.1).collect();
        let g: Vec<f32> = (0..c.out_len()).map(|i| (i % 11) as f32 - 5.0).collect();
        let out = sparse_bwd_compact(&c, &x, &w, &g, &[1], true);
        let n = c.n();
        assert!(out.dw[..n].iter().all(|&v| v == 0.0), "channel 0 dw must be zero");
        assert!(out.dw[2 * n..].iter().all(|&v| v == 0.0), "channel 2 dw must be zero");
        assert!(out.dw[n..2 * n].iter().any(|&v| v != 0.0), "kept channel dw nonzero");
        assert_eq!(out.db[0], 0.0);
        assert_eq!(out.db[2], 0.0);
        assert_ne!(out.db[1], 0.0);
    }
}
