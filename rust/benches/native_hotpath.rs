//! Native-backend hot path: img2col conv forward, dense vs compacted
//! sparse backward, and the raw GEMM — the costs the ROADMAP's "faster hot
//! paths" work items move. Runs on the default build (no PJRT, no
//! artifacts), so any machine can baseline it:
//!
//! Run: `cargo bench --bench native_hotpath`

use std::time::Duration;

use ssprop::backend::{Backend, Conv2d, NativeBackend};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::util::bench::{bench, report};
use ssprop::util::rng::Pcg;

fn main() {
    let be = NativeBackend::new();
    println!("== native backend hot path ==\n-- conv fwd/bwd (bt 16, 32ch, 16x16, k3) --");

    let cfg = Conv2d { bt: 16, cin: 32, h: 16, w: 16, cout: 32, k: 3, stride: 1, padding: 1 };
    let mut rng = Pcg::new(3, 3);
    let x: Vec<f32> = (0..cfg.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cfg.w_len()).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..cfg.cout).map(|_| rng.normal() * 0.1).collect();
    let g: Vec<f32> = (0..cfg.out_len()).map(|_| rng.normal()).collect();

    let r = bench("native/conv_fwd", 2, 20, Duration::from_secs(6), || {
        std::hint::black_box(be.conv2d_fwd(&cfg, &x, &w, Some(&b)));
    });
    report(&r);

    for (label, d, need_dx) in [
        ("dense", 0.0f64, true),
        ("d50", 0.5, true),
        ("d80", 0.8, true),
        ("d80_nodx", 0.8, false),
    ] {
        let r = bench(&format!("native/conv_bwd_{label}"), 2, 20, Duration::from_secs(6), || {
            std::hint::black_box(be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, need_dx));
        });
        report(&r);
    }

    println!("\n-- raw GEMM (256x288 . 288x128) --");
    let (m, k, n) = (256, 288, 128);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let bb: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let r = bench("native/gemm_256x288x128", 2, 30, Duration::from_secs(5), || {
        std::hint::black_box(be.gemm(m, k, n, &a, &bb));
    });
    report(&r);

    println!("\n-- end-to-end SimpleCNN training step --");
    for (label, d) in [("dense", 0.0f64), ("d80", 0.8)] {
        let mut t = NativeTrainer::new(NativeTrainConfig::quick("cifar10", 1, 1)).unwrap();
        let order = t.loader.epoch_order(0);
        let batch = t.loader.batch(&order, 0);
        let r = bench(&format!("native/train_step_{label}"), 2, 20, Duration::from_secs(6), || {
            t.step(&batch, d).unwrap();
        });
        report(&r);
    }
}
