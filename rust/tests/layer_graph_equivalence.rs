//! Bit-identity suite for the layer-graph refactor: a `Sequential` built
//! by `simple_cnn()` must reproduce the **pre-refactor** hand-rolled
//! SimpleCNN exactly — same per-step loss bits, same kept-channel counts,
//! same parameter bits — on the serial path and through the generalized
//! `ParallelExecutor` at t ∈ {1, 2, 4}.
//!
//! The oracle is an embedded, line-faithful copy of the legacy
//! implementation (`legacy` module below: the old `SimpleCnn::train_step`
//! and the old conv-stack-specific executor), kept on the *public*
//! plan-path Backend API so it stays executable forever. If a future
//! change to the layer graph re-associates a single f32 addition, these
//! tests catch it at the bit level.

use ssprop::backend::{
    simple_cnn, ExecConfig, NativeBackend, ParallelExecutor, Sequential, SimpleCnnCfg,
};
use ssprop::util::rng::Pcg;

/// The legacy implementation, frozen. Copied from the pre-refactor
/// `backend/simple_cnn.rs` + `backend/parallel.rs` with only visibility
/// adjustments (crate-private helpers inlined).
mod legacy {
    use std::sync::{Barrier, Mutex};

    use ssprop::backend::sparse::{channel_abs_sums, topk_channels};
    use ssprop::backend::{Backend, Conv2d, Conv2dPlan};
    use ssprop::flops::keep_channels;
    use ssprop::util::rng::Pcg;
    use ssprop::util::shard::shard_ranges;

    #[derive(Debug, Clone, Copy)]
    pub struct Cfg {
        pub in_ch: usize,
        pub img: usize,
        pub classes: usize,
        pub depth: usize,
        pub width: usize,
        pub seed: u64,
    }

    pub struct ConvBlock {
        pub w: Vec<f32>,
        pub b: Vec<f32>,
        pub cin: usize,
        pub stride: usize,
    }

    pub struct LegacyCnn {
        pub cfg: Cfg,
        pub convs: Vec<ConvBlock>,
        pub fc_w: Vec<f32>,
        pub fc_b: Vec<f32>,
        plans: Vec<Conv2dPlan>,
    }

    fn out_size(n: usize, k: usize, s: usize, p: usize) -> usize {
        (n + 2 * p - k) / s + 1
    }

    impl LegacyCnn {
        pub fn new(cfg: Cfg) -> LegacyCnn {
            let mut rng = Pcg::new(cfg.seed ^ 0xC44, 29);
            let mut convs = Vec::with_capacity(cfg.depth);
            for l in 0..cfg.depth {
                let cin = if l == 0 { cfg.in_ch } else { cfg.width };
                let fan_in = (cin * 9) as f32;
                let scale = (2.0 / fan_in).sqrt();
                convs.push(ConvBlock {
                    w: (0..cfg.width * cin * 9).map(|_| rng.normal() * scale).collect(),
                    b: vec![0f32; cfg.width],
                    cin,
                    stride: if l == 0 { 2 } else { 1 },
                });
            }
            let fc_scale = (2.0 / cfg.width as f32).sqrt();
            LegacyCnn {
                cfg,
                convs,
                fc_w: (0..cfg.width * cfg.classes).map(|_| rng.normal() * fc_scale).collect(),
                fc_b: vec![0f32; cfg.classes],
                plans: Vec::new(),
            }
        }

        pub fn ensure_plans(&mut self, bt: usize) {
            for l in 0..self.cfg.depth {
                let cfg = self.conv_cfg(l, bt);
                if l < self.plans.len() {
                    self.plans[l].ensure(cfg);
                } else {
                    self.plans.push(Conv2dPlan::new(cfg));
                }
            }
        }

        fn in_size(&self, l: usize) -> usize {
            if l == 0 {
                self.cfg.img
            } else {
                out_size(self.cfg.img, 3, 2, 1)
            }
        }

        pub fn conv_cfg(&self, l: usize, bt: usize) -> Conv2d {
            let s = self.in_size(l);
            Conv2d {
                bt,
                cin: self.convs[l].cin,
                h: s,
                w: s,
                cout: self.cfg.width,
                k: 3,
                stride: self.convs[l].stride,
                padding: 1,
            }
        }

        #[allow(clippy::type_complexity)]
        pub fn forward(
            &self,
            backend: &dyn Backend,
            x: &[f32],
            bt: usize,
            plans: &mut [Conv2dPlan],
        ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
            let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
            let mut zs: Vec<Vec<f32>> = Vec::with_capacity(self.cfg.depth);
            for l in 0..self.cfg.depth {
                let cb = &self.convs[l];
                let z = backend.conv2d_fwd_planned(&mut plans[l], &acts[l], &cb.w, Some(&cb.b));
                let a: Vec<f32> = z.iter().map(|&v| v.max(0.0)).collect();
                zs.push(z);
                acts.push(a);
            }
            let last = self.conv_cfg(self.cfg.depth - 1, bt);
            let hw = last.hout() * last.wout();
            let width = self.cfg.width;
            let mut pooled = vec![0f32; bt * width];
            let top = &acts[self.cfg.depth];
            for b in 0..bt {
                for f in 0..width {
                    let plane = &top[(b * width + f) * hw..][..hw];
                    pooled[b * width + f] = plane.iter().sum::<f32>() / hw as f32;
                }
            }
            let classes = self.cfg.classes;
            let mut logits = backend.gemm(bt, width, classes, &pooled, &self.fc_w);
            for b in 0..bt {
                for (c, &bias) in self.fc_b.iter().enumerate() {
                    logits[b * classes + c] += bias;
                }
            }
            (acts, zs, pooled, logits)
        }

        #[allow(clippy::type_complexity)]
        pub fn head_backward(
            &self,
            pooled: &[f32],
            dlogits: &[f32],
            bt: usize,
        ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let (width, classes) = (self.cfg.width, self.cfg.classes);
            let mut dpooled = vec![0f32; bt * width];
            for b in 0..bt {
                let drow = &dlogits[b * classes..][..classes];
                for f in 0..width {
                    let wrow = &self.fc_w[f * classes..][..classes];
                    let mut acc_dp = 0f32;
                    for (dv, wv) in drow.iter().zip(wrow) {
                        acc_dp += dv * wv;
                    }
                    dpooled[b * width + f] = acc_dp;
                }
            }
            let mut dfc_w = vec![0f32; width * classes];
            let mut dfc_b = vec![0f32; classes];
            for b in 0..bt {
                let drow = &dlogits[b * classes..][..classes];
                let prow = &pooled[b * width..][..width];
                for (f, &pv) in prow.iter().enumerate() {
                    let dst = &mut dfc_w[f * classes..][..classes];
                    for (dw, &dv) in dst.iter_mut().zip(drow) {
                        *dw += pv * dv;
                    }
                }
                for (db, &dv) in dfc_b.iter_mut().zip(drow) {
                    *db += dv;
                }
            }
            (dfc_w, dfc_b, dpooled)
        }

        pub fn pool_backward(&self, dpooled: &[f32], ztop: &[f32], bt: usize) -> Vec<f32> {
            let width = self.cfg.width;
            let last = self.conv_cfg(self.cfg.depth - 1, bt);
            let hw = last.hout() * last.wout();
            let inv_hw = 1.0 / hw as f32;
            let mut g = vec![0f32; bt * width * hw];
            for b in 0..bt {
                for f in 0..width {
                    let gv = dpooled[b * width + f] * inv_hw;
                    let base = (b * width + f) * hw;
                    for pix in 0..hw {
                        if ztop[base + pix] > 0.0 {
                            g[base + pix] = gv;
                        }
                    }
                }
            }
            g
        }

        /// One legacy SGD step; returns (loss, kept_channels).
        pub fn train_step(
            &mut self,
            backend: &dyn Backend,
            x: &[f32],
            y: &[i32],
            drop_rate: f64,
            lr: f32,
        ) -> (f64, usize) {
            let bt = y.len();
            self.ensure_plans(bt);
            let mut plans = std::mem::take(&mut self.plans);
            let (acts, zs, pooled, logits) = self.forward(backend, x, bt, &mut plans);
            self.plans = plans;
            let (loss_sum, _correct, dlogits) = softmax_ce_core(&logits, y, self.cfg.classes, bt);
            let loss = loss_sum / bt as f64;

            let (dfc_w, dfc_b, dpooled) = self.head_backward(&pooled, &dlogits, bt);
            let mut g = self.pool_backward(&dpooled, &zs[self.cfg.depth - 1], bt);
            for (wv, &dv) in self.fc_w.iter_mut().zip(&dfc_w) {
                *wv -= lr * dv;
            }
            for (bv, &dv) in self.fc_b.iter_mut().zip(&dfc_b) {
                *bv -= lr * dv;
            }

            let mut kept = 0usize;
            for l in (0..self.cfg.depth).rev() {
                let grads = backend.conv2d_bwd_planned(
                    &mut self.plans[l],
                    &acts[l],
                    &self.convs[l].w,
                    &g,
                    drop_rate,
                    l > 0,
                );
                kept += grads.keep_idx.len();
                for (wv, &dv) in self.convs[l].w.iter_mut().zip(&grads.dw) {
                    *wv -= lr * dv;
                }
                for (bv, &dv) in self.convs[l].b.iter_mut().zip(&grads.db) {
                    *bv -= lr * dv;
                }
                if l > 0 {
                    let zprev = &zs[l - 1];
                    g = grads.dx;
                    for (gv, &zv) in g.iter_mut().zip(zprev) {
                        if zv <= 0.0 {
                            *gv = 0.0;
                        }
                    }
                }
            }
            (loss, kept)
        }

        pub fn params(&self) -> Vec<f32> {
            let mut out = Vec::new();
            for cb in &self.convs {
                out.extend_from_slice(&cb.w);
                out.extend_from_slice(&cb.b);
            }
            out.extend_from_slice(&self.fc_w);
            out.extend_from_slice(&self.fc_b);
            out
        }
    }

    pub fn softmax_ce_core(
        logits: &[f32],
        y: &[i32],
        classes: usize,
        grad_denom: usize,
    ) -> (f64, usize, Vec<f32>) {
        let bt = y.len();
        let mut dlogits = vec![0f32; bt * classes];
        let (mut loss, mut correct) = (0f64, 0usize);
        for b in 0..bt {
            let row = &logits[b * classes..][..classes];
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut denom = 0f32;
            for &v in row {
                denom += (v - max).exp();
            }
            let label = y[b] as usize;
            loss += (denom.ln() - (row[label] - max)) as f64;
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            if argmax == label {
                correct += 1;
            }
            let drow = &mut dlogits[b * classes..][..classes];
            for (c, &v) in row.iter().enumerate() {
                let p = (v - max).exp() / denom;
                drow[c] = (p - if c == label { 1.0 } else { 0.0 }) / grad_denom as f32;
            }
        }
        (loss, correct, dlogits)
    }

    fn tree_reduce(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
        while parts.len() > 1 {
            let mut next = Vec::with_capacity(parts.len().div_ceil(2));
            let mut it = parts.into_iter();
            while let Some(mut a) = it.next() {
                if let Some(b) = it.next() {
                    for (av, bv) in a.iter_mut().zip(&b) {
                        *av += bv;
                    }
                }
                next.push(a);
            }
            parts = next;
        }
        parts.pop().unwrap_or_default()
    }

    fn reduce_select(
        imp_slots: &[Mutex<Vec<f32>>],
        bt: usize,
        hw: usize,
        cout: usize,
        keep: usize,
    ) -> Vec<usize> {
        let mut imp = vec![0f32; cout];
        for slot in imp_slots {
            let part = slot.lock().expect("importance slot poisoned");
            for (tot, &v) in imp.iter_mut().zip(part.iter()) {
                *tot += v;
            }
        }
        let denom = (bt * hw) as f32;
        for v in &mut imp {
            *v /= denom;
        }
        topk_channels(&imp, keep)
    }

    struct BarrierAttendance<'a> {
        barrier: &'a Barrier,
        remaining: std::cell::Cell<usize>,
    }

    impl<'a> BarrierAttendance<'a> {
        fn new(barrier: &'a Barrier, total: usize) -> BarrierAttendance<'a> {
            BarrierAttendance { barrier, remaining: std::cell::Cell::new(total) }
        }

        fn wait(&self) {
            self.barrier.wait();
            self.remaining.set(self.remaining.get() - 1);
        }
    }

    impl Drop for BarrierAttendance<'_> {
        fn drop(&mut self) {
            for _ in 0..self.remaining.get() {
                self.barrier.wait();
            }
        }
    }

    #[derive(Default)]
    struct ShardOut {
        loss_sum: f64,
        dfc_w: Vec<f32>,
        dfc_b: Vec<f32>,
        conv: Vec<(Vec<f32>, Vec<f32>)>,
        kept: usize,
    }

    /// The legacy conv-stack-specific data-parallel executor.
    pub struct LegacyExec {
        threads: usize,
        worker_plans: Vec<Vec<Conv2dPlan>>,
    }

    impl LegacyExec {
        pub fn new(threads: usize) -> LegacyExec {
            LegacyExec { threads: threads.max(1), worker_plans: Vec::new() }
        }

        fn ensure_worker_plans(&mut self, model: &LegacyCnn, shards: &[std::ops::Range<usize>]) {
            let depth = model.cfg.depth;
            if self.worker_plans.len() != shards.len() {
                self.worker_plans.resize_with(shards.len(), Vec::new);
            }
            for (wp, r) in self.worker_plans.iter_mut().zip(shards) {
                let sbt = r.end - r.start;
                wp.truncate(depth);
                for l in 0..depth {
                    let cfg = model.conv_cfg(l, sbt);
                    if l < wp.len() {
                        wp[l].ensure(cfg);
                    } else {
                        wp.push(Conv2dPlan::new(cfg));
                    }
                }
            }
        }

        /// One legacy data-parallel step; returns (loss, kept_channels).
        pub fn train_step(
            &mut self,
            model: &mut LegacyCnn,
            backend: &dyn Backend,
            x: &[f32],
            y: &[i32],
            drop_rate: f64,
            lr: f32,
        ) -> (f64, usize) {
            let bt = y.len();
            let n_in = model.cfg.in_ch * model.cfg.img * model.cfg.img;
            let depth = model.cfg.depth;
            let shards = shard_ranges(bt, self.threads);
            let nw = shards.len();
            model.ensure_plans(bt);
            self.ensure_worker_plans(model, &shards);

            let mut outs: Vec<ShardOut> = (0..nw).map(|_| ShardOut::default()).collect();
            let barrier = Barrier::new(nw);
            let imp_slots: Vec<Mutex<Vec<f32>>> =
                (0..nw).map(|_| Mutex::new(Vec::new())).collect();
            let keep_slot: Mutex<Vec<usize>> = Mutex::new(Vec::new());
            let m: &LegacyCnn = model;

            std::thread::scope(|s| {
                let iter = shards.iter().zip(self.worker_plans.iter_mut()).zip(outs.iter_mut());
                for (w, ((range, plans), out)) in iter.enumerate() {
                    let (barrier, imp_slots, keep_slot) = (&barrier, &imp_slots, &keep_slot);
                    let range = range.clone();
                    s.spawn(move || {
                        let sbt = range.end - range.start;
                        let xs = &x[range.start * n_in..range.end * n_in];
                        let ys = &y[range.start..range.end];

                        let sparse_layers = (0..depth)
                            .filter(|&l| {
                                let c = m.conv_cfg(l, sbt);
                                keep_channels(c.cout, drop_rate) < c.cout
                            })
                            .count();
                        let attendance = BarrierAttendance::new(barrier, 2 * sparse_layers);

                        let (acts, zs, pooled, logits) = m.forward(backend, xs, sbt, plans);
                        let (loss_sum, _corr, dlogits) =
                            softmax_ce_core(&logits, ys, m.cfg.classes, bt);
                        let (dfc_w, dfc_b, dpooled) = m.head_backward(&pooled, &dlogits, sbt);
                        let mut g = m.pool_backward(&dpooled, &zs[depth - 1], sbt);
                        out.loss_sum = loss_sum;
                        out.dfc_w = dfc_w;
                        out.dfc_b = dfc_b;
                        out.conv = (0..depth).map(|_| (Vec::new(), Vec::new())).collect();

                        for l in (0..depth).rev() {
                            let cfg = *plans[l].cfg();
                            let keep_count = keep_channels(cfg.cout, drop_rate);
                            let keep = if keep_count == cfg.cout {
                                (0..cfg.cout).collect::<Vec<_>>()
                            } else {
                                *imp_slots[w].lock().expect("importance slot poisoned") =
                                    channel_abs_sums(&cfg, &g);
                                attendance.wait();
                                if w == 0 {
                                    let hw = cfg.hout() * cfg.wout();
                                    let sel =
                                        reduce_select(imp_slots, bt, hw, cfg.cout, keep_count);
                                    *keep_slot.lock().expect("keep slot poisoned") = sel;
                                }
                                attendance.wait();
                                keep_slot.lock().expect("keep slot poisoned").clone()
                            };
                            if w == 0 {
                                out.kept += keep.len();
                            }
                            let grads = backend.conv2d_bwd_planned_with(
                                &mut plans[l],
                                &acts[l],
                                &m.convs[l].w,
                                &g,
                                &keep,
                                l > 0,
                            );
                            if l > 0 {
                                g = grads.dx;
                                for (gv, &zv) in g.iter_mut().zip(&zs[l - 1]) {
                                    if zv <= 0.0 {
                                        *gv = 0.0;
                                    }
                                }
                            }
                            out.conv[l] = (grads.dw, grads.db);
                        }
                    });
                }
            });

            let mut loss_sum = 0f64;
            for o in &outs {
                loss_sum += o.loss_sum;
            }
            let loss = loss_sum / bt as f64;
            let kept = outs[0].kept;

            let mut dfc_w_parts = Vec::with_capacity(nw);
            let mut dfc_b_parts = Vec::with_capacity(nw);
            let mut conv_dw: Vec<Vec<Vec<f32>>> =
                (0..depth).map(|_| Vec::with_capacity(nw)).collect();
            let mut conv_db: Vec<Vec<Vec<f32>>> =
                (0..depth).map(|_| Vec::with_capacity(nw)).collect();
            for o in outs {
                dfc_w_parts.push(o.dfc_w);
                dfc_b_parts.push(o.dfc_b);
                for (l, (dw, db)) in o.conv.into_iter().enumerate() {
                    conv_dw[l].push(dw);
                    conv_db[l].push(db);
                }
            }
            let dfc_w = tree_reduce(dfc_w_parts);
            let dfc_b = tree_reduce(dfc_b_parts);
            for (wv, &dv) in model.fc_w.iter_mut().zip(&dfc_w) {
                *wv -= lr * dv;
            }
            for (bv, &dv) in model.fc_b.iter_mut().zip(&dfc_b) {
                *bv -= lr * dv;
            }
            for (l, (dw_parts, db_parts)) in conv_dw.into_iter().zip(conv_db).enumerate() {
                let dw = tree_reduce(dw_parts);
                let db = tree_reduce(db_parts);
                for (wv, &dv) in model.convs[l].w.iter_mut().zip(&dw) {
                    *wv -= lr * dv;
                }
                for (bv, &dv) in model.convs[l].b.iter_mut().zip(&db) {
                    *bv -= lr * dv;
                }
            }

            (loss, kept)
        }
    }
}

const CFG: legacy::Cfg =
    legacy::Cfg { in_ch: 2, img: 12, classes: 4, depth: 3, width: 8, seed: 33 };

fn seq_model() -> Sequential {
    simple_cnn(SimpleCnnCfg {
        in_ch: CFG.in_ch,
        img: CFG.img,
        classes: CFG.classes,
        depth: CFG.depth,
        width: CFG.width,
        seed: CFG.seed,
    })
}

fn batches(bt: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
    let n = CFG.in_ch * CFG.img * CFG.img;
    (0..8)
        .map(|i| {
            let mut rng = Pcg::new(0xB17 + i, 2);
            let x = (0..bt * n).map(|_| rng.normal()).collect();
            let y = (0..bt).map(|j| ((i as usize + j) % CFG.classes) as i32).collect();
            (x, y)
        })
        .collect()
}

/// Dense / sparse / mid-rate rotation so every selection path is hit.
fn drop_at(step: usize) -> f64 {
    match step % 3 {
        0 => 0.0,
        1 => 0.8,
        _ => 0.5,
    }
}

#[test]
fn construction_matches_legacy_parameter_stream_bitwise() {
    let old = legacy::LegacyCnn::new(CFG);
    let new = seq_model();
    assert_eq!(old.params(), new.flat_params(), "He-init streams must be identical");
}

#[test]
fn serial_train_steps_match_legacy_bitwise() {
    let be = NativeBackend::new();
    let mut old = legacy::LegacyCnn::new(CFG);
    let mut new = seq_model();
    for (step, (x, y)) in batches(12).iter().enumerate() {
        let d = drop_at(step);
        let (old_loss, old_kept) = old.train_step(&be, x, y, d, 0.05);
        let stats = new.train_step(&be, x, y, d, 0.05).unwrap();
        assert_eq!(stats.loss.to_bits(), old_loss.to_bits(), "step {step} loss bits");
        assert_eq!(stats.kept_channels, old_kept, "step {step} selection");
        assert_eq!(new.flat_params(), old.params(), "step {step} parameter bits");
    }
}

#[test]
fn generalized_executor_matches_legacy_executor_bitwise() {
    let be = NativeBackend::new();
    // bt 12 shards evenly over 1/2/4 workers; bt 10 over 4 covers the
    // uneven 3/3/2/2 path.
    for (bt, threads) in [(12usize, 1usize), (12, 2), (12, 4), (10, 4)] {
        let mut old = legacy::LegacyCnn::new(CFG);
        let mut old_exec = legacy::LegacyExec::new(threads);
        let mut new = seq_model();
        let mut new_exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        for (step, (x, y)) in batches(bt).iter().enumerate() {
            let d = drop_at(step + 1); // start sparse: selection must agree too
            let (old_loss, old_kept) = old_exec.train_step(&mut old, &be, x, y, d, 0.05);
            let stats = new_exec.train_step(&mut new, &be, x, y, d, 0.05).unwrap();
            assert_eq!(
                stats.loss.to_bits(),
                old_loss.to_bits(),
                "bt {bt} t{threads} step {step} loss bits"
            );
            assert_eq!(stats.kept_channels, old_kept, "bt {bt} t{threads} step {step} selection");
            assert_eq!(
                new.flat_params(),
                old.params(),
                "bt {bt} t{threads} step {step} parameter bits"
            );
        }
    }
}

#[test]
fn single_worker_executor_reproduces_serial_bitwise() {
    let be = NativeBackend::new();
    let mut serial = seq_model();
    let mut sharded = seq_model();
    let mut exec = ParallelExecutor::new(ExecConfig::with_threads(1));
    for (step, (x, y)) in batches(6).iter().enumerate() {
        let d = drop_at(step + 1);
        let a = serial.train_step(&be, x, y, d, 0.05).unwrap();
        let b = exec.train_step(&mut sharded, &be, x, y, d, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss");
        assert_eq!(a.kept_channels, b.kept_channels, "step {step} selection");
        assert_eq!(serial.flat_params(), sharded.flat_params(), "step {step} weights");
    }
}
