//! Report formatting: markdown tables on stdout + raw JSON rows under
//! `results/` so EXPERIMENTS.md can be regenerated from data.

use std::path::Path;

use crate::util::json::{arr, Json};

/// Simple column-aligned markdown table.
pub struct Table {
    /// Heading printed above the table.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each the same arity as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (panics on arity mismatch).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as column-aligned markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Persist rows as JSON for downstream regeneration.
    pub fn save_json(&self, id: &str) {
        let _ = std::fs::create_dir_all("results");
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
            .collect();
        let j = crate::util::json::obj(vec![
            ("id", Json::Str(id.to_string())),
            ("title", Json::Str(self.title.clone())),
            ("headers", arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect())),
            ("rows", arr(rows)),
        ]);
        let path = Path::new("results").join(format!("{id}.json"));
        let _ = std::fs::write(path, j.to_string());
    }
}

/// Format with 2 decimal places.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Format with 3 decimal places.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

/// Format with 4 decimal places.
pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

/// Format a fraction as a percentage with 1 decimal place.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["a", "bcd"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["1000".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("### T"));
        assert!(r.contains("| a    | bcd |"));
        assert!(r.contains("| 1000 | x   |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(f3(0.12345), "0.123");
        assert_eq!(pct(0.4), "40.0%");
    }
}
