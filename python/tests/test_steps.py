"""AOT step builders: training decreases loss; manifests are consistent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import steps as steps_mod
from compile.models.ddpm_unet import UNet
from compile.models.simple_cnn import SimpleCNN


def _toy_batch(rng, batch=16, classes=4, img=12):
    """Linearly separable-ish blobs so a tiny CNN learns in a few steps."""
    y = rng.integers(0, classes, size=(batch,))
    x = rng.normal(size=(batch, 3, img, img)).astype(np.float32) * 0.3
    for i, cls in enumerate(y):
        x[i, cls % 3, :, :] += 1.0 + 0.5 * cls
    return jnp.array(x), jnp.array(y, jnp.int32)


@pytest.mark.parametrize("drop_rate", [0.0, 0.8])
def test_train_step_decreases_loss(drop_rate):
    model = SimpleCNN(depth=2, in_ch=3, img=12, classes=4, width=8)
    pack = steps_mod.make_classify_steps(model, batch=16, loss="ce")
    train, args, _, _ = pack["train"]
    train = jax.jit(train)
    params, opt, bn = args[0], args[1], args[2]
    rng = np.random.default_rng(0)
    x, y = _toy_batch(rng)
    losses = []
    n = 30 if drop_rate == 0.0 else 60  # sparse training converges slower
    for i in range(n):
        key = jnp.asarray([i, 0], jnp.uint32)
        params, opt, bn, l, a = train(params, opt, bn, x, y, jnp.float32(3e-3),
                                      jnp.float32(drop_rate), jnp.float32(0), key)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.5, losses[::6]
    assert np.isfinite(losses).all()


def test_sparse_step_matches_dense_at_zero_rate():
    model = SimpleCNN(depth=2, in_ch=3, img=12, classes=4, width=8)
    pack = steps_mod.make_classify_steps(model, batch=8, loss="ce")
    train, args, _, _ = pack["train"]
    train = jax.jit(train)
    rng = np.random.default_rng(1)
    x, y = _toy_batch(rng, batch=8)
    key = jnp.zeros((2,), jnp.uint32)
    out1 = train(args[0], args[1], args[2], x, y, jnp.float32(1e-3), jnp.float32(0),
                 jnp.float32(0), key)
    out2 = train(args[0], args[1], args[2], x, y, jnp.float32(1e-3), jnp.float32(0),
                 jnp.float32(0), key)
    for a, b in zip(jax.tree.leaves(out1), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bce_steps_for_multilabel():
    model = SimpleCNN(depth=2, in_ch=3, img=12, classes=6, width=8)
    pack = steps_mod.make_classify_steps(model, batch=8, loss="bce")
    train, args, roles, out_roles = pack["train"]
    x = jnp.array(np.random.default_rng(0).normal(size=(8, 3, 12, 12)), jnp.float32)
    y = jnp.array(np.random.default_rng(1).integers(0, 2, size=(8, 6)), jnp.float32)
    params, opt, bn, l, a = jax.jit(train)(
        args[0], args[1], args[2], x, y, jnp.float32(1e-3), jnp.float32(0.5),
        jnp.float32(0), jnp.zeros((2,), jnp.uint32))
    assert np.isfinite(float(l)) and 0.0 <= float(a) <= 1.0


def test_ddpm_train_step_runs_and_decreases():
    unet = UNet(in_ch=1, img=12, base=8)
    pack = steps_mod.make_ddpm_steps(unet, batch=8, timesteps=20)
    train, args, _, _ = pack["train"]
    train = jax.jit(train)
    params, opt = args[0], args[1]
    rng = np.random.default_rng(0)
    x0 = jnp.array(rng.normal(size=(8, 1, 12, 12)).astype(np.float32))
    losses = []
    for i in range(25):
        key = jnp.asarray([i, 1], jnp.uint32)
        params, opt, l = train(params, opt, x0, jnp.float32(2e-3), jnp.float32(0.5), key)
        losses.append(float(l))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_manifest_io_roundtrip_and_feeds():
    model = SimpleCNN(depth=2, in_ch=1, img=8, classes=3, width=8)
    pack = steps_mod.make_classify_steps(model, batch=4, loss="ce")
    train, args, roles, out_roles = pack["train"]
    outs = jax.eval_shape(train, *args)
    inputs, outputs = steps_mod.manifest_io(args, roles, outs, out_roles)
    # every state output feeds a uniquely-named input of identical shape
    fed = [o for o in outputs if o["feeds_input"] >= 0]
    assert len(fed) == sum(1 for o in outputs if o["role"] in ("param", "opt", "bn"))
    for o in fed:
        i = inputs[o["feeds_input"]]
        assert i["name"] == o["name"] and i["shape"] == o["shape"] and i["dtype"] == o["dtype"]
    # scalar controls present exactly once each
    for role in ("lr", "drop_rate", "dropout_rate", "key"):
        assert sum(1 for i in inputs if i["role"] == role) == 1
    # input count equals jax's flattened calling convention
    assert len(inputs) == len(jax.tree.leaves(args))
