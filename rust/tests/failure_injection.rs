//! Failure injection: every layer of the runtime must fail loudly and
//! specifically, never silently mis-train. The manifest/tensorstore/
//! scheduler/discovery checks run on every build, as do the serving-path
//! checks (checkpoint/`--model` mismatch, BN-less folds, truncated folded
//! checkpoints); engine-level checks need the `pjrt` feature.

use std::io::Write as _;

use ssprop::runtime::{EngineError, Manifest};
use ssprop::tensorstore::{self, Tensor};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ssprop_fail_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn artifacts_discovery_error_is_typed() {
    // On a bare runner there is no artifacts/index.json: the error must be
    // the typed ArtifactsMissing (downcastable through anyhow) so tests and
    // benches can downgrade it to a skip. When artifacts do exist, the
    // discovered directory must actually contain the index.
    match ssprop::runtime::find_artifacts_dir() {
        Ok(dir) => {
            // env override is trusted verbatim; fallback needs the index
            assert!(std::env::var("SSPROP_ARTIFACTS").is_ok() || dir.join("index.json").exists());
        }
        Err(err) => {
            let EngineError::ArtifactsMissing { searched } = &err;
            assert!(!searched.is_empty());
            let any: anyhow::Error = err.clone().into();
            assert!(any.downcast_ref::<EngineError>().is_some());
        }
    }
}

#[test]
fn manifest_parser_rejects_malformed_documents() {
    for bad in [
        "",                                             // empty
        "{",                                            // truncated
        r#"{"name": "x"}"#,                             // missing inputs/outputs
        r#"{"name": "x", "inputs": 3, "outputs": []}"#, // wrong type
    ] {
        assert!(Manifest::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn tensorstore_header_lying_about_offsets_rejected() {
    let d = tmp_dir("tstore");
    let p = d.join("x.tstore");
    tensorstore::write(&p, &[("a".into(), Tensor::from_f32(vec![2], &[1.0, 2.0]))]).unwrap();
    // corrupt: rewrite header with an offset past the payload
    let raw = std::fs::read(&p).unwrap();
    let hlen = u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize;
    let header = String::from_utf8(raw[12..12 + hlen].to_vec()).unwrap();
    let evil = header.replace("\"offset\":0", "\"offset\":9999");
    assert_ne!(header, evil);
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(b"TSTORE01").unwrap();
    f.write_all(&(evil.len() as u32).to_le_bytes()).unwrap();
    f.write_all(evil.as_bytes()).unwrap();
    f.write_all(&raw[12 + hlen..]).unwrap();
    drop(f);
    assert!(tensorstore::read(&p).is_err());
}

#[test]
fn scheduler_rejects_invalid_targets() {
    use ssprop::schedule::{DropScheduler, Schedule};
    for bad in [1.0, 1.5, -0.1] {
        let r = std::panic::catch_unwind(|| DropScheduler::new(Schedule::Constant, bad, 1, 1));
        assert!(r.is_err(), "target {bad} must be rejected");
    }
}

#[test]
fn native_trainer_rejects_bad_configs() {
    use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
    let mut cfg = NativeTrainConfig::quick("cifar10", 1, 1);
    cfg.batch = 0;
    assert!(NativeTrainer::new(cfg).is_err(), "zero batch must be rejected");
    let err = NativeTrainer::new(NativeTrainConfig::quick("celeba", 1, 1))
        .err()
        .expect("BCE dataset must be rejected")
        .to_string();
    assert!(err.contains("CE"), "{err}");
}

// ---------------------------------------------------------------------------
// serving-path injections: fold + serve fail typed, never panic
// ---------------------------------------------------------------------------

mod serving {
    use std::collections::HashMap;

    use super::tmp_dir;
    use ssprop::backend::fold::{self, FoldError};
    use ssprop::backend::{build_model, parse_model_spec};
    use ssprop::coordinator::{checkpoint, ServeConfig, ServeError, Server};
    use ssprop::tensorstore::Tensor;

    /// Save an untrained checkpoint for `spec` on the mnist geometry
    /// (serve and fold rebuild the model through the dataset registry, so
    /// the artifact must name a registered dataset).
    fn save_checkpoint(dir: &std::path::Path, file: &str, spec: &str) -> std::path::PathBuf {
        let parsed = parse_model_spec(spec).unwrap();
        let ds = ssprop::data::spec("mnist").unwrap();
        let m = build_model(&parsed, ds.channels, ds.img, ds.classes, 5).unwrap();
        let state: HashMap<String, Tensor> = m.state_tensors().into_iter().collect();
        let path = dir.join(file);
        let artifact = format!("native_mnist:{}", parsed.canonical());
        checkpoint::save_tensors(&path, &state, &artifact, 1).unwrap();
        path
    }

    #[test]
    fn serve_model_mismatch_is_typed_and_names_both_specs() {
        let d = tmp_dir("serve_mismatch");
        let ck = save_checkpoint(&d, "vgg.tstore", "vgg-tiny-w4");
        let err = Server::from_checkpoint(&ck, Some("resnet-tiny-w4-b1"), ServeConfig::default())
            .err()
            .expect("mismatched --model must be rejected");
        let typed = err.downcast_ref::<ServeError>().expect("typed ServeError");
        let ServeError::SpecMismatch { saved, requested } = typed;
        assert_eq!(saved, "vgg-tiny-w4");
        assert_eq!(requested, "resnet-tiny-w4-b1");
        let msg = err.to_string();
        assert!(msg.contains("vgg-tiny-w4") && msg.contains("resnet-tiny-w4-b1"), "{msg}");
    }

    #[test]
    fn folding_a_bn_less_checkpoint_is_a_typed_no_op() {
        let d = tmp_dir("fold_nobn");
        let ck = save_checkpoint(&d, "plain.tstore", "simple-cnn-d2-w4");
        let out = d.join("folded.tstore");
        let err = fold::fold_checkpoint(&ck, &out).err().expect("no-BN fold must refuse");
        match err.downcast_ref::<FoldError>() {
            Some(FoldError::NoBatchNorm { spec }) => assert_eq!(spec, "simple-cnn-d2-w4"),
            other => panic!("want NoBatchNorm, got {other:?}"),
        }
        assert!(!out.exists(), "a refused fold must not write an output file");
    }

    #[test]
    fn truncated_folded_checkpoint_is_rejected_at_load() {
        let d = tmp_dir("fold_trunc");
        let ck = save_checkpoint(&d, "rn.tstore", "resnet-tiny-w4-b1");
        let folded = d.join("rn_folded.tstore");
        fold::fold_checkpoint(&ck, &folded).unwrap();
        fold::load_folded(&folded).expect("the intact folded checkpoint loads");
        // Chop the payload mid-tensor: the store reader must reject the
        // file instead of serving a half-restored model.
        let raw = std::fs::read(&folded).unwrap();
        std::fs::write(&folded, &raw[..raw.len() - 64]).unwrap();
        assert!(fold::load_folded(&folded).is_err(), "truncated checkpoint must not load");
    }
}

// ---------------------------------------------------------------------------
// engine-level injections (PJRT builds only)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_failures {
    use super::tmp_dir;
    use ssprop::runtime::{f32_literal, Engine};

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let d = tmp_dir("missing");
        std::fs::write(d.join("index.json"), r#"{"artifacts": []}"#).unwrap();
        let engine = Engine::new(&d).unwrap();
        let err = engine.load("nope_train").err().expect("must fail").to_string();
        assert!(err.contains("nope_train"), "{err}");
    }

    #[test]
    fn garbage_hlo_text_fails_at_parse_not_execute() {
        let d = tmp_dir("garbage");
        std::fs::write(d.join("bad.hlo.txt"), "this is not hlo").unwrap();
        std::fs::write(
            d.join("bad.manifest.json"),
            r#"{"name": "bad", "inputs": [], "outputs": []}"#,
        )
        .unwrap();
        let engine = Engine::new(&d).unwrap();
        let err = format!("{:?}", engine.load("bad").err().expect("must fail"));
        assert!(err.contains("parse"), "{err}");
    }

    #[test]
    fn wrong_input_count_rejected_before_pjrt() {
        // use the real artifacts if present; otherwise skip
        let Ok(engine) = Engine::auto() else { return };
        let Ok(g) = engine.load("conv_pallas_dense") else { return };
        let one = f32_literal(&[1], &[0.0]).unwrap();
        let err = g.run(&[&one]).err().expect("must fail").to_string();
        assert!(err.contains("expects"), "{err}");
    }

    #[test]
    fn engine_with_bad_dir_fails_lazily_on_use() {
        let d = tmp_dir("empty_dir");
        // Engine::new itself succeeds (lazy); loading must fail
        if let Ok(e) = Engine::new(d.join("does_not_exist")) {
            assert!(e.load("anything").is_err());
            assert!(e.list_artifacts().is_err());
        }
    }
}
