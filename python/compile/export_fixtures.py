"""Export conv fwd/bwd reference fixtures for the Rust NativeBackend tests.

Mirrors the exact algorithms implemented in ``rust/src/backend/`` with plain
numpy loops, cross-checks every value against the L1 reference oracle
(:mod:`python.compile.kernels.ref`, i.e. the paper's equations via JAX), and
writes ``rust/tests/fixtures/native_conv.json``.

Run from the repo root:

    python python/compile/export_fixtures.py

The JSON is committed so `cargo test` never needs Python/JAX; re-run this
script only when the reference semantics change.
"""

from __future__ import annotations

import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import jax.numpy as jnp  # noqa: E402
from kernels import ref  # noqa: E402


# ---------------------------------------------------------------------------
# numpy mirror of the Rust NativeBackend (same index math, same loop order)
# ---------------------------------------------------------------------------

def out_size(h: int, k: int, stride: int, padding: int) -> int:
    return (h + 2 * padding - k) // stride + 1


def np_im2col(x, k, stride, padding):
    bt, cin, h, w = x.shape
    ho, wo = out_size(h, k, stride, padding), out_size(w, k, stride, padding)
    cols = np.zeros((bt * ho * wo, cin * k * k), np.float32)
    for b in range(bt):
        for i in range(ho):
            for j in range(wo):
                m = (b * ho + i) * wo + j
                for c in range(cin):
                    for ky in range(k):
                        for kx in range(k):
                            n = (c * k + ky) * k + kx
                            y = i * stride + ky - padding
                            xx = j * stride + kx - padding
                            if 0 <= y < h and 0 <= xx < w:
                                cols[m, n] = x[b, c, y, xx]
    return cols


def np_col2img(cols, x_shape, k, stride, padding):
    bt, cin, h, w = x_shape
    ho, wo = out_size(h, k, stride, padding), out_size(w, k, stride, padding)
    out = np.zeros(x_shape, np.float32)
    for b in range(bt):
        for i in range(ho):
            for j in range(wo):
                m = (b * ho + i) * wo + j
                for c in range(cin):
                    for ky in range(k):
                        for kx in range(k):
                            n = (c * k + ky) * k + kx
                            y = i * stride + ky - padding
                            xx = j * stride + kx - padding
                            if 0 <= y < h and 0 <= xx < w:
                                out[b, c, y, xx] += cols[m, n]
    return out


def np_keep_channels(cout: int, d: float) -> int:
    # ties-to-even, matching both jnp.round and Rust f64::round_ties_even
    return int(min(max(np.round((1.0 - d) * cout), 1), cout))


def np_importance(g):
    return np.mean(np.abs(g), axis=(0, 2, 3), dtype=np.float32).astype(np.float32)


def np_topk_channels(imp, keep):
    order = sorted(range(len(imp)), key=lambda i: (-imp[i], i))
    return sorted(order[:keep])


def np_backend(x, w, b, g, drop_rate, stride, padding):
    """Forward + ssProp backward exactly as NativeBackend computes them."""
    bt, cin, h, wd = x.shape
    cout, _, k, _ = w.shape
    ho, wo = out_size(h, k, stride, padding), out_size(wd, k, stride, padding)
    m, n = bt * ho * wo, cin * k * k

    cols = np_im2col(x, k, stride, padding)              # (M, N)
    cw = w.reshape(cout, n).T.copy()                     # (N, Cout)
    ycol = cols @ cw + b[None, :]                        # (M, Cout)
    y = ycol.reshape(bt, ho, wo, cout).transpose(0, 3, 1, 2)

    imp = np_importance(g)
    keep = np_keep_channels(cout, drop_rate)
    keep_idx = np_topk_channels(imp, keep)

    gc = g.transpose(0, 2, 3, 1).reshape(m, cout)        # col[dY]
    gck = gc[:, keep_idx]                                # (M, k')
    cwk = cw[:, keep_idx]                                # (N, k')
    dwk = cols.T @ gck                                   # (N, k')
    dw = np.zeros((cout, cin, k, k), np.float32)
    for pos, o in enumerate(keep_idx):
        dw[o] = dwk[:, pos].reshape(cin, k, k)
    dcols = gck @ cwk.T                                  # (M, N)
    dx = np_col2img(dcols, x.shape, k, stride, padding)
    db = np.zeros(cout, np.float32)
    for pos, o in enumerate(keep_idx):
        db[o] = gck[:, pos].sum()
    return y.astype(np.float32), imp, keep_idx, dx, dw.astype(np.float32), db


# ---------------------------------------------------------------------------
# cross-check against the JAX reference oracle, then export
# ---------------------------------------------------------------------------

CASES = [
    # (name, bt, cin, cout, h, w, k, stride, padding, drop_rate)
    ("k3_s1_p1_d50", 2, 3, 8, 6, 6, 3, 1, 1, 0.5),
    ("k3_s2_p0_d90", 1, 2, 4, 5, 5, 3, 2, 0, 0.9),
    ("k3_s2_p1_dense", 2, 1, 6, 8, 8, 3, 2, 1, 0.0),
    # keep-count tie: (1-0.5)*5 = 2.5 rounds to even -> keep 2
    ("k3_s1_p1_tie", 1, 2, 5, 4, 4, 3, 1, 1, 0.5),
    # plan/fused-path coverage beyond the quickstart geometry: 1x1 kernels
    # (pure channel mixes), stride-2 + padding-0 downsampling, rectangular
    # H != W inputs, and a k=5 receptive field
    ("k1_s1_p0_d50", 2, 3, 6, 5, 4, 1, 1, 0, 0.5),
    ("k1_s2_p0_dense", 1, 4, 5, 6, 5, 1, 2, 0, 0.0),
    ("k3_s2_p0_rect_d25", 2, 2, 6, 7, 6, 3, 2, 0, 0.25),
    ("k5_s2_p0_d75", 1, 2, 4, 9, 7, 5, 2, 0, 0.75),
]


def check_close(name, a, b, tol=1e-5):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    err = np.max(np.abs(a - b) / np.maximum(1.0, np.abs(b)))
    assert err < tol, f"{name}: max rel err {err}"
    return err


def build_case(name, bt, cin, cout, h, w, k, stride, padding, drop_rate, rng):
    x = rng.standard_normal((bt, cin, h, w)).astype(np.float32)
    wt = (rng.standard_normal((cout, cin, k, k)) * 0.2).astype(np.float32)
    b = (rng.standard_normal(cout) * 0.1).astype(np.float32)
    ho, wo = out_size(h, k, stride, padding), out_size(w, k, stride, padding)
    g = rng.standard_normal((bt, cout, ho, wo)).astype(np.float32)

    y, imp, keep_idx, dx, dw, db = np_backend(x, wt, b, g, drop_rate, stride, padding)

    # oracle: forward + importance + selection
    y_ref = ref.conv_fwd_ref(jnp.array(x), jnp.array(wt), jnp.array(b),
                             stride=stride, padding=padding)
    check_close(f"{name}/y", y, y_ref)
    imp_ref = ref.importance_ref(jnp.array(g), "channel")
    check_close(f"{name}/importance", imp, imp_ref)
    keep_ref = int(ref.keep_k_from_drop_rate(jnp.float32(drop_rate), cout))
    assert len(keep_idx) == keep_ref, f"{name}: keep {len(keep_idx)} vs ref {keep_ref}"
    mask_ref = np.asarray(ref.topk_mask_ref(jnp.array(imp), keep_ref))
    assert keep_idx == [i for i in range(cout) if mask_ref[i] > 0], f"{name}: keep_idx"

    # oracle: backward (compacted reference; dense when keep == cout)
    dx_ref, dw_ref, db_ref = ref.sparse_bwd_compact_ref(
        jnp.array(x), jnp.array(wt), jnp.array(g), jnp.array(keep_idx),
        stride=stride, padding=padding,
    )
    check_close(f"{name}/dx", dx, dx_ref)
    check_close(f"{name}/dw", dw, dw_ref)
    check_close(f"{name}/db", db, db_ref)
    if drop_rate == 0.0:
        ddx, ddw, ddb = ref.conv_bwd_ref(jnp.array(x), jnp.array(wt), jnp.array(g),
                                         stride=stride, padding=padding)
        check_close(f"{name}/dx_dense", dx, ddx)
        check_close(f"{name}/dw_dense", dw, ddw)
        check_close(f"{name}/db_dense", db, ddb)

    flat = lambda a: [float(v) for v in np.asarray(a, np.float32).reshape(-1)]
    return {
        "name": name,
        "bt": bt, "cin": cin, "cout": cout, "h": h, "w": w,
        "k": k, "stride": stride, "padding": padding,
        "drop_rate": drop_rate,
        # "wt"/"bias": the conv parameters ("w" is the image width above)
        "x": flat(x), "wt": flat(wt), "bias": flat(b), "g": flat(g),
        "y": flat(y), "importance": flat(imp),
        "keep_idx": keep_idx,
        "dx": flat(dx), "dw": flat(dw), "db": flat(db),
    }


def main():
    rng = np.random.default_rng(20240825)
    cases = [build_case(*case, rng) for case in CASES]
    out = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "fixtures"
    out.mkdir(parents=True, exist_ok=True)
    path = out / "native_conv.json"
    path.write_text(json.dumps({"cases": cases}))
    print(f"wrote {path} ({path.stat().st_size} bytes, {len(cases)} cases)")


if __name__ == "__main__":
    main()
