//! PJRT training loop (feature `pjrt`): drives AOT-compiled train/eval
//! graphs, feeding state leaves back from the previous iteration's outputs.
//! The same loop drives every classifier artifact; `ddpm.rs` reuses the
//! state machinery for generation.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::{TrainConfig, TrainMetrics};
use crate::data::{Loader, Split, SynthDataset};
use crate::runtime::{
    f32_literal, i32_literal, literal_scalar_f32, scalar_f32, tensor_to_literal, u32_literal,
    Engine, LoadedGraph, Role,
};
use crate::util::rng::Pcg;

/// A live training job: compiled graphs + mutable state leaves.
pub struct Trainer {
    /// The configuration this job was built from.
    pub cfg: TrainConfig,
    /// Compiled training-step graph.
    pub train_graph: Arc<LoadedGraph>,
    /// Compiled eval graph, when the artifact provides one.
    pub eval_graph: Option<Arc<LoadedGraph>>,
    /// State leaves keyed by manifest input name (params, opt, bn).
    pub state: HashMap<String, xla::Literal>,
    /// Train-split batch loader.
    pub loader: Loader,
    /// Test-split batch loader (evaluation).
    pub test_loader: Loader,
    /// Loss/acc curves, FLOPs ledger, wall-clock.
    pub metrics: TrainMetrics,
    rng: Pcg,
}

impl Trainer {
    /// Load the artifact's `_train`/`_eval` graphs, initial state and
    /// data plane for `cfg`.
    pub fn new(engine: &Engine, cfg: TrainConfig) -> Result<Trainer> {
        let train_graph = engine.load(&format!("{}_train", cfg.artifact))?;
        let eval_graph = engine.load(&format!("{}_eval", cfg.artifact)).ok();
        let man = &train_graph.manifest;
        let spec = crate::data::spec(&man.dataset)
            .with_context(|| format!("unknown dataset {:?}", man.dataset))?;
        let ds = SynthDataset::new(spec.clone(), cfg.seed);
        let loader = Loader::new(ds.clone(), Split::Train, man.batch);
        let test_loader = Loader::new(ds, Split::Test, man.batch);

        // initial state from the AOT-produced tensorstore
        let mut state = HashMap::new();
        for (name, t) in engine.load_init(&format!("{}_train", cfg.artifact))? {
            state.insert(name, tensor_to_literal(&t)?);
        }
        // sanity: every state input has an initial value
        for i in &man.inputs {
            if i.role.is_state() && !state.contains_key(&i.name) {
                bail!("no initial value for state input {:?}", i.name);
            }
        }
        let rng = Pcg::new(cfg.seed ^ 0xC0FFEE, 11);
        Ok(Trainer {
            cfg,
            train_graph,
            eval_graph,
            state,
            loader,
            test_loader,
            metrics: TrainMetrics::default(),
            rng,
        })
    }

    /// Iterations per epoch after capping to the dataset size.
    pub fn iters_per_epoch(&self) -> usize {
        self.cfg.iters_per_epoch.min(self.loader.batches_per_epoch()).max(1)
    }

    /// Run the configured number of epochs. Returns final test (loss, acc).
    pub fn run(&mut self) -> Result<(f64, f64)> {
        let ipe = self.iters_per_epoch();
        let mut it = 0usize;
        for epoch in 0..self.cfg.epochs {
            let rx = self.loader.prefetch_epoch(epoch, 4);
            let t0 = Instant::now();
            for (b, batch) in rx.iter().enumerate() {
                if b >= ipe {
                    break;
                }
                let d = self.cfg.scheduler.rate_at(it);
                let (loss, acc) = self.step(&batch, d)?;
                let man = &self.train_graph.manifest;
                self.metrics.record_iter(loss, acc, d, &man.layers, man.batch);
                it += 1;
            }
            self.metrics.record_epoch(t0.elapsed());
            if self.cfg.verbose {
                let m = &self.metrics;
                println!(
                    "epoch {epoch:>3}  loss {:.4}  acc {:.3}  drop {:.2}  ({} iters)",
                    m.last_epoch_loss(ipe),
                    m.last_epoch_acc(ipe),
                    self.cfg.scheduler.rate_at(it.saturating_sub(1)),
                    ipe
                );
            }
            if self.cfg.eval_every > 0 && (epoch + 1) % self.cfg.eval_every == 0 {
                let (l, a) = self.evaluate()?;
                self.metrics.record_eval(epoch, l, a);
                if self.cfg.verbose {
                    println!("          test loss {l:.4}  test acc {a:.3}");
                }
            }
        }
        let fin = self.evaluate()?;
        self.metrics.record_eval(self.cfg.epochs.saturating_sub(1), fin.0, fin.1);
        Ok(fin)
    }

    /// One training step at drop rate `d`.
    pub fn step(&mut self, batch: &crate::data::Batch, d: f64) -> Result<(f64, f64)> {
        // keep an Arc to the graph so `man` borrows from it, not from self
        // (avoids deep-cloning the manifest every iteration).
        let graph = self.train_graph.clone();
        let man = &graph.manifest;
        let key = self.rng.jax_key();
        // ephemeral (non-state) literals, keyed by input index
        let mut ephemeral: Vec<(usize, xla::Literal)> = Vec::new();
        for (idx, spec) in man.inputs.iter().enumerate() {
            let lit = match spec.role {
                Role::Param | Role::Opt | Role::Bn => continue,
                Role::DataX => f32_literal(&spec.shape, &batch.x)?,
                Role::DataY => {
                    if spec.dtype == "i32" {
                        i32_literal(&spec.shape, &batch.y_class)?
                    } else {
                        f32_literal(&spec.shape, &batch.y_multi)?
                    }
                }
                Role::Lr => scalar_f32(self.cfg.lr as f32)?,
                Role::DropRate => scalar_f32(d as f32)?,
                Role::DropoutRate => scalar_f32(self.cfg.dropout_rate as f32)?,
                Role::Key => u32_literal(&spec.shape, &key)?,
                other => bail!("unexpected train input role {other:?}"),
            };
            ephemeral.push((idx, lit));
        }
        let outs = run_with_state(&graph, &self.state, ephemeral)?;

        // re-bind state + extract scalars
        let mut loss = f64::NAN;
        let mut acc = f64::NAN;
        for (o, lit) in man.outputs.iter().zip(outs) {
            if o.feeds_input >= 0 {
                self.state.insert(o.name.clone(), lit);
            } else if o.role == Role::Loss {
                loss = literal_scalar_f32(&lit)? as f64;
            } else if o.role == Role::Acc {
                acc = literal_scalar_f32(&lit)? as f64;
            }
        }
        if !loss.is_finite() {
            bail!("non-finite loss at drop rate {d}");
        }
        Ok((loss, acc))
    }

    /// Mean (loss, acc) over the test split using the eval graph.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let graph = match &self.eval_graph {
            Some(g) => g.clone(),
            None => return Ok((f64::NAN, f64::NAN)),
        };
        let man = &graph.manifest;
        let order = self.test_loader.epoch_order(0);
        let nb = self.test_loader.batches_per_epoch();
        let (mut sl, mut sa) = (0.0, 0.0);
        for b in 0..nb {
            let batch = self.test_loader.batch(&order, b);
            let mut ephemeral: Vec<(usize, xla::Literal)> = Vec::new();
            for (idx, spec) in man.inputs.iter().enumerate() {
                let lit = match spec.role {
                    Role::Param | Role::Bn => continue,
                    Role::DataX => f32_literal(&spec.shape, &batch.x)?,
                    Role::DataY => {
                        if spec.dtype == "i32" {
                            i32_literal(&spec.shape, &batch.y_class)?
                        } else {
                            f32_literal(&spec.shape, &batch.y_multi)?
                        }
                    }
                    other => bail!("unexpected eval input role {other:?}"),
                };
                ephemeral.push((idx, lit));
            }
            let outs = run_with_state(&graph, &self.state, ephemeral)?;
            sl += literal_scalar_f32(&outs[man.output_index(Role::Loss).context("loss")?])? as f64;
            sa += literal_scalar_f32(&outs[man.output_index(Role::Acc).context("acc")?])? as f64;
        }
        Ok((sl / nb as f64, sa / nb as f64))
    }
}

/// Execute `graph` with state leaves pulled from `state` by name and the
/// provided ephemeral literals (indexed by manifest input position).
pub fn run_with_state(
    graph: &LoadedGraph,
    state: &HashMap<String, xla::Literal>,
    ephemeral: Vec<(usize, xla::Literal)>,
) -> Result<Vec<xla::Literal>> {
    let man = &graph.manifest;
    let eph: HashMap<usize, xla::Literal> = ephemeral.into_iter().collect();
    let mut refs: Vec<&xla::Literal> = Vec::with_capacity(man.inputs.len());
    for (idx, spec) in man.inputs.iter().enumerate() {
        if let Some(l) = eph.get(&idx) {
            refs.push(l);
        } else {
            refs.push(
                state
                    .get(&spec.name)
                    .with_context(|| format!("missing state leaf {:?}", spec.name))?,
            );
        }
    }
    graph.run(&refs)
}
