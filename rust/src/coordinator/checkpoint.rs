//! Checkpointing: persist/restore the coordinator's state leaves (params,
//! optimizer moments, BN statistics) as a tensorstore file, plus a JSON
//! sidecar with the training position. Checkpoints are interchangeable with
//! the Python side (same format as `*.init.tstore`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::{literal_to_tensor, tensor_to_literal};
use crate::tensorstore;
use crate::util::json::{num, obj, s, Json};

pub fn save<P: AsRef<Path>>(
    path: P,
    state: &HashMap<String, xla::Literal>,
    artifact: &str,
    epoch: usize,
) -> Result<()> {
    let mut names: Vec<&String> = state.keys().collect();
    names.sort();
    let mut tensors = Vec::with_capacity(names.len());
    for name in names {
        tensors.push((name.clone(), literal_to_tensor(&state[name])?));
    }
    tensorstore::write(path.as_ref(), &tensors)?;
    let meta = obj(vec![
        ("artifact", s(artifact)),
        ("epoch", num(epoch as f64)),
        ("leaves", num(tensors.len() as f64)),
    ]);
    std::fs::write(sidecar(path.as_ref()), meta.to_string())?;
    Ok(())
}

pub fn load<P: AsRef<Path>>(path: P) -> Result<(HashMap<String, xla::Literal>, String, usize)> {
    let mut state = HashMap::new();
    for (name, t) in tensorstore::read(path.as_ref())? {
        state.insert(name, tensor_to_literal(&t)?);
    }
    let meta_text = std::fs::read_to_string(sidecar(path.as_ref()))
        .with_context(|| "checkpoint sidecar missing")?;
    let meta = Json::parse(&meta_text).map_err(anyhow::Error::msg)?;
    let artifact = meta.str_field("artifact").map_err(anyhow::Error::msg)?.to_string();
    let epoch = meta.usize_field("epoch").map_err(anyhow::Error::msg)?;
    Ok((state, artifact, epoch))
}

fn sidecar(path: &Path) -> std::path::PathBuf {
    path.with_extension("meta.json")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::f32_literal;

    #[test]
    fn roundtrip_state() {
        let dir = std::env::temp_dir().join("ssprop_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ck.tstore");
        let mut state = HashMap::new();
        state.insert("param['w']".to_string(), f32_literal(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap());
        state.insert("opt['m']".to_string(), f32_literal(&[2], &[0.5, -0.5]).unwrap());
        save(&p, &state, "resnet18_cifar10", 7).unwrap();
        let (back, artifact, epoch) = load(&p).unwrap();
        assert_eq!(artifact, "resnet18_cifar10");
        assert_eq!(epoch, 7);
        assert_eq!(back.len(), 2);
        let w = back["param['w']"].to_vec::<f32>().unwrap();
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
