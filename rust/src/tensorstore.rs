//! Tensorstore — binary tensor interchange with the Python compile path
//! (S8). Format documented in python/compile/tensorstore.py; round-trip
//! equality across languages is covered by rust/tests/tensorstore_interop.rs.

use std::collections::BTreeMap;
use std::io::{Read as _, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

const MAGIC: &[u8; 8] = b"TSTORE01";

/// Element type of a stored tensor (all 4-byte, little-endian).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
    /// 32-bit unsigned integer.
    U32,
}

impl Dtype {
    /// Wire name used in headers ("f32" / "i32" / "u32").
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::U32 => "u32",
        }
    }
    /// Parse a wire name back into a dtype.
    pub fn from_name(n: &str) -> Result<Dtype> {
        Ok(match n {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }
    /// Bytes per element.
    pub fn size(self) -> usize {
        4
    }
}

/// A host tensor: raw little-endian bytes + shape + dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Element type.
    pub dtype: Dtype,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Raw little-endian element bytes.
    pub data: Vec<u8>,
}

impl Tensor {
    /// An f32 tensor from values (asserts shape/value-count agreement).
    pub fn from_f32(shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: Dtype::F32, shape, data }
    }

    /// An i32 tensor from values (asserts shape/value-count agreement).
    pub fn from_i32(shape: Vec<usize>, vals: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: Dtype::I32, shape, data }
    }

    /// A u32 tensor from values (asserts shape/value-count agreement).
    pub fn from_u32(shape: Vec<usize>, vals: &[u32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: Dtype::U32, shape, data }
    }

    /// Element count (product of the shape; 1 for scalars).
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor holds zero elements (some dimension is 0).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode as f32 values (asserts the dtype).
    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32);
        self.data.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }

    /// Decode as i32 values (asserts the dtype).
    pub fn to_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, Dtype::I32);
        self.data.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }

    /// Decode as u32 values (asserts the dtype).
    pub fn to_u32(&self) -> Vec<u32> {
        assert_eq!(self.dtype, Dtype::U32);
        self.data.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }
}

/// Write tensors (ordered) to `path`.
pub fn write<P: AsRef<Path>>(path: P, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut metas = Vec::new();
    let mut offset = 0usize;
    for (name, t) in tensors {
        metas.push(obj(vec![
            ("name", s(name)),
            ("dtype", s(t.dtype.name())),
            ("shape", arr(t.shape.iter().map(|&d| num(d as f64)).collect())),
            ("offset", num(offset as f64)),
            ("nbytes", num(t.data.len() as f64)),
        ]));
        offset += t.data.len();
    }
    let header = obj(vec![("tensors", arr(metas))]).to_string();
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for (_, t) in tensors {
        f.write_all(&t.data)?;
    }
    Ok(())
}

/// Read all tensors from `path`, preserving file order.
pub fn read<P: AsRef<Path>>(path: P) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{:?}: bad magic {:?}", path.as_ref(), magic);
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?).map_err(anyhow::Error::msg)?;
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;

    let mut out = Vec::new();
    for m in header.arr_field("tensors").map_err(anyhow::Error::msg)? {
        let name = m.str_field("name").map_err(anyhow::Error::msg)?.to_string();
        let dtype = Dtype::from_name(m.str_field("dtype").map_err(anyhow::Error::msg)?)?;
        let shape: Vec<usize> = m
            .arr_field("shape")
            .map_err(anyhow::Error::msg)?
            .iter()
            .map(|j| j.as_usize().unwrap_or(0))
            .collect();
        let off = m.usize_field("offset").map_err(anyhow::Error::msg)?;
        let nbytes = m.usize_field("nbytes").map_err(anyhow::Error::msg)?;
        if off + nbytes > payload.len() {
            bail!("tensor {name} out of bounds");
        }
        out.push((
            name,
            Tensor { dtype, shape, data: payload[off..off + nbytes].to_vec() },
        ));
    }
    Ok(out)
}

/// Read into a name-keyed map.
pub fn read_map<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, Tensor>> {
    Ok(read(path)?.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("ssprop_ts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.tstore");
        let tensors = vec![
            ("w".to_string(), Tensor::from_f32(vec![2, 3], &[1.0, -2.5, 3.0, 0.0, 5.5, -6.0])),
            ("idx".to_string(), Tensor::from_i32(vec![4], &[-1, 0, 1, 2])),
            ("key".to_string(), Tensor::from_u32(vec![2], &[7, 9])),
            ("scalar".to_string(), Tensor::from_f32(vec![], &[42.0])),
        ];
        write(&p, &tensors).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("ssprop_ts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tstore");
        std::fs::write(&p, b"NOTMAGICxxxxxxxxxxx").unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = std::env::temp_dir().join("ssprop_ts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.tstore");
        let tensors = vec![("w".to_string(), Tensor::from_f32(vec![4], &[1.0; 4]))];
        write(&p, &tensors).unwrap();
        let all = std::fs::read(&p).unwrap();
        std::fs::write(&p, &all[..all.len() - 8]).unwrap();
        assert!(read(&p).is_err());
    }

    #[test]
    fn scalar_and_empty_shapes() {
        let t = Tensor::from_f32(vec![], &[3.5]);
        assert_eq!(t.len(), 1);
        let e = Tensor::from_f32(vec![0, 3], &[]);
        assert!(e.is_empty());
    }
}
