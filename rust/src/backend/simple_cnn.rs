//! The paper's Fig. 4 workhorse model as a *thin constructor* over the
//! layer graph: a stack of 3×3 convs (stride-2 stem) with ReLU, global
//! average pool, and a linear classifier, assembled from
//! [`crate::backend::layers`] building blocks.
//!
//! Historically this module carried a hand-rolled model with its own
//! forward/backward; the layer-graph refactor moved every loop into the
//! layers verbatim, so [`simple_cnn`] builds a [`Sequential`] that replays
//! the legacy model **bit-for-bit** — same parameter-init stream, same
//! per-step loss bits, same checkpoint tensor names
//! (`param['conv{l}.w']`, `param['fc.w']`, ...). The bit-identity suite
//! `rust/tests/layer_graph_equivalence.rs` pins this against an embedded
//! copy of the legacy implementation.

use super::layers::{Conv2dLayer, GlobalAvgPool, Layer, Linear, ReLU, Sequential, Shape};
use crate::util::rng::Pcg;

/// Geometry/init knobs for a native SimpleCNN.
#[derive(Debug, Clone, Copy)]
pub struct SimpleCnnCfg {
    /// Input channels (1 for grayscale datasets, 3 for RGB).
    pub in_ch: usize,
    /// Input image side length (images are square).
    pub img: usize,
    /// Number of classifier outputs.
    pub classes: usize,
    /// Number of 3×3 conv layers (≥ 1); the first is stride 2.
    pub depth: usize,
    /// Channels per conv layer.
    pub width: usize,
    /// Parameter-init seed (two models built from equal cfgs are equal).
    pub seed: u64,
}

/// Build and He-initialize a SimpleCNN layer graph from `cfg`
/// (deterministic per seed; bit-identical to the historical model).
pub fn simple_cnn(cfg: SimpleCnnCfg) -> Sequential {
    assert!(cfg.depth >= 1 && cfg.width >= 1 && cfg.classes >= 1);
    // One shared parameter stream, drawn in layer order — the exact stream
    // the legacy constructor used.
    let mut rng = Pcg::new(cfg.seed ^ 0xC44, 29);
    let mut parts: Vec<(String, Box<dyn Layer>)> = Vec::new();
    let mut side = cfg.img;
    for l in 0..cfg.depth {
        let cin = if l == 0 { cfg.in_ch } else { cfg.width };
        let stride = if l == 0 { 2 } else { 1 };
        let conv = Conv2dLayer::init(&mut rng, cin, side, side, cfg.width, 3, stride, 1);
        side = conv.cfg_at(1).hout();
        parts.push((format!("conv{l}"), Box::new(conv)));
        parts.push((String::new(), Box::new(ReLU)));
    }
    parts.push((String::new(), Box::new(GlobalAvgPool::new(cfg.width, side, side))));
    parts.push(("fc".to_string(), Box::new(Linear::init(&mut rng, cfg.width, cfg.classes))));
    let in_shape = Shape::Spatial { c: cfg.in_ch, h: cfg.img, w: cfg.img };
    Sequential::new(format!("simple-cnn-d{}-w{}", cfg.depth, cfg.width), in_shape, parts)
        .expect("simple-cnn geometry is always valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::tensorstore::Tensor;

    fn tiny() -> Sequential {
        simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 })
    }

    fn batch(bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg::new(seed, 1);
        let x = (0..bt * 64).map(|_| rng.normal()).collect();
        let y = (0..bt).map(|i| (i % 3) as i32).collect();
        (x, y)
    }

    #[test]
    fn graph_shape_matches_legacy_model() {
        let m = tiny();
        // conv+relu per depth, then gap + fc
        assert_eq!(m.num_layers(), 2 * 2 + 2);
        assert_eq!(m.conv_count(), 2);
        assert_eq!(m.total_channels(), 8);
        assert_eq!(m.out_features(), 3);
        assert_eq!(m.spec(), "simple-cnn-d2-w4");
        // stride-2 stem halves the 8px input; later convs preserve it
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 2);
        assert_eq!((set.convs[0].hout, set.convs[1].hout), (4, 4));
        assert_eq!(set.convs[0].cin, 1);
        assert_eq!(set.convs[1].cin, 4);
    }

    #[test]
    fn construction_is_deterministic_per_seed() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.flat_params(), b.flat_params());
        let c =
            simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 8 });
        assert_ne!(a.flat_params(), c.flat_params());
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(6, 3);
        let first = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        for _ in 0..20 {
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let last = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
        assert_eq!(first.kept_channels, first.total_channels);
    }

    #[test]
    fn sparse_step_keeps_fewer_channels_and_diverges_from_dense() {
        let be = NativeBackend::new();
        let mut dense = tiny();
        let mut sparse = tiny();
        let (x, y) = batch(4, 9);
        dense.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let stats = sparse.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        // width 4 at D=0.8: keep round(0.8) = 1 channel per layer
        assert_eq!(stats.kept_channels, 2);
        assert_eq!(stats.total_channels, 8);
        assert_ne!(dense.flat_params(), sparse.flat_params());
    }

    #[test]
    fn train_step_builds_cols_once_per_layer() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(4, 13);
        assert_eq!(m.plan_cols_builds(), 0);
        m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert_eq!(m.plan_cols_builds(), 2, "fwd cols reused by bwd");
        m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert_eq!(m.plan_cols_builds(), 4);
    }

    #[test]
    fn state_tensors_keep_the_legacy_names() {
        let mut a = tiny();
        let be = NativeBackend::new();
        let (x, y) = batch(4, 5);
        a.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let saved = a.state_tensors();
        assert_eq!(saved.len(), 2 * 2 + 2);
        let names: Vec<&str> = saved.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "param['conv0.w']",
                "param['conv0.b']",
                "param['conv1.w']",
                "param['conv1.b']",
                "param['fc.w']",
                "param['fc.b']"
            ]
        );

        let mut b = tiny();
        assert_ne!(a.flat_params(), b.flat_params());
        b.load_state_tensors(&saved).unwrap();
        assert_eq!(a.flat_params(), b.flat_params());
        let (la, _) = a.eval_batch(&be, &x, &y);
        let (lb, _) = b.eval_batch(&be, &x, &y);
        assert_eq!(la, lb);
    }

    #[test]
    fn load_rejects_bad_shapes() {
        let mut m = tiny();
        let bad = vec![("param['fc.b']".to_string(), Tensor::from_f32(vec![2], &[0.0, 1.0]))];
        assert!(m.load_state_tensors(&bad).is_err());
        let unknown = vec![("param['nope']".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(m.load_state_tensors(&unknown).is_err());
    }
}
