//! Bench for paper Table 6: ResNet-50 step cost under Dropout, ssProp, and
//! both. Shows ssProp *reduces* backward cost while Dropout adds forward
//! cost (Eq. 8's extra FLOPs), mirroring the table's FLOPs columns.
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench table6_dropout --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::runtime::Engine;
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping table6_dropout: {err}");
                return;
            }
        };
        println!("== Table 6 bench: ResNet-50 step latency — Dropout vs ssProp vs both ==\n");

        for (label, drop_rate, dropout) in [
            ("baseline", 0.0f64, 0.0f64),
            ("dropout_0.4", 0.0, 0.4),
            ("ssprop_0.4", 0.4, 0.0),
            ("both_0.2+0.2", 0.2, 0.2),
            ("both_0.4+0.4", 0.4, 0.4),
        ] {
            let mut cfg = TrainConfig::quick("resnet50_cifar10", 1, 1);
            cfg.dropout_rate = dropout;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            let order = t.loader.epoch_order(0);
            let batch = t.loader.batch(&order, 0);
            let r = bench(
                &format!("resnet50_cifar10/{label}/step"),
                2,
                15,
                Duration::from_secs(8),
                || {
                    t.step(&batch, drop_rate).unwrap();
                },
            );
            report(&r);
            let man = &t.train_graph.manifest;
            println!(
                "  analytic bwd FLOPs/iter at D={drop_rate}: {:.3} B",
                man.bwd_flops(drop_rate) / 1e9
            );
        }
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("skipping table6_dropout: PJRT runtime not compiled (build with --features pjrt)");
}

fn main() {
    run();
}
