"""ResNet family (He et al. 2016) with ssProp convolutions.

Configurations used by the paper:
  * ResNet-18: BasicBlock, (2, 2, 2, 2)
  * ResNet-26: BasicBlock, (2, 3, 5, 2)   — the iso-FLOPs model of Table 7
  * ResNet-50: Bottleneck, (3, 4, 6, 3)

``width_mult`` scales all channel counts (default 0.25 for the CPU-PJRT
testbed; the analytic FLOPs tables are always computed at full width, see
rust/src/flops). Stems adapt to image size: 3x3/s1 for <=32 px (CIFAR-style),
5x5/s2 for 64 px. Optional spatial Dropout (runtime rate; 0 = exact identity)
after each stage implements the paper's "w/ Dropout" rows in Table 6.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm

CONFIGS = {
    "resnet18": ("basic", (2, 2, 2, 2)),
    "resnet26": ("basic", (2, 3, 5, 2)),
    "resnet50": ("bottleneck", (3, 4, 6, 3)),
}
BASE_WIDTHS = (64, 128, 256, 512)
EXPANSION = {"basic": 1, "bottleneck": 4}


class ResNet:
    def __init__(self, *, arch: str, in_ch: int, img: int, classes: int,
                 width_mult: float = 0.25, mode: str = "channel",
                 select: str = "topk", with_dropout: bool = False):
        self.arch, self.in_ch, self.img, self.classes = arch, in_ch, img, classes
        self.mode, self.select, self.with_dropout = mode, select, with_dropout
        self.block, self.layers = CONFIGS[arch]
        self.exp = EXPANSION[self.block]
        self.widths = [max(8, int(w * width_mult)) for w in BASE_WIDTHS]
        self.width_mult = width_mult
        if img <= 32:
            self.stem = dict(k=3, s=1, p=1)
        else:
            self.stem = dict(k=5, s=2, p=2)
        # Build a static plan of every conv: list of dicts with names.
        self.plan = []
        self._build_plan()

    # -- static architecture plan --------------------------------------------
    def _add(self, name, cin, cout, k, s, p, h):
        ho = cm.conv_out(h, k, s, p)
        self.plan.append(dict(name=name, cin=cin, cout=cout, k=k, s=s, p=p,
                              hin=h, hout=ho))
        return ho

    def _build_plan(self):
        c = self.widths[0]
        h = self._add("stem", self.in_ch, c, self.stem["k"], self.stem["s"], self.stem["p"], self.img)
        cin = c
        for si, (w, n) in enumerate(zip(self.widths, self.layers)):
            for bi in range(n):
                s = 2 if (bi == 0 and si > 0) else 1
                pre = f"s{si}b{bi}"
                cout = w * self.exp
                if self.block == "basic":
                    h2 = self._add(f"{pre}.conv1", cin, w, 3, s, 1, h)
                    self._add(f"{pre}.conv2", w, w, 3, 1, 1, h2)
                    if s != 1 or cin != cout:
                        self._add(f"{pre}.down", cin, cout, 1, s, 0, h)
                    h = h2
                    cin = cout
                else:
                    h2 = self._add(f"{pre}.conv1", cin, w, 1, 1, 0, h)
                    h2 = self._add(f"{pre}.conv2", w, w, 3, s, 1, h2)
                    self._add(f"{pre}.conv3", w, cout, 1, 1, 0, h2)
                    if s != 1 or cin != cout:
                        self._add(f"{pre}.down", cin, cout, 1, s, 0, h)
                    h = h2
                    cin = cout
        self.out_ch, self.out_hw = cin, h

    def inventory(self) -> cm.Inventory:
        inv = cm.Inventory()
        for c in self.plan:
            inv.conv(c["cin"], c["cout"], c["k"], c["s"], c["p"], c["hin"], c["hin"])
            inv.bn(c["cout"], c["hout"], c["hout"])
        if self.with_dropout:
            # one spatial dropout after each stage
            h = None
            for si in range(4):
                last = [c for c in self.plan if c["name"].startswith(f"s{si}b")][-1]
                inv.dropout(last["cout"], last["hout"], last["hout"])
        return inv

    # -- params ---------------------------------------------------------------
    def init(self, key):
        params, state = {}, {}
        keys = jax.random.split(key, len(self.plan) + 1)
        for i, c in enumerate(self.plan):
            params[c["name"]] = cm.init_conv(keys[i], c["cin"], c["cout"], c["k"])
            params[c["name"] + ".bn"] = cm.init_bn(c["cout"])
            state[c["name"] + ".bn"] = cm.init_bn_state(c["cout"])
        params["fc"] = cm.init_dense(keys[-1], self.out_ch, self.classes)
        return params, state

    # -- forward ---------------------------------------------------------------
    def _conv_bn(self, params, state, new_state, name, x, drop_rate, key, i, *,
                 train, relu=True):
        c = next(p for p in self.plan if p["name"] == name)
        x = cm.conv(params[name], x, drop_rate, cm.fold_key(key, i),
                    stride=c["s"], padding=c["p"], mode=self.mode, select=self.select)
        x, new_state[name + ".bn"] = cm.batchnorm(params[name + ".bn"], state[name + ".bn"], x, train=train)
        return jax.nn.relu(x) if relu else x

    def apply(self, params, state, x, *, train: bool, drop_rate, dropout_rate, key):
        new_state = {}
        li = 0  # running conv index for key folding
        x = self._conv_bn(params, state, new_state, "stem", x, drop_rate, key, li, train=train)
        li += 1
        cin = self.widths[0]
        for si, (w, n) in enumerate(zip(self.widths, self.layers)):
            for bi in range(n):
                s = 2 if (bi == 0 and si > 0) else 1
                pre = f"s{si}b{bi}"
                cout = w * self.exp
                identity = x
                if self.block == "basic":
                    y = self._conv_bn(params, state, new_state, f"{pre}.conv1", x, drop_rate, key, li, train=train); li += 1
                    y = self._conv_bn(params, state, new_state, f"{pre}.conv2", y, drop_rate, key, li, train=train, relu=False); li += 1
                else:
                    y = self._conv_bn(params, state, new_state, f"{pre}.conv1", x, drop_rate, key, li, train=train); li += 1
                    y = self._conv_bn(params, state, new_state, f"{pre}.conv2", y, drop_rate, key, li, train=train); li += 1
                    y = self._conv_bn(params, state, new_state, f"{pre}.conv3", y, drop_rate, key, li, train=train, relu=False); li += 1
                if s != 1 or cin != cout:
                    identity = self._conv_bn(params, state, new_state, f"{pre}.down", x, drop_rate, key, li, train=train, relu=False); li += 1
                x = jax.nn.relu(y + identity)
                cin = cout
            if self.with_dropout and train:
                # spatial (channel-wise) dropout, runtime rate
                bt, c, h, wd = x.shape
                mask = jax.random.bernoulli(
                    _threefry(cm.fold_key(key, 1000 + si)), 1.0 - dropout_rate, (bt, c, 1, 1)
                ).astype(x.dtype)
                x = jnp.where(dropout_rate > 0,
                              x * mask / jnp.maximum(1.0 - dropout_rate, 1e-6), x)
        x = cm.global_avg_pool(x)
        return cm.dense(params["fc"], x), new_state


def _threefry(key_u32):
    return jax.random.wrap_key_data(key_u32.astype(jnp.uint32), impl="threefry2x32")
