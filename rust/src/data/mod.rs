//! Synthetic dataset substrate (S14) — stands in for the paper's six
//! datasets (Table 1) per DESIGN.md §3's substitution rule.
//!
//! Every dataset is *procedural*: images are generated deterministically
//! from (seed, split, index), so there is nothing to download, epochs can
//! be replayed bit-identically, and the generator doubles as an unbounded
//! augmentation source. Class structure (oriented sinusoid textures +
//! class-conditional channel biases + noise) makes the tasks learnable yet
//! overfittable — the axis Tables 4/6/7 measure.

pub mod loader;
pub mod synth;

pub use loader::{Batch, Loader};
pub use synth::SynthDataset;

/// Loss family, mirroring the manifest's `loss` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loss {
    /// Softmax cross-entropy, integer labels.
    Ce,
    /// Sigmoid binary cross-entropy, multi-hot labels (CelebA's 40 attrs).
    Bce,
}

/// Dataset split identity (each split draws from its own RNG stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Training split (shuffled per epoch).
    Train,
    /// Validation split (fixed order).
    Val,
    /// Test split (fixed order).
    Test,
}

/// Geometry + statistics of one dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Registry name ("mnist", "cifar10", ...).
    pub name: &'static str,
    /// Image channels.
    pub channels: usize,
    /// Image side length (square).
    pub img: usize,
    /// Class count (CE) or attribute count (BCE).
    pub classes: usize,
    /// Loss family the dataset trains under.
    pub loss: Loss,
    /// Paper Table 1 sizes (reported by `ssprop datasets`).
    pub paper_split: (usize, usize, usize),
    /// Scaled train-split size actually generated on this testbed.
    pub train_n: usize,
    /// Scaled validation-split size.
    pub val_n: usize,
    /// Scaled test-split size.
    pub test_n: usize,
}

/// Registry mirroring python/compile/aot.py's DATASETS (geometry of Table 1).
pub fn registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "mnist", channels: 1, img: 28, classes: 10, loss: Loss::Ce,
            paper_split: (48_000, 12_000, 10_000), train_n: 2048, val_n: 512, test_n: 512,
        },
        DatasetSpec {
            name: "fashion", channels: 1, img: 28, classes: 10, loss: Loss::Ce,
            paper_split: (48_000, 12_000, 10_000), train_n: 2048, val_n: 512, test_n: 512,
        },
        DatasetSpec {
            name: "cifar10", channels: 3, img: 32, classes: 10, loss: Loss::Ce,
            paper_split: (40_000, 10_000, 10_000), train_n: 2048, val_n: 512, test_n: 512,
        },
        DatasetSpec {
            name: "cifar100", channels: 3, img: 32, classes: 100, loss: Loss::Ce,
            paper_split: (40_000, 10_000, 10_000), train_n: 4096, val_n: 512, test_n: 512,
        },
        DatasetSpec {
            name: "celeba", channels: 3, img: 64, classes: 40, loss: Loss::Bce,
            paper_split: (162_770, 19_867, 19_962), train_n: 1024, val_n: 256, test_n: 256,
        },
        DatasetSpec {
            name: "imagenet64", channels: 3, img: 64, classes: 100, loss: Loss::Ce,
            paper_split: (1_281_167, 50_000, 100_000), train_n: 4096, val_n: 512, test_n: 512,
        },
    ]
}

/// Look up a dataset by registry name.
pub fn spec(name: &str) -> Option<DatasetSpec> {
    registry().into_iter().find(|d| d.name == name)
}

/// Label for one example.
#[derive(Debug, Clone, PartialEq)]
pub enum Label {
    /// Single class index (CE datasets).
    Class(u32),
    /// Multi-hot attribute bits (BCE datasets).
    Multi(Vec<f32>),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_geometry() {
        let r = registry();
        assert_eq!(r.len(), 6);
        let mnist = spec("mnist").unwrap();
        assert_eq!((mnist.channels, mnist.img, mnist.classes), (1, 28, 10));
        assert_eq!(mnist.paper_split.0 + mnist.paper_split.1 + mnist.paper_split.2, 70_000);
        let celeba = spec("celeba").unwrap();
        assert_eq!(celeba.loss, Loss::Bce);
        assert_eq!(celeba.classes, 40);
        let c100 = spec("cifar100").unwrap();
        assert_eq!(c100.classes, 100);
        assert_eq!(
            c100.paper_split.0 + c100.paper_split.1 + c100.paper_split.2,
            60_000
        );
    }

    #[test]
    fn unknown_dataset_is_none() {
        assert!(spec("svhn").is_none());
    }
}
