"""L2 model zoo: shapes, finiteness, BN state, inventory consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.ssprop as ssprop_mod
from compile.models.ddpm_unet import UNet, make_beta_schedule, time_embedding
from compile.models.resnet import ResNet
from compile.models.simple_cnn import SimpleCNN

KEY0 = jnp.zeros((2,), jnp.uint32)
D0 = jnp.float32(0)


def _apply(model, x, train=True, drop=0.0, dropout=0.0):
    params, state = model.init(jax.random.PRNGKey(0))
    return model.apply(params, state, x, train=train, drop_rate=jnp.float32(drop),
                       dropout_rate=jnp.float32(dropout), key=KEY0)


@pytest.mark.parametrize("depth", [1, 2, 5, 8, 11])
def test_simple_cnn_shapes(depth):
    m = SimpleCNN(depth=depth, in_ch=3, img=32, classes=100)
    x = jnp.zeros((4, 3, 32, 32))
    logits, new_state = _apply(m, x)
    assert logits.shape == (4, 100)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(new_state) == depth


@pytest.mark.parametrize("arch,img,cin", [
    ("resnet18", 32, 3), ("resnet26", 32, 3), ("resnet50", 32, 3),
    ("resnet18", 28, 1), ("resnet50", 64, 3),
])
def test_resnet_shapes(arch, img, cin):
    m = ResNet(arch=arch, in_ch=cin, img=img, classes=10, width_mult=0.125)
    x = jnp.zeros((2, cin, img, img))
    logits, _ = _apply(m, x)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_conv_counts():
    # paper topologies: 18 = 17 convs + fc (incl. 3 downsample 1x1 at 32px stem),
    # verify against the static plan rather than magic numbers:
    for arch, nblocks, per in (("resnet18", (2, 2, 2, 2), 2), ("resnet26", (2, 3, 5, 2), 2)):
        m = ResNet(arch=arch, in_ch=3, img=32, classes=10)
        base = 1 + per * sum(nblocks)          # stem + block convs
        downs = 3                              # stages 1..3 change stride/width
        assert len(m.plan) == base + downs
    m50 = ResNet(arch="resnet50", in_ch=3, img=32, classes=10)
    assert len(m50.plan) == 1 + 3 * 16 + 4     # bottleneck: stage0 also projects


def test_inventory_matches_applied_convs(monkeypatch):
    """Every ssprop_conv call during apply() must appear in the inventory."""
    calls = []
    orig = ssprop_mod.ssprop_conv

    def counting(x, w, b, d, k, spec=ssprop_mod.ConvSpec()):
        calls.append((x.shape, w.shape, spec.stride, spec.padding))
        return orig(x, w, b, d, k, spec)

    for model in (SimpleCNN(depth=4, in_ch=3, img=32, classes=10),
                  ResNet(arch="resnet18", in_ch=3, img=32, classes=10, width_mult=0.25)):
        calls.clear()
        import compile.models.common as cm
        monkeypatch.setattr(cm, "ssprop_conv", counting)
        _apply(model, jnp.zeros((2, 3, 32, 32)))
        inv = model.inventory()
        assert len(calls) == len(inv.convs)
        for (xshape, wshape, s, p), c in zip(calls, inv.convs):
            assert xshape[1] == c["cin"] and wshape[0] == c["cout"]
            assert wshape[2] == c["k"] and s == c["stride"] and p == c["padding"]
            assert xshape[2] == c["hin"]


def test_bn_state_updates_in_train_only():
    m = SimpleCNN(depth=2, in_ch=1, img=28, classes=10)
    params, state = m.init(jax.random.PRNGKey(1))
    x = jnp.array(np.random.default_rng(0).normal(size=(8, 1, 28, 28)), jnp.float32)
    _, st_train = m.apply(params, state, x, train=True, drop_rate=D0,
                          dropout_rate=D0, key=KEY0)
    _, st_eval = m.apply(params, state, x, train=False, drop_rate=D0,
                         dropout_rate=D0, key=KEY0)
    assert not np.allclose(np.asarray(st_train["bn0"]["mean"]), np.asarray(state["bn0"]["mean"]))
    np.testing.assert_array_equal(np.asarray(st_eval["bn0"]["mean"]),
                                  np.asarray(state["bn0"]["mean"]))


def test_resnet_dropout_identity_at_zero_rate():
    m = ResNet(arch="resnet50", in_ch=3, img=32, classes=10, width_mult=0.125,
               with_dropout=True)
    params, state = m.init(jax.random.PRNGKey(2))
    x = jnp.array(np.random.default_rng(1).normal(size=(2, 3, 32, 32)), jnp.float32)
    y0, _ = m.apply(params, state, x, train=True, drop_rate=D0,
                    dropout_rate=jnp.float32(0), key=KEY0)
    y1, _ = m.apply(params, state, x, train=True, drop_rate=D0,
                    dropout_rate=jnp.float32(0), key=jnp.asarray([5, 6], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    y2, _ = m.apply(params, state, x, train=True, drop_rate=D0,
                    dropout_rate=jnp.float32(0.5), key=KEY0)
    assert not np.allclose(np.asarray(y0), np.asarray(y2))


# -- DDPM --------------------------------------------------------------------

def test_unet_shapes_and_finiteness():
    for cin, img in ((1, 28), (3, 64)):
        u = UNet(in_ch=cin, img=img, base=8)
        params, _ = u.init(jax.random.PRNGKey(0))
        x = jnp.array(np.random.default_rng(0).normal(size=(2, cin, img, img)), jnp.float32)
        t = jnp.array([0, 5], jnp.int32)
        eps = u.apply(params, x, t, drop_rate=D0, key=KEY0)
        assert eps.shape == x.shape
        assert np.isfinite(np.asarray(eps)).all()


def test_time_embedding_distinct_and_bounded():
    t = jnp.arange(10, dtype=jnp.int32)
    e = np.asarray(time_embedding(t, 32))
    assert e.shape == (10, 32)
    assert np.abs(e).max() <= 1.0 + 1e-6
    assert np.linalg.matrix_rank(e) > 1


def test_beta_schedule_monotone():
    s = make_beta_schedule(100)
    betas, abar = np.asarray(s["betas"]), np.asarray(s["alpha_bar"])
    assert (np.diff(betas) > 0).all()
    assert (np.diff(abar) < 0).all()
    assert 0 < abar[-1] < abar[0] < 1


def test_unet_inventory_matches_convs(monkeypatch):
    calls = []
    orig = ssprop_mod.ssprop_conv

    def counting(x, w, b, d, k, spec=ssprop_mod.ConvSpec()):
        calls.append(x.shape)
        return orig(x, w, b, d, k, spec)

    import compile.models.common as cm
    monkeypatch.setattr(cm, "ssprop_conv", counting)
    u = UNet(in_ch=1, img=28, base=8)
    params, _ = u.init(jax.random.PRNGKey(0))
    u.apply(params, jnp.zeros((2, 1, 28, 28)), jnp.zeros((2,), jnp.int32),
            drop_rate=D0, key=KEY0)
    assert len(calls) == len(u.inventory().convs)
