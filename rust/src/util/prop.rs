//! Mini property-testing framework (proptest is not in the offline vendor
//! set; S13). Seeded generators + a check loop with failure shrinking for
//! integer/float tuples. Used for coordinator invariants (schedulers,
//! selection, batching, FLOPs monotonicity).

use super::rng::Pcg;

/// Default number of random cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` on `cases` random inputs drawn by `gen`. On failure, attempt
/// to shrink the input with `shrink` (halving-style candidates) and panic
/// with the smallest failing case found.
pub fn check<T, G, P, S>(name: &str, cases: usize, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Pcg::new(0x5550_5250, name.len() as u64);
    for case in 0..cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink loop
        let mut smallest = input.clone();
        let mut improved = true;
        while improved {
            improved = false;
            for cand in shrink(&smallest) {
                if !prop(&cand) {
                    smallest = cand;
                    improved = true;
                    break;
                }
            }
        }
        panic!(
            "property {name:?} failed at case {case}:\n  original: {input:?}\n  shrunk:   {smallest:?}"
        );
    }
}

/// Convenience: no shrinking.
pub fn check_no_shrink<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> bool,
{
    check(name, cases, gen, prop, |_| Vec::new());
}

/// Shrinker for a single usize: 0, n/2, n-1.
pub fn shrink_usize(n: usize) -> Vec<usize> {
    let mut v = Vec::new();
    if n > 0 {
        v.push(0);
        v.push(n / 2);
        v.push(n - 1);
    }
    v.dedup();
    v
}

/// Shrinker for an f64 in [0,1]: 0, x/2.
pub fn shrink_unit_f64(x: f64) -> Vec<f64> {
    if x > 1e-9 {
        vec![0.0, x / 2.0]
    } else {
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_no_shrink("add-commutes", 128, |r| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn failing_property_shrinks() {
        check(
            "all-below-50",
            512,
            |r| r.below(100) as usize,
            |&n| n < 50,
            |&n| shrink_usize(n),
        );
    }

    #[test]
    fn shrinkers_propose_smaller() {
        assert!(shrink_usize(10).iter().all(|&c| c < 10));
        assert!(shrink_unit_f64(0.8).iter().all(|&c| c < 0.8));
        assert!(shrink_usize(0).is_empty());
    }
}
