"""Pallas img2col / col2img kernels (paper Fig. 1b).

im2col: grid over (batch, output-row). Each program reads the K input rows
that contribute to one output row (a (Cin, K, Wp) slab — the natural
HBM->VMEM streaming unit on TPU, expressed with a BlockSpec over the padded
input) and emits the W_out patch rows of col_X.

col2img: the reverse scatter-add. Programs iterate output rows per batch
element sequentially on the grid's minor axis so overlapping windows
accumulate without atomics — the same trick Mosaic uses for revisiting
output tiles (the out BlockSpec maps every (b, i) to the same batch block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import out_size


def _im2col_kernel(x_ref, o_ref, *, k: int, stride: int, wo: int):
    # x_ref: (1, Cin, Hp, Wp) — full padded image of batch b
    # o_ref: (1, 1, wo, Cin*K*K) — patch rows of output row i
    i = pl.program_id(1)
    cin = x_ref.shape[1]
    slab = x_ref[0, :, pl.ds(i * stride, k), :]  # (Cin, K, Wp)
    rows = []
    for j in range(wo):
        win = slab[:, :, j * stride : j * stride + k]  # (Cin, K, K)
        rows.append(win.reshape(cin * k * k))
    o_ref[0, 0] = jnp.stack(rows)


@functools.partial(jax.jit, static_argnames=("k", "stride", "padding", "interpret"))
def im2col(x, *, k: int, stride: int = 1, padding: int = 0, interpret: bool = True):
    """(Bt,Cin,H,W) -> (Bt*Hout*Wout, Cin*K*K), matching ref.im2col_ref."""
    bt, cin, h, w = x.shape
    ho = out_size(h, k, stride, padding)
    wo = out_size(w, k, stride, padding)
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    wp = w + 2 * padding
    out = pl.pallas_call(
        functools.partial(_im2col_kernel, k=k, stride=stride, wo=wo),
        grid=(bt, ho),
        in_specs=[
            # whole padded image per batch element; the kernel slices the
            # (Cin, K, Wp) slab for row i with pl.ds (overlapping slabs cannot
            # be expressed in block-unit BlockSpec index maps).
            pl.BlockSpec((1, cin, h + 2 * padding, wp), lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, wo, cin * k * k), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, ho, wo, cin * k * k), x.dtype),
        interpret=interpret,
    )(xp)
    return out.reshape(bt * ho * wo, cin * k * k)


def _col2img_kernel(c_ref, o_ref, *, k: int, stride: int, ho: int, wo: int, cin: int):
    # c_ref: (1, 1, wo, Cin*K*K) — patch rows of output row i
    # o_ref: (1, Cin, Hp, Wp)    — full padded image of batch b (revisited per i)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = c_ref[0, 0]  # (wo, Cin*K*K)
    for j in range(wo):
        win = rows[j].reshape(cin, k, k)
        cur = o_ref[0, :, pl.ds(i * stride, k), pl.ds(j * stride, k)]
        o_ref[0, :, pl.ds(i * stride, k), pl.ds(j * stride, k)] = cur + win


@functools.partial(jax.jit, static_argnames=("x_shape", "k", "stride", "padding", "interpret"))
def col2img(cols, *, x_shape, k: int, stride: int = 1, padding: int = 0, interpret: bool = True):
    """(Bt*Hout*Wout, Cin*K*K) -> x_shape scatter-add, matching col2img_ref."""
    bt, cin, h, w = x_shape
    ho = out_size(h, k, stride, padding)
    wo = out_size(w, k, stride, padding)
    hp, wp = h + 2 * padding, w + 2 * padding
    c4 = cols.reshape(bt, ho, wo, cin * k * k)
    # NOTE: i*stride slabs overlap for k > stride, so the output block must be
    # the whole padded image; the (b, i) grid revisits it row-sequentially.
    xp = pl.pallas_call(
        functools.partial(_col2img_kernel, k=k, stride=stride, ho=ho, wo=wo, cin=cin),
        grid=(bt, ho),
        in_specs=[pl.BlockSpec((1, 1, wo, cin * k * k), lambda b, i: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((1, cin, hp, wp), lambda b, i: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, cin, hp, wp), cols.dtype),
        interpret=interpret,
    )(c4)
    if padding:
        xp = xp[:, :, padding:-padding, padding:-padding]
    return xp
