//! Model zoo: the `--model` spec language and its presets. A spec is a
//! preset name plus optional dash-separated parameters —
//! `simple-cnn-d4-w16`, `vgg-tiny-w12`, `dropout-cnn-w8-p25`,
//! `resnet-tiny-w8-b2` — parsed into a typed [`ModelSpec`] (malformed
//! specs produce the typed [`ModelSpecError`], not a stringly error) and
//! built into a layer [`Graph`] for any dataset geometry.
//!
//! Presets:
//!
//! | spec | stack | exercises |
//! |---|---|---|
//! | `simple-cnn[-dD-wW]` | D× (3×3 conv + ReLU), stride-2 stem; GAP; fc | the paper's Fig. 4 model (legacy-bitwise) |
//! | `vgg-tiny[-wW]` | 2× (conv W + ReLU), maxpool; conv 2W + ReLU, maxpool; GAP; fc | MaxPool in the backward path |
//! | `dropout-cnn[-wW-pP]` | stride-2 conv W, ReLU, Dropout P%; conv W, ReLU, Dropout P%; GAP; fc | the paper's ssProp+Dropout compatibility claim |
//! | `resnet-tiny[-wW-bB]` | CIFAR-stem conv W + BN + ReLU; 4 stages of B basic blocks (conv–BN–ReLU–conv–BN + identity/1×1-proj skip) at widths W,2W,4W,8W; GAP; fc | residual graphs + BatchNorm — the paper's ResNet family, stage geometry mirroring [`crate::flops::resnet_config`] |

use std::fmt;

use anyhow::Result;

use super::im2col::out_size;
use super::layers::{
    BatchNorm2d, Conv2dLayer, Dropout, GlobalAvgPool, Graph, Layer, Linear, MaxPool2d, ReLU,
    Sequential, Shape, INPUT_SLOT,
};
use super::simple_cnn::{simple_cnn, SimpleCnnCfg};
use crate::util::rng::Pcg;

/// A parsed `--model` spec: preset plus its resolved parameters.
/// `simple-cnn` leaves depth/width `None` until
/// [`ModelSpec::with_defaults`] fills them (the trainer supplies its
/// `--depth`/`--width` knobs), so `--model simple-cnn --depth 4` composes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelSpec {
    /// The paper's Fig. 4 stack (see [`simple_cnn`]).
    SimpleCnn {
        /// Conv layers (None = trainer default).
        depth: Option<usize>,
        /// Channels per conv layer (None = trainer default).
        width: Option<usize>,
    },
    /// A tiny VGG-style stack with two max pools.
    VggTiny {
        /// Base channel count (the last conv block doubles it).
        width: usize,
    },
    /// SimpleCNN-like stack with Dropout after each ReLU.
    DropoutCnn {
        /// Channels per conv layer.
        width: usize,
        /// Drop probability in percent (1..=99).
        rate_pct: usize,
    },
    /// A scaled-down residual network (basic blocks + BatchNorm) whose
    /// per-stage geometry mirrors [`crate::flops::resnet_config`]:
    /// CIFAR-style 3×3/s1 stem, stage widths W, 2W, 4W, 8W, first block
    /// of stages 2–4 at stride 2 with a 1×1 projection shortcut.
    /// `resnet-tiny-w8-b2` is ResNet-18 at 1/8 width.
    ResnetTiny {
        /// Stage-1 channel count (later stages double it).
        width: usize,
        /// Basic blocks per stage.
        blocks: usize,
    },
}

/// Typed parse error for `--model` specs — the CLI error path matches on
/// these variants instead of scraping strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelSpecError {
    /// The spec names no known preset.
    UnknownPreset {
        /// The offending spec string.
        spec: String,
    },
    /// A parameter token is malformed (unknown key, missing digits, or a
    /// key the preset does not take).
    BadParam {
        /// The offending spec string.
        spec: String,
        /// The token that failed to parse.
        token: String,
    },
    /// A parameter parsed but its value is out of range (zero dimensions,
    /// dropout percentage outside 1..=99).
    OutOfRange {
        /// The offending spec string.
        spec: String,
        /// The token whose value is out of range.
        token: String,
    },
}

impl fmt::Display for ModelSpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelSpecError::UnknownPreset { spec } => {
                write!(f, "unknown model preset {spec:?} (known: {})", PRESETS.join(", "))
            }
            ModelSpecError::BadParam { spec, token } => {
                write!(f, "bad parameter {token:?} in model spec {spec:?}")
            }
            ModelSpecError::OutOfRange { spec, token } => {
                write!(f, "parameter {token:?} out of range in model spec {spec:?}")
            }
        }
    }
}

impl std::error::Error for ModelSpecError {}

/// Preset names the spec parser recognizes (longest-match first).
pub const PRESETS: &[&str] = &["simple-cnn", "vgg-tiny", "dropout-cnn", "resnet-tiny"];

/// Parse a `--model` spec string into a typed [`ModelSpec`].
pub fn parse_model_spec(spec: &str) -> Result<ModelSpec, ModelSpecError> {
    let (preset, rest) = PRESETS
        .iter()
        .find_map(|p| spec.strip_prefix(p).map(|rest| (*p, rest)))
        .ok_or_else(|| ModelSpecError::UnknownPreset { spec: spec.to_string() })?;
    let tokens: Vec<&str> = if rest.is_empty() {
        Vec::new()
    } else {
        match rest.strip_prefix('-') {
            // "simple-cnnx" must not parse as simple-cnn + garbage
            None => return Err(ModelSpecError::UnknownPreset { spec: spec.to_string() }),
            Some(tail) => tail.split('-').collect(),
        }
    };

    let (mut depth, mut width, mut rate_pct, mut blocks) = (None, None, None, None);
    for token in tokens {
        let bad = || ModelSpecError::BadParam { spec: spec.to_string(), token: token.to_string() };
        let (key, digits) = token.split_at(1.min(token.len()));
        let value: usize = digits.parse().map_err(|_| bad())?;
        let slot = match key {
            "d" if preset == "simple-cnn" => &mut depth,
            "w" => &mut width,
            "p" if preset == "dropout-cnn" => &mut rate_pct,
            "b" if preset == "resnet-tiny" => &mut blocks,
            _ => return Err(bad()),
        };
        if slot.is_some() {
            return Err(bad());
        }
        if value == 0 {
            return Err(ModelSpecError::OutOfRange {
                spec: spec.to_string(),
                token: token.to_string(),
            });
        }
        *slot = Some(value);
    }

    match preset {
        "simple-cnn" => Ok(ModelSpec::SimpleCnn { depth, width }),
        "vgg-tiny" => Ok(ModelSpec::VggTiny { width: width.unwrap_or(8) }),
        "dropout-cnn" => {
            let rate_pct = rate_pct.unwrap_or(25);
            if rate_pct >= 100 {
                return Err(ModelSpecError::OutOfRange {
                    spec: spec.to_string(),
                    token: format!("p{rate_pct}"),
                });
            }
            Ok(ModelSpec::DropoutCnn { width: width.unwrap_or(8), rate_pct })
        }
        "resnet-tiny" => {
            Ok(ModelSpec::ResnetTiny { width: width.unwrap_or(8), blocks: blocks.unwrap_or(1) })
        }
        other => unreachable!("preset {other:?} is listed in PRESETS but not parsed"),
    }
}

impl ModelSpec {
    /// Fill `simple-cnn`'s unset depth/width from the trainer's knobs
    /// (no-op for fully-specified specs and other presets).
    pub fn with_defaults(self, depth: usize, width: usize) -> ModelSpec {
        match self {
            ModelSpec::SimpleCnn { depth: d, width: w } => ModelSpec::SimpleCnn {
                depth: Some(d.unwrap_or(depth)),
                width: Some(w.unwrap_or(width)),
            },
            other => other,
        }
    }

    /// The fully-resolved spec string (parse → resolve → canonical is
    /// idempotent); checkpoint sidecars record this.
    pub fn canonical(&self) -> String {
        match *self {
            ModelSpec::SimpleCnn { depth, width } => {
                format!("simple-cnn-d{}-w{}", depth.unwrap_or(2), width.unwrap_or(8))
            }
            ModelSpec::VggTiny { width } => format!("vgg-tiny-w{width}"),
            ModelSpec::DropoutCnn { width, rate_pct } => {
                format!("dropout-cnn-w{width}-p{rate_pct}")
            }
            ModelSpec::ResnetTiny { width, blocks } => format!("resnet-tiny-w{width}-b{blocks}"),
        }
    }
}

/// Build a [`Sequential`] for `spec` over a `(in_ch, img, img)` input with
/// `classes` logits. Fails when the preset's pools cannot fit the image.
pub fn build_model(
    spec: &ModelSpec,
    in_ch: usize,
    img: usize,
    classes: usize,
    seed: u64,
) -> Result<Sequential> {
    match *spec {
        ModelSpec::SimpleCnn { depth, width } => Ok(simple_cnn(SimpleCnnCfg {
            in_ch,
            img,
            classes,
            depth: depth.unwrap_or(2),
            width: width.unwrap_or(8),
            seed,
        })),
        ModelSpec::VggTiny { width } => build_vgg_tiny(spec, in_ch, img, classes, seed, width),
        ModelSpec::DropoutCnn { width, rate_pct } => {
            build_dropout_cnn(spec, in_ch, img, classes, seed, width, rate_pct)
        }
        ModelSpec::ResnetTiny { width, blocks } => {
            build_resnet_tiny(spec, in_ch, img, classes, seed, width, blocks)
        }
    }
}

/// conv W + ReLU ×2, maxpool2; conv 2W + ReLU, maxpool2; GAP; fc.
fn build_vgg_tiny(
    spec: &ModelSpec,
    in_ch: usize,
    img: usize,
    classes: usize,
    seed: u64,
    width: usize,
) -> Result<Sequential> {
    if img < 4 {
        anyhow::bail!("vgg-tiny needs at least a 4x4 input (got {img}x{img})");
    }
    let mut rng = Pcg::new(seed ^ 0xC44, 29);
    let mut parts: Vec<(String, Box<dyn Layer>)> = Vec::new();
    let mut side = img;
    let conv0 = Conv2dLayer::init(&mut rng, in_ch, side, side, width, 3, 1, 1);
    parts.push(("conv0".to_string(), Box::new(conv0)));
    parts.push((String::new(), Box::new(ReLU)));
    let conv1 = Conv2dLayer::init(&mut rng, width, side, side, width, 3, 1, 1);
    parts.push(("conv1".to_string(), Box::new(conv1)));
    parts.push((String::new(), Box::new(ReLU)));
    parts.push((String::new(), Box::new(MaxPool2d::new(width, side, side, 2, 2))));
    side = out_size(side, 2, 2, 0);
    let conv2 = Conv2dLayer::init(&mut rng, width, side, side, 2 * width, 3, 1, 1);
    parts.push(("conv2".to_string(), Box::new(conv2)));
    parts.push((String::new(), Box::new(ReLU)));
    parts.push((String::new(), Box::new(MaxPool2d::new(2 * width, side, side, 2, 2))));
    side = out_size(side, 2, 2, 0);
    parts.push((String::new(), Box::new(GlobalAvgPool::new(2 * width, side, side))));
    parts.push(("fc".to_string(), Box::new(Linear::init(&mut rng, 2 * width, classes))));
    Sequential::new(spec.canonical(), Shape::Spatial { c: in_ch, h: img, w: img }, parts)
}

/// stride-2 conv W, ReLU, Dropout; conv W, ReLU, Dropout; GAP; fc.
fn build_dropout_cnn(
    spec: &ModelSpec,
    in_ch: usize,
    img: usize,
    classes: usize,
    seed: u64,
    width: usize,
    rate_pct: usize,
) -> Result<Sequential> {
    let rate = rate_pct as f64 / 100.0;
    let mut rng = Pcg::new(seed ^ 0xC44, 29);
    let mut parts: Vec<(String, Box<dyn Layer>)> = Vec::new();
    let conv0 = Conv2dLayer::init(&mut rng, in_ch, img, img, width, 3, 2, 1);
    let side = conv0.cfg_at(1).hout();
    let shape = Shape::Spatial { c: width, h: side, w: side };
    parts.push(("conv0".to_string(), Box::new(conv0)));
    parts.push((String::new(), Box::new(ReLU)));
    parts.push((String::new(), Box::new(Dropout::new(rate, shape, seed ^ 0xD0_0))));
    let conv1 = Conv2dLayer::init(&mut rng, width, side, side, width, 3, 1, 1);
    parts.push(("conv1".to_string(), Box::new(conv1)));
    parts.push((String::new(), Box::new(ReLU)));
    parts.push((String::new(), Box::new(Dropout::new(rate, shape, seed ^ 0xD0_1))));
    parts.push((String::new(), Box::new(GlobalAvgPool::new(width, side, side))));
    parts.push(("fc".to_string(), Box::new(Linear::init(&mut rng, width, classes))));
    Sequential::new(spec.canonical(), Shape::Spatial { c: in_ch, h: img, w: img }, parts)
}

/// CIFAR-stem residual network of basic blocks — the paper's ResNet
/// family scaled down. Stage geometry mirrors [`crate::flops::resnet_config`]:
/// stem 3×3/s1/p1 into width W, four stages of `blocks` basic blocks at
/// widths W, 2W, 4W, 8W, the first block of stages 2–4 at stride 2 with a
/// 1×1/s2 projection shortcut (every other skip is the identity). Each
/// block is conv–BN–ReLU–conv–BN, merged with its shortcut by an `Add`
/// node and closed with a ReLU; the projection carries no BatchNorm, so
/// the native ledger matches [`crate::flops::paper_resnet`]'s accounting
/// (BN counted on main-path convs only).
fn build_resnet_tiny(
    spec: &ModelSpec,
    in_ch: usize,
    img: usize,
    classes: usize,
    seed: u64,
    width: usize,
    blocks: usize,
) -> Result<Sequential> {
    let mut rng = Pcg::new(seed ^ 0xC44, 29);
    let mut b = Graph::builder(spec.canonical(), Shape::Spatial { c: in_ch, h: img, w: img });
    // Stem: conv W + BN + ReLU (BN counted on the stem conv, as in the
    // paper's tables).
    let stem = Conv2dLayer::init(&mut rng, in_ch, img, img, width, 3, 1, 1);
    let mut side = stem.cfg_at(1).hout();
    let mut cur = b.layer("stem.conv", INPUT_SLOT, Box::new(stem))?;
    cur = b.layer("stem.bn", cur, Box::new(BatchNorm2d::new(width, side, side)))?;
    cur = b.layer("", cur, Box::new(ReLU))?;
    let mut cin = width;
    for si in 0..4usize {
        let wout = width << si;
        for bi in 0..blocks {
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            let name = format!("s{si}b{bi}");
            let (block_in, in_side) = (cur, side);
            // Main path: conv–BN–ReLU–conv–BN.
            let conv1 = Conv2dLayer::init(&mut rng, cin, in_side, in_side, wout, 3, stride, 1);
            let out_side = conv1.cfg_at(1).hout();
            cur = b.layer(format!("{name}.conv1"), cur, Box::new(conv1))?;
            cur = b.layer(
                format!("{name}.bn1"),
                cur,
                Box::new(BatchNorm2d::new(wout, out_side, out_side)),
            )?;
            cur = b.layer("", cur, Box::new(ReLU))?;
            let conv2 = Conv2dLayer::init(&mut rng, wout, out_side, out_side, wout, 3, 1, 1);
            cur = b.layer(format!("{name}.conv2"), cur, Box::new(conv2))?;
            cur = b.layer(
                format!("{name}.bn2"),
                cur,
                Box::new(BatchNorm2d::new(wout, out_side, out_side)),
            )?;
            // Shortcut: identity where the geometry allows, else a 1×1
            // projection (ssProp-selectable like every conv).
            let shortcut = if stride != 1 || cin != wout {
                let proj = Conv2dLayer::init(&mut rng, cin, in_side, in_side, wout, 1, stride, 0);
                b.layer(format!("{name}.proj"), block_in, Box::new(proj))?
            } else {
                block_in
            };
            cur = b.add(cur, shortcut)?;
            cur = b.layer("", cur, Box::new(ReLU))?;
            cin = wout;
            side = out_side;
        }
    }
    cur = b.layer("", cur, Box::new(GlobalAvgPool::new(cin, side, side)))?;
    b.layer("fc", cur, Box::new(Linear::init(&mut rng, cin, classes)))?;
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::flops::keep_channels;

    #[test]
    fn parse_presets_and_params() {
        assert_eq!(
            parse_model_spec("simple-cnn").unwrap(),
            ModelSpec::SimpleCnn { depth: None, width: None }
        );
        assert_eq!(
            parse_model_spec("simple-cnn-d4-w16").unwrap(),
            ModelSpec::SimpleCnn { depth: Some(4), width: Some(16) }
        );
        assert_eq!(parse_model_spec("vgg-tiny").unwrap(), ModelSpec::VggTiny { width: 8 });
        assert_eq!(parse_model_spec("vgg-tiny-w12").unwrap(), ModelSpec::VggTiny { width: 12 });
        assert_eq!(
            parse_model_spec("dropout-cnn-w6-p40").unwrap(),
            ModelSpec::DropoutCnn { width: 6, rate_pct: 40 }
        );
        assert_eq!(
            parse_model_spec("resnet-tiny").unwrap(),
            ModelSpec::ResnetTiny { width: 8, blocks: 1 }
        );
        assert_eq!(
            parse_model_spec("resnet-tiny-w4-b2").unwrap(),
            ModelSpec::ResnetTiny { width: 4, blocks: 2 }
        );
    }

    #[test]
    fn parse_errors_are_typed() {
        use ModelSpecError::{BadParam, OutOfRange, UnknownPreset};
        let err = |s: &str| parse_model_spec(s).unwrap_err();
        assert!(matches!(err("resnet18"), UnknownPreset { .. }));
        assert!(matches!(err("simple-cnnx"), UnknownPreset { .. }));
        // unknown key, missing digits, key not valid for the preset
        assert!(matches!(err("simple-cnn-q4"), BadParam { .. }));
        assert!(matches!(err("vgg-tiny-w"), BadParam { .. }));
        assert!(matches!(err("vgg-tiny-d4"), BadParam { .. }));
        assert!(matches!(err("simple-cnn-p25"), BadParam { .. }));
        // zero / repeated / oversized values
        assert!(matches!(err("simple-cnn-d0"), OutOfRange { .. }));
        assert!(matches!(err("simple-cnn-w4-w8"), BadParam { .. }));
        assert!(matches!(err("dropout-cnn-p100"), OutOfRange { .. }));
        // resnet-tiny grammar: b is its key alone; zero blocks/width reject
        assert!(matches!(err("vgg-tiny-b2"), BadParam { .. }));
        assert!(matches!(err("resnet-tiny-p25"), BadParam { .. }));
        assert!(matches!(err("resnet-tiny-b0"), OutOfRange { .. }));
        assert!(matches!(err("resnet-tiny-w0"), OutOfRange { .. }));
        // the error displays the offending spec
        let shown = err("nope");
        assert!(shown.to_string().contains("nope"), "{shown}");
    }

    #[test]
    fn canonical_roundtrips_through_parse() {
        for spec in ["simple-cnn-d3-w6", "vgg-tiny-w8", "dropout-cnn-w8-p25", "resnet-tiny-w4-b2"]
        {
            let parsed = parse_model_spec(spec).unwrap();
            assert_eq!(parsed.canonical(), spec);
            assert_eq!(parse_model_spec(&parsed.canonical()).unwrap(), parsed);
        }
        let resolved = parse_model_spec("simple-cnn").unwrap().with_defaults(4, 16);
        assert_eq!(resolved.canonical(), "simple-cnn-d4-w16");
        // explicit spec parameters beat the trainer defaults
        let explicit = parse_model_spec("simple-cnn-d3-w6").unwrap().with_defaults(4, 16);
        assert_eq!(explicit.canonical(), "simple-cnn-d3-w6");
    }

    #[test]
    fn vgg_tiny_builds_and_trains_sparse() {
        let be = NativeBackend::new();
        let spec = parse_model_spec("vgg-tiny-w4").unwrap();
        let mut m = build_model(&spec, 1, 8, 3, 5).unwrap();
        assert_eq!(m.conv_count(), 3);
        assert_eq!(m.total_channels(), 4 + 4 + 8);
        let mut rng = Pcg::new(2, 2);
        let x: Vec<f32> = (0..6 * 64).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let stats = m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert!(stats.loss.is_finite());
        let want: usize = [4, 4, 8].iter().map(|&c| keep_channels(c, 0.8)).sum();
        assert_eq!(stats.kept_channels, want, "sparse backward engaged through the pools");
        // too-small images are a clean error, not a panic
        assert!(build_model(&spec, 1, 3, 3, 5).is_err());
    }

    #[test]
    fn dropout_cnn_builds_with_flops_entries() {
        let spec = parse_model_spec("dropout-cnn-w4-p50").unwrap();
        let m = build_model(&spec, 1, 8, 3, 5).unwrap();
        assert_eq!(m.conv_count(), 2);
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 2);
        assert_eq!(set.dropouts.len(), 2, "Eq. 8 entries for both dropout layers");
        assert_eq!(set.dropouts[0], (4, 4, 4));
    }

    #[test]
    fn resnet_tiny_builds_trains_and_accounts_bn() {
        let be = NativeBackend::new();
        let spec = parse_model_spec("resnet-tiny-w4").unwrap();
        let mut m = build_model(&spec, 1, 8, 3, 5).unwrap();
        // stem + stage0 (2 convs) + stages 1-3 (2 convs + 1x1 proj each)
        assert_eq!(m.conv_count(), 1 + 2 + 3 * 3);
        assert!(m.describe().contains("add"), "{}", m.describe());
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 12);
        let counted = set.convs.iter().filter(|c| c.counted_bn).count();
        let proj = set.convs.iter().filter(|c| c.k == 1).count();
        assert_eq!(proj, 3, "one projection per strided stage");
        assert_eq!(counted, 9, "BN on main-path convs only, projections uncounted");

        let mut rng = Pcg::new(2, 2);
        let x: Vec<f32> = (0..6 * 64).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let stats = m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert!(stats.loss.is_finite());
        let want: usize = set.convs.iter().map(|c| keep_channels(c.cout, 0.8)).sum();
        assert_eq!(stats.kept_channels, want, "sparse backward engages every conv incl. proj");
        // BN running stats moved off their init during the training step
        let saved = m.state_tensors();
        let rm = saved.iter().find(|(n, _)| n == "param['s1b0.bn1.rm']").expect("bn rm leaf");
        assert!(rm.1.to_f32().iter().any(|&v| v != 0.0), "running mean must update");
    }

    #[test]
    fn simple_cnn_spec_builds_the_legacy_graph() {
        let spec = parse_model_spec("simple-cnn-d2-w4").unwrap();
        let via_zoo = build_model(&spec, 1, 8, 3, 7).unwrap();
        let direct =
            simple_cnn(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 });
        assert_eq!(via_zoo.flat_params(), direct.flat_params());
        assert_eq!(via_zoo.spec(), direct.spec());
    }
}
