"""ssProp convolution — scheduled sparse back-propagation (paper's core).

Two interchangeable implementations, mirroring the paper's own pair
("img2col version" and "PyTorch built-in backward version"):

* :func:`ssprop_conv` — **masked, runtime drop rate.** Forward is the dense
  XLA conv; backward computes the channel importance, builds an exact-k mask
  from the *runtime scalar* ``drop_rate`` and zeroes dropped channels before
  the (dense) dW/dX/dB computation. Numerically identical to physically
  discarding channels; one AOT executable serves every drop rate, selection
  mode, and scheduler — this is what the L3 coordinator drives for all
  accuracy experiments.

* :func:`ssprop_conv_pallas` — **compacted, static drop rate.** The true
  img2col path built from the L1 Pallas kernels: importance reduction,
  static top-k channel compaction, and the *shrunk* matmuls
  ``dW' = col_Xᵀ @ col[dY]'`` and ``col[dX] = col[dY]' @ col_W'ᵀ`` that
  realize the FLOPs saving in the executed graph.

Selection semantics shared by both: k = clamp(round((1-D)·C_out), 1, C_out),
exact-k by stable rank (ties deterministic). drop_rate == 0 reproduces dense
training bit-for-bit, which the integration tests assert.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.im2col import col2img, im2col
from .kernels.importance import channel_importance
from .kernels.matmul import matmul


class ConvSpec(NamedTuple):
    """Static configuration of one ssProp convolution."""

    stride: int = 1
    padding: int = 0
    mode: str = "channel"  # 'channel' | 'hw' | 'all'  (Fig. 2a)
    select: str = "topk"   # 'topk' | 'random'         (Fig. 2b)


# ---------------------------------------------------------------------------
# masked path (runtime drop rate) — used by every AOT train step
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssprop_conv(x, w, b, drop_rate, key, spec: ConvSpec = ConvSpec()):
    """Dense forward; sparse backward controlled by runtime ``drop_rate``.

    Args:
      x: (Bt, Cin, H, W) input.
      w: (Cout, Cin, K, K) filters.  b: (Cout,) bias.
      drop_rate: f32 scalar in [0, 1) — fraction of gradient channels dropped.
      key: (2,) uint32 — only consumed when spec.select == 'random'.
      spec: static conv/selection configuration.
    """
    return ref.conv_fwd_ref(x, w, b, stride=spec.stride, padding=spec.padding)


def _selection_size(g_shape, mode: str) -> int:
    _, c, h, w = g_shape
    return {"channel": c, "hw": h * w, "all": c * h * w}[mode]


def _make_mask(g, drop_rate, key, spec: ConvSpec):
    n = _selection_size(g.shape, spec.mode)
    keep_k = ref.keep_k_from_drop_rate(drop_rate, n)
    if spec.select == "topk":
        imp = ref.importance_ref(g, spec.mode)
        mask = ref.topk_mask_ref(imp, keep_k)
    elif spec.select == "random":
        mask = ref.random_mask_ref(_key_from_u32(key), n, keep_k, g.dtype)
    else:
        raise ValueError(f"unknown select {spec.select!r}")
    return ref.mask_grad_ref(g, mask.astype(g.dtype), spec.mode)


def _key_from_u32(key_u32):
    """(2,) uint32 runtime input -> jax PRNG key (threefry)."""
    return jax.random.wrap_key_data(key_u32.astype(jnp.uint32), impl="threefry2x32")


def _ssprop_fwd(x, w, b, drop_rate, key, spec: ConvSpec):
    y = ref.conv_fwd_ref(x, w, b, stride=spec.stride, padding=spec.padding)
    return y, (x, w, drop_rate, key)


def _ssprop_bwd(spec: ConvSpec, res, g):
    x, w, drop_rate, key = res
    gm = _make_mask(g, drop_rate, key, spec)
    dx, dw, db = ref.conv_bwd_ref(x, w, gm, stride=spec.stride, padding=spec.padding)
    # drop_rate and key are non-differentiable controls.
    return dx, dw, db, jnp.zeros_like(drop_rate), jnp.zeros_like(key)


ssprop_conv.defvjp(_ssprop_fwd, _ssprop_bwd)


# ---------------------------------------------------------------------------
# compacted Pallas path (static drop rate) — the true-sparse hot path
# ---------------------------------------------------------------------------

def _static_keep(cout: int, drop_rate: float) -> int:
    return int(max(1, min(cout, round((1.0 - drop_rate) * cout))))


def make_ssprop_conv_pallas(*, stride=1, padding=0, drop_rate=0.8, interpret=True):
    """Build a compacted ssProp conv with a *static* drop rate.

    Returns f(x, w, b) -> y whose VJP runs entirely through the L1 Pallas
    kernels with physically shrunk matmuls (k' = keep channels). Used for the
    ``*_compact_*`` artifacts and the kernel-level perf benches.
    """

    @jax.custom_vjp
    def conv(x, w, b):
        return _pallas_fwd_impl(x, w, b)

    def _pallas_fwd_impl(x, w, b):
        bt, cin, h, wd = x.shape
        cout, _, k, _ = w.shape
        ho = ref.out_size(h, k, stride, padding)
        wo = ref.out_size(wd, k, stride, padding)
        cols = im2col(x, k=k, stride=stride, padding=padding, interpret=interpret)
        y = matmul(cols, ref.col_w_ref(w), interpret=interpret) + b[None, :]
        return jnp.transpose(y.reshape(bt, ho, wo, cout), (0, 3, 1, 2))

    def fwd(x, w, b):
        return _pallas_fwd_impl(x, w, b), (x, w)

    def bwd(res, g):
        x, w = res
        bt, cin, h, wd = x.shape
        cout, _, k, _ = w.shape
        ho = ref.out_size(h, k, stride, padding)
        wo = ref.out_size(wd, k, stride, padding)
        keep = _static_keep(cout, drop_rate)
        imp = channel_importance(g, interpret=interpret)
        # static-shape top-k indices (sorted for deterministic scatter).
        # NOTE: argsort rather than lax.top_k — the latter lowers to a
        # `topk(..., largest=true)` HLO attribute the xla_extension 0.5.1
        # text parser rejects.
        idx = jnp.sort(jnp.argsort(-imp)[:keep])
        cols = im2col(x, k=k, stride=stride, padding=padding, interpret=interpret)
        gc = jnp.transpose(g, (0, 2, 3, 1)).reshape(bt * ho * wo, cout)
        gck = jnp.take(gc, idx, axis=1)                      # (M, k') compaction
        cw = ref.col_w_ref(w)
        cwk = jnp.take(cw, idx, axis=1)                      # (N, k')
        dwk = matmul(cols.T, gck, interpret=interpret)       # shrunk GEMM 1
        dw = jnp.zeros((cin * k * k, cout), x.dtype).at[:, idx].set(dwk)
        dw = jnp.transpose(dw, (1, 0)).reshape(cout, cin, k, k)
        dcols = matmul(gck, cwk.T, interpret=interpret)      # shrunk GEMM 2
        dx = col2img(dcols, x_shape=x.shape, k=k, stride=stride, padding=padding,
                     interpret=interpret)
        db = jnp.zeros((cout,), g.dtype).at[idx].set(jnp.sum(gck, axis=0))
        return dx, dw, db

    conv.defvjp(fwd, bwd)
    return conv
