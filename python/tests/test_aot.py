"""AOT pipeline: registry coverage and end-to-end emission on a tmpdir."""

import json
import os

import pytest

from compile import aot, tensorstore


def test_registry_covers_every_experiment():
    names = [s[0] for s in aot.build_registry()]
    # Table 4 grid
    for arch in ("resnet18", "resnet50"):
        for ds in ("mnist", "fashion", "cifar10", "cifar100", "celeba", "imagenet64"):
            assert f"{arch}_{ds}_train" in names
            assert f"{arch}_{ds}_eval" in names
    # Table 7
    assert "resnet26_cifar10_train" in names and "resnet26_cifar100_train" in names
    # Fig 2 variants
    for tag in ("hw", "all", "random"):
        assert f"resnet18_cifar10_{tag}_train" in names
    # Fig 4 depth sweep
    for d in (2, 3, 4, 5, 6, 7):
        assert f"cnn{d}_cifar100_train" in names
    # Table 5 / Fig 3
    for ds in ("mnist", "fashion", "celeba"):
        assert f"ddpm_{ds}_train" in names and f"ddpm_{ds}_denoise" in names
    # compacted Pallas microbench
    for tag in ("dense", "d50", "d80"):
        assert f"conv_pallas_{tag}" in names
    assert len(names) == len(set(names)), "artifact names must be unique"


def test_dataset_registry_geometry_matches_table1():
    assert aot.DATASETS["mnist"] == (1, 28, 10, "ce", 32)
    assert aot.DATASETS["celeba"][:4] == (3, 64, 40, "bce")
    assert aot.DATASETS["cifar100"][2] == 100


@pytest.mark.slow
def test_emit_small_artifact_roundtrip(tmp_path):
    specs = [s for s in aot.build_registry() if s[0] == "cnn2_cifar100_train"]
    assert len(specs) == 1
    name, fn, args, roles, out_roles, meta = specs[0]
    info = aot._emit(str(tmp_path), name, fn, args, roles, out_roles, meta)
    assert info["n_inputs"] > 0
    hlo = (tmp_path / f"{name}.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
    man = json.loads((tmp_path / f"{name}.manifest.json").read_text())
    assert man["name"] == name
    assert len(man["inputs"]) == info["n_inputs"]
    # init tensorstore holds every state input
    init = tensorstore.read(str(tmp_path / f"{name}.init.tstore"))
    state_inputs = [i for i in man["inputs"] if i["role"] in ("param", "opt", "bn")]
    assert set(init) == {i["name"] for i in state_inputs}
    for i in state_inputs:
        assert list(init[i["name"]].shape) == i["shape"]
    # layer inventory present for the FLOPs accounting
    assert len(man["layers"]["convs"]) == 2
    assert all(set(c) >= {"cin", "cout", "k", "hout", "wout"} for c in man["layers"]["convs"])
