//! img2col / col2img (paper Fig. 1b), mirroring `ref.py::im2col_ref` /
//! `col2img_ref` index-for-index.
//!
//! Row `(b, i, j)` of the column matrix is the flattened Cin×K×K patch
//! under output pixel `(i, j)`: row `m = (b·Ho + i)·Wo + j`, column
//! `n = (c·K + ky)·K + kx`. `col_w` lays weights out as (N, Cout) so the
//! forward is one `cols · col_w` GEMM.

use super::Conv2d;

/// Output spatial size: (H + 2P − K) / S + 1.
pub fn out_size(h: usize, k: usize, stride: usize, padding: usize) -> usize {
    (h + 2 * padding - k) / stride + 1
}

/// (Bt, Cin, H, W) -> column matrix (M, N), zero-padded out of bounds.
pub fn im2col(cfg: &Conv2d, x: &[f32]) -> Vec<f32> {
    let mut cols = Vec::new();
    im2col_into(cfg, x, &mut cols);
    cols
}

/// [`im2col`] into a caller-owned buffer, reusing its allocation (the
/// plan/workspace hot path rebuilds into the same `Vec` every step).
pub fn im2col_into(cfg: &Conv2d, x: &[f32], cols: &mut Vec<f32>) {
    assert_eq!(x.len(), cfg.in_len(), "im2col input length");
    let (ho, wo, n) = (cfg.hout(), cfg.wout(), cfg.n());
    cols.clear();
    cols.resize(cfg.m() * n, 0f32);
    for b in 0..cfg.bt {
        for c in 0..cfg.cin {
            let plane = &x[(b * cfg.cin + c) * cfg.h * cfg.w..][..cfg.h * cfg.w];
            for i in 0..ho {
                for ky in 0..cfg.k {
                    let y = i * cfg.stride + ky;
                    if y < cfg.padding || y >= cfg.h + cfg.padding {
                        continue;
                    }
                    let row = &plane[(y - cfg.padding) * cfg.w..][..cfg.w];
                    for j in 0..wo {
                        let m = (b * ho + i) * wo + j;
                        for kx in 0..cfg.k {
                            let xx = j * cfg.stride + kx;
                            if xx < cfg.padding || xx >= cfg.w + cfg.padding {
                                continue;
                            }
                            cols[m * n + (c * cfg.k + ky) * cfg.k + kx] = row[xx - cfg.padding];
                        }
                    }
                }
            }
        }
    }
}

/// Inverse of [`im2col`]: scatter-add (M, N) columns back to (Bt, Cin, H, W).
pub fn col2img(cfg: &Conv2d, cols: &[f32]) -> Vec<f32> {
    let (ho, wo, n) = (cfg.hout(), cfg.wout(), cfg.n());
    assert_eq!(cols.len(), cfg.m() * n, "col2img input length");
    let mut x = vec![0f32; cfg.in_len()];
    for b in 0..cfg.bt {
        for c in 0..cfg.cin {
            let plane = &mut x[(b * cfg.cin + c) * cfg.h * cfg.w..][..cfg.h * cfg.w];
            for i in 0..ho {
                for ky in 0..cfg.k {
                    let y = i * cfg.stride + ky;
                    if y < cfg.padding || y >= cfg.h + cfg.padding {
                        continue;
                    }
                    for j in 0..wo {
                        let m = (b * ho + i) * wo + j;
                        for kx in 0..cfg.k {
                            let xx = j * cfg.stride + kx;
                            if xx < cfg.padding || xx >= cfg.w + cfg.padding {
                                continue;
                            }
                            plane[(y - cfg.padding) * cfg.w + (xx - cfg.padding)] +=
                                cols[m * n + (c * cfg.k + ky) * cfg.k + kx];
                        }
                    }
                }
            }
        }
    }
    x
}

/// (Cout, Cin, K, K) -> col_W (N, Cout), matching the im2col row layout
/// (`ref.py::col_w_ref`).
pub fn col_w(cfg: &Conv2d, w: &[f32]) -> Vec<f32> {
    let mut cw = Vec::new();
    col_w_into(cfg, w, &mut cw);
    cw
}

/// [`col_w`] into a caller-owned buffer, reusing its allocation.
pub fn col_w_into(cfg: &Conv2d, w: &[f32], cw: &mut Vec<f32>) {
    let n = cfg.n();
    assert_eq!(w.len(), cfg.w_len(), "col_w input length");
    cw.clear();
    cw.resize(n * cfg.cout, 0f32);
    for o in 0..cfg.cout {
        for i in 0..n {
            cw[i * cfg.cout + o] = w[o * n + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_3x3() -> Conv2d {
        Conv2d { bt: 1, cin: 1, h: 3, w: 3, cout: 1, k: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn out_size_matches_reference() {
        assert_eq!(out_size(6, 3, 1, 1), 6);
        assert_eq!(out_size(5, 3, 2, 0), 2);
        assert_eq!(out_size(8, 3, 2, 1), 4);
        assert_eq!(out_size(28, 3, 2, 1), 14);
    }

    #[test]
    fn im2col_center_row_is_full_patch() {
        // 3x3 image 1..9, padded 3x3 kernel: center output row = the image.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let cols = im2col(&cfg_3x3(), &x);
        assert_eq!(cols.len(), 9 * 9);
        let center = &cols[4 * 9..5 * 9];
        assert_eq!(center, x.as_slice());
        // corner row (0,0): only the bottom-right 2x2 of the patch in-bounds
        let corner = &cols[0..9];
        assert_eq!(corner, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]);
    }

    #[test]
    fn col2img_adjoint_of_im2col() {
        // <im2col(x), c> == <x, col2img(c)> for random-ish x, c (adjointness
        // is exactly what the backward pass relies on).
        let cfg = Conv2d { bt: 2, cin: 2, h: 5, w: 4, cout: 1, k: 3, stride: 2, padding: 1 };
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| ((i * 37 + 11) % 17) as f32 - 8.0).collect();
        let c: Vec<f32> =
            (0..cfg.m() * cfg.n()).map(|i| ((i * 13 + 5) % 19) as f32 - 9.0).collect();
        let lhs: f32 = im2col(&cfg, &x).iter().zip(&c).map(|(a, b)| a * b).sum();
        let rhs: f32 = x.iter().zip(col2img(&cfg, &c)).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn into_variants_reuse_allocations() {
        let cfg = cfg_3x3();
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut cols = im2col(&cfg, &x);
        let (cap, ptr) = (cols.capacity(), cols.as_ptr());
        im2col_into(&cfg, &x, &mut cols);
        assert_eq!((cols.capacity(), cols.as_ptr()), (cap, ptr), "rebuild must not reallocate");
        assert_eq!(cols, im2col(&cfg, &x));
    }

    #[test]
    fn col_w_transposes_weights() {
        let cfg = Conv2d { bt: 1, cin: 2, h: 3, w: 3, cout: 3, k: 1, stride: 1, padding: 0 };
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3, 2, 1, 1)
        let cw = col_w(&cfg, &w);
        assert_eq!(cw, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]); // (2, 3)
    }
}
