//! Quickstart: train a model-zoo CNN with scheduled sparse
//! back-propagation — pure Rust, no artifacts, no FFI, runs on any
//! machine:
//!
//! ```bash
//! cargo run --release --example quickstart
//! # any zoo preset works, e.g. the residual/BatchNorm family:
//! cargo run --release --example quickstart -- --model resnet-tiny-w8-b1
//! ```
//!
//! Trains the selected `--model` (default: the paper's SimpleCNN) on the
//! synthetic CIFAR-10 substitute with the paper's bar-2-epoch scheduler at
//! D*=0.8 through the NativeBackend (img2col GEMM forward, channel top-k
//! compacted sparse backward), and prints the resolved canonical spec, the
//! loss curve, and the FLOPs/energy ledger.

use anyhow::Result;
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::energy::RTX_A5000;
use ssprop::schedule::DropScheduler;
use ssprop::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let (epochs, ipe) = (4, 24);
    let mut cfg = NativeTrainConfig::quick(args.get_or("dataset", "cifar10"), epochs, ipe);
    cfg.model = args.get_or("model", "simple-cnn").to_string();
    cfg.scheduler = DropScheduler::paper_default(epochs, ipe); // bar, 2-epoch, D*=0.8
    cfg.verbose = true;

    println!("== ssProp quickstart: {} on synth-{} (native backend) ==\n", cfg.model, cfg.dataset);
    let mut trainer = NativeTrainer::new(cfg)?;
    let (test_loss, test_acc) = trainer.run()?;

    let m = &trainer.metrics;
    println!("\nmodel           {} ({})", trainer.model_spec, trainer.model.describe());
    println!("final test loss {test_loss:.4}, acc {test_acc:.3}");
    println!(
        "loss curve (every 8 iters): {:?}",
        m.losses.iter().step_by(8).map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!("mean drop rate  {:.2} (bar scheduler alternates 0 / 0.8)", m.mean_drop_rate());
    println!(
        "backward FLOPs  {:.3e} dense-equivalent -> {:.3e} actual ({:.1}% saved)",
        m.flops_dense,
        m.flops_actual,
        m.flops_saving() * 100.0
    );
    let saved = m.energy_saved(&RTX_A5000);
    println!("energy saved    {:.6} kWh / {:.4} gCO2e at A5000 scale", saved.kwh, saved.gco2e);
    Ok(())
}
