//! Evaluation metrics beyond train-loop loss/acc: the FID-proxy for
//! generation quality (S20) and the small dense linear algebra it needs.

pub mod fid;
pub mod linalg;

pub use fid::{fid_proxy, FeatureExtractor};
