//! Runtime (S7): the contract with the Python compile path.
//!
//! Always available: the artifact [`manifest`] schema (also the FLOPs
//! geometry source for the coordinator) plus artifact-directory discovery
//! with the typed [`EngineError`] — tests and benches downgrade
//! `ArtifactsMissing` to a skip instead of failing on bare runners.
//!
//! Behind the `pjrt` cargo feature: the PJRT engine itself
//! (`Engine`/`LoadedGraph` in the `pjrt` module), which loads `artifacts/*.hlo.txt`
//! produced by the Python compile path, compiles them on the CPU PJRT
//! client, and executes them from the coordinator's hot loop. Python never
//! runs here.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::fmt;
use std::path::PathBuf;

pub use manifest::{IoSpec, Manifest, Role};
#[cfg(feature = "pjrt")]
pub use pjrt::{
    f32_literal, i32_literal, literal_scalar_f32, literal_to_tensor, scalar_f32, tensor_to_literal,
    u32_literal, Engine, LoadedGraph,
};

/// Typed runtime errors. Kept xla-free so artifact-gated tests can
/// `downcast_ref::<EngineError>()` and skip-with-message on any build.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// No `index.json` found in any candidate artifacts directory.
    ArtifactsMissing { searched: Vec<PathBuf> },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::ArtifactsMissing { searched } => write!(
                f,
                "no artifacts directory found (searched {searched:?}) — run `make artifacts` \
                 or set SSPROP_ARTIFACTS"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Locate the artifacts directory: $SSPROP_ARTIFACTS (trusted as-is —
/// per-artifact loads only need `.hlo.txt` + `.manifest.json`, so a
/// hand-copied directory without an `index.json` still works), falling
/// back to ./artifacts or ../artifacts (cargo test/bench run with CWD =
/// the package root); fallback candidates count only when they hold an
/// `index.json`.
pub fn find_artifacts_dir() -> Result<PathBuf, EngineError> {
    if let Ok(dir) = std::env::var("SSPROP_ARTIFACTS") {
        return Ok(PathBuf::from(dir));
    }
    let mut searched = Vec::new();
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("index.json").exists() {
            return Ok(p);
        }
        searched.push(p);
    }
    Err(EngineError::ArtifactsMissing { searched })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_missing_error_is_typed_and_descriptive() {
        let err = EngineError::ArtifactsMissing { searched: vec![PathBuf::from("artifacts")] };
        let msg = err.to_string();
        assert!(msg.contains("artifacts"), "{msg}");
        assert!(msg.contains("SSPROP_ARTIFACTS"), "{msg}");
        // round-trips through anyhow for downcast-based skips
        let any: anyhow::Error = err.clone().into();
        assert_eq!(any.downcast_ref::<EngineError>(), Some(&err));
    }

    #[test]
    fn discovery_requires_index_json_for_fallback_candidates() {
        match find_artifacts_dir() {
            // the env override is trusted verbatim; fallback discovery only
            // returns a directory that actually holds an index.json
            Ok(dir) => assert!(
                std::env::var("SSPROP_ARTIFACTS").is_ok() || dir.join("index.json").exists()
            ),
            Err(EngineError::ArtifactsMissing { searched }) => assert!(!searched.is_empty()),
        }
    }
}
