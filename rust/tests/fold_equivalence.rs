//! Fold-equivalence property suite for the BN-folding inference path
//! (`ssprop::backend::fold`, docs/ARCHITECTURE.md "Inference path").
//!
//! Three contracts are pinned here:
//!
//! 1. **Numerical equivalence** — for every zoo preset that carries
//!    BatchNorm (the `resnet-tiny` family), training a few steps and then
//!    folding the running statistics and γ/β into the preceding convs
//!    must reproduce the unfolded eval logits within `1e-5 · (1 + |a|)`
//!    on randomized batches. The fold is a per-output-channel affine
//!    rewrite, so the only drift allowed is the float re-association of
//!    `(w·x)·s` vs `(w·s)·x`.
//! 2. **Exact identity** — when every BN is an identity in eval mode
//!    (γ = 1, β = 0, running mean 0, and a running variance chosen so
//!    that `1/√(rv+ε)` is *exactly* 1.0f32), folding must be a no-op on
//!    the weights and the folded logits must match **bitwise**.
//! 3. **Checkpoint roundtrip** — `fold_checkpoint` followed by
//!    `load_folded` must reproduce the in-memory fold bitwise
//!    (`flat_params` and logits), keep the stable `param['{name}.w']`
//!    conv keys, drop every BN tensor, and tag the artifact with
//!    `#folded`; a second save→load of the folded state is bitwise too.

use std::collections::HashMap;

use ssprop::backend::{build_model, fold, parse_model_spec, NativeBackend, Sequential};
use ssprop::coordinator::checkpoint;
use ssprop::tensorstore::Tensor;
use ssprop::util::rng::Pcg;

const CLASSES: usize = 4;
/// Examples are (2, 12, 12) images — small enough that the deepest
/// preset's release-mode training steps stay fast.
const N_IN: usize = 2 * 12 * 12;

/// Every zoo preset that carries BatchNorm: the residual family at two
/// widths and two depths (the other presets are BN-free and covered by
/// the typed-error tests in `failure_injection.rs`).
const BN_PRESETS: &[&str] = &["resnet-tiny-w4-b1", "resnet-tiny-w8-b1", "resnet-tiny-w4-b2"];

fn build(spec: &str, seed: u64) -> Sequential {
    build_model(&parse_model_spec(spec).unwrap(), 2, 12, CLASSES, seed).unwrap()
}

fn batch(bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Pcg::new(seed, 2);
    let x = (0..bt * N_IN).map(|_| rng.normal()).collect();
    let y = (0..bt).map(|j| (j % CLASSES) as i32).collect();
    (x, y)
}

/// A twin of `m` with identical state, BatchNorms folded away.
fn folded_twin(m: &Sequential, spec: &str) -> (Sequential, usize) {
    let mut twin = build(spec, 0); // weights are overwritten below
    twin.load_state_tensors(&m.state_tensors()).unwrap();
    let n = fold::fold_graph(&mut twin);
    (twin, n)
}

#[test]
fn folded_logits_match_unfolded_eval_within_1e5_for_every_bn_preset() {
    let be = NativeBackend::new();
    for spec in BN_PRESETS {
        // Train a few steps so γ/β move off init and the running stats
        // absorb real batch statistics — the fold must hold away from the
        // identity point, not just at it.
        let mut m = build(spec, 11);
        for step in 0..3u64 {
            let (x, y) = batch(6, 100 + step);
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let (mut folded, n) = folded_twin(&m, spec);
        assert!(n > 0, "{spec}: the residual preset has BatchNorms to fold");
        assert_eq!(fold::fold_graph(&mut folded), 0, "{spec}: folding is idempotent");

        for bseed in [7u64, 8, 9] {
            let (x, _) = batch(5, 200 + bseed);
            let want = m.infer_logits(&be, &x, 5);
            let got = folded.infer_logits(&be, &x, 5);
            assert_eq!(want.len(), got.len());
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                let tol = 1e-5 * (1.0 + a.abs());
                assert!((a - b).abs() <= tol, "{spec} batch {bseed} logit {i}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn identity_batchnorm_folds_bitwise() {
    // ε = 1e-5 is baked into the layer, so a literal rv = 1.0 gives a
    // scale of 1/√(1 + ε) ≠ 1. Instead search the few ulps below
    // 1 − ε for the running variance whose sum with ε rounds to exactly
    // 1.0f32; with the untrained defaults γ = 1, β = 0, rm = 0 the fold
    // factors are then scale = 1.0 and shift = +0.0 bitwise, multiplying
    // and shifting nothing — folded and unfolded logits must agree to
    // the bit.
    let mut rv = 1.0f32 - 2e-5f32;
    while rv + 1e-5f32 != 1.0f32 {
        rv = f32::from_bits(rv.to_bits() + 1);
    }
    assert_eq!(1.0f32 / (rv + 1e-5f32).sqrt(), 1.0f32);

    let be = NativeBackend::new();
    let spec = "resnet-tiny-w4-b1";
    let mut m = build(spec, 21);
    let state: Vec<(String, Tensor)> = m
        .state_tensors()
        .into_iter()
        .map(|(k, t)| {
            if k.ends_with(".rv']") {
                let n = t.to_f32().len();
                (k, Tensor::from_f32(vec![n], &vec![rv; n]))
            } else {
                (k, t)
            }
        })
        .collect();
    m.load_state_tensors(&state).unwrap();

    let (mut folded, n) = folded_twin(&m, spec);
    assert!(n > 0);
    let (x, _) = batch(4, 77);
    let want = m.infer_logits(&be, &x, 4);
    let got = folded.infer_logits(&be, &x, 4);
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: identity fold must be bitwise");
    }
}

#[test]
fn folded_checkpoints_roundtrip_bitwise() {
    let be = NativeBackend::new();
    let dir = std::env::temp_dir().join("ssprop_fold_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // A short-trained raw checkpoint on the registered mnist geometry
    // (fold_checkpoint rebuilds the model through the dataset registry,
    // so the artifact must name a real dataset).
    let spec = parse_model_spec("resnet-tiny-w4-b1").unwrap();
    let mut m = build_model(&spec, 1, 28, 10, 7).unwrap();
    let mut rng = Pcg::new(0xC0FFEE, 3);
    for step in 0..2usize {
        let x: Vec<f32> = (0..4 * 28 * 28).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..4).map(|j| ((j + step) % 10) as i32).collect();
        m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
    }
    let raw = dir.join("raw.tstore");
    let state: HashMap<String, Tensor> = m.state_tensors().into_iter().collect();
    checkpoint::save_tensors(&raw, &state, "native_mnist:resnet-tiny-w4-b1", 2).unwrap();

    // Fold on disk, then load the folded artifact back.
    let folded_path = dir.join("folded.tstore");
    let summary = fold::fold_checkpoint(&raw, &folded_path).unwrap();
    assert!(summary.folded > 0);
    assert_eq!(summary.spec, "resnet-tiny-w4-b1");
    assert_eq!(summary.artifact, "native_mnist:resnet-tiny-w4-b1#folded");
    assert!(fold::is_folded(&summary.artifact));

    let (mut loaded, artifact, epoch) = fold::load_folded(&folded_path).unwrap();
    assert_eq!(artifact, summary.artifact);
    assert_eq!(epoch, 2);

    // The in-memory fold of the same state is the bitwise reference.
    fold::fold_graph(&mut m);
    assert_eq!(m.flat_params(), loaded.flat_params(), "folded params roundtrip bitwise");

    // Stable names: conv keys survive the fold, BN tensors are gone.
    let keys: Vec<String> = loaded.state_tensors().into_iter().map(|(k, _)| k).collect();
    assert!(keys.iter().any(|k| k == "param['stem.conv.w']"), "{keys:?}");
    assert!(keys.iter().any(|k| k == "param['s0b0.conv1.w']"), "{keys:?}");
    assert!(keys.iter().all(|k| !k.contains(".bn")), "{keys:?}");

    // And the served logits agree bitwise with the in-memory fold.
    let x: Vec<f32> = (0..3 * 28 * 28).map(|_| rng.normal()).collect();
    let a = m.infer_logits(&be, &x, 3);
    let b = loaded.infer_logits(&be, &x, 3);
    for (i, (u, v)) in a.iter().zip(&b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "logit {i}");
    }

    // A second save→load of the already-folded state is bitwise too.
    let again = dir.join("again.tstore");
    let st2: HashMap<String, Tensor> = loaded.state_tensors().into_iter().collect();
    checkpoint::save_tensors(&again, &st2, &artifact, epoch).unwrap();
    let (reload, _, _) = fold::load_folded(&again).unwrap();
    assert_eq!(loaded.flat_params(), reload.flat_params());
}
