//! The layer-graph container: topologically-ordered nodes over named
//! activation *slots*, supporting residual (skip) connections through an
//! `Add` merge node — the structure the paper's ResNet experiments need
//! that a linear `Sequential` cannot express.
//!
//! Slot model: slot 0 is the graph input; node `i`'s output is slot
//! `i + 1`. Every node consumes one or two earlier slots ([`NodeOp`]), so
//! construction order *is* a topological order and one forward walk /
//! one reverse backward walk visits every node exactly once. The backward
//! accumulates gradients per slot: a slot consumed by several nodes (the
//! residual trunk feeding both a conv branch and its skip) receives each
//! consumer's contribution in fixed reverse-node order, and an `Add`
//! node fans the incoming gradient to both operands unchanged — which is
//! exactly the calculus of `y = a + b`.
//!
//! A chain-shaped graph ([`Graph::new`], the [`super::Sequential`]
//! constructor) degenerates to the historical container: every slot has
//! one consumer, gradient accumulation is a move, and the walk replays
//! the legacy SimpleCNN **bitwise** (pinned by
//! `rust/tests/layer_graph_equivalence.rs`).

use anyhow::{bail, Context, Result};

use super::{
    softmax_ce_core, softmax_ce_examples, FwdCtx, Layer, LayerWs, Selection, Shape, StepStats,
    INPUT_SLOT,
};
use crate::backend::{Backend, Conv2d};
use crate::flops::LayerSet;
use crate::tensorstore::Tensor;

/// What one graph node computes.
#[derive(Debug)]
pub(crate) enum NodeOp {
    /// A [`Layer`] applied to one predecessor slot.
    Layer {
        /// The layer (owns its parameters).
        layer: Box<dyn Layer>,
        /// Input slot id (0 = graph input, `i + 1` = node i's output).
        input: usize,
    },
    /// Residual merge: elementwise sum of two predecessor slots. Its
    /// backward fans the incoming gradient to both operands unchanged.
    Add {
        /// Left operand slot.
        a: usize,
        /// Right operand slot.
        b: usize,
    },
}

/// One node of a [`Graph`]: a checkpoint name (empty = stateless, not
/// checkpointed) plus its operation.
#[derive(Debug)]
pub(crate) struct Node {
    pub(crate) name: String,
    pub(crate) op: NodeOp,
}

/// Elementwise sum of two equal-length activation buffers (the `Add`
/// node's forward).
pub(crate) fn add_forward(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len(), "add operands must match");
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Accumulate a gradient contribution into a slot: the first contribution
/// moves in (bitwise — this is what keeps chain graphs identical to the
/// legacy walk), later ones add elementwise in the caller's fixed order.
pub(crate) fn accumulate(slot: &mut Option<Vec<f32>>, g: Vec<f32>) {
    match slot {
        None => *slot = Some(g),
        Some(acc) => {
            debug_assert_eq!(acc.len(), g.len(), "gradient fan-in length mismatch");
            for (av, gv) in acc.iter_mut().zip(&g) {
                *av += gv;
            }
        }
    }
}

/// Incremental constructor for residual [`Graph`]s: append nodes against
/// already-created slots, then [`GraphBuilder::finish`]. Shapes are
/// propagated and validated per node, so a malformed wiring fails at
/// build time, not mid-training.
#[derive(Debug)]
pub struct GraphBuilder {
    spec: String,
    nodes: Vec<Node>,
    /// `shapes[s]` is slot s's per-example shape.
    shapes: Vec<Shape>,
}

impl GraphBuilder {
    /// Start a graph over per-example inputs of `in_shape` (slot
    /// [`INPUT_SLOT`]).
    pub fn new(spec: impl Into<String>, in_shape: Shape) -> GraphBuilder {
        GraphBuilder { spec: spec.into(), nodes: Vec::new(), shapes: vec![in_shape] }
    }

    /// Shape of an existing slot (useful while wiring skip connections).
    pub fn slot_shape(&self, slot: usize) -> Option<Shape> {
        self.shapes.get(slot).copied()
    }

    /// Append `layer` consuming slot `input`; returns the new node's
    /// output slot. Stateless layers pass an empty `name`.
    pub fn layer(
        &mut self,
        name: impl Into<String>,
        input: usize,
        layer: Box<dyn Layer>,
    ) -> Result<usize> {
        let Some(in_shape) = self.shapes.get(input) else {
            bail!("layer {:?} wired to unknown slot {input}", layer.describe());
        };
        let out = layer
            .out_shape(in_shape)
            .with_context(|| format!("layer {:?} rejects its input", layer.describe()))?;
        self.nodes.push(Node { name: name.into(), op: NodeOp::Layer { layer, input } });
        self.shapes.push(out);
        Ok(self.shapes.len() - 1)
    }

    /// Append a residual merge of slots `a` and `b` (shapes must match);
    /// returns the merge's output slot.
    pub fn add(&mut self, a: usize, b: usize) -> Result<usize> {
        let (Some(&sa), Some(&sb)) = (self.shapes.get(a), self.shapes.get(b)) else {
            bail!("add wired to unknown slot ({a}, {b})");
        };
        if sa != sb {
            bail!("add operands disagree: slot {a} is {sa:?}, slot {b} is {sb:?}");
        }
        self.nodes.push(Node { name: String::new(), op: NodeOp::Add { a, b } });
        self.shapes.push(sa);
        Ok(self.shapes.len() - 1)
    }

    /// Validate and seal the graph. The final node must produce flat
    /// logits, and every intermediate node output must be consumed by a
    /// later node (a dangling branch would silently drop its gradient).
    pub fn finish(self) -> Result<Graph> {
        let GraphBuilder { spec, nodes, shapes } = self;
        if nodes.is_empty() {
            bail!("a model needs at least one layer");
        }
        let classes = match *shapes.last().expect("shapes is never empty") {
            Shape::Flat { features } => features,
            Shape::Spatial { .. } => bail!("the final layer must produce flat logits"),
        };
        let mut consumed = vec![false; shapes.len()];
        for node in &nodes {
            match node.op {
                NodeOp::Layer { input, .. } => consumed[input] = true,
                NodeOp::Add { a, b } => {
                    consumed[a] = true;
                    consumed[b] = true;
                }
            }
        }
        for (slot, used) in consumed.iter().enumerate().take(shapes.len() - 1).skip(1) {
            if !used {
                bail!("node {} output (slot {slot}) is never consumed", slot - 1);
            }
        }
        let ws = (0..nodes.len()).map(|_| LayerWs::default()).collect();
        Ok(Graph { spec, nodes, shapes, classes, ws, step: 0 })
    }
}

/// A feed-forward layer graph — residual connections allowed — trained
/// end-to-end through the [`Backend`] trait: owns the nodes, one
/// [`LayerWs`] per node, and the step counter that seeds stochastic
/// layers. The final node must produce a [`Shape::Flat`] logits vector;
/// the softmax cross-entropy loss lives in the container, not in a
/// layer, exactly as in the historical model.
#[derive(Debug)]
pub struct Graph {
    /// Resolved model-spec string ("simple-cnn-d2-w8") — display and
    /// checkpoint identity.
    spec: String,
    nodes: Vec<Node>,
    /// `shapes[s]` is slot s's shape (`shapes[0]` the input, `shapes[i+1]`
    /// node i's output).
    shapes: Vec<Shape>,
    /// Logit count of the final [`Shape::Flat`] output.
    classes: usize,
    /// Per-node workspaces for the serial path (the executor owns
    /// per-worker sets instead).
    ws: Vec<LayerWs>,
    /// Monotone train-step counter (dropout mask streams).
    step: u64,
}

impl Graph {
    /// Build a *chain-shaped* graph from `(checkpoint name, layer)` pairs,
    /// each consuming its predecessor's output — the [`super::Sequential`]
    /// constructor, bitwise-compatible with the historical container. The
    /// final shape must be flat (the logits); stateless layers pass an
    /// empty name.
    pub fn new(
        spec: impl Into<String>,
        in_shape: Shape,
        parts: Vec<(String, Box<dyn Layer>)>,
    ) -> Result<Graph> {
        let mut b = GraphBuilder::new(spec, in_shape);
        let mut cur = INPUT_SLOT;
        for (name, layer) in parts {
            cur = b.layer(name, cur, layer)?;
        }
        b.finish()
    }

    /// Start an explicit [`GraphBuilder`] (residual wiring).
    pub fn builder(spec: impl Into<String>, in_shape: Shape) -> GraphBuilder {
        GraphBuilder::new(spec, in_shape)
    }

    /// The resolved model-spec string this graph was built from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// One-line architecture summary (node descriptions joined in
    /// topological order; residual merges print as "add").
    pub fn describe(&self) -> String {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                NodeOp::Layer { layer, .. } => layer.describe(),
                NodeOp::Add { .. } => "add".to_string(),
            })
            .collect::<Vec<_>>()
            .join(" > ")
    }

    /// Per-example input shape.
    pub fn in_shape(&self) -> Shape {
        self.shapes[0]
    }

    /// Logit count of the classifier head.
    pub fn out_features(&self) -> usize {
        self.classes
    }

    /// Number of nodes in the graph (kept under the historical name; Add
    /// merges count as nodes).
    pub fn num_layers(&self) -> usize {
        self.nodes.len()
    }

    /// Read access to node `i` (the executor walks the graph this way).
    pub(crate) fn node(&self, i: usize) -> &Node {
        &self.nodes[i]
    }

    /// Node `i`'s layer, or `None` for an Add merge.
    pub(crate) fn node_layer(&self, i: usize) -> Option<&dyn Layer> {
        match &self.nodes[i].op {
            NodeOp::Layer { layer, .. } => Some(layer.as_ref()),
            NodeOp::Add { .. } => None,
        }
    }

    /// Mutable parameter arrays of node `i` (empty for stateless nodes and
    /// Add merges) — the executor applies reduced updates through this.
    pub(crate) fn node_params_mut(&mut self, i: usize) -> Vec<&mut Vec<f32>> {
        match &mut self.nodes[i].op {
            NodeOp::Layer { layer, .. } => layer.params_mut(),
            NodeOp::Add { .. } => Vec::new(),
        }
    }

    /// Key node `i`'s workspace to batch size `bt` (no-op for Add merges).
    pub(crate) fn node_ensure_ws(&self, i: usize, ws: &mut LayerWs, bt: usize) {
        if let NodeOp::Layer { layer, .. } = &self.nodes[i].op {
            layer.ensure_ws(ws, bt);
        }
    }

    /// Fold the batch statistics node `i`'s last training forward left in
    /// `ws` into persistent layer state (BatchNorm running stats); no-op
    /// for every other node.
    pub(crate) fn node_commit_stats(&mut self, i: usize, ws: &LayerWs) {
        if let NodeOp::Layer { layer, .. } = &mut self.nodes[i].op {
            layer.commit_stats(ws);
        }
    }

    /// Number of conv layers (ssProp-selectable units), including convs on
    /// residual projection shortcuts.
    pub fn conv_count(&self) -> usize {
        (0..self.nodes.len())
            .filter(|&i| self.node_layer(i).is_some_and(|l| l.conv_geom().is_some()))
            .count()
    }

    /// Geometry of every conv layer in node order (per-example batch
    /// size; callers re-key with [`Conv2d::with_batch`] as needed). The
    /// bench uses this to time the sparse backward GEMMs of a preset's
    /// actual layer shapes.
    pub fn conv_geoms(&self) -> Vec<Conv2d> {
        (0..self.nodes.len())
            .filter_map(|i| self.node_layer(i).and_then(|l| l.conv_geom()))
            .collect()
    }

    /// Total conv output channels — [`StepStats::total_channels`].
    pub fn total_channels(&self) -> usize {
        (0..self.nodes.len())
            .filter_map(|i| self.node_layer(i).and_then(|l| l.conv_geom()))
            .map(|g| g.cout)
            .sum()
    }

    /// Key every node workspace to batch size `bt` (conv plans re-key in
    /// place, preserving capacity). Called by `train_step`; also useful to
    /// prewarm before a timed loop — and, with the epoch-tail batch size,
    /// to prewarm the tail re-key.
    pub fn ensure_ws(&mut self, bt: usize) {
        let mut ws = std::mem::take(&mut self.ws);
        for (i, w) in ws.iter_mut().enumerate() {
            self.node_ensure_ws(i, w, bt);
        }
        self.ws = ws;
    }

    /// A fresh throwaway workspace set keyed to `bt` (eval has no backward
    /// to reuse caches for, and `&self` keeps eval shareable).
    fn fresh_ws(&self, bt: usize) -> Vec<LayerWs> {
        let mut ws: Vec<LayerWs> = (0..self.nodes.len()).map(|_| LayerWs::default()).collect();
        for (i, w) in ws.iter_mut().enumerate() {
            self.node_ensure_ws(i, w, bt);
        }
        ws
    }

    /// Advance and return the step counter seeding this step's stochastic
    /// layers. The serial and data-parallel paths both draw from here, so
    /// a sharded step reproduces the serial dropout masks.
    pub(crate) fn begin_step(&mut self) -> u64 {
        let step = self.step;
        self.step += 1;
        step
    }

    /// Forward pass keeping every slot: `acts[0] = x`, `acts[i + 1]` is
    /// node i's output, `acts[num_layers()]` the logits. Runs through the
    /// workspaces in `ws` — the executor passes per-worker sets so the
    /// identical forward runs per shard without locks. Batch-normalizing
    /// layers compute their statistics locally over `bt` (the serial and
    /// eval semantics; the executor substitutes globally-reduced
    /// statistics via [`Layer::forward_with_stats`]).
    pub(crate) fn forward_collect(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut [LayerWs],
        ctx: &FwdCtx,
    ) -> Vec<Vec<f32>> {
        assert_eq!(ws.len(), self.nodes.len(), "workspace count");
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes.len() + 1);
        acts.push(x.to_vec());
        for (i, node) in self.nodes.iter().enumerate() {
            let out = match &node.op {
                NodeOp::Layer { layer, input } => {
                    layer.forward(be, &acts[*input], bt, &mut ws[i], ctx)
                }
                NodeOp::Add { a, b } => add_forward(&acts[*a], &acts[*b]),
            };
            acts.push(out);
        }
        acts
    }

    /// One SGD training step at `drop_rate`; returns loss/acc/kept-channel
    /// stats. `x` is `(bt, in_shape)` flattened, `y` integer labels. Every
    /// conv layer selects its ssProp channels locally from the batch
    /// gradient (the data-parallel executor substitutes global selection);
    /// batch-normalizing layers use this batch's statistics and fold them
    /// into their running state.
    pub fn train_step(
        &mut self,
        be: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        if bt == 0 || x.len() != bt * self.in_shape().volume() {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        self.ensure_ws(bt);
        let step = self.begin_step();
        let ctx = FwdCtx { train: true, step, example_offset: 0 };
        // Take the workspaces out so the forward can borrow them alongside
        // `self` (same dance the legacy model did with its plans).
        let mut ws = std::mem::take(&mut self.ws);
        let acts = self.forward_collect(be, x, bt, &mut ws, &ctx);
        let n = self.nodes.len();
        let logits = &acts[n];
        let (loss_sum, correct, dlogits) = softmax_ce_core(logits, y, self.classes, bt);
        let loss = loss_sum / bt as f64;
        let acc = correct as f64 / bt as f64;
        if !loss.is_finite() {
            self.ws = ws;
            bail!("non-finite loss at drop rate {drop_rate}");
        }

        // Backward in reverse topological order over per-slot gradient
        // accumulators: each node takes its output slot's (fully
        // accumulated) gradient, computes its own gradients on pre-update
        // parameters, takes its SGD update immediately — updates never
        // feed another node's backward, so the order only has to be
        // fixed, not clever — and accumulates d loss / d input into its
        // input slot(s). An Add merge fans the gradient to both operands.
        let mut slot_grads: Vec<Option<Vec<f32>>> = (0..n + 1).map(|_| None).collect();
        slot_grads[n] = Some(dlogits);
        let mut kept = 0usize;
        for i in (0..n).rev() {
            let g = slot_grads[i + 1].take().expect("every node output feeds a later node");
            let (layer, input) = match &self.nodes[i].op {
                NodeOp::Add { a, b } => {
                    accumulate(&mut slot_grads[*a], g.clone());
                    accumulate(&mut slot_grads[*b], g);
                    continue;
                }
                NodeOp::Layer { layer, input } => (layer, *input),
            };
            let need_dx = input != INPUT_SLOT;
            let out = layer.backward(
                be,
                &acts[input],
                &g,
                bt,
                &mut ws[i],
                Selection::Local(drop_rate),
                need_dx,
            );
            kept += out.kept;
            for (param, grad) in self.node_params_mut(i).into_iter().zip(&out.grads) {
                for (pv, &gv) in param.iter_mut().zip(grad) {
                    *pv -= lr * gv;
                }
            }
            if need_dx {
                accumulate(&mut slot_grads[input], out.dx);
            }
        }
        // Fold this batch's statistics into persistent state (BN running
        // stats) exactly once per training step.
        for (i, w) in ws.iter().enumerate() {
            self.node_commit_stats(i, w);
        }
        self.ws = ws;

        Ok(StepStats { loss, acc, kept_channels: kept, total_channels: self.total_channels() })
    }

    /// Forward-only mean (loss, accuracy) on a batch. Stochastic layers run
    /// in eval mode (Dropout is the identity, BatchNorm normalizes with its
    /// running statistics); workspaces are throwaway.
    pub fn eval_batch(&self, be: &dyn Backend, x: &[f32], y: &[i32]) -> (f64, f64) {
        let bt = y.len();
        let mut ws = self.fresh_ws(bt);
        let ctx = FwdCtx { train: false, step: self.step, example_offset: 0 };
        let acts = self.forward_collect(be, x, bt, &mut ws, &ctx);
        let (losses, correct) = softmax_ce_examples(acts.last().unwrap(), y, self.classes);
        let mut loss_sum = 0f64;
        for &l in &losses {
            loss_sum += l;
        }
        (loss_sum / bt as f64, correct as f64 / bt as f64)
    }

    /// Inference-only forward walk: logits for `bt` examples in eval mode
    /// (Dropout is the identity, BatchNorm normalizes with its running
    /// statistics), run over the graph's own persistent workspaces so conv
    /// im2col plans are keyed once and reused across requests — the
    /// serving hot path ([`crate::coordinator::serve`]) allocates no
    /// gradient accumulators and no backward scratch. Unlike
    /// [`Graph::eval_batch`] there is no throwaway workspace set and no
    /// loss computation; label-side bookkeeping stays with the caller.
    /// Eval-mode layers are per-example, so the logits of example `i` are
    /// bitwise identical whatever batch it arrives in.
    pub fn infer_logits(&mut self, be: &dyn Backend, x: &[f32], bt: usize) -> Vec<f32> {
        assert!(bt > 0, "empty inference batch");
        assert_eq!(x.len(), bt * self.in_shape().volume(), "inference batch geometry");
        self.ensure_ws(bt);
        let mut ws = std::mem::take(&mut self.ws);
        let ctx = FwdCtx { train: false, step: self.step, example_offset: 0 };
        let mut acts = self.forward_collect(be, x, bt, &mut ws, &ctx);
        self.ws = ws;
        acts.pop().expect("forward_collect returns at least the input slot")
    }

    /// How many nodes consume `slot` (an Add merge of a slot with itself
    /// counts twice).
    fn slot_consumers(&self, slot: usize) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                NodeOp::Layer { input, .. } => usize::from(*input == slot),
                NodeOp::Add { a, b } => usize::from(*a == slot) + usize::from(*b == slot),
            })
            .sum()
    }

    /// Fold every eligible BatchNorm into the conv producing its input —
    /// `w'[o,·] = w[o,·]·scale[o]`, `b'[o] = b[o]·scale[o] + shift[o]` with
    /// `(scale, shift)` from [`Layer::bn_fold_factors`] — then remove the
    /// BN node and rewire its consumers to the conv's output slot. A BN is
    /// eligible when its producer is a conv layer whose output *only* that
    /// BN consumes; anything else (BN on the graph input, BN after a
    /// non-conv node, a conv fanning out to a skip connection) is left in
    /// place, as are BN-less convs like `resnet-tiny`'s 1×1 projections.
    /// Conv node names are untouched, so the folded graph's state tensors
    /// keep their stable `param['{name}.w']` keys. Returns the number of
    /// BN nodes folded away. The resulting graph computes the *eval*
    /// forward only — training it would recompute batch statistics the
    /// fold already baked in.
    pub(crate) fn fold_batchnorm(&mut self) -> usize {
        let mut folded = 0usize;
        let mut j = 0usize;
        while j < self.nodes.len() {
            let factors = match &self.nodes[j].op {
                NodeOp::Layer { layer, input } => layer.bn_fold_factors().map(|f| (f, *input)),
                NodeOp::Add { .. } => None,
            };
            let Some(((scale, shift), in_slot)) = factors else {
                j += 1;
                continue;
            };
            let producer_is_conv = in_slot != INPUT_SLOT
                && match &self.nodes[in_slot - 1].op {
                    NodeOp::Layer { layer, .. } => layer.conv_geom().is_some(),
                    NodeOp::Add { .. } => false,
                };
            if !producer_is_conv || self.slot_consumers(in_slot) != 1 {
                j += 1;
                continue;
            }
            // Scale the producer conv's weights row-wise (OIHW: one
            // contiguous cin·k·k row per output channel) and fold the
            // shift through its bias.
            let NodeOp::Layer { layer, .. } = &mut self.nodes[in_slot - 1].op else {
                unreachable!("producer checked to be a conv layer node");
            };
            let cout = layer.conv_geom().expect("producer is a conv").cout;
            assert_eq!(scale.len(), cout, "BN channels must match conv cout");
            let (mut w, mut b) = {
                let ps = layer.params();
                let w = ps.iter().find(|p| p.field == "w").expect("conv has weights");
                let b = ps.iter().find(|p| p.field == "b").expect("conv has a bias");
                (w.data.to_vec(), b.data.to_vec())
            };
            let row = w.len() / cout;
            for o in 0..cout {
                let s = scale[o];
                for v in &mut w[o * row..(o + 1) * row] {
                    *v *= s;
                }
                b[o] = b[o] * s + shift[o];
            }
            layer.load_param("w", w).expect("folded weights keep their shape");
            layer.load_param("b", b).expect("folded bias keeps its shape");
            // Remove the BN node and compact the slot space: its output
            // slot j+1 redirects to the conv's slot, every later slot
            // shifts down by one.
            self.nodes.remove(j);
            self.shapes.remove(j + 1);
            self.ws.remove(j);
            for node in &mut self.nodes {
                let remap = |s: &mut usize| {
                    if *s == j + 1 {
                        *s = in_slot;
                    } else if *s > j + 1 {
                        *s -= 1;
                    }
                };
                match &mut node.op {
                    NodeOp::Layer { input, .. } => remap(input),
                    NodeOp::Add { a, b } => {
                        remap(a);
                        remap(b);
                    }
                }
            }
            folded += 1;
            // The node that was at j+1 now sits at j — revisit it.
        }
        folded
    }

    /// Parameters as named tensors — `param['{name}.{field}']`, the
    /// checkpoint format shared with the AOT path (and bit-compatible with
    /// the legacy SimpleCNN's `conv{l}`/`fc` naming). Node names may
    /// themselves contain dots (`s1b0.bn1`); the field is everything after
    /// the *last* dot, so BatchNorm running stats land under stable names
    /// like `param['s1b0.bn1.rm']`.
    pub fn state_tensors(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for node in &self.nodes {
            if node.name.is_empty() {
                continue;
            }
            let NodeOp::Layer { layer, .. } = &node.op else { continue };
            for p in layer.params() {
                let key = format!("param['{}.{}']", node.name, p.field);
                out.push((key, Tensor::from_f32(p.shape.clone(), p.data)));
            }
        }
        out
    }

    /// Restore parameters saved by [`Graph::state_tensors`].
    pub fn load_state_tensors(&mut self, tensors: &[(String, Tensor)]) -> Result<()> {
        for (name, t) in tensors {
            let inner = name
                .strip_prefix("param['")
                .and_then(|r| r.strip_suffix("']"))
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            let (lname, field) = inner
                .rsplit_once('.')
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            let node = self
                .nodes
                .iter_mut()
                .find(|n| n.name == lname)
                .ok_or_else(|| anyhow::anyhow!("unknown state leaf {name:?}"))?;
            let NodeOp::Layer { layer, .. } = &mut node.op else {
                bail!("state leaf {name:?} names a parameterless node");
            };
            layer.load_param(field, t.to_f32()).with_context(|| format!("loading {name:?}"))?;
        }
        Ok(())
    }

    /// Every parameter flattened in checkpoint order — including BatchNorm
    /// running statistics — the bitwise-comparison target for the
    /// determinism suites.
    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for i in 0..self.nodes.len() {
            if let Some(layer) = self.node_layer(i) {
                for p in layer.params() {
                    out.extend_from_slice(p.data);
                }
            }
        }
        out
    }

    /// Conv + BN + dropout inventory for Eq. 6–9 FLOPs accounting, in node
    /// order. A batch-normalizing node marks `counted_bn` on the conv that
    /// *produces its input slot* — resolved through the graph wiring, not
    /// node append order — so the Eq. 7 ledger stays correct however a
    /// builder interleaves projection shortcuts with the main-path BNs.
    pub fn layer_set(&self) -> LayerSet {
        let mut set = LayerSet::default();
        // conv_at_slot[s]: index into set.convs of the conv producing slot s.
        let mut conv_at_slot: Vec<Option<usize>> = vec![None; self.nodes.len() + 1];
        for (i, node) in self.nodes.iter().enumerate() {
            let NodeOp::Layer { layer, input } = &node.op else { continue };
            if layer.needs_batch_stats() {
                if let Some(ci) = conv_at_slot[*input] {
                    set.convs[ci].counted_bn = true;
                }
                continue;
            }
            layer.account_flops(&mut set);
            if layer.conv_geom().is_some() {
                conv_at_slot[i + 1] = Some(set.convs.len() - 1);
            }
        }
        set
    }

    /// Total im2col materializations across this graph's own workspaces —
    /// advances by exactly [`Graph::conv_count`] per serial `train_step`
    /// when the fused path is healthy.
    pub fn plan_cols_builds(&self) -> u64 {
        self.ws.iter().map(|w| w.plan_cols_builds()).sum()
    }

    /// Capacity fingerprints of every conv plan, conv order (regression
    /// tests pin these flat across steps).
    pub fn plan_caps(&self) -> Vec<[usize; 7]> {
        self.ws.iter().filter_map(|w| w.plan_caps()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{
        BatchNorm2d, Conv2dLayer, Dropout, GlobalAvgPool, Linear, ReLU, Sequential,
    };
    use super::*;
    use crate::backend::NativeBackend;
    use crate::util::rng::Pcg;

    fn tiny() -> Sequential {
        let mut rng = Pcg::new(3, 1);
        let parts: Vec<(String, Box<dyn Layer>)> = vec![
            ("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 1, 6, 6, 4, 3, 1, 1))),
            (String::new(), Box::new(ReLU)),
            (String::new(), Box::new(GlobalAvgPool::new(4, 6, 6))),
            ("fc".into(), Box::new(Linear::init(&mut rng, 4, 3))),
        ];
        Sequential::new("tiny", Shape::Spatial { c: 1, h: 6, w: 6 }, parts).unwrap()
    }

    #[test]
    fn shape_propagation_and_metadata() {
        let m = tiny();
        assert_eq!(m.in_shape(), Shape::Spatial { c: 1, h: 6, w: 6 });
        assert_eq!(m.out_features(), 3);
        assert_eq!(m.num_layers(), 4);
        assert_eq!(m.conv_count(), 1);
        assert_eq!(m.total_channels(), 4);
        assert!(m.describe().contains("conv3x3"));
        assert_eq!(m.spec(), "tiny");
    }

    #[test]
    fn rejects_spatial_output_and_geometry_mismatch() {
        let mut rng = Pcg::new(3, 1);
        let spatial_end: Vec<(String, Box<dyn Layer>)> =
            vec![("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 1, 6, 6, 4, 3, 1, 1)))];
        assert!(Sequential::new("bad", Shape::Spatial { c: 1, h: 6, w: 6 }, spatial_end).is_err());

        let mut rng = Pcg::new(3, 1);
        let wrong_in: Vec<(String, Box<dyn Layer>)> =
            vec![("conv0".into(), Box::new(Conv2dLayer::init(&mut rng, 2, 6, 6, 4, 3, 1, 1)))];
        assert!(Sequential::new("bad", Shape::Spatial { c: 1, h: 6, w: 6 }, wrong_in).is_err());

        assert!(Sequential::new("empty", Shape::Flat { features: 3 }, Vec::new()).is_err());
    }

    #[test]
    fn builder_rejects_bad_wiring() {
        let shape = Shape::Spatial { c: 2, h: 4, w: 4 };
        // unknown slot
        let mut b = Graph::builder("bad", shape);
        assert!(b.layer("", 7, Box::new(ReLU)).is_err());
        // add of mismatched shapes
        let mut b = Graph::builder("bad", shape);
        let r = b.layer("", INPUT_SLOT, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        assert!(b.add(INPUT_SLOT, r).is_err(), "spatial + flat must not merge");
        // dangling node output
        let mut b = Graph::builder("bad", shape);
        b.layer("", INPUT_SLOT, Box::new(ReLU)).unwrap();
        let g = b.layer("", INPUT_SLOT, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        let mut rng = Pcg::new(1, 1);
        b.layer("fc", g, Box::new(Linear::init(&mut rng, 2, 3))).unwrap();
        let err = b.finish().err().expect("dangling relu must fail").to_string();
        assert!(err.contains("never consumed"), "{err}");
        // slot_shape reads back what was wired
        let b = Graph::builder("ok", shape);
        assert_eq!(b.slot_shape(INPUT_SLOT), Some(shape));
        assert_eq!(b.slot_shape(9), None);
    }

    #[test]
    fn add_merge_forwards_sum_and_fans_gradient() {
        // Residual identity: y = dropout0(x) + x = 2x on positive input
        // (rate-0 dropout is the identity). Training this graph on x must
        // match training the plain gap->fc chain on 2x bit-for-bit: the
        // forward sums, and the trunk slot accumulates both consumers'
        // gradients without disturbing the head's own gradient flow.
        let be = NativeBackend::new();
        let shape = Shape::Spatial { c: 2, h: 2, w: 2 };
        let head = |rng: &mut Pcg| Linear::init(rng, 2, 3);

        let mut b = Graph::builder("res", shape);
        let branch = b.layer("", INPUT_SLOT, Box::new(Dropout::new(0.0, shape, 1))).unwrap();
        let sum = b.add(branch, INPUT_SLOT).unwrap();
        let gap = b.layer("", sum, Box::new(GlobalAvgPool::new(2, 2, 2))).unwrap();
        let mut rng = Pcg::new(5, 1);
        b.layer("fc", gap, Box::new(head(&mut rng))).unwrap();
        let mut res = b.finish().unwrap();
        assert!(res.describe().contains("add"), "{}", res.describe());

        let mut rng = Pcg::new(5, 1);
        let chain: Vec<(String, Box<dyn Layer>)> = vec![
            (String::new(), Box::new(GlobalAvgPool::new(2, 2, 2))),
            ("fc".into(), Box::new(head(&mut rng))),
        ];
        let mut plain = Sequential::new("chain", shape, chain).unwrap();

        let mut drng = Pcg::new(9, 9);
        let x: Vec<f32> = (0..4 * 8).map(|_| drng.uniform() + 0.1).collect();
        let x2: Vec<f32> = x.iter().map(|&v| v + v).collect();
        let y = vec![0, 1, 2, 0];
        for step in 0..3 {
            let a = res.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
            let b = plain.train_step(&be, &x2, &y, 0.0, 0.05).unwrap();
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss bits");
            assert_eq!(res.flat_params(), plain.flat_params(), "step {step} params");
        }
    }

    #[test]
    fn train_step_reduces_loss_and_counts_channels() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut rng = Pcg::new(9, 2);
        let x: Vec<f32> = (0..6 * 36).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let first = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert_eq!(first.kept_channels, first.total_channels);
        for _ in 0..20 {
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let last = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
        // sparse step keeps round((1-0.8)*4) = 1 of 4 channels
        let sparse = m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert_eq!(sparse.kept_channels, 1);
        assert_eq!(sparse.total_channels, 4);
    }

    #[test]
    fn train_step_rejects_bad_geometry() {
        let be = NativeBackend::new();
        let mut m = tiny();
        assert!(m.train_step(&be, &[0.0; 5], &[0, 1], 0.0, 0.05).is_err());
        assert!(m.train_step(&be, &[], &[], 0.0, 0.05).is_err());
    }

    #[test]
    fn state_tensor_roundtrip_and_errors() {
        let be = NativeBackend::new();
        let mut a = tiny();
        let mut rng = Pcg::new(11, 4);
        let x: Vec<f32> = (0..4 * 36).map(|_| rng.normal()).collect();
        let y: Vec<i32> = vec![0, 1, 2, 0];
        a.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let saved = a.state_tensors();
        assert_eq!(saved.len(), 4, "conv w/b + fc w/b");
        assert!(saved.iter().any(|(n, _)| n == "param['conv0.w']"));
        assert!(saved.iter().any(|(n, _)| n == "param['fc.b']"));

        let mut b = tiny();
        assert_ne!(a.flat_params(), b.flat_params());
        b.load_state_tensors(&saved).unwrap();
        assert_eq!(a.flat_params(), b.flat_params());
        let (la, _) = a.eval_batch(&be, &x, &y);
        let (lb, _) = b.eval_batch(&be, &x, &y);
        assert_eq!(la, lb);

        let bad = vec![("param['fc.b']".to_string(), Tensor::from_f32(vec![2], &[0.0, 1.0]))];
        assert!(b.load_state_tensors(&bad).is_err(), "shape mismatch must fail");
        let unknown = vec![("param['nope.w']".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(b.load_state_tensors(&unknown).is_err(), "unknown layer must fail");
        let mangled = vec![("weights".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(b.load_state_tensors(&mangled).is_err(), "malformed key must fail");
    }

    #[test]
    fn dotted_node_names_checkpoint_on_the_last_dot() {
        let be = NativeBackend::new();
        let shape = Shape::Spatial { c: 1, h: 4, w: 4 };
        let mut b = Graph::builder("dotted", shape);
        let bn = b.layer("s0b0.bn1", INPUT_SLOT, Box::new(BatchNorm2d::new(1, 4, 4))).unwrap();
        let gap = b.layer("", bn, Box::new(GlobalAvgPool::new(1, 4, 4))).unwrap();
        let mut rng = Pcg::new(2, 1);
        b.layer("fc", gap, Box::new(Linear::init(&mut rng, 1, 2))).unwrap();
        let mut m = b.finish().unwrap();
        let x: Vec<f32> = (0..2 * 16).map(|i| i as f32 * 0.1).collect();
        m.train_step(&be, &x, &[0, 1], 0.0, 0.05).unwrap();
        let saved = m.state_tensors();
        let names: Vec<&str> = saved.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"param['s0b0.bn1.rm']"), "{names:?}");
        let mut fresh = {
            let mut b = Graph::builder("dotted", shape);
            let bn = b.layer("s0b0.bn1", INPUT_SLOT, Box::new(BatchNorm2d::new(1, 4, 4))).unwrap();
            let gap = b.layer("", bn, Box::new(GlobalAvgPool::new(1, 4, 4))).unwrap();
            let mut rng = Pcg::new(7, 1);
            b.layer("fc", gap, Box::new(Linear::init(&mut rng, 1, 2))).unwrap();
            b.finish().unwrap()
        };
        fresh.load_state_tensors(&saved).unwrap();
        assert_eq!(m.flat_params(), fresh.flat_params(), "dotted names must roundtrip");
    }

    #[test]
    fn flops_inventory_lists_convs() {
        let m = tiny();
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 1);
        assert_eq!((set.convs[0].cin, set.convs[0].cout, set.convs[0].k), (1, 4, 3));
        assert!(set.dropouts.is_empty());
        assert!(!set.convs[0].counted_bn, "no BN in this graph");
    }

    fn conv_bn_chain() -> Graph {
        let shape = Shape::Spatial { c: 1, h: 4, w: 4 };
        let mut rng = Pcg::new(17, 1);
        let mut b = Graph::builder("foldable", shape);
        let conv = Conv2dLayer::init(&mut rng, 1, 4, 4, 2, 3, 1, 1);
        let c = b.layer("c0", INPUT_SLOT, Box::new(conv)).unwrap();
        let bn = b.layer("bn0", c, Box::new(BatchNorm2d::new(2, 4, 4))).unwrap();
        let r = b.layer("", bn, Box::new(ReLU)).unwrap();
        let gap = b.layer("", r, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        b.layer("fc", gap, Box::new(Linear::init(&mut rng, 2, 3))).unwrap();
        b.finish().unwrap()
    }

    #[test]
    fn fold_batchnorm_removes_bn_and_preserves_eval_forward() {
        let be = NativeBackend::new();
        let mut m = conv_bn_chain();
        // a couple of training steps give the BN nontrivial running stats
        let mut rng = Pcg::new(23, 5);
        let x: Vec<f32> = (0..4 * 16).map(|_| rng.normal()).collect();
        let y = vec![0, 1, 2, 0];
        for _ in 0..3 {
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let before = m.infer_logits(&be, &x, 4);
        let layers_before = m.num_layers();
        assert_eq!(m.fold_batchnorm(), 1);
        assert_eq!(m.num_layers(), layers_before - 1);
        assert!(!m.describe().contains("bn"), "{}", m.describe());
        let names: Vec<String> = m.state_tensors().into_iter().map(|(n, _)| n).collect();
        assert!(names.contains(&"param['c0.w']".to_string()), "{names:?}");
        assert!(!names.iter().any(|n| n.contains("bn0")), "{names:?}");
        let after = m.infer_logits(&be, &x, 4);
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "folded logits drift: {a} vs {b}");
        }
        // a second pass finds nothing left to fold
        assert_eq!(m.fold_batchnorm(), 0);
    }

    #[test]
    fn fold_batchnorm_skips_ineligible_bns() {
        let shape = Shape::Spatial { c: 2, h: 4, w: 4 };
        let mut rng = Pcg::new(19, 1);
        // BN directly on the graph input: no producer conv, must stay.
        let mut b = Graph::builder("bn-on-input", shape);
        let bn = b.layer("bn", INPUT_SLOT, Box::new(BatchNorm2d::new(2, 4, 4))).unwrap();
        let gap = b.layer("", bn, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        b.layer("fc", gap, Box::new(Linear::init(&mut rng, 2, 2))).unwrap();
        let mut m = b.finish().unwrap();
        assert_eq!(m.fold_batchnorm(), 0);
        assert!(m.describe().contains("bn"));

        // Conv output fanning out to a skip consumer besides the BN: the
        // fold would corrupt the skip branch, so it must be skipped.
        let mut b = Graph::builder("fanout", shape);
        let conv = Conv2dLayer::init(&mut rng, 2, 4, 4, 2, 3, 1, 1);
        let c = b.layer("c0", INPUT_SLOT, Box::new(conv)).unwrap();
        let bn = b.layer("bn0", c, Box::new(BatchNorm2d::new(2, 4, 4))).unwrap();
        let sum = b.add(bn, c).unwrap();
        let gap = b.layer("", sum, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        b.layer("fc", gap, Box::new(Linear::init(&mut rng, 2, 2))).unwrap();
        let mut m = b.finish().unwrap();
        assert_eq!(m.fold_batchnorm(), 0);
        assert!(m.describe().contains("bn"));
    }

    #[test]
    fn infer_logits_matches_eval_batch_and_reuses_plans() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut rng = Pcg::new(29, 3);
        let x: Vec<f32> = (0..6 * 36).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let logits = m.infer_logits(&be, &x, 6);
        assert_eq!(logits.len(), 6 * 3);
        // batched logits equal each example inferred alone, bitwise
        for i in 0..6 {
            let one = m.infer_logits(&be, &x[i * 36..(i + 1) * 36], 1);
            for (a, b) in logits[i * 3..(i + 1) * 3].iter().zip(&one) {
                assert_eq!(a.to_bits(), b.to_bits(), "example {i}");
            }
        }
        // eval_batch's accuracy agrees with the argmax of these logits
        let (_, acc) = m.eval_batch(&be, &x, &y);
        let hits = (0..6)
            .filter(|&i| {
                let row = &logits[i * 3..(i + 1) * 3];
                let arg = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(c, _)| c)
                    .unwrap();
                arg as i32 == y[i]
            })
            .count();
        assert_eq!(acc, hits as f64 / 6.0);
        // repeated same-batch inference rebuilds no conv plans beyond the
        // per-request im2col (capacity fingerprints stay flat)
        let caps = m.plan_caps();
        m.infer_logits(&be, &x, 6);
        assert_eq!(m.plan_caps(), caps, "plan capacities must not grow across requests");
    }

    #[test]
    fn layer_set_marks_bn_on_its_own_conv_regardless_of_node_order() {
        // The projection conv is appended BETWEEN the main conv and its BN
        // in node order; the BN must still mark the conv that produces its
        // input slot — never "whichever conv was inventoried last".
        let shape = Shape::Spatial { c: 1, h: 4, w: 4 };
        let mut rng = Pcg::new(3, 1);
        let mut b = Graph::builder("order", shape);
        let main = Conv2dLayer::init(&mut rng, 1, 4, 4, 2, 3, 1, 1);
        let c1 = b.layer("c1", INPUT_SLOT, Box::new(main)).unwrap();
        let proj = Conv2dLayer::init(&mut rng, 1, 4, 4, 2, 1, 1, 0);
        let pr = b.layer("proj", INPUT_SLOT, Box::new(proj)).unwrap();
        let bn = b.layer("bn", c1, Box::new(BatchNorm2d::new(2, 4, 4))).unwrap();
        let sum = b.add(bn, pr).unwrap();
        let gap = b.layer("", sum, Box::new(GlobalAvgPool::new(2, 4, 4))).unwrap();
        b.layer("fc", gap, Box::new(Linear::init(&mut rng, 2, 2))).unwrap();
        let m = b.finish().unwrap();
        let set = m.layer_set();
        assert_eq!(set.convs.len(), 2);
        assert!(set.convs[0].counted_bn, "bn marks the conv feeding it");
        assert!(!set.convs[1].counted_bn, "the projection stays uncounted");
    }
}
