"""SimpleCNN-d — the hyperparameter-search model of Fig. 4.

"a simple CNN architecture with a few convolutional layers followed by a
fully connected layer"; depth ranges 2..11 in the paper's sweep. Channels
start at ``width`` and double on each stride-2 downsample (every second
layer), capped so the spatial size never drops below 2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm


class SimpleCNN:
    def __init__(self, *, depth: int, in_ch: int, img: int, classes: int,
                 width: int = 16, mode: str = "channel", select: str = "topk"):
        assert depth >= 1
        self.depth, self.in_ch, self.img, self.classes = depth, in_ch, img, classes
        self.width, self.mode, self.select = width, mode, select
        # plan: (cin, cout, stride) per layer
        self.plan = []
        c, h = in_ch, img
        w = width
        for i in range(depth):
            stride = 2 if (i % 2 == 1 and h > 4) else 1
            cout = min(w * (2 ** sum(1 for (_, _, s) in self.plan if s == 2)), 128)
            self.plan.append((c, cout, stride))
            c = cout
            h = cm.conv_out(h, 3, stride, 1)
        self.out_ch, self.out_hw = c, h

    def inventory(self) -> cm.Inventory:
        inv = cm.Inventory()
        h = self.img
        for (cin, cout, s) in self.plan:
            ho, _ = inv.conv(cin, cout, 3, s, 1, h, h)
            inv.bn(cout, ho, ho)
            h = ho
        return inv

    def init(self, key):
        params, state = {}, {}
        keys = jax.random.split(key, self.depth + 1)
        for i, (cin, cout, _) in enumerate(self.plan):
            params[f"conv{i}"] = cm.init_conv(keys[i], cin, cout, 3)
            params[f"bn{i}"] = cm.init_bn(cout)
            state[f"bn{i}"] = cm.init_bn_state(cout)
        params["fc"] = cm.init_dense(keys[-1], self.out_ch, self.classes)
        return params, state

    def apply(self, params, state, x, *, train: bool, drop_rate, dropout_rate, key):
        del dropout_rate  # SimpleCNN has no Dropout layers
        new_state = {}
        for i, (_, _, s) in enumerate(self.plan):
            lkey = cm.fold_key(key, i)
            x = cm.conv(params[f"conv{i}"], x, drop_rate, lkey,
                        stride=s, padding=1, mode=self.mode, select=self.select)
            x, new_state[f"bn{i}"] = cm.batchnorm(params[f"bn{i}"], state[f"bn{i}"], x, train=train)
            x = jax.nn.relu(x)
        x = cm.global_avg_pool(x)
        return cm.dense(params["fc"], x), new_state
