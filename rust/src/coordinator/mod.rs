//! L3 coordinator (S18): owns the training loop.
//!
//! Responsibilities per iteration:
//!   1. ask the [`DropScheduler`](crate::schedule::DropScheduler) for this
//!      iteration's drop rate (the paper's *scheduled* sparsity),
//!   2. pull the next batch from the prefetching data plane,
//!   3. execute the training step,
//!   4. account FLOPs (dense-equivalent vs actual) and record metrics.
//!
//! Two interchangeable executors implement step 3:
//!
//! * [`NativeTrainer`] (always available) drives any model-zoo layer graph
//!   (`--model`: SimpleCNN, vgg-tiny, dropout-cnn, ...) through the
//!   [`Backend`](crate::backend::Backend) op trait — pure Rust, no
//!   artifacts, no FFI;
//! * `Trainer` (feature `pjrt`) assembles the AOT step's inputs in
//!   manifest order, executes through PJRT, and re-binds state via
//!   `feeds_input`. `ddpm.rs` reuses the same state machinery for
//!   generation.
//!
//! The inference-side counterpart is [`serve`]: a [`Server`] answers
//! batched classify requests over a BN-folded checkpoint
//! ([`crate::backend::fold`]) with no training state allocated at all.

pub mod checkpoint;
pub mod metrics;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod serve;

pub use metrics::TrainMetrics;
pub use native::{NativeTrainConfig, NativeTrainer};
pub use serve::{Answer, ClassifyRequest, ServeConfig, ServeError, ServeStats, Server};
#[cfg(feature = "pjrt")]
pub use pjrt::{run_with_state, Trainer};

use crate::schedule::DropScheduler;

/// Training-job configuration (the `ssprop train` CLI maps 1:1 onto this).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact base name, e.g. "resnet18_cifar10" (loads `_train`/`_eval`).
    pub artifact: String,
    /// Epochs to run.
    pub epochs: usize,
    /// Iterations per epoch (caps the synthetic dataset's epoch length).
    pub iters_per_epoch: usize,
    /// Learning rate fed to the AOT step's `lr` input.
    pub lr: f64,
    /// Drop-rate schedule driving the ssProp sparsity.
    pub scheduler: DropScheduler,
    /// Runtime Dropout rate (paper Table 6's "w/ Dropout" rows).
    pub dropout_rate: f64,
    /// Seed for data order and the step's RNG key input.
    pub seed: u64,
    /// Evaluate on the test split every N epochs (0 = only at the end).
    pub eval_every: usize,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl TrainConfig {
    /// Paper-default hyperparameters (Table 2 lr, bar-2-epoch scheduler)
    /// at the given scale.
    pub fn quick(artifact: &str, epochs: usize, iters_per_epoch: usize) -> TrainConfig {
        TrainConfig {
            artifact: artifact.to_string(),
            epochs,
            iters_per_epoch,
            lr: 2e-4, // paper Table 2
            scheduler: DropScheduler::paper_default(epochs, iters_per_epoch),
            dropout_rate: 0.0,
            seed: 0,
            eval_every: 0,
            verbose: false,
        }
    }
}
