//! Quickstart: train a small CNN with scheduled sparse back-propagation.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT-compiled `cnn4_cifar100` train/eval graphs, trains a few
//! epochs with the paper's bar-2-epoch scheduler at D*=0.8, and prints the
//! loss curve plus the FLOPs/energy ledger.

use anyhow::Result;
use ssprop::coordinator::{TrainConfig, Trainer};
use ssprop::energy::RTX_A5000;
use ssprop::runtime::Engine;
use ssprop::schedule::DropScheduler;

fn main() -> Result<()> {
    let engine = Engine::auto()?;

    let (epochs, ipe) = (4, 16);
    let cfg = TrainConfig {
        artifact: "cnn4_cifar100".into(),
        epochs,
        iters_per_epoch: ipe,
        lr: 2e-3,
        scheduler: DropScheduler::paper_default(epochs, ipe), // bar, 2-epoch, D*=0.8
        dropout_rate: 0.0,
        seed: 0,
        eval_every: 1,
        verbose: true,
    };

    println!("== ssProp quickstart: SimpleCNN-4 on synth-CIFAR-100 ==\n");
    let mut trainer = Trainer::new(&engine, cfg)?;
    let (test_loss, test_acc) = trainer.run()?;

    let m = &trainer.metrics;
    println!("\nfinal test loss {test_loss:.4}, acc {test_acc:.3}");
    println!("loss curve (every 8 iters): {:?}",
             m.losses.iter().step_by(8).map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("mean drop rate  {:.2} (bar scheduler alternates 0 / 0.8)", m.mean_drop_rate());
    println!("backward FLOPs  {:.3e} dense-equivalent -> {:.3e} actual ({:.1}% saved)",
             m.flops_dense, m.flops_actual, m.flops_saving() * 100.0);
    let saved = m.energy_saved(&RTX_A5000);
    println!("energy saved    {:.6} kWh / {:.4} gCO2e at A5000 scale", saved.kwh, saved.gco2e);
    Ok(())
}
