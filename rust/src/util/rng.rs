//! PCG64-DXSM pseudo-random generator + distributions (the `rand` crate is
//! not in the offline vendor set; DESIGN.md S10).
//!
//! Deterministic by construction: every dataset shard, shuffle and init in
//! the coordinator derives from explicit seeds, so experiments replay
//! bit-identically.

/// Permuted congruential generator (PCG64-DXSM variant, 128-bit state).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg {
    /// A generator seeded on (seed, stream) — distinct streams are
    /// independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Pcg { state: 0, inc: ((stream as u128) << 1) | 1 };
        r.state = r.state.wrapping_add(seed as u128).wrapping_mul(MUL).wrapping_add(r.inc);
        r.next_u64();
        r.next_u64();
        r
    }

    /// Derive an independent generator (for per-worker / per-epoch streams).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        // DXSM output permutation
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Next 32-bit output (high bits of [`Pcg::next_u64`]).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// A fresh (2,) u32 key for the AOT step's `key` input.
    pub fn jax_key(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(8, 1);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg::new(1, 2);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg::new(3, 4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(9, 0);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Pcg::new(11, 0);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
