//! Batching pipeline: per-epoch seeded shuffling, normalization, and a
//! prefetch thread with bounded-channel backpressure (the L3 "data plane").

use std::sync::mpsc;
use std::thread;

use super::{Label, Loss, Split, SynthDataset};
use crate::util::rng::Pcg;
use crate::util::shard::shard_ranges;

/// One ready-to-execute batch in the AOT step's layout.
#[derive(Debug, Clone)]
pub struct Batch {
    /// (batch, C, H, W) flattened.
    pub x: Vec<f32>,
    /// CE labels, i32 (empty when loss is BCE).
    pub y_class: Vec<i32>,
    /// BCE labels, f32 multi-hot (empty when loss is CE).
    pub y_multi: Vec<f32>,
    /// Number of examples in this batch.
    pub batch_size: usize,
}

impl Batch {
    /// Split into at most `parts` contiguous sub-batches, aligned with the
    /// parallel executor's shard boundaries ([`shard_ranges`] is the single
    /// source of truth for both). Non-divisible sizes are handled — shard
    /// sizes differ by at most one, never panicking — and `parts` beyond
    /// the batch size yields one shard per example. Examples keep their
    /// order, so concatenating the shards reproduces `self`.
    pub fn shard(&self, parts: usize) -> Vec<Batch> {
        let bt = self.batch_size;
        // per-example strides, robust to whichever label family is empty
        let nx = if bt == 0 { 0 } else { self.x.len() / bt };
        let nc = if bt == 0 { 0 } else { self.y_class.len() / bt };
        let nm = if bt == 0 { 0 } else { self.y_multi.len() / bt };
        shard_ranges(bt, parts)
            .into_iter()
            .map(|r| Batch {
                x: self.x[r.start * nx..r.end * nx].to_vec(),
                y_class: self.y_class[r.start * nc..r.end * nc].to_vec(),
                y_multi: self.y_multi[r.start * nm..r.end * nm].to_vec(),
                batch_size: r.end - r.start,
            })
            .collect()
    }
}

/// Deterministic batch loader. `normalize` applies per-dataset whitening
/// (mean/std estimated once from the first 64 training examples, mirroring
/// the paper's per-dataset normalization).
pub struct Loader {
    /// The procedural dataset batches are drawn from.
    pub ds: SynthDataset,
    /// Which split this loader serves.
    pub split: Split,
    /// Examples per batch.
    pub batch_size: usize,
    mean: f32,
    std: f32,
}

impl Loader {
    /// A loader over `split` of `ds`, estimating normalization stats once.
    pub fn new(ds: SynthDataset, split: Split, batch_size: usize) -> Loader {
        let (mean, std) = estimate_stats(&ds);
        Loader { ds, split, batch_size, mean, std }
    }

    /// Full batches per epoch: ⌊split len / batch size⌋. **The tail
    /// partial batch is dropped** — an epoch visits `len − len %
    /// batch_size` examples, matching the AOT step's fixed batch geometry.
    /// (The shuffled order changes per epoch, so over a run every example
    /// is still seen.) The native path can opt back in via
    /// [`Loader::tail_batch`]; sub-batch slicing likewise handles
    /// non-divisible sizes — see [`Batch::shard`].
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len(self.split) / self.batch_size
    }

    /// Size of the epoch-tail partial batch (`len % batch_size`; 0 when
    /// the split divides evenly).
    pub fn tail_len(&self) -> usize {
        self.ds.len(self.split) % self.batch_size
    }

    /// Batches per epoch counting the tail partial batch when one exists.
    pub fn batches_per_epoch_with_tail(&self) -> usize {
        self.batches_per_epoch() + usize::from(self.tail_len() > 0)
    }

    /// Materialize the epoch-tail batch — the last [`Loader::tail_len`]
    /// examples of `order` — or `None` when the split divides evenly.
    pub fn tail_batch(&self, order: &[usize]) -> Option<Batch> {
        let tail = self.tail_len();
        if tail == 0 {
            return None;
        }
        Some(self.batch_ids(&order[order.len() - tail..]))
    }

    /// Shuffled example order for `epoch` (bit-reproducible).
    pub fn epoch_order(&self, epoch: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.ds.len(self.split)).collect();
        let mut rng = Pcg::new(0x5EED ^ epoch as u64, 7);
        if self.split == Split::Train {
            rng.shuffle(&mut idx);
        }
        idx
    }

    /// Materialize batch `b` of `order` (normalized images + labels).
    pub fn batch(&self, order: &[usize], b: usize) -> Batch {
        let lo = b * self.batch_size;
        self.batch_ids(&order[lo..lo + self.batch_size])
    }

    /// Materialize the batch holding exactly `ids` (normalized images +
    /// labels); full batches and the epoch tail share this path.
    fn batch_ids(&self, ids: &[usize]) -> Batch {
        let n = self.ds.spec.channels * self.ds.spec.img * self.ds.spec.img;
        let mut x = Vec::with_capacity(ids.len() * n);
        let mut y_class = Vec::new();
        let mut y_multi = Vec::new();
        for &i in ids {
            let (img, label) = self.ds.example(self.split, i);
            x.extend(img.iter().map(|v| (v - self.mean) / self.std));
            match label {
                Label::Class(c) => y_class.push(c as i32),
                Label::Multi(bits) => y_multi.extend(bits),
            }
        }
        Batch { x, y_class, y_multi, batch_size: ids.len() }
    }

    /// Spawn a prefetch thread producing the epoch's full batches with
    /// bounded lookahead (backpressure: the channel holds at most `depth`
    /// batches). The epoch-tail partial batch is not part of the stream —
    /// callers that train it fetch it synchronously via
    /// [`Loader::tail_batch`].
    pub fn prefetch_epoch(&self, epoch: usize, depth: usize) -> mpsc::Receiver<Batch> {
        let (tx, rx) = mpsc::sync_channel(depth);
        let loader = Loader {
            ds: self.ds.clone(),
            split: self.split,
            batch_size: self.batch_size,
            mean: self.mean,
            std: self.std,
        };
        thread::spawn(move || {
            let order = loader.epoch_order(epoch);
            for b in 0..loader.batches_per_epoch() {
                let batch = loader.batch(&order, b);
                if tx.send(batch).is_err() {
                    return; // consumer dropped — stop generating
                }
            }
        });
        rx
    }

    /// Spawn a single run-long prefetch thread streaming every training
    /// batch of every epoch in order — up to `iters_per_epoch` full
    /// batches per epoch (capped by [`Loader::batches_per_epoch`]), then
    /// the epoch-tail partial batch when `include_tail` holds and one
    /// exists. This is the trainer's pipelined data plane: the next batch
    /// (including the tail's different geometry, which re-keys conv
    /// plans) materializes while the current step trains, and the next
    /// epoch's batches keep flowing while the trainer evaluates between
    /// epochs. Batches are built by the same [`Loader::epoch_order`] /
    /// [`Loader::batch`] / [`Loader::tail_batch`] calls the synchronous
    /// path makes, in the same order, so the stream's contents are
    /// bit-identical to non-pipelined loading by construction.
    pub fn prefetch_run(
        &self,
        epochs: usize,
        iters_per_epoch: usize,
        include_tail: bool,
        depth: usize,
    ) -> mpsc::Receiver<RunItem> {
        let (tx, rx) = mpsc::sync_channel(depth);
        let loader = Loader {
            ds: self.ds.clone(),
            split: self.split,
            batch_size: self.batch_size,
            mean: self.mean,
            std: self.std,
        };
        thread::spawn(move || {
            for epoch in 0..epochs {
                let order = loader.epoch_order(epoch);
                for b in 0..iters_per_epoch.min(loader.batches_per_epoch()) {
                    let item = RunItem { epoch, is_tail: false, batch: loader.batch(&order, b) };
                    if tx.send(item).is_err() {
                        return; // consumer dropped — stop generating
                    }
                }
                if include_tail {
                    if let Some(batch) = loader.tail_batch(&order) {
                        if tx.send(RunItem { epoch, is_tail: true, batch }).is_err() {
                            return;
                        }
                    }
                }
            }
        });
        rx
    }

    /// Loss family of the underlying dataset (CE or BCE).
    pub fn loss(&self) -> Loss {
        self.ds.spec.loss
    }
}

/// One item of the cross-epoch prefetch stream ([`Loader::prefetch_run`]).
#[derive(Debug, Clone)]
pub struct RunItem {
    /// The epoch this batch belongs to.
    pub epoch: usize,
    /// True for the epoch-tail partial batch (smaller geometry).
    pub is_tail: bool,
    /// The materialized batch.
    pub batch: Batch,
}

fn estimate_stats(ds: &SynthDataset) -> (f32, f32) {
    let mut vals = Vec::new();
    for i in 0..64.min(ds.spec.train_n) {
        vals.extend(ds.example(Split::Train, i).0);
    }
    let n = vals.len() as f32;
    let mean = vals.iter().sum::<f32>() / n;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    (mean, var.sqrt().max(1e-6))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;

    fn loader(name: &str, bs: usize) -> Loader {
        Loader::new(SynthDataset::new(spec(name).unwrap(), 1), Split::Train, bs)
    }

    #[test]
    fn batch_shapes_and_normalization() {
        let l = loader("cifar10", 8);
        let order = l.epoch_order(0);
        let b = l.batch(&order, 0);
        assert_eq!(b.x.len(), 8 * 3 * 32 * 32);
        assert_eq!(b.y_class.len(), 8);
        assert!(b.y_multi.is_empty());
        // normalized data roughly zero-mean unit-var
        let mean = b.x.iter().sum::<f32>() / b.x.len() as f32;
        assert!(mean.abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn epoch_orders_differ_but_replay() {
        let l = loader("mnist", 16);
        let o0 = l.epoch_order(0);
        let o1 = l.epoch_order(1);
        assert_ne!(o0, o1);
        assert_eq!(o0, l.epoch_order(0));
        let mut sorted = o0.clone();
        sorted.sort();
        assert_eq!(sorted, (0..l.ds.len(Split::Train)).collect::<Vec<_>>());
    }

    #[test]
    fn val_split_not_shuffled() {
        let l = Loader::new(SynthDataset::new(spec("mnist").unwrap(), 1), Split::Val, 16);
        assert_eq!(l.epoch_order(3), (0..512).collect::<Vec<_>>());
    }

    #[test]
    fn bce_batches_have_multi_labels() {
        let l = loader("celeba", 4);
        let order = l.epoch_order(0);
        let b = l.batch(&order, 0);
        assert_eq!(b.y_multi.len(), 4 * 40);
        assert!(b.y_class.is_empty());
    }

    #[test]
    fn batch_shards_cover_and_concatenate_back() {
        let l = loader("cifar10", 10); // 10 examples over 4 shards: 3,3,2,2
        let order = l.epoch_order(0);
        let b = l.batch(&order, 0);
        let shards = b.shard(4);
        assert_eq!(shards.iter().map(|s| s.batch_size).collect::<Vec<_>>(), vec![3, 3, 2, 2]);
        let x: Vec<f32> = shards.iter().flat_map(|s| s.x.clone()).collect();
        let y: Vec<i32> = shards.iter().flat_map(|s| s.y_class.clone()).collect();
        assert_eq!(x, b.x, "shards must concatenate back to the batch");
        assert_eq!(y, b.y_class);
        assert!(shards.iter().all(|s| s.y_multi.is_empty()));
    }

    #[test]
    fn batch_shard_handles_degenerate_part_counts() {
        let l = loader("mnist", 3);
        let b = l.batch(&l.epoch_order(1), 0);
        assert_eq!(b.shard(1).len(), 1);
        assert_eq!(b.shard(1)[0].x, b.x);
        // more parts than examples: one shard per example, none empty
        let per_example = b.shard(9);
        assert_eq!(per_example.len(), 3);
        assert!(per_example.iter().all(|s| s.batch_size == 1));
        // parts = 0 clamps to a single shard
        assert_eq!(b.shard(0).len(), 1);
    }

    #[test]
    fn bce_batches_shard_multi_labels() {
        let l = loader("celeba", 5);
        let b = l.batch(&l.epoch_order(0), 0);
        let shards = b.shard(2); // 3 + 2
        assert_eq!(shards[0].y_multi.len(), 3 * 40);
        assert_eq!(shards[1].y_multi.len(), 2 * 40);
        let cat: Vec<f32> = shards.iter().flat_map(|s| s.y_multi.clone()).collect();
        assert_eq!(cat, b.y_multi);
    }

    #[test]
    fn tail_batch_holds_the_leftover_examples() {
        // mnist train is 2048 examples; batch 30 leaves an 8-example tail
        let l = loader("mnist", 30);
        assert_eq!(l.batches_per_epoch(), 68);
        assert_eq!(l.tail_len(), 8);
        assert_eq!(l.batches_per_epoch_with_tail(), 69);
        let order = l.epoch_order(0);
        let tail = l.tail_batch(&order).expect("tail exists");
        assert_eq!(tail.batch_size, 8);
        assert_eq!(tail.y_class.len(), 8);
        // an evenly-dividing batch size has no tail
        let even = loader("mnist", 32);
        assert_eq!(even.tail_len(), 0);
        assert!(even.tail_batch(&even.epoch_order(0)).is_none());
        assert_eq!(even.batches_per_epoch_with_tail(), even.batches_per_epoch());
    }

    #[test]
    fn prefetch_stream_excludes_the_tail() {
        let l = loader("mnist", 30);
        let rx = l.prefetch_epoch(1, 2);
        let batches: Vec<Batch> = rx.iter().collect();
        assert_eq!(batches.len(), 68, "the stream carries full batches only");
        assert!(batches.iter().all(|b| b.batch_size == 30));
        // the tail examples are exactly the order's last tail_len entries,
        // disjoint from what the stream delivered
        let order = l.epoch_order(1);
        let tail = l.tail_batch(&order).unwrap();
        assert_eq!(tail.batch_size, 8);
        let streamed: Vec<f32> = batches.iter().flat_map(|b| b.x.clone()).collect();
        let sync: Vec<f32> = (0..68).flat_map(|b| l.batch(&order, b).x).collect();
        assert_eq!(streamed, sync, "stream matches the sync slices the tail excludes");
    }

    #[test]
    fn prefetch_run_streams_epochs_in_order_with_tails() {
        // mnist train is 2048 examples; batch 30 → 68 full batches + 8-tail
        let l = loader("mnist", 30);
        let items: Vec<RunItem> = l.prefetch_run(2, usize::MAX, true, 2).iter().collect();
        assert_eq!(items.len(), 2 * 69, "68 full + 1 tail per epoch");
        for epoch in 0..2 {
            let chunk = &items[epoch * 69..(epoch + 1) * 69];
            assert!(chunk.iter().all(|i| i.epoch == epoch));
            assert!(chunk[..68].iter().all(|i| !i.is_tail && i.batch.batch_size == 30));
            assert!(chunk[68].is_tail && chunk[68].batch.batch_size == 8);
            // bit-identical to the synchronous path, tail included
            let order = l.epoch_order(epoch);
            for (b, item) in chunk[..68].iter().enumerate() {
                let sync = l.batch(&order, b);
                assert_eq!(item.batch.x, sync.x);
                assert_eq!(item.batch.y_class, sync.y_class);
            }
            let tail = l.tail_batch(&order).unwrap();
            assert_eq!(chunk[68].batch.x, tail.x);
            assert_eq!(chunk[68].batch.y_class, tail.y_class);
        }
    }

    #[test]
    fn prefetch_run_respects_iter_cap_and_tail_opt_out() {
        let l = loader("mnist", 30);
        let capped: Vec<RunItem> = l.prefetch_run(2, 4, true, 2).iter().collect();
        assert_eq!(capped.len(), 2 * 5, "4 capped full batches + the tail per epoch");
        assert!(capped.iter().filter(|i| i.is_tail).count() == 2);
        let no_tail: Vec<RunItem> = l.prefetch_run(1, 4, false, 2).iter().collect();
        assert_eq!(no_tail.len(), 4);
        assert!(no_tail.iter().all(|i| !i.is_tail));
        // an evenly-dividing batch size never emits a tail item
        let even = loader("mnist", 32);
        let items: Vec<RunItem> = even.prefetch_run(1, usize::MAX, true, 2).iter().collect();
        assert_eq!(items.len(), 64);
        assert!(items.iter().all(|i| !i.is_tail));
    }

    #[test]
    fn prefetch_matches_sync_path() {
        let l = loader("mnist", 32);
        let rx = l.prefetch_epoch(2, 2);
        let order = l.epoch_order(2);
        let mut got = 0;
        for (b, batch) in rx.iter().enumerate() {
            let sync = l.batch(&order, b);
            assert_eq!(batch.x, sync.x);
            assert_eq!(batch.y_class, sync.y_class);
            got += 1;
        }
        assert_eq!(got, l.batches_per_epoch());
    }
}
