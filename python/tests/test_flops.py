"""FLOPs model (Eq. 6/7/8/10) — python mirror; exact parity with rust is
asserted by rust/tests (both sides compute the same closed forms)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def test_eq6_example():
    # hand-computed: Bt=2, Hout=Wout=4, Cin=3, K=3, Cout=8
    m, n = 2 * 4 * 4, 3 * 9
    assert ref.conv_bwd_flops(2, 3, 8, 3, 4, 4) == m * (4 * n + 1) * 8


def test_eq7_eq8_examples():
    assert ref.bn_bwd_flops(2, 8, 4, 4) == 12 * (2 * 4 * 4 * 8) + 10 * 8
    assert ref.dropout_bwd_flops(2, 8, 4, 4) == 2 * (2 * 4 * 4 * 8)


@settings(max_examples=50, deadline=None)
@given(bt=st.integers(1, 64), cin=st.integers(1, 64), cout=st.integers(2, 128),
       k=st.sampled_from([1, 3, 5, 7]), ho=st.integers(1, 32), d=st.floats(0.05, 0.95))
def test_sparse_flops_below_dense_above_lower_bound(bt, cin, cout, k, ho, d):
    dense = ref.conv_bwd_flops(bt, cin, cout, k, ho, ho)
    sparse = ref.conv_bwd_flops(bt, cin, cout, k, ho, ho, drop_rate=d, with_selection=True)
    lb = ref.drop_rate_lower_bound(cin, k)
    keep = max(1, round((1.0 - d) * cout))
    if d > lb and keep < cout and bt * ho * ho > 1:
        assert sparse < dense


def test_lower_bound_eq11():
    # paper: K>=3, Cin>=1 -> bound <= 1/37 ~ 2.70%
    assert abs(ref.drop_rate_lower_bound(1, 3) - 1 / 37) < 1e-12
    assert ref.drop_rate_lower_bound(1, 3) <= 0.027028
    # larger layers have an even smaller break-even rate
    assert ref.drop_rate_lower_bound(64, 3) < ref.drop_rate_lower_bound(1, 3)


def test_savings_at_paper_config():
    """80% drop on a typical conv saves ~80% of backward conv FLOPs."""
    dense = ref.conv_bwd_flops(128, 64, 128, 3, 16, 16)
    sparse = ref.conv_bwd_flops(128, 64, 128, 3, 16, 16, drop_rate=0.8, with_selection=True)
    assert 0.79 < 1.0 - sparse / dense < 0.81
