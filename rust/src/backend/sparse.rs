//! ssProp selection primitives + the compacted (true-sparse) backward:
//! channel importance (paper Fig. 1a "abs + spatial mean"), exact-k top-k
//! with deterministic tie-breaking, and the shrunk img2col GEMMs of
//! Sec. "Scheduled Sparse BP". Mirrors `ref.py::importance_ref`,
//! `topk_mask_ref`, `keep_k_from_drop_rate`, `sparse_bwd_compact_ref`.

use super::gemm::{gemm_into_tiled, nr_for, GemmPack, Kernel, Operand};
use super::im2col::{col2img, im2col};
use super::{Conv2d, ConvGrads};
use crate::flops::keep_channels;

/// Unnormalized channel importance: per-output-channel Σ|g| over `cfg`'s
/// (Bt, H, W). This is the shard-local partial the data-parallel executor
/// reduces across workers (in fixed shard order, so runs are
/// bit-reproducible) before dividing by the *global* batch volume. With a
/// single shard the reduction reproduces the serial
/// [`channel_importance`] accumulation bit-for-bit; across shards the
/// pre-summed partials re-associate the f32 additions, so importances —
/// and, for near-tied channels, the selection — can differ from serial by
/// float rounding (the determinism suite therefore pins cross-thread
/// agreement at a tolerance, not bitwise).
pub fn channel_abs_sums(cfg: &Conv2d, g: &[f32]) -> Vec<f32> {
    let hw = cfg.hout() * cfg.wout();
    assert_eq!(g.len(), cfg.bt * cfg.cout * hw, "gradient length");
    let mut imp = vec![0f32; cfg.cout];
    for b in 0..cfg.bt {
        for o in 0..cfg.cout {
            let plane = &g[(b * cfg.cout + o) * hw..][..hw];
            imp[o] += plane.iter().map(|v| v.abs()).sum::<f32>();
        }
    }
    imp
}

/// Fig. 1(a) channel mode: importance[o] = mean |g| over (Bt, H, W).
pub fn channel_importance(cfg: &Conv2d, g: &[f32]) -> Vec<f32> {
    let mut imp = channel_abs_sums(cfg, g);
    let denom = (cfg.bt * cfg.hout() * cfg.wout()) as f32;
    for v in &mut imp {
        *v /= denom;
    }
    imp
}

/// Indices of the `keep` largest importances, ascending. Ties break toward
/// the lower channel index (matching the stable argsort in the reference).
///
/// A NaN importance means the upstream gradients have diverged; comparing
/// NaN would silently collapse the sort order (and select different
/// channels per run/platform), so this fails loudly instead of training
/// on garbage selections.
pub fn topk_channels(imp: &[f32], keep: usize) -> Vec<usize> {
    if let Some(bad) = imp.iter().position(|v| v.is_nan()) {
        panic!("channel importance[{bad}] is NaN: upstream gradients diverged");
    }
    let keep = keep.min(imp.len());
    let mut order: Vec<usize> = (0..imp.len()).collect();
    order.sort_by(|&a, &b| imp[b].partial_cmp(&imp[a]).expect("not NaN").then(a.cmp(&b)));
    let mut kept = order[..keep].to_vec();
    kept.sort_unstable();
    kept
}

/// Selection for a drop rate: k = clamp(round((1−D)·Cout), 1, Cout)
/// channels by importance (shared rust/python semantics via
/// [`keep_channels`]).
pub fn select_channels(cfg: &Conv2d, g: &[f32], drop_rate: f64) -> Vec<usize> {
    let keep = keep_channels(cfg.cout, drop_rate);
    if keep == cfg.cout {
        return (0..cfg.cout).collect();
    }
    topk_channels(&channel_importance(cfg, g), keep)
}

/// Scratch buffers for [`sparse_bwd_with_cols`]: the compacted dW
/// accumulator (`dwk`, N × k'), the col-form dx (`dcols`, M × N), and the
/// GEMM pack panels. Earlier revisions also materialized a compacted
/// gradient (`gck`) and weight view (`cwk`); both are gone — the
/// sparsity-aware GEMM gathers kept channels straight from the NCHW
/// gradient and the OIHW weights while packing
/// ([`Operand::KeptChannels`] / [`Operand::KeptRows`]). Starts empty;
/// every call resizes in place, so steady-state use allocates nothing
/// (the workspace-reuse tests pin this).
#[derive(Debug, Clone, Default)]
pub struct SparseBwdWorkspace {
    pub(crate) dwk: Vec<f32>,
    pub(crate) dcols: Vec<f32>,
    pub(crate) pack: GemmPack,
}

impl SparseBwdWorkspace {
    /// Capacity of each buffer (dwk, dcols, packed A, packed B).
    pub fn caps(&self) -> [usize; 4] {
        let [pa, pb] = self.pack.caps();
        [self.dwk.capacity(), self.dcols.capacity(), pa, pb]
    }
}

/// Compacted img2col backward with static keep indices:
///   col[dY]' = channel-compacted col[dY]          (M × k')
///   dW'      = col_Xᵀ · col[dY]'                  (N × k')
///   col[dX]  = col[dY]' · col_W'ᵀ                 (M × N)
///   db'      = column sums of col[dY]'
/// Dropped channels receive exactly-zero dW/db rows. With
/// `keep_idx = 0..Cout` this is the exact dense backward (Eq. 3/4/5).
/// `need_dx = false` skips the col[dX] GEMM + col2img (dx comes back
/// empty). Allocates its columns and scratch fresh every call — the
/// planned hot path uses [`sparse_bwd_with_cols`] with borrowed buffers
/// instead, and the two are bit-identical.
pub fn sparse_bwd_compact(
    cfg: &Conv2d,
    x: &[f32],
    w: &[f32],
    g: &[f32],
    keep_idx: &[usize],
    need_dx: bool,
) -> ConvGrads {
    let cols = im2col(cfg, x); // (M, N)
    sparse_bwd_with_cols(cfg, &cols, w, g, keep_idx, need_dx, &mut SparseBwdWorkspace::default())
}

/// The workspace form of [`sparse_bwd_compact`]: consumes a prebuilt
/// column matrix (the forward's, on the fused path) and a borrowed
/// scratch, so the hot loop gathers no patches and allocates only the
/// returned gradients. Same FP operations in the same order as the
/// allocating wrapper — bit-identical results.
pub fn sparse_bwd_with_cols(
    cfg: &Conv2d,
    cols: &[f32],
    w: &[f32],
    g: &[f32],
    keep_idx: &[usize],
    need_dx: bool,
    ws: &mut SparseBwdWorkspace,
) -> ConvGrads {
    let (m, n, kp) = (cfg.m(), cfg.n(), keep_idx.len());
    let hw = cfg.hout() * cfg.wout();
    assert!((1..=cfg.cout).contains(&kp), "keep count out of range");
    assert_eq!(cols.len(), m * n, "column matrix length");
    assert_eq!(g.len(), cfg.out_len(), "gradient length");

    // col[dY]' is a *view*: the sparsity-aware GEMM gathers the kept
    // channels out of the NCHW gradient while packing, so dropped
    // channels are never read and nothing (M × k')-sized materializes.
    let gck = Operand::KeptChannels { g, keep: keep_idx, cout: cfg.cout, hw };

    // dW' = col_Xᵀ · col[dY]'  (N × k') — the output columns are the
    // kept channels, so the tile width follows the keep count: small
    // keep sets (high-sparsity steps) stay on the narrow tile, dense
    // steps take the wide one. Pure shape function; bits unaffected.
    let kernel = Kernel::active();
    gemm_into_tiled(
        n,
        m,
        kp,
        Operand::Transposed(cols),
        gck,
        &mut ws.dwk,
        &mut ws.pack,
        kernel,
        nr_for(kp),
    );
    // scatter into full (Cout, Cin, K, K)
    let mut dw = vec![0f32; cfg.w_len()];
    for (pos, &o) in keep_idx.iter().enumerate() {
        let dst = &mut dw[o * n..][..n];
        for (ni, d) in dst.iter_mut().enumerate() {
            *d = ws.dwk[ni * kp + pos];
        }
    }

    // col[dX] = col[dY]' · col_W'ᵀ — col_W' is not materialized either:
    // the rhs packs the kept rows of the OIHW weights directly.
    let dx = if need_dx {
        assert_eq!(w.len(), cfg.w_len(), "weight length");
        let cwk = Operand::KeptRows { data: w, keep: keep_idx };
        // output columns here are the dense patch width N, not the keep
        // set — the width heuristic sees the dense shape
        gemm_into_tiled(m, kp, n, gck, cwk, &mut ws.dcols, &mut ws.pack, kernel, nr_for(n));
        col2img(cfg, &ws.dcols)
    } else {
        Vec::new()
    };

    // db' — Σ g over (batch, pixel) per kept channel
    let mut db = vec![0f32; cfg.cout];
    for b in 0..cfg.bt {
        for &o in keep_idx {
            let plane = &g[(b * cfg.cout + o) * hw..][..hw];
            for &gv in plane {
                db[o] += gv;
            }
        }
    }

    ConvGrads { dx, dw, db, keep_idx: keep_idx.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Conv2d {
        Conv2d { bt: 2, cin: 1, h: 4, w: 4, cout: 3, k: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn importance_is_abs_mean_per_channel() {
        let c = cfg();
        let hw = c.hout() * c.wout();
        let mut g = vec![0f32; c.out_len()];
        // channel 1 gets |v| = 2 everywhere in batch 0 only -> mean 1.0
        for v in &mut g[hw..2 * hw] {
            *v = -2.0;
        }
        let imp = channel_importance(&c, &g);
        assert_eq!(imp, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn abs_sums_are_unnormalized_importance() {
        let c = cfg();
        let g: Vec<f32> = (0..c.out_len()).map(|i| (i % 9) as f32 - 4.0).collect();
        let sums = channel_abs_sums(&c, &g);
        let imp = channel_importance(&c, &g);
        let denom = (c.bt * c.hout() * c.wout()) as f32;
        for (s, i) in sums.iter().zip(&imp) {
            assert_eq!(s / denom, *i);
        }
    }

    #[test]
    fn topk_stable_under_ties() {
        assert_eq!(topk_channels(&[0.5, 0.5, 0.5, 0.5], 2), vec![0, 1]);
        assert_eq!(topk_channels(&[0.1, 0.9, 0.3, 0.9], 2), vec![1, 3]);
        assert_eq!(topk_channels(&[0.1, 0.9, 0.3], 5), vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "is NaN")]
    fn nan_importance_fails_loudly() {
        // regression: partial_cmp(..).unwrap_or(Equal) used to let a NaN
        // importance scramble the sort order silently
        topk_channels(&[0.5, f32::NAN, 0.1], 2);
    }

    #[test]
    #[should_panic(expected = "is NaN")]
    fn select_channels_rejects_nan_gradient() {
        let c = cfg();
        let mut g = vec![1.0f32; c.out_len()];
        g[0] = f32::NAN;
        select_channels(&c, &g, 0.5);
    }

    #[test]
    fn select_channels_keeps_clamped_count() {
        let c = cfg();
        let g = vec![1.0f32; c.out_len()];
        assert_eq!(select_channels(&c, &g, 0.0).len(), 3);
        assert_eq!(select_channels(&c, &g, 0.5).len(), 2); // round(1.5) = 2
        assert_eq!(select_channels(&c, &g, 0.99).len(), 1); // clamp to 1
    }

    #[test]
    fn with_cols_matches_allocating_wrapper_bitwise() {
        let c = cfg();
        let x: Vec<f32> = (0..c.in_len()).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..c.w_len()).map(|i| (i % 5) as f32 * 0.1).collect();
        let g: Vec<f32> = (0..c.out_len()).map(|i| (i % 11) as f32 - 5.0).collect();
        let cols = im2col(&c, &x);
        let mut ws = SparseBwdWorkspace::default();
        for keep in [vec![0, 1, 2], vec![1], vec![0, 2]] {
            let a = sparse_bwd_compact(&c, &x, &w, &g, &keep, true);
            let b = sparse_bwd_with_cols(&c, &cols, &w, &g, &keep, true, &mut ws);
            assert_eq!(a.dx, b.dx, "keep {keep:?}");
            assert_eq!(a.dw, b.dw, "keep {keep:?}");
            assert_eq!(a.db, b.db, "keep {keep:?}");
        }
        // a repeat call must not grow the scratch
        let caps = ws.caps();
        sparse_bwd_with_cols(&c, &cols, &w, &g, &[1], true, &mut ws);
        assert_eq!(ws.caps(), caps, "workspace must be reused, not regrown");
    }

    #[test]
    fn dropped_channels_get_zero_dw_db() {
        let c = cfg();
        let x: Vec<f32> = (0..c.in_len()).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..c.w_len()).map(|i| (i % 5) as f32 * 0.1).collect();
        let g: Vec<f32> = (0..c.out_len()).map(|i| (i % 11) as f32 - 5.0).collect();
        let out = sparse_bwd_compact(&c, &x, &w, &g, &[1], true);
        let n = c.n();
        assert!(out.dw[..n].iter().all(|&v| v == 0.0), "channel 0 dw must be zero");
        assert!(out.dw[2 * n..].iter().all(|&v| v == 0.0), "channel 2 dw must be zero");
        assert!(out.dw[n..2 * n].iter().any(|&v| v != 0.0), "kept channel dw nonzero");
        assert_eq!(out.db[0], 0.0);
        assert_eq!(out.db[2], 0.0);
        assert_ne!(out.db[1], 0.0);
    }
}
