//! Drop-rate schedulers — the "scheduled" in ssProp (paper Fig. 2c/2d).
//!
//! The L3 coordinator evaluates the schedule each iteration and feeds the
//! resulting drop rate to the AOT train step's runtime `drop_rate` input
//! (and routes to the compacted executable when one exists for that rate).
//!
//! Shapes (target rate D*, training horizon T iterations):
//!   * Constant:   d(t) = D*                       (paper's baseline mode)
//!   * Linear:     d(t) = D* · t/T
//!   * Cosine:     d(t) = D* · (1 − cos(π·t/T))/2  (ramps 0 → D*)
//!   * Bar:        d(t) = 0 for t < T/2, else D*   (step function)
//!   * IterPeriodic{period}: bar wave with the given period in iterations
//!     (Fig. 2d sweeps 30..300)
//!   * EpochBar{period_epochs}: the paper's deployed schedule — alternate
//!     dense / D* epochs (period 2 ⇒ epochs 1,3,5,… dense; 2,4,6,… at D*).

/// Drop-rate schedule shape (see module docs for the formulas).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// d(t) = D* for every iteration.
    Constant,
    /// Linear ramp 0 → D* over the horizon.
    Linear,
    /// Cosine ramp 0 → D* over the horizon.
    Cosine,
    /// Dense first half, D* second half.
    Bar,
    /// Bar wave alternating every `period` iterations (Fig. 2d).
    IterPeriodic {
        /// Half-period in iterations.
        period: usize,
    },
    /// The paper's deployed schedule: alternate dense / D* epochs.
    EpochBar {
        /// Full period in epochs (2 ⇒ dense, D*, dense, D*, …).
        period_epochs: usize,
    },
    /// Paper §Conclusion future work (1): dense warm-up for the first
    /// `warmup_epochs`, then the paper's 2-epoch bar at the target rate.
    WarmupBar {
        /// Dense epochs before the bar starts.
        warmup_epochs: usize,
        /// Bar period in epochs after warm-up.
        period_epochs: usize,
    },
}

impl Schedule {
    /// Parse a CLI schedule name (+ its `--period` argument, where used).
    pub fn parse(name: &str, period: usize) -> Option<Schedule> {
        Some(match name {
            "constant" => Schedule::Constant,
            "linear" => Schedule::Linear,
            "cosine" => Schedule::Cosine,
            "bar" => Schedule::Bar,
            "iter-bar" | "iter_periodic" => Schedule::IterPeriodic { period: period.max(1) },
            "epoch-bar" | "bar2" => Schedule::EpochBar { period_epochs: period.max(2) },
            "warmup-bar" => Schedule::WarmupBar { warmup_epochs: period.max(1), period_epochs: 2 },
            _ => return None,
        })
    }
}

/// A fully-specified drop scheduler over a training horizon.
#[derive(Debug, Clone, Copy)]
pub struct DropScheduler {
    /// Schedule shape.
    pub schedule: Schedule,
    /// Target (maximum) drop rate D* in [0, 1).
    pub target: f64,
    /// Training horizon, epochs.
    pub total_epochs: usize,
    /// Training horizon, iterations per epoch.
    pub iters_per_epoch: usize,
}

impl DropScheduler {
    /// A scheduler over `total_epochs × iters_per_epoch` iterations
    /// (asserts `target` ∈ [0, 1) and a positive horizon).
    pub fn new(
        schedule: Schedule,
        target: f64,
        total_epochs: usize,
        iters_per_epoch: usize,
    ) -> Self {
        assert!((0.0..1.0).contains(&target), "target drop rate must be in [0,1)");
        assert!(total_epochs > 0 && iters_per_epoch > 0);
        DropScheduler { schedule, target, total_epochs, iters_per_epoch }
    }

    /// Paper's deployed configuration: bar scheduler, 2-epoch period, D*=0.8.
    pub fn paper_default(total_epochs: usize, iters_per_epoch: usize) -> Self {
        Self::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, total_epochs, iters_per_epoch)
    }

    /// Drop rate for global iteration `it` (0-based).
    pub fn rate_at(&self, it: usize) -> f64 {
        let total = self.total_epochs * self.iters_per_epoch;
        let it = it.min(total.saturating_sub(1));
        let frac = if total <= 1 { 1.0 } else { it as f64 / (total - 1) as f64 };
        match self.schedule {
            Schedule::Constant => self.target,
            Schedule::Linear => self.target * frac,
            Schedule::Cosine => self.target * 0.5 * (1.0 - (std::f64::consts::PI * frac).cos()),
            Schedule::Bar => {
                if frac < 0.5 {
                    0.0
                } else {
                    self.target
                }
            }
            Schedule::IterPeriodic { period } => {
                if (it / period) % 2 == 0 {
                    0.0
                } else {
                    self.target
                }
            }
            Schedule::EpochBar { period_epochs } => {
                let epoch = it / self.iters_per_epoch;
                let phase = epoch % period_epochs;
                // first half of each period dense, second half sparse
                if phase < period_epochs / 2 {
                    0.0
                } else {
                    self.target
                }
            }
            Schedule::WarmupBar { warmup_epochs, period_epochs } => {
                let epoch = it / self.iters_per_epoch;
                if epoch < warmup_epochs {
                    return 0.0;
                }
                let phase = (epoch - warmup_epochs) % period_epochs;
                if phase < period_epochs / 2 {
                    0.0
                } else {
                    self.target
                }
            }
        }
    }

    /// All per-iteration rates (for FLOPs accounting over a whole run).
    pub fn rates(&self) -> Vec<f64> {
        (0..self.total_epochs * self.iters_per_epoch).map(|it| self.rate_at(it)).collect()
    }

    /// Time-averaged drop rate over the run.
    pub fn mean_rate(&self) -> f64 {
        let r = self.rates();
        r.iter().sum::<f64>() / r.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_no_shrink, DEFAULT_CASES};
    use crate::util::rng::Pcg;

    fn sched(s: Schedule) -> DropScheduler {
        DropScheduler::new(s, 0.8, 10, 100)
    }

    #[test]
    fn epoch_bar_alternates_dense_sparse() {
        let d = DropScheduler::paper_default(6, 10);
        for it in 0..60 {
            let epoch = it / 10;
            let expect = if epoch % 2 == 0 { 0.0 } else { 0.8 };
            assert_eq!(d.rate_at(it), expect, "iter {it}");
        }
        // mean is exactly target/2 -> the paper's ~40% average saving
        assert!((d.mean_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn linear_and_cosine_ramp_from_zero_to_target() {
        for s in [Schedule::Linear, Schedule::Cosine] {
            let d = sched(s);
            assert_eq!(d.rate_at(0), 0.0);
            assert!((d.rate_at(999) - 0.8).abs() < 1e-9);
        }
    }

    #[test]
    fn bar_is_a_step_at_half() {
        let d = sched(Schedule::Bar);
        assert_eq!(d.rate_at(0), 0.0);
        assert_eq!(d.rate_at(498), 0.0);
        assert_eq!(d.rate_at(501), 0.8);
        assert_eq!(d.rate_at(999), 0.8);
    }

    #[test]
    fn iter_periodic_wave() {
        let d = DropScheduler::new(Schedule::IterPeriodic { period: 30 }, 0.5, 2, 300);
        assert_eq!(d.rate_at(0), 0.0);
        assert_eq!(d.rate_at(29), 0.0);
        assert_eq!(d.rate_at(30), 0.5);
        assert_eq!(d.rate_at(59), 0.5);
        assert_eq!(d.rate_at(60), 0.0);
    }

    #[test]
    fn warmup_bar_is_dense_through_warmup_then_bars() {
        let d = DropScheduler::new(
            Schedule::WarmupBar { warmup_epochs: 3, period_epochs: 2 },
            0.8,
            9,
            10,
        );
        for it in 0..30 {
            assert_eq!(d.rate_at(it), 0.0, "warm-up iter {it}");
        }
        // epochs 3,5,7 dense; 4,6,8 sparse
        assert_eq!(d.rate_at(30), 0.0);
        assert_eq!(d.rate_at(40), 0.8);
        assert_eq!(d.rate_at(50), 0.0);
        assert_eq!(d.rate_at(60), 0.8);
        // mean drop sits below the plain bar's target/2 because of warm-up
        let plain = DropScheduler::paper_default(9, 10);
        assert!(d.mean_rate() < plain.mean_rate());
    }

    #[test]
    fn warmup_bar_parses() {
        assert_eq!(
            Schedule::parse("warmup-bar", 5),
            Some(Schedule::WarmupBar { warmup_epochs: 5, period_epochs: 2 })
        );
    }

    #[test]
    fn paper_default_curve_hits_target_mean_rate() {
        // the deployed 2-epoch bar at D*=0.8 averages to D*/2 = 0.4 over any
        // even number of epochs — the paper's ~40% backward-FLOPs headline
        for epochs in [2usize, 6, 10, 50] {
            let d = DropScheduler::paper_default(epochs, 37);
            assert!((d.mean_rate() - 0.4).abs() < 1e-12, "epochs {epochs}");
        }
        // odd horizons end on a dense epoch, pulling the mean below D*/2
        let odd = DropScheduler::paper_default(5, 10);
        assert!(odd.mean_rate() < 0.4);
        assert!((odd.mean_rate() - 0.8 * 2.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn constant_is_flat_across_the_horizon() {
        let d = sched(Schedule::Constant);
        for it in [0usize, 1, 499, 999, 5000] {
            assert_eq!(d.rate_at(it), 0.8);
        }
    }

    // -- property tests (S13 mini-framework) ---------------------------------

    #[test]
    fn prop_rates_always_bounded() {
        check_no_shrink(
            "rates-in-[0,target]",
            DEFAULT_CASES,
            |r: &mut Pcg| {
                let schedules = [
                    Schedule::Constant,
                    Schedule::Linear,
                    Schedule::Cosine,
                    Schedule::Bar,
                    Schedule::IterPeriodic { period: 1 + r.below(100) as usize },
                    Schedule::EpochBar { period_epochs: 2 + r.below(4) as usize },
                    Schedule::WarmupBar {
                        warmup_epochs: r.below(5) as usize,
                        period_epochs: 2 + r.below(4) as usize,
                    },
                ];
                let s = schedules[r.below(7) as usize];
                let target = r.uniform() as f64 * 0.99;
                let epochs = 1 + r.below(20) as usize;
                let ipe = 1 + r.below(200) as usize;
                let it = r.below((epochs * ipe) as u64 * 2) as usize;
                (s, target, epochs, ipe, it)
            },
            |&(s, target, epochs, ipe, it)| {
                let d = DropScheduler::new(s, target, epochs, ipe);
                let r = d.rate_at(it);
                (0.0..=target + 1e-12).contains(&r)
            },
        );
    }

    #[test]
    fn prop_linear_monotone_nondecreasing() {
        check_no_shrink(
            "linear-monotone",
            DEFAULT_CASES,
            |r: &mut Pcg| {
                let epochs = 1 + r.below(10) as usize;
                let ipe = 2 + r.below(100) as usize;
                let it = r.below((epochs * ipe - 1) as u64) as usize;
                (epochs, ipe, it)
            },
            |&(epochs, ipe, it)| {
                let d = DropScheduler::new(Schedule::Linear, 0.9, epochs, ipe);
                d.rate_at(it) <= d.rate_at(it + 1) + 1e-12
            },
        );
    }

    #[test]
    fn prop_cosine_monotone_nondecreasing() {
        check_no_shrink(
            "cosine-monotone",
            DEFAULT_CASES,
            |r: &mut Pcg| {
                let epochs = 1 + r.below(10) as usize;
                let ipe = 2 + r.below(100) as usize;
                let it = r.below((epochs * ipe - 1) as u64) as usize;
                (epochs, ipe, it)
            },
            |&(epochs, ipe, it)| {
                let d = DropScheduler::new(Schedule::Cosine, 0.9, epochs, ipe);
                d.rate_at(it) <= d.rate_at(it + 1) + 1e-12
            },
        );
    }

    #[test]
    fn prop_epoch_bar_mean_is_half_target_for_even_epochs() {
        check_no_shrink(
            "epoch-bar-mean",
            64,
            |r: &mut Pcg| {
                let epochs = 2 * (1 + r.below(10) as usize);
                let ipe = 1 + r.below(50) as usize;
                let target = 0.05 + 0.9 * r.uniform() as f64;
                (epochs, ipe, target)
            },
            |&(epochs, ipe, target)| {
                let d = DropScheduler::new(
                    Schedule::EpochBar { period_epochs: 2 },
                    target,
                    epochs,
                    ipe,
                );
                (d.mean_rate() - target / 2.0).abs() < 1e-9
            },
        );
    }

    #[test]
    fn prop_rate_constant_within_epoch_for_epoch_bar() {
        check_no_shrink(
            "epoch-bar-constant-within-epoch",
            DEFAULT_CASES,
            |r: &mut Pcg| {
                let ipe = 2 + r.below(100) as usize;
                let epochs = 2 + r.below(10) as usize;
                let e = r.below(epochs as u64) as usize;
                let i1 = r.below(ipe as u64) as usize;
                let i2 = r.below(ipe as u64) as usize;
                (epochs, ipe, e, i1, i2)
            },
            |&(epochs, ipe, e, i1, i2)| {
                let d = DropScheduler::paper_default(epochs, ipe);
                d.rate_at(e * ipe + i1) == d.rate_at(e * ipe + i2)
            },
        );
    }
}
