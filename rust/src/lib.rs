//! # ssProp — energy-efficient CNN training with scheduled sparse back-prop
//!
//! Rust + JAX + Pallas reproduction of *"ssProp: Energy-Efficient Training
//! for Convolutional Neural Networks with Scheduled Sparse Back Propagation"*
//! (Zhong, Huang, Shi; 2024), as a three-layer AOT stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — img2col GEMMs,
//!   channel-importance reduction, compacted sparse backward.
//! * **L2** (`python/compile/`): JAX model zoo (SimpleCNN, ResNet-18/26/50,
//!   DDPM UNet) built on the ssProp `custom_vjp` convolution; AOT-lowered
//!   once to HLO text.
//! * **L3** (this crate): the coordinator — drop-rate schedulers, executable
//!   routing, synthetic data plane, FLOPs/energy accounting, metrics,
//!   checkpoints, experiment harness. Python never runs at L3.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record of every table and figure.

pub mod coordinator;
pub mod data;
pub mod ddpm;
pub mod energy;
pub mod experiments;
pub mod flops;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod tensorstore;
pub mod util;
