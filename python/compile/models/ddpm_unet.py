"""Tiny DDPM UNet (Ho et al. 2020) with ssProp convolutions.

Matches the paper's generation setup structurally: GroupNorm (excluded from
the FLOPs accounting, as the paper does), sinusoidal time embedding, residual
blocks with time injection, one down/up level pair plus a middle block. Every
convolution is an ssProp conv, so Table 5's sparse DDPM training runs through
the identical selection path as classification.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import common as cm


def time_embedding(t, dim: int):
    """Sinusoidal embedding of integer timesteps t (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class UNet:
    def __init__(self, *, in_ch: int, img: int, base: int = 16,
                 mode: str = "channel", select: str = "topk"):
        self.in_ch, self.img, self.base = in_ch, img, base
        self.mode, self.select = mode, select
        self.tdim = base * 4
        c1, c2 = base, base * 2
        self.c1, self.c2 = c1, c2
        h2 = img // 2
        # (name, cin, cout, k, s, p, h) — mirrors inventory
        self.plan = [
            ("stem",      in_ch, c1, 3, 1, 1, img),
            ("d1.conv1",  c1, c1, 3, 1, 1, img),
            ("d1.conv2",  c1, c1, 3, 1, 1, img),
            ("down",      c1, c2, 3, 2, 1, img),
            ("d2.conv1",  c2, c2, 3, 1, 1, h2),
            ("d2.conv2",  c2, c2, 3, 1, 1, h2),
            ("mid.conv1", c2, c2, 3, 1, 1, h2),
            ("mid.conv2", c2, c2, 3, 1, 1, h2),
            ("up",        c2, c1, 3, 1, 1, img),          # after nearest x2
            ("u1.conv1",  c1 + c1, c1, 3, 1, 1, img),     # concat skip
            ("u1.conv2",  c1, c1, 3, 1, 1, img),
            ("out",       c1, in_ch, 3, 1, 1, img),
        ]
        self.res_blocks = ["d1", "d2", "mid", "u1"]

    def inventory(self) -> cm.Inventory:
        inv = cm.Inventory()
        for (_, cin, cout, k, s, p, h) in self.plan:
            inv.conv(cin, cout, k, s, p, h, h)
        return inv

    def init(self, key):
        params = {}
        keys = jax.random.split(key, len(self.plan) + 2 + 2 * len(self.res_blocks) + 2)
        ki = 0
        for (name, cin, cout, k, *_rest) in self.plan:
            params[name] = cm.init_conv(keys[ki], cin, cout, k); ki += 1
        # time MLP
        params["tmlp1"] = cm.init_dense(keys[ki], self.tdim, self.tdim); ki += 1
        params["tmlp2"] = cm.init_dense(keys[ki], self.tdim, self.tdim); ki += 1
        # per-res-block time projection + the two GroupNorms
        for rb in self.res_blocks:
            ch = self.c1 if rb in ("d1", "u1") else self.c2
            params[f"{rb}.tproj"] = cm.init_dense(keys[ki], self.tdim, ch); ki += 1
            params[f"{rb}.gn1"] = cm.init_gn(ch)
            params[f"{rb}.gn2"] = cm.init_gn(ch)
        params["out.gn"] = cm.init_gn(self.c1)
        return params, {}  # no BN state in the UNet (GroupNorm is stateless)

    def _res(self, params, rb, x, temb, drop_rate, key, li):
        ch = x.shape[1]
        h = cm.groupnorm(params[f"{rb}.gn1"], x)
        h = cm.silu(h)
        h = cm.conv(params[f"{rb}.conv1"], h, drop_rate, cm.fold_key(key, li),
                    stride=1, padding=1, mode=self.mode, select=self.select)
        h = h + cm.dense(params[f"{rb}.tproj"], temb)[:, :, None, None]
        h = cm.groupnorm(params[f"{rb}.gn2"], h)
        h = cm.silu(h)
        h = cm.conv(params[f"{rb}.conv2"], h, drop_rate, cm.fold_key(key, li + 1),
                    stride=1, padding=1, mode=self.mode, select=self.select)
        return x + h

    def apply(self, params, x, t, *, drop_rate, key):
        """eps prediction: x (B,C,H,W), t (B,) int32 -> (B,C,H,W)."""
        temb = time_embedding(t, self.tdim)
        temb = cm.dense(params["tmlp2"], cm.silu(cm.dense(params["tmlp1"], temb)))
        li = 0
        h0 = cm.conv(params["stem"], x, drop_rate, cm.fold_key(key, li), stride=1, padding=1,
                     mode=self.mode, select=self.select); li += 1
        h1 = self._res(params, "d1", h0, temb, drop_rate, key, li); li += 2
        hd = cm.conv(params["down"], h1, drop_rate, cm.fold_key(key, li), stride=2, padding=1,
                     mode=self.mode, select=self.select); li += 1
        h2 = self._res(params, "d2", hd, temb, drop_rate, key, li); li += 2
        hm = self._res(params, "mid", h2, temb, drop_rate, key, li); li += 2
        # upsample (nearest x2) + conv
        hu = jnp.repeat(jnp.repeat(hm, 2, axis=2), 2, axis=3)
        hu = cm.conv(params["up"], hu, drop_rate, cm.fold_key(key, li), stride=1, padding=1,
                     mode=self.mode, select=self.select); li += 1
        hc = jnp.concatenate([hu, h1], axis=1)
        hc = cm.conv(params["u1.conv1"], hc, drop_rate, cm.fold_key(key, li), stride=1, padding=1,
                     mode=self.mode, select=self.select); li += 1
        h3 = self._res_u1_tail(params, hc, temb, drop_rate, key, li); li += 1
        out = cm.groupnorm(params["out.gn"], h3)
        out = cm.silu(out)
        return cm.conv(params["out"], out, drop_rate, cm.fold_key(key, li), stride=1, padding=1,
                       mode=self.mode, select=self.select)

    def _res_u1_tail(self, params, x, temb, drop_rate, key, li):
        h = x + cm.dense(params["u1.tproj"], temb)[:, :, None, None]
        h = cm.groupnorm(params["u1.gn1"], h)
        h = cm.silu(h)
        h = cm.conv(params["u1.conv2"], h, drop_rate, cm.fold_key(key, li),
                    stride=1, padding=1, mode=self.mode, select=self.select)
        return cm.groupnorm(params["u1.gn2"], x + h)


def make_beta_schedule(timesteps: int, beta_start=1e-4, beta_end=0.02):
    """Linear beta schedule (Ho et al. 2020); exported to the manifest so the
    rust sampler (rust/src/ddpm.rs) uses bit-identical constants."""
    betas = jnp.linspace(beta_start, beta_end, timesteps, dtype=jnp.float32)
    alphas = 1.0 - betas
    abar = jnp.cumprod(alphas)
    return {"betas": betas, "alphas": alphas, "alpha_bar": abar}
