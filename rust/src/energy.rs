//! Energy / carbon model (S16) — turns the FLOPs accounting into the
//! paper's sustainability claim: backward-FLOPs → device-seconds → kWh →
//! gCO₂e, with device profiles for the paper's testbed (RTX A5000) and a
//! reference TPU target.
//!
//! The paper argues savings at the *R&D-phase* scale: many training runs
//! during hyperparameter search (Fig. 4). `rnd_phase_savings` models that.

/// Hardware profile for converting FLOPs to time and energy.
///
/// The model is deliberately simple — sustained throughput is
/// `peak_flops × utilization` (FLOP/s) and power draw is a constant
/// `watts` at load — because the paper's claim is *relative* (fraction of
/// backward compute removed), not an absolute power measurement.
///
/// # Examples
///
/// ```
/// use ssprop::energy::{estimate, DeviceProfile};
/// // 1 TFLOP/s peak at 50% sustained utilization → 5e11 FLOPs is one second
/// let dev = DeviceProfile { name: "toy", peak_flops: 1e12, utilization: 0.5, watts: 100.0 };
/// assert_eq!(estimate(5e11, &dev).seconds, 1.0);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    /// Human-readable device name.
    pub name: &'static str,
    /// Peak f32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Sustained fraction of peak achieved on conv workloads (0..=1,
    /// dimensionless).
    pub utilization: f64,
    /// Board power draw at load, watts (J/s).
    pub watts: f64,
}

/// The paper's testbed GPU.
pub const RTX_A5000: DeviceProfile = DeviceProfile {
    name: "RTX A5000",
    peak_flops: 27.8e12,
    utilization: 0.45,
    watts: 230.0,
};

/// TPU v4-ish single-core profile (for the §Hardware-Adaptation estimate).
pub const TPU_CORE: DeviceProfile = DeviceProfile {
    name: "TPU core (bf16 MXU)",
    peak_flops: 137.5e12,
    utilization: 0.55,
    watts: 170.0,
};

/// This CPU-PJRT testbed (rough single-socket estimate; used for scaled
/// wall-clock sanity checks, not headline numbers).
pub const CPU_TESTBED: DeviceProfile = DeviceProfile {
    name: "CPU (PJRT)",
    peak_flops: 3.0e11,
    utilization: 0.30,
    watts: 120.0,
};

/// Grid carbon intensity, gCO₂e per kWh (US average ~390).
pub const GRID_GCO2_PER_KWH: f64 = 390.0;

/// FLOPs converted to device-time, energy and carbon on one device.
///
/// Produced by [`estimate`]; every field is a pure function of the input
/// FLOPs and the [`DeviceProfile`], so reports are deterministic and safe
/// to commit as baseline artifacts (`BENCH_native.json`, gated by
/// `ssprop bench-check`). All fields scale linearly with `flops`.
///
/// # Examples
///
/// ```
/// use ssprop::energy::{estimate, RTX_A5000};
/// // one sustained device-second on the paper's testbed GPU
/// let r = estimate(RTX_A5000.peak_flops * RTX_A5000.utilization, &RTX_A5000);
/// assert!((r.seconds - 1.0).abs() < 1e-12);
/// assert!((r.joules() - RTX_A5000.watts).abs() < 1e-9); // 230 W × 1 s
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// The FLOPs this report accounts for.
    pub flops: f64,
    /// Device-seconds at sustained throughput
    /// (`flops / (peak_flops × utilization)`).
    pub seconds: f64,
    /// Energy at the device's load power, kWh (`seconds × watts / 3.6e6`).
    pub kwh: f64,
    /// Emissions at [`GRID_GCO2_PER_KWH`], grams CO₂-equivalent.
    pub gco2e: f64,
}

impl EnergyReport {
    /// The energy in joules (`kwh × 3.6e6`) — the per-iteration unit the
    /// committed bench ledger records, where kWh round to zero.
    ///
    /// # Examples
    ///
    /// ```
    /// use ssprop::energy::{estimate, RTX_A5000};
    /// let r = estimate(1e12, &RTX_A5000);
    /// assert_eq!(r.joules(), r.kwh * 3.6e6);
    /// ```
    pub fn joules(&self) -> f64 {
        self.kwh * 3.6e6
    }
}

/// Convert `flops` into time/energy/carbon on device `dev`.
///
/// # Examples
///
/// ```
/// use ssprop::energy::{estimate, RTX_A5000, TPU_CORE};
/// // the same FLOPs cost less energy on a more efficient device
/// assert!(estimate(1e15, &TPU_CORE).kwh < estimate(1e15, &RTX_A5000).kwh);
/// ```
pub fn estimate(flops: f64, dev: &DeviceProfile) -> EnergyReport {
    let seconds = flops / (dev.peak_flops * dev.utilization);
    let kwh = seconds * dev.watts / 3.6e6;
    EnergyReport { flops, seconds, kwh, gco2e: kwh * GRID_GCO2_PER_KWH }
}

/// R&D-phase savings: `runs` independent trainings (hyperparameter search),
/// each of `flops_per_run` backward FLOPs, trained with a schedule saving
/// `saving_frac` of backward compute.
///
/// Assumptions (paper Fig. 4): runs are independent and identically sized,
/// the schedule's saving fraction applies uniformly to every run's
/// backward pass (forward compute is unchanged by ssProp and excluded),
/// and the device profile is constant across the sweep — so total savings
/// are simply `runs × flops_per_run × saving_frac` routed through
/// [`estimate`].
///
/// # Examples
///
/// ```
/// use ssprop::energy::{rnd_phase_savings, RTX_A5000};
/// // a 100-run sweep saves 100× what one run saves
/// let one = rnd_phase_savings(1, 1e15, 0.4, &RTX_A5000);
/// let sweep = rnd_phase_savings(100, 1e15, 0.4, &RTX_A5000);
/// assert!((sweep.kwh / one.kwh - 100.0).abs() < 1e-9);
/// ```
pub fn rnd_phase_savings(
    runs: usize,
    flops_per_run: f64,
    saving_frac: f64,
    dev: &DeviceProfile,
) -> EnergyReport {
    estimate(runs as f64 * flops_per_run * saving_frac, dev)
}

/// Human-readable FLOPs (MFLOPs → PFLOPs autoscaling).
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e15 {
        format!("{:.2} PFLOPs", f / 1e15)
    } else if f >= 1e12 {
        format!("{:.2} TFLOPs", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2} GFLOPs", f / 1e9)
    } else {
        format!("{:.2} MFLOPs", f / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_scales_linearly_with_flops() {
        let a = estimate(1e12, &RTX_A5000);
        let b = estimate(2e12, &RTX_A5000);
        assert!((b.kwh / a.kwh - 2.0).abs() < 1e-9);
        assert!((b.gco2e / a.gco2e - 2.0).abs() < 1e-9);
    }

    #[test]
    fn paper_scale_sanity() {
        // Table 4 ImageNet ResNet-50: 17,064.82 quadrillion FLOPs total.
        let r = estimate(17_064.82e15, &RTX_A5000);
        // should be on the order of days of GPU time, not minutes or years
        assert!(r.seconds > 3600.0 * 24.0, "{}s", r.seconds);
        assert!(r.seconds < 3600.0 * 24.0 * 60.0, "{}s", r.seconds);
        assert!(r.kwh > 10.0 && r.kwh < 10_000.0);
    }

    #[test]
    fn savings_accumulate_over_rnd_runs() {
        let one = rnd_phase_savings(1, 1e15, 0.4, &RTX_A5000);
        let hundred = rnd_phase_savings(100, 1e15, 0.4, &RTX_A5000);
        assert!((hundred.gco2e / one.gco2e - 100.0).abs() < 1e-9);
    }

    #[test]
    fn tpu_more_efficient_than_cpu() {
        let flops = 1e15;
        assert!(estimate(flops, &TPU_CORE).kwh < estimate(flops, &CPU_TESTBED).kwh);
    }
}
