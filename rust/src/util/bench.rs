//! Micro-benchmark harness (criterion is not in the offline vendor set; S12).
//!
//! Warmup + timed iterations with median / MAD / throughput reporting, and a
//! machine-readable JSON row per benchmark appended to `results/bench.jsonl`
//! so EXPERIMENTS.md tables regenerate from raw data.

use std::io::Write as _;
use std::time::{Duration, Instant};

/// Robust statistics of one benchmark's timed iterations.
pub struct BenchResult {
    /// Benchmark name (`native/...` convention).
    pub name: String,
    /// Timed iterations actually run (budget-capped).
    pub iters: usize,
    /// Median iteration time, nanoseconds.
    pub median_ns: f64,
    /// Median absolute deviation, nanoseconds.
    pub mad_ns: f64,
    /// Mean iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// The median as a [`Duration`].
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

/// Run `f` repeatedly: `warmup` untimed passes then up to `iters` timed ones
/// (capped by `budget`). Returns robust statistics.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples_ns: Vec<f64> = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    summarize(name, samples_ns)
}

fn summarize(name: &str, mut ns: Vec<f64>) -> BenchResult {
    assert!(!ns.is_empty());
    ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile(&ns, 50.0);
    let mut dev: Vec<f64> = ns.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = percentile(&dev, 50.0);
    let mean = ns.iter().sum::<f64>() / ns.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: ns.len(),
        median_ns: median,
        mad_ns: mad,
        mean_ns: mean,
        min_ns: ns[0],
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = p / 100.0 * (sorted.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    let frac = pos - pos.floor();
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Human-readable nanoseconds (ns → µs → ms → s autoscaling).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a criterion-style line and append the JSON record.
pub fn report(r: &BenchResult) {
    println!(
        "{:<48} {:>12} ± {:<10} ({} iters, min {})",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.mad_ns),
        r.iters,
        fmt_ns(r.min_ns)
    );
    let _ = std::fs::create_dir_all("results");
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("results/bench.jsonl")
    {
        let _ = writeln!(
            f,
            "{{\"name\":\"{}\",\"median_ns\":{},\"mad_ns\":{},\"mean_ns\":{},\"iters\":{}}}",
            r.name, r.median_ns, r.mad_ns, r.mean_ns, r.iters
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_samples() {
        let r = summarize("t", vec![10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(r.median_ns, 30.0);
        assert_eq!(r.mad_ns, 10.0);
        assert_eq!(r.min_ns, 10.0);
        assert_eq!(r.mean_ns, 30.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn bench_runs_and_respects_budget() {
        let r = bench("sleepless", 1, 10_000, Duration::from_millis(50), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 1);
        assert!(r.median_ns < 1e7);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
