//! Data-parallel execution layer: shard each training batch over a fixed
//! worker count, run the fused plan path per shard, reduce gradients
//! deterministically.
//!
//! Design (see `docs/ARCHITECTURE.md` for the full write-up):
//!
//! * **Sharding.** The batch splits into contiguous sub-batches via
//!   [`shard_ranges`] (non-divisible sizes allowed — leading shards take
//!   the remainder). Each worker owns one [`Conv2dPlan`] per layer, forked
//!   from the model's plans with [`Conv2dPlan::for_batch`], so the hot
//!   path takes **no locks**: forward im2col columns are cached per worker
//!   and consumed by that worker's backward, exactly like the serial path.
//! * **Global selection.** ssProp's channel top-k is defined over the
//!   *whole* batch, so per-layer the workers publish unnormalized
//!   importance partials ([`channel_abs_sums`]), synchronize on a barrier,
//!   worker 0 reduces them in fixed shard order and broadcasts the keep
//!   set, and every shard runs the identical compacted backward
//!   ([`Backend::conv2d_bwd_planned_with`]). Dense layers (keep == Cout)
//!   skip the rendezvous entirely. This keeps parallel selection
//!   *semantically identical* to serial selection.
//! * **Deterministic reduction.** Weight/bias gradients reduce through a
//!   fixed-shape pairwise tree (`tree_reduce`) in shard-index order —
//!   never in thread-completion order — so repeated runs at the same
//!   thread count are bit-identical, and a single-worker run reproduces
//!   [`SimpleCnn::train_step`] exactly. Against other thread counts only
//!   float re-association differs (≪ 1e-5 on the loss trajectory; pinned
//!   by `rust/tests/determinism.rs`).
//!
//! Worker threads are scoped to each step (`std::thread::scope`), which
//! keeps the borrows safe without `unsafe`; the persistent state a "pool"
//! would carry — the per-worker plan workspaces — lives in the executor
//! and is reused across steps, so steady-state steps allocate only the
//! gradients themselves. A panicking worker (a backend invariant
//! violation) aborts the step *loudly*: every worker owes a fixed number
//! of rendezvous per step, and the `BarrierAttendance` guard pays any
//! outstanding ones during unwinding, so the surviving workers are never
//! left blocked on a barrier that cannot complete and the panic
//! propagates out of `thread::scope` instead of deadlocking training.

use std::sync::{Barrier, Mutex};

use anyhow::{bail, Result};

use super::plan::Conv2dPlan;
use super::simple_cnn::softmax_ce_core;
use super::sparse::{channel_abs_sums, topk_channels};
use super::{Backend, SimpleCnn, StepStats};
use crate::flops::keep_channels;
use crate::util::shard::shard_ranges;

/// Execution-layer knobs for [`ParallelExecutor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecConfig {
    /// Worker threads a batch is sharded over (≥ 1; 1 = serial layout).
    pub threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { threads: 1 }
    }
}

impl ExecConfig {
    /// Config with `threads` workers (clamped to ≥ 1).
    pub fn with_threads(threads: usize) -> ExecConfig {
        ExecConfig { threads: threads.max(1) }
    }
}

/// Everything one shard worker hands back to the reducer.
#[derive(Debug, Default)]
struct ShardOut {
    /// Σ per-example losses over the shard (full-batch mean = Σ/Bt).
    loss_sum: f64,
    /// Correct predictions in the shard.
    correct: usize,
    /// Head gradients, already in full-batch (1/Bt) units.
    dfc_w: Vec<f32>,
    dfc_b: Vec<f32>,
    /// Per conv layer (dw, db), full-batch units.
    conv: Vec<(Vec<f32>, Vec<f32>)>,
    /// Kept channels summed over layers (filled by worker 0 only).
    kept: usize,
}

/// Unwind insurance for the barrier protocol. Every worker owes the same
/// fixed number of rendezvous per step (two per sparse layer); a worker
/// that panics mid-step would otherwise leave its peers blocked forever
/// on a `std::sync::Barrier` that cannot complete (std barriers have no
/// poisoning). The guard tracks the waits still owed and pays them during
/// unwinding, so peers proceed — at worst briefly computing on a stale or
/// empty keep set, whose validity asserts make *them* panic and drain the
/// same way — and the original panic then propagates out of
/// `std::thread::scope`, aborting the step instead of deadlocking it.
struct BarrierAttendance<'a> {
    barrier: &'a Barrier,
    remaining: std::cell::Cell<usize>,
}

impl<'a> BarrierAttendance<'a> {
    fn new(barrier: &'a Barrier, total: usize) -> BarrierAttendance<'a> {
        BarrierAttendance { barrier, remaining: std::cell::Cell::new(total) }
    }

    /// Attend one rendezvous and mark it paid.
    fn wait(&self) {
        self.barrier.wait();
        self.remaining.set(self.remaining.get() - 1);
    }
}

impl Drop for BarrierAttendance<'_> {
    fn drop(&mut self) {
        for _ in 0..self.remaining.get() {
            self.barrier.wait();
        }
    }
}

/// Deterministic pairwise tree reduction: parts are summed elementwise in
/// a fixed index-ordered binary tree — (0+1)+(2+3)… — so the result
/// depends only on the part order, never on thread timing. A single part
/// passes through bitwise untouched.
fn tree_reduce(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (av, bv) in a.iter_mut().zip(&b) {
                    *av += bv;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap_or_default()
}

/// Reduce per-worker importance partials in fixed shard order, normalize
/// by the *global* batch volume, and select the top-k channels — the
/// cross-shard equivalent of [`super::sparse::select_channels`] (bitwise
/// so for a single shard).
fn reduce_select(
    imp_slots: &[Mutex<Vec<f32>>],
    bt: usize,
    hw: usize,
    cout: usize,
    keep: usize,
) -> Vec<usize> {
    let mut imp = vec![0f32; cout];
    for slot in imp_slots {
        let part = slot.lock().expect("importance slot poisoned");
        for (tot, &v) in imp.iter_mut().zip(part.iter()) {
            *tot += v;
        }
    }
    let denom = (bt * hw) as f32;
    for v in &mut imp {
        *v /= denom;
    }
    topk_channels(&imp, keep)
}

/// Data-parallel trainer over a [`SimpleCnn`]: owns the per-worker plan
/// workspaces and runs [`ParallelExecutor::train_step`] as described in
/// the module docs. Construct once and reuse — worker plans keep their
/// buffer capacity across steps (and re-key in place when the batch size
/// or shard sizes change, mirroring [`SimpleCnn::ensure_plans`]).
#[derive(Debug)]
pub struct ParallelExecutor {
    cfg: ExecConfig,
    /// `worker_plans[w][l]`: worker w's plan for conv layer l.
    worker_plans: Vec<Vec<Conv2dPlan>>,
}

impl ParallelExecutor {
    /// An executor with no allocated workspaces yet (they grow on first
    /// step and are reused afterwards).
    pub fn new(cfg: ExecConfig) -> ParallelExecutor {
        ParallelExecutor { cfg, worker_plans: Vec::new() }
    }

    /// Configured worker count (shards per step; capped by the batch size
    /// at step time).
    pub fn threads(&self) -> usize {
        self.cfg.threads
    }

    /// Total im2col materializations across all worker plans — advances by
    /// `depth × workers` per step when the fused path is healthy (each
    /// worker builds each layer's columns once, in its forward).
    pub fn plan_cols_builds(&self) -> u64 {
        self.worker_plans.iter().flatten().map(|p| p.cols_builds()).sum()
    }

    /// Key the per-worker plans to the given shard sizes, forking from the
    /// model's (already ensured) full-batch plans. Capacity is preserved
    /// on re-key, so steady-state steps allocate nothing here.
    fn ensure_worker_plans(&mut self, model: &SimpleCnn, shards: &[std::ops::Range<usize>]) {
        let depth = model.cfg.depth;
        if self.worker_plans.len() != shards.len() {
            self.worker_plans.resize_with(shards.len(), Vec::new);
        }
        for (wp, r) in self.worker_plans.iter_mut().zip(shards) {
            let sbt = r.end - r.start;
            wp.truncate(depth);
            for (l, mp) in model.plans().iter().enumerate() {
                if l < wp.len() {
                    wp[l].ensure(mp.cfg().with_batch(sbt));
                } else {
                    wp.push(mp.for_batch(sbt));
                }
            }
        }
    }

    /// One data-parallel SGD training step at `drop_rate`; the parallel
    /// counterpart of [`SimpleCnn::train_step`] with identical semantics:
    /// same loss/accuracy, same global channel selection, gradients equal
    /// up to float re-association (bit-identical with one worker, and
    /// bit-identical run-to-run at any fixed worker count).
    pub fn train_step(
        &mut self,
        model: &mut SimpleCnn,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        let n_in = model.cfg.in_ch * model.cfg.img * model.cfg.img;
        if bt == 0 || x.len() != bt * n_in {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        let depth = model.cfg.depth;
        let shards = shard_ranges(bt, self.cfg.threads);
        let nw = shards.len();
        model.ensure_plans(bt);
        self.ensure_worker_plans(model, &shards);

        let mut outs: Vec<ShardOut> = (0..nw).map(|_| ShardOut::default()).collect();
        let barrier = Barrier::new(nw);
        let imp_slots: Vec<Mutex<Vec<f32>>> = (0..nw).map(|_| Mutex::new(Vec::new())).collect();
        let keep_slot: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let m: &SimpleCnn = model;

        std::thread::scope(|s| {
            let worker_iter = shards.iter().zip(self.worker_plans.iter_mut()).zip(outs.iter_mut());
            for (w, ((range, plans), out)) in worker_iter.enumerate() {
                let (barrier, imp_slots, keep_slot) = (&barrier, &imp_slots, &keep_slot);
                let range = range.clone();
                s.spawn(move || {
                    let sbt = range.end - range.start;
                    let xs = &x[range.start * n_in..range.end * n_in];
                    let ys = &y[range.start..range.end];

                    // Fixed rendezvous budget (two per sparse layer); the
                    // guard pays any outstanding waits if we unwind, so a
                    // panic here can never strand the other workers.
                    let sparse_layers = (0..depth)
                        .filter(|&l| {
                            let c = m.conv_cfg(l, sbt);
                            keep_channels(c.cout, drop_rate) < c.cout
                        })
                        .count();
                    let attendance = BarrierAttendance::new(barrier, 2 * sparse_layers);

                    // Shard-local forward + head/pool backward, all in
                    // full-batch gradient units (grad_denom = bt).
                    let (acts, zs, pooled, logits) = m.forward(backend, xs, sbt, plans);
                    let (loss_sum, correct, dlogits) =
                        softmax_ce_core(&logits, ys, m.cfg.classes, bt);
                    let (dfc_w, dfc_b, dpooled) = m.head_backward(&pooled, &dlogits, sbt);
                    let mut g = m.pool_backward(&dpooled, &zs[depth - 1], sbt);
                    out.loss_sum = loss_sum;
                    out.correct = correct;
                    out.dfc_w = dfc_w;
                    out.dfc_b = dfc_b;
                    out.conv = (0..depth).map(|_| (Vec::new(), Vec::new())).collect();

                    // Conv stack backward, top-down. Selection is global:
                    // publish importance partials, rendezvous, worker 0
                    // reduces + broadcasts; dense layers skip the sync.
                    for l in (0..depth).rev() {
                        let cfg = *plans[l].cfg();
                        let keep_count = keep_channels(cfg.cout, drop_rate);
                        let keep = if keep_count == cfg.cout {
                            (0..cfg.cout).collect::<Vec<_>>()
                        } else {
                            *imp_slots[w].lock().expect("importance slot poisoned") =
                                channel_abs_sums(&cfg, &g);
                            attendance.wait();
                            if w == 0 {
                                let hw = cfg.hout() * cfg.wout();
                                let sel = reduce_select(imp_slots, bt, hw, cfg.cout, keep_count);
                                *keep_slot.lock().expect("keep slot poisoned") = sel;
                            }
                            attendance.wait();
                            keep_slot.lock().expect("keep slot poisoned").clone()
                        };
                        if w == 0 {
                            out.kept += keep.len();
                        }
                        let grads = backend.conv2d_bwd_planned_with(
                            &mut plans[l],
                            &acts[l],
                            &m.convs[l].w,
                            &g,
                            &keep,
                            l > 0,
                        );
                        if l > 0 {
                            g = grads.dx;
                            for (gv, &zv) in g.iter_mut().zip(&zs[l - 1]) {
                                if zv <= 0.0 {
                                    *gv = 0.0;
                                }
                            }
                        }
                        out.conv[l] = (grads.dw, grads.db);
                    }
                });
            }
        });

        // Scalar reductions in fixed shard order.
        let (mut loss_sum, mut correct) = (0f64, 0usize);
        for o in &outs {
            loss_sum += o.loss_sum;
            correct += o.correct;
        }
        let loss = loss_sum / bt as f64;
        if !loss.is_finite() {
            bail!("non-finite loss at drop rate {drop_rate}");
        }
        let kept = outs[0].kept;

        // Gradient tree-reduction (fixed shard order) + SGD updates.
        let mut dfc_w_parts = Vec::with_capacity(nw);
        let mut dfc_b_parts = Vec::with_capacity(nw);
        let mut conv_dw: Vec<Vec<Vec<f32>>> = (0..depth).map(|_| Vec::with_capacity(nw)).collect();
        let mut conv_db: Vec<Vec<Vec<f32>>> = (0..depth).map(|_| Vec::with_capacity(nw)).collect();
        for o in outs {
            dfc_w_parts.push(o.dfc_w);
            dfc_b_parts.push(o.dfc_b);
            for (l, (dw, db)) in o.conv.into_iter().enumerate() {
                conv_dw[l].push(dw);
                conv_db[l].push(db);
            }
        }
        let dfc_w = tree_reduce(dfc_w_parts);
        let dfc_b = tree_reduce(dfc_b_parts);
        for (wv, &dv) in model.fc_w.iter_mut().zip(&dfc_w) {
            *wv -= lr * dv;
        }
        for (bv, &dv) in model.fc_b.iter_mut().zip(&dfc_b) {
            *bv -= lr * dv;
        }
        for (l, (dw_parts, db_parts)) in conv_dw.into_iter().zip(conv_db).enumerate() {
            let dw = tree_reduce(dw_parts);
            let db = tree_reduce(db_parts);
            for (wv, &dv) in model.convs[l].w.iter_mut().zip(&dw) {
                *wv -= lr * dv;
            }
            for (bv, &dv) in model.convs[l].b.iter_mut().zip(&db) {
                *bv -= lr * dv;
            }
        }

        Ok(StepStats {
            loss,
            acc: correct as f64 / bt as f64,
            kept_channels: kept,
            total_channels: depth * model.cfg.width,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, SimpleCnnCfg};
    use crate::util::rng::Pcg;

    fn tiny() -> SimpleCnn {
        SimpleCnn::new(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 })
    }

    fn batch(m: &SimpleCnn, bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg::new(seed, 1);
        let n = m.cfg.in_ch * m.cfg.img * m.cfg.img;
        let x = (0..bt * n).map(|_| rng.normal()).collect();
        let y = (0..bt).map(|i| (i % m.cfg.classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn tree_reduce_sums_in_any_part_count() {
        for nparts in 1..6 {
            let parts: Vec<Vec<f32>> = (0..nparts).map(|p| vec![p as f32, 1.0]).collect();
            let want: f32 = (0..nparts).map(|p| p as f32).sum();
            let got = tree_reduce(parts);
            assert_eq!(got[0], want, "{nparts} parts");
            assert_eq!(got[1], nparts as f32);
        }
        assert!(tree_reduce(Vec::new()).is_empty());
    }

    #[test]
    fn exec_config_clamps_threads() {
        assert_eq!(ExecConfig::with_threads(0).threads, 1);
        assert_eq!(ExecConfig::with_threads(3).threads, 3);
        assert_eq!(ExecConfig::default().threads, 1);
    }

    #[test]
    fn rejects_bad_geometry() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(2));
        assert!(exec.train_step(&mut m, &be, &[0.0; 3], &[0, 1], 0.0, 0.05).is_err());
        assert!(exec.train_step(&mut m, &be, &[], &[], 0.0, 0.05).is_err());
    }

    #[test]
    fn worker_plans_build_cols_once_per_layer_per_step() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(&m, 6, 13);
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(3));
        exec.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        let per_step = (m.cfg.depth * 3) as u64;
        assert_eq!(exec.plan_cols_builds(), per_step, "one build per layer per worker");
        exec.train_step(&mut m, &be, &x, &y, 0.5, 0.05).unwrap();
        assert_eq!(exec.plan_cols_builds(), 2 * per_step);
    }

    #[test]
    fn more_threads_than_examples_still_trains() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(&m, 2, 5);
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(8));
        let stats = exec.train_step(&mut m, &be, &x, &y, 0.8, 0.05).unwrap();
        assert!(stats.loss.is_finite());
        assert_eq!(stats.kept_channels, 2, "D=0.8 at width 4 keeps 1 channel per layer");
        assert_eq!(exec.worker_plans.len(), 2, "shards are capped at the batch size");
    }

    #[test]
    fn workspaces_rekey_across_batch_sizes() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(2));
        let (x8, y8) = batch(&m, 8, 3);
        let (x4, y4) = batch(&m, 4, 4);
        exec.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let caps: Vec<Vec<[usize; 7]>> = exec
            .worker_plans
            .iter()
            .map(|wp| wp.iter().map(|p| p.buffer_caps()).collect())
            .collect();
        exec.train_step(&mut m, &be, &x4, &y4, 0.0, 0.05).unwrap();
        exec.train_step(&mut m, &be, &x8, &y8, 0.0, 0.05).unwrap();
        let caps2: Vec<Vec<[usize; 7]>> = exec
            .worker_plans
            .iter()
            .map(|wp| wp.iter().map(|p| p.buffer_caps()).collect())
            .collect();
        assert_eq!(caps, caps2, "shrinking then regrowing the batch must reuse capacity");
    }
}
