"""AOT step builders — the compute graphs the rust coordinator executes.

Each builder returns ``(fn, example_args, arg_roles, out_roles)`` where
``fn`` is the jittable step function and the role lists drive the manifest
(rust maps output leaves back onto next-iteration inputs by name).

Runtime scalars (never baked into the graph): ``lr``, ``drop_rate``,
``dropout_rate``, the PRNG ``key``. This is what lets ONE executable serve
every point of Fig. 2's drop-rate sweep, Fig. 4's LR sweep, and every
scheduler the L3 coordinator implements.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import optim
from .models.ddpm_unet import UNet, make_beta_schedule

Role = str


def _ce_loss(logits, y):
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, acc


def _bce_loss(logits, y):
    yf = y.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * yf + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0).astype(jnp.float32) == yf).astype(jnp.float32))
    return loss, acc


def make_classify_steps(model, *, batch: int, loss: str, optimizer: str = "adam"):
    """Returns (train_fn, train_args, eval_fn, eval_args) + roles via attrs."""
    wd = optim.ADAMW_WD if optimizer == "adamw" else 0.0
    img, cin, classes = model.img, model.in_ch, model.classes
    x = jnp.zeros((batch, cin, img, img), jnp.float32)
    if loss == "ce":
        y = jnp.zeros((batch,), jnp.int32)
        loss_fn = _ce_loss
    elif loss == "bce":
        y = jnp.zeros((batch, classes), jnp.float32)
        loss_fn = _bce_loss
    else:
        raise ValueError(loss)

    params0, bn0 = model.init(jax.random.PRNGKey(0))
    opt0 = optim.init_opt_state(params0)
    scalars = (jnp.float32(0), jnp.float32(0), jnp.float32(0),
               jnp.zeros((2,), jnp.uint32))  # lr, drop_rate, dropout_rate, key

    def train_step(params, opt_state, bn_state, xb, yb, lr, drop_rate, dropout_rate, key):
        def lf(p):
            logits, new_bn = model.apply(p, bn_state, xb, train=True,
                                         drop_rate=drop_rate,
                                         dropout_rate=dropout_rate, key=key)
            l, a = loss_fn(logits, yb)
            return l, (new_bn, a)

        (l, (new_bn, a)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_opt = optim.adam_update(params, grads, opt_state, lr, weight_decay=wd)
        return new_p, new_opt, new_bn, l, a

    def eval_step(params, bn_state, xb, yb):
        logits, _ = model.apply(params, bn_state, xb, train=False,
                                drop_rate=jnp.float32(0), dropout_rate=jnp.float32(0),
                                key=jnp.zeros((2,), jnp.uint32))
        l, a = loss_fn(logits, yb)
        return l, a

    train_args = (params0, opt0, bn0, x, y) + scalars
    eval_args = (params0, bn0, x, y)
    train_roles = ["param", "opt", "bn", "data_x", "data_y", "lr", "drop_rate", "dropout_rate", "key"]
    train_out_roles = ["param", "opt", "bn", "loss", "acc"]
    eval_roles = ["param", "bn", "data_x", "data_y"]
    eval_out_roles = ["loss", "acc"]
    return dict(train=(train_step, train_args, train_roles, train_out_roles),
                eval=(eval_step, eval_args, eval_roles, eval_out_roles))


def make_ddpm_steps(unet: UNet, *, batch: int, timesteps: int):
    """DDPM training + denoise graphs (Table 5 / Fig. 3).

    train: samples t ~ U[0,T) and eps ~ N(0,1) from the runtime key,
           minimizes ||eps - eps_theta(x_t, t)||^2 (Ho et al. 2020, Alg. 1).
    denoise: eps prediction for the sampler loop (Alg. 2 runs in rust).
    """
    sched = make_beta_schedule(timesteps)
    abar = sched["alpha_bar"]
    img, cin = unet.img, unet.in_ch
    x0 = jnp.zeros((batch, cin, img, img), jnp.float32)
    params0, _ = unet.init(jax.random.PRNGKey(0))
    opt0 = optim.init_opt_state(params0)

    def train_step(params, opt_state, xb, lr, drop_rate, key):
        kt = jax.random.wrap_key_data(key, impl="threefry2x32")
        k1, k2 = jax.random.split(kt)
        t = jax.random.randint(k1, (batch,), 0, timesteps)
        eps = jax.random.normal(k2, xb.shape, jnp.float32)
        ab = abar[t][:, None, None, None]
        xt = jnp.sqrt(ab) * xb + jnp.sqrt(1.0 - ab) * eps

        def lf(p):
            pred = unet.apply(p, xt, t, drop_rate=drop_rate, key=key)
            return jnp.mean((pred - eps) ** 2)

        l, grads = jax.value_and_grad(lf)(params)
        new_p, new_opt = optim.adam_update(params, grads, opt_state, lr,
                                           weight_decay=optim.ADAMW_WD)
        return new_p, new_opt, l

    def denoise_step(params, xt, t):
        return unet.apply(params, xt, t, drop_rate=jnp.float32(0),
                          key=jnp.zeros((2,), jnp.uint32))

    train_args = (params0, opt0, x0, jnp.float32(0), jnp.float32(0),
                  jnp.zeros((2,), jnp.uint32))
    denoise_args = (params0, x0, jnp.zeros((batch,), jnp.int32))
    return dict(
        train=(train_step, train_args, ["param", "opt", "data_x", "lr", "drop_rate", "key"],
               ["param", "opt", "loss"]),
        denoise=(denoise_step, denoise_args, ["param", "data_x", "t"], ["eps"]),
        schedule={k: [float(v) for v in sched[k]] for k in sched},
    )


# ---------------------------------------------------------------------------
# manifest construction
# ---------------------------------------------------------------------------

def _leaf_entries(role: Role, tree) -> List[Dict[str, Any]]:
    out = []
    dt_names = {"float32": "f32", "int32": "i32", "uint32": "u32"}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = role + jax.tree_util.keystr(path)
        # works for both concrete arrays and jax.eval_shape's ShapeDtypeStructs
        out.append({"name": name, "role": role, "shape": list(leaf.shape),
                    "dtype": dt_names[str(leaf.dtype)]})
    return out


def manifest_io(args: Tuple, roles: List[Role], outs: Tuple, out_roles: List[Role]):
    """Flattened input/output specs in exactly jax.jit's calling convention
    order (arg-by-arg, tree-leaf order within each arg)."""
    inputs, outputs = [], []
    for role, tree in zip(roles, args):
        inputs.extend(_leaf_entries(role, tree))
    for role, tree in zip(out_roles, outs):
        outputs.extend(_leaf_entries(role, tree))
    # feeds: map output index -> input index for state that loops back
    by_name = {e["name"]: i for i, e in enumerate(inputs)}
    for e in outputs:
        e["feeds_input"] = by_name.get(e["name"], -1) if e["role"] in ("param", "opt", "bn") else -1
    return inputs, outputs
