//! BatchNorm folding: the checkpoint → folded-model conversion behind the
//! inference/serving path (`docs/ARCHITECTURE.md` § Inference path).
//!
//! In eval mode a [`super::layers::BatchNorm2d`] is a per-channel affine
//! map `y = scale·x + shift` with `scale = γ/√(rv+ε)` and
//! `shift = β − rm·scale` — constants once training stops. Folding bakes
//! that map into the *preceding* conv: `w'[o,·] = w[o,·]·scale[o]`
//! (OIHW rows) and `b'[o] = b[o]·scale[o] + shift[o]`, after which the BN
//! node is removed from the graph and its consumers rewire to the conv's
//! output slot ([`Graph`]'s fold pass). The folded model answers eval
//! queries without ever touching BN state — one GEMM per conv, no
//! normalization pass — and `resnet-tiny`'s BN-less 1×1 projection
//! shortcuts pass through untouched.
//!
//! Conv node names survive the fold, so folded state tensors keep their
//! stable `param['{name}.w']` / `param['{name}.b']` keys and a folded
//! checkpoint roundtrips bitwise. Folded checkpoints are marked by the
//! [`FOLDED_TAG`] suffix on the recorded artifact
//! (`native_{dataset}:{spec}#folded`): [`load_folded`] rebuilds the
//! BN-free graph from the spec and restores the folded values into it.
//! Failures are typed ([`FoldError`]): folding a spec with no BatchNorm is
//! a [`FoldError::NoBatchNorm`] no-op signal, never a panic.

use std::collections::HashMap;
use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use super::zoo::{build_model, parse_model_spec};
use super::Graph;
use crate::coordinator::checkpoint::{self, artifact_dataset, artifact_model_spec};
use crate::data;
use crate::tensorstore::Tensor;

/// Artifact-name suffix marking a folded checkpoint
/// (`native_{dataset}:{spec}#folded`). The `#` cannot appear in the zoo's
/// spec grammar, so raw and folded artifacts never collide.
pub const FOLDED_TAG: &str = "#folded";

/// Typed failures of the fold/serve conversion path. All variants are
/// recoverable signals, not panics; callers downcast through
/// [`anyhow::Error`] to react to specific cases (the serve CLI treats
/// [`FoldError::NoBatchNorm`] as "serve unfolded").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FoldError {
    /// The model has no foldable BatchNorm layer — folding is a no-op, and
    /// the explicit conversion reports it instead of writing a copy.
    NoBatchNorm {
        /// Canonical model spec of the checkpoint.
        spec: String,
    },
    /// The checkpoint is already a folded artifact; folding twice would
    /// silently re-scale weights that no longer have BN state.
    AlreadyFolded {
        /// The artifact recorded in the checkpoint.
        artifact: String,
    },
    /// The artifact field does not name a `native_{dataset}:{spec}` pair
    /// this crate can rebuild a model from.
    BadArtifact {
        /// The artifact recorded in the checkpoint.
        artifact: String,
    },
    /// [`load_folded`] was pointed at a checkpoint that is not marked
    /// folded (train-time checkpoints load via the trainer instead).
    NotFolded {
        /// The artifact recorded in the checkpoint.
        artifact: String,
    },
}

impl fmt::Display for FoldError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FoldError::NoBatchNorm { spec } => {
                write!(f, "model spec {spec:?} has no BatchNorm layer to fold (nothing to do)")
            }
            FoldError::AlreadyFolded { artifact } => {
                write!(f, "checkpoint artifact {artifact:?} is already folded")
            }
            FoldError::BadArtifact { artifact } => {
                write!(f, "artifact {artifact:?} does not name a native dataset:model pair")
            }
            FoldError::NotFolded { artifact } => {
                write!(f, "checkpoint artifact {artifact:?} is not a folded checkpoint")
            }
        }
    }
}

impl std::error::Error for FoldError {}

/// `true` when `artifact` carries the [`FOLDED_TAG`] suffix.
pub fn is_folded(artifact: &str) -> bool {
    artifact.ends_with(FOLDED_TAG)
}

/// The folded counterpart of a raw artifact name.
pub fn folded_artifact(artifact: &str) -> String {
    format!("{artifact}{FOLDED_TAG}")
}

/// Strip the [`FOLDED_TAG`] suffix, if present.
pub fn base_artifact(artifact: &str) -> &str {
    artifact.strip_suffix(FOLDED_TAG).unwrap_or(artifact)
}

/// Fold every eligible BatchNorm of a live model into its producing conv
/// (see the module docs for the math and eligibility rules); returns the
/// number of BN nodes folded away — `0` means the model had nothing to
/// fold and is unchanged. The folded model computes the *eval* forward
/// only; train it no further.
pub fn fold_graph(model: &mut Graph) -> usize {
    model.fold_batchnorm()
}

/// What [`fold_checkpoint`] did.
#[derive(Debug, Clone)]
pub struct FoldSummary {
    /// Canonical model spec of the converted checkpoint.
    pub spec: String,
    /// BatchNorm nodes folded away.
    pub folded: usize,
    /// Artifact name written to the folded checkpoint (tagged).
    pub artifact: String,
    /// State leaves in the folded checkpoint.
    pub leaves: usize,
}

/// Rebuild the (unfolded) model a native checkpoint artifact describes,
/// resolving the input geometry and class count through the dataset
/// registry. Typed [`FoldError::BadArtifact`] when the artifact is not a
/// `native_{dataset}:{spec}` pair.
pub(crate) fn model_for_artifact(artifact: &str) -> Result<Graph> {
    let base = base_artifact(artifact);
    let (Some(ds_name), Some(spec)) = (artifact_dataset(base), artifact_model_spec(base)) else {
        return Err(FoldError::BadArtifact { artifact: artifact.to_string() }.into());
    };
    let ds = data::spec(ds_name)
        .ok_or_else(|| FoldError::BadArtifact { artifact: artifact.to_string() })?;
    let parsed = parse_model_spec(spec)?;
    build_model(&parsed, ds.channels, ds.img, ds.classes, 0)
}

/// Convert a trained native checkpoint at `src` into a folded serving
/// checkpoint at `dst`: restore the recorded model, fold its BatchNorms,
/// and save the BN-free state under the [`FOLDED_TAG`]-marked artifact
/// (epoch preserved). Typed errors: [`FoldError::NoBatchNorm`] when the
/// spec has nothing to fold, [`FoldError::AlreadyFolded`] on a folded
/// input, [`FoldError::BadArtifact`] on an unrecognized artifact.
pub fn fold_checkpoint(src: &Path, dst: &Path) -> Result<FoldSummary> {
    let (state, artifact, epoch) = checkpoint::load_tensors(src)?;
    if is_folded(&artifact) {
        return Err(FoldError::AlreadyFolded { artifact }.into());
    }
    let mut model = model_for_artifact(&artifact)?;
    let tensors: Vec<(String, Tensor)> = state.into_iter().collect();
    model.load_state_tensors(&tensors).context("restoring checkpoint state")?;
    let folded = model.fold_batchnorm();
    if folded == 0 {
        return Err(FoldError::NoBatchNorm { spec: model.spec().to_string() }.into());
    }
    let new_state: HashMap<String, Tensor> = model.state_tensors().into_iter().collect();
    let leaves = new_state.len();
    let out_artifact = folded_artifact(&artifact);
    checkpoint::save_tensors(dst, &new_state, &out_artifact, epoch)?;
    Ok(FoldSummary { spec: model.spec().to_string(), folded, artifact: out_artifact, leaves })
}

/// Load a folded checkpoint back into a BN-free model: rebuild the graph
/// from the artifact's spec, replay the structural fold, and restore the
/// folded values — parameters roundtrip bitwise. Returns
/// `(model, artifact, epoch)`. Typed [`FoldError::NotFolded`] when the
/// checkpoint is not marked folded; truncated or corrupt tensor data is
/// rejected by the tensorstore reader before any state is applied.
pub fn load_folded(path: &Path) -> Result<(Graph, String, usize)> {
    let (state, artifact, epoch) = checkpoint::load_tensors(path)?;
    if !is_folded(&artifact) {
        return Err(FoldError::NotFolded { artifact }.into());
    }
    let mut model = model_for_artifact(&artifact)?;
    // Replay the structural fold on the freshly built graph (the interim
    // weight scaling is irrelevant — every parameter is overwritten by the
    // folded state below).
    model.fold_batchnorm();
    let tensors: Vec<(String, Tensor)> = state.into_iter().collect();
    model.load_state_tensors(&tensors).context("restoring folded state")?;
    Ok((model, artifact, epoch))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ssprop_fold_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn artifact_tag_helpers() {
        assert!(!is_folded("native_cifar10:resnet-tiny-w8-b1"));
        let f = folded_artifact("native_cifar10:resnet-tiny-w8-b1");
        assert_eq!(f, "native_cifar10:resnet-tiny-w8-b1#folded");
        assert!(is_folded(&f));
        assert_eq!(base_artifact(&f), "native_cifar10:resnet-tiny-w8-b1");
        assert_eq!(base_artifact("plain"), "plain");
    }

    #[test]
    fn folding_a_bnless_spec_is_a_typed_no_op() {
        let dir = tmp_dir("nobn");
        let src = dir.join("vgg.tstore");
        let ds = data::spec("mnist").unwrap();
        let parsed = parse_model_spec("vgg-tiny-w4").unwrap();
        let model = build_model(&parsed, ds.channels, ds.img, ds.classes, 3).unwrap();
        let state: HashMap<String, Tensor> = model.state_tensors().into_iter().collect();
        checkpoint::save_tensors(&src, &state, "native_mnist:vgg-tiny-w4", 0).unwrap();
        let err = fold_checkpoint(&src, &dir.join("out.tstore")).unwrap_err();
        match err.downcast_ref::<FoldError>() {
            Some(FoldError::NoBatchNorm { spec }) => assert_eq!(spec, "vgg-tiny-w4"),
            other => panic!("expected NoBatchNorm, got {other:?}: {err}"),
        }
        assert!(!dir.join("out.tstore").exists(), "no-op must not write a folded file");
    }

    #[test]
    fn unrecognized_artifacts_are_typed_errors() {
        let dir = tmp_dir("badart");
        let src = dir.join("odd.tstore");
        let state: HashMap<String, Tensor> =
            [("param['w']".to_string(), Tensor::from_f32(vec![1], &[1.0]))].into_iter().collect();
        checkpoint::save_tensors(&src, &state, "resnet18_cifar10", 0).unwrap();
        let err = fold_checkpoint(&src, &dir.join("out.tstore")).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<FoldError>(), Some(FoldError::BadArtifact { .. })),
            "{err}"
        );
        // unknown dataset in an otherwise well-formed artifact
        checkpoint::save_tensors(&src, &state, "native_svhn:vgg-tiny-w4", 0).unwrap();
        let err = fold_checkpoint(&src, &dir.join("out.tstore")).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<FoldError>(), Some(FoldError::BadArtifact { .. })),
            "{err}"
        );
    }

    #[test]
    fn load_folded_rejects_raw_checkpoints() {
        let dir = tmp_dir("raw");
        let src = dir.join("raw.tstore");
        let parsed = parse_model_spec("vgg-tiny-w4").unwrap();
        let model = build_model(&parsed, 1, 12, 4, 3).unwrap();
        let state: HashMap<String, Tensor> = model.state_tensors().into_iter().collect();
        checkpoint::save_tensors(&src, &state, "native_mnist:vgg-tiny-w4", 0).unwrap();
        let err = load_folded(&src).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<FoldError>(), Some(FoldError::NotFolded { .. })),
            "{err}"
        );
    }
}
