//! Artifact manifest: the contract between the Python compile path and the
//! rust runtime. Produced by python/compile/aot.py, one JSON per artifact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::flops::{ConvLayer, LayerSet};
use crate::util::json::Json;

/// Input/output role taxonomy (mirrors python/compile/steps.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Model parameter leaf (looped-back state).
    Param,
    /// Optimizer moment leaf (looped-back state).
    Opt,
    /// BatchNorm running statistic (looped-back state).
    Bn,
    /// Input images.
    DataX,
    /// Input labels.
    DataY,
    /// Learning-rate scalar.
    Lr,
    /// ssProp drop-rate scalar.
    DropRate,
    /// Runtime Dropout-rate scalar.
    DropoutRate,
    /// RNG key, (2,) u32.
    Key,
    /// Diffusion timestep (DDPM steps).
    T,
    /// Loss output scalar.
    Loss,
    /// Accuracy output scalar.
    Acc,
    /// Sampled noise (DDPM steps).
    Eps,
    /// Anything the runtime routes opaquely.
    Other,
}

impl Role {
    /// Parse a manifest role string (unknown strings map to [`Role::Other`]).
    pub fn parse(s: &str) -> Role {
        match s {
            "param" => Role::Param,
            "opt" => Role::Opt,
            "bn" => Role::Bn,
            "data_x" => Role::DataX,
            "data_y" => Role::DataY,
            "lr" => Role::Lr,
            "drop_rate" => Role::DropRate,
            "dropout_rate" => Role::DropoutRate,
            "key" => Role::Key,
            "t" => Role::T,
            "loss" => Role::Loss,
            "acc" => Role::Acc,
            "eps" => Role::Eps,
            _ => Role::Other,
        }
    }

    /// Roles whose values persist across iterations (looped-back state).
    pub fn is_state(self) -> bool {
        matches!(self, Role::Param | Role::Opt | Role::Bn)
    }
}

/// One input or output of a compiled step.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// Leaf name, e.g. `param['conv0.w']`.
    pub name: String,
    /// Routing role.
    pub role: Role,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Dtype name ("f32", "i32", "u32").
    pub dtype: String,
    /// For outputs: index of the input this output feeds next iteration (-1 none).
    pub feeds_input: i64,
}

/// A compiled artifact's manifest (one JSON per artifact).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact name, e.g. "resnet18_cifar10_train".
    pub name: String,
    /// Step kind ("train", "eval", "denoise", ...).
    pub kind: String,
    /// Model architecture name.
    pub model: String,
    /// Dataset the step was lowered for.
    pub dataset: String,
    /// Batch size baked into the graph.
    pub batch: usize,
    /// Loss family name ("ce" / "bce" / "mse").
    pub loss: String,
    /// Class count.
    pub classes: usize,
    /// Image side length.
    pub img: usize,
    /// Image channels.
    pub channels: usize,
    /// Diffusion timesteps (0 for classifiers).
    pub timesteps: usize,
    /// Width multiplier the model was scaled by.
    pub width_mult: f64,
    /// Step inputs, execution order.
    pub inputs: Vec<IoSpec>,
    /// Step outputs, execution order.
    pub outputs: Vec<IoSpec>,
    /// Conv inventory for FLOPs accounting.
    pub layers: LayerSet,
    /// DDPM beta schedule (empty for classifiers).
    pub alpha_bar: Vec<f64>,
    /// DDPM per-step betas (empty for classifiers).
    pub betas: Vec<f64>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.str_field("name").map_err(anyhow::Error::msg)?.to_string(),
        role: Role::parse(j.str_field("role").map_err(anyhow::Error::msg)?),
        shape: j
            .arr_field("shape")
            .map_err(anyhow::Error::msg)?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect(),
        dtype: j.str_field("dtype").map_err(anyhow::Error::msg)?.to_string(),
        feeds_input: j.get("feeds_input").and_then(Json::as_i64).unwrap_or(-1),
    })
}

impl Manifest {
    /// Load and parse a manifest JSON file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Manifest::parse(&text)
    }

    /// Parse a manifest from JSON text (validates `feeds_input` ranges).
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(anyhow::Error::msg)?;
        let inputs = j
            .arr_field("inputs")
            .map_err(anyhow::Error::msg)?
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .arr_field("outputs")
            .map_err(anyhow::Error::msg)?
            .iter()
            .map(parse_io)
            .collect::<Result<Vec<_>>>()?;
        for o in &outputs {
            if o.feeds_input >= inputs.len() as i64 {
                bail!("output {} feeds out-of-range input {}", o.name, o.feeds_input);
            }
        }

        let mut layers = LayerSet::default();
        if let Some(ls) = j.get("layers") {
            if let Some(convs) = ls.get("convs").and_then(Json::as_arr) {
                for c in convs {
                    layers.convs.push(ConvLayer {
                        cin: c.usize_field("cin").map_err(anyhow::Error::msg)?,
                        cout: c.usize_field("cout").map_err(anyhow::Error::msg)?,
                        k: c.usize_field("k").map_err(anyhow::Error::msg)?,
                        hout: c.usize_field("hout").map_err(anyhow::Error::msg)?,
                        wout: c.usize_field("wout").map_err(anyhow::Error::msg)?,
                        counted_bn: false,
                    });
                }
            }
            // bns in the manifest are listed separately; mark matching convs
            let nbns = ls.get("bns").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
            for (i, c) in layers.convs.iter_mut().enumerate() {
                if i < nbns {
                    c.counted_bn = true;
                }
            }
            if let Some(drops) = ls.get("dropouts").and_then(Json::as_arr) {
                for d in drops {
                    layers.dropouts.push((
                        d.usize_field("c").map_err(anyhow::Error::msg)?,
                        d.usize_field("h").map_err(anyhow::Error::msg)?,
                        d.usize_field("w").map_err(anyhow::Error::msg)?,
                    ));
                }
            }
        }

        let sched = j.get("beta_schedule");
        let getf = |key: &str| -> Vec<f64> {
            sched
                .and_then(|s| s.get(key))
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default()
        };

        Ok(Manifest {
            name: j.str_field("name").map_err(anyhow::Error::msg)?.to_string(),
            kind: j.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
            model: j.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
            dataset: j.get("dataset").and_then(Json::as_str).unwrap_or("").to_string(),
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
            loss: j.get("loss").and_then(Json::as_str).unwrap_or("").to_string(),
            classes: j.get("classes").and_then(Json::as_usize).unwrap_or(0),
            img: j.get("img").and_then(Json::as_usize).unwrap_or(0),
            channels: j.get("channels").and_then(Json::as_usize).unwrap_or(0),
            timesteps: j.get("timesteps").and_then(Json::as_usize).unwrap_or(0),
            width_mult: j.get("width_mult").and_then(Json::as_f64).unwrap_or(1.0),
            inputs,
            outputs,
            layers,
            alpha_bar: getf("alpha_bar"),
            betas: getf("betas"),
        })
    }

    /// Index of the first input with `role`.
    pub fn input_index(&self, role: Role) -> Option<usize> {
        self.inputs.iter().position(|i| i.role == role)
    }

    /// Index of the first output with `role`.
    pub fn output_index(&self, role: Role) -> Option<usize> {
        self.outputs.iter().position(|o| o.role == role)
    }

    /// Backward FLOPs per iteration at drop rate d (uses manifest geometry —
    /// i.e. the *scaled* model actually executing; full-width paper numbers
    /// come from flops::paper_resnet).
    pub fn bwd_flops(&self, d: f64) -> f64 {
        self.layers.bwd_flops_per_iter(self.batch, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "toy_train", "kind": "train", "model": "cnn2", "dataset": "cifar10",
      "batch": 8, "loss": "ce", "classes": 10, "img": 32, "channels": 3,
      "width_mult": 0.25,
      "inputs": [
        {"name": "param['w']", "role": "param", "shape": [4, 3, 3, 3], "dtype": "f32"},
        {"name": "lr", "role": "lr", "shape": [], "dtype": "f32"},
        {"name": "drop_rate", "role": "drop_rate", "shape": [], "dtype": "f32"}
      ],
      "outputs": [
        {"name": "param['w']", "role": "param", "shape": [4, 3, 3, 3], "dtype": "f32", "feeds_input": 0},
        {"name": "loss", "role": "loss", "shape": [], "dtype": "f32", "feeds_input": -1}
      ],
      "layers": {"convs": [{"cin": 3, "cout": 4, "k": 3, "stride": 1, "padding": 1,
                            "hin": 32, "win": 32, "hout": 32, "wout": 32}],
                 "bns": [{"c": 4, "h": 32, "w": 32}], "dropouts": []}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "toy_train");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.outputs[0].feeds_input, 0);
        assert_eq!(m.inputs[0].role, Role::Param);
        assert_eq!(m.layers.convs.len(), 1);
        assert!(m.layers.convs[0].counted_bn);
        assert_eq!(m.input_index(Role::Lr), Some(1));
        assert_eq!(m.output_index(Role::Loss), Some(1));
    }

    #[test]
    fn flops_from_manifest_geometry() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let dense = m.bwd_flops(0.0);
        // conv Eq6 + bn Eq7 at bs 8
        let conv = (8 * 32 * 32) as f64 * (4.0 * 27.0 + 1.0) * 4.0;
        let bn = 12.0 * (8 * 32 * 32 * 4) as f64 + 40.0;
        assert!((dense - (conv + bn)).abs() < 1e-6);
        assert!(m.bwd_flops(0.8) < dense);
    }

    #[test]
    fn rejects_out_of_range_feed() {
        let bad = SAMPLE.replace("\"feeds_input\": 0", "\"feeds_input\": 99");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn role_parse_roundtrip() {
        for (s, r) in [
            ("param", Role::Param), ("opt", Role::Opt), ("bn", Role::Bn),
            ("data_x", Role::DataX), ("data_y", Role::DataY), ("lr", Role::Lr),
            ("drop_rate", Role::DropRate), ("dropout_rate", Role::DropoutRate),
            ("key", Role::Key), ("t", Role::T), ("loss", Role::Loss),
            ("acc", Role::Acc), ("eps", Role::Eps), ("whatever", Role::Other),
        ] {
            assert_eq!(Role::parse(s), r);
        }
        assert!(Role::Param.is_state() && Role::Opt.is_state() && Role::Bn.is_state());
        assert!(!Role::Loss.is_state());
    }
}
