//! Pooling layers: windowed max/average pooling plus the global average
//! pool the classifier heads sit on. `GlobalAvgPool` reproduces the
//! historical SimpleCNN head loops bit-for-bit (its forward mean and
//! backward spread are the exact FP operations of the legacy model).

use anyhow::{bail, Result};

use super::{BwdOut, FwdCtx, Layer, LayerWs, Selection, Shape};
use crate::backend::im2col::out_size;
use crate::backend::Backend;

/// Shared geometry for the windowed pools: `(c, h, w)` input, `k`×`k`
/// window at `stride` (no padding).
#[derive(Debug, Clone, Copy)]
struct PoolGeom {
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
}

impl PoolGeom {
    fn new(c: usize, h: usize, w: usize, k: usize, stride: usize) -> PoolGeom {
        assert!(c >= 1 && k >= 1 && stride >= 1, "degenerate pool geometry");
        assert!(h >= k && w >= k, "pool window {k} exceeds the {h}x{w} input");
        PoolGeom { c, h, w, k, stride }
    }

    fn hout(&self) -> usize {
        out_size(self.h, self.k, self.stride, 0)
    }

    fn wout(&self) -> usize {
        out_size(self.w, self.k, self.stride, 0)
    }

    fn check(&self, input: &Shape, what: &str) -> Result<Shape> {
        match *input {
            Shape::Spatial { c, h, w } if (c, h, w) == (self.c, self.h, self.w) => {
                Ok(Shape::Spatial { c: self.c, h: self.hout(), w: self.wout() })
            }
            other => {
                let want = (self.c, self.h, self.w);
                bail!("{what} built for {want:?} input, got {other:?}")
            }
        }
    }
}

/// Windowed max pooling. The forward records each output's argmax (flat
/// input index) in the workspace; the backward scatters the gradient back
/// to exactly those positions (accumulating where windows overlap).
#[derive(Debug, Clone, Copy)]
pub struct MaxPool2d {
    geom: PoolGeom,
}

impl MaxPool2d {
    /// A `k`×`k`/`stride` max pool over `(c, h, w)` feature maps.
    pub fn new(c: usize, h: usize, w: usize, k: usize, stride: usize) -> MaxPool2d {
        MaxPool2d { geom: PoolGeom::new(c, h, w, k, stride) }
    }
}

impl Layer for MaxPool2d {
    fn describe(&self) -> String {
        format!("maxpool{}x{}/s{}", self.geom.k, self.geom.k, self.geom.stride)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        self.geom.check(input, "maxpool")
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        let g = &self.geom;
        let (ho, wo) = (g.hout(), g.wout());
        assert_eq!(x.len(), bt * g.c * g.h * g.w, "maxpool input length");
        let mut y = vec![0f32; bt * g.c * ho * wo];
        ws.argmax.clear();
        ws.argmax.resize(y.len(), 0);
        for b in 0..bt {
            for c in 0..g.c {
                let plane = (b * g.c + c) * g.h * g.w;
                for oh in 0..ho {
                    for ow in 0..wo {
                        let (mut best, mut best_idx) = (f32::NEG_INFINITY, 0usize);
                        for kh in 0..g.k {
                            for kw in 0..g.k {
                                let idx = plane + (oh * g.stride + kh) * g.w + ow * g.stride + kw;
                                if x[idx] > best {
                                    best = x[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let out_idx = ((b * g.c + c) * ho + oh) * wo + ow;
                        y[out_idx] = best;
                        ws.argmax[out_idx] = best_idx;
                    }
                }
            }
        }
        y
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        _bt: usize,
        ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        assert_eq!(ws.argmax.len(), g.len(), "maxpool backward without a matching forward");
        let mut dx = vec![0f32; x.len()];
        for (&src, &gv) in ws.argmax.iter().zip(g) {
            dx[src] += gv;
        }
        BwdOut { dx, ..BwdOut::default() }
    }
}

/// Windowed average pooling.
#[derive(Debug, Clone, Copy)]
pub struct AvgPool2d {
    geom: PoolGeom,
}

impl AvgPool2d {
    /// A `k`×`k`/`stride` average pool over `(c, h, w)` feature maps.
    pub fn new(c: usize, h: usize, w: usize, k: usize, stride: usize) -> AvgPool2d {
        AvgPool2d { geom: PoolGeom::new(c, h, w, k, stride) }
    }
}

impl Layer for AvgPool2d {
    fn describe(&self) -> String {
        format!("avgpool{}x{}/s{}", self.geom.k, self.geom.k, self.geom.stride)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        self.geom.check(input, "avgpool")
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        let g = &self.geom;
        let (ho, wo) = (g.hout(), g.wout());
        assert_eq!(x.len(), bt * g.c * g.h * g.w, "avgpool input length");
        let inv_kk = 1.0 / (g.k * g.k) as f32;
        let mut y = vec![0f32; bt * g.c * ho * wo];
        for b in 0..bt {
            for c in 0..g.c {
                let plane = (b * g.c + c) * g.h * g.w;
                for oh in 0..ho {
                    for ow in 0..wo {
                        let mut acc = 0f32;
                        for kh in 0..g.k {
                            for kw in 0..g.k {
                                acc += x[plane + (oh * g.stride + kh) * g.w + ow * g.stride + kw];
                            }
                        }
                        y[((b * g.c + c) * ho + oh) * wo + ow] = acc * inv_kk;
                    }
                }
            }
        }
        y
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        let gm = &self.geom;
        let (ho, wo) = (gm.hout(), gm.wout());
        let inv_kk = 1.0 / (gm.k * gm.k) as f32;
        let mut dx = vec![0f32; x.len()];
        for b in 0..bt {
            for c in 0..gm.c {
                let plane = (b * gm.c + c) * gm.h * gm.w;
                for oh in 0..ho {
                    for ow in 0..wo {
                        let gv = g[((b * gm.c + c) * ho + oh) * wo + ow] * inv_kk;
                        for kh in 0..gm.k {
                            for kw in 0..gm.k {
                                let idx =
                                    plane + (oh * gm.stride + kh) * gm.w + ow * gm.stride + kw;
                                dx[idx] += gv;
                            }
                        }
                    }
                }
            }
        }
        BwdOut { dx, ..BwdOut::default() }
    }
}

/// Global average pool: each (C, H, W) feature map collapses to a flat
/// C-vector of plane means — the classifier-head reduction of the
/// historical SimpleCNN, loop-for-loop.
#[derive(Debug, Clone, Copy)]
pub struct GlobalAvgPool {
    c: usize,
    h: usize,
    w: usize,
}

impl GlobalAvgPool {
    /// A global average pool over `(c, h, w)` feature maps.
    pub fn new(c: usize, h: usize, w: usize) -> GlobalAvgPool {
        assert!(c >= 1 && h >= 1 && w >= 1, "degenerate pool geometry");
        GlobalAvgPool { c, h, w }
    }
}

impl Layer for GlobalAvgPool {
    fn describe(&self) -> String {
        "gap".to_string()
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        match *input {
            Shape::Spatial { c, h, w } if (c, h, w) == (self.c, self.h, self.w) => {
                Ok(Shape::Flat { features: self.c })
            }
            other => {
                let want = (self.c, self.h, self.w);
                bail!("gap built for {want:?} input, got {other:?}")
            }
        }
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        let hw = self.h * self.w;
        assert_eq!(x.len(), bt * self.c * hw, "gap input length");
        let mut pooled = vec![0f32; bt * self.c];
        for b in 0..bt {
            for f in 0..self.c {
                let plane = &x[(b * self.c + f) * hw..][..hw];
                pooled[b * self.c + f] = plane.iter().sum::<f32>() / hw as f32;
            }
        }
        pooled
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        _ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        let hw = self.h * self.w;
        let inv_hw = 1.0 / hw as f32;
        let mut dx = vec![0f32; x.len()];
        for b in 0..bt {
            for f in 0..self.c {
                let gv = g[b * self.c + f] * inv_hw;
                let base = (b * self.c + f) * hw;
                for pix in 0..hw {
                    dx[base + pix] = gv;
                }
            }
        }
        BwdOut { dx, ..BwdOut::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn ctx() -> FwdCtx {
        FwdCtx { train: true, step: 0, example_offset: 0 }
    }

    #[test]
    fn maxpool_forward_backward_hand_checked() {
        let be = NativeBackend::new();
        // one 4x4 plane, 2x2/s2 pool
        let p = MaxPool2d::new(1, 4, 4, 2, 2);
        assert_eq!(
            p.out_shape(&Shape::Spatial { c: 1, h: 4, w: 4 }).unwrap(),
            Shape::Spatial { c: 1, h: 2, w: 2 }
        );
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0,   0.0, 0.0,
            3.0, 4.0,   0.0, 5.0,
            -1.0, -2.0, 7.0, 6.0,
            -3.0, -4.0, 8.0, 9.0,
        ];
        let mut ws = LayerWs::default();
        let y = p.forward(&be, &x, 1, &mut ws, &ctx());
        assert_eq!(y, vec![4.0, 5.0, -1.0, 9.0]);
        let g = vec![1.0, 2.0, 3.0, 4.0];
        let out = p.backward(&be, &x, &g, 1, &mut ws, Selection::Local(0.0), true);
        let mut want = vec![0f32; 16];
        want[5] = 1.0; // 4.0
        want[7] = 2.0; // 5.0
        want[8] = 3.0; // -1.0
        want[15] = 4.0; // 9.0
        assert_eq!(out.dx, want);
    }

    #[test]
    fn maxpool_overlapping_windows_accumulate() {
        let be = NativeBackend::new();
        // 3x3 input, 2x2/s1 pool -> 2x2 output; the center max wins all
        let p = MaxPool2d::new(1, 3, 3, 2, 1);
        let x = vec![0.0, 0.0, 0.0, 0.0, 9.0, 0.0, 0.0, 0.0, 0.0];
        let mut ws = LayerWs::default();
        let y = p.forward(&be, &x, 1, &mut ws, &ctx());
        assert_eq!(y, vec![9.0; 4]);
        let out = p.backward(&be, &x, &[1.0; 4], 1, &mut ws, Selection::Local(0.0), true);
        assert_eq!(out.dx[4], 4.0, "all four windows route their gradient to the max");
    }

    #[test]
    fn avgpool_forward_backward() {
        let be = NativeBackend::new();
        let p = AvgPool2d::new(1, 4, 4, 2, 2);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut ws = LayerWs::default();
        let y = p.forward(&be, &x, 1, &mut ws, &ctx());
        assert_eq!(y, vec![2.5, 4.5, 10.5, 12.5]);
        let g = vec![4.0, 8.0, 12.0, 16.0];
        let out = p.backward(&be, &x, &g, 1, &mut ws, Selection::Local(0.0), true);
        assert_eq!(out.dx[0], 1.0);
        assert_eq!(out.dx[3], 2.0);
        assert_eq!(out.dx[15], 4.0);
    }

    #[test]
    fn gap_is_plane_mean() {
        let be = NativeBackend::new();
        let p = GlobalAvgPool::new(2, 2, 2);
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 10.0, 10.0, 10.0];
        let mut ws = LayerWs::default();
        let y = p.forward(&be, &x, 1, &mut ws, &ctx());
        assert_eq!(y, vec![2.5, 10.0]);
        let out = p.backward(&be, &x, &[4.0, 8.0], 1, &mut ws, Selection::Local(0.0), true);
        assert_eq!(out.dx, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        let flat = p.out_shape(&Shape::Spatial { c: 2, h: 2, w: 2 }).unwrap();
        assert_eq!(flat, Shape::Flat { features: 2 });
        assert!(p.out_shape(&Shape::Flat { features: 8 }).is_err());
    }

    #[test]
    #[should_panic(expected = "pool window")]
    fn pool_rejects_window_larger_than_input() {
        MaxPool2d::new(1, 2, 2, 3, 1);
    }
}
