//! Experiment harness (S22): one driver per paper table/figure.
//!
//! Every driver prints a markdown table mirroring the paper's rows and
//! writes raw JSON under `results/`. Scale knobs (`epochs`, `iters`) default
//! to CPU-testbed sizes; absolute accuracies are synthetic-data accuracies,
//! but the *comparisons* (dense vs ssProp, Dropout interactions, iso-FLOPs,
//! scheduler shapes) reproduce the paper's findings. FLOPs columns are
//! analytic and match the paper exactly at full width (flops.rs).
//!
//! Analytic drivers (Tables 1–3, FLOPs parity, energy projection) run on
//! any build; drivers that train through compiled artifacts require the
//! `pjrt` feature.

pub mod figures;
pub mod report;
pub mod tables;

#[cfg(feature = "pjrt")]
use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::coordinator::{TrainConfig, Trainer};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
#[cfg(feature = "pjrt")]
use crate::schedule::{DropScheduler, Schedule};

/// Shared scale knobs for all experiment drivers.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Epochs per run.
    pub epochs: usize,
    /// Iterations per epoch.
    pub iters_per_epoch: usize,
    /// Base seed for data order and init.
    pub seed: u64,
    /// Learning rate.
    pub lr: f64,
}

impl Default for Scale {
    fn default() -> Self {
        Scale { epochs: 4, iters_per_epoch: 24, seed: 0, lr: 1e-3 }
    }
}

/// One classifier training run; returns (trainer-with-metrics, test acc).
#[cfg(feature = "pjrt")]
pub fn run_classifier(
    engine: &Engine,
    artifact: &str,
    scale: Scale,
    schedule: Schedule,
    target_drop: f64,
    dropout_rate: f64,
) -> Result<(Trainer, f64)> {
    let sched =
        DropScheduler::new(schedule, target_drop.min(0.999), scale.epochs, scale.iters_per_epoch);
    let cfg = TrainConfig {
        artifact: artifact.to_string(),
        epochs: scale.epochs,
        iters_per_epoch: scale.iters_per_epoch,
        lr: scale.lr,
        scheduler: sched,
        dropout_rate,
        seed: scale.seed,
        eval_every: 0,
        verbose: false,
    };
    let mut t = Trainer::new(engine, cfg)?;
    let (_, acc) = t.run()?;
    Ok((t, acc))
}

/// Dense baseline: constant schedule at rate 0.
#[cfg(feature = "pjrt")]
pub fn run_dense(engine: &Engine, artifact: &str, scale: Scale) -> Result<(Trainer, f64)> {
    run_classifier(engine, artifact, scale, Schedule::Constant, 0.0, 0.0)
}

/// Paper-default ssProp: bar scheduler, 2-epoch period, D* = 0.8.
#[cfg(feature = "pjrt")]
pub fn run_ssprop(engine: &Engine, artifact: &str, scale: Scale) -> Result<(Trainer, f64)> {
    run_classifier(engine, artifact, scale, Schedule::EpochBar { period_epochs: 2 }, 0.8, 0.0)
}
