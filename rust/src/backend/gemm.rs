//! Cache-blocked, register-tiled f32 GEMM — the kernel behind the native
//! backend's im2col convolutions (the ROADMAP's "single biggest lever
//! `native_hotpath` can measure").
//!
//! The decomposition is the classic panel-packing one: the depth
//! dimension is split into [`KC`]-sized blocks; each block's B rows are
//! packed into [`NR`]-wide column panels and its A rows into [`MR`]-wide
//! row panels; a fixed MR×NR register tile then walks the packed panels.
//! Packing makes both microkernel operands contiguous streaming reads,
//! with the panel sizes chosen so one A panel plus one B panel sit in L1
//! while a whole packed A block ([`MC`]×[`KC`]) stays L2-resident. Edge
//! tiles are zero-padded during packing, so the microkernel itself never
//! branches on shape.
//!
//! Two properties the rest of the crate leans on:
//!
//! * **Deterministic accumulation.** Every output element accumulates its
//!   depth products in strictly increasing depth order — KC blocks in
//!   order, in-order within each block — so results do not depend on how
//!   the blocking parameters land on a given shape, are identical from
//!   run to run, and (the kernel is single-threaded; the parallel
//!   executor shards *batches*, never a GEMM) stay bit-identical per
//!   worker-thread count. For depths ≤ [`KC`] the summation order is
//!   exactly the naive triple loop's ([`gemm_ref`]).
//! * **Dense semantics.** There is no value-based zero skipping (the old
//!   naive kernel skipped `a == 0.0` terms, silently swallowing NaN/Inf
//!   from the B operand). Sparsity enters only *structurally*: the
//!   [`Operand::KeptChannels`] / [`Operand::KeptRows`] views fuse the
//!   ssProp `keep_idx` gather into the packing stage, so the compacted
//!   backward GEMMs never read, pack, or multiply a dropped channel's
//!   rows at all — zero by construction, not by test.

/// Rows of the register tile (width of a packed A panel).
pub const MR: usize = 4;
/// Columns of the register tile (width of a packed B panel). Kept narrow
/// on purpose: the dW GEMM's output columns are the *kept channels*, so a
/// wide tile would pad small keep sets back up to dense-width work.
pub const NR: usize = 8;
/// Depth block: one A panel (MR×KC) plus one B panel (KC×NR) is 12 KiB —
/// comfortably L1-resident.
const KC: usize = 256;
/// Row block: the packed A block (MC×KC, 64 KiB) stays L2-resident.
const MC: usize = 64;
/// Column block: bounds the packed B block (KC×NC) at 1 MiB.
const NC: usize = 1024;

/// Reusable packing buffers for [`gemm_into`]. Each plan/workspace owns
/// its own pack, so the parallel executor's per-worker plans stay
/// lock-free and the steady-state hot loop allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct GemmPack {
    /// Packed A block: up to MC/MR panels of KC×MR.
    pa: Vec<f32>,
    /// Packed B block: up to NC/NR panels of KC×NR.
    pb: Vec<f32>,
}

impl GemmPack {
    /// A fresh, empty pack (panel buffers grow lazily on first use).
    pub fn new() -> GemmPack {
        GemmPack::default()
    }

    /// Capacity of the two panel buffers (packed A, packed B); the
    /// workspace-reuse tests pin these flat across steady-state steps.
    pub fn caps(&self) -> [usize; 2] {
        [self.pa.capacity(), self.pb.capacity()]
    }
}

/// A read-only GEMM operand: how the packing stage reads logical element
/// (row, col) of a (rows × cols) matrix. The dense layouts index straight
/// into the slice; the `Kept*` views are what makes the backward GEMMs
/// sparsity-aware — they gather only the ssProp `keep_idx` channels while
/// packing, so dropped channels contribute no reads and no FLOPs.
#[derive(Debug, Clone, Copy)]
pub enum Operand<'a> {
    /// Row-major (rows × cols) matrix.
    Dense(&'a [f32]),
    /// Transposed view: the slice holds the (cols × rows) row-major
    /// underlying matrix; element (r, c) reads `data[c * rows + r]`.
    Transposed(&'a [f32]),
    /// Kept output channels of an NCHW gradient as the compacted
    /// (Bt·Ho·Wo × k') col-form matrix `col[dY]'`: element (r, c) reads
    /// plane `keep[c]` of image `r / hw` at pixel `r % hw`.
    KeptChannels {
        /// NCHW gradient, length (rows / `hw`) · `cout` · `hw`.
        g: &'a [f32],
        /// Kept channel indices (each < `cout`); the logical column axis.
        keep: &'a [usize],
        /// Total output channels in `g`.
        cout: usize,
        /// Spatial plane size Ho·Wo.
        hw: usize,
    },
    /// Kept rows of a row-major matrix: logical row r is underlying row
    /// `keep[r]` (the compacted OIHW weight view `col_W'ᵀ`).
    KeptRows {
        /// Underlying row-major matrix, rows of length cols.
        data: &'a [f32],
        /// Kept row indices; the logical row axis.
        keep: &'a [usize],
    },
}

impl Operand<'_> {
    /// Validate the operand against its logical (rows × cols) shape.
    fn check(&self, rows: usize, cols: usize, side: &str) {
        match *self {
            Operand::Dense(d) | Operand::Transposed(d) => {
                assert_eq!(d.len(), rows * cols, "{side}: operand length");
            }
            Operand::KeptChannels { g, keep, cout, hw } => {
                assert_eq!(keep.len(), cols, "{side}: kept-channel count");
                assert!(hw > 0 && rows % hw == 0, "{side}: rows must be whole planes");
                assert_eq!(g.len(), (rows / hw) * cout * hw, "{side}: NCHW gradient length");
                assert!(keep.iter().all(|&o| o < cout), "{side}: keep index out of range");
            }
            Operand::KeptRows { data, keep } => {
                assert_eq!(keep.len(), rows, "{side}: kept-row count");
                let fits = keep.iter().all(|&r| (r + 1) * cols <= data.len());
                assert!(fits, "{side}: kept row out of range");
            }
        }
    }
}

/// Pack rows `i0..i0+mc` × depth `p0..p0+kc` of the (m × k) operand `a`
/// into MR-wide row panels (`buf[panel][depth][row]`), dispatching the
/// per-variant index math once so the inner loops stay monomorphic.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    a: &Operand<'_>,
    m: usize,
    k: usize,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    buf: &mut Vec<f32>,
) {
    match *a {
        Operand::Dense(d) => pack_a_with(|r, p| d[r * k + p], i0, mc, p0, kc, buf),
        Operand::Transposed(d) => pack_a_with(|r, p| d[p * m + r], i0, mc, p0, kc, buf),
        Operand::KeptChannels { g, keep, cout, hw } => {
            pack_a_with(|r, p| g[((r / hw) * cout + keep[p]) * hw + r % hw], i0, mc, p0, kc, buf)
        }
        Operand::KeptRows { data, keep } => {
            pack_a_with(|r, p| data[keep[r] * k + p], i0, mc, p0, kc, buf)
        }
    }
}

/// Shared A-packing loop: `get(row, depth)` reads the operand; rows past
/// the block edge pad with zeros so the microkernel never branches.
fn pack_a_with(
    get: impl Fn(usize, usize) -> f32,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
    buf: &mut Vec<f32>,
) {
    let panels = mc.div_ceil(MR);
    buf.clear();
    buf.resize(panels * kc * MR, 0.0);
    for ip in 0..panels {
        let iw = MR.min(mc - ip * MR);
        let panel = &mut buf[ip * kc * MR..][..kc * MR];
        for (p, prow) in panel.chunks_exact_mut(MR).enumerate() {
            for (i, slot) in prow.iter_mut().enumerate().take(iw) {
                *slot = get(i0 + ip * MR + i, p0 + p);
            }
        }
    }
}

/// Pack depth `p0..p0+kc` × columns `j0..j0+nc` of the (k × n) operand
/// `b` into NR-wide column panels (`buf[panel][depth][col]`).
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &Operand<'_>,
    k: usize,
    n: usize,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    buf: &mut Vec<f32>,
) {
    match *b {
        Operand::Dense(d) => pack_b_with(|p, c| d[p * n + c], p0, kc, j0, nc, buf),
        Operand::Transposed(d) => pack_b_with(|p, c| d[c * k + p], p0, kc, j0, nc, buf),
        Operand::KeptChannels { g, keep, cout, hw } => {
            pack_b_with(|p, c| g[((p / hw) * cout + keep[c]) * hw + p % hw], p0, kc, j0, nc, buf)
        }
        Operand::KeptRows { data, keep } => {
            pack_b_with(|p, c| data[keep[p] * n + c], p0, kc, j0, nc, buf)
        }
    }
}

/// Shared B-packing loop: `get(depth, col)` reads the operand; columns
/// past the block edge pad with zeros.
fn pack_b_with(
    get: impl Fn(usize, usize) -> f32,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
    buf: &mut Vec<f32>,
) {
    let panels = nc.div_ceil(NR);
    buf.clear();
    buf.resize(panels * kc * NR, 0.0);
    for jp in 0..panels {
        let jw = NR.min(nc - jp * NR);
        let panel = &mut buf[jp * kc * NR..][..kc * NR];
        for (p, prow) in panel.chunks_exact_mut(NR).enumerate() {
            for (j, slot) in prow.iter_mut().enumerate().take(jw) {
                *slot = get(p0 + p, j0 + jp * NR + j);
            }
        }
    }
}

/// The register tile: `acc[MR][NR] += a_panel ⊗ b_panel` over one depth
/// block, depth-major so each element's sum order is the plain in-order
/// one. `chunks_exact` hands LLVM fixed-size rows, so this compiles to
/// broadcast + FMA without `unsafe`.
#[inline]
fn microkernel(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (arow, brow) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        for (accrow, &av) in acc.iter_mut().zip(arow) {
            for (c, &bv) in accrow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
}

/// Walk one packed (mc × kc × nc) block with the register tile, adding
/// each tile's partial sums into `c` (row stride `n`). Zero-padded edge
/// lanes are computed but never written back, so padding cannot leak —
/// not even NaN × 0 artifacts.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    n: usize,
    i0: usize,
    mc: usize,
    j0: usize,
    nc: usize,
    kc: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
) {
    for jp in 0..nc.div_ceil(NR) {
        let jw = NR.min(nc - jp * NR);
        let bpanel = &pb[jp * kc * NR..][..kc * NR];
        for ip in 0..mc.div_ceil(MR) {
            let iw = MR.min(mc - ip * MR);
            let apanel = &pa[ip * kc * MR..][..kc * MR];
            let mut acc = [[0f32; NR]; MR];
            microkernel(apanel, bpanel, &mut acc);
            for (i, accrow) in acc.iter().enumerate().take(iw) {
                let crow = &mut c[(i0 + ip * MR + i) * n + j0 + jp * NR..][..jw];
                for (cv, &av) in crow.iter_mut().zip(accrow) {
                    *cv += av;
                }
            }
        }
    }
}

/// C(m×n) = A(m×k) · B(k×n) into `c` (cleared and resized in place),
/// reusing `pack`'s panel buffers across calls.
///
/// Accumulation per output element is strictly increasing-depth (see the
/// module docs), so results are deterministic for every shape and
/// bit-identical to [`gemm_ref`] whenever `k` fits one depth block.
pub fn gemm_into(
    m: usize,
    k: usize,
    n: usize,
    a: Operand<'_>,
    b: Operand<'_>,
    c: &mut Vec<f32>,
    pack: &mut GemmPack,
) {
    a.check(m, k, "gemm lhs");
    b.check(k, n, "gemm rhs");
    c.clear();
    c.resize(m * n, 0.0);
    for j0 in (0..n).step_by(NC) {
        let nc = NC.min(n - j0);
        for p0 in (0..k).step_by(KC) {
            let kc = KC.min(k - p0);
            pack_b(&b, k, n, p0, kc, j0, nc, &mut pack.pb);
            for i0 in (0..m).step_by(MC) {
                let mc = MC.min(m - i0);
                pack_a(&a, m, k, i0, mc, p0, kc, &mut pack.pa);
                macro_kernel(n, i0, mc, j0, nc, kc, &pack.pa, &pack.pb, c);
            }
        }
    }
}

/// Allocating dense GEMM: `C = A · B` through the blocked kernel with a
/// throwaway pack. Op-level convenience — the plan path passes its own
/// [`GemmPack`] to [`gemm_into`] so nothing allocates per step.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_into(m, k, n, Operand::Dense(a), Operand::Dense(b), &mut c, &mut GemmPack::new());
    c
}

/// Naive in-order triple-loop reference (no blocking, no skipping): the
/// correctness oracle for the property tests and the "before" side of the
/// bench's `native/gemm_speedup_*` lines.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "gemm lhs length");
    assert_eq!(b.len(), k * n, "gemm rhs length");
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..][..n];
        for (p, &av) in a[i * k..][..k].iter().enumerate() {
            let brow = &b[p * n..][..n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..len).map(f).collect()
    }

    fn mat(len: usize, mul: usize, md: usize, scale: f32, off: f32) -> Vec<f32> {
        fill(len, |i| ((i * mul) % md) as f32 * scale - off)
    }

    #[test]
    fn matches_reference_across_tile_edges() {
        // shapes straddling the MR/NR/MC/KC boundaries, incl. 1-wide edges
        let shapes =
            [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 9), (64, 16, 8), (65, 257, 17), (70, 300, 33)];
        for (m, k, n) in shapes {
            let a = mat(m * k, 7, 13, 0.25, 1.5);
            let b = mat(k * n, 5, 11, 0.5, 2.0);
            let got = gemm(m, k, n, &a, &b);
            let want = gemm_ref(m, k, n, &a, &b);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "shape ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn bitwise_reference_match_within_one_depth_block() {
        // k ≤ KC ⇒ a single depth block ⇒ the blocked summation order is
        // exactly the naive in-order chain
        let (m, k, n) = (13, KC, 21);
        let a = mat(m * k, 3, 17, 0.125, 1.0);
        let b = mat(k * n, 11, 19, 0.25, 2.25);
        assert_eq!(gemm(m, k, n, &a, &b), gemm_ref(m, k, n, &a, &b));
    }

    #[test]
    fn transposed_view_matches_materialized_transpose() {
        let (m, k, n) = (6, 10, 9);
        let at = mat(k * m, 7, 23, 0.2, 2.0); // underlying (k × m)
        let b = mat(k * n, 3, 13, 0.4, 1.2);
        let mut a = vec![0f32; m * k];
        for r in 0..m {
            for p in 0..k {
                a[r * k + p] = at[p * m + r];
            }
        }
        let mut c = Vec::new();
        let mut pk = GemmPack::new();
        gemm_into(m, k, n, Operand::Transposed(&at), Operand::Dense(&b), &mut c, &mut pk);
        assert_eq!(c, gemm(m, k, n, &a, &b), "A-side transposed view");
        let bt = mat(n * k, 9, 29, 0.3, 1.9); // underlying (n × k)
        let mut bm = vec![0f32; k * n];
        for p in 0..k {
            for j in 0..n {
                bm[p * n + j] = bt[j * k + p];
            }
        }
        gemm_into(m, k, n, Operand::Dense(&a), Operand::Transposed(&bt), &mut c, &mut pk);
        assert_eq!(c, gemm(m, k, n, &a, &bm), "B-side transposed view");
    }

    #[test]
    fn kept_views_equal_explicit_gathers_bitwise() {
        // KeptChannels: (bt·hw × k') gather of an NCHW gradient
        let (bt, cout, hw) = (2, 5, 6);
        let g = mat(bt * cout * hw, 7, 31, 0.2, 3.0);
        let keep = [0usize, 2, 4];
        let rows = bt * hw;
        let mut gck = vec![0f32; rows * keep.len()];
        for r in 0..rows {
            for (c, &o) in keep.iter().enumerate() {
                gck[r * keep.len() + c] = g[((r / hw) * cout + o) * hw + r % hw];
            }
        }
        let b = mat(keep.len() * 4, 3, 11, 0.5, 1.0);
        let view = Operand::KeptChannels { g: &g, keep: &keep, cout, hw };
        let (mut c1, mut c2) = (Vec::new(), Vec::new());
        let pk = &mut GemmPack::new();
        gemm_into(rows, keep.len(), 4, view, Operand::Dense(&b), &mut c1, pk);
        gemm_into(rows, keep.len(), 4, Operand::Dense(&gck), Operand::Dense(&b), &mut c2, pk);
        assert_eq!(c1, c2, "KeptChannels must equal the explicit gather");

        // KeptRows: kept rows of a (cout × n) weight matrix as the rhs
        let n = 7;
        let w = mat(cout * n, 5, 17, 0.25, 2.0);
        let mut wk = vec![0f32; keep.len() * n];
        for (r, &o) in keep.iter().enumerate() {
            wk[r * n..][..n].copy_from_slice(&w[o * n..][..n]);
        }
        let a = mat(3 * keep.len(), 9, 13, 0.4, 1.1);
        let rows_view = Operand::KeptRows { data: &w, keep: &keep };
        gemm_into(3, keep.len(), n, Operand::Dense(&a), rows_view, &mut c1, pk);
        gemm_into(3, keep.len(), n, Operand::Dense(&a), Operand::Dense(&wk), &mut c2, pk);
        assert_eq!(c1, c2, "KeptRows must equal the explicit gather");
    }

    #[test]
    fn empty_dims_and_empty_keep_are_fine() {
        assert!(gemm(0, 3, 4, &[], &[0.0; 12]).is_empty());
        assert_eq!(gemm(2, 0, 3, &[], &[]), vec![0.0; 6]);
        assert!(gemm(2, 3, 0, &[0.0; 6], &[]).is_empty());
        // an empty keep set is a legal (if useless) 0-column operand
        let g = vec![1.0f32; 8];
        let view = Operand::KeptChannels { g: &g, keep: &[], cout: 2, hw: 4 };
        let mut c = vec![99.0];
        gemm_into(4, 0, 3, view, Operand::Dense(&[]), &mut c, &mut GemmPack::new());
        assert_eq!(c, vec![0.0; 12]);
    }

    #[test]
    fn nan_and_inf_propagate_like_dense_math() {
        // 0·NaN and 0·Inf are NaN under dense semantics; the kernel must
        // not "optimize" them away (the old zero-skip bug)
        let c = gemm(1, 2, 2, &[0.0, 1.0], &[f32::NAN, 1.0, 2.0, 3.0]);
        assert!(c[0].is_nan(), "0·NaN must surface as NaN");
        assert_eq!(c[1], 3.0); // 0·1 + 1·3
        let c = gemm(1, 1, 1, &[0.0], &[f32::INFINITY]);
        assert!(c[0].is_nan(), "0·Inf must surface as NaN");
    }

    #[test]
    fn pack_caps_stay_flat_on_reuse() {
        let (m, k, n) = (37, 29, 23);
        let a = mat(m * k, 3, 7, 0.5, 1.0);
        let b = mat(k * n, 5, 9, 0.25, 0.5);
        let mut pack = GemmPack::new();
        let mut c = Vec::new();
        gemm_into(m, k, n, Operand::Dense(&a), Operand::Dense(&b), &mut c, &mut pack);
        let caps = pack.caps();
        gemm_into(m, k, n, Operand::Dense(&a), Operand::Dense(&b), &mut c, &mut pack);
        assert_eq!(pack.caps(), caps, "packing must reuse, not regrow");
    }
}
