//! §Perf hot-path bench: the compacted Pallas executables (true shrunk
//! matmuls) vs dense, plus the L3 overheads around each step (literal
//! construction, batch generation, manifest-order input assembly).
//!
//! This is the bench the EXPERIMENTS.md §Perf iteration log tracks.
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench perf_hotpath --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::data::{Loader, Split, SynthDataset};
    use ssprop::runtime::{f32_literal, Engine};
    use ssprop::util::bench::{bench, report};
    use ssprop::util::rng::Pcg;

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping perf_hotpath: {err}");
                return;
            }
        };
        println!("== §Perf hot path ==\n-- compacted Pallas conv bwd (true sparse) --");

        // compacted conv executables: dense vs d50 vs d80
        let g = engine.load("conv_pallas_dense").unwrap();
        let man = g.manifest.clone();
        let l = &man.layers.convs[0];
        let (bt, c, h, k, cin) = (man.batch, l.cout, l.hout, l.k, l.cin);
        let mut rng = Pcg::new(1, 1);
        let x: Vec<f32> = (0..bt * cin * h * h).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..c * cin * k * k).map(|_| rng.normal() * 0.1).collect();
        let b: Vec<f32> = (0..c).map(|_| rng.normal() * 0.1).collect();
        let inputs = vec![
            f32_literal(&[bt, cin, h, h], &x).unwrap(),
            f32_literal(&[c, cin, k, k], &w).unwrap(),
            f32_literal(&[c], &b).unwrap(),
        ];
        for name in ["conv_pallas_dense", "conv_pallas_d50", "conv_pallas_d80"] {
            let g = engine.load(name).unwrap();
            let r = bench(&format!("{name}/fwd+bwd"), 2, 12, Duration::from_secs(10), || {
                g.run(&inputs).unwrap();
            });
            report(&r);
        }

        println!("\n-- L3 overheads around the step --");
        let ds = SynthDataset::new(ssprop::data::spec("cifar10").unwrap(), 0);
        let loader = Loader::new(ds, Split::Train, 32);
        let order = loader.epoch_order(0);
        let r = bench("l3/batch_generation_bs32", 2, 30, Duration::from_secs(5), || {
            std::hint::black_box(loader.batch(&order, 0));
        });
        report(&r);

        let batch = loader.batch(&order, 0);
        let r = bench("l3/literal_from_batch", 2, 50, Duration::from_secs(5), || {
            std::hint::black_box(f32_literal(&[32, 3, 32, 32], &batch.x).unwrap());
        });
        report(&r);

        // end-to-end step vs its pieces: quantifies non-execute overhead
        let mut t = Trainer::new(&engine, TrainConfig::quick("resnet18_cifar10", 1, 1)).unwrap();
        let r = bench("l3/resnet18_step_total", 2, 15, Duration::from_secs(8), || {
            t.step(&batch, 0.8).unwrap();
        });
        report(&r);

        println!("\n-- substrate microbenches --");
        let manifest_text = std::fs::read_to_string(
            engine.artifacts_dir.join("resnet18_cifar10_train.manifest.json"),
        )
        .unwrap();
        let r = bench("json/parse_resnet18_manifest", 2, 30, Duration::from_secs(5), || {
            std::hint::black_box(ssprop::util::json::Json::parse(&manifest_text).unwrap());
        });
        report(&r);

        let mut rng2 = Pcg::new(9, 9);
        let r = bench("rng/normal_x10k", 2, 100, Duration::from_secs(3), || {
            let mut acc = 0.0f32;
            for _ in 0..10_000 {
                acc += rng2.normal();
            }
            std::hint::black_box(acc);
        });
        report(&r);
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("skipping perf_hotpath: PJRT runtime not compiled (build with --features pjrt)");
}

fn main() {
    run();
}
