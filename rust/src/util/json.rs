//! Minimal JSON parser/writer (serde is unavailable in the offline vendor
//! set, so this substrate is hand-rolled; see DESIGN.md S9).
//!
//! Supports the full JSON grammar needed by manifests/configs/results:
//! objects, arrays, strings (with escapes), numbers, bools, null. Numbers
//! are kept as f64 — all our schemas fit (shapes are < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value (numbers as f64, objects key-sorted).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (BTreeMap keeps writer output deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field `key`, if this is an object holding it.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The numeric value truncated to i64, if this is a number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    /// The numeric value as usize, if this is a non-negative number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key-value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.str_field("key")?` style helper that errors with the key name.
    pub fn str_field(&self, key: &str) -> Result<&str, String> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing str field {key:?}"))
    }
    /// Like [`Json::str_field`], for non-negative integer fields.
    pub fn usize_field(&self, key: &str) -> Result<usize, String> {
        self.get(key).and_then(Json::as_usize).ok_or_else(|| format!("missing int field {key:?}"))
    }
    /// Like [`Json::str_field`], for numeric fields.
    pub fn f64_field(&self, key: &str) -> Result<f64, String> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing num field {key:?}"))
    }
    /// Like [`Json::str_field`], for array fields.
    pub fn arr_field(&self, key: &str) -> Result<&[Json], String> {
        self.get(key).and_then(Json::as_arr).ok_or_else(|| format!("missing arr field {key:?}"))
    }

    // -- writer (via Display; `json.to_string()` comes from ToString) ---------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Convenience constructor: an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// Convenience constructor: a number.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
/// Convenience constructor: a string.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
/// Convenience constructor: an array.
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // fast path: consume a contiguous run of plain bytes
                    // (no quote/backslash) in one go — scanning per char
                    // with from_utf8 over the tail would be O(n²).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_values() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].str_field("b").unwrap(), "x");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s\"x"],"num":-7,"obj":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn integer_fidelity_in_writer() {
        let j = Json::parse("[1234567890123, 0.5]").unwrap();
        assert_eq!(j.to_string(), "[1234567890123,0.5]");
    }
}
