//! Integration tests over real AOT artifacts (require `make artifacts` and
//! a real PJRT `xla` crate patched in — the whole file is gated on the
//! `pjrt` feature and each test skips with a message when artifacts are
//! absent, so `cargo test` stays green on a bare runner).
//!
//! These exercise the full L3→runtime→compiled-HLO path: loading, manifest
//! binding, state feedback, schedulers, checkpoints, the DDPM sampler, and
//! the compacted Pallas executables.
#![cfg(feature = "pjrt")]

use std::sync::OnceLock;

use ssprop::coordinator::{checkpoint, TrainConfig, Trainer};
use ssprop::data::{Loader, Split, SynthDataset};
use ssprop::ddpm::DdpmTrainer;
use ssprop::runtime::{f32_literal, literal_scalar_f32, Engine, EngineError, Role};
use ssprop::schedule::{DropScheduler, Schedule};
use ssprop::util::rng::Pcg;

/// Shared engine; `None` (with an eprintln) when artifacts are missing so
/// every test downgrades to a skip instead of failing the suite.
fn engine() -> Option<&'static Engine> {
    static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| match Engine::auto() {
            Ok(e) => Some(e),
            Err(err) if err.downcast_ref::<EngineError>().is_some() => {
                eprintln!("skipping integration test: {err}");
                None
            }
            Err(err) => panic!("engine init failed: {err:?}"),
        })
        .as_ref()
}

macro_rules! engine_or_skip {
    () => {
        match engine() {
            Some(e) => e,
            None => return,
        }
    };
}

fn quick_cfg(artifact: &str, epochs: usize, ipe: usize) -> TrainConfig {
    let mut cfg = TrainConfig::quick(artifact, epochs, ipe);
    cfg.lr = 2e-3;
    cfg
}

#[test]
fn loads_artifact_and_manifest_consistent() {
    let e = engine_or_skip!();
    let g = e.load("cnn2_cifar100_train").unwrap();
    let man = &g.manifest;
    assert_eq!(man.kind, "train");
    assert_eq!(man.dataset, "cifar100");
    assert!(man.input_index(Role::DropRate).is_some());
    assert!(man.input_index(Role::Lr).is_some());
    // every param output feeds a param input
    for o in &man.outputs {
        if o.role.is_state() {
            assert!(o.feeds_input >= 0, "{} must feed an input", o.name);
            let i = &man.inputs[o.feeds_input as usize];
            assert_eq!(i.name, o.name);
            assert_eq!(i.shape, o.shape);
        }
    }
}

#[test]
fn single_step_runs_and_is_deterministic() {
    let e = engine_or_skip!();
    let mut t1 = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 2)).unwrap();
    let mut t2 = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 2)).unwrap();
    let order = t1.loader.epoch_order(0);
    let batch = t1.loader.batch(&order, 0);
    let (l1, a1) = t1.step(&batch, 0.0).unwrap();
    let (l2, a2) = t2.step(&batch, 0.0).unwrap();
    assert!(l1.is_finite() && (0.0..=1.0).contains(&a1));
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
}

#[test]
fn training_decreases_loss_dense_and_sparse() {
    let e = engine_or_skip!();
    for (schedule, target) in [
        (Schedule::Constant, 0.0),
        (Schedule::EpochBar { period_epochs: 2 }, 0.8),
    ] {
        let mut cfg = quick_cfg("cnn2_cifar100", 4, 12);
        cfg.scheduler = DropScheduler::new(schedule, target, 4, 12);
        let mut t = Trainer::new(e, cfg).unwrap();
        t.run().unwrap();
        let m = &t.metrics;
        let first = m.losses[..6].iter().sum::<f64>() / 6.0;
        let last = m.losses[m.losses.len() - 6..].iter().sum::<f64>() / 6.0;
        assert!(
            last < first,
            "target {target}: loss should fall ({first:.3} -> {last:.3})"
        );
        if target > 0.0 {
            assert!(m.flops_saving() > 0.3, "saving {}", m.flops_saving());
        } else {
            assert_eq!(m.flops_saving(), 0.0);
        }
    }
}

#[test]
fn sparse_step_diverges_from_dense_step() {
    let e = engine_or_skip!();
    let mut td = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 2)).unwrap();
    let mut ts = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 2)).unwrap();
    let order = td.loader.epoch_order(0);
    let batch = td.loader.batch(&order, 0);
    td.step(&batch, 0.0).unwrap();
    ts.step(&batch, 0.8).unwrap();
    // pick a conv weight leaf and compare
    let name = td
        .state
        .keys()
        .find(|k| k.starts_with("param") && k.contains("conv"))
        .unwrap()
        .clone();
    let wd = td.state[&name].to_vec::<f32>().unwrap();
    let ws = ts.state[&name].to_vec::<f32>().unwrap();
    assert_ne!(wd, ws, "sparse backward must change the update");
}

#[test]
fn eval_graph_runs_and_scores() {
    let e = engine_or_skip!();
    let mut t = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 4)).unwrap();
    let (loss, acc) = t.run().unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn resnet_artifact_trains() {
    let e = engine_or_skip!();
    let mut cfg = quick_cfg("resnet18_cifar10", 2, 4);
    cfg.scheduler = DropScheduler::paper_default(2, 4);
    let mut t = Trainer::new(e, cfg).unwrap();
    let (loss, _) = t.run().unwrap();
    assert!(loss.is_finite());
    // epoch 0 dense, epoch 1 at 0.8 -> mean drop 0.4
    assert!((t.metrics.mean_drop_rate() - 0.4).abs() < 1e-9);
}

#[test]
fn dropout_artifact_accepts_runtime_rate() {
    let e = engine_or_skip!();
    let mut cfg = quick_cfg("resnet50_cifar10", 1, 2);
    cfg.dropout_rate = 0.4;
    let mut t = Trainer::new(e, cfg).unwrap();
    let order = t.loader.epoch_order(0);
    let batch = t.loader.batch(&order, 0);
    let (loss, _) = t.step(&batch, 0.0).unwrap();
    assert!(loss.is_finite());
}

#[test]
fn checkpoint_roundtrip_preserves_training() {
    let e = engine_or_skip!();
    let dir = std::env::temp_dir().join("ssprop_int_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("state.tstore");

    let mut t = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 4)).unwrap();
    t.run().unwrap();
    checkpoint::save(&path, &t.state, "cnn2_cifar100", 1).unwrap();
    let (state, artifact, epoch) = checkpoint::load(&path).unwrap();
    assert_eq!(artifact, "cnn2_cifar100");
    assert_eq!(epoch, 1);
    assert_eq!(state.len(), t.state.len());

    // restored state continues training identically to in-memory state
    let mut t2 = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 4)).unwrap();
    t2.state = state;
    let order = t.loader.epoch_order(5);
    let batch = t.loader.batch(&order, 0);
    let (l1, _) = t.step(&batch, 0.0).unwrap();
    let (l2, _) = t2.step(&batch, 0.0).unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn ddpm_trains_and_samples() {
    let e = engine_or_skip!();
    let mut tr = DdpmTrainer::new(e, "mnist", 2e-3, 0).unwrap();
    let sched = DropScheduler::paper_default(2, 8);
    let loss = tr.train(16, &sched).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let samples = tr.sample(3).unwrap();
    assert_eq!(samples.len(), tr.denoise_graph.manifest.batch);
    for s in &samples {
        assert_eq!(s.len(), 28 * 28);
        assert!(s.iter().all(|v| v.is_finite()));
    }
    assert!(tr.metrics.flops_saving() > 0.2);
}

#[test]
fn compacted_pallas_executables_match_semantics() {
    let e = engine_or_skip!();
    let dense = e.load("conv_pallas_dense").unwrap();
    let d80 = e.load("conv_pallas_d80").unwrap();
    let man = &dense.manifest;
    let (bt, c, h) = (man.batch, man.layers.convs[0].cout, man.layers.convs[0].hout);
    let k = man.layers.convs[0].k;
    let cin = man.layers.convs[0].cin;

    let mut rng = Pcg::new(5, 1);
    let x: Vec<f32> = (0..bt * cin * h * h).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..c * cin * k * k).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..c).map(|_| rng.normal() * 0.1).collect();
    let inputs = vec![
        f32_literal(&[bt, cin, h, h], &x).unwrap(),
        f32_literal(&[c, cin, k, k], &w).unwrap(),
        f32_literal(&[c], &b).unwrap(),
    ];
    let out_dense = dense.run(&inputs).unwrap();
    let out_d80 = d80.run(&inputs).unwrap();

    // loss (output 3) identical: forward is dense in both
    let ld = literal_scalar_f32(&out_dense[3]).unwrap();
    let ls = literal_scalar_f32(&out_d80[3]).unwrap();
    assert!((ld - ls).abs() <= 1e-2 * ld.abs().max(1.0), "fwd must match: {ld} vs {ls}");

    // dW (output 1): dense has all rows nonzero, d80 exactly ceil(0.2*C)
    let count_rows = |lit: &xla::Literal| -> usize {
        let v = lit.to_vec::<f32>().unwrap();
        let row = cin * k * k;
        (0..c).filter(|i| v[i * row..(i + 1) * row].iter().any(|x| *x != 0.0)).count()
    };
    assert_eq!(count_rows(&out_dense[1]), c);
    let keep = ssprop::flops::keep_channels(c, 0.8);
    assert_eq!(count_rows(&out_d80[1]), keep);
}

#[test]
fn prefetched_loader_feeds_trainer_consistently() {
    let e = engine_or_skip!();
    let t = Trainer::new(e, quick_cfg("cnn2_cifar100", 1, 4)).unwrap();
    let rx = t.loader.prefetch_epoch(0, 2);
    let order = t.loader.epoch_order(0);
    for (i, b) in rx.iter().take(4).enumerate() {
        assert_eq!(b.x, t.loader.batch(&order, i).x);
    }
}

#[test]
fn celeba_multilabel_artifact_runs() {
    let e = engine_or_skip!();
    let mut t = Trainer::new(e, quick_cfg("resnet18_celeba", 1, 2)).unwrap();
    let order = t.loader.epoch_order(0);
    let batch = t.loader.batch(&order, 0);
    assert!(!batch.y_multi.is_empty());
    let (loss, acc) = t.step(&batch, 0.5).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

#[test]
fn fig2_variant_artifacts_load_and_step() {
    let e = engine_or_skip!();
    for suffix in ["_hw", "_all", "_random"] {
        let name = format!("resnet18_cifar10{suffix}");
        let mut t = Trainer::new(e, quick_cfg(&name, 1, 2)).unwrap();
        let order = t.loader.epoch_order(0);
        let batch = t.loader.batch(&order, 0);
        let (loss, _) = t.step(&batch, 0.6).unwrap();
        assert!(loss.is_finite(), "{name}");
    }
}

#[test]
fn python_written_tensorstore_reads_back() {
    let e = engine_or_skip!();
    let init = e.load_init("cnn2_cifar100_train").unwrap();
    assert!(!init.is_empty());
    let names: Vec<&str> = init.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names.iter().any(|n| n.starts_with("param")));
    assert!(names.iter().any(|n| n.starts_with("opt")));
    assert!(names.iter().any(|n| n.starts_with("bn")));
    for (_, t) in &init {
        assert_eq!(t.data.len(), t.len() * 4);
    }
}

#[test]
fn loader_matches_manifest_geometry() {
    let e = engine_or_skip!();
    let g = e.load("resnet18_cifar10_train").unwrap();
    let man = &g.manifest;
    let ds = SynthDataset::new(ssprop::data::spec(&man.dataset).unwrap(), 0);
    let loader = Loader::new(ds, Split::Train, man.batch);
    let order = loader.epoch_order(0);
    let b = loader.batch(&order, 0);
    let x_spec = &man.inputs[man.input_index(Role::DataX).unwrap()];
    assert_eq!(b.x.len(), x_spec.shape.iter().product::<usize>());
}
