//! Per-layer plan/workspace for the conv hot path (the ROADMAP "cols built
//! twice" item): one [`Conv2dPlan`] per conv layer holds every reusable
//! buffer the planned forward/backward needs, so a training step builds the
//! (M, N) im2col matrix exactly once per layer — the forward materializes
//! it, the sparse backward's dW GEMM consumes it — and the next step
//! reallocates nothing (buffers keep their capacity across steps).
//!
//! Plans are also the natural unit to shard once batching/multi-threading
//! lands: each holds everything one layer's fwd+bwd touches.

use super::im2col::im2col_into;
use super::sparse::SparseBwdWorkspace;
use super::Conv2d;

/// Length + endpoint-bits fingerprint of an input slice (collision-proof
/// enough for the always-on stale-cols guard, free enough for the hot
/// path).
fn fingerprint(x: &[f32]) -> (usize, u64) {
    let head = x.first().map_or(0, |v| v.to_bits() as u64);
    let tail = x.last().map_or(0, |v| v.to_bits() as u64);
    (x.len(), head | (tail << 32))
}

/// Reusable buffers for the planned conv path of one layer.
///
/// A plan is keyed to one [`Conv2d`] geometry; [`Conv2dPlan::ensure`]
/// re-keys it in place (keeping allocated capacity) when the geometry
/// changes, e.g. at a new batch size. The cached `cols` matrix is keyed to
/// the `x` of the most recent planned forward and is *consumed* by the next
/// planned backward — a backward without a preceding forward gathers its
/// own columns, so the pair is always numerically identical to the unfused
/// op-level route.
#[derive(Debug, Clone)]
pub struct Conv2dPlan {
    cfg: Conv2d,
    /// (M, N) im2col of the layer input, live between fwd and bwd.
    pub(crate) cols: Vec<f32>,
    pub(crate) cols_valid: bool,
    cols_builds: u64,
    /// Cheap fingerprint of the input the cached cols were built from
    /// (checked always-on by the planned backward to catch cache misuse).
    cols_src: (usize, u64),
    /// (N, Cout) col-form weights for the forward GEMM.
    pub(crate) cw: Vec<f32>,
    /// (M, Cout) forward GEMM output before the NCHW transpose.
    pub(crate) ycol: Vec<f32>,
    /// Sparse-backward scratch (compacted dW/dX accumulators) plus the
    /// GEMM pack panels. Living here — one set per plan — keeps the
    /// parallel executor's per-worker plans lock-free: no shared packing
    /// state, no contention on the hot path.
    pub(crate) ws: SparseBwdWorkspace,
}

impl Conv2dPlan {
    /// An empty plan keyed to `cfg` (buffers grow lazily on first use).
    pub fn new(cfg: Conv2d) -> Conv2dPlan {
        Conv2dPlan {
            cfg,
            cols: Vec::new(),
            cols_valid: false,
            cols_builds: 0,
            cols_src: (0, 0),
            cw: Vec::new(),
            ycol: Vec::new(),
            ws: SparseBwdWorkspace::default(),
        }
    }

    /// The geometry this plan is currently keyed to.
    pub fn cfg(&self) -> &Conv2d {
        &self.cfg
    }

    /// Re-key the plan to `cfg`, invalidating any cached columns but
    /// keeping every buffer's capacity. No-op geometry-wise when unchanged.
    pub fn ensure(&mut self, cfg: Conv2d) {
        self.cfg = cfg;
        self.cols_valid = false;
    }

    /// Drop the cached columns (call when `x` changed since the forward).
    pub fn invalidate_cols(&mut self) {
        self.cols_valid = false;
    }

    /// How many times this plan materialized its im2col matrix. On the
    /// fused path this advances once per fwd+bwd pair — the
    /// workspace-reuse tests pin `train_step` to exactly one build per
    /// layer per step.
    pub fn cols_builds(&self) -> u64 {
        self.cols_builds
    }

    /// Capacity of every buffer (cols, cw, ycol, then the backward
    /// scratch: dwk, dcols, and the two GEMM pack panels). Regression
    /// tests assert these stay flat across steps.
    pub fn buffer_caps(&self) -> [usize; 7] {
        let [dwk, dcols, pa, pb] = self.ws.caps();
        [self.cols.capacity(), self.cw.capacity(), self.ycol.capacity(), dwk, dcols, pa, pb]
    }

    /// Materialize im2col(x) into the plan's column buffer and mark it live.
    pub(crate) fn build_cols(&mut self, x: &[f32]) {
        im2col_into(&self.cfg, x, &mut self.cols);
        self.cols_valid = true;
        self.cols_builds += 1;
        self.cols_src = fingerprint(x);
    }

    /// Stale-cols guard: were the cached columns built from this `x`? (A
    /// cheap length + endpoint fingerprint — catches the cache-misuse
    /// pattern of a forward on one input followed by a backward on
    /// another. Checked always-on, release builds included.)
    pub(crate) fn cols_match(&self, x: &[f32]) -> bool {
        self.cols_valid && self.cols_src == fingerprint(x)
    }

    /// Disjoint borrows of the cached columns and the backward scratch
    /// (the dW GEMM reads one while writing the other).
    pub(crate) fn split_cols_ws(&mut self) -> (&[f32], &mut SparseBwdWorkspace) {
        (&self.cols, &mut self.ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Conv2d {
        Conv2d { bt: 1, cin: 2, h: 4, w: 4, cout: 3, k: 3, stride: 1, padding: 1 }
    }

    #[test]
    fn build_counts_and_validity() {
        let c = cfg();
        let mut plan = Conv2dPlan::new(c);
        assert_eq!(plan.cols_builds(), 0);
        let x = vec![1f32; c.in_len()];
        plan.build_cols(&x);
        assert!(plan.cols_valid);
        assert_eq!(plan.cols_builds(), 1);
        assert_eq!(plan.cols.len(), c.m() * c.n());
        plan.invalidate_cols();
        assert!(!plan.cols_valid);
        assert_eq!(plan.cols_builds(), 1, "invalidation is not a rebuild");
    }

    #[test]
    fn cols_match_fingerprints_the_input() {
        let c = cfg();
        let mut plan = Conv2dPlan::new(c);
        let x = vec![1f32; c.in_len()];
        plan.build_cols(&x);
        assert!(plan.cols_match(&x));
        let mut other = x.clone();
        *other.last_mut().unwrap() = 2.0;
        assert!(!plan.cols_match(&other), "a different input must not match the cache");
        plan.invalidate_cols();
        assert!(!plan.cols_match(&x), "an invalidated cache matches nothing");
    }

    #[test]
    fn ensure_rekeys_without_shrinking_buffers() {
        let big = cfg();
        let mut plan = Conv2dPlan::new(big);
        plan.build_cols(&vec![0f32; big.in_len()]);
        let caps = plan.buffer_caps();
        let small = Conv2d { bt: 1, cin: 1, h: 3, w: 3, cout: 2, k: 3, stride: 1, padding: 1 };
        plan.ensure(small);
        assert_eq!(plan.cfg(), &small);
        assert!(!plan.cols_valid, "re-keying must drop the cached cols");
        plan.build_cols(&vec![0f32; small.in_len()]);
        assert!(plan.buffer_caps()[0] >= small.m() * small.n());
        assert_eq!(plan.buffer_caps()[0], caps[0], "capacity survives re-keying");
    }

}
