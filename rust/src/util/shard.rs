//! Deterministic contiguous partitioning, shared by the data plane (batch
//! slicing) and the parallel executor (batch sharding). Keeping the split
//! rule in one place guarantees `Batch::shard` and the executor agree on
//! which examples land in which shard.

use std::ops::Range;

/// Split `0..n` into at most `parts` contiguous non-empty ranges whose
/// lengths differ by at most one — the first `n % parts` ranges take the
/// extra element, so non-divisible sizes shard without padding or panics.
/// Returns fewer than `parts` ranges when `n < parts` (never an empty
/// range) and no ranges at all when `n == 0`; `parts` is clamped to ≥ 1.
pub fn shard_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let (base, extra) = (n / parts, n % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ranges must tile 0..n in order with no gaps or overlaps.
    fn assert_covers(n: usize, parts: usize) {
        let ranges = shard_ranges(n, parts);
        let mut pos = 0;
        for r in &ranges {
            assert_eq!(r.start, pos, "gap/overlap at {r:?} (n={n}, parts={parts})");
            assert!(r.end > r.start, "empty shard {r:?} (n={n}, parts={parts})");
            pos = r.end;
        }
        assert_eq!(pos, n, "ranges must cover 0..{n}");
    }

    #[test]
    fn divisible_split_is_even() {
        let r = shard_ranges(8, 4);
        assert_eq!(r, vec![0..2, 2..4, 4..6, 6..8]);
    }

    #[test]
    fn remainder_goes_to_leading_shards() {
        // 10 over 4: sizes 3,3,2,2 — lengths differ by at most one
        let r = shard_ranges(10, 4);
        assert_eq!(r, vec![0..3, 3..6, 6..8, 8..10]);
    }

    #[test]
    fn more_parts_than_items_drops_empty_shards() {
        let r = shard_ranges(2, 4);
        assert_eq!(r, vec![0..1, 1..2]);
    }

    #[test]
    fn zero_items_and_zero_parts() {
        assert!(shard_ranges(0, 4).is_empty());
        assert_eq!(shard_ranges(3, 0), vec![0..3], "parts clamps to 1");
        assert_eq!(shard_ranges(3, 1), vec![0..3]);
    }

    #[test]
    fn always_covers_without_gaps() {
        for n in 0..40 {
            for parts in 0..10 {
                assert_covers(n, parts);
            }
        }
    }
}
