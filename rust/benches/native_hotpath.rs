//! Native-backend hot path: img2col conv forward, dense vs compacted
//! sparse backward, the raw GEMM, and — the headline — the fused
//! plan/workspace fwd+bwd vs the unfused op calls (the fused path builds
//! each (M, N) im2col matrix once per step instead of twice and reuses
//! every scratch buffer). Runs on the default build (no PJRT, no
//! artifacts), so any machine can baseline it:
//!
//! Run: `cargo bench --bench native_hotpath`
//!
//! `--smoke` shrinks warmup/iterations/budget to a CI-sized run that still
//! exercises every path (used by the CI release job). `--model SPEC`
//! restricts the run to the data-parallel executor section for that model
//! zoo preset (`simple-cnn-d4-w16`, `vgg-tiny`, `dropout-cnn-w8-p25`,
//! `resnet-tiny-w8-b1`, ...) and tags the `native/{serial,parallel}_step_*`
//! / `native/parallel_speedup_*` lines with the spec, so CI can compare the
//! sharding win across architectures; each per-model run closes with a
//! `native/bwd_speedup_{spec}_d80` line (serial dense step / serial sparse
//! step at the paper's D* = 0.8 — the model-level sparse-backward saving,
//! including through residual graphs and BatchNorm).

use std::time::Duration;

use ssprop::backend::im2col::im2col;
use ssprop::backend::sparse::{select_channels, sparse_bwd_with_cols, SparseBwdWorkspace};
use ssprop::backend::{
    build_model, parse_model_spec, Backend, Conv2d, Conv2dPlan, ExecConfig, NativeBackend,
    ParallelExecutor, Sequential,
};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::util::bench::{bench, report};
use ssprop::util::rng::Pcg;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let model_arg = argv
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| argv.get(i + 1))
        .map(String::as_str);
    let (warm, iters, secs) = if smoke { (1, 3, 1) } else { (2, 20, 6) };
    let budget = Duration::from_secs(secs);

    // With an explicit --model, run only the data-parallel executor
    // section for that preset (CI invokes this once per zoo model).
    if let Some(spec) = model_arg {
        println!("== native backend hot path{} ==", if smoke { " (smoke)" } else { "" });
        parallel_section(spec, warm, iters, budget);
        return;
    }

    let be = NativeBackend::new();
    println!("== native backend hot path{} ==", if smoke { " (smoke)" } else { "" });
    println!("-- conv fwd/bwd (bt 16, 32ch, 16x16, k3) --");

    let cfg = Conv2d { bt: 16, cin: 32, h: 16, w: 16, cout: 32, k: 3, stride: 1, padding: 1 };
    let mut rng = Pcg::new(3, 3);
    let x: Vec<f32> = (0..cfg.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cfg.w_len()).map(|_| rng.normal() * 0.1).collect();
    let b: Vec<f32> = (0..cfg.cout).map(|_| rng.normal() * 0.1).collect();
    let g: Vec<f32> = (0..cfg.out_len()).map(|_| rng.normal()).collect();

    let r = bench("native/conv_fwd", warm, iters, budget, || {
        std::hint::black_box(be.conv2d_fwd(&cfg, &x, &w, Some(&b)));
    });
    report(&r);

    for (label, d, need_dx) in [
        ("dense", 0.0f64, true),
        ("d50", 0.5, true),
        ("d80", 0.8, true),
        ("d80_nodx", 0.8, false),
    ] {
        let r = bench(&format!("native/conv_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, need_dx));
        });
        report(&r);
    }

    // The tentpole comparison, two cuts:
    //  * full layer step — unfused op calls (two im2col builds, fresh
    //    buffers every call) vs the fused plan path (one build, workspace
    //    reused across iterations);
    //  * backward only — rebuild-the-cols (`conv2d_bwd_ssprop`) vs the
    //    cached-cols workspace backward the fused path runs. At the
    //    paper's drop rates the compacted GEMMs shrink, so the removed
    //    patch gather dominates and this ratio is the headline saving.
    println!("\n-- fused plan path vs unfused op calls --");
    let pairs = [("dense", 0.0f64, true), ("d80", 0.8, true), ("d80_nodx", 0.8, false)];
    for (label, d, need_dx) in pairs {
        let un = bench(&format!("native/unfused_fwd_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_fwd(&cfg, &x, &w, Some(&b)));
            std::hint::black_box(be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, need_dx));
        });
        report(&un);
        let mut plan = Conv2dPlan::new(cfg);
        let fu = bench(&format!("native/fused_fwd_bwd_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_fwd_bwd(&mut plan, &x, &w, Some(&b), &g, d, need_dx));
        });
        report(&fu);
        let bwd = bench(&format!("native/bwd_rebuild_cols_{label}"), warm, iters, budget, || {
            std::hint::black_box(be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, need_dx));
        });
        report(&bwd);
        let cols = im2col(&cfg, &x);
        let mut ws = SparseBwdWorkspace::default();
        let cached = bench(&format!("native/bwd_cached_cols_{label}"), warm, iters, budget, || {
            let keep = select_channels(&cfg, &g, d);
            let out = sparse_bwd_with_cols(&cfg, &cols, &w, &g, &keep, need_dx, &mut ws);
            std::hint::black_box(out);
        });
        report(&cached);
        println!(
            "{:<48} {:>11.2}x (unfused / fused median)",
            format!("native/fused_speedup_{label}"),
            un.median_ns / fu.median_ns
        );
        println!(
            "{:<48} {:>11.2}x (rebuild / cached median)",
            format!("native/bwd_speedup_{label}"),
            bwd.median_ns / cached.median_ns
        );
    }

    println!("\n-- raw GEMM (256x288 . 288x128) --");
    let (m, k, n) = (256, 288, 128);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let bb: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let r = bench("native/gemm_256x288x128", warm, iters, budget, || {
        std::hint::black_box(be.gemm(m, k, n, &a, &bb));
    });
    report(&r);

    println!("\n-- end-to-end SimpleCNN training step (planned path) --");
    for (label, d) in [("dense", 0.0f64), ("d80", 0.8)] {
        let mut t = NativeTrainer::new(NativeTrainConfig::quick("cifar10", 1, 1)).unwrap();
        let order = t.loader.epoch_order(0);
        let batch = t.loader.batch(&order, 0);
        let r = bench(&format!("native/train_step_{label}"), warm, iters, budget, || {
            t.step(&batch, d).unwrap();
        });
        report(&r);
    }

    parallel_section("simple-cnn-d4-w16", warm, iters, budget);
}

/// Data-parallel executor vs the serial step for one zoo preset on a
/// cifar10-sized input (3x32x32, bt 32). Each parallel step shards the
/// batch over the worker count, runs the layer graph per shard with
/// globally-reduced channel selection (and, for presets with BatchNorm,
/// globally-reduced batch statistics), and tree-reduces gradients;
/// `native/parallel_speedup_{spec}_*` is the serial/parallel median ratio
/// (> 1 = the sharded step is faster on this machine). The closing
/// `native/bwd_speedup_{spec}_d80` line is the whole-model sparse-backward
/// saving at the paper's D* = 0.8: serial dense step / serial d80 step —
/// tracked per preset so the residual-graph saving is visible next to the
/// plain conv stacks.
fn parallel_section(spec: &str, warm: usize, iters: usize, budget: Duration) {
    let be = NativeBackend::new();
    let parsed = parse_model_spec(spec).expect("--model spec");
    let slug = parsed.canonical();
    let build = || -> Sequential { build_model(&parsed, 3, 32, 10, 11).expect("zoo build") };
    println!("\n-- data-parallel executor ({slug}, 3x32x32, bt 32) --");
    let n_in = 3 * 32 * 32;
    let bt = 32;
    let mut prng = Pcg::new(17, 9);
    let px: Vec<f32> = (0..bt * n_in).map(|_| prng.normal()).collect();
    let py: Vec<i32> = (0..bt).map(|i| (i % 10) as i32).collect();
    let mut serial_medians = [0f64; 2];
    for (idx, (label, d)) in [("dense", 0.0f64), ("d80", 0.8)].into_iter().enumerate() {
        let mut serial = build();
        let name = format!("native/serial_step_{slug}_{label}");
        let base = bench(&name, warm, iters, budget, || {
            serial.train_step(&be, &px, &py, d, 0.01).unwrap();
        });
        report(&base);
        serial_medians[idx] = base.median_ns;
        for threads in [2usize, 4] {
            let mut model = build();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let name = format!("native/parallel_step_{slug}_{label}_t{threads}");
            let r = bench(&name, warm, iters, budget, || {
                exec.train_step(&mut model, &be, &px, &py, d, 0.01).unwrap();
            });
            report(&r);
            println!(
                "{:<48} {:>11.2}x (serial / t{threads} median)",
                format!("native/parallel_speedup_{slug}_{label}_t{threads}"),
                base.median_ns / r.median_ns
            );
        }
    }
    println!(
        "{:<48} {:>11.2}x (serial dense / serial d80 median)",
        format!("native/bwd_speedup_{slug}_d80"),
        serial_medians[0] / serial_medians[1]
    );
}
