//! DDPM substrate (S19): β-schedule math and the ancestral sampling loop
//! (Ho et al. 2020, Alg. 2), driving the AOT `*_denoise` graph through PJRT.
//!
//! Training runs through the generic coordinator machinery; only the eps
//! prediction ε_θ(x_t, t) is a compiled graph — the posterior update runs
//! in rust with constants exported from the manifest's beta schedule so
//! both sides are bit-identical.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{run_with_state, TrainMetrics};
use crate::data::SynthDataset;
use crate::runtime::{
    f32_literal, i32_literal, literal_scalar_f32, scalar_f32, tensor_to_literal, u32_literal,
    Engine, LoadedGraph, Role,
};
use crate::schedule::DropScheduler;
use crate::util::rng::Pcg;

/// DDPM training job (Table 5 rows).
pub struct DdpmTrainer {
    /// Compiled training-step graph.
    pub train_graph: Arc<LoadedGraph>,
    /// Compiled ε-prediction graph driven by the sampling loop.
    pub denoise_graph: Arc<LoadedGraph>,
    /// Looped-back state leaves (params, optimizer moments).
    pub state: HashMap<String, xla::Literal>,
    /// Target-distribution dataset.
    pub ds: SynthDataset,
    /// Loss curve + FLOPs ledger.
    pub metrics: TrainMetrics,
    /// Learning rate fed to the step's `lr` input.
    pub lr: f64,
    rng: Pcg,
}

impl DdpmTrainer {
    /// Load the `ddpm_<dataset>_{train,denoise}` graphs and initial state.
    pub fn new(engine: &Engine, dataset: &str, lr: f64, seed: u64) -> Result<DdpmTrainer> {
        let train_graph = engine.load(&format!("ddpm_{dataset}_train"))?;
        let denoise_graph = engine.load(&format!("ddpm_{dataset}_denoise"))?;
        let spec = crate::data::spec(dataset).context("unknown dataset")?;
        let ds = SynthDataset::new(spec, seed);
        let mut state = HashMap::new();
        for (name, t) in engine.load_init(&format!("ddpm_{dataset}_train"))? {
            state.insert(name, tensor_to_literal(&t)?);
        }
        Ok(DdpmTrainer {
            train_graph,
            denoise_graph,
            state,
            ds,
            metrics: TrainMetrics::default(),
            lr,
            rng: Pcg::new(seed ^ 0xDDD, 13),
        })
    }

    /// Train for `iters` iterations under `sched`; returns final loss.
    pub fn train(&mut self, iters: usize, sched: &DropScheduler) -> Result<f64> {
        let man = self.train_graph.manifest.clone();
        let batch = man.batch;
        let n = man.channels * man.img * man.img;
        let mut loss = f64::NAN;
        let t0 = Instant::now();
        for it in 0..iters {
            let d = sched.rate_at(it);
            // assemble a training batch of target images
            let mut x = Vec::with_capacity(batch * n);
            for b in 0..batch {
                let idx = self.rng.below(self.ds.spec.train_n as u64) as usize;
                let _ = b;
                x.extend(self.ds.ddpm_example(idx));
            }
            let key = self.rng.jax_key();
            let mut ephemeral: Vec<(usize, xla::Literal)> = Vec::new();
            for (idx, spec) in man.inputs.iter().enumerate() {
                let lit = match spec.role {
                    Role::Param | Role::Opt => continue,
                    Role::DataX => f32_literal(&spec.shape, &x)?,
                    Role::Lr => scalar_f32(self.lr as f32)?,
                    Role::DropRate => scalar_f32(d as f32)?,
                    Role::Key => u32_literal(&spec.shape, &key)?,
                    other => bail!("unexpected ddpm train input role {other:?}"),
                };
                ephemeral.push((idx, lit));
            }
            let outs = run_with_state(&self.train_graph, &self.state, ephemeral)?;
            for (o, lit) in man.outputs.iter().zip(outs) {
                if o.feeds_input >= 0 {
                    self.state.insert(o.name.clone(), lit);
                } else if o.role == Role::Loss {
                    loss = literal_scalar_f32(&lit)? as f64;
                }
            }
            self.metrics.record_iter(loss, f64::NAN, d, &man.layers, man.batch);
        }
        self.metrics.record_epoch(t0.elapsed());
        Ok(loss)
    }

    /// Ancestral sampling (Alg. 2): returns `batch` images (flattened CHW).
    pub fn sample(&mut self, seed: u64) -> Result<Vec<Vec<f32>>> {
        let man = self.denoise_graph.manifest.clone();
        let tman = &self.train_graph.manifest;
        let (batch, n) = (man.batch, man.channels * man.img * man.img);
        let timesteps = tman.timesteps;
        let abar = &tman.alpha_bar;
        let betas = &tman.betas;
        if abar.len() != timesteps || betas.len() != timesteps {
            bail!("beta schedule missing from manifest");
        }
        let mut rng = Pcg::new(seed ^ 0x5A3F, 17);
        let mut x: Vec<f32> = (0..batch * n).map(|_| rng.normal()).collect();
        for t in (0..timesteps).rev() {
            let eps = self.predict_eps(&x, t, batch)?;
            let alpha_t = 1.0 - betas[t];
            let abar_t = abar[t];
            let c1 = 1.0 / alpha_t.sqrt();
            let c2 = betas[t] / (1.0 - abar_t).sqrt();
            let sigma = if t > 0 { betas[t].sqrt() } else { 0.0 };
            for i in 0..x.len() {
                let mu = c1 as f32 * (x[i] - c2 as f32 * eps[i]);
                x[i] = mu + sigma as f32 * if t > 0 { rng.normal() } else { 0.0 };
            }
        }
        Ok(x.chunks(n).map(|c| c.to_vec()).collect())
    }

    fn predict_eps(&self, x: &[f32], t: usize, batch: usize) -> Result<Vec<f32>> {
        let man = &self.denoise_graph.manifest;
        let tvec = vec![t as i32; batch];
        let mut ephemeral: Vec<(usize, xla::Literal)> = Vec::new();
        for (idx, spec) in man.inputs.iter().enumerate() {
            let lit = match spec.role {
                Role::Param => continue,
                Role::DataX => f32_literal(&spec.shape, x)?,
                Role::T => i32_literal(&spec.shape, &tvec)?,
                other => bail!("unexpected denoise input role {other:?}"),
            };
            ephemeral.push((idx, lit));
        }
        let outs = run_with_state(&self.denoise_graph, &self.state, ephemeral)?;
        outs[man.output_index(Role::Eps).context("eps output")?]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    /// Real data reference batch for FID-proxy evaluation.
    pub fn real_batch(&self, count: usize) -> Vec<Vec<f32>> {
        (0..count).map(|i| self.ds.ddpm_example(i)).collect()
    }
}

/// Write a grid of generated samples as a PGM image (Fig. 3 artifact).
pub fn write_pgm_grid(path: &str, images: &[Vec<f32>], img: usize, channels: usize) -> Result<()> {
    let cols = (images.len() as f64).sqrt().ceil() as usize;
    let rows = images.len().div_ceil(cols);
    let (gw, gh) = (cols * (img + 2), rows * (img + 2));
    let mut canvas = vec![0u8; gw * gh];
    for (i, im) in images.iter().enumerate() {
        let (r, c) = (i / cols, i % cols);
        for y in 0..img {
            for x in 0..img {
                // grayscale: mean over channels, map [-1,1] -> [0,255]
                let mut v = 0.0;
                for ch in 0..channels {
                    v += im[(ch * img + y) * img + x];
                }
                v /= channels as f32;
                let px = ((v * 0.5 + 0.5).clamp(0.0, 1.0) * 255.0) as u8;
                canvas[(r * (img + 2) + y + 1) * gw + c * (img + 2) + x + 1] = px;
            }
        }
    }
    let mut out = format!("P5\n{gw} {gh}\n255\n").into_bytes();
    out.extend(canvas);
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_grid_writes_valid_header() {
        let dir = std::env::temp_dir().join("ssprop_pgm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.pgm");
        let imgs = vec![vec![0.0f32; 4 * 4]; 4];
        write_pgm_grid(p.to_str().unwrap(), &imgs, 4, 1).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P5\n12 12\n255\n"));
        assert_eq!(data.len(), b"P5\n12 12\n255\n".len() + 144);
    }
}
