//! Bench for paper Table 5: DDPM training-step and sampling-chain latency,
//! dense vs ssProp, plus the per-iteration analytic FLOPs of our tiny UNet.
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench table5_generation --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::ddpm::DdpmTrainer;
    use ssprop::runtime::Engine;
    use ssprop::schedule::{DropScheduler, Schedule};
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping table5_generation: {err}");
                return;
            }
        };
        println!("== Table 5 bench: DDPM step latency, dense vs ssProp ==\n");

        for ds in ["mnist"] {
            for (mode, target) in [("dense", 0.0f64), ("ssprop_d80", 0.8)] {
                let mut tr = DdpmTrainer::new(&engine, ds, 1e-3, 0).unwrap();
                let sched = DropScheduler::new(Schedule::Constant, target, 1, 1);
                tr.train(1, &sched).unwrap(); // warm
                let r = bench(
                    &format!("ddpm_{ds}/{mode}/train_step"),
                    1,
                    12,
                    Duration::from_secs(10),
                    || {
                        tr.train(1, &sched).unwrap();
                    },
                );
                report(&r);
                let man = tr.train_graph.manifest.clone();
                println!(
                    "  analytic bwd FLOPs/iter: dense {:.3} B, at D=0.8 {:.3} B",
                    man.bwd_flops(0.0) / 1e9,
                    man.bwd_flops(0.8) / 1e9
                );
            }

            // sampling cost (denoise-step latency dominates Alg. 2)
            let mut tr = DdpmTrainer::new(&engine, ds, 1e-3, 0).unwrap();
            let r = bench(
                &format!("ddpm_{ds}/sample_full_chain"),
                1,
                3,
                Duration::from_secs(30),
                || {
                    tr.sample(1).unwrap();
                },
            );
            report(&r);
        }
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!("skipping table5_generation: PJRT runtime not compiled (build with --features pjrt)");
}

fn main() {
    run();
}
