//! Bench for paper Table 7: sparse ResNet-50 vs iso-FLOPs ResNet-26. The
//! analytic columns reproduce the paper's pairing (ssProp-50 ≈ 404 B/iter vs
//! ResNet-26 dense ≈ 440 B/iter at full width); latencies show the same
//! ordering on this testbed's scaled models.
//!
//! Requires `--features pjrt` + artifacts; skips with a message otherwise.
//!
//! Run: `cargo bench --bench table7_similar_flops --features pjrt`

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use std::time::Duration;

    use ssprop::coordinator::{TrainConfig, Trainer};
    use ssprop::flops::paper_resnet;
    use ssprop::runtime::Engine;
    use ssprop::util::bench::{bench, report};

    pub fn run() {
        let engine = match Engine::auto() {
            Ok(e) => e,
            Err(err) => {
                println!("skipping table7_similar_flops: {err}");
                return;
            }
        };
        println!("== Table 7 bench: ssProp-50 vs ResNet-26 (iso-FLOPs) ==\n");

        for (artifact, arch, d, label) in [
            ("resnet50_cifar10", "resnet50", 0.0f64, "resnet50/dense"),
            ("resnet50_cifar10", "resnet50", 0.8, "resnet50/ssprop_d80"),
            ("resnet26_cifar10", "resnet26", 0.0, "resnet26/dense"),
            ("resnet26_cifar10", "resnet26", 0.8, "resnet26/ssprop_d80"),
        ] {
            let mut t = Trainer::new(&engine, TrainConfig::quick(artifact, 1, 1)).unwrap();
            let order = t.loader.epoch_order(0);
            let batch = t.loader.batch(&order, 0);
            let r = bench(&format!("{label}/step"), 2, 15, Duration::from_secs(8), || {
                t.step(&batch, d).unwrap();
            });
            report(&r);
            let full = paper_resnet(arch, 32, 3, 1.0);
            let b = if d == 0.0 {
                full.bwd_flops_per_iter(128, 0.0)
            } else {
                full.bwd_flops_scheduled(128, &[0.0, 0.8])
            } / 1e9;
            println!("  full-width analytic: {b:.2} B/iter");
        }
        println!("\npaper pairing: ssProp-50 404.18 vs ResNet-26 440.19 (B/iter) — iso-FLOPs");
    }
}

#[cfg(feature = "pjrt")]
use pjrt_bench::run;

#[cfg(not(feature = "pjrt"))]
fn run() {
    println!(
        "skipping table7_similar_flops: PJRT runtime not compiled (build with --features pjrt)"
    );
}

fn main() {
    run();
}
