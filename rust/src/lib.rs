//! # ssProp — energy-efficient CNN training with scheduled sparse back-prop
//!
//! Rust + JAX + Pallas reproduction of *"ssProp: Energy-Efficient Training
//! for Convolutional Neural Networks with Scheduled Sparse Back Propagation"*
//! (Zhong, Huang, Shi; 2024), as a three-layer AOT stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — img2col GEMMs,
//!   channel-importance reduction, compacted sparse backward.
//! * **L2** (`python/compile/`): JAX model zoo (SimpleCNN, ResNet-18/26/50,
//!   DDPM UNet) built on the ssProp `custom_vjp` convolution; AOT-lowered
//!   once to HLO text.
//! * **L3** (this crate): the coordinator — drop-rate schedulers, executable
//!   routing, synthetic data plane, FLOPs/energy accounting, metrics,
//!   checkpoints, experiment harness. Python never runs at L3.
//!
//! ## Workspace layout
//!
//! The Cargo workspace root is the repository root; this package lives in
//! `rust/` with two vendored path crates keeping the default build fully
//! offline: `rust/vendor/anyhow` (API-compatible error shim) and
//! `rust/vendor/xla` (compile-time stub of the PJRT FFI crate).
//!
//! Two execution routes share the L3 coordinator:
//!
//! * [`backend`] — the default, dependency-free route: a [`backend::Backend`]
//!   op trait with a pure-Rust [`backend::NativeBackend`] (img2col GEMM
//!   forward, channel top-k compacted sparse backward mirroring
//!   `python/compile/kernels/ref.py`), a composable layer-graph model API
//!   ([`backend::layers`] + the [`backend::zoo`] `--model` presets), all
//!   driven by [`coordinator::NativeTrainer`]. `cargo run -- quickstart`
//!   trains a zoo CNN on the synthetic data plane with zero setup.
//! * [`runtime`] — the AOT/PJRT route (cargo feature `pjrt`): loads
//!   `artifacts/*.hlo.txt` compiled by the Python side and executes whole
//!   training-step graphs. Gated so the default build has no FFI deps;
//!   [`runtime::find_artifacts_dir`] and the typed
//!   [`runtime::EngineError`] stay available for artifact discovery either
//!   way.
//!
//! See `docs/ARCHITECTURE.md` for the layer map and the data-parallel
//! execution design.

// Every public item must carry rustdoc; CI denies rustdoc warnings
// (`cargo doc --no-deps -p ssprop` with RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod backend;
pub mod bench_report;
pub mod coordinator;
pub mod data;
#[cfg(feature = "pjrt")]
pub mod ddpm;
pub mod energy;
pub mod experiments;
pub mod flops;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod tensorstore;
pub mod util;
