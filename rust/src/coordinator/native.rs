//! Native training loop: the scheduler-driven coordinator running any
//! model-zoo layer graph ([`Sequential`] chains and residual graphs
//! alike) through the [`Backend`] trait — no artifacts, no FFI, works on
//! any machine. Shares the data plane, scheduler, FLOPs ledger and
//! checkpoint format with the PJRT path, so dense-vs-ssProp comparisons
//! and energy accounting read identically across executors *and* across
//! architectures (`--model simple-cnn-d4-w16`, `vgg-tiny`, `dropout-cnn`,
//! `resnet-tiny-w8-b2`, ...). The ledger's [`LayerSet`] is derived from
//! the *live* model graph at construction, so BatchNorm terms
//! (`counted_bn`) and residual projection convs are accounted for every
//! preset automatically.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::{checkpoint, TrainMetrics};
use crate::backend::{
    build_model, default_backend, parse_model_spec, Backend, ExecConfig, Sequential, WorkerPool,
};
use crate::data::{Loader, Loss, Split, SynthDataset};
use crate::flops::LayerSet;
use crate::schedule::DropScheduler;

/// Configuration for a native training job (`ssprop train-native`).
#[derive(Debug, Clone)]
pub struct NativeTrainConfig {
    /// Synthetic dataset name (CE datasets: mnist, fashion, cifar10, ...).
    pub dataset: String,
    /// Model-zoo spec (`simple-cnn`, `simple-cnn-d4-w16`, `vgg-tiny`,
    /// `dropout-cnn-w8-p25`, `resnet-tiny-w8-b2`, ...). A bare
    /// `simple-cnn` takes its geometry from
    /// [`NativeTrainConfig::depth`]/[`NativeTrainConfig::width`].
    pub model: String,
    /// SimpleCNN depth (used when the model spec leaves it unset).
    pub depth: usize,
    /// SimpleCNN channels per conv layer (used when the spec leaves it
    /// unset).
    pub width: usize,
    /// Training batch size (must fit both splits).
    pub batch: usize,
    /// Epochs to run.
    pub epochs: usize,
    /// Iterations per epoch (capped by the dataset's epoch length).
    pub iters_per_epoch: usize,
    /// SGD learning rate.
    pub lr: f64,
    /// Drop-rate schedule driving the ssProp sparsity.
    pub scheduler: DropScheduler,
    /// Seed for model init and the synthetic data plane.
    pub seed: u64,
    /// Worker threads for data-parallel train steps (1 = single-threaded;
    /// batches shard across a persistent [`WorkerPool`] when > 1; 0 =
    /// auto-detect via [`ExecConfig::auto`]'s documented clamp).
    pub threads: usize,
    /// Pipeline the data plane: a run-long prefetch thread assembles the
    /// next batch (including the epoch-tail re-key) while the current
    /// step trains. Bit-identical to the synchronous path — the stream
    /// carries the same batches in the same order — so this is purely a
    /// wall-clock knob (the `native/pipeline_speedup_*` bench lines
    /// track it).
    pub pipeline: bool,
    /// Also train on each epoch's tail partial batch (the `train_n %
    /// batch` leftover the fixed-geometry loaders otherwise drop). Plans
    /// are prewarmed for both batch sizes, so the tail step re-keys
    /// without reallocating.
    pub include_tail: bool,
    /// Pin pool worker `w` to CPU core `w` (Linux/x86-64 only; a no-op
    /// with a warning elsewhere). A placement hint for the OS scheduler —
    /// the trained bits are identical either way.
    pub affinity: bool,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl NativeTrainConfig {
    /// Small-but-real defaults: paper-default bar scheduler at D* = 0.8.
    /// The SGD lr is calibrated so ~100 steps visibly learn the synthetic
    /// class structure at this width/batch.
    pub fn quick(dataset: &str, epochs: usize, iters_per_epoch: usize) -> NativeTrainConfig {
        NativeTrainConfig {
            dataset: dataset.to_string(),
            model: "simple-cnn".to_string(),
            depth: 2,
            width: 8,
            batch: 16,
            epochs,
            iters_per_epoch,
            lr: 0.3,
            scheduler: DropScheduler::paper_default(epochs, iters_per_epoch),
            seed: 0,
            threads: 1,
            pipeline: true,
            include_tail: false,
            affinity: false,
            verbose: false,
        }
    }
}

/// A live native training job: model + backend + data plane + metrics.
pub struct NativeTrainer {
    /// The configuration this job was built from.
    pub cfg: NativeTrainConfig,
    /// The model being trained (any zoo-built layer graph).
    pub model: Sequential,
    /// The fully-resolved model spec ("simple-cnn-d2-w8"); recorded in
    /// checkpoint sidecars and verified on restore.
    pub model_spec: String,
    /// Train-split batch loader.
    pub loader: Loader,
    /// Test-split batch loader (evaluation).
    pub test_loader: Loader,
    /// Conv inventory for the Eq. 6/9 FLOPs ledger.
    pub layers: LayerSet,
    /// Loss/acc curves, FLOPs ledger, wall-clock.
    pub metrics: TrainMetrics,
    backend: Box<dyn Backend>,
    /// Persistent data-parallel worker pool; drives `step` (and sharded
    /// evaluation) when the resolved thread count exceeds 1. Lives as
    /// long as the trainer, so its workers and their plan/workspace sets
    /// are reused across every step, evaluation, and epoch.
    pool: WorkerPool,
}

impl NativeTrainer {
    /// A trainer on the default ([`crate::backend::NativeBackend`]) backend.
    pub fn new(cfg: NativeTrainConfig) -> Result<NativeTrainer> {
        NativeTrainer::with_backend(cfg, default_backend())
    }

    /// A trainer over an explicit backend (validates config, dataset and
    /// model spec; prewarms the model's layer workspaces at the configured
    /// batch size — and at the epoch-tail size when tail training is on).
    pub fn with_backend(
        cfg: NativeTrainConfig,
        backend: Box<dyn Backend>,
    ) -> Result<NativeTrainer> {
        let spec = crate::data::spec(&cfg.dataset)
            .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
        if spec.loss != Loss::Ce {
            bail!("native trainer supports CE datasets only (got {:?})", cfg.dataset);
        }
        if cfg.batch == 0 || cfg.epochs == 0 || cfg.iters_per_epoch == 0 {
            bail!("batch/epochs/iters must be positive");
        }
        if cfg.depth == 0 || cfg.width == 0 {
            bail!("depth/width must be positive");
        }
        if cfg.batch > spec.train_n || cfg.batch > spec.test_n {
            bail!(
                "batch {} exceeds the {:?} split sizes (train {}, test {})",
                cfg.batch,
                cfg.dataset,
                spec.train_n,
                spec.test_n
            );
        }
        let parsed = parse_model_spec(&cfg.model)
            .with_context(|| format!("invalid --model {:?}", cfg.model))?
            .with_defaults(cfg.depth, cfg.width);
        let model_spec = parsed.canonical();
        let mut model = build_model(&parsed, spec.channels, spec.img, spec.classes, cfg.seed)
            .with_context(|| format!("model {model_spec:?} cannot fit {:?}", cfg.dataset))?;
        // Prewarm the layer workspaces at every batch size the run will
        // see: the epoch-tail size first (when tail training is on), then
        // the full size — re-keying keeps capacity, so the tail step of an
        // epoch reallocates nothing.
        let tail = spec.train_n % cfg.batch;
        if cfg.include_tail && tail > 0 {
            model.ensure_ws(tail);
        }
        model.ensure_ws(cfg.batch);
        let layers = model.layer_set();
        let ds = SynthDataset::new(spec.clone(), cfg.seed);
        let loader = Loader::new(ds.clone(), Split::Train, cfg.batch);
        let test_loader = Loader::new(ds, Split::Test, cfg.batch);
        let pool =
            WorkerPool::new(ExecConfig::with_threads(cfg.threads).with_affinity(cfg.affinity));
        Ok(NativeTrainer {
            cfg,
            model,
            model_spec,
            loader,
            test_loader,
            layers,
            metrics: TrainMetrics::default(),
            backend,
            pool,
        })
    }

    /// Resolved worker count (`cfg.threads`, or the auto-detected count
    /// when the config asked for `0`).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Name of the backend executing the conv ops.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Total im2col builds across the model's and the pool's conv plans —
    /// advances by exactly `conv_count` per training step single-thread
    /// (or `conv_count × workers` data-parallel) when the fused path is
    /// healthy.
    pub fn plan_cols_builds(&self) -> u64 {
        self.model.plan_cols_builds() + self.pool.plan_cols_builds()
    }

    /// Full-batch iterations per epoch after capping to the dataset size.
    fn full_iters_per_epoch(&self) -> usize {
        self.cfg.iters_per_epoch.min(self.loader.batches_per_epoch()).max(1)
    }

    /// Steps per epoch actually trained: the capped full batches, plus the
    /// epoch-tail partial batch when tail training is on. The tail is the
    /// *point* of `include_tail`, so it is trained every epoch regardless
    /// of where the `--iters` cap lands.
    pub fn iters_per_epoch(&self) -> usize {
        let tail = usize::from(self.cfg.include_tail && self.loader.tail_len() > 0);
        self.full_iters_per_epoch() + tail
    }

    /// One training step at drop rate `d`; returns (loss, acc). Routes
    /// through the persistent worker pool when the resolved thread count
    /// exceeds 1 (sharded batch, globally-selected channels, tree-reduced
    /// gradients) and through the serial [`Sequential::train_step`]
    /// otherwise.
    pub fn step(&mut self, batch: &crate::data::Batch, d: f64) -> Result<(f64, f64)> {
        let lr = self.cfg.lr as f32;
        let stats = if self.pool.threads() > 1 {
            self.pool.train_step(
                &mut self.model,
                self.backend.as_ref(),
                &batch.x,
                &batch.y_class,
                d,
                lr,
            )?
        } else {
            self.model.train_step(self.backend.as_ref(), &batch.x, &batch.y_class, d, lr)?
        };
        Ok((stats.loss, stats.acc))
    }

    /// Run the configured number of epochs. Returns final test (loss, acc).
    ///
    /// With [`NativeTrainConfig::pipeline`] on (the default), the data
    /// plane is a run-long prefetch stream: the next batch — including
    /// the epoch-tail partial batch, whose smaller geometry re-keys conv
    /// plans — materializes on a producer thread while the current step
    /// trains, and the next epoch's batches keep flowing while this
    /// thread evaluates. Both paths see the same batches in the same
    /// order and train them at the same scheduled rates, so their
    /// loss/parameter trajectories are bit-identical; only wall-clock
    /// differs.
    pub fn run(&mut self) -> Result<(f64, f64)> {
        if self.cfg.pipeline {
            self.run_pipelined()?;
        } else {
            self.run_sync()?;
        }
        let fin = self.evaluate();
        self.metrics.record_eval(self.cfg.epochs.saturating_sub(1), fin.0, fin.1);
        Ok(fin)
    }

    /// The pipelined epoch loop: consume [`Loader::prefetch_run`]'s
    /// cross-epoch stream, stepping each item as it lands.
    fn run_pipelined(&mut self) -> Result<()> {
        let ipe_full = self.full_iters_per_epoch();
        let ipe = self.iters_per_epoch();
        let rx = self.loader.prefetch_run(self.cfg.epochs, ipe_full, self.cfg.include_tail, 4);
        let mut it = 0usize;
        let mut epoch = 0usize;
        let mut t0 = Instant::now();
        for item in rx.iter() {
            if item.epoch > epoch {
                self.metrics.record_epoch(t0.elapsed());
                self.log_epoch(epoch, ipe, it);
                epoch = item.epoch;
                t0 = Instant::now();
            }
            // The tail belongs to its epoch: it trains at the epoch's
            // current scheduled rate *without* advancing the schedule
            // counter — the scheduler's horizon was built from
            // iters_per_epoch full batches, so epoch-keyed schedules
            // (the paper's bar) keep their phase.
            let d = if item.is_tail {
                self.cfg.scheduler.rate_at(it.saturating_sub(1))
            } else {
                self.cfg.scheduler.rate_at(it)
            };
            let (loss, acc) = self.step(&item.batch, d)?;
            self.metrics.record_iter(loss, acc, d, &self.layers, item.batch.batch_size);
            if !item.is_tail {
                it += 1;
            }
        }
        self.metrics.record_epoch(t0.elapsed());
        self.log_epoch(epoch, ipe, it);
        Ok(())
    }

    /// The synchronous epoch loop: materialize every batch inline, right
    /// before its step — the reference the `native/pipeline_speedup_*`
    /// bench lines (and the pipeline determinism suite) compare against.
    fn run_sync(&mut self) -> Result<()> {
        let ipe_full = self.full_iters_per_epoch();
        let ipe = self.iters_per_epoch();
        let mut it = 0usize;
        for epoch in 0..self.cfg.epochs {
            let t0 = Instant::now();
            let order = self.loader.epoch_order(epoch);
            for b in 0..ipe_full {
                let batch = self.loader.batch(&order, b);
                let d = self.cfg.scheduler.rate_at(it);
                let (loss, acc) = self.step(&batch, d)?;
                self.metrics.record_iter(loss, acc, d, &self.layers, batch.batch_size);
                it += 1;
            }
            if self.cfg.include_tail {
                if let Some(tail) = self.loader.tail_batch(&order) {
                    // Same tail discipline as the pipelined path: the
                    // epoch's current rate, no counter advance.
                    let d = self.cfg.scheduler.rate_at(it.saturating_sub(1));
                    let (loss, acc) = self.step(&tail, d)?;
                    self.metrics.record_iter(loss, acc, d, &self.layers, tail.batch_size);
                }
            }
            self.metrics.record_epoch(t0.elapsed());
            self.log_epoch(epoch, ipe, it);
        }
        Ok(())
    }

    /// Per-epoch progress line (when `cfg.verbose`).
    fn log_epoch(&self, epoch: usize, ipe: usize, it: usize) {
        if self.cfg.verbose {
            let m = &self.metrics;
            println!(
                "epoch {epoch:>3}  loss {:.4}  acc {:.3}  drop {:.2}  ({} iters)",
                m.last_epoch_loss(ipe),
                m.last_epoch_acc(ipe),
                self.cfg.scheduler.rate_at(it.saturating_sub(1)),
                ipe
            );
        }
    }

    /// Mean (loss, acc) over the test split (forward only). Shards each
    /// eval batch across the pool's workers when the resolved thread
    /// count exceeds 1 — bit-identical to the serial evaluation at any
    /// thread count (the reducer sums per-example losses in global
    /// example order).
    pub fn evaluate(&mut self) -> (f64, f64) {
        let order = self.test_loader.epoch_order(0);
        let nb = self.test_loader.batches_per_epoch().max(1);
        let (mut sl, mut sa) = (0.0, 0.0);
        for b in 0..nb {
            let batch = self.test_loader.batch(&order, b);
            let (l, a) = if self.pool.threads() > 1 {
                let be = self.backend.as_ref();
                self.pool.eval_batch(&self.model, be, &batch.x, &batch.y_class)
            } else {
                self.model.eval_batch(self.backend.as_ref(), &batch.x, &batch.y_class)
            };
            sl += l;
            sa += a;
        }
        (sl / nb as f64, sa / nb as f64)
    }

    /// Persist model parameters in the shared checkpoint format. The
    /// sidecar's artifact field records `native_{dataset}:{model_spec}` so
    /// a restore into a different architecture fails early.
    pub fn save_checkpoint<P: AsRef<Path>>(&self, path: P, epoch: usize) -> Result<()> {
        let state: std::collections::HashMap<_, _> =
            self.model.state_tensors().into_iter().collect();
        let artifact = format!("native_{}:{}", self.cfg.dataset, self.model_spec);
        checkpoint::save_tensors(path, &state, &artifact, epoch)
    }

    /// Restore model parameters from [`NativeTrainer::save_checkpoint`],
    /// rejecting checkpoints recorded for a different model spec.
    pub fn load_checkpoint<P: AsRef<Path>>(&mut self, path: P) -> Result<usize> {
        let (state, artifact, epoch) = checkpoint::load_tensors(path)?;
        if let Some(saved_spec) = checkpoint::artifact_model_spec(&artifact) {
            if saved_spec != self.model_spec {
                bail!(
                    "checkpoint was saved for model {saved_spec:?}, this trainer runs {:?}",
                    self.model_spec
                );
            }
        }
        let tensors: Vec<(String, crate::tensorstore::Tensor)> = state.into_iter().collect();
        self.model.load_state_tensors(&tensors)?;
        Ok(epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;

    fn quick_cfg() -> NativeTrainConfig {
        let mut cfg = NativeTrainConfig::quick("mnist", 2, 6);
        cfg.width = 6;
        cfg.batch = 8;
        cfg
    }

    #[test]
    fn rejects_bce_and_unknown_datasets() {
        assert!(NativeTrainer::new(NativeTrainConfig::quick("celeba", 1, 1)).is_err());
        assert!(NativeTrainer::new(NativeTrainConfig::quick("nope", 1, 1)).is_err());
    }

    #[test]
    fn zero_threads_resolves_to_auto_detected_pool() {
        let mut cfg = quick_cfg();
        cfg.threads = 0;
        let t = NativeTrainer::new(cfg).unwrap();
        let resolved = t.threads();
        assert!(
            (1..=crate::backend::parallel::MAX_AUTO_THREADS).contains(&resolved),
            "auto resolved to {resolved}"
        );
        assert_eq!(
            resolved,
            ExecConfig::auto().resolved_threads(),
            "the trainer's pool uses the documented auto clamp"
        );
    }

    #[test]
    fn rejects_bad_model_specs() {
        let mut cfg = quick_cfg();
        cfg.model = "resnet9000".to_string();
        let err = NativeTrainer::new(cfg).err().expect("must reject");
        assert!(
            err.downcast_ref::<crate::backend::ModelSpecError>().is_some(),
            "the spec error must stay typed through the trainer: {err}"
        );
    }

    #[test]
    fn model_spec_resolves_from_depth_width_knobs() {
        let mut cfg = quick_cfg();
        cfg.depth = 3;
        let t = NativeTrainer::new(cfg).unwrap();
        assert_eq!(t.model_spec, "simple-cnn-d3-w6");
        assert_eq!(t.model.conv_count(), 3);
    }

    #[test]
    fn zoo_models_train_through_the_coordinator() {
        for model in ["vgg-tiny-w4", "dropout-cnn-w6-p25", "resnet-tiny-w4"] {
            let mut cfg = quick_cfg();
            cfg.model = model.to_string();
            let mut t = NativeTrainer::new(cfg).unwrap();
            let (loss, acc) = t.run().unwrap();
            assert!(loss.is_finite(), "{model}: loss {loss}");
            assert!((0.0..=1.0).contains(&acc), "{model}: acc {acc}");
            assert!(t.metrics.flops_actual < t.metrics.flops_dense, "{model}: schedule engaged");
        }
    }

    #[test]
    fn multithreaded_run_matches_single_thread_loss() {
        let t1_cfg = quick_cfg();
        let mut t4_cfg = quick_cfg();
        t4_cfg.threads = 4;
        let mut t1 = NativeTrainer::new(t1_cfg).unwrap();
        let mut t4 = NativeTrainer::new(t4_cfg).unwrap();
        let (l1, _) = t1.run().unwrap();
        let (l4, _) = t4.run().unwrap();
        // same schedule, same data, same selection semantics — only float
        // re-association differs between the serial and sharded paths
        assert!((l1 - l4).abs() < 1e-4, "test loss {l1} vs {l4}");
        assert_eq!(t1.metrics.flops_actual, t4.metrics.flops_actual, "same FLOPs ledger");
        // the parallel path builds its cols in the executor's worker plans
        assert!(t4.plan_cols_builds() > 0);
    }

    #[test]
    fn sharded_evaluate_is_bit_identical_to_serial() {
        let mut serial = NativeTrainer::new(quick_cfg()).unwrap();
        let mut t4_cfg = quick_cfg();
        t4_cfg.threads = 4;
        let mut sharded = NativeTrainer::new(t4_cfg).unwrap();
        // identical init — evaluate before any training so params match
        assert_eq!(serial.evaluate(), sharded.evaluate());
    }

    #[test]
    fn rejects_batch_larger_than_splits() {
        // mnist test split is 512; an oversized batch must fail at config
        // time, not panic inside evaluate() after a full training run
        let mut cfg = NativeTrainConfig::quick("mnist", 1, 1);
        cfg.batch = 600;
        let err = NativeTrainer::new(cfg).err().expect("must reject").to_string();
        assert!(err.contains("batch 600"), "{err}");
    }

    #[test]
    fn flops_ledger_matches_schedule() {
        let mut cfg = quick_cfg();
        cfg.scheduler =
            DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, 2, 6);
        let mut t = NativeTrainer::new(cfg).unwrap();
        t.run().unwrap();
        let m = &t.metrics;
        assert_eq!(m.losses.len(), 12);
        // epoch 0 dense, epoch 1 sparse -> mean drop target/2
        assert!((m.mean_drop_rate() - 0.4).abs() < 1e-12);
        assert!(m.flops_actual < m.flops_dense);
        let expect = 1.0
            - t.layers.bwd_flops_scheduled(t.cfg.batch, &[0.0, 0.8])
                / t.layers.bwd_flops_per_iter(t.cfg.batch, 0.0);
        assert!((m.flops_saving() - expect).abs() < 1e-9, "{} vs {expect}", m.flops_saving());
    }

    #[test]
    fn trainer_steps_reuse_plan_workspaces() {
        let mut t = NativeTrainer::new(quick_cfg()).unwrap();
        let order = t.loader.epoch_order(0);
        let batch = t.loader.batch(&order, 0);
        t.step(&batch, 0.5).unwrap();
        let caps = t.model.plan_caps();
        assert_eq!(t.plan_cols_builds(), t.cfg.depth as u64, "one im2col per layer per step");
        t.step(&batch, 0.5).unwrap();
        assert_eq!(t.plan_cols_builds(), 2 * t.cfg.depth as u64);
        assert_eq!(caps, t.model.plan_caps(), "second step must not grow any plan buffer");
    }

    #[test]
    fn epoch_tail_trains_without_reallocation() {
        // mnist train_n = 2048; batch 30 -> 68 full batches + an 8-example
        // tail. With include_tail the epoch runs 69 steps and the tail
        // re-key must neither rebuild extra cols nor grow any buffer.
        let mut cfg = NativeTrainConfig::quick("mnist", 1, 1000);
        cfg.batch = 30;
        cfg.include_tail = true;
        let mut t = NativeTrainer::new(cfg).unwrap();
        assert_eq!(t.loader.batches_per_epoch(), 68);
        assert_eq!(t.loader.batches_per_epoch_with_tail(), 69);
        assert_eq!(t.iters_per_epoch(), 69);
        t.run().unwrap();
        assert_eq!(t.metrics.losses.len(), 69, "the tail step must be trained on");
        let per_step = t.model.conv_count() as u64;
        assert_eq!(t.plan_cols_builds(), 69 * per_step, "tail re-key must not rebuild cols");
        let caps = t.model.plan_caps();
        // stepping a full batch again after the tail re-keys back without
        // allocating
        let order = t.loader.epoch_order(1);
        let batch = t.loader.batch(&order, 0);
        t.step(&batch, 0.0).unwrap();
        assert_eq!(caps, t.model.plan_caps(), "full-size re-key must reuse capacity");
    }

    #[test]
    fn tail_trains_even_when_iters_caps_the_epoch_and_keeps_schedule_phase() {
        // --iters 4 caps the full batches, but --include-tail's whole point
        // is the leftover examples — the tail step still runs each epoch.
        // It must not advance the schedule counter: epoch-keyed schedules
        // keep the exact phase a tail-free run would have.
        let mut cfg = NativeTrainConfig::quick("mnist", 2, 4);
        cfg.batch = 30;
        cfg.include_tail = true;
        cfg.scheduler = DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, 2, 4);
        let mut t = NativeTrainer::new(cfg).unwrap();
        assert_eq!(t.iters_per_epoch(), 5);
        t.run().unwrap();
        assert_eq!(t.metrics.losses.len(), 10, "(4 capped full batches + tail) x 2 epochs");
        assert_eq!(t.plan_cols_builds(), 10 * t.model.conv_count() as u64);
        let rates = &t.metrics.drop_rates;
        assert!(rates[..5].iter().all(|&d| d == 0.0), "epoch 0 (incl. tail) is dense: {rates:?}");
        assert!(rates[5..].iter().all(|&d| d == 0.8), "epoch 1 (incl. tail) is sparse: {rates:?}");
    }

    #[test]
    fn pipelined_run_is_bit_identical_to_sync_run_including_tail_rekey() {
        // batch 30 on mnist (train_n 2048) leaves an 8-example tail, so
        // the stream exercises the mid-run plan re-key; 2 epochs + the
        // EpochBar schedule exercise the tail's no-counter-advance rule.
        for threads in [1usize, 2] {
            let mk = |pipeline: bool| {
                let mut cfg = NativeTrainConfig::quick("mnist", 2, 4);
                cfg.batch = 30;
                cfg.include_tail = true;
                cfg.threads = threads;
                cfg.pipeline = pipeline;
                cfg.scheduler =
                    DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, 2, 4);
                NativeTrainer::new(cfg).unwrap()
            };
            let mut piped = mk(true);
            let mut sync = mk(false);
            let fin_piped = piped.run().unwrap();
            let fin_sync = sync.run().unwrap();
            assert_eq!(fin_piped, fin_sync, "t{threads}: final eval must be bitwise equal");
            assert_eq!(piped.metrics.losses.len(), 10, "(4 full + tail) x 2 epochs");
            for (i, (a, b)) in piped.metrics.losses.iter().zip(&sync.metrics.losses).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t{threads} step {i} loss");
            }
            assert_eq!(piped.metrics.drop_rates, sync.metrics.drop_rates, "same schedule phase");
            assert_eq!(piped.metrics.flops_actual, sync.metrics.flops_actual);
        }
    }

    #[test]
    fn checkpoint_roundtrip_restores_eval() {
        let dir = std::env::temp_dir().join("ssprop_native_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("native.tstore");

        let mut a = NativeTrainer::new(quick_cfg()).unwrap();
        a.run().unwrap();
        a.save_checkpoint(&path, 2).unwrap();

        let mut b = NativeTrainer::new(quick_cfg()).unwrap();
        let epoch = b.load_checkpoint(&path).unwrap();
        assert_eq!(epoch, 2);
        assert_eq!(a.evaluate(), b.evaluate());
    }

    #[test]
    fn checkpoint_rejects_model_spec_mismatch() {
        let dir = std::env::temp_dir().join("ssprop_native_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("native_vgg.tstore");

        let mut vgg_cfg = quick_cfg();
        vgg_cfg.model = "vgg-tiny-w4".to_string();
        let vgg = NativeTrainer::new(vgg_cfg).unwrap();
        vgg.save_checkpoint(&path, 1).unwrap();

        let mut simple = NativeTrainer::new(quick_cfg()).unwrap();
        let err = simple.load_checkpoint(&path).err().expect("must reject").to_string();
        assert!(err.contains("vgg-tiny-w4"), "{err}");
    }
}
