"""Optimizer correctness: Adam vs closed form, AdamW decoupled decay."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optim


def test_adam_first_step_closed_form():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    s = optim.init_opt_state(p)
    lr = 0.1
    p2, s2 = optim.adam_update(p, g, s, jnp.float32(lr))
    # after bias correction the first step is lr * g/(|g|+eps) ~ lr*sign(g)
    expect = np.array([1.0, -2.0]) - lr * np.array([0.5, 0.5]) / (np.abs([0.5, 0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert int(s2["t"]) == 1


def test_adam_converges_on_quadratic():
    p = {"w": jnp.array([5.0])}
    s = optim.init_opt_state(p)
    for _ in range(300):
        g = {"w": 2.0 * p["w"]}
        p, s = optim.adam_update(p, g, s, jnp.float32(0.05))
    assert abs(float(p["w"][0])) < 0.05


def test_adamw_decays_weights_with_zero_grad():
    p = {"w": jnp.array([1.0])}
    s = optim.init_opt_state(p)
    g = {"w": jnp.array([0.0])}
    p2, _ = optim.adam_update(p, g, s, jnp.float32(0.1), weight_decay=0.01)
    np.testing.assert_allclose(float(p2["w"][0]), 1.0 - 0.1 * 0.01 * 1.0, rtol=1e-6)


def test_state_tree_structure_preserved():
    p = {"a": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}, "c": jnp.zeros(())}
    s = optim.init_opt_state(p)
    g = jax.tree.map(jnp.ones_like, p)
    p2, s2 = optim.adam_update(p, g, s, jnp.float32(0.01))
    assert jax.tree.structure(p2) == jax.tree.structure(p)
    assert jax.tree.structure(s2["m"]) == jax.tree.structure(p)
