//! NativeBackend vs the L1 reference oracle: fixtures exported from
//! `python/compile/kernels/ref.py` (via `python/compile/export_fixtures.py`)
//! pin the conv forward, channel-importance selection, and compacted sparse
//! backward to the paper's equations within 1e-4 — on both the op-level
//! route and the fused plan/workspace route. Plus pure-Rust consistency
//! checks (masked path ≡ compacted path) and an end-to-end native training
//! run whose measured backward-FLOPs reduction must track the configured
//! drop rate.

use ssprop::backend::sparse::{channel_importance, select_channels, sparse_bwd_compact};
use ssprop::backend::{Backend, Conv2d, Conv2dPlan, NativeBackend};
use ssprop::coordinator::{NativeTrainConfig, NativeTrainer};
use ssprop::flops::keep_channels;
use ssprop::schedule::{DropScheduler, Schedule};
use ssprop::util::json::Json;

fn fixtures() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("native_conv.json");
    let text = std::fs::read_to_string(&path).expect("fixture file present (committed)");
    Json::parse(&text).expect("fixture JSON parses")
}

fn vecf(case: &Json, key: &str) -> Vec<f32> {
    case.arr_field(key)
        .unwrap_or_else(|e| panic!("{e}"))
        .iter()
        .map(|v| v.as_f64().expect("number") as f32)
        .collect()
}

fn assert_close(name: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let err = (g - w).abs() / w.abs().max(1.0);
        assert!(err <= tol, "{name}[{i}]: got {g}, want {w} (rel err {err})");
    }
}

fn case_cfg(case: &Json) -> Conv2d {
    Conv2d {
        bt: case.usize_field("bt").unwrap(),
        cin: case.usize_field("cin").unwrap(),
        h: case.usize_field("h").unwrap(),
        w: case.usize_field("w").unwrap(),
        cout: case.usize_field("cout").unwrap(),
        k: case.usize_field("k").unwrap(),
        stride: case.usize_field("stride").unwrap(),
        padding: case.usize_field("padding").unwrap(),
    }
}

#[test]
fn native_backend_matches_reference_fixtures() {
    let be = NativeBackend::new();
    let fx = fixtures();
    let cases = fx.arr_field("cases").unwrap();
    assert!(!cases.is_empty());
    // coverage beyond the quickstart geometry: k=1, stride-2/padding-0,
    // rectangular inputs, k=5 (exported by export_fixtures.py)
    for want in ["k1_s1_p0_d50", "k1_s2_p0_dense", "k3_s2_p0_rect_d25", "k5_s2_p0_d75"] {
        let found = cases.iter().any(|c| c.str_field("name").unwrap() == want);
        assert!(found, "fixture case {want} missing — re-run export_fixtures.py");
    }
    for case in cases {
        let name = case.str_field("name").unwrap();
        let cfg = case_cfg(case);
        let drop_rate = case.f64_field("drop_rate").unwrap();
        let (x, w, b) = (vecf(case, "x"), vecf(case, "wt"), vecf(case, "bias"));
        let g = vecf(case, "g");

        // forward (Eq. 1)
        let y = be.conv2d_fwd(&cfg, &x, &w, Some(&b));
        assert_close(&format!("{name}/y"), &y, &vecf(case, "y"), 1e-4);

        // channel importance (Fig. 1a) + top-k selection
        let imp = channel_importance(&cfg, &g);
        assert_close(&format!("{name}/importance"), &imp, &vecf(case, "importance"), 1e-4);
        let want_keep: Vec<usize> = case
            .arr_field("keep_idx")
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(select_channels(&cfg, &g, drop_rate), want_keep, "{name}/keep_idx");

        // compacted sparse backward (Eq. 3/4/5 + compaction)
        let grads = be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, drop_rate, true);
        assert_eq!(grads.keep_idx, want_keep, "{name}/grads.keep_idx");
        assert_close(&format!("{name}/dx"), &grads.dx, &vecf(case, "dx"), 1e-4);
        assert_close(&format!("{name}/dw"), &grads.dw, &vecf(case, "dw"), 1e-4);
        assert_close(&format!("{name}/db"), &grads.db, &vecf(case, "db"), 1e-4);

        // the fused plan path must pin to the same oracle values, sharing
        // a single im2col build between the forward and the backward
        let mut plan = Conv2dPlan::new(cfg);
        let (yf, gf) = be.conv2d_fwd_bwd(&mut plan, &x, &w, Some(&b), &g, drop_rate, true);
        assert_eq!(plan.cols_builds(), 1, "{name}: fused pair must build cols once");
        assert_close(&format!("{name}/fused_y"), &yf, &vecf(case, "y"), 1e-4);
        assert_eq!(gf.keep_idx, want_keep, "{name}/fused keep_idx");
        assert_close(&format!("{name}/fused_dx"), &gf.dx, &vecf(case, "dx"), 1e-4);
        assert_close(&format!("{name}/fused_dw"), &gf.dw, &vecf(case, "dw"), 1e-4);
        assert_close(&format!("{name}/fused_db"), &gf.db, &vecf(case, "db"), 1e-4);
    }
}

#[test]
fn compacted_backward_equals_masked_dense_backward() {
    // Numerics invariant from the paper: compacting the matmuls must give
    // exactly what masking the gradient and running dense would give.
    let be = NativeBackend::new();
    let cfg = Conv2d { bt: 2, cin: 3, h: 7, w: 6, cout: 5, k: 3, stride: 2, padding: 1 };
    let mut rng = ssprop::util::rng::Pcg::new(42, 1);
    let x: Vec<f32> = (0..cfg.in_len()).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..cfg.w_len()).map(|_| rng.normal() * 0.2).collect();
    let g: Vec<f32> = (0..cfg.out_len()).map(|_| rng.normal()).collect();

    for drop_rate in [0.3, 0.6, 0.9] {
        let keep = select_channels(&cfg, &g, drop_rate);
        assert_eq!(keep.len(), keep_channels(cfg.cout, drop_rate));

        // masked path: zero dropped channels of g, then full dense backward
        let hw = cfg.hout() * cfg.wout();
        let mut gm = g.clone();
        for b in 0..cfg.bt {
            for o in 0..cfg.cout {
                if !keep.contains(&o) {
                    for v in &mut gm[(b * cfg.cout + o) * hw..][..hw] {
                        *v = 0.0;
                    }
                }
            }
        }
        let dense_idx: Vec<usize> = (0..cfg.cout).collect();
        let masked = sparse_bwd_compact(&cfg, &x, &w, &gm, &dense_idx, true);
        let compact = be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, drop_rate, true);
        assert_close("dx", &compact.dx, &masked.dx, 1e-5);
        assert_close("dw", &compact.dw, &masked.dw, 1e-5);
        assert_close("db", &compact.db, &masked.db, 1e-5);
    }
}

#[test]
fn native_training_loss_falls_dense_and_sparse() {
    for (schedule, target) in
        [(Schedule::Constant, 0.0), (Schedule::EpochBar { period_epochs: 2 }, 0.8)]
    {
        let mut cfg = NativeTrainConfig::quick("mnist", 10, 12);
        cfg.scheduler = DropScheduler::new(schedule, target, 10, 12);
        let mut t = NativeTrainer::new(cfg).unwrap();
        t.run().unwrap();
        let m = &t.metrics;
        assert_eq!(m.losses.len(), 120);
        let first = m.losses[..12].iter().sum::<f64>() / 12.0;
        let last = m.losses[m.losses.len() - 12..].iter().sum::<f64>() / 12.0;
        assert!(last < first, "target {target}: loss should fall ({first:.3} -> {last:.3})");
        if target > 0.0 {
            assert!(m.flops_saving() > 0.3, "saving {}", m.flops_saving());
        } else {
            assert_eq!(m.flops_saving(), 0.0);
        }
    }
}

#[test]
fn measured_flops_reduction_tracks_configured_drop_rate() {
    // Constant schedule at D: the ledger's saving must equal the analytic
    // Eq. 9 saving for this model, which approaches D as overhead vanishes.
    let mut cfg = NativeTrainConfig::quick("cifar10", 1, 6);
    cfg.width = 10;
    cfg.batch = 8;
    let d = 0.8;
    cfg.scheduler = DropScheduler::new(Schedule::Constant, d, 1, 6);
    let mut t = NativeTrainer::new(cfg).unwrap();
    t.run().unwrap();
    let saving = t.metrics.flops_saving();
    let analytic = t.layers.saving_at(t.cfg.batch, d);
    assert!((saving - analytic).abs() < 1e-9, "ledger {saving} vs analytic {analytic}");
    // width 10 at D=0.8 keeps 2/10 channels; selection overhead is small,
    // so the measured reduction sits near the configured rate
    assert!((saving - d).abs() < 0.1, "saving {saving} should approximate D={d}");
}

#[test]
fn sparse_training_diverges_from_dense_on_same_stream() {
    let mk = || {
        let mut cfg = NativeTrainConfig::quick("mnist", 1, 4);
        cfg.width = 6;
        cfg.batch = 8;
        cfg
    };
    let mut dense = NativeTrainer::new(mk()).unwrap();
    let mut sparse = NativeTrainer::new(mk()).unwrap();
    let order = dense.loader.epoch_order(0);
    let batch = dense.loader.batch(&order, 0);
    let (ld, _) = dense.step(&batch, 0.0).unwrap();
    let (ls, _) = sparse.step(&batch, 0.8).unwrap();
    assert_eq!(ld, ls, "loss is computed on the (identical) forward pass");
    assert_ne!(
        dense.model.flat_params(),
        sparse.model.flat_params(),
        "sparse backward must change the update"
    );
}
