//! ssProp-sparsified 2-D convolution layer: arbitrary kernel/stride/pad,
//! run through the plan/workspace [`Backend`] path — the forward caches
//! its im2col columns in the layer's [`Conv2dPlan`], the backward consumes
//! them (one patch gather per layer per step), and the channel top-k makes
//! this the layer the drop-rate schedule acts on.

use anyhow::{bail, Result};

use super::{BwdOut, FwdCtx, Layer, LayerWs, ParamView, Selection, Shape};
use crate::backend::plan::Conv2dPlan;
use crate::backend::{Backend, Conv2d};
use crate::flops::{ConvLayer, LayerSet};
use crate::util::rng::Pcg;

/// A conv layer (weights OIHW, per-channel bias) with fixed input geometry.
/// He-initialized from the shared model RNG so multi-layer graphs draw one
/// deterministic parameter stream, exactly like the historical SimpleCNN.
#[derive(Debug, Clone)]
pub struct Conv2dLayer {
    /// Batch-1 geometry (the ssProp selection unit).
    geom: Conv2d,
    /// Weights, (Cout, Cin, K, K) flattened.
    w: Vec<f32>,
    /// Bias, (Cout,).
    b: Vec<f32>,
}

impl Conv2dLayer {
    /// He-initialize a conv over a `(cin, h, w_in)` input: `cout` filters
    /// of size `k`×`k` at `stride`/`padding`. Weight draws come from `rng`
    /// in (Cout, Cin, K, K) order; biases start at zero.
    #[allow(clippy::too_many_arguments)]
    pub fn init(
        rng: &mut Pcg,
        cin: usize,
        h: usize,
        w_in: usize,
        cout: usize,
        k: usize,
        stride: usize,
        padding: usize,
    ) -> Conv2dLayer {
        assert!(cin >= 1 && cout >= 1 && k >= 1 && stride >= 1, "degenerate conv geometry");
        let geom = Conv2d { bt: 1, cin, h, w: w_in, cout, k, stride, padding };
        let fan_in = (cin * k * k) as f32;
        let scale = (2.0 / fan_in).sqrt();
        Conv2dLayer {
            geom,
            w: (0..cout * cin * k * k).map(|_| rng.normal() * scale).collect(),
            b: vec![0f32; cout],
        }
    }

    /// This layer's geometry at batch size `bt`.
    pub fn cfg_at(&self, bt: usize) -> Conv2d {
        self.geom.with_batch(bt)
    }
}

impl Layer for Conv2dLayer {
    fn describe(&self) -> String {
        let g = &self.geom;
        format!("conv{}x{}/s{} {}->{}", g.k, g.k, g.stride, g.cin, g.cout)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        let g = &self.geom;
        match *input {
            Shape::Spatial { c, h, w } if (c, h, w) == (g.cin, g.h, g.w) => {
                Ok(Shape::Spatial { c: g.cout, h: g.hout(), w: g.wout() })
            }
            other => {
                let want = (g.cin, g.h, g.w);
                bail!("{} expects {want:?} input, got {other:?}", self.describe())
            }
        }
    }

    fn ensure_ws(&self, ws: &mut LayerWs, bt: usize) {
        let cfg = self.cfg_at(bt);
        match &mut ws.plan {
            Some(plan) => plan.ensure(cfg),
            None => ws.plan = Some(Conv2dPlan::new(cfg)),
        }
    }

    fn forward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        self.ensure_ws(ws, bt);
        let plan = ws.plan.as_mut().expect("conv plan just ensured");
        be.conv2d_fwd_planned(plan, x, &self.w, Some(&self.b))
    }

    fn backward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        _bt: usize,
        ws: &mut LayerWs,
        sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        let plan = ws.plan.as_mut().expect("conv backward without a forward-keyed workspace");
        let grads = match sel {
            Selection::Local(d) => be.conv2d_bwd_planned(plan, x, &self.w, g, d, need_dx),
            Selection::Keep(keep) => be.conv2d_bwd_planned_with(plan, x, &self.w, g, keep, need_dx),
        };
        BwdOut { dx: grads.dx, kept: grads.keep_idx.len(), grads: vec![grads.dw, grads.db] }
    }

    fn params(&self) -> Vec<ParamView<'_>> {
        let g = &self.geom;
        vec![
            ParamView { field: "w", data: &self.w, shape: vec![g.cout, g.cin, g.k, g.k] },
            ParamView { field: "b", data: &self.b, shape: vec![g.cout] },
        ]
    }

    fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        vec![&mut self.w, &mut self.b]
    }

    fn load_param(&mut self, field: &str, vals: Vec<f32>) -> Result<()> {
        let dst = match field {
            "w" => &mut self.w,
            "b" => &mut self.b,
            other => bail!("unknown conv field {other:?}"),
        };
        if dst.len() != vals.len() {
            bail!("shape mismatch: {} vs {}", vals.len(), dst.len());
        }
        *dst = vals;
        Ok(())
    }

    fn conv_geom(&self) -> Option<Conv2d> {
        Some(self.geom)
    }

    fn account_flops(&self, set: &mut LayerSet) {
        let g = &self.geom;
        set.convs.push(ConvLayer {
            cin: g.cin,
            cout: g.cout,
            k: g.k,
            hout: g.hout(),
            wout: g.wout(),
            counted_bn: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn layer() -> Conv2dLayer {
        let mut rng = Pcg::new(5, 1);
        Conv2dLayer::init(&mut rng, 2, 5, 5, 3, 3, 2, 1)
    }

    #[test]
    fn geometry_and_describe() {
        let l = layer();
        assert_eq!(l.describe(), "conv3x3/s2 2->3");
        let out = l.out_shape(&Shape::Spatial { c: 2, h: 5, w: 5 }).unwrap();
        assert_eq!(out, Shape::Spatial { c: 3, h: 3, w: 3 });
        assert!(l.out_shape(&Shape::Spatial { c: 2, h: 4, w: 5 }).is_err());
        assert!(l.out_shape(&Shape::Flat { features: 50 }).is_err());
        assert_eq!(l.conv_geom().unwrap().cout, 3);
    }

    #[test]
    fn forward_matches_op_level_backend_call() {
        let be = NativeBackend::new();
        let l = layer();
        let cfg = l.cfg_at(2);
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
        let mut ws = LayerWs::default();
        let ctx = FwdCtx { train: true, step: 0, example_offset: 0 };
        let y = l.forward(&be, &x, 2, &mut ws, &ctx);
        let want = be.conv2d_fwd(&cfg, &x, &l.w, Some(&l.b));
        assert_eq!(y, want);
        assert_eq!(ws.plan_cols_builds(), 1);
    }

    #[test]
    fn backward_local_and_keep_selections_agree() {
        use crate::backend::sparse::select_channels;
        let be = NativeBackend::new();
        let l = layer();
        let cfg = l.cfg_at(2);
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| (i % 5) as f32 * 0.2 - 0.4).collect();
        let g: Vec<f32> = (0..cfg.out_len()).map(|i| (i % 9) as f32 - 4.0).collect();
        let ctx = FwdCtx { train: true, step: 0, example_offset: 0 };

        let mut ws_a = LayerWs::default();
        l.forward(&be, &x, 2, &mut ws_a, &ctx);
        let a = l.backward(&be, &x, &g, 2, &mut ws_a, Selection::Local(0.5), true);

        let keep = select_channels(&cfg, &g, 0.5);
        let mut ws_b = LayerWs::default();
        l.forward(&be, &x, 2, &mut ws_b, &ctx);
        let b = l.backward(&be, &x, &g, 2, &mut ws_b, Selection::Keep(&keep), true);

        assert_eq!(a.kept, b.kept);
        assert_eq!(a.dx, b.dx);
        assert_eq!(a.grads, b.grads);
        assert_eq!(a.kept, keep.len());
    }

    #[test]
    fn param_roundtrip() {
        let mut l = layer();
        let ps = l.params();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].shape, vec![3, 2, 3, 3]);
        assert_eq!(ps[1].shape, vec![3]);
        let w2: Vec<f32> = vec![0.5; 3 * 2 * 9];
        l.load_param("w", w2.clone()).unwrap();
        assert_eq!(l.params()[0].data, &w2[..]);
        assert!(l.load_param("w", vec![1.0]).is_err(), "wrong length must fail");
        assert!(l.load_param("nope", vec![1.0]).is_err());
    }
}
