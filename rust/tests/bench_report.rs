//! The committed perf/energy trajectory: the `BENCH_native.json` baseline
//! at the repo root must stay consistent with the live code (the
//! deterministic Eq. 6/9 FLOPs and joules ledgers are recomputed here and
//! compared exactly), the report schema must round-trip losslessly through
//! `util::json`, the regression gate must pass identical runs / fail
//! perturbed ones with the documented per-class tolerances, and the
//! `ssprop bench-check` CLI must turn those verdicts into exit codes.

use std::path::Path;
use std::process::Command;

use ssprop::bench_report::{
    gate, preset_ledger, BenchReport, ReportError, Tolerance, BASELINE_PRESETS, SCHEMA_VERSION,
};

/// The committed baseline at the repo root (CARGO_MANIFEST_DIR = `rust/`).
const BASELINE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_native.json");

fn baseline() -> BenchReport {
    BenchReport::load(Path::new(BASELINE)).expect("committed BENCH_native.json loads")
}

#[test]
fn committed_baseline_has_every_tracked_preset() {
    let rep = baseline();
    assert_eq!(rep.schema_version, SCHEMA_VERSION);
    assert_eq!(rep.bench, "native_hotpath");
    let specs: Vec<&str> = rep.presets.iter().map(|p| p.spec.as_str()).collect();
    assert_eq!(specs, BASELINE_PRESETS, "baseline presets drifted from BASELINE_PRESETS");
    assert!(
        ssprop::backend::gemm::Kernel::parse(&rep.kernel).is_some(),
        "baseline kernel {:?} is not a known kernel name",
        rep.kernel
    );
    for p in &rep.presets {
        assert!(!p.timings_ns.is_empty(), "{}: no step times recorded", p.spec);
        assert!(p.ratios.contains_key("bwd_speedup_d80"), "{}: missing model bwd ratio", p.spec);
        assert!(
            p.ratios.contains_key("sparse_gemm_speedup_d50"),
            "{}: missing sparse-GEMM ratio",
            p.spec
        );
        assert!(
            p.ratios.contains_key("sparse_gemm_nr16_speedup"),
            "{}: missing wide-tile sparse-GEMM ratio (schema v4)",
            p.spec
        );
    }
    for key in [
        "fused_speedup_dense",
        "fused_speedup_d80",
        "bwd_speedup_d80_nodx",
        "gemm_speedup_256x288x128",
        "gemm_speedup_1024x576x64",
        "gemm_simd_speedup_256x288x128",
        "gemm_simd_speedup_1024x576x64",
    ] {
        assert!(rep.conv_ratios.contains_key(key), "baseline missing conv ratio {key}");
    }
}

/// The ledger halves of the committed baseline are not measurements — they
/// are analytic values the code must reproduce bit-for-bit. Recompute them
/// from the live zoo graphs and compare exactly: any drift in `flops.rs`,
/// `energy.rs`, or the zoo geometry must show up as a deliberate baseline
/// regeneration, never as silent skew.
#[test]
fn committed_ledger_matches_recomputation_exactly() {
    let rep = baseline();
    for p in &rep.presets {
        let (flops, energy) = preset_ledger(&p.spec, rep.batch).expect("ledger recompute");
        assert_eq!(p.flops, flops, "{}: FLOPs ledger drifted from committed baseline", p.spec);
        assert_eq!(p.energy, energy, "{}: energy ledger drifted from committed baseline", p.spec);
    }
}

#[test]
fn schema_roundtrips_through_util_json() {
    let rep = baseline();
    let compact = rep.to_json().to_string();
    assert_eq!(BenchReport::parse(&compact).unwrap(), rep);
    // and through the pretty (committed) form, which is what save() writes
    let pretty = rep.to_pretty_string();
    assert_eq!(BenchReport::parse(&pretty).unwrap(), rep);
}

#[test]
fn gate_passes_identical_and_noisy_rerun() {
    let base = baseline();
    let tol = Tolerance::default();
    assert!(gate(&base, &base, &tol).passed());

    // a realistic rerun: timings drift wildly, ratios wobble within band
    let mut fresh = base.clone();
    for p in &mut fresh.presets {
        for v in p.timings_ns.values_mut() {
            *v *= 23.0;
        }
        for v in p.ratios.values_mut() {
            *v *= 1.4;
        }
    }
    for v in fresh.conv_ratios.values_mut() {
        *v /= 1.9;
    }
    let res = gate(&base, &fresh, &tol);
    assert!(res.passed(), "noisy rerun should pass: {:?}", res.failures());
}

#[test]
fn gate_fails_out_of_tolerance_ratio() {
    let base = baseline();
    let mut fresh = base.clone();
    *fresh.conv_ratios.get_mut("fused_speedup_dense").unwrap() /= 100.0;
    let res = gate(&base, &fresh, &Tolerance::default());
    assert!(!res.passed());
    assert!(res.failures().iter().any(|f| f.contains("fused_speedup_dense")));
}

#[test]
fn gate_fails_changed_deterministic_value() {
    let base = baseline();
    let tol = Tolerance::default();

    let mut flops_drift = base.clone();
    flops_drift.presets[0].flops.bwd_dense += 1.0;
    assert!(!gate(&base, &flops_drift, &tol).passed());

    let mut energy_drift = base.clone();
    energy_drift.presets[1].energy.saved_j *= 1.000001;
    assert!(!gate(&base, &energy_drift, &tol).passed());

    // but a representation-level wiggle below exact_rel is not a failure
    let mut eps = base.clone();
    eps.presets[0].flops.bwd_dense *= 1.0 + 1e-15;
    assert!(gate(&base, &eps, &tol).passed());
}

#[test]
fn gate_flags_missing_preset_as_problem() {
    let base = baseline();
    let mut fresh = base.clone();
    fresh.presets.retain(|p| p.spec != "vgg-tiny-w8");
    let res = gate(&base, &fresh, &Tolerance::default());
    assert!(!res.passed());
    assert!(res.problems.iter().any(|p| p.contains("vgg-tiny-w8")));
}

#[test]
fn schema_version_mismatch_is_a_typed_error() {
    let text = std::fs::read_to_string(BASELINE).unwrap();
    let tag = format!("\"schema_version\": {SCHEMA_VERSION}");
    let bumped = text.replace(&tag, "\"schema_version\": 999");
    assert_ne!(text, bumped, "baseline should carry the current schema_version");
    match BenchReport::parse(&bumped) {
        Err(ReportError::SchemaVersion { found, expected }) => {
            assert_eq!(found, 999);
            assert_eq!(expected, SCHEMA_VERSION);
        }
        other => panic!("expected SchemaVersion error, got {other:?}"),
    }
}

/// End-to-end exit codes: `ssprop bench-check` must exit 0 when a fresh
/// report matches the committed baseline and nonzero once a metric is
/// perturbed beyond tolerance (the CI contract).
#[test]
fn bench_check_cli_exit_codes() {
    let exe = env!("CARGO_BIN_EXE_ssprop");
    let ok = Command::new(exe)
        .args(["bench-check", BASELINE, BASELINE])
        .output()
        .expect("run ssprop bench-check");
    assert!(
        ok.status.success(),
        "self-check should pass:\n{}\n{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );

    let dir = std::env::temp_dir().join("ssprop_bench_report_test");
    std::fs::create_dir_all(&dir).unwrap();

    let mut bad = baseline();
    bad.presets[0].flops.bwd_dense += 1.0;
    let bad_path = dir.join("fresh_bad.json");
    bad.save(&bad_path).unwrap();
    let fail = Command::new(exe)
        .args(["bench-check", BASELINE, bad_path.to_str().unwrap()])
        .output()
        .expect("run ssprop bench-check");
    assert!(!fail.status.success(), "perturbed ledger must fail the gate");

    // --trajectory renders a table (one row per preset) and exits 0
    let traj = Command::new(exe)
        .args(["bench-check", "--trajectory", BASELINE])
        .output()
        .expect("run ssprop bench-check --trajectory");
    assert!(traj.status.success());
    let out = String::from_utf8_lossy(&traj.stdout);
    for spec in BASELINE_PRESETS {
        assert!(out.contains(spec), "trajectory missing {spec}:\n{out}");
    }
}

/// A baseline stamped with an unknown `kernel` (or `device`) string must
/// fail `bench-check` with a typed error naming the offending key — not
/// gate timings against a mismatched machine silently.
#[test]
fn bench_check_refuses_unknown_kernel_naming_the_key() {
    let exe = env!("CARGO_BIN_EXE_ssprop");
    let dir = std::env::temp_dir().join("ssprop_bench_report_unknown_kernel");
    std::fs::create_dir_all(&dir).unwrap();

    let text = std::fs::read_to_string(BASELINE).unwrap();
    let rep = BenchReport::parse(&text).unwrap();
    let tag = format!("\"kernel\": \"{}\"", rep.kernel);
    let bad = text.replace(&tag, "\"kernel\": \"turboencabulator\"");
    assert_ne!(text, bad, "baseline should carry a kernel field");
    let bad_path = dir.join("baseline_bad_kernel.json");
    std::fs::write(&bad_path, &bad).unwrap();

    // ... whether the bad string sits in the baseline or the fresh report
    let bad_str = bad_path.to_str().unwrap();
    for args in [[bad_str, BASELINE], [BASELINE, bad_str]] {
        let out = Command::new(exe)
            .arg("bench-check")
            .args(args)
            .output()
            .expect("run ssprop bench-check");
        assert!(!out.status.success(), "unknown kernel must fail the gate");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("kernel"), "error must name the offending key:\n{err}");
        assert!(err.contains("turboencabulator"), "error must show the value:\n{err}");
    }

    // the parse layer carries the same information as a typed value
    match BenchReport::parse(&bad) {
        Err(ReportError::UnknownValue { key, value }) => {
            assert_eq!(key, "kernel");
            assert_eq!(value, "turboencabulator");
        }
        other => panic!("expected UnknownValue, got {other:?}"),
    }
}
