"""Tensorstore — the tiny binary tensor-interchange format shared with rust.

Layout (little-endian):
    8 bytes   magic  b"TSTORE01"
    u32       header length (bytes)
    header    JSON: {"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}]}
    payload   raw tensor bytes, offsets relative to payload start

dtypes: "f32" | "i32" | "u32". The rust reader/writer lives in
rust/src/tensorstore.rs; round-trip equality is tested on both sides.
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"TSTORE01"
DTYPES = {"f32": np.float32, "i32": np.int32, "u32": np.uint32}
DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
               np.dtype(np.uint32): "u32"}


def write(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    metas, blobs, off = [], [], 0
    for name, arr in tensors:
        shape = list(np.shape(arr))  # before ascontiguousarray: it promotes 0-d to 1-d
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in DTYPE_NAMES:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes()
        metas.append({"name": name, "dtype": DTYPE_NAMES[arr.dtype],
                      "shape": shape, "offset": off, "nbytes": len(raw)})
        blobs.append(raw)
        off += len(raw)
    header = json.dumps({"tensors": metas}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        payload = f.read()
    out = {}
    for m in header["tensors"]:
        dt = DTYPES[m["dtype"]]
        raw = payload[m["offset"]: m["offset"] + m["nbytes"]]
        out[m["name"]] = np.frombuffer(raw, dtype=dt).reshape(m["shape"]).copy()
    return out
