//! Determinism suite for the data-parallel executor (run in release mode
//! by CI): repeated parallel runs must be **bit-identical** at any fixed
//! worker count, a single-worker run must reproduce the serial
//! `Sequential::train_step` exactly, multi-worker loss trajectories must
//! track the serial one within accumulation tolerance (1e-5 over 10
//! steps) — gradients differ only by float re-association, never by
//! selection semantics (channel top-k is reduced globally across shards) —
//! and sharded evaluation must be bit-identical to serial evaluation at
//! every thread count (per-example losses reduce in global example
//! order). The residual `resnet-tiny` graph carries the same contract:
//! its BatchNorm statistics are reduced in fixed shard order at the
//! barrier rendezvous, so runs (parameters *and* running stats) are
//! bit-identical run-to-run per thread count, and one worker reproduces
//! the serial step bitwise.
//!
//! The persistent [`WorkerPool`] carries the exact same contract as the
//! per-step scoped crew — it runs the same shared shard bodies — so
//! pooled runs are pinned bitwise against scoped runs at every thread
//! count (t8 included; CI runs the `t8`-named tests in release), pool
//! *reuse* across train/eval phases is pinned against fresh executors,
//! and the batch-prefetch training pipeline is pinned bitwise against the
//! fully synchronous loop (epoch-tail re-key included).
//!
//! The serving path inherits the same contract: coalescing queued classify
//! requests into batches and sharding them across the pool must answer
//! **bit-identically** to serving one request at a time on one thread —
//! eval-mode layers are per-example, so neither batching nor thread count
//! may change a logit (pinned below at t ∈ {1, 2, 4} with an uneven tail
//! batch).

use std::collections::HashMap;

use ssprop::backend::{
    build_model, parse_model_spec, simple_cnn, ExecConfig, NativeBackend, ParallelExecutor,
    Sequential, SimpleCnnCfg, StepStats, WorkerPool,
};
use ssprop::coordinator::{
    checkpoint, ClassifyRequest, NativeTrainConfig, NativeTrainer, ServeConfig, Server,
};
use ssprop::schedule::{DropScheduler, Schedule};
use ssprop::tensorstore::Tensor;
use ssprop::util::rng::Pcg;

const CLASSES: usize = 4;
/// Examples are (2, 12, 12) images.
const N_IN: usize = 2 * 12 * 12;

fn model() -> Sequential {
    simple_cnn(SimpleCnnCfg { in_ch: 2, img: 12, classes: CLASSES, depth: 3, width: 8, seed: 33 })
}

/// Ten fixed batches of `bt` examples (bt = 12 shards evenly over 1/2/4
/// workers; the uneven 3/3/2/2 case uses bt = 10 over 4).
fn batches(bt: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
    (0..10)
        .map(|i| {
            let mut rng = Pcg::new(0xD0_0D + i, 2);
            let x = (0..bt * N_IN).map(|_| rng.normal()).collect();
            let y = (0..bt).map(|j| ((i as usize + j) % CLASSES) as i32).collect();
            (x, y)
        })
        .collect()
}

/// The alternating dense/sparse schedule the trajectory tests use.
fn drop_at(step: usize) -> f64 {
    if step % 2 == 0 {
        0.0
    } else {
        0.8
    }
}

#[test]
fn parallel_loss_trajectory_matches_serial_within_1e5() {
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);

    let mut serial = model();
    let mut want: Vec<StepStats> = Vec::new();
    for (step, (x, y)) in data.iter().enumerate() {
        want.push(serial.train_step(&be, x, y, drop_at(step), 0.05).unwrap());
    }

    for threads in [1usize, 2, 4] {
        let mut m = model();
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        for (step, (x, y)) in data.iter().enumerate() {
            let stats = exec.train_step(&mut m, &be, x, y, drop_at(step), 0.05).unwrap();
            let (got, exp) = (stats.loss, want[step].loss);
            assert!((got - exp).abs() < 1e-5, "t{threads} step {step}: loss {got} vs {exp}");
            assert_eq!(
                stats.kept_channels, want[step].kept_channels,
                "t{threads} step {step}: kept-channel accounting must match serial"
            );
        }
    }
}

#[test]
fn parallel_runs_are_bit_identical_at_every_thread_count() {
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);
    for threads in [1usize, 2, 4] {
        let run = || {
            let mut m = model();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().take(4).enumerate() {
                exec.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
            }
            m.flat_params()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "t{threads}: repeated runs must be bit-identical");
    }
}

#[test]
fn single_worker_executor_reproduces_serial_bitwise() {
    // With one shard the executor runs the exact serial computation (the
    // layers are shared code), so even the weights are bit-identical.
    let be = NativeBackend::new();
    let bt = 6;
    let data = batches(bt);
    let mut serial = model();
    let mut parallel = model();
    let mut exec = ParallelExecutor::new(ExecConfig::with_threads(1));
    for (step, (x, y)) in data.iter().enumerate() {
        let d = drop_at(step + 1); // start sparse: selection must agree too
        let a = serial.train_step(&be, x, y, d, 0.05).unwrap();
        let b = exec.train_step(&mut parallel, &be, x, y, d, 0.05).unwrap();
        assert_eq!(a.loss, b.loss, "step {step} loss");
        assert_eq!(a.kept_channels, b.kept_channels, "step {step} selection");
        assert_eq!(serial.flat_params(), parallel.flat_params(), "step {step} weights");
    }
}

#[test]
fn uneven_shards_stay_deterministic_and_close_to_serial() {
    // bt = 10 over 4 workers shards as 3/3/2/2 — the non-divisible path.
    let be = NativeBackend::new();
    let bt = 10;
    let data = batches(bt);
    let mut serial = model();
    let mut m = model();
    let mut exec = ParallelExecutor::new(ExecConfig::with_threads(4));
    for (step, (x, y)) in data.iter().enumerate() {
        let a = serial.train_step(&be, x, y, drop_at(step), 0.05).unwrap();
        let b = exec.train_step(&mut m, &be, x, y, drop_at(step), 0.05).unwrap();
        assert!((a.loss - b.loss).abs() < 1e-5, "step {step}: {} vs {}", a.loss, b.loss);
        assert_eq!(a.kept_channels, b.kept_channels, "step {step}");
    }
    // and the uneven run is itself reproducible
    let mut m2 = model();
    let mut exec2 = ParallelExecutor::new(ExecConfig::with_threads(4));
    for (step, (x, y)) in data.iter().enumerate() {
        exec2.train_step(&mut m2, &be, x, y, drop_at(step), 0.05).unwrap();
    }
    assert_eq!(m.flat_params(), m2.flat_params(), "uneven sharding must be bit-reproducible");
}

fn resnet() -> Sequential {
    // 2x12x12 inputs through the residual/BatchNorm preset at width 4.
    build_model(&parse_model_spec("resnet-tiny-w4-b1").unwrap(), 2, 12, CLASSES, 33).unwrap()
}

#[test]
fn resnet_tiny_runs_are_bit_identical_at_every_thread_count() {
    // BatchNorm moments and gradient sums reduce in fixed shard order at
    // the barrier rendezvous, so a fixed worker count must reproduce its
    // own parameters — BN running statistics included (flat_params carries
    // them) — bit-for-bit.
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);
    for threads in [1usize, 2, 4] {
        let run = || {
            let mut m = resnet();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().take(3).enumerate() {
                exec.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
            }
            m.flat_params()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "t{threads}: repeated resnet-tiny runs must be bit-identical");
    }
}

#[test]
fn resnet_tiny_single_worker_reproduces_serial_bitwise() {
    // One shard's statistics reduction is the identity (the first partial
    // seeds the accumulator bitwise), so the executor at t=1 replays the
    // serial residual step exactly — loss bits, selection, parameters,
    // and BN running statistics.
    let be = NativeBackend::new();
    let bt = 6;
    let data = batches(bt);
    let mut serial = resnet();
    let mut parallel = resnet();
    let mut exec = ParallelExecutor::new(ExecConfig::with_threads(1));
    for (step, (x, y)) in data.iter().take(4).enumerate() {
        let d = drop_at(step + 1); // start sparse: selection must agree too
        let a = serial.train_step(&be, x, y, d, 0.05).unwrap();
        let b = exec.train_step(&mut parallel, &be, x, y, d, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss");
        assert_eq!(a.kept_channels, b.kept_channels, "step {step} selection");
        assert_eq!(serial.flat_params(), parallel.flat_params(), "step {step} weights+stats");
    }
    // and eval (running-stat BN, per-example) is bitwise at any count
    let (x, y) = &data[5];
    let want = serial.eval_batch(&be, x, y);
    for threads in [1usize, 2, 3] {
        let mut e = ParallelExecutor::new(ExecConfig::with_threads(threads));
        let got = e.eval_batch(&serial, &be, x, y);
        assert_eq!(got.0.to_bits(), want.0.to_bits(), "t{threads} resnet eval bits");
    }
}

/// Train the residual preset a few steps on mnist-shaped data and save a
/// raw checkpoint the serving path can fold (the artifact names a
/// registered dataset, so the server is self-describing).
fn serve_checkpoint(tag: &str) -> std::path::PathBuf {
    let be = NativeBackend::new();
    let spec = parse_model_spec("resnet-tiny-w4-b1").unwrap();
    let mut m = build_model(&spec, 1, 28, 10, 7).unwrap();
    let mut rng = Pcg::new(0xBEEF, 3);
    for step in 0..3 {
        let x: Vec<f32> = (0..6 * 28 * 28).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..6).map(|j| ((j + step) % 10) as i32).collect();
        m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
    }
    let dir = std::env::temp_dir().join(format!("ssprop_serve_det_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rn.tstore");
    let state: HashMap<String, Tensor> = m.state_tensors().into_iter().collect();
    checkpoint::save_tensors(&path, &state, "native_mnist:resnet-tiny-w4-b1", 3).unwrap();
    path
}

fn serve_requests(n: usize, n_in: usize) -> Vec<ClassifyRequest> {
    let mut rng = Pcg::new(0xFACE, 5);
    (0..n)
        .map(|i| ClassifyRequest {
            id: i as u64,
            pixels: (0..n_in).map(|_| rng.normal()).collect(),
        })
        .collect()
}

#[test]
fn serve_batches_are_bit_identical_to_one_at_a_time_at_any_thread_count() {
    let ck = serve_checkpoint("bitwise");
    let n = 11usize; // at batch 4 the queue coalesces as 4 + 4 + 3 (uneven tail)

    // Reference: every request served alone on a single thread.
    let cfg1 = ServeConfig { batch: 1, threads: 1 };
    let mut solo = Server::from_checkpoint(&ck, Some("resnet-tiny-w4-b1"), cfg1).unwrap();
    assert!(solo.folded() > 0, "the residual preset folds its BatchNorms at load");
    let (want, solo_stats) = solo.serve(serve_requests(n, solo.input_len()));
    assert_eq!(solo_stats.batches, n);

    for threads in [1usize, 2, 4] {
        let cfg = ServeConfig { batch: 4, threads };
        let mut srv = Server::from_checkpoint(&ck, None, cfg).unwrap();
        let (got, stats) = srv.serve(serve_requests(n, srv.input_len()));
        assert_eq!(stats.batches, 3, "11 requests at batch 4 coalesce as 4 + 4 + 3");
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id, "answers keep request order");
            assert_eq!(g.class, w.class, "t{threads} request {}", g.id);
            for (a, b) in g.logits.iter().zip(&w.logits) {
                assert_eq!(a.to_bits(), b.to_bits(), "t{threads} request {}: logit bits", g.id);
            }
        }
    }
}

#[test]
fn serve_answers_agree_with_eval_batch_accuracy() {
    let ck = serve_checkpoint("evalx");
    let cfg = ServeConfig { batch: 4, threads: 2 };
    let mut srv = Server::from_checkpoint(&ck, None, cfg).unwrap();
    let (n, n_in) = (10usize, srv.input_len());
    let mut rng = Pcg::new(0xAB, 9);
    let x: Vec<f32> = (0..n * n_in).map(|_| rng.normal()).collect();
    let y: Vec<i32> = (0..n).map(|j| (j % 10) as i32).collect();
    let reqs: Vec<ClassifyRequest> = (0..n)
        .map(|i| ClassifyRequest { id: i as u64, pixels: x[i * n_in..(i + 1) * n_in].to_vec() })
        .collect();
    let (answers, stats) = srv.serve(reqs);
    assert_eq!(stats.answered, n);
    let hits = answers.iter().zip(&y).filter(|(a, &label)| a.class == label as usize).count();
    let (_, acc) = srv.eval_batch(&x, &y);
    assert_eq!(acc, hits as f64 / n as f64, "serve argmax must agree with eval accuracy");
}

#[test]
fn pooled_runs_match_scoped_executor_bitwise_up_to_t8() {
    // The persistent pool dispatches the same shared shard bodies the
    // scoped crew spawns, so at every worker count — t8's
    // more-workers-than-examples shape included — a pooled run must
    // reproduce the scoped run's parameters bit-for-bit.
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);
    for threads in [1usize, 2, 4, 8] {
        let scoped = {
            let mut m = model();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().take(4).enumerate() {
                exec.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
            }
            m.flat_params()
        };
        let pooled = {
            let mut m = model();
            let mut pool = WorkerPool::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().take(4).enumerate() {
                pool.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
            }
            m.flat_params()
        };
        assert_eq!(scoped, pooled, "t{threads}: pooled run must match the scoped crew bitwise");
    }
}

#[test]
fn resnet_tiny_pooled_t8_matches_scoped_bitwise() {
    // Same pin through the residual/BatchNorm graph: the pool's barrier
    // rendezvous reduces BN statistics in the same fixed shard order, so
    // parameters *and* running stats match the scoped crew bitwise at t8.
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);
    for threads in [2usize, 8] {
        let run = |pool_mode: bool| {
            let mut m = resnet();
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let mut pool = WorkerPool::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().take(3).enumerate() {
                if pool_mode {
                    pool.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
                } else {
                    exec.train_step(&mut m, &be, x, y, drop_at(step + 1), 0.05).unwrap();
                }
            }
            m.flat_params()
        };
        assert_eq!(run(false), run(true), "t{threads}: resnet pooled vs scoped bits");
    }
}

#[test]
fn single_worker_pool_reproduces_serial_bitwise() {
    // t=1 is the strongest pin: one pool worker replays the exact serial
    // computation, so even the weights are bit-identical step by step.
    let be = NativeBackend::new();
    let bt = 6;
    let data = batches(bt);
    let mut serial = model();
    let mut pooled = model();
    let mut pool = WorkerPool::new(ExecConfig::with_threads(1));
    for (step, (x, y)) in data.iter().enumerate() {
        let d = drop_at(step + 1); // start sparse: selection must agree too
        let a = serial.train_step(&be, x, y, d, 0.05).unwrap();
        let b = pool.train_step(&mut pooled, &be, x, y, d, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss");
        assert_eq!(a.kept_channels, b.kept_channels, "step {step} selection");
        assert_eq!(serial.flat_params(), pooled.flat_params(), "step {step} weights");
    }
}

#[test]
fn pool_reuse_across_train_and_eval_matches_fresh_executors() {
    // One pool reused across interleaved train/eval phases (the trainer
    // and server lifecycle) must be bit-identical to running each phase
    // on a freshly constructed scoped executor.
    let be = NativeBackend::new();
    let bt = 12;
    let data = batches(bt);
    for threads in [1usize, 2, 4] {
        let mut m_ref = model();
        let mut m_pool = model();
        let mut pool = WorkerPool::new(ExecConfig::with_threads(threads));
        for phase in 0..2 {
            // train phase: 3 steps, fresh executor on the reference side
            let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
            for (step, (x, y)) in data.iter().skip(phase * 3).take(3).enumerate() {
                let d = drop_at(phase * 3 + step);
                exec.train_step(&mut m_ref, &be, x, y, d, 0.05).unwrap();
                pool.train_step(&mut m_pool, &be, x, y, d, 0.05).unwrap();
            }
            // eval phase: fresh executor again on the reference side
            let (x, y) = &data[7 + phase];
            let mut exec2 = ParallelExecutor::new(ExecConfig::with_threads(threads));
            let want = exec2.eval_batch(&m_ref, &be, x, y);
            let got = pool.eval_batch(&m_pool, &be, x, y);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "t{threads} phase {phase} eval loss");
            assert_eq!(got.1, want.1, "t{threads} phase {phase} eval accuracy");
        }
        assert_eq!(
            m_ref.flat_params(),
            m_pool.flat_params(),
            "t{threads}: reused pool must end bit-identical to fresh executors"
        );
    }
}

#[test]
fn pipelined_training_is_bit_identical_to_sync_at_every_thread_count() {
    // The batch-prefetch pipeline assembles the next batch while the
    // current one trains; the stream delivers the same batches (epoch-tail
    // included, with its workspace re-key at the smaller batch size) in
    // the same order, so whole runs must match the synchronous loop
    // bitwise — final eval, every per-step loss, and the FLOPs ledger.
    for threads in [1usize, 2, 4] {
        let mk = |pipeline: bool| {
            let mut cfg = NativeTrainConfig::quick("mnist", 2, 4);
            cfg.batch = 30; // 2048 examples -> an uneven tail of 8 per epoch
            cfg.threads = threads;
            cfg.include_tail = true;
            cfg.pipeline = pipeline;
            cfg.scheduler =
                DropScheduler::new(Schedule::EpochBar { period_epochs: 2 }, 0.8, 2, 4);
            let mut t = NativeTrainer::new(cfg).unwrap();
            let (loss, acc) = t.run().unwrap();
            (loss, acc, t.metrics.losses.clone(), t.metrics.flops_actual)
        };
        let (l_s, a_s, losses_s, fl_s) = mk(false);
        let (l_p, a_p, losses_p, fl_p) = mk(true);
        assert_eq!(l_s.to_bits(), l_p.to_bits(), "t{threads}: final eval loss bits");
        assert_eq!(a_s, a_p, "t{threads}: final eval accuracy");
        assert_eq!(losses_s.len(), 10, "(4 capped full batches + tail) x 2 epochs");
        assert_eq!(losses_p.len(), losses_s.len());
        for (i, (p, s)) in losses_p.iter().zip(&losses_s).enumerate() {
            assert_eq!(p.to_bits(), s.to_bits(), "t{threads} step {i}: loss bits");
        }
        assert_eq!(fl_p, fl_s, "t{threads}: FLOPs ledger");
    }
}

#[test]
fn sharded_eval_is_bit_identical_across_thread_counts() {
    // Evaluation reduces per-example losses in global example order, so
    // any worker count must reproduce the serial loss *bitwise* — no
    // accumulation tolerance here.
    let be = NativeBackend::new();
    let bt = 10;
    let data = batches(bt);
    let mut m = model();
    for (step, (x, y)) in data.iter().take(3).enumerate() {
        m.train_step(&be, x, y, drop_at(step), 0.05).unwrap();
    }
    let (x, y) = &data[5];
    let want = m.eval_batch(&be, x, y);
    for threads in [1usize, 2, 3, 4, 8] {
        let mut exec = ParallelExecutor::new(ExecConfig::with_threads(threads));
        let got = exec.eval_batch(&m, &be, x, y);
        assert_eq!(
            got.0.to_bits(),
            want.0.to_bits(),
            "t{threads}: eval loss must be bit-identical to serial"
        );
        assert_eq!(got.1, want.1, "t{threads}: eval accuracy");
    }
}
