"""Shared layers for the L2 model zoo.

All parameters live in nested dicts of jnp arrays; BatchNorm running
statistics live in a parallel ``state`` dict. Every convolution is an
:func:`compile.ssprop.ssprop_conv`, so the whole zoo inherits scheduled
sparse back-propagation from a single runtime ``drop_rate`` scalar.

Initialization is Kaiming-normal (paper: "all models are initialized with
Kaiming Initialization"), biases zero, BN gamma=1/beta=0.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..ssprop import ConvSpec, ssprop_conv

Params = Dict[str, Any]

BN_EPS = 1e-5
BN_MOMENTUM = 0.1


# -- init -------------------------------------------------------------------

def kaiming_conv(key, cin: int, cout: int, k: int):
    fan_in = cin * k * k
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (cout, cin, k, k), jnp.float32) * std


def init_conv(key, cin: int, cout: int, k: int) -> Params:
    return {"w": kaiming_conv(key, cin, cout, k), "b": jnp.zeros((cout,), jnp.float32)}


def init_bn(c: int) -> Params:
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


def init_bn_state(c: int) -> Params:
    return {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def init_gn(c: int) -> Params:
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


def init_dense(key, nin: int, nout: int) -> Params:
    std = math.sqrt(2.0 / nin)
    return {
        "w": jax.random.normal(key, (nin, nout), jnp.float32) * std,
        "b": jnp.zeros((nout,), jnp.float32),
    }


# -- ops --------------------------------------------------------------------

def conv(p: Params, x, drop_rate, key, *, stride=1, padding=1,
         mode="channel", select="topk"):
    spec = ConvSpec(stride=stride, padding=padding, mode=mode, select=select)
    return ssprop_conv(x, p["w"], p["b"], drop_rate, key, spec)


def batchnorm(p: Params, s: Params, x, *, train: bool):
    """Returns (y, new_state). Running stats update only when train=True."""
    if train:
        mu = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.var(x, axis=(0, 2, 3))
        new_s = {
            "mean": (1 - BN_MOMENTUM) * s["mean"] + BN_MOMENTUM * mu,
            "var": (1 - BN_MOMENTUM) * s["var"] + BN_MOMENTUM * var,
        }
    else:
        mu, var = s["mean"], s["var"]
        new_s = s
    inv = jax.lax.rsqrt(var + BN_EPS)
    y = (x - mu[None, :, None, None]) * inv[None, :, None, None]
    return y * p["gamma"][None, :, None, None] + p["beta"][None, :, None, None], new_s


def groupnorm(p: Params, x, *, groups: int = 4):
    bt, c, h, w = x.shape
    g = min(groups, c)
    xg = x.reshape(bt, g, c // g, h, w)
    mu = jnp.mean(xg, axis=(2, 3, 4), keepdims=True)
    var = jnp.var(xg, axis=(2, 3, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + BN_EPS)
    x = xg.reshape(bt, c, h, w)
    return x * p["gamma"][None, :, None, None] + p["beta"][None, :, None, None]


def dense(p: Params, x):
    return x @ p["w"] + p["b"]


def dropout(x, rate, key):
    """Inverted dropout with *runtime* rate (0 => identity, exactly)."""
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape).astype(x.dtype)
    # rate==0 -> keep==1 -> mask==1 and division is exact identity.
    return jnp.where(rate > 0, x * mask / jnp.maximum(keep, 1e-6), x)


def fold_key(key_u32, i: int):
    """Derive a per-layer (2,) uint32 key from the step key input (cheap
    Weyl-sequence fold; only consumed by random-select and Dropout)."""
    return (key_u32 + jnp.asarray([(i * 2654435761) % (2 ** 32), i], jnp.uint32)).astype(jnp.uint32)


def global_avg_pool(x):
    return jnp.mean(x, axis=(2, 3))


def silu(x):
    return x * jax.nn.sigmoid(x)


# -- FLOPs inventory helpers (mirrors rust/src/flops) -------------------------

def conv_out(h: int, k: int, s: int, p: int) -> int:
    return (h + 2 * p - k) // s + 1


class Inventory:
    """Records conv/bn/dropout layer geometry while a model is constructed,
    for the rust-side FLOPs accounting (manifest ``layers`` section)."""

    def __init__(self):
        self.convs = []      # dicts: cin,cout,k,stride,padding,hin,win,hout,wout
        self.bns = []        # dicts: c,h,w
        self.dropouts = []   # dicts: c,h,w

    def conv(self, cin, cout, k, s, p, hin, win):
        ho, wo = conv_out(hin, k, s, p), conv_out(win, k, s, p)
        self.convs.append(dict(cin=cin, cout=cout, k=k, stride=s, padding=p,
                               hin=hin, win=win, hout=ho, wout=wo))
        return ho, wo

    def bn(self, c, h, w):
        self.bns.append(dict(c=c, h=h, w=w))

    def dropout(self, c, h, w):
        self.dropouts.append(dict(c=c, h=h, w=w))

    def as_json(self):
        return {"convs": self.convs, "bns": self.bns, "dropouts": self.dropouts}
