//! Stub of the `xla` PJRT FFI crate, mirroring exactly the API surface the
//! `ssprop` crate's `pjrt` feature uses.
//!
//! The real crate links libxla/PJRT, which is unavailable in the offline
//! vendor set. This stub keeps `--features pjrt` *compiling* everywhere so
//! the feature-gated runtime cannot rot:
//!
//! * [`Literal`] is implemented for real (host buffer + shape + dtype), so
//!   literal/tensor conversion code and checkpoint round-trips work;
//! * PJRT entry points ([`PjRtClient::compile`],
//!   [`HloModuleProto::from_text_file`], execution) fail with an explicit
//!   "stub" error — executing compiled HLO needs the real crate, installed
//!   by pointing a `[patch."..."]` at an `xla` build with the PJRT
//!   toolchain (see README "PJRT backend").

use std::borrow::Borrow;

/// Error type mirroring the real crate's (only `Debug` is relied upon).
pub struct Error(pub String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: cannot {what} without the real PJRT toolchain — \
             patch the `xla` dependency with a real build (see README)"
        ))
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element dtypes used by the ssprop runtime (subset of XLA's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    F32,
    F64,
    S32,
    S64,
    U32,
    U64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred => 1,
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::F64 | ElementType::S64 | ElementType::U64 => 8,
        }
    }
}

/// Rust scalar types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le(b: &[u8]) -> u32 {
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// Array shape: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal: shape + little-endian bytes. Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal size mismatch: shape {dims:?} x {ty:?} needs {} bytes, got {}",
                elems * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty, dims: dims.iter().map(|&d| d as i64).collect() },
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.shape.ty != T::TY {
            return Err(Error(format!("dtype mismatch: literal is {:?}", self.shape.ty)));
        }
        Ok(self.data.chunks_exact(self.shape.ty.byte_size()).map(T::from_le).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let size = self.shape.ty.byte_size();
        if self.shape.ty != T::TY || self.data.len() < size {
            return Err(Error(format!("cannot read scalar from {:?} literal", self.shape.ty)));
        }
        Ok(T::from_le(&self.data[..size]))
    }

    /// Tuple literals are only produced by execution, which the stub
    /// cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("decompose a tuple literal (only execution produces tuples)"))
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("parse HLO text"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. Construction succeeds (it is lazy in the runtime's usage);
/// compiling or executing anything fails with the stub error.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compile an executable"))
    }
}

/// Compiled executable handle (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execute"))
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("fetch a device buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals: Vec<f32> = vec![1.0, -2.5, 3.5, 0.0, 7.0, -8.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
            .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn pjrt_paths_fail_loudly() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _private: () };
        let err = format!("{:?}", client.compile(&comp).err().unwrap());
        assert!(err.contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
